//! Cross-layer equivalence: the AOT-compiled XLA artifact (L1 Pallas
//! kernel lowered through L2 jax) must match the native Rust interpreter
//! bit-for-bit, and the window-aggregation artifact must match a scalar
//! reference. This is the three-layer contract of DESIGN.md §7.
//!
//! The whole suite is gated on the `xla` cargo feature (the default
//! build is std-only). Enabling the feature requires the vendored
//! `xla`/`anyhow` crates wired into Cargo.toml first (see the notes
//! there and in rack/README.md); once it compiles, a machine without
//! AOT artifacts on disk (`make artifacts`) skips each test with a
//! notice instead of failing.

#![cfg(feature = "xla")]

use pulse::interp::{logic_pass, Workspace};
use pulse::isa::{Asm, Status};
use pulse::runtime::PjrtRuntime;
use pulse::util::prng::Rng;

/// Skip (returning `None`) with a notice when the artifacts directory
/// is absent, so `cargo test --features xla` passes on machines that
/// never ran `make artifacts`.
fn runtime() -> Option<PjrtRuntime> {
    let dir = PjrtRuntime::default_dir();
    if !dir.exists() {
        eprintln!(
            "skipping runtime test: no AOT artifacts at {} \
             (run `make artifacts`)",
            dir.display()
        );
        return None;
    }
    Some(PjrtRuntime::new(dir).expect("pjrt client"))
}

#[test]
fn logic_step_artifact_matches_native_interpreter() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load_logic_step(32).expect("artifact (make artifacts?)");
    let p = pulse::testgen::list_find_program();

    let mut rng = Rng::new(99);
    let mut xla_ws: Vec<Workspace> = (0..32)
        .map(|i| {
            let mut w = Workspace::new();
            w.sp[0] = (i % 4) as i64; // search keys
            w.data[0] = rng.below(4) as i64; // node.key
            w.data[1] = rng.next_i64(); // node.value
            w.data[2] = if rng.chance(0.5) { rng.next_i64() } else { 0 };
            w
        })
        .collect();
    let mut native_ws = xla_ws.clone();

    let statuses = exe.run(&p, &mut xla_ws).expect("xla run");
    for (i, w) in native_ws.iter_mut().enumerate() {
        let r = logic_pass(&p, w);
        assert_eq!(statuses[i], r.status, "lane {i} status");
    }
    assert_eq!(xla_ws, native_ws, "workspace divergence");
}

#[test]
fn logic_step_artifact_matches_on_random_programs() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load_logic_step(32).expect("artifact");
    let mut rng = Rng::new(7);

    for case in 0..10 {
        let p = pulse::testgen::random_verified_program(&mut rng, 20);
        let mut xla_ws: Vec<Workspace> = (0..32)
            .map(|_| pulse::testgen::random_workspace(&mut rng))
            .collect();
        let mut native_ws = xla_ws.clone();
        let statuses = exe.run(&p, &mut xla_ws).expect("xla run");
        for (i, w) in native_ws.iter_mut().enumerate() {
            let r = logic_pass(&p, w);
            assert_eq!(
                statuses[i], r.status,
                "case {case} lane {i} status (program: {p:?})"
            );
        }
        assert_eq!(xla_ws, native_ws, "case {case} workspace divergence");
    }
}

#[test]
fn logic_step_b256_artifact_loads_and_runs() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load_logic_step(256).expect("artifact");
    let mut a = Asm::new();
    a.spl(1, 0);
    a.addi(1, 1, 1000);
    a.sps(1, 1);
    a.ret();
    let p = a.finish(1).unwrap();
    let mut ws: Vec<Workspace> = (0..256)
        .map(|i| {
            let mut w = Workspace::new();
            w.sp[0] = i as i64;
            w
        })
        .collect();
    let st = exe.run(&p, &mut ws).unwrap();
    assert!(st.iter().all(|&s| s == Status::Return));
    for (i, w) in ws.iter().enumerate() {
        assert_eq!(w.sp[1], i as i64 + 1000);
    }
}

#[test]
fn partial_batch_is_padded() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load_logic_step(32).expect("artifact");
    let mut a = Asm::new();
    a.movi(1, 7);
    a.sps(1, 0);
    a.ret();
    let p = a.finish(1).unwrap();
    let mut ws: Vec<Workspace> = (0..5).map(|_| Workspace::new()).collect();
    let st = exe.run(&p, &mut ws).unwrap();
    assert_eq!(st.len(), 5);
    assert!(ws.iter().all(|w| w.sp[0] == 7));
}

#[test]
fn window_agg_artifact_matches_scalar_reference() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load_window_agg(4096, 64).expect("artifact");
    let mut rng = Rng::new(5);
    let values: Vec<f32> = (0..4096)
        .map(|_| (rng.next_normal() * 100.0) as f32)
        .collect();
    let out = exe.run(&values).unwrap();
    assert_eq!(out.sum.len(), 64);
    for w in 0..64 {
        let chunk = &values[w * 64..(w + 1) * 64];
        let sum: f32 = chunk.iter().sum();
        let min = chunk.iter().cloned().fold(f32::INFINITY, f32::min);
        let max = chunk.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        assert!(
            (out.sum[w] - sum).abs() <= 1e-2 * sum.abs().max(1.0),
            "w{w} sum {} vs {}",
            out.sum[w],
            sum
        );
        assert_eq!(out.min[w], min, "w{w} min");
        assert_eq!(out.max[w], max, "w{w} max");
        assert!((out.mean[w] - sum / 64.0).abs() <= 1e-2);
    }
}
