//! Property tests over the ISA core: verifier soundness, wire-format
//! round trips, interpreter invariants (bounded steps, determinism,
//! the scratchpad migration contract).

use pulse::interp::logic_pass;
use pulse::isa::{verify, Instr, Op, Program, Status, MAX_INSTRS};
use pulse::testgen::{random_verified_program, random_workspace};
use pulse::util::prng::Rng;
use pulse::util::ptest::run_prop;
use pulse::{prop_assert, prop_assert_eq};

#[test]
fn prop_verified_programs_terminate_within_length_bound() {
    run_prop("terminate", 0xA11CE, 500, |rng| {
        let p = random_verified_program(rng, MAX_INSTRS);
        let mut ws = random_workspace(rng);
        let r = logic_pass(&p, &mut ws);
        // forward-only jumps: dynamic steps <= static length (+1 for
        // the fall-off-the-end trap probe)
        prop_assert!(
            (r.steps as usize) <= p.len() + 1,
            "steps {} > len {}",
            r.steps,
            p.len()
        );
        prop_assert!(r.status != Status::Running);
        Ok(())
    });
}

#[test]
fn prop_program_wire_round_trip() {
    run_prop("wire", 0xB0B, 300, |rng| {
        let p = random_verified_program(rng, 32);
        let buf = p.encode();
        let q = Program::decode(&buf).ok_or("decode failed")?;
        prop_assert_eq!(p, q);
        Ok(())
    });
}

#[test]
fn prop_packed_form_preserves_instructions() {
    run_prop("pack", 0xCAFE, 200, |rng| {
        let p = random_verified_program(rng, 24);
        let (ops, imm) = p.pack();
        for (k, i) in p.instrs.iter().enumerate() {
            prop_assert_eq!(ops[k * 4], i.op as i32);
            prop_assert_eq!(ops[k * 4 + 1], i.a as i32);
            prop_assert_eq!(imm[k], i.imm);
        }
        // padding slots trap
        for slot in p.len()..MAX_INSTRS {
            prop_assert_eq!(ops[slot * 4], Op::Trap as i32);
        }
        Ok(())
    });
}

#[test]
fn prop_interpreter_is_deterministic() {
    run_prop("deterministic", 0xD00D, 200, |rng| {
        let p = random_verified_program(rng, 32);
        let ws0 = random_workspace(rng);
        let mut a = ws0.clone();
        let mut b = ws0;
        let ra = logic_pass(&p, &mut a);
        let rb = logic_pass(&p, &mut b);
        prop_assert_eq!(ra, rb);
        prop_assert!(a == b, "workspaces diverged");
        Ok(())
    });
}

#[test]
fn prop_corrupt_programs_rejected_by_verifier() {
    run_prop("mutation", 0x5EED, 300, |rng| {
        let p = random_verified_program(rng, 16);
        let mut instrs = p.instrs.clone();
        let idx = rng.below(instrs.len() as u64) as usize;
        match rng.below(3) {
            0 => {
                if idx == 0 {
                    return Ok(()); // self-jump also rejected; skip
                }
                instrs[idx] = Instr::new(Op::Jmp, 0, 0, 0, 0); // backward
            }
            1 => instrs[idx] = Instr::new(Op::Movi, 200, 0, 0, 1),
            _ => instrs[idx] = Instr::new(Op::Ldd, 1, 0, 0, 9999),
        }
        // re-terminate if we clobbered the tail terminal with a
        // non-terminal? (Movi/Ldd at the tail also fails the tail rule,
        // which still counts as rejection.)
        let mutated = Program::new(instrs, p.load_words);
        prop_assert!(
            verify(&mutated).is_err(),
            "verifier accepted a corrupt program at idx {}",
            idx
        );
        Ok(())
    });
}

#[test]
fn prop_scratchpad_is_the_only_cross_iteration_state() {
    // With registers reset (as the accelerator does before each pass)
    // and identical r0/sp/data, outcomes must match — the §5 migration
    // contract that lets traversals move between memory nodes.
    run_prop("sp-contract", 0xFACE, 200, |rng| {
        let p = random_verified_program(rng, 24);
        let base = random_workspace(rng);
        let mut a = base.clone();
        let mut b = base.clone();
        a.regs = [0; pulse::isa::NREG];
        b.regs = [0; pulse::isa::NREG];
        a.regs[0] = 0x1234;
        b.regs[0] = 0x1234;
        let ra = logic_pass(&p, &mut a);
        let rb = logic_pass(&p, &mut b);
        prop_assert_eq!(ra, rb);
        prop_assert!(a.sp == b.sp && a.data == b.data);
        Ok(())
    });
}

#[test]
fn prop_status_codes_round_trip_via_i32() {
    let mut rng = Rng::new(1);
    for _ in 0..100 {
        let v = (rng.below(4)) as i32;
        let s = Status::from_i32(v);
        assert_eq!(s as i32, v);
    }
}

#[test]
fn prop_cost_model_monotone_in_length() {
    use pulse::isa::CostModel;
    run_prop("cost-monotone", 0x12345, 100, |rng| {
        let p = random_verified_program(rng, 30);
        let m = CostModel::default();
        let c = m.cost(&p);
        prop_assert!(c.t_c_ns > 0.0 && c.t_d_ns > 0.0);
        // appending a NOP before the terminal increases t_c
        let mut longer = p.instrs.clone();
        let term = longer.pop().unwrap();
        longer.push(Instr::new(Op::Nop, 0, 0, 0, 0));
        longer.push(term);
        if longer.len() <= MAX_INSTRS {
            let p2 = Program::new(longer, p.load_words);
            prop_assert!(m.cost(&p2).t_c_ns > c.t_c_ns);
        }
        Ok(())
    });
}
