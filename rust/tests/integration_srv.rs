//! Lifecycle + conformance tests of the TCP wire-serving tier (`srv`).
//!
//! Everything runs over real loopback sockets against ephemeral binds
//! (`127.0.0.1:0`) — no fixed ports, CI-safe. The conformance tests
//! pin the serving tier's core contract: an op stream served over the
//! wire produces scratchpads **bit-identical** to in-process
//! execution of the same stream, because client (stage chaining) and
//! server (single-traversal execution) reuse the exact resolve/visit
//! logic of the in-process engines.

use std::sync::Arc;
use std::thread::JoinHandle;

use pulse::backend::TraversalBackend;
use pulse::bench_support::{
    build_serving_ops, make_backend, ServingSpec,
};
use pulse::ds::ForwardList;
use pulse::isa::{Status, SP_WORDS};
use pulse::live::LiveBackend;
use pulse::rack::{Rack, RackConfig};
use pulse::srv::loadgen::WireClient;
use pulse::srv::wire::{
    crc32, encode_frame, ErrCode, Frame, MIN_PAYLOAD,
};
use pulse::bench_support::check_stats_partition;
use pulse::srv::{
    fetch_stats, run_loadgen, LoadgenConfig, Server, ServerHandle,
    SrvConfig, SrvSummary,
};

const NODES: usize = 2;

fn rack_cfg() -> RackConfig {
    RackConfig::small(NODES)
}

/// Start a server for `spec` on an ephemeral port; returns the handle,
/// the join handle for its summary, and the op stream materialized
/// against an identically built shadow rack (the loadgen contract).
fn start_server(
    backend_kind: &str,
    spec: &ServingSpec,
    cfg: SrvConfig,
) -> (ServerHandle, JoinHandle<SrvSummary>, Vec<pulse::rack::Op>) {
    let mut backend = make_backend(backend_kind, rack_cfg());
    let _ = build_serving_ops(backend.rack_mut(), spec);
    let (server, handle) =
        Server::bind(backend, "127.0.0.1:0", cfg).expect("bind");
    let join = std::thread::spawn(move || server.run());
    let mut shadow = Rack::new(rack_cfg());
    let ops = build_serving_ops(&mut shadow, spec);
    (handle, join, ops)
}

/// In-process ground truth: replay the same stream sequentially
/// through the functional substrate of an identically built rack.
fn expected_sps(
    spec: &ServingSpec,
    ops: &[pulse::rack::Op],
) -> Vec<[i64; SP_WORDS]> {
    let mut rack = Rack::new(rack_cfg());
    let _ = build_serving_ops(&mut rack, spec);
    ops.iter().map(|op| rack.run_op_functional(op)).collect()
}

#[test]
fn ycsb_c_loopback_bit_matches_in_process_serving() {
    let spec = ServingSpec {
        workload: "mix-c".into(),
        keys: 4_000,
        ops: 1_200,
        ..ServingSpec::default()
    };
    let (handle, join, ops) =
        start_server("live", &spec, SrvConfig::default());

    // in-process reference #1: the functional oracle
    let want = expected_sps(&spec, &ops);
    // in-process reference #2: LiveBackend::serve with recording —
    // read-only stream, so concurrent serving is order-insensitive
    let mut live = LiveBackend::new(Rack::new(rack_cfg()));
    let _ = build_serving_ops(live.rack_mut(), &spec);
    live.record_results(true);
    let rep = live.serve_batch(&ops, 16);
    assert_eq!(rep.completed as usize, ops.len());
    assert_eq!(live.last_results(), &want[..], "live vs oracle");

    // over the wire: pipelined across 3 connections
    let report = run_loadgen(
        &LoadgenConfig {
            addr: handle.addr().to_string(),
            conns: 3,
            depth: 8,
            record_results: true,
            ..LoadgenConfig::default()
        },
        ops.clone(),
    )
    .expect("loadgen");
    assert_eq!(report.completed as usize, ops.len());
    assert_eq!(report.busy, 0, "sub-saturating load must never BUSY");
    assert_eq!(report.errors, 0);
    assert_eq!(report.trapped, 0);
    for (i, got) in report.results.iter().enumerate() {
        assert_eq!(
            got.as_ref(),
            Some(&want[i]),
            "op {i} scratchpad diverged over the wire"
        );
    }

    handle.shutdown();
    let summary = join.join().unwrap();
    assert_eq!(summary.engine.report.completed as usize, ops.len());
    assert_eq!(summary.srv.decode_errors, 0);
    assert_eq!(summary.backend.wire_busy, 0);
}

#[test]
fn mixed_ab_stream_bit_matches_when_serialized() {
    // writes make ordering observable, so the wire run is serialized
    // (1 conn, depth 1) and compared against sequential functional
    // replay — the same rule PR 4's mutating conformance pinned
    for mix in ["mix-a", "mix-b"] {
        let spec = ServingSpec {
            workload: mix.into(),
            keys: 2_000,
            ops: 600,
            ..ServingSpec::default()
        };
        let (handle, join, ops) =
            start_server("live", &spec, SrvConfig::default());
        let want = expected_sps(&spec, &ops);
        let report = run_loadgen(
            &LoadgenConfig {
                addr: handle.addr().to_string(),
                conns: 1,
                depth: 1,
                record_results: true,
                ..LoadgenConfig::default()
            },
            ops.clone(),
        )
        .expect("loadgen");
        assert_eq!(report.completed as usize, ops.len(), "{mix}");
        assert_eq!(report.errors, 0);
        assert_eq!(report.busy, 0);
        for (i, got) in report.results.iter().enumerate() {
            assert_eq!(
                got.as_ref(),
                Some(&want[i]),
                "{mix} op {i} diverged"
            );
        }
        handle.shutdown();
        let summary = join.join().unwrap();
        assert_eq!(summary.engine.report.trapped, 0, "{mix}");
    }
}

#[test]
fn multi_stage_scan_ops_chain_client_side() {
    // skiplist YCSB-E: two-stage ops with repeat_while continuation —
    // the client library's stage chaining over real sockets
    let spec = ServingSpec {
        workload: "skiplist".into(),
        keys: 1_500,
        ops: 400,
        max_scan: 40,
        ..ServingSpec::default()
    };
    let (handle, join, ops) =
        start_server("live", &spec, SrvConfig::default());
    let want = expected_sps(&spec, &ops);
    let report = run_loadgen(
        &LoadgenConfig {
            addr: handle.addr().to_string(),
            conns: 2,
            depth: 6,
            record_results: true,
            ..LoadgenConfig::default()
        },
        ops.clone(),
    )
    .expect("loadgen");
    assert_eq!(report.completed as usize, ops.len());
    assert_eq!(report.errors, 0);
    for (i, got) in report.results.iter().enumerate() {
        assert_eq!(got.as_ref(), Some(&want[i]), "scan op {i}");
    }
    handle.shutdown();
    let summary = join.join().unwrap();
    // scans require more wire requests than ops (continuation rounds)
    assert!(
        summary.srv.requests > summary.engine.report.completed / 2
            && summary.srv.requests as usize >= ops.len(),
        "requests={} ops={}",
        summary.srv.requests,
        ops.len()
    );
}

#[test]
fn inline_backends_serve_the_same_bytes() {
    // a model backend (cache) behind the wire tier shares the
    // functional substrate: identical scratchpads, inline execution
    let spec = ServingSpec {
        workload: "mix-c".into(),
        keys: 1_000,
        ops: 300,
        ..ServingSpec::default()
    };
    let (handle, join, ops) =
        start_server("cache", &spec, SrvConfig::default());
    let want = expected_sps(&spec, &ops);
    let report = run_loadgen(
        &LoadgenConfig {
            addr: handle.addr().to_string(),
            conns: 2,
            depth: 4,
            record_results: true,
            ..LoadgenConfig::default()
        },
        ops.clone(),
    )
    .expect("loadgen");
    assert_eq!(report.completed as usize, ops.len());
    for (i, got) in report.results.iter().enumerate() {
        assert_eq!(got.as_ref(), Some(&want[i]), "op {i}");
    }
    handle.shutdown();
    let _ = join.join().unwrap();
}

/// A server whose backend holds one long list (slow sum ops), plus
/// everything the client side needs to drive it; used by the
/// backpressure + pipelining tests.
struct SlowListServer {
    handle: ServerHandle,
    join: JoinHandle<SrvSummary>,
    iter: Arc<pulse::compiler::CompiledIter>,
    head: u64,
}

fn slow_list_server(cfg: SrvConfig, len: i64) -> SlowListServer {
    let mut backend = make_backend("live", rack_cfg());
    let (head, iter) = {
        let rack = backend.rack_mut();
        let mut l = ForwardList::new();
        for i in 1..=len {
            l.push(rack, i);
        }
        (l.head, l.sum_program())
    };
    let (server, handle) =
        Server::bind(backend, "127.0.0.1:0", cfg).expect("bind");
    let join = std::thread::spawn(move || server.run());
    SlowListServer { handle, join, iter, head }
}

fn request_sp() -> [i64; SP_WORDS] {
    [0i64; SP_WORDS]
}

#[test]
fn busy_under_tiny_queue_never_hangs_and_conn_stays_usable() {
    // window 1, pending 1, inbox 2: a burst of 10 slow ops (20k-hop
    // list walks) must split into served + explicit BUSY — nothing
    // blocks, nothing is dropped silently
    let cfg = SrvConfig {
        window: 1,
        pending_cap: 1,
        inbox_capacity: 2,
        ..SrvConfig::default()
    };
    let SlowListServer { handle, join, iter, head } =
        slow_list_server(cfg, 20_000);
    let mut c = WireClient::connect(handle.addr()).unwrap();
    c.register(1, &iter.program).unwrap();
    let n = 10u64;
    let seqs: Vec<u64> = (0..n).map(|_| c.next_seq()).collect();
    for &seq in &seqs {
        c.send(
            seq,
            &Frame::Request {
                prog: 1,
                budget: 0,
                start: head,
                sp: request_sp(),
            },
        )
        .unwrap();
    }
    let mut done = 0u64;
    let mut busy = 0u64;
    for _ in 0..n {
        match c.recv().unwrap().expect("frame").frame {
            Frame::Response { status, sp, .. } => {
                assert_eq!(status, Status::Return);
                assert_eq!(sp[3], (1..=20_000i64).sum::<i64>());
                done += 1;
            }
            Frame::Busy => busy += 1,
            other => panic!("unexpected {other:?}"),
        }
    }
    assert_eq!(done + busy, n);
    assert!(busy >= 1, "burst of {n} through capacity ~3 never shed");
    assert!(done >= 1, "backpressure starved the engine entirely");

    // the connection is still fully usable after shedding
    let seq = c.next_seq();
    c.send(
        seq,
        &Frame::Request {
            prog: 1,
            budget: 0,
            start: head,
            sp: request_sp(),
        },
    )
    .unwrap();
    match c.recv().unwrap().expect("frame").frame {
        Frame::Response { status, .. } => {
            assert_eq!(status, Status::Return)
        }
        other => panic!("post-busy request failed: {other:?}"),
    }
    handle.shutdown();
    let summary = join.join().unwrap();
    assert_eq!(summary.srv.busy, busy);
    assert_eq!(summary.backend.wire_busy, busy);
}

#[test]
fn pipelined_responses_complete_out_of_order_by_request_id() {
    // one connection, two requests: a 30k-hop walk (forced to yield
    // by the 4096-iteration grant) then a 10-hop walk. The short one
    // must overtake the long one in the response stream; request ids
    // are what keep the pipeline coherent.
    let SlowListServer {
        handle,
        join,
        iter: long_iter,
        head: long_head,
    } = slow_list_server(SrvConfig::default(), 30_000);
    let mut c = WireClient::connect(handle.addr()).unwrap();
    c.register(1, &long_iter.program).unwrap();

    // a short list on the client side cannot exist server-side; reuse
    // the same list but cap the walk with a tiny budget? No — budget
    // exhaustion is granted transparently. Instead: issue the long op
    // twice with wildly different *remaining* work by starting the
    // second walk near the tail. Walking from element k sums the
    // tail; the near-tail start finishes in a few iterations.
    let mut rack = Rack::new(rack_cfg());
    let mut l = ForwardList::new();
    let mut addrs = Vec::new();
    for i in 1..=30_000i64 {
        addrs.push(l.push(&mut rack, i));
    }
    // shadow rack layout is deterministic: the server's node k sits at
    // the same address
    let near_tail = *addrs.last().unwrap();

    let slow_seq = c.next_seq();
    c.send(
        slow_seq,
        &Frame::Request {
            prog: 1,
            budget: 0,
            start: long_head,
            sp: request_sp(),
        },
    )
    .unwrap();
    let fast_seq = c.next_seq();
    c.send(
        fast_seq,
        &Frame::Request {
            prog: 1,
            budget: 0,
            start: near_tail,
            sp: request_sp(),
        },
    )
    .unwrap();

    let first = c.recv().unwrap().expect("frame");
    let second = c.recv().unwrap().expect("frame");
    assert_eq!(
        first.seq, fast_seq,
        "short op did not overtake the 30k-hop walk"
    );
    assert_eq!(second.seq, slow_seq);
    match (first.frame, second.frame) {
        (
            Frame::Response { sp: fast_sp, .. },
            Frame::Response { sp: slow_sp, iters, .. },
        ) => {
            // the near-tail walk sums only the last element it visits
            assert!(fast_sp[3] > 0);
            assert_eq!(slow_sp[3], (1..=30_000i64).sum::<i64>());
            assert!(iters >= 30_000);
        }
        other => panic!("unexpected {other:?}"),
    }
    handle.shutdown();
    let _ = join.join().unwrap();
}

#[test]
fn graceful_shutdown_drains_in_flight_ops() {
    let SlowListServer { handle, join, iter, head } =
        slow_list_server(SrvConfig::default(), 15_000);
    let mut c = WireClient::connect(handle.addr()).unwrap();
    c.register(1, &iter.program).unwrap();
    let n = 24u64;
    for _ in 0..n {
        let seq = c.next_seq();
        c.send(
            seq,
            &Frame::Request {
                prog: 1,
                budget: 0,
                start: head,
                sp: request_sp(),
            },
        )
        .unwrap();
    }
    // wait for the first response, then shut down mid-stream
    let first = c.recv().unwrap().expect("first response");
    assert!(matches!(first.frame, Frame::Response { .. }));
    handle.shutdown();

    // every remaining frame must decode cleanly: full responses for
    // drained ops, ERROR(ShuttingDown) for rejected ones, then EOF
    let mut responses = 1u64;
    let mut rejected = 0u64;
    let mut torn = false;
    loop {
        match c.recv() {
            Ok(Some(env)) => match env.frame {
                Frame::Response { status, sp, .. } => {
                    assert_eq!(status, Status::Return);
                    assert_eq!(sp[3], (1..=15_000i64).sum::<i64>());
                    responses += 1;
                }
                Frame::Error { code, .. } => {
                    assert_eq!(code, ErrCode::ShuttingDown);
                    rejected += 1;
                }
                Frame::Busy => rejected += 1,
                other => panic!("unexpected {other:?}"),
            },
            Ok(None) => break,
            Err(_) => {
                // reset during teardown: some drained responses may
                // have been lost on the wire, so only the inequality
                // below can be asserted
                torn = true;
                break;
            }
        }
    }
    let summary = join.join().unwrap();
    assert!(
        responses >= 1 && responses + rejected <= n,
        "responses={responses} rejected={rejected}"
    );
    if torn {
        // drained ops may outnumber the responses that survived the
        // torn stream, never the reverse
        assert!(summary.engine.report.completed >= responses);
    } else {
        // clean EOF: drained means drained — every engine completion
        // reached the client before the stream closed
        assert_eq!(summary.engine.report.completed, responses);
    }
}

#[test]
fn malformed_frames_answer_error_or_clean_disconnect() {
    let spec = ServingSpec {
        workload: "mix-c".into(),
        keys: 500,
        ops: 10,
        ..ServingSpec::default()
    };
    let (handle, join, ops) =
        start_server("live", &spec, SrvConfig::default());
    let addr = handle.addr();
    let prog = &ops[0].stages[0].iter.program;

    // (a) bad magic: best-effort ERROR then disconnect
    {
        let mut c = WireClient::connect(addr).unwrap();
        let mut wire = encode_frame(1, &Frame::Busy);
        wire[4] ^= 0xFF; // magic byte
        patch_crc(&mut wire);
        c.send_raw(&wire).unwrap();
        match c.recv() {
            Ok(Some(env)) => {
                assert!(matches!(
                    env.frame,
                    Frame::Error { code: ErrCode::BadMagic, .. }
                ));
                // then EOF (or reset) — the stream is untrusted
                assert!(matches!(c.recv(), Ok(None) | Err(_)));
            }
            Ok(None) | Err(_) => {}
        }
    }

    // (b) bad CRC: ERROR with the request's seq, connection survives
    {
        let mut c = WireClient::connect(addr).unwrap();
        c.register(1, prog).unwrap();
        let mut wire = encode_frame(
            42,
            &Frame::Request {
                prog: 1,
                budget: 0,
                start: 0x4000,
                sp: request_sp(),
            },
        );
        let last = wire.len() - 1;
        wire[last] ^= 1; // corrupt the crc
        c.send_raw(&wire).unwrap();
        let env = c.recv().unwrap().expect("error frame");
        assert_eq!(env.seq, 42);
        assert!(matches!(
            env.frame,
            Frame::Error { code: ErrCode::BadCrc, .. }
        ));
        // still serves valid traffic afterwards
        roundtrip_one(&mut c, &ops[0]);
    }

    // (c) oversized length prefix: ERROR then disconnect
    {
        let mut c = WireClient::connect(addr).unwrap();
        c.send_raw(&(64u32 << 20).to_le_bytes()).unwrap();
        match c.recv() {
            Ok(Some(env)) => {
                assert!(matches!(
                    env.frame,
                    Frame::Error { code: ErrCode::Oversize, .. }
                ));
                assert!(matches!(c.recv(), Ok(None) | Err(_)));
            }
            Ok(None) | Err(_) => {}
        }
    }

    // (d) truncated frame then hangup: server survives (next
    // connection works)
    {
        let mut c = WireClient::connect(addr).unwrap();
        let wire = encode_frame(1, &Frame::Busy);
        c.send_raw(&wire[..wire.len() - 3]).unwrap();
        drop(c);
    }

    // (e) unknown program id: ERROR, connection continues
    {
        let mut c = WireClient::connect(addr).unwrap();
        let seq = c.next_seq();
        c.send(
            seq,
            &Frame::Request {
                prog: 99,
                budget: 0,
                start: 0x4000,
                sp: request_sp(),
            },
        )
        .unwrap();
        let env = c.recv().unwrap().expect("error frame");
        assert_eq!(env.seq, seq);
        assert!(matches!(
            env.frame,
            Frame::Error { code: ErrCode::UnknownProgram, .. }
        ));
        c.register(1, prog).unwrap();
        roundtrip_one(&mut c, &ops[0]);
    }

    // (f) garbage program bytes in REGISTER: ERROR(BadBody), continue
    {
        let mut c = WireClient::connect(addr).unwrap();
        let mut body = vec![0u8; 40];
        body[0] = 1; // program id 1; remainder is not a program
        let wire = raw_frame(7, 1 /* KIND_REGISTER */, &body);
        c.send_raw(&wire).unwrap();
        let env = c.recv().unwrap().expect("error frame");
        assert!(matches!(
            env.frame,
            Frame::Error {
                code: ErrCode::BadBody | ErrCode::BadProgram,
                ..
            }
        ));
        c.register(1, prog).unwrap();
        roundtrip_one(&mut c, &ops[0]);
    }

    // (g) byte-corruption sweep over a valid request frame: every
    // flip answers ERROR or disconnects; none wedges the listener
    {
        let good = encode_frame(
            5,
            &Frame::Request {
                prog: 1,
                budget: 0,
                start: 0x4000,
                sp: request_sp(),
            },
        );
        for pos in [4usize, 8, 9, 10, 16, 20, 40, good.len() - 1] {
            let mut c = WireClient::connect(addr).unwrap();
            c.register(1, prog).unwrap();
            let mut bad = good.clone();
            bad[pos] ^= 0x5A;
            c.send_raw(&bad).unwrap();
            match c.recv() {
                Ok(Some(env)) => assert!(
                    matches!(env.frame, Frame::Error { .. }),
                    "flip at {pos}: expected ERROR, got {env:?}"
                ),
                Ok(None) | Err(_) => {} // clean disconnect is fine
            }
        }
    }

    // the server survived all of it and still serves
    let mut c = WireClient::connect(addr).unwrap();
    c.register(1, prog).unwrap();
    roundtrip_one(&mut c, &ops[0]);

    handle.shutdown();
    let summary = join.join().unwrap();
    assert!(summary.srv.decode_errors >= 3);
    assert_eq!(summary.backend.wire_decode_errors, summary.srv.decode_errors);
}

#[test]
fn port_in_use_and_double_start_fail_cleanly() {
    let spec = ServingSpec {
        workload: "mix-c".into(),
        keys: 100,
        ops: 10,
        ..ServingSpec::default()
    };
    let (handle, join, _ops) =
        start_server("live", &spec, SrvConfig::default());
    let addr = handle.addr().to_string();
    // second bind on the same port: a clean io::Error, not a panic
    let backend2 = make_backend("live", rack_cfg());
    let err = Server::bind(backend2, &addr, SrvConfig::default());
    assert!(err.is_err(), "double bind on {addr} must fail");
    handle.shutdown();
    let _ = join.join().unwrap();
    // the port is free again after a full teardown
    let backend3 = make_backend("live", rack_cfg());
    let (server3, handle3) =
        Server::bind(backend3, &addr, SrvConfig::default())
            .expect("rebind after teardown");
    let join3 = std::thread::spawn(move || server3.run());
    handle3.shutdown();
    let _ = join3.join().unwrap();
}

/// Send `op`'s first stage and assert a Return response (helper for
/// the hardening test's "connection still works" checks).
fn roundtrip_one(c: &mut WireClient, op: &pulse::rack::Op) {
    let stage = &op.stages[0];
    let (start, sp) = stage.resolve(&[0i64; SP_WORDS], None);
    let seq = c.next_seq();
    c.send(
        seq,
        &Frame::Request { prog: 1, budget: 0, start, sp },
    )
    .unwrap();
    let env = c.recv().unwrap().expect("response");
    assert_eq!(env.seq, seq);
    assert!(
        matches!(env.frame, Frame::Response { status: Status::Return, .. }),
        "{env:?}"
    );
}

/// Hand-build a frame with an arbitrary kind byte + body (for
/// malformed-body injection the typed encoder cannot produce).
fn raw_frame(seq: u64, kind: u8, body: &[u8]) -> Vec<u8> {
    let mut p = Vec::new();
    p.extend_from_slice(&[0u8; 4]);
    p.extend_from_slice(&u32::from_le_bytes(*b"PLSE").to_le_bytes());
    p.push(1); // version
    p.push(kind);
    p.extend_from_slice(&0u16.to_le_bytes());
    p.extend_from_slice(&seq.to_le_bytes());
    p.extend_from_slice(body);
    let crc = crc32(&p[4..]).to_le_bytes();
    p.extend_from_slice(&crc);
    let len = (p.len() - 4) as u32;
    p[..4].copy_from_slice(&len.to_le_bytes());
    p
}

/// Re-stamp a (possibly corrupted) frame's CRC so only the targeted
/// field is invalid, not the checksum.
fn patch_crc(wire: &mut [u8]) {
    let n = wire.len();
    let crc = crc32(&wire[4..n - 4]).to_le_bytes();
    wire[n - 4..].copy_from_slice(&crc);
}

#[test]
fn open_loop_pacing_completes_the_stream() {
    let spec = ServingSpec {
        workload: "mix-c".into(),
        keys: 1_000,
        ops: 300,
        ..ServingSpec::default()
    };
    let (handle, join, ops) =
        start_server("live", &spec, SrvConfig::default());
    let want = expected_sps(&spec, &ops);
    let report = run_loadgen(
        &LoadgenConfig {
            addr: handle.addr().to_string(),
            conns: 2,
            depth: 8,
            open_rate: 30_000.0, // paced, comfortably sub-saturating
            record_results: true,
            ..LoadgenConfig::default()
        },
        ops.clone(),
    )
    .expect("loadgen");
    // open-loop in-flight is unbounded by design, so a scheduler
    // stall on a loaded CI host can legitimately shed a few ops as
    // BUSY; the invariants are exact accounting, zero protocol
    // errors, and bit-correct scratchpads for everything served
    assert_eq!(report.completed + report.busy, ops.len() as u64);
    assert_eq!(report.errors, 0);
    assert!(
        report.completed >= ops.len() as u64 / 2,
        "sub-saturating pace mostly shed: completed={} busy={}",
        report.completed,
        report.busy
    );
    for (i, got) in report.results.iter().enumerate() {
        if let Some(got) = got {
            assert_eq!(got, &want[i], "op {i}");
        }
    }
    handle.shutdown();
    let _ = join.join().unwrap();
}

#[test]
fn stats_frame_returns_a_partitioned_registry_snapshot() {
    let spec = ServingSpec {
        workload: "mix-c".into(),
        keys: 1_000,
        ops: 400,
        ..ServingSpec::default()
    };
    let (handle, join, ops) =
        start_server("live", &spec, SrvConfig::default());
    let addr = handle.addr().to_string();

    // a snapshot is servable before any request traffic, and the
    // engine's queue gauges are already registered
    let snap0 = fetch_stats(&addr).expect("stats before traffic");
    assert_eq!(
        snap0.get("srv.requests").and_then(|v| v.as_f64()),
        Some(0.0),
        "fresh server already counted requests"
    );
    assert!(
        snap0.get("engine.inbox.depth").is_some(),
        "engine gauges missing from the snapshot: {}",
        snap0.render()
    );

    let report = run_loadgen(
        &LoadgenConfig {
            addr: addr.clone(),
            conns: 2,
            depth: 8,
            ..LoadgenConfig::default()
        },
        ops.clone(),
    )
    .expect("loadgen");
    assert_eq!(report.completed as usize, ops.len());
    assert_eq!(report.busy, 0);
    assert_eq!(report.errors, 0);

    // the writer thread counts a response batch after flushing it, so
    // the loadgen can observe its last response a beat before the
    // counters do — poll briefly instead of flaking on that race
    let mut last = String::new();
    let mut ok = false;
    for _ in 0..100 {
        let snap = fetch_stats(&addr).expect("stats poll");
        let requests = snap
            .get("srv.requests")
            .and_then(|v| v.as_f64())
            .unwrap_or(-1.0);
        match check_stats_partition(&snap) {
            // mix-c ops are single-stage: one REQUEST each, and the
            // STATS polls themselves are not requests
            Ok(()) if requests >= ops.len() as f64 => {
                ok = true;
                break;
            }
            Ok(()) => {
                last = format!(
                    "partitioned but requests={requests} < {}",
                    ops.len()
                )
            }
            Err(e) => last = e,
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    assert!(ok, "stats never partitioned cleanly: {last}");

    handle.shutdown();
    let summary = join.join().unwrap();
    assert_eq!(summary.srv.requests, ops.len() as u64);
}

#[test]
fn min_payload_constant_matches_the_codec() {
    // keep the wire constants honest: the smallest frame the encoder
    // produces is exactly MIN_PAYLOAD
    let wire = encode_frame(0, &Frame::Busy);
    assert_eq!(wire.len() - 4, MIN_PAYLOAD);
}

/// Wire admission, on BOTH serving tiers: a program that decodes and
/// verifies fine but that the abstract interpreter denies — here a
/// division by a constant zero — must be rejected with
/// ERROR(BadProgram) carrying the rendered diagnostic; and under
/// `--read-only` serving a program the analyzer proves may write node
/// DRAM must be rejected while read-only programs still register.
/// These are semantic rejections (answered ERROR, counted as
/// errors_sent), not wire corruption: decode_errors must stay 0 and
/// the connection must keep working.
#[test]
fn analyzer_deny_and_read_only_are_enforced_at_wire_admission() {
    use pulse::isa::{Instr, Op, Program};
    for legacy in [false, true] {
        let spec = ServingSpec {
            workload: "mix-c".into(),
            keys: 200,
            ops: 10,
            ..ServingSpec::default()
        };
        let cfg = SrvConfig {
            legacy_threads: legacy,
            allow_writes: false,
            ..SrvConfig::default()
        };
        let (handle, join, _ops) = start_server("live", &spec, cfg);
        let mut c = WireClient::connect(handle.addr()).unwrap();

        // (a) analyzer deny: r3 = r1 / 0 — passes the structural
        // verifier, certainly traps at runtime
        let denied = Program::new(
            vec![
                Instr::new(Op::Movi, 1, 0, 0, 5),
                Instr::new(Op::Movi, 2, 0, 0, 0),
                Instr::new(Op::Div, 3, 1, 2, 0),
                Instr::new(Op::Ret, 0, 0, 0, 0),
            ],
            1,
        );
        assert!(
            pulse::isa::verify(&denied).is_ok(),
            "the deny exemplar must be verifier-clean"
        );
        let seq = c.next_seq();
        c.send(seq, &Frame::Register { id: 7, program: denied })
            .unwrap();
        let env = c.recv().unwrap().expect("deny reply");
        assert_eq!(env.seq, seq);
        match env.frame {
            Frame::Error { code, msg } => {
                assert_eq!(
                    code,
                    ErrCode::BadProgram,
                    "legacy={legacy}: wrong code: {msg}"
                );
                assert!(
                    msg.contains("PossibleDivByZero"),
                    "legacy={legacy}: diagnostic text missing: {msg}"
                );
                assert!(
                    msg.contains("Div"),
                    "legacy={legacy}: rendered instruction missing: \
                     {msg}"
                );
            }
            other => panic!("legacy={legacy}: expected ERROR: {other:?}"),
        }

        // (b) read-only serving rejects a proven-mutating program...
        let mutating = pulse::ds::list::push_front_iter();
        let seq = c.next_seq();
        c.send(
            seq,
            &Frame::Register {
                id: 8,
                program: (*mutating.program).clone(),
            },
        )
        .unwrap();
        let env = c.recv().unwrap().expect("read-only reply");
        match env.frame {
            Frame::Error { code, msg } => {
                assert_eq!(code, ErrCode::BadProgram);
                assert!(
                    msg.contains("read-only"),
                    "legacy={legacy}: want read-only rejection: {msg}"
                );
            }
            other => panic!("legacy={legacy}: expected ERROR: {other:?}"),
        }

        // (c) ...and still admits a read-only program on the very
        // same connection
        let find = pulse::ds::list::find_iter();
        c.register(9, &find.program).unwrap();

        drop(c);
        handle.shutdown();
        let summary = join.join().unwrap();
        assert_eq!(
            summary.srv.decode_errors, 0,
            "legacy={legacy}: semantic rejections must not count as \
             decode errors"
        );
    }
}

#[test]
fn attribution_off_keeps_legacy_frames_and_records_nothing() {
    // pay-for-what-you-ask: a run that never sets the REGISTER timing
    // flag must see zero timing blocks on the wire (report.timed == 0
    // — the decoder would hand Some(..) to the client if the server
    // grew the frame), zero samples in every phase histogram (the
    // names still exist: hists are created eagerly so dashboards see
    // stable schemas), and no per-program labeled histograms at all
    let spec = ServingSpec {
        workload: "mix-c".into(),
        keys: 1_000,
        ops: 300,
        ..ServingSpec::default()
    };
    let (handle, join, ops) =
        start_server("live", &spec, SrvConfig::default());
    let addr = handle.addr().to_string();
    let report = run_loadgen(
        &LoadgenConfig {
            addr: addr.clone(),
            conns: 2,
            depth: 8,
            ..LoadgenConfig::default()
        },
        ops.clone(),
    )
    .expect("loadgen");
    assert_eq!(report.completed as usize, ops.len());
    assert_eq!(
        report.timed, 0,
        "server attached timing blocks without negotiation"
    );

    let snap = fetch_stats(&addr).expect("stats");
    for key in [
        "engine.phase.queue_wait.count",
        "engine.phase.execute.count",
        "engine.phase.transit.count",
        "srv.phase.completion.count",
        "srv.phase.write.count",
    ] {
        assert_eq!(
            snap.get(key).and_then(|v| v.as_f64()),
            Some(0.0),
            "{key} recorded samples on an unattributed run"
        );
    }
    assert!(
        snap.get("srv.e2e.prog0.count").is_none(),
        "per-program histogram materialized without the timing flag"
    );

    handle.shutdown();
    let _ = join.join().unwrap();
}

#[test]
fn attribution_slices_bound_rtt_and_fill_per_program_hists() {
    // the full attributed path: flagged REGISTER, timing block on
    // every RESPONSE, slow-op log at threshold 0 (log everything).
    // Nesting invariant per row: queue + exec + transit + completion
    // <= server_ns <= client RTT; residue is exactly the difference.
    let spec = ServingSpec {
        workload: "mix-c".into(),
        keys: 1_000,
        ops: 400,
        ..ServingSpec::default()
    };
    let (handle, join, ops) =
        start_server("live", &spec, SrvConfig::default());
    let log_path = std::env::temp_dir()
        .join(format!("pulse_slow_{}.jsonl", std::process::id()));
    let report = run_loadgen(
        &LoadgenConfig {
            // one connection: wire seqs are per-connection, and the
            // uniqueness check below joins rows on seq
            addr: handle.addr().to_string(),
            conns: 1,
            depth: 8,
            attribution: true,
            slow_op_log: Some(log_path.to_str().unwrap().to_string()),
            slow_op_us: 0,
            ..LoadgenConfig::default()
        },
        ops.clone(),
    )
    .expect("loadgen");
    assert_eq!(report.completed as usize, ops.len());
    assert_eq!(report.busy, 0);
    assert_eq!(report.errors, 0);
    // mix-c ops are single-stage: one attributed response per op
    assert_eq!(report.timed as usize, ops.len());

    let text = std::fs::read_to_string(&log_path).expect("slow log");
    let mut seqs = std::collections::HashSet::new();
    let mut rows = 0usize;
    for line in text.lines() {
        let row = pulse::util::json::Json::parse(line)
            .unwrap_or_else(|e| panic!("bad row {e}: {line}"));
        let g = |k: &str| {
            row.get(k)
                .and_then(|v| v.as_f64())
                .unwrap_or_else(|| panic!("row missing {k}: {line}"))
        };
        let slices = g("queue_ns")
            + g("exec_ns")
            + g("transit_ns")
            + g("completion_ns");
        assert!(
            slices <= g("server_ns"),
            "slices exceed server time: {line}"
        );
        assert!(
            g("server_ns") <= g("rtt_ns"),
            "server time exceeds client RTT: {line}"
        );
        assert_eq!(
            g("residue_ns"),
            g("rtt_ns") - g("server_ns"),
            "residue is not rtt - server: {line}"
        );
        assert!(g("visits") >= 1.0, "attributed op with no visits: {line}");
        assert!(
            seqs.insert(g("seq").to_bits()),
            "duplicate seq in slow-op log: {line}"
        );
        rows += 1;
    }
    assert_eq!(
        rows, report.timed as usize,
        "threshold 0 must log every attributed op"
    );
    let _ = std::fs::remove_file(&log_path);

    handle.shutdown();
    let summary = join.join().unwrap();
    let g = |k: &str| {
        summary
            .registry
            .get(k)
            .and_then(|v| v.as_f64())
            .unwrap_or(-1.0)
    };
    // loadgen assigns wire ids in first-appearance order: mix-c's one
    // program is prog0
    assert_eq!(g("srv.e2e.prog0.count") as usize, ops.len());
    assert_eq!(g("engine.execute.prog0.count") as usize, ops.len());
    for key in [
        "engine.phase.queue_wait.count",
        "engine.phase.execute.count",
        "srv.phase.completion.count",
        "srv.phase.write.count",
    ] {
        assert_eq!(g(key) as usize, ops.len(), "{key}");
    }
    check_stats_partition(&summary.registry).expect("partition");
}

#[test]
fn queue_wait_slice_reflects_serialized_admission() {
    // window 1 with a roomy pending buffer serializes 20k-hop walks:
    // a pipelined burst all completes, and the most-queued op must
    // have waited at least one full execution in the queue slice —
    // queue-wait shows up exactly where queueing happens
    let cfg = SrvConfig {
        window: 1,
        pending_cap: 16,
        ..SrvConfig::default()
    };
    let SlowListServer { handle, join, iter, head } =
        slow_list_server(cfg, 20_000);
    let mut c = WireClient::connect(handle.addr()).unwrap();
    c.register_opts(1, &iter.program, true).unwrap();

    let n = 8u64;
    let wall = std::time::Instant::now();
    for _ in 0..n {
        let seq = c.next_seq();
        c.send(
            seq,
            &Frame::Request {
                prog: 1,
                budget: 0,
                start: head,
                sp: request_sp(),
            },
        )
        .unwrap();
    }
    let mut timings = Vec::new();
    for _ in 0..n {
        match c.recv().unwrap().expect("frame").frame {
            Frame::Response { status, timing, .. } => {
                assert_eq!(status, Status::Return);
                timings.push(
                    timing.expect("negotiated conn lost its timing block"),
                );
            }
            other => panic!("unexpected {other:?}"),
        }
    }
    let wall_ns = wall.elapsed().as_nanos() as u64;
    for t in &timings {
        let slices =
            t.queue_ns + t.exec_ns + t.transit_ns + t.completion_ns;
        assert!(
            slices <= t.server_ns,
            "slices {slices} > server {}",
            t.server_ns
        );
        assert!(
            t.server_ns <= wall_ns,
            "server time {} exceeds client wall clock {wall_ns}",
            t.server_ns
        );
        assert!(t.visits >= 1);
    }
    let qmax = timings.iter().map(|t| t.queue_ns).max().unwrap();
    let emin = timings.iter().map(|t| t.exec_ns).min().unwrap();
    assert!(
        qmax > emin,
        "serialized burst shows no queue wait (qmax={qmax} emin={emin})"
    );
    handle.shutdown();
    let _ = join.join().unwrap();
}
