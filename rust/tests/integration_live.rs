//! The live engine's contract: real threads, same answers.
//!
//! * functional equivalence — the same YCSB-C workload through
//!   `LiveBackend` at 1/2/4 shards produces scratchpads identical to
//!   the purely functional path and op/iteration/crossing counts
//!   identical to the rack DES (timing excluded: the DES reports
//!   virtual time, the live engine wall time);
//! * distributed traversals — pointer chains spanning shards bounce
//!   shard-to-shard (in-network) or via the dispatcher (PULSE-ACC)
//!   and still produce the functional results;
//! * teardown — repeated serves on one backend restart the worker
//!   fleet cleanly, and the bounded queue drains fully under heavy
//!   multi-producer contention (shutdown/drain ordering).

use pulse::backend::TraversalBackend;
use pulse::ds::{ForwardList, HashMapDs};
use pulse::isa::SP_WORDS;
use pulse::live::{queue, LiveBackend};
use pulse::rack::{Op, Rack, RackConfig};
use pulse::workloads::{YcsbOp, YcsbSpec, YcsbWorkload};

const KEYS: u64 = 2_000;
const OPS: u64 = 300;
const CONC: usize = 8;

fn cfg(nodes: usize) -> RackConfig {
    RackConfig {
        nodes,
        node_capacity: 64 << 20,
        // small slabs: consecutive chain nodes land ~12 KB apart (one
        // alloc per bucket per round), so chains hop slabs — and at
        // >1 node, shards — constantly; the equivalence test then
        // really exercises cross-shard forwarding, not just shard 0
        granularity: 8 << 10,
        ..Default::default()
    }
}

/// Identical hash index in any rack (deterministic layout: the VA
/// sequence does not depend on the node count).
fn build_index(rack: &mut Rack) -> HashMapDs {
    let mut m = HashMapDs::build(rack, 512);
    for k in 0..KEYS as i64 {
        m.insert(rack, k, k * 11);
    }
    m
}

/// The same deterministic YCSB-C stream every backend serves.
fn make_ops(m: &HashMapDs) -> Vec<Op> {
    let prog = m.find_program();
    let mut w = YcsbWorkload::new(YcsbSpec::C, KEYS, false, 77);
    (0..OPS)
        .map(|_| {
            let key = match w.next_op() {
                YcsbOp::Read(k) => (k % KEYS) as i64,
                other => panic!("YCSB-C produced {other:?}"),
            };
            let mut sp = [0i64; SP_WORDS];
            sp[0] = key;
            Op::new(prog.clone(), m.bucket_ptr(key), sp)
        })
        .collect()
}

#[test]
fn live_matches_functional_results_and_des_counts() {
    for shards in [1usize, 2, 4] {
        // ground truth: the purely functional path
        let mut fr = Rack::new(cfg(shards));
        let fm = build_index(&mut fr);
        let ops = make_ops(&fm);
        let expected: Vec<[i64; SP_WORDS]> =
            ops.iter().map(|op| fr.run_op_functional(op)).collect();

        // accounting reference: the rack DES on an identical layout
        let mut des = Rack::new(cfg(shards));
        let dm = build_index(&mut des);
        let des_rep = des.serve_batch(&make_ops(&dm), CONC);

        // the live engine on an identical layout
        let mut live = LiveBackend::new(Rack::new(cfg(shards)));
        let lm = build_index(live.rack_mut());
        let live_ops = make_ops(&lm);
        live.record_results(true);
        let rep = live.serve_batch(&live_ops, CONC);

        assert_eq!(rep.completed, OPS, "{shards} shards: lost ops");
        assert_eq!(rep.trapped, 0, "{shards} shards: traps");
        assert_eq!(
            rep.completed, des_rep.completed,
            "{shards} shards: op count diverged from the DES"
        );
        assert_eq!(
            rep.total_iters, des_rep.total_iters,
            "{shards} shards: iteration count diverged from the DES"
        );
        assert_eq!(
            rep.cross_node_requests, des_rep.cross_node_requests,
            "{shards} shards: crossing accounting diverged"
        );
        let got = live.last_results();
        assert_eq!(got.len(), expected.len());
        for (i, (g, e)) in got.iter().zip(&expected).enumerate() {
            assert_eq!(
                g, e,
                "{shards} shards: op {i} scratchpad diverged"
            );
        }
        // wall-clock metrics are present and sane
        assert_eq!(rep.latency.count(), OPS);
        assert!(rep.tput_ops_per_s > 0.0);
        let run = live.last_run().unwrap();
        assert_eq!(run.total_iters(), rep.total_iters);
        assert_eq!(run.total_drops(), 0, "teardown lost messages");
        if shards > 1 {
            // the layout spreads chains over every node: the identical
            // counts above were produced *through* cross-shard hops
            assert!(
                rep.cross_node_requests > 0,
                "{shards} shards: workload never crossed shards"
            );
            assert!(
                run.total_forwards() > 0,
                "{shards} shards: no in-network shard-to-shard forward"
            );
        }
    }
}

#[test]
fn distributed_walks_bounce_between_live_shards() {
    for in_network in [true, false] {
        let mut c = cfg(4);
        c.granularity = 4096; // chains cross shards constantly
        c.in_network_routing = in_network;
        let mut live = LiveBackend::new(Rack::new(c));
        let mut l = ForwardList::new();
        for i in 0..3000 {
            l.push(live.rack_mut(), i);
        }
        let prog = l.find_program();
        let head = l.head;
        let ops: Vec<Op> = (0..40)
            .map(|i| {
                let mut sp = [0i64; SP_WORDS];
                sp[0] = 2500 + (i % 400);
                Op::new(prog.clone(), head, sp)
            })
            .collect();
        // read-only walk: functional expectations from the same rack
        let expected: Vec<[i64; SP_WORDS]> = ops
            .iter()
            .map(|op| live.rack_mut().run_op_functional(op))
            .collect();
        live.record_results(true);
        let rep = live.serve_batch(&ops, 4);
        assert_eq!(rep.completed, 40, "in_network={in_network}");
        assert_eq!(rep.trapped, 0, "in_network={in_network}");
        assert!(
            rep.cross_node_requests > 0,
            "in_network={in_network}: no cross-shard traffic"
        );
        assert_eq!(live.last_results(), &expected[..]);
        let run = live.last_run().unwrap();
        if in_network {
            assert!(
                run.router.reroutes > 0,
                "in-network mode never forwarded shard-to-shard"
            );
            assert!(run.total_forwards() > 0);
        } else {
            // ACC mode: every bounce returns to the dispatcher
            assert_eq!(run.total_forwards(), 0);
        }
    }
}

#[test]
fn repeated_serves_restart_the_worker_fleet_cleanly() {
    let mut live = LiveBackend::new(Rack::new(cfg(2)));
    let m = build_index(live.rack_mut());
    let ops = make_ops(&m);
    for round in 1..=3u64 {
        let rep = live.serve_batch(&ops, 6);
        assert_eq!(rep.completed, OPS, "round {round}");
        assert_eq!(rep.trapped, 0, "round {round}");
        let run = live.last_run().unwrap();
        assert_eq!(run.total_drops(), 0, "round {round}: lost messages");
        // per-run queue counters balance: everything pushed was popped
        for (i, q) in run.queues.iter().enumerate() {
            assert_eq!(
                q.depth(),
                0,
                "round {round}: shard {i} queue not drained"
            );
        }
        assert_eq!(live.metrics().ops, OPS * round, "cumulative ops");
    }
}

#[test]
fn bounded_queue_drains_fully_under_contention() {
    // shutdown/drain ordering under heavy multi-producer pressure: a
    // tiny queue forces constant full-queue blocking; dropping the
    // senders is the shutdown signal; the consumer must still see
    // every message exactly once, then observe disconnect.
    const PRODUCERS: u64 = 4;
    const PER: u64 = 5_000;
    let (tx, rx) = queue::bounded::<u64>(4);
    let stats = rx.stats_handle();
    std::thread::scope(|s| {
        for p in 0..PRODUCERS {
            let tx = tx.clone();
            s.spawn(move || {
                for i in 0..PER {
                    if tx.send(p * PER + i).is_err() {
                        panic!("receiver vanished mid-run");
                    }
                }
            });
        }
        drop(tx); // producers' clones keep the channel open until done
        let mut seen = 0u64;
        let mut sum = 0u64;
        while let Some(v) = rx.recv() {
            seen += 1;
            sum += v;
        }
        assert_eq!(seen, PRODUCERS * PER, "messages lost or duplicated");
        let n = PRODUCERS * PER;
        assert_eq!(sum, n * (n - 1) / 2, "payloads corrupted");
    });
    let snap = stats.snapshot();
    assert_eq!(snap.pushed, PRODUCERS * PER);
    assert_eq!(snap.popped, PRODUCERS * PER);
    assert_eq!(snap.depth(), 0);
    assert!(
        snap.full_blocks > 0,
        "capacity-4 queue under 20k sends never filled"
    );
}
