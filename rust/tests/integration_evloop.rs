//! Event-loop runtime tests: the serving semantics of
//! `integration_srv.rs` hold at scales and in configurations the
//! threaded tier never faced — a thousand concurrent connections,
//! everything multiplexed through a single worker, connection churn,
//! the legacy baseline, and the connection-ledger / serving-window
//! accounting the runtime rework exposed.
//!
//! (`integration_srv.rs` itself also runs against the event loop —
//! it is the default serving path — and stays byte-for-byte
//! unmodified; this file covers what that harness does not reach.)

#![cfg(unix)]

use std::thread::JoinHandle;

use pulse::backend::TraversalBackend;
use pulse::bench_support::{
    build_serving_ops, check_stats_partition, make_backend, ServingSpec,
};
use pulse::ds::ForwardList;
use pulse::isa::{Status, SP_WORDS};
use pulse::rack::{Rack, RackConfig};
use pulse::srv::loadgen::WireClient;
use pulse::srv::wire::Frame;
use pulse::srv::{
    fetch_stats, run_loadgen, LoadgenConfig, Server, ServerHandle,
    SrvConfig, SrvSummary,
};

const NODES: usize = 2;

fn rack_cfg() -> RackConfig {
    RackConfig::small(NODES)
}

fn start_server(
    backend_kind: &str,
    spec: &ServingSpec,
    cfg: SrvConfig,
) -> (ServerHandle, JoinHandle<SrvSummary>, Vec<pulse::rack::Op>) {
    let mut backend = make_backend(backend_kind, rack_cfg());
    let _ = build_serving_ops(backend.rack_mut(), spec);
    let (server, handle) =
        Server::bind(backend, "127.0.0.1:0", cfg).expect("bind");
    let join = std::thread::spawn(move || server.run());
    let mut shadow = Rack::new(rack_cfg());
    let ops = build_serving_ops(&mut shadow, spec);
    (handle, join, ops)
}

fn expected_sps(
    spec: &ServingSpec,
    ops: &[pulse::rack::Op],
) -> Vec<[i64; SP_WORDS]> {
    let mut rack = Rack::new(rack_cfg());
    let _ = build_serving_ops(&mut rack, spec);
    ops.iter().map(|op| rack.run_op_functional(op)).collect()
}

/// The connection ledger must reconcile after any run:
/// `accepted == opened + failed` and `opened == closed + active`.
fn assert_ledger_reconciles(summary: &SrvSummary, ctx: &str) {
    let s = &summary.srv;
    assert_eq!(
        s.conns_accepted,
        s.conns_opened + s.conns_failed,
        "{ctx}: accepted != opened+failed ({s:?})"
    );
    assert_eq!(
        s.conns_opened,
        s.conns_closed + s.conns_active,
        "{ctx}: opened != closed+active ({s:?})"
    );
    assert_eq!(
        s.conns_active, 0,
        "{ctx}: sessions leaked past drain ({s:?})"
    );
}

#[test]
fn thousand_connections_complete_cleanly() {
    // ≥1k concurrent loopback connections, all served by a handful of
    // event-loop workers. The window admits every in-flight op
    // (conns × depth == window), so a clean run is exact: every op
    // completes, nothing sheds, no decode errors, and the connection
    // ledger balances to zero leaked sessions.
    const CONNS: usize = 1024;
    const DEPTH: usize = 2;
    let spec = ServingSpec {
        workload: "mix-c".into(),
        keys: 8_000,
        ops: 4 * CONNS,
        ..ServingSpec::default()
    };
    let cfg = SrvConfig {
        window: CONNS * DEPTH,
        ..SrvConfig::default()
    };
    let (handle, join, ops) = start_server("live", &spec, cfg);
    let report = run_loadgen(
        &LoadgenConfig {
            addr: handle.addr().to_string(),
            conns: CONNS,
            depth: DEPTH,
            ..LoadgenConfig::default()
        },
        ops.clone(),
    )
    .expect("loadgen");
    assert_eq!(report.completed as usize, ops.len());
    assert_eq!(report.busy, 0, "window covers all in-flight ops");
    assert_eq!(report.errors, 0);
    // tail sanity at scale: not a flatness proof (the bench sweeps
    // that), but a runaway event loop fails this by orders of
    // magnitude
    assert!(
        report.latency.p99() < 30_000_000_000,
        "p99 {}ns at {CONNS} conns",
        report.latency.p99()
    );

    handle.shutdown();
    let summary = join.join().unwrap();
    assert_eq!(summary.engine.report.completed as usize, ops.len());
    assert_eq!(summary.srv.decode_errors, 0);
    assert_eq!(summary.srv.backlog_drops, 0);
    assert!(summary.srv.conns_accepted >= CONNS as u64);
    assert_ledger_reconciles(&summary, "1k conns");
    // serving-window accounting: both windows measured, and the rate
    // denominator is the serving window, not serve+drain
    assert!(summary.serving_ms > 0.0);
    assert!(summary.drain_ms >= 0.0);
    let implied = summary.engine.report.completed as f64
        / (summary.engine.report.wall_ms / 1e3);
    assert!(
        (summary.engine.report.tput_ops_per_s - implied).abs()
            < implied * 1e-6,
        "tput {} not computed over the serving window {}ms",
        summary.engine.report.tput_ops_per_s,
        summary.engine.report.wall_ms
    );
}

#[test]
fn single_worker_multiplexes_busy_edges_and_out_of_order() {
    // io_threads=1: every connection shares ONE event-loop worker.
    // The BUSY discipline (window/pending/inbox edges) and pipelined
    // out-of-order completion must hold with zero per-connection
    // threads to hide behind.
    let cfg = SrvConfig {
        window: 1,
        pending_cap: 1,
        inbox_capacity: 2,
        io_threads: 1,
        ..SrvConfig::default()
    };
    let mut backend = make_backend("live", rack_cfg());
    let (head, near_tail, iter) = {
        let rack = backend.rack_mut();
        let mut l = ForwardList::new();
        let mut last = 0u64;
        for i in 1..=20_000i64 {
            last = l.push(rack, i);
        }
        (l.head, last, l.sum_program())
    };
    let (server, handle) =
        Server::bind(backend, "127.0.0.1:0", cfg).expect("bind");
    let join = std::thread::spawn(move || server.run());

    // burst of 10 slow walks through capacity ~3: explicit BUSY for
    // the shed ones, full responses for the served ones, no hangs
    let mut c = WireClient::connect(handle.addr()).unwrap();
    c.register(1, &iter.program).unwrap();
    let sp0 = [0i64; SP_WORDS];
    let n = 10u64;
    for _ in 0..n {
        let seq = c.next_seq();
        c.send(
            seq,
            &Frame::Request { prog: 1, budget: 0, start: head, sp: sp0 },
        )
        .unwrap();
    }
    let mut done = 0u64;
    let mut busy = 0u64;
    for _ in 0..n {
        match c.recv().unwrap().expect("frame").frame {
            Frame::Response { status, sp, .. } => {
                assert_eq!(status, Status::Return);
                assert_eq!(sp[3], (1..=20_000i64).sum::<i64>());
                done += 1;
            }
            Frame::Busy => busy += 1,
            other => panic!("unexpected {other:?}"),
        }
    }
    assert_eq!(done + busy, n);
    assert!(busy >= 1, "burst through capacity ~3 never shed");
    assert!(done >= 1, "backpressure starved the engine entirely");

    // out-of-order pipelining on the SAME single-worker connection: a
    // near-tail walk issued second must overtake the full 20k-hop walk
    let slow_seq = c.next_seq();
    c.send(
        slow_seq,
        &Frame::Request { prog: 1, budget: 0, start: head, sp: sp0 },
    )
    .unwrap();
    let fast_seq = c.next_seq();
    c.send(
        fast_seq,
        &Frame::Request {
            prog: 1,
            budget: 0,
            start: near_tail,
            sp: sp0,
        },
    )
    .unwrap();
    let first = c.recv().unwrap().expect("frame");
    let second = c.recv().unwrap().expect("frame");
    assert_eq!(
        first.seq, fast_seq,
        "short walk did not overtake the 20k-hop walk"
    );
    assert_eq!(second.seq, slow_seq);

    handle.shutdown();
    let summary = join.join().unwrap();
    assert_eq!(summary.srv.busy, busy);
    assert_ledger_reconciles(&summary, "single worker");
}

#[test]
fn graceful_drain_flushes_every_admitted_op_across_connections() {
    // pipelined slow ops spread over several connections, shutdown
    // mid-stream: every client that keeps reading sees one decodable
    // frame per request (Response, BUSY, or ShuttingDown) and then a
    // clean EOF — the event-loop final flush may not drop completions
    let cfg = SrvConfig::default();
    let mut backend = make_backend("live", rack_cfg());
    let (head, iter) = {
        let rack = backend.rack_mut();
        let mut l = ForwardList::new();
        for i in 1..=15_000i64 {
            l.push(rack, i);
        }
        (l.head, l.sum_program())
    };
    let (server, handle) =
        Server::bind(backend, "127.0.0.1:0", cfg).expect("bind");
    let join = std::thread::spawn(move || server.run());

    const CONNS: usize = 4;
    const PER_CONN: u64 = 8;
    let sp0 = [0i64; SP_WORDS];
    let mut clients = Vec::new();
    for _ in 0..CONNS {
        let mut c = WireClient::connect(handle.addr()).unwrap();
        c.register(1, &iter.program).unwrap();
        for _ in 0..PER_CONN {
            let seq = c.next_seq();
            c.send(
                seq,
                &Frame::Request {
                    prog: 1,
                    budget: 0,
                    start: head,
                    sp: sp0,
                },
            )
            .unwrap();
        }
        clients.push(c);
    }
    // first response proves ops are flowing, then drain mid-stream
    let first = clients[0].recv().unwrap().expect("first response");
    assert!(matches!(first.frame, Frame::Response { .. }));
    handle.shutdown();

    let mut responses = 1u64; // the one already read
    let mut rejected = 0u64;
    let mut torn = false;
    for c in &mut clients {
        loop {
            match c.recv() {
                Ok(Some(env)) => match env.frame {
                    Frame::Response { status, sp, .. } => {
                        assert_eq!(status, Status::Return);
                        assert_eq!(
                            sp[3],
                            (1..=15_000i64).sum::<i64>()
                        );
                        responses += 1;
                    }
                    Frame::Error { .. } | Frame::Busy => {
                        rejected += 1
                    }
                    other => panic!("unexpected {other:?}"),
                },
                Ok(None) => break,
                Err(_) => {
                    torn = true;
                    break;
                }
            }
        }
    }
    let summary = join.join().unwrap();
    assert!(
        responses + rejected <= CONNS as u64 * PER_CONN,
        "more answers than requests"
    );
    if torn {
        // frames can be lost on a torn teardown; only the inequality
        // survives
        assert!(summary.engine.report.completed >= responses);
    } else {
        // clean EOFs everywhere: every admitted op's response reached
        // a client — the event-loop drain invariant
        assert_eq!(summary.engine.report.completed, responses);
    }
    assert_ledger_reconciles(&summary, "graceful drain");
}

#[test]
fn connection_churn_keeps_the_ledger_balanced() {
    // connections that speak, connections that connect and leave
    // without a byte, connections torn mid-register: after the dust
    // settles, accepted == opened + failed and opened == closed
    let spec = ServingSpec {
        workload: "mix-c".into(),
        keys: 1_000,
        ops: 200,
        ..ServingSpec::default()
    };
    let (handle, join, ops) =
        start_server("live", &spec, SrvConfig::default());
    let addr = handle.addr();

    // silent visitors: connect, never write, hang up
    for _ in 0..16 {
        let s = std::net::TcpStream::connect(addr).unwrap();
        drop(s);
    }
    // real traffic among the churn
    let report = run_loadgen(
        &LoadgenConfig {
            addr: addr.to_string(),
            conns: 4,
            depth: 4,
            ..LoadgenConfig::default()
        },
        ops.clone(),
    )
    .expect("loadgen");
    assert_eq!(report.completed as usize, ops.len());
    assert_eq!(report.errors, 0);
    // more silent churn after the load
    for _ in 0..16 {
        let s = std::net::TcpStream::connect(addr).unwrap();
        drop(s);
    }

    // churned conns close asynchronously; poll the live gauges until
    // the ledger balances rather than racing the reaper
    let mut balanced = false;
    for _ in 0..200 {
        let m = handle.metrics();
        if m.conns_opened == m.conns_closed + m.conns_active
            && m.conns_active == 0
            && m.conns_accepted >= 32 + 4
        {
            balanced = true;
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    handle.shutdown();
    let summary = join.join().unwrap();
    assert!(
        balanced,
        "ledger never balanced while live: {:?}",
        summary.srv
    );
    assert_ledger_reconciles(&summary, "churn");
}

#[test]
fn stats_partition_holds_through_the_event_loop() {
    let spec = ServingSpec {
        workload: "mix-c".into(),
        keys: 1_000,
        ops: 300,
        ..ServingSpec::default()
    };
    let (handle, join, ops) =
        start_server("live", &spec, SrvConfig::default());
    let addr = handle.addr().to_string();

    let report = run_loadgen(
        &LoadgenConfig {
            addr: addr.clone(),
            conns: 3,
            depth: 4,
            ..LoadgenConfig::default()
        },
        ops.clone(),
    )
    .expect("loadgen");
    assert_eq!(report.completed as usize, ops.len());
    assert_eq!(report.errors, 0);

    // sent-side counters land after the bytes flush; poll briefly
    let mut ok = false;
    let mut last = String::new();
    for _ in 0..100 {
        let snap = fetch_stats(&addr).expect("stats poll");
        let requests = snap
            .get("srv.requests")
            .and_then(|v| v.as_f64())
            .unwrap_or(-1.0);
        match check_stats_partition(&snap) {
            Ok(()) if requests >= ops.len() as f64 => {
                // the new ledger gauges ride in the same snapshot
                for key in
                    ["srv.conns_opened", "srv.conns_closed", "srv.conns_failed"]
                {
                    assert!(
                        snap.get(key).is_some(),
                        "{key} missing from snapshot"
                    );
                }
                ok = true;
                break;
            }
            Ok(()) => last = format!("requests={requests}"),
            Err(e) => last = e,
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    assert!(ok, "stats never partitioned through the event loop: {last}");

    handle.shutdown();
    let summary = join.join().unwrap();
    assert_eq!(summary.srv.requests, ops.len() as u64);
}

#[test]
fn legacy_threaded_path_still_serves_bit_identically() {
    // the two-threads-per-connection baseline stays selectable (it is
    // the old side of the net_serving old-vs-new sweep) and must keep
    // producing bit-identical scratchpads and a balanced ledger
    let spec = ServingSpec {
        workload: "mix-c".into(),
        keys: 2_000,
        ops: 400,
        ..ServingSpec::default()
    };
    let cfg = SrvConfig { legacy_threads: true, ..SrvConfig::default() };
    let (handle, join, ops) = start_server("live", &spec, cfg);
    let want = expected_sps(&spec, &ops);
    let report = run_loadgen(
        &LoadgenConfig {
            addr: handle.addr().to_string(),
            conns: 3,
            depth: 8,
            record_results: true,
            ..LoadgenConfig::default()
        },
        ops.clone(),
    )
    .expect("loadgen");
    assert_eq!(report.completed as usize, ops.len());
    assert_eq!(report.busy, 0);
    assert_eq!(report.errors, 0);
    for (i, got) in report.results.iter().enumerate() {
        assert_eq!(
            got.as_ref(),
            Some(&want[i]),
            "legacy path op {i} diverged"
        );
    }
    handle.shutdown();
    let summary = join.join().unwrap();
    assert_eq!(summary.engine.report.completed as usize, ops.len());
    assert_ledger_reconciles(&summary, "legacy");
    // the serving-window split reports on this path too
    assert!(summary.serving_ms > 0.0);
    assert!(summary.drain_ms >= 0.0);
}

/// Cross-mode conformance: the same op stream through the event loop
/// and through the legacy threaded tier must yield identical final
/// scratchpads (both equal to the functional oracle).
#[test]
fn event_loop_and_legacy_agree_with_the_oracle() {
    let spec = ServingSpec {
        workload: "skiplist".into(),
        keys: 1_200,
        ops: 250,
        max_scan: 30,
        ..ServingSpec::default()
    };
    let want = {
        let mut shadow = Rack::new(rack_cfg());
        let ops = build_serving_ops(&mut shadow, &spec);
        expected_sps(&spec, &ops)
    };
    for legacy in [false, true] {
        let cfg = SrvConfig {
            legacy_threads: legacy,
            ..SrvConfig::default()
        };
        let (handle, join, ops) = start_server("live", &spec, cfg);
        let report = run_loadgen(
            &LoadgenConfig {
                addr: handle.addr().to_string(),
                conns: 2,
                depth: 4,
                record_results: true,
                ..LoadgenConfig::default()
            },
            ops.clone(),
        )
        .expect("loadgen");
        assert_eq!(
            report.completed as usize,
            ops.len(),
            "legacy={legacy}"
        );
        assert_eq!(report.errors, 0, "legacy={legacy}");
        for (i, got) in report.results.iter().enumerate() {
            assert_eq!(
                got.as_ref(),
                Some(&want[i]),
                "legacy={legacy} op {i} diverged"
            );
        }
        handle.shutdown();
        let _ = join.join().unwrap();
    }
}

#[test]
fn phase_attribution_parity_across_serving_tiers() {
    // the same attributed stream through the event loop and the
    // legacy thread-pair tier must land the same number of samples in
    // every phase histogram and the same per-program counts — the
    // timing negotiation and phase stamping are tier-independent
    let spec = ServingSpec {
        workload: "mix-c".into(),
        keys: 1_000,
        ops: 300,
        ..ServingSpec::default()
    };
    for legacy in [false, true] {
        let cfg = SrvConfig {
            legacy_threads: legacy,
            ..SrvConfig::default()
        };
        let (handle, join, ops) = start_server("live", &spec, cfg);
        let report = run_loadgen(
            &LoadgenConfig {
                addr: handle.addr().to_string(),
                conns: 2,
                depth: 4,
                attribution: true,
                ..LoadgenConfig::default()
            },
            ops.clone(),
        )
        .expect("loadgen");
        assert_eq!(report.completed as usize, ops.len(), "legacy={legacy}");
        assert_eq!(report.busy, 0, "legacy={legacy}");
        assert_eq!(
            report.timed as usize,
            ops.len(),
            "legacy={legacy}: every response must carry a timing block"
        );

        handle.shutdown();
        let summary = join.join().unwrap();
        let g = |k: &str| {
            summary
                .registry
                .get(k)
                .and_then(|v| v.as_f64())
                .unwrap_or(-1.0)
        };
        for key in [
            "engine.phase.queue_wait.count",
            "engine.phase.execute.count",
            "srv.phase.completion.count",
            "srv.phase.write.count",
            "srv.e2e.prog0.count",
            "engine.execute.prog0.count",
        ] {
            assert_eq!(
                g(key) as usize,
                ops.len(),
                "legacy={legacy}: {key}"
            );
        }
        check_stats_partition(&summary.registry)
            .unwrap_or_else(|e| panic!("legacy={legacy}: {e}"));
        assert_ledger_reconciles(&summary, "attribution parity");
    }
}
