//! Cross-backend differential conformance: the contract that makes
//! scenario growth safe.
//!
//! For every one of the 16 registered traversal scenarios
//! (`testgen::StructureKind::ALL`, old and new), one seeded op sequence
//! is streamed through
//!
//! * the functional oracle (`Rack::run_op_functional`),
//! * the rack DES as PULSE and as PULSE-ACC (`in_network_routing`
//!   on/off), and
//! * the live multi-threaded engine (`LiveBackend`) in both routing
//!   modes, at 1 / 2 / 4 shards,
//!
//! asserting **bit-identical scratchpads** (oracle vs DES-functional vs
//! live) and **identical op / iteration / crossing / trap counts**
//! across every executor and both routing modes. Query streams are
//! read-only by construction (`testgen` fuzzer invariant), so results
//! cannot depend on concurrent scheduling.
//!
//! The **mixed read-write suite** (`mut_conform`) additionally streams
//! the offloaded mutation scenarios (hashmap put, list push_front,
//! B+Tree leaf update) and pins *final-structure-state* equivalence
//! plus `check_invariants` against the functional oracle — see the
//! write-path section of `rack/README.md` for the restriction that
//! makes this sound under concurrency (single-writer-per-key /
//! commutative pushes).
//!
//! Nightly CI scales the stream lengths via `PULSE_TEST_SCALE` (see
//! `util::ptest::test_scale`).

use pulse::backend::TraversalBackend;
use pulse::isa::SP_WORDS;
use pulse::live::LiveBackend;
use pulse::rack::{Rack, RackConfig, ServeReport};
use pulse::testgen::{
    random_mutating_ops, random_structure_ops, BuiltScenario, MutScenario,
    StructureKind,
};
use pulse::util::ptest::test_scale;

const CONC: usize = 8;
const SEED: u64 = 0xC04F;
const MUT_SEED: u64 = 0xBEE5;

fn cfg(shards: usize, in_network: bool) -> RackConfig {
    RackConfig {
        nodes: shards,
        node_capacity: 64 << 20,
        // small slabs: structures spread across shards, so the parity
        // below is exercised through real cross-node traversal traffic
        granularity: 4 << 10,
        in_network_routing: in_network,
        ..Default::default()
    }
}

struct Counts {
    completed: u64,
    trapped: u64,
    iters: u64,
    crossings: u64,
}

impl Counts {
    fn of(rep: &ServeReport) -> Self {
        Self {
            completed: rep.completed,
            trapped: rep.trapped,
            iters: rep.total_iters,
            crossings: rep.cross_node_requests,
        }
    }
}

/// Stream one scenario through every executor at one shard count and
/// assert full agreement. Returns the common counts for reporting.
fn conform(kind: StructureKind, shards: usize) -> Counts {
    let scale = test_scale() as usize;
    let build_n = 300 * scale.min(4);
    let query_n = 30 * scale;
    let plan = random_structure_ops(kind, SEED, build_n, query_n);

    // ground truth: the functional oracle on its own rack
    let mut oracle = Rack::new(cfg(shards, true));
    let ob = BuiltScenario::build(&plan, &mut oracle);
    let ops = ob.ops(&plan);
    let expected: Vec<[i64; SP_WORDS]> =
        ops.iter().map(|op| oracle.run_op_functional(op)).collect();

    let mut counts: Option<Counts> = None;
    let mut check = |who: String, got: Counts| {
        assert_eq!(
            got.completed,
            ops.len() as u64,
            "{who}: lost ops ({} of {})",
            got.completed,
            ops.len()
        );
        assert_eq!(got.trapped, 0, "{who}: trapped traversals");
        if let Some(base) = counts.as_ref() {
            assert_eq!(
                got.iters, base.iters,
                "{who}: iteration count diverged"
            );
            assert_eq!(
                got.crossings, base.crossings,
                "{who}: crossing count diverged"
            );
        } else {
            counts = Some(got);
        }
    };

    for in_network in [true, false] {
        let mode = if in_network { "PULSE" } else { "PULSE-ACC" };

        // the rack DES
        let mut des = Rack::new(cfg(shards, in_network));
        let db = BuiltScenario::build(&plan, &mut des);
        let des_ops = db.ops(&plan);
        let rep = des.serve_batch(&des_ops, CONC);
        check(
            format!("{}/{shards} shards/DES {mode}", kind.name()),
            Counts::of(&rep),
        );
        // the DES rack's functional substrate answers like the oracle
        // (read-only streams leave the heap untouched by serving)
        for (i, op) in des_ops.iter().enumerate() {
            assert_eq!(
                des.run_op_functional(op),
                expected[i],
                "{}/{shards} shards/DES {mode}: op {i} scratchpad",
                kind.name()
            );
        }

        // the live engine: real threads, same answers
        let mut live = LiveBackend::new(Rack::new(cfg(shards, in_network)));
        let lb = BuiltScenario::build(&plan, live.rack_mut());
        let live_ops = lb.ops(&plan);
        live.record_results(true);
        let rep = live.serve_batch(&live_ops, CONC);
        check(
            format!("{}/{shards} shards/live {mode}", kind.name()),
            Counts::of(&rep),
        );
        let got = live.last_results();
        assert_eq!(got.len(), expected.len());
        for (i, (g, e)) in got.iter().zip(&expected).enumerate() {
            assert_eq!(
                g, e,
                "{}/{shards} shards/live {mode}: op {i} scratchpad",
                kind.name()
            );
        }
    }
    counts.unwrap()
}

/// One test per scenario family keeps failures attributable and lets
/// the harness parallelize the 16 × {1,2,4} matrix. `expect_cross`
/// is false only for the hash family, whose chains co-locate with
/// their bucket by design (paper §6.1) and therefore never cross.
macro_rules! conformance_tests {
    ($($test_name:ident => $kind:expr, $expect_cross:expr;)*) => {
        $(
            #[test]
            fn $test_name() {
                let mut crossed_anywhere = false;
                for shards in [1usize, 2, 4] {
                    let c = conform($kind, shards);
                    if shards > 1 && c.crossings > 0 {
                        crossed_anywhere = true;
                    }
                }
                // the 4 KB slabs must have spread every multi-node
                // layout; a scenario that never crosses shards is not
                // testing distributed traversal at all
                assert_eq!(
                    crossed_anywhere,
                    $expect_cross,
                    "{}: cross-shard traffic expectation violated",
                    $kind.name()
                );
            }
        )*
    };
}

conformance_tests! {
    conform_forward_list => StructureKind::ForwardList, true;
    conform_linked_list => StructureKind::LinkedList, true;
    conform_hashmap => StructureKind::HashMap, false;
    conform_hashset => StructureKind::HashSet, false;
    conform_bimap => StructureKind::Bimap, false;
    conform_bst_plain => StructureKind::BstPlain, true;
    conform_bst_avl => StructureKind::BstAvl, true;
    conform_bst_splay => StructureKind::BstSplay, true;
    conform_bst_scapegoat => StructureKind::BstScapegoat, true;
    conform_google_btree => StructureKind::GoogleBtree, true;
    conform_bplustree_get => StructureKind::BPlusTreeGet, true;
    conform_bplustree_scan => StructureKind::BPlusTreeScan, true;
    conform_skiplist_find => StructureKind::SkipListFind, true;
    conform_skiplist_scan => StructureKind::SkipListScan, true;
    conform_radix_trie => StructureKind::RadixTrie, true;
    conform_graph_khop => StructureKind::GraphKhop, true;
}

// ---------------------------------------------------------------------
// Mixed read-write conformance (the offloaded write path)
// ---------------------------------------------------------------------

/// Stream one mutating scenario through the functional oracle, the
/// rack DES (both routing modes), and the live engine, at one shard
/// count and at serialized (conc 1) + concurrent (conc 8) windows.
///
/// What must agree, and why it can despite concurrency:
/// * updates are single-writer-per-key (the generator's invariant), so
///   the final hashmap / B+Tree state is schedule-independent and is
///   compared **exactly** against the oracle at every concurrency;
/// * list pushes commute as a set (each links its own pre-allocated
///   node; the sentinel iteration is the linearization point), so the
///   chain is compared exactly under serialized serving and as a
///   multiset under concurrent serving;
/// * `check_invariants` must hold everywhere (acyclic chains, intact
///   sentinels, sorted leaves, stable entry counts);
/// * nothing traps and nothing is lost;
/// * at conc 1 the live engine's per-op scratchpads are bit-identical
///   to the oracle's (under concurrency, a read racing a write may
///   legitimately see either value, so per-op outputs are unchecked).
fn mut_conform(kind: StructureKind, shards: usize) {
    let scale = test_scale() as usize;
    let build_n = 200 * scale.min(4);
    let query_n = 40 * scale;
    let plan = random_mutating_ops(kind, MUT_SEED, build_n, query_n);

    // ground truth: serial functional application in issue order
    let mut oracle = Rack::new(cfg(shards, true));
    let om = MutScenario::build(&plan, &mut oracle);
    let ops = om.ops(&plan);
    let expected_sp: Vec<[i64; SP_WORDS]> =
        ops.iter().map(|op| oracle.run_op_functional(op)).collect();
    om.check_final_state(&mut oracle, &plan, true)
        .unwrap_or_else(|e| panic!("{}/oracle: {e}", kind.name()));
    om.check_invariants(&mut oracle, &plan);

    for in_network in [true, false] {
        let mode = if in_network { "PULSE" } else { "PULSE-ACC" };
        for conc in [1usize, CONC] {
            // exact chain order is only guaranteed when serving is
            // serialized; single-writer structures are always exact
            let exact = conc == 1 || kind != StructureKind::ForwardList;

            // the rack DES
            let mut des = Rack::new(cfg(shards, in_network));
            let dm = MutScenario::build(&plan, &mut des);
            let des_ops = dm.ops(&plan);
            let rep = des.serve_batch(&des_ops, conc);
            let who = format!(
                "{}/{shards} shards/DES {mode}/conc {conc}",
                kind.name()
            );
            assert_eq!(rep.completed, ops.len() as u64, "{who}: lost ops");
            assert_eq!(rep.trapped, 0, "{who}: trapped");
            dm.check_final_state(&mut des, &plan, exact)
                .unwrap_or_else(|e| panic!("{who}: {e}"));
            dm.check_invariants(&mut des, &plan);

            // the live engine
            let mut live =
                LiveBackend::new(Rack::new(cfg(shards, in_network)));
            let lm = MutScenario::build(&plan, live.rack_mut());
            let live_ops = lm.ops(&plan);
            live.record_results(conc == 1);
            let rep = live.serve_batch(&live_ops, conc);
            let who = format!(
                "{}/{shards} shards/live {mode}/conc {conc}",
                kind.name()
            );
            assert_eq!(rep.completed, ops.len() as u64, "{who}: lost ops");
            assert_eq!(rep.trapped, 0, "{who}: trapped");
            if conc == 1 {
                let got = live.last_results();
                assert_eq!(got.len(), expected_sp.len(), "{who}");
                for (i, (g, e)) in
                    got.iter().zip(&expected_sp).enumerate()
                {
                    assert_eq!(g, e, "{who}: op {i} scratchpad");
                }
            }
            lm.check_final_state(live.rack_mut(), &plan, exact)
                .unwrap_or_else(|e| panic!("{who}: {e}"));
            lm.check_invariants(live.rack_mut(), &plan);
        }
    }
}

#[test]
fn mutating_conform_hashmap_put() {
    for shards in [1usize, 2, 4] {
        mut_conform(StructureKind::HashMap, shards);
    }
}

#[test]
fn mutating_conform_list_push_front() {
    for shards in [1usize, 2, 4] {
        mut_conform(StructureKind::ForwardList, shards);
    }
}

#[test]
fn mutating_conform_bplustree_leaf_update() {
    for shards in [1usize, 2, 4] {
        mut_conform(StructureKind::BPlusTreeGet, shards);
    }
}

// ---------------------------------------------------------------------
// Trace conformance (obs/): a sampled trace is a backend-conformance
// artifact, not just a debugging aid — the DES and the live engine
// must narrate the same story hop for hop.
// ---------------------------------------------------------------------

/// Same seeded op stream, serialized serving (conc 1) on the rack DES
/// and on the live engine, every op sampled: the drained traces must be
/// span-for-span identical in `(op, kind)` identity — same dispatches,
/// same shard visits with the same iteration/DRAM-byte counts, same
/// forwards/bounces, same boost grants, same finishes. Timestamps are
/// excluded by construction (the DES stamps virtual ns, the live engine
/// wall ns). Covered at 1/2/4 shards in both routing modes, on a
/// co-located family (hash: Dispatch/Visit/Finish only) and a
/// cross-shard family (skip list: Forward/Bounce traffic too).
#[test]
fn trace_identity_conforms_des_vs_live() {
    let tcfg = pulse::obs::TraceConfig {
        sample_every: 1,
        seed: 0x7ACE,
        ..Default::default()
    };
    for kind in [StructureKind::HashMap, StructureKind::SkipListFind] {
        let plan = random_structure_ops(kind, SEED, 300, 40);
        for shards in [1usize, 2, 4] {
            for in_network in [true, false] {
                let mode =
                    if in_network { "PULSE" } else { "PULSE-ACC" };
                let who = format!(
                    "{}/{shards} shards/{mode}",
                    kind.name()
                );

                let mut des = Rack::new(cfg(shards, in_network));
                let db = BuiltScenario::build(&plan, &mut des);
                let des_ops = db.ops(&plan);
                des.enable_trace(tcfg);
                let rep = des.serve_batch(&des_ops, 1);
                assert_eq!(
                    rep.completed,
                    des_ops.len() as u64,
                    "{who}: DES lost ops"
                );
                let des_trace = des.take_trace();

                let mut live = LiveBackend::new(Rack::new(cfg(
                    shards, in_network,
                )));
                let lb = BuiltScenario::build(&plan, live.rack_mut());
                let live_ops = lb.ops(&plan);
                live.enable_trace(tcfg);
                let rep = live.serve_batch(&live_ops, 1);
                assert_eq!(
                    rep.completed,
                    live_ops.len() as u64,
                    "{who}: live lost ops"
                );
                let live_trace = live.take_trace();

                assert!(
                    !des_trace.is_empty(),
                    "{who}: DES trace is empty with sampling on"
                );
                assert_eq!(
                    des_trace.len(),
                    live_trace.len(),
                    "{who}: span counts diverged"
                );
                assert_eq!(
                    des_trace.identity(),
                    live_trace.identity(),
                    "{who}: traces diverged"
                );
            }
        }
    }
}

/// The zero-overhead contract: with the tracer disabled (the default),
/// serving records nothing, drops nothing, and allocates no rings —
/// pinned via the tracer's own counters on both executors.
#[test]
fn disabled_tracer_records_nothing_and_allocates_no_rings() {
    let plan =
        random_structure_ops(StructureKind::SkipListFind, SEED, 200, 30);

    let mut des = Rack::new(cfg(2, true));
    let db = BuiltScenario::build(&plan, &mut des);
    let ops = db.ops(&plan);
    let _ = des.serve_batch(&ops, CONC);
    assert_eq!(
        des.tracer_stats(),
        pulse::obs::TracerStats::default(),
        "DES: disabled tracer did work"
    );
    assert!(des.take_trace().is_empty());

    let mut live = LiveBackend::new(Rack::new(cfg(2, true)));
    let lb = BuiltScenario::build(&plan, live.rack_mut());
    let live_ops = lb.ops(&plan);
    let _ = live.serve_batch(&live_ops, CONC);
    assert_eq!(
        live.tracer_stats(),
        pulse::obs::TracerStats::default(),
        "live: disabled tracer did work"
    );
    assert!(live.take_trace().is_empty());
}

#[test]
fn registry_covers_all_sixteen_scenarios() {
    assert_eq!(StructureKind::ALL.len(), 16);
    let names: std::collections::BTreeSet<_> =
        StructureKind::ALL.iter().map(|k| k.name()).collect();
    assert_eq!(names.len(), 16, "duplicate scenario names");
}
