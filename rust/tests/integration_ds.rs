//! Data-structure integration: all 13 ported structures exercised
//! through the offload path on a multi-node rack (paper Table 1/5).

use pulse::ds::{
    Bimap, BPlusTree, BstKind, BstMap, ForwardList, GoogleBtree,
    HashMapDs, HashSetDs, LinkedList,
};
use pulse::rack::{Rack, RackConfig};

fn rack() -> Rack {
    Rack::new(RackConfig {
        nodes: 4,
        node_capacity: 128 << 20,
        granularity: 256 << 10,
        ..Default::default()
    })
}

#[test]
fn stl_list_and_forward_list() {
    let mut r = rack();
    let mut fl = ForwardList::new();
    let mut ll = LinkedList::new();
    for i in 0..500 {
        fl.push(&mut r, i * 2);
        ll.push_back(&mut r, i * 2);
    }
    assert!(fl.find(&mut r, 444).is_some());
    assert!(fl.find(&mut r, 445).is_none());
    assert!(ll.find(&mut r, 444).is_some());
    assert!(ll.find(&mut r, 445).is_none());
    assert_eq!(fl.sum(&mut r), ((0..500).map(|i| i * 2).sum(), 500));
}

#[test]
fn stl_map_set_multimap_multiset() {
    // STL ordered containers share the lower_bound walk (Table 5).
    let mut r = rack();
    let mut map = BstMap::new(BstKind::Plain); // std::map / std::set
    let mut multi = BstMap::new(BstKind::Plain); // multimap/multiset
    for i in 0..300 {
        map.insert(&mut r, i * 5, i);
    }
    multi.insert(&mut r, 7, 1);
    multi.insert(&mut r, 7, 2); // duplicate key (multimap)
    assert_eq!(map.get(&mut r, 100), Some(20));
    assert_eq!(map.get(&mut r, 101), None);
    assert_eq!(multi.get(&mut r, 7), Some(1)); // first equal key
}

#[test]
fn boost_unordered_map_set_bimap() {
    let mut r = rack();
    let mut m = HashMapDs::build(&mut r, 64);
    let mut s = HashSetDs::build(&mut r, 64);
    let mut bm = Bimap::build(&mut r, 64);
    for i in 0..400 {
        m.insert(&mut r, i, i * i);
        if i % 2 == 0 {
            s.insert(&mut r, i);
        }
        bm.insert(&mut r, i, 100_000 + i);
    }
    assert_eq!(m.get(&mut r, 20), Some(400));
    assert!(s.contains(&mut r, 20));
    assert!(!s.contains(&mut r, 21));
    assert_eq!(bm.get_by_left(&mut r, 33), Some(100_033));
    assert_eq!(bm.get_by_right(&mut r, 100_033), Some(33));
}

#[test]
fn boost_avl_splay_scapegoat() {
    let mut r = rack();
    for kind in [BstKind::Avl, BstKind::Splay, BstKind::Scapegoat] {
        let mut t = BstMap::new(kind);
        for i in 0..200 {
            t.insert(&mut r, i, 1000 + i); // adversarial sorted order
        }
        for i in (0..200).step_by(17) {
            assert_eq!(t.get(&mut r, i), Some(1000 + i), "{kind:?}");
        }
        assert_eq!(t.get(&mut r, 777), None, "{kind:?}");
    }
}

#[test]
fn google_btree_and_bplustree() {
    let mut r = rack();
    let pairs: Vec<(i64, i64)> = (0..3000).map(|i| (i * 2, i)).collect();
    let gb = GoogleBtree::build_sorted(&mut r, &pairs);
    let bp = BPlusTree::build_sorted(&mut r, &pairs, 7);
    for probe in (0..6000).step_by(61) {
        let want = (probe % 2 == 0 && probe < 6000)
            .then(|| probe / 2)
            .filter(|_| probe / 2 < 3000);
        assert_eq!(gb.get(&mut r, probe), want, "btree {probe}");
        assert_eq!(bp.get(&mut r, probe), want, "bplus {probe}");
    }
    // range ops are B+Tree-only
    assert_eq!(
        bp.scan(&mut r, 100, 5),
        vec![50, 51, 52, 53, 54]
    );
}

#[test]
fn distributed_structures_cross_node_boundaries() {
    // With tiny slabs every structure spans all 4 nodes; traversals
    // must cross (and the accelerators must bounce through the switch).
    let mut r = Rack::new(RackConfig {
        nodes: 4,
        node_capacity: 128 << 20,
        granularity: 4096,
        ..Default::default()
    });
    let pairs: Vec<(i64, i64)> = (0..5000).map(|i| (i, i * 3)).collect();
    let bp = BPlusTree::build_sorted(&mut r, &pairs, 7);
    for probe in (0..5000).step_by(97) {
        assert_eq!(bp.get(&mut r, probe), Some(probe * 3));
    }
    let bounces: u64 = r.memnodes.iter().map(|m| m.bounces).sum();
    assert!(bounces > 0, "no cross-node traversals happened");
    // owners really differ
    let owners: std::collections::BTreeSet<_> = (0..5000)
        .step_by(111)
        .filter_map(|k| {
            let leaf = bp.locate(&mut r, k);
            r.alloc.owner(leaf)
        })
        .collect();
    assert!(owners.len() >= 3, "tree not spread: {owners:?}");
}

#[test]
fn traversal_results_independent_of_node_count() {
    let build_and_probe = |nodes: usize| -> Vec<Option<i64>> {
        let mut r = Rack::new(RackConfig {
            nodes,
            node_capacity: 128 << 20,
            granularity: 64 << 10,
            ..Default::default()
        });
        let mut m = HashMapDs::build(&mut r, 128);
        for i in 0..1000 {
            m.insert(&mut r, i * 7 % 997, i);
        }
        (0..1000).map(|k| m.get(&mut r, k)).collect()
    };
    let r1 = build_and_probe(1);
    let r2 = build_and_probe(2);
    let r4 = build_and_probe(4);
    assert_eq!(r1, r2);
    assert_eq!(r2, r4);
}
