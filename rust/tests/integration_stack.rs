//! End-to-end integration over the full rack stack: dispatch engine →
//! switch → accelerators → responses, under the DES with loss,
//! continuations, caching, and all three applications.

use pulse::apps::{BtrDbApp, WebServiceApp, WiredTigerApp};
use pulse::ds::HashMapDs;
use pulse::isa::SP_WORDS;
use pulse::rack::{Op, Rack, RackConfig};
use pulse::workloads::{YcsbSpec, YcsbWorkload};

fn cfg(nodes: usize) -> RackConfig {
    RackConfig {
        nodes,
        node_capacity: 512 << 20,
        granularity: 8 << 20,
        ..Default::default()
    }
}

#[test]
fn webservice_ycsb_abc_across_node_counts() {
    for nodes in [1usize, 2, 4] {
        let mut r = Rack::new(cfg(nodes));
        let app = WebServiceApp::build(&mut r, 1000, 7);
        for spec in [YcsbSpec::A, YcsbSpec::B, YcsbSpec::C] {
            let w = YcsbWorkload::new(spec, 1000, true, 11);
            let mut ops = app.op_stream(w, 200);
            let report = r.serve(move |i| ops(i), 16);
            assert_eq!(
                report.completed, 200,
                "{spec:?} on {nodes} nodes lost ops"
            );
            assert_eq!(report.trapped, 0, "{spec:?} trapped");
            assert!(report.latency.p50() > 0);
        }
    }
}

#[test]
fn wiredtiger_scans_complete_across_nodes() {
    let mut r = Rack::new(cfg(4));
    let app = WiredTigerApp::build(&mut r, 20_000, 3);
    let w = YcsbWorkload::new(YcsbSpec::E, 20_000, true, 5)
        .with_max_scan(60);
    let mut ops = app.op_stream(w, 150);
    let report = r.serve(move |i| ops(i), 8);
    assert_eq!(report.completed, 150);
    assert_eq!(report.trapped, 0);
    // scans average ~30 records ⇒ many iterations per op
    assert!(report.total_iters / report.completed > 10);
}

#[test]
fn btrdb_windows_complete_and_scale_with_resolution() {
    let mut r = Rack::new(cfg(2));
    let app = BtrDbApp::build(&mut r, 30_000, 5);
    const SEC: i64 = 1_000_000_000;
    let mut latencies = Vec::new();
    for win in [SEC, 2 * SEC, 4 * SEC, 8 * SEC] {
        let mut ops = app.op_stream(win, 40, 13);
        let report = r.serve(move |i| ops(i), 4);
        assert_eq!(report.completed, 40, "window {win}");
        latencies.push(report.latency.mean());
    }
    // 8x the window is ~8x the leaf iterations, but fixed network +
    // descend costs dilute the scaling at this data size.
    assert!(
        latencies[3] > latencies[0] * 2.0,
        "8s window should cost ≫ 1s: {latencies:?}"
    );
}

#[test]
fn throughput_increases_with_memory_nodes() {
    // Fig. 7 bottom-row trend: more memory nodes => more accelerators
    // => higher aggregate throughput (B+Tree workload spreads load).
    let tput_of = |nodes: usize| {
        let mut c = cfg(nodes);
        c.granularity = 64 << 10; // fine slabs spread the tree itself
        let mut r = Rack::new(c);
        let app = WiredTigerApp::build(&mut r, 50_000, 9);
        let w = YcsbWorkload::new(YcsbSpec::E, 50_000, true, 5)
            .with_max_scan(20);
        let mut ops = app.op_stream(w, 2000);
        let report = r.serve(move |i| ops(i), 512);
        report.tput_ops_per_s
    };
    let t1 = tput_of(1);
    let t4 = tput_of(4);
    assert!(t4 > 1.2 * t1, "t1={t1:.0} t4={t4:.0}");
}

#[test]
fn library_cache_reduces_offloads_for_zipf() {
    // Appendix C.2 access-pattern study: with a CPU-side cache, skewed
    // (Zipf) traffic completes more requests locally than uniform.
    let hits_with = |zipf: bool| {
        let mut c = cfg(1);
        c.dispatch.cache_bytes = 8 << 20;
        let mut r = Rack::new(c);
        let mut m = HashMapDs::build(&mut r, 4096);
        for k in 0..4096 {
            m.insert(&mut r, k, k);
        }
        // warm the cache with node images (the library caches what it
        // inserted/read, §2.3)
        for k in 0..4096i64 {
            let mut node = [0i64; 3];
            let b = m.bucket_ptr(k);
            r.read_words(b, &mut node);
            r.dispatch.cache.insert(b, &node);
            if node[2] != 0 {
                let mut chain = [0i64; 3];
                r.read_words(node[2] as u64, &mut chain);
                r.dispatch.cache.insert(node[2] as u64, &chain);
            }
        }
        let w = YcsbWorkload::new(YcsbSpec::C, 4096, zipf, 21);
        let prog = m.find_program();
        let mut w2 = w;
        let buckets: Vec<u64> =
            (0..4096).map(|k| m.bucket_ptr(k)).collect();
        let mut ops = move |i: u64| {
            if i >= 500 {
                return None;
            }
            let key = match w2.next_op() {
                pulse::workloads::YcsbOp::Read(k) => k as i64,
                _ => 0,
            };
            let mut sp = [0i64; SP_WORDS];
            sp[0] = key;
            Some(Op::new(prog.clone(), buckets[key as usize], sp))
        };
        let report = r.serve(move |i| ops(i), 8);
        assert_eq!(report.completed, 500);
        r.dispatch.stats.cache_hit_iters
    };
    let zipf_hits = hits_with(true);
    let unif_hits = hits_with(false);
    assert!(zipf_hits > 0, "cache never hit");
    let _ = unif_hits; // both hit (cache is warm); zipf >= uniform holds
    assert!(zipf_hits >= unif_hits * 9 / 10);
}

#[test]
fn heavy_loss_still_completes_everything() {
    let mut c = cfg(2);
    c.loss = 0.15;
    c.dispatch.timeout_ns = 80_000;
    let mut r = Rack::new(c);
    let app = WebServiceApp::build(&mut r, 200, 2);
    let w = YcsbWorkload::new(YcsbSpec::C, 200, true, 3);
    let mut ops = app.op_stream(w, 120);
    let report = r.serve(move |i| ops(i), 8);
    assert_eq!(report.completed, 120, "loss broke completion");
    assert!(report.retransmits > 0);
}
