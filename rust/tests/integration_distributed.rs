//! Distributed-traversal integration (paper §5 + Fig. 9): in-network
//! re-routing vs PULSE-ACC, hierarchical translation consistency,
//! stateful continuation across nodes, and allocation-policy effects
//! (Appendix C.2).

use pulse::ds::{BPlusTree, ForwardList};
use pulse::isa::SP_WORDS;
use pulse::mem::AllocPolicy;
use pulse::rack::{Op, Rack, RackConfig};

fn spread_cfg(nodes: usize) -> RackConfig {
    RackConfig {
        nodes,
        node_capacity: 128 << 20,
        granularity: 4096,
        ..Default::default()
    }
}

#[test]
fn stateful_aggregation_survives_node_crossings() {
    // list_sum carries a running aggregate in the scratchpad; spreading
    // the list over 4 nodes must not change the sum (the §5 migration
    // property).
    let sum_with_nodes = |nodes: usize| {
        let mut r = Rack::new(spread_cfg(nodes));
        let mut l = ForwardList::new();
        for i in 1..=2000 {
            l.push(&mut r, i);
        }
        l.sum(&mut r)
    };
    assert_eq!(sum_with_nodes(1), (2001000, 2000));
    assert_eq!(sum_with_nodes(4), (2001000, 2000));
}

#[test]
fn switch_reroutes_without_cpu_in_pulse_mode() {
    let mut r = Rack::new(spread_cfg(4));
    let mut l = ForwardList::new();
    for i in 0..2000 {
        l.push(&mut r, i);
    }
    let prog = l.find_program();
    let head = l.head;
    let mut n = 0;
    let report = r.serve(
        move |_| {
            n += 1;
            if n > 30 {
                return None;
            }
            let mut sp = [0i64; SP_WORDS];
            sp[0] = 1900; // deep target
            Some(Op::new(prog.clone(), head, sp))
        },
        2,
    );
    assert_eq!(report.completed, 30);
    assert!(r.switch.stats.reroutes > 0, "no in-network reroutes");
}

#[test]
fn fig9_pulse_acc_latency_penalty_in_paper_band() {
    // Fig. 9: identical single-node performance; 1.02–1.15× higher
    // latency for PULSE-ACC at 2 nodes (some traversals bounce).
    let run = |nodes: usize, in_network: bool| {
        let mut cfg = spread_cfg(nodes);
        cfg.in_network_routing = in_network;
        cfg.granularity = 64 << 10;
        let mut r = Rack::new(cfg);
        let pairs: Vec<(i64, i64)> =
            (0..20_000).map(|i| (i, i)).collect();
        let t = BPlusTree::build_sorted(&mut r, &pairs, 7);
        let prog = t.get_program();
        let root = t.root;
        let mut n = 0u64;
        let report = r.serve(
            move |_| {
                n += 1;
                if n > 200 {
                    return None;
                }
                let mut sp = [0i64; SP_WORDS];
                sp[0] = ((n * 97) % 20_000) as i64;
                Some(Op::new(prog.clone(), root, sp))
            },
            4,
        );
        assert_eq!(report.completed, 200);
        report.latency.mean()
    };
    let single_pulse = run(1, true);
    let single_acc = run(1, false);
    let ratio1 = single_acc / single_pulse;
    assert!(
        (0.98..1.02).contains(&ratio1),
        "single-node should be identical: {ratio1}"
    );
    let two_pulse = run(2, true);
    let two_acc = run(2, false);
    let ratio2 = two_acc / two_pulse;
    assert!(
        (1.0..1.6).contains(&ratio2),
        "2-node ACC penalty out of band: {ratio2}"
    );
}

#[test]
fn allocation_policy_changes_crossings_not_results() {
    // Appendix C.2: random allocation costs 3.7–10.8× more for
    // distributed traversals; results must be identical.
    let run = |policy: AllocPolicy| {
        let mut cfg = spread_cfg(2);
        cfg.policy = policy;
        cfg.granularity = 4096;
        let mut r = Rack::new(cfg);
        let pairs: Vec<(i64, i64)> =
            (0..10_000).map(|i| (i, i * 2)).collect();
        let t = BPlusTree::build_sorted(&mut r, &pairs, 7);
        let mut results = Vec::new();
        for probe in (0..10_000).step_by(501) {
            results.push(t.get(&mut r, probe));
        }
        let bounces: u64 = r.memnodes.iter().map(|m| m.bounces).sum();
        (results, bounces)
    };
    let (res_contig, bounce_contig) = run(AllocPolicy::Contiguous);
    let (res_random, bounce_random) = run(AllocPolicy::Random);
    assert_eq!(res_contig, res_random, "policy changed results!");
    assert!(
        bounce_random > bounce_contig,
        "random placement should cross more: {bounce_random} vs {bounce_contig}"
    );
}

#[test]
fn finer_granularity_increases_crossings() {
    // Fig. 2b: smaller allocation granularity => more cross-node
    // traversals.
    let crossings_at = |gran: u64| {
        let mut cfg = spread_cfg(4);
        cfg.granularity = gran;
        let mut r = Rack::new(cfg);
        let mut l = ForwardList::new();
        for i in 0..4000 {
            l.push(&mut r, i);
        }
        for probe in (0..4000).step_by(201) {
            let _ = l.find(&mut r, probe);
        }
        r.memnodes.iter().map(|m| m.bounces).sum::<u64>()
    };
    let fine = crossings_at(4096);
    let coarse = crossings_at(1 << 20);
    assert!(
        fine > coarse,
        "4 KB slabs should cross more than 1 MB: {fine} vs {coarse}"
    );
}

#[test]
fn invalid_pointer_traps_and_notifies_cpu() {
    let mut r = Rack::new(spread_cfg(2));
    let mut l = ForwardList::new();
    let a = l.push(&mut r, 1);
    // corrupt the next pointer to an unmapped address
    r.write_words(a, &[1, 0xDEAD_0000_0000i64]);
    let prog = l.find_program();
    let mut sp = [0i64; SP_WORDS];
    sp[0] = 42; // won't match; walks into the corrupt pointer
    let (st, _sp, _) = r.traverse(&prog, l.head, sp);
    assert_eq!(st, pulse::isa::Status::Trap);
}
