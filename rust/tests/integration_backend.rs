//! The `TraversalBackend` contract: the same YCSB-C workload runs
//! through PULSE (the rack DES), the swap-cache adapter, and the RPC
//! adapter — every system behind the one trait the benches drive, each
//! producing non-empty, internally consistent metrics.

use std::sync::Arc;

use pulse::backend::{CacheBackend, RpcBackend, TraversalBackend};
use pulse::baselines::RpcKind;
use pulse::bench_support::make_backend;
use pulse::compiler::IterBuilder;
use pulse::ds::HashMapDs;
use pulse::isa::SP_WORDS;
use pulse::rack::{Op, Rack, RackConfig, ServeReport, StartAddr};
use pulse::workloads::{YcsbOp, YcsbSpec, YcsbWorkload};

const KEYS: u64 = 2_000;
const OPS: u64 = 300;
const CONC: usize = 8;

fn cfg() -> RackConfig {
    RackConfig {
        nodes: 2,
        node_capacity: 64 << 20,
        granularity: 256 << 10,
        ..Default::default()
    }
}

/// Build the identical hash index in the backend's rack and serve the
/// same deterministic YCSB-C stream through the trait.
fn run_ycsb_c(backend: &mut dyn TraversalBackend) -> ServeReport {
    let mut m = HashMapDs::build(backend.rack_mut(), 512);
    for k in 0..KEYS as i64 {
        m.insert(backend.rack_mut(), k, k * 11);
    }
    let prog = m.find_program();
    // uniform chooser: the swap-cache backend's working set stays far
    // bigger than its page cache, as in the paper's setup
    let mut w = YcsbWorkload::new(YcsbSpec::C, KEYS, false, 77);
    let ops: Vec<Op> = (0..OPS)
        .map(|_| {
            let key = match w.next_op() {
                YcsbOp::Read(k) => (k % KEYS) as i64,
                other => panic!("YCSB-C produced {other:?}"),
            };
            let mut sp = [0i64; SP_WORDS];
            sp[0] = key;
            Op::new(prog.clone(), m.bucket_ptr(key), sp)
        })
        .collect();
    backend.serve_batch(&ops, CONC)
}

fn check_consistent(rep: &ServeReport, m: &pulse::backend::BackendMetrics) {
    assert_eq!(rep.completed, OPS, "{}: lost ops", m.name);
    assert_eq!(rep.trapped, 0, "{}: traps", m.name);
    assert_eq!(rep.latency.count(), OPS, "{}: latency samples", m.name);
    assert!(rep.latency.mean() > 0.0, "{}: zero latency", m.name);
    assert!(
        rep.latency.p99() >= rep.latency.p50(),
        "{}: p99 < p50",
        m.name
    );
    assert!(rep.tput_ops_per_s > 0.0, "{}: zero throughput", m.name);
    assert!(rep.total_iters >= OPS, "{}: fewer iters than ops", m.name);
    assert!(rep.makespan_ns > 0, "{}: zero makespan", m.name);
    // cumulative metrics reflect the run
    assert_eq!(m.ops, OPS, "{}: cumulative ops", m.name);
    assert!(m.mean_latency_ns > 0.0, "{}: cumulative latency", m.name);
    assert!(m.tput_ops_per_s > 0.0, "{}: cumulative tput", m.name);
}

#[test]
fn same_workload_through_all_backends() {
    let mut systems: Vec<Box<dyn TraversalBackend>> = vec![
        Box::new(Rack::new(cfg())),
        // 8 KB page cache vs an ~80 KB working set: thrash, as the
        // paper's cache:WSS ratios do
        Box::new(CacheBackend::new(Rack::new(cfg()), 8 << 10)),
        Box::new(RpcBackend::new(Rack::new(cfg()), RpcKind::Rpc)),
    ];
    let mut names = Vec::new();
    let mut means = Vec::new();
    for backend in systems.iter_mut() {
        let rep = run_ycsb_c(backend.as_mut());
        let m = backend.metrics();
        check_consistent(&rep, &m);
        names.push(m.name);
        means.push(m.mean_latency_ns);
    }
    assert_eq!(names, ["PULSE", "Cache", "RPC"]);
    // the paper's headline ordering at this scale: the swap cache is
    // far slower than both offload paths
    let (pulse, cache) = (means[0], means[1]);
    assert!(
        cache > pulse,
        "swap cache ({cache:.0} ns) should be slower than PULSE \
         ({pulse:.0} ns)"
    );
}

#[test]
fn closed_loop_trait_serving_matches_batch() {
    // `serve` (closed loop) and `serve_batch` (open loop) must agree on
    // virtual-time results for the same op stream on the rack backend.
    let mut backend: Box<dyn TraversalBackend> = Box::new(Rack::new(cfg()));
    let mut m = HashMapDs::build(backend.rack_mut(), 256);
    for k in 0..500i64 {
        m.insert(backend.rack_mut(), k, k);
    }
    let prog = m.find_program();
    let ops: Vec<Op> = (0..100u64)
        .map(|i| {
            let key = (i % 500) as i64;
            let mut sp = [0i64; SP_WORDS];
            sp[0] = key;
            Op::new(prog.clone(), m.bucket_ptr(key), sp)
        })
        .collect();
    let batch = backend.serve_batch(&ops, 4);
    let closed = backend
        .serve(&mut |i| ops.get(i as usize).cloned(), 4);
    assert_eq!(batch.completed, closed.completed);
    assert_eq!(batch.makespan_ns, closed.makespan_ns);
    assert_eq!(batch.latency.p50(), closed.latency.p50());
}

/// A t_c > η·t_d body: the dispatch engine refuses to offload it, so
/// the DES runs it on the CPU with host-side remote reads — the path
/// that used to panic on unmapped addresses.
fn compute_heavy_iter() -> Arc<pulse::compiler::CompiledIter> {
    let mut b = IterBuilder::new();
    let x = b.imm(3);
    let mark = b.temp_mark();
    for _ in 0..12 {
        let y = b.mul(x, x);
        let z = b.add(y, x);
        b.assign(x, z);
        b.temp_release(mark);
    }
    b.sp_store(0, x);
    b.ret();
    Arc::new(b.finish().unwrap())
}

#[test]
fn unmapped_addresses_trap_through_every_backend() {
    // three shapes of stray pointer, served through all five systems:
    //  * an offloadable read starting at unallocated VA (switch/router
    //    answers with a trap);
    //  * an offloaded *write* starting there (the dirty write-back path
    //    must trap identically);
    //  * a non-offloadable body starting there (the DES host-side
    //    `run_on_cpu` read — the `expect` panic this regression pins).
    const BAD: u64 = 0xDEAD_0000_0000;
    for kind in ["pulse", "pulse-acc", "live", "cache", "rpc"] {
        let mut backend = make_backend(kind, cfg());
        let mut m = HashMapDs::build(backend.rack_mut(), 16);
        for k in 0..50 {
            m.insert(backend.rack_mut(), k, k);
        }
        let mut read_op = m.find_op(1);
        read_op.stages[0].start = StartAddr::Fixed(BAD);
        let mut write_op = m.update_op(1, 9);
        write_op.stages[0].start = StartAddr::Fixed(BAD);
        let mut sp = [0i64; SP_WORDS];
        sp[0] = 1;
        let cpu_op = Op::new(compute_heavy_iter(), BAD, sp);
        // a repeat_while stage whose continuation word already points
        // at the stray address: a trapped stage must terminate the op
        // instead of re-issuing the same faulting continuation forever
        let mut sp = [0i64; SP_WORDS];
        sp[0] = BAD as i64; // repeat addr word
        sp[2] = 3; // repeat guard (remaining > 0)
        let mut repeat_op = Op::new(
            m.find_program(),
            BAD,
            sp,
        );
        repeat_op.stages[0].repeat_while = Some((0, 2));
        let ops = vec![read_op, write_op, cpu_op, repeat_op];
        let rep = backend.serve_batch(&ops, 2);
        assert_eq!(rep.completed, 4, "{kind}: lost ops");
        assert_eq!(
            rep.trapped, 4,
            "{kind}: every stray-pointer op must trap (not panic)"
        );
    }
}

#[test]
fn malformed_ops_trap_at_admission() {
    // a repeat-stage op without a usable repeat_while (its words point
    // past the scratchpad) used to panic the DES mid-run; admission
    // validation must trap that op and keep serving the rest
    for kind in ["pulse", "live", "cache"] {
        let mut backend = make_backend(kind, cfg());
        let mut m = HashMapDs::build(backend.rack_mut(), 16);
        for k in 0..20 {
            m.insert(backend.rack_mut(), k, k);
        }
        let mut bad = m.find_op(3);
        bad.stages[0].repeat_while = Some((99, 2));
        let good = m.find_op(5);
        let rep = backend.serve_batch(&[bad, good], 2);
        assert_eq!(rep.completed, 2, "{kind}: lost ops");
        assert_eq!(rep.trapped, 1, "{kind}: malformed op must trap");
        assert_eq!(rep.latency.count(), 2, "{kind}: latency samples");
    }
}

#[test]
fn functional_submit_is_backend_independent() {
    // submit() returns the final scratchpad; the hash lookup's value
    // must be identical through every backend (shared functional
    // substrate, different timing models).
    let build = || {
        let mut r = Rack::new(cfg());
        let mut m = HashMapDs::build(&mut r, 512);
        for k in 0..KEYS as i64 {
            m.insert(&mut r, k, k * 11);
        }
        let prog = m.find_program();
        let mut sp = [0i64; SP_WORDS];
        sp[0] = 1234;
        let op = Op::new(prog, m.bucket_ptr(1234), sp);
        (r, op)
    };
    let (r, op) = build();
    let mut systems: Vec<Box<dyn TraversalBackend>> = vec![
        Box::new(r),
        Box::new(CacheBackend::new(build().0, 1 << 20)),
        Box::new(RpcBackend::new(build().0, RpcKind::RpcArm)),
    ];
    for backend in systems.iter_mut() {
        let sp = backend.submit(&op);
        assert_eq!(
            sp[1],
            1234 * 11,
            "{} returned a wrong functional result",
            backend.name()
        );
    }
}
