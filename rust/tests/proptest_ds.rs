//! Property tests over the data-structure layer: offloaded traversals
//! must agree with host-side reference walks for random operation
//! sequences, regardless of allocation policy, granularity, node count
//! or balancing discipline — the paper's core correctness contract
//! (placement never changes results, only performance).

use pulse::ds::{BPlusTree, BstKind, BstMap, ForwardList, HashMapDs};
use pulse::mem::AllocPolicy;
use pulse::rack::{Rack, RackConfig};
use pulse::util::prng::Rng;
use pulse::util::ptest::run_prop;
use pulse::{prop_assert, prop_assert_eq};

fn rack_with(rng: &mut Rng) -> Rack {
    let nodes = *rng.choose(&[1usize, 2, 4]);
    let granularity = *rng.choose(&[4096u64, 64 << 10, 1 << 20]);
    let policy = *rng.choose(&[
        AllocPolicy::Contiguous,
        AllocPolicy::RoundRobin,
        AllocPolicy::Random,
    ]);
    Rack::new(RackConfig {
        nodes,
        node_capacity: 64 << 20,
        granularity,
        policy,
        seed: rng.next_u64(),
        ..Default::default()
    })
}

#[test]
fn prop_hashmap_matches_reference_under_any_placement() {
    run_prop("hashmap", 0x11AA, 25, |rng| {
        let mut r = rack_with(rng);
        let mut m = HashMapDs::build(&mut r, 32);
        let mut reference = std::collections::HashMap::new();
        for _ in 0..300 {
            let k = rng.below(500) as i64;
            let v = rng.next_i64() >> 8;
            m.insert(&mut r, k, v);
            reference.insert(k, v);
        }
        for k in 0..500i64 {
            prop_assert_eq!(
                m.get(&mut r, k),
                reference.get(&k).copied(),
                "key {}",
                k
            );
        }
        Ok(())
    });
}

#[test]
fn prop_offloaded_update_visible_to_reads() {
    run_prop("update-vis", 0x22BB, 20, |rng| {
        let mut r = rack_with(rng);
        let mut m = HashMapDs::build(&mut r, 16);
        for k in 0..100 {
            m.insert(&mut r, k, 0);
        }
        for _ in 0..200 {
            let k = rng.below(100) as i64;
            let v = rng.next_i64() >> 4;
            prop_assert!(m.update(&mut r, k, v));
            prop_assert_eq!(m.get(&mut r, k), Some(v));
            prop_assert_eq!(m.host_get(&mut r, k), Some(v));
        }
        Ok(())
    });
}

#[test]
fn prop_trees_match_reference_for_all_balancing_kinds() {
    run_prop("trees", 0x33CC, 12, |rng| {
        let kind = *rng.choose(&[
            BstKind::Plain,
            BstKind::Avl,
            BstKind::Splay,
            BstKind::Scapegoat,
        ]);
        let mut r = rack_with(rng);
        let mut t = BstMap::new(kind);
        let mut reference = std::collections::BTreeMap::new();
        for _ in 0..150 {
            let k = rng.below(400) as i64;
            if let std::collections::btree_map::Entry::Vacant(e) =
                reference.entry(k)
            {
                let v = rng.next_i64() >> 8;
                e.insert(v);
                t.insert(&mut r, k, v);
            }
        }
        for k in 0..400i64 {
            prop_assert_eq!(
                t.get(&mut r, k),
                reference.get(&k).copied(),
                "{:?} key {}",
                kind,
                k
            );
        }
        Ok(())
    });
}

#[test]
fn prop_bplustree_point_and_range_ops_agree() {
    run_prop("bplus", 0x44DD, 12, |rng| {
        let mut r = rack_with(rng);
        let n = 200 + rng.below(800) as i64;
        let pairs: Vec<(i64, i64)> =
            (0..n).map(|i| (i * 3, rng.next_i64() >> 8)).collect();
        let t = BPlusTree::build_sorted(&mut r, &pairs, 7);
        // point lookups
        for _ in 0..50 {
            let probe = rng.below(3 * n as u64 + 10) as i64;
            let want = pairs
                .binary_search_by_key(&probe, |p| p.0)
                .ok()
                .map(|i| pairs[i].1);
            prop_assert_eq!(t.get(&mut r, probe), want, "probe {}", probe);
        }
        // range scans
        for _ in 0..10 {
            let start_idx = rng.below(n as u64) as usize;
            let count = 1 + rng.below(60) as usize;
            let got = t.scan(&mut r, pairs[start_idx].0, count);
            let want: Vec<i64> = pairs
                [start_idx..(start_idx + count).min(pairs.len())]
                .iter()
                .map(|p| p.1)
                .collect();
            prop_assert_eq!(got, want, "scan {} +{}", start_idx, count);
        }
        // range sums
        for _ in 0..10 {
            let lo = rng.below(3 * n as u64) as i64;
            let hi = lo + rng.below(600) as i64;
            prop_assert_eq!(
                t.sum_range(&mut r, lo, hi),
                t.host_sum_range(&mut r, lo, hi),
                "sum {}..{}",
                lo,
                hi
            );
        }
        Ok(())
    });
}

#[test]
fn prop_list_find_agnostic_to_granularity() {
    // The same list contents must produce identical find results across
    // slab granularities (which change node placement entirely).
    run_prop("list-gran", 0x55EE, 10, |rng| {
        let values: Vec<i64> =
            (0..400).map(|_| rng.below(300) as i64).collect();
        let probes: Vec<i64> =
            (0..50).map(|_| rng.below(350) as i64).collect();
        let mut results: Option<Vec<bool>> = None;
        for gran in [4096u64, 1 << 20] {
            let mut r = Rack::new(RackConfig {
                nodes: 4,
                node_capacity: 32 << 20,
                granularity: gran,
                policy: AllocPolicy::RoundRobin,
                seed: 7,
                ..Default::default()
            });
            let mut l = ForwardList::new();
            for &v in &values {
                l.push(&mut r, v);
            }
            let found: Vec<bool> = probes
                .iter()
                .map(|&p| l.find(&mut r, p).is_some())
                .collect();
            if let Some(prev) = &results {
                prop_assert_eq!(prev.clone(), found.clone());
            }
            results = Some(found);
        }
        Ok(())
    });
}
