//! Property tests over the data-structure layer, driven by the shared
//! structure-op fuzzer (`testgen::random_structure_ops`) — the same
//! generator the cross-backend conformance suite streams, here checked
//! against host-side references: offloaded traversals must agree with
//! reference walks for random build/insert/delete/lookup/scan
//! sequences, regardless of allocation policy, granularity, node count
//! or balancing discipline — the paper's core correctness contract
//! (placement never changes results, only performance).

use pulse::ds::{BPlusTree, HashMapDs};
use pulse::mem::AllocPolicy;
use pulse::prop_assert;
use pulse::prop_assert_eq;
use pulse::rack::{Rack, RackConfig};
use pulse::testgen::{
    random_mutating_ops, random_structure_ops, BuiltScenario, MutScenario,
    StructureKind, MUTATING_KINDS,
};
use pulse::util::prng::Rng;
use pulse::util::ptest::run_prop;

fn rack_with(rng: &mut Rng) -> Rack {
    let nodes = *rng.choose(&[1usize, 2, 4]);
    let granularity = *rng.choose(&[4096u64, 64 << 10, 1 << 20]);
    let policy = *rng.choose(&[
        AllocPolicy::Contiguous,
        AllocPolicy::RoundRobin,
        AllocPolicy::Random,
    ]);
    Rack::new(RackConfig {
        nodes,
        node_capacity: 64 << 20,
        granularity,
        policy,
        seed: rng.next_u64(),
        ..Default::default()
    })
}

/// Fuzz one scenario family: seeded plan, random rack shape, offloaded
/// answers vs the host reference for every query.
fn fuzz_kind(kind: StructureKind, seed: u64, cases: u64) {
    run_prop(kind.name(), seed, cases, |rng| {
        let mut rack = rack_with(rng);
        let plan = random_structure_ops(
            kind,
            rng.next_u64(),
            60 + rng.below(240) as usize,
            40,
        );
        let built = BuiltScenario::build(&plan, &mut rack);
        built.check_against_reference(&mut rack, &plan)
    });
}

#[test]
fn prop_lists_match_reference_under_any_placement() {
    fuzz_kind(StructureKind::ForwardList, 0x11AA, 8);
    fuzz_kind(StructureKind::LinkedList, 0x11AB, 8);
}

#[test]
fn prop_hash_family_matches_model() {
    fuzz_kind(StructureKind::HashMap, 0x22BB, 10);
    fuzz_kind(StructureKind::HashSet, 0x22BC, 6);
    fuzz_kind(StructureKind::Bimap, 0x22BD, 6);
}

#[test]
fn prop_trees_match_model_for_all_balancing_kinds() {
    fuzz_kind(StructureKind::BstPlain, 0x33C0, 5);
    fuzz_kind(StructureKind::BstAvl, 0x33C1, 5);
    fuzz_kind(StructureKind::BstSplay, 0x33C2, 5);
    fuzz_kind(StructureKind::BstScapegoat, 0x33C3, 5);
    fuzz_kind(StructureKind::GoogleBtree, 0x33C4, 6);
}

#[test]
fn prop_bplustree_point_and_range_ops_agree() {
    fuzz_kind(StructureKind::BPlusTreeGet, 0x44DD, 8);
    fuzz_kind(StructureKind::BPlusTreeScan, 0x44DE, 8);
}

#[test]
fn prop_bplustree_sum_range_under_any_placement() {
    // the leaf-chain aggregation program (BTrDB's traversal) is not in
    // the streamed-conformance registry — pin it here: offloaded
    // boundary-leaf + chain-sum vs the host reference walk across
    // random rack shapes (the chain crosses shard edges at small
    // granularities)
    run_prop("bplus-sum", 0xAB10, 8, |rng| {
        let mut r = rack_with(rng);
        let plan = random_structure_ops(
            StructureKind::BPlusTreeGet,
            rng.next_u64(),
            200,
            0,
        );
        let pairs: Vec<(i64, i64)> = plan.model().into_iter().collect();
        let t = BPlusTree::build_sorted(&mut r, &pairs, 7);
        for _ in 0..12 {
            let lo = rng.below(700) as i64;
            let hi = lo + rng.below(700) as i64;
            prop_assert_eq!(
                t.sum_range(&mut r, lo, hi),
                t.host_sum_range(&mut r, lo, hi),
                "range {}..{}",
                lo,
                hi
            );
        }
        Ok(())
    });
}

#[test]
fn prop_skiplist_survives_insert_delete_interleaving() {
    fuzz_kind(StructureKind::SkipListFind, 0x55E0, 8);
    fuzz_kind(StructureKind::SkipListScan, 0x55E1, 8);
}

#[test]
fn prop_radix_trie_matches_model() {
    fuzz_kind(StructureKind::RadixTrie, 0x66F0, 8);
}

#[test]
fn prop_graph_khop_matches_host_walk() {
    fuzz_kind(StructureKind::GraphKhop, 0x77A0, 8);
}

#[test]
fn prop_mutating_streams_reach_the_oracle_state() {
    // the offloaded write path under random rack shapes: a seeded
    // mixed read-write stream (hashmap puts, list push_fronts, B+Tree
    // leaf updates) applied through the functional path must land the
    // structure exactly on the plan's final model, with invariants
    // intact — regardless of node count, granularity, or placement
    // policy. Runs in the scheduled nightly-soak at PULSE_TEST_SCALE=10
    // like every run_prop suite.
    run_prop("mut-streams", 0xAB77, 12, |rng| {
        let kind = *rng.choose(&MUTATING_KINDS);
        let mut rack = rack_with(rng);
        let plan = random_mutating_ops(
            kind,
            rng.next_u64(),
            40 + rng.below(160) as usize,
            30,
        );
        let ms = MutScenario::build(&plan, &mut rack);
        for op in ms.ops(&plan) {
            rack.run_op_functional(&op);
        }
        ms.check_final_state(&mut rack, &plan, true)
            .map_err(|e| format!("{}: {e}", kind.name()))?;
        ms.check_invariants(&mut rack, &plan);
        Ok(())
    });
}

#[test]
fn prop_mutating_streams_survive_des_serving() {
    // same streams through the timed DES at both routing modes: the
    // final heap must match the single-writer model and hold its
    // invariants after concurrent virtual-time serving
    run_prop("mut-des", 0xAB78, 8, |rng| {
        let kind = *rng.choose(&MUTATING_KINDS);
        let in_network = rng.chance(0.5);
        let mut rack = rack_with(rng);
        rack.cfg.in_network_routing = in_network;
        let plan = random_mutating_ops(
            kind,
            rng.next_u64(),
            40 + rng.below(120) as usize,
            25,
        );
        let ms = MutScenario::build(&plan, &mut rack);
        let ops = ms.ops(&plan);
        let rep = rack.serve_batch(&ops, 6);
        prop_assert_eq!(rep.completed, ops.len() as u64);
        prop_assert_eq!(rep.trapped, 0u64);
        let exact = kind != StructureKind::ForwardList;
        ms.check_final_state(&mut rack, &plan, exact)
            .map_err(|e| format!("{}: {e}", kind.name()))?;
        ms.check_invariants(&mut rack, &plan);
        Ok(())
    });
}

#[test]
fn prop_offloaded_update_visible_to_reads() {
    // the one mutating offload path (chain update write-back) — kept on
    // fuzzer-generated keys, asserted through host reads
    run_prop("update-vis", 0x8811, 15, |rng| {
        let mut r = rack_with(rng);
        let mut m = HashMapDs::build(&mut r, 16);
        for k in 0..100 {
            m.insert(&mut r, k, 0);
        }
        for _ in 0..150 {
            let k = rng.below(100) as i64;
            let v = rng.next_i64() >> 4;
            prop_assert!(m.update(&mut r, k, v));
            prop_assert_eq!(m.get(&mut r, k), Some(v));
            prop_assert_eq!(m.host_get(&mut r, k), Some(v));
        }
        Ok(())
    });
}

#[test]
fn prop_results_agnostic_to_granularity() {
    // the same plan must produce identical query outcomes across slab
    // granularities (which change placement entirely) — for the three
    // new scenarios, whose layouts stress arbitrary shard boundaries
    run_prop("gran-agnostic", 0x99AA, 6, |rng| {
        let kind = *rng.choose(&[
            StructureKind::SkipListFind,
            StructureKind::RadixTrie,
            StructureKind::GraphKhop,
        ]);
        let plan =
            random_structure_ops(kind, rng.next_u64(), 150, 30);
        let mut results: Option<Vec<[i64; pulse::isa::SP_WORDS]>> = None;
        for gran in [4096u64, 1 << 20] {
            let mut rack = Rack::new(RackConfig {
                nodes: 4,
                node_capacity: 64 << 20,
                granularity: gran,
                policy: AllocPolicy::RoundRobin,
                seed: 7,
                ..Default::default()
            });
            let built = BuiltScenario::build(&plan, &mut rack);
            let got: Vec<_> = built
                .ops(&plan)
                .iter()
                .map(|op| rack.run_op_functional(op))
                .collect();
            if let Some(prev) = &results {
                prop_assert_eq!(
                    prev.len(),
                    got.len(),
                    "{} op count",
                    kind.name()
                );
                for (i, (a, b)) in prev.iter().zip(&got).enumerate() {
                    prop_assert_eq!(a, b, "{} op {}", kind.name(), i);
                }
            }
            results = Some(got);
        }
        Ok(())
    });
}

/// Differential soundness of the abstract interpreter: whenever
/// `isa::analyze` proves a program trap-free, no engine may trap on
/// it, for any workspace. Two generators feed the property — the
/// dedicated provable generator (every case exercises the proof) and
/// the unrestricted may-trap generator (whatever the analyzer happens
/// to certify must hold up). Pinned seeds; `PULSE_TEST_SCALE` deepens
/// both the case count (via `run_prop`) as in the rest of this suite.
#[test]
fn prop_analyzer_trap_free_proof_is_sound() {
    use pulse::interp::logic_pass;
    use pulse::isa::{analyze, Status, SP_INPUTS_ALL};
    use pulse::testgen::{random_provable_program, random_workspace};
    use pulse::util::ptest::run_prop;

    run_prop("analyzer-soundness-provable", 0x50AD, 40, |rng| {
        let p = random_provable_program(rng, 10);
        let a = analyze(&p, SP_INPUTS_ALL);
        prop_assert!(
            !a.has_deny(),
            "provable program denied: {:?}",
            a.diags
        );
        prop_assert!(
            a.trap_free,
            "provable program not proved trap-free:\n{p:?}"
        );
        for _ in 0..8 {
            let mut w = random_workspace(rng);
            let r = logic_pass(&p, &mut w);
            prop_assert!(
                r.status != Status::Trap,
                "analyzer-certified program trapped:\n{p:?}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_analyzer_never_falsely_certifies_random_programs() {
    use pulse::interp::logic_pass;
    use pulse::isa::{analyze, Status, SP_INPUTS_ALL};
    use pulse::testgen::{random_verified_program, random_workspace};
    use pulse::util::ptest::run_prop;

    run_prop("analyzer-soundness-random", 0x50AE, 60, |rng| {
        let p = random_verified_program(rng, 24);
        let a = analyze(&p, SP_INPUTS_ALL);
        if !a.trap_free {
            // nothing was certified; nothing to contradict
            return Ok(());
        }
        for _ in 0..8 {
            let mut w = random_workspace(rng);
            let r = logic_pass(&p, &mut w);
            prop_assert!(
                r.status != Status::Trap,
                "analyzer certified a trapping program:\n{p:?}"
            );
        }
        Ok(())
    });
}
