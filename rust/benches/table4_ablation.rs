//! Table 4: coupled (multi-core) vs PULSE's disaggregated pipelines —
//! FPGA area (LUT/BRAM %) and WebService throughput/latency for every
//! (m, n) combination the paper measured.
//! Expected shape: disaggregated 1L+4M tracks coupled 4×4 throughput at
//! substantially less area, with a small latency penalty.

use pulse::accel::{AccelConfig, AccelSim, AreaModel, IterTrace};
use pulse::bench_support::Table;
use pulse::sim::LatencyModel;

fn webservice_trace() -> Vec<IterTrace> {
    // Table 3: ~48 iterations per request, small hash-chain nodes.
    vec![IterTrace { words: 3, instrs: 14, dirty: false }; 48]
}

fn measure(cfg: AccelConfig) -> (f64, f64) {
    let mut sim = AccelSim::new(cfg, LatencyModel::default());
    let tr = webservice_trace();
    let visits: Vec<_> = (0..256)
        .map(|i| pulse::accel::des::VisitSpec {
            arrive: i * 100,
            trace: tr.clone(),
        })
        .collect();
    let done = sim.run(&visits);
    let makespan = *done.iter().max().unwrap() as f64;
    let tput_mops = 256.0 / (makespan / 1e9) / 1e6;
    // single-request latency on an idle accelerator
    let mut idle = AccelSim::new(cfg, LatencyModel::default());
    let lat_us = idle.schedule_visit(0, &tr) as f64 / 1e3;
    (tput_mops, lat_us)
}

fn main() {
    let area = AreaModel::fit();
    let mut tbl = Table::new(
        "Table 4: coupled vs disaggregated",
        &["design", "m", "n", "LUT %", "BRAM %", "tput Mops/s", "lat us"],
    );

    let mut base_tput = None;
    for k in 1..=4usize {
        let cfg = AccelConfig { m_logic: k, n_mem: k, coupled: true };
        let a = area.area(&cfg);
        let (t, l) = measure(cfg);
        if k == 1 {
            base_tput = Some(t);
        }
        tbl.row(&[
            "coupled".into(),
            k.to_string(),
            k.to_string(),
            format!("{:.2}", a.lut_pct),
            format!("{:.2}", a.bram_pct),
            format!(
                "{:.2} ({:+.0}%)",
                t,
                (t / base_tput.unwrap() - 1.0) * 100.0
            ),
            format!("{l:.2}"),
        ]);
    }
    for m in 1..=4usize {
        for n in 1..=4usize {
            let cfg = AccelConfig { m_logic: m, n_mem: n, coupled: false };
            let a = area.area(&cfg);
            let (t, l) = measure(cfg);
            tbl.row(&[
                "PULSE".into(),
                m.to_string(),
                n.to_string(),
                format!("{:.2}", a.lut_pct),
                format!("{:.2}", a.bram_pct),
                format!(
                    "{:.2} ({:+.0}%)",
                    t,
                    (t / base_tput.unwrap() - 1.0) * 100.0
                ),
                format!("{l:.2}"),
            ]);
        }
    }
    tbl.print();
    tbl.save_csv("table4_ablation").expect("write bench_out CSV");

    // headline: 1L+4M vs coupled 4x4
    let (t_pulse, l_pulse) = measure(AccelConfig {
        m_logic: 1,
        n_mem: 4,
        coupled: false,
    });
    let (t_cpl, l_cpl) = measure(AccelConfig {
        m_logic: 4,
        n_mem: 4,
        coupled: true,
    });
    let a_pulse = area.area(&AccelConfig {
        m_logic: 1,
        n_mem: 4,
        coupled: false,
    });
    let a_cpl =
        area.area(&AccelConfig { m_logic: 4, n_mem: 4, coupled: true });
    println!(
        "\nheadline: PULSE 1L+4M = {:.0}% of coupled-4x4 throughput \
         at {:.0}% less LUT area, {:+.0}% latency",
        t_pulse / t_cpl * 100.0,
        (1.0 - a_pulse.lut_pct / a_cpl.lut_pct) * 100.0,
        (l_pulse / l_cpl - 1.0) * 100.0
    );
}
