//! Batched open-loop serving vs closed-loop serving (wall clock).
//!
//! `TraversalBackend::serve_batch` amortizes per-request setup: op
//! generation (YCSB key choosing + op construction) moves out of the
//! timed region, and the rack reuses its DES scratch (event queue,
//! per-node slot tables, run map) across calls instead of reallocating
//! per run. (On the rack DES, issue still clones each `Op` from the
//! slice — the program is `Arc`-shared, so the clone is shallow; the
//! measured win is generation + scratch reuse. The *live* engine's
//! `serve_batch` goes further and issues ops by reference — its
//! clone-vs-borrow before/after is recorded by
//! `benches/live_throughput.rs`.) This bench measures both paths over
//! the same YCSB-C workload and records the wall-clock serving rates +
//! speedup in `bench_out/BENCH_backend_batch.json`.
//!
//! Virtual-time results are identical by construction (asserted below);
//! the win is wall-clock ops/s of the simulator itself.

use pulse::backend::TraversalBackend;
use pulse::bench_support::{save_json, Table};
use pulse::ds::HashMapDs;
use pulse::isa::SP_WORDS;
use pulse::rack::{Op, Rack, RackConfig};
use pulse::util::json::Json;
use pulse::util::zipf::KeyChooser;
use pulse::util::prng::Rng;

const KEYS: u64 = 100_000;
const OPS: u64 = 20_000;
const ROUNDS: usize = 5;
const CONC: usize = 64;

fn build(rack: &mut Rack) -> HashMapDs {
    let mut m = HashMapDs::build(rack, 8192);
    for k in 0..KEYS as i64 {
        m.insert(rack, k, k * 3);
    }
    m
}

fn main() -> std::io::Result<()> {
    let mut rack = Rack::new(RackConfig::bench(2, 1 << 20));
    let m = build(&mut rack);
    let prog = m.find_program();

    // --- closed loop: ops generated inside the timed run -------------
    let closed_t0 = std::time::Instant::now();
    let mut closed_completed = 0u64;
    let mut closed_makespan = 0u64;
    for round in 0..ROUNDS {
        let chooser = KeyChooser::scrambled_zipfian(KEYS);
        let mut rng = Rng::new(round as u64 ^ 0xBA7C);
        let prog = prog.clone();
        let m = &m;
        let rep = rack.serve(
            move |i| {
                if i >= OPS {
                    return None;
                }
                let key = chooser.next(&mut rng) as i64;
                let mut sp = [0i64; SP_WORDS];
                sp[0] = key;
                Some(Op::new(prog.clone(), m.bucket_ptr(key), sp))
            },
            CONC,
        );
        closed_completed += rep.completed;
        closed_makespan += rep.makespan_ns;
    }
    let closed_wall_s = closed_t0.elapsed().as_secs_f64();

    // --- open loop: pre-materialized batch, scratch reuse ------------
    // (generation cost is paid here, outside the serving measurement)
    let batches: Vec<Vec<Op>> = (0..ROUNDS)
        .map(|round| {
            let chooser = KeyChooser::scrambled_zipfian(KEYS);
            let mut rng = Rng::new(round as u64 ^ 0xBA7C);
            (0..OPS)
                .map(|_| {
                    let key = chooser.next(&mut rng) as i64;
                    let mut sp = [0i64; SP_WORDS];
                    sp[0] = key;
                    Op::new(prog.clone(), m.bucket_ptr(key), sp)
                })
                .collect()
        })
        .collect();
    let batch_t0 = std::time::Instant::now();
    let mut batch_completed = 0u64;
    let mut batch_makespan = 0u64;
    for batch in &batches {
        let rep = TraversalBackend::serve_batch(&mut rack, batch, CONC);
        batch_completed += rep.completed;
        batch_makespan += rep.makespan_ns;
    }
    let batch_wall_s = batch_t0.elapsed().as_secs_f64();

    assert_eq!(closed_completed, batch_completed);
    assert_eq!(
        closed_makespan, batch_makespan,
        "same ops must yield identical virtual timing"
    );

    let closed_rate = closed_completed as f64 / closed_wall_s;
    let batch_rate = batch_completed as f64 / batch_wall_s;
    let speedup = batch_rate / closed_rate;

    let mut tbl = Table::new(
        "serve vs serve_batch (wall clock)",
        &["path", "ops", "wall s", "ops/s (wall)"],
    );
    tbl.row(&[
        "serve (closed loop)".into(),
        closed_completed.to_string(),
        format!("{closed_wall_s:.3}"),
        format!("{closed_rate:.0}"),
    ]);
    tbl.row(&[
        "serve_batch (open loop)".into(),
        batch_completed.to_string(),
        format!("{batch_wall_s:.3}"),
        format!("{batch_rate:.0}"),
    ]);
    tbl.print();
    println!("\nserve_batch speedup: {speedup:.2}x (same virtual-time results)");

    let mut j = Json::obj();
    j.set("bench", "backend_batch")
        .set("workload", "ycsb-c/zipf hash lookups")
        .set("keys", KEYS)
        .set("ops_per_round", OPS)
        .set("rounds", ROUNDS as u64)
        .set("concurrency", CONC as u64)
        .set("closed_loop_wall_s", closed_wall_s)
        .set("closed_loop_ops_per_s", closed_rate)
        .set("batch_wall_s", batch_wall_s)
        .set("batch_ops_per_s", batch_rate)
        .set("batch_speedup", speedup)
        .set("virtual_makespan_ns", batch_makespan);
    save_json("BENCH_backend_batch", &j)?;
    Ok(())
}
