//! Fig. 10: latency breakdown inside the PULSE accelerator for one
//! WebService request iteration (calibrated constants + measured
//! end-to-end composition check against the DES).

use pulse::bench_support::Table;
use pulse::rack::{Op, Rack, RackConfig};
use pulse::sim::LatencyModel;

fn main() {
    let m = LatencyModel::default();
    let mut tbl = Table::new(
        "Fig. 10: accelerator latency breakdown (WebService)",
        &["component", "ns"],
    );
    tbl.row(&["network stack (in)".into(), format!("{}", m.accel_net_stack_ns)]);
    tbl.row(&["scheduler".into(), format!("{}", m.accel_sched_ns)]);
    tbl.row(&["TCAM translation".into(), format!("{}", m.accel_tcam_ns)]);
    tbl.row(&["memory controller".into(), format!("{}", m.accel_memctrl_ns)]);
    tbl.row(&["interconnect".into(), format!("{}", m.accel_interconnect_ns)]);
    tbl.row(&["logic (≈3 instr/iter eff.)".into(), "10".into()]);
    tbl.row(&["network stack (out)".into(), format!("{}", m.accel_net_stack_ns)]);
    tbl.print();
    tbl.save_csv("fig10_breakdown").expect("write bench_out CSV");

    // composition check: a single-iteration request through the DES
    // should cost ≈ 2·net_stack + sched + tcam+memctl+interconnect+
    // logic + network path.
    let mut rack = Rack::new(RackConfig {
        nodes: 1,
        node_capacity: 64 << 20,
        granularity: 1 << 20,
        ..Default::default()
    });
    let mut m2 = pulse::ds::HashMapDs::build(&mut rack, 64);
    m2.insert(&mut rack, 7, 70);
    let prog = m2.find_program();
    let bucket = m2.bucket_ptr(7);
    let mut sent = false;
    let report = rack.serve(
        move |_| {
            if sent {
                None
            } else {
                sent = true;
                let mut sp = [0i64; 32];
                sp[0] = 7;
                Some(Op::new(prog.clone(), bucket, sp))
            }
        },
        1,
    );
    let total = report.latency.mean();
    let net = 2.0
        * (m.host_net_stack_ns
            + 2.0 * m.net_hop_ns
            + m.switch_pipeline_ns);
    println!(
        "\nDES single-request end-to-end: {total:.0} ns \
         (network path ≈ {net:.0} ns, accelerator ≈ {:.0} ns)",
        total - net
    );
}
