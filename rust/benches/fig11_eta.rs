//! Fig. 11: sensitivity to η — one logic pipeline, 1..16 memory
//! pipelines, WebService-class workload (t_c/t_d ≈ 1/16). Performance
//! per watt normalized to η = 1; expected ~1.9× gain from η=1 → η=1/4.

use pulse::accel::{AccelConfig, AccelSim, IterTrace};
use pulse::bench_support::Table;
use pulse::energy::PowerModel;
use pulse::sim::LatencyModel;

fn main() {
    let mut tbl = Table::new(
        "Fig. 11: η sensitivity (m=1 logic pipeline)",
        &["n mem", "eta", "tput Mops/s", "node W", "perf/W (norm)"],
    );
    let power = PowerModel::default();
    // very memory-lean logic: hash-chain walk
    let tr = vec![IterTrace { words: 3, instrs: 4, dirty: false }; 48];
    let mut base: Option<f64> = None;
    for n in [1usize, 2, 4, 8, 16] {
        let cfg = AccelConfig { m_logic: 1, n_mem: n, coupled: false };
        let mut sim = AccelSim::new(cfg, LatencyModel::default());
        let visits: Vec<_> = (0..512)
            .map(|i| pulse::accel::des::VisitSpec {
                arrive: i * 50,
                trace: tr.clone(),
            })
            .collect();
        let done = sim.run(&visits);
        let makespan = *done.iter().max().unwrap() as f64;
        let tput = 512.0 / (makespan / 1e9);
        let ppw = power.perf_per_watt(&cfg, tput);
        let norm = match base {
            None => {
                base = Some(ppw);
                1.0
            }
            Some(b) => ppw / b,
        };
        tbl.row(&[
            n.to_string(),
            format!("1/{n}"),
            format!("{:.2}", tput / 1e6),
            format!("{:.1}", power.pulse_node_w(&cfg)),
            format!("{norm:.2}x"),
        ]);
    }
    tbl.print();
    tbl.save_csv("fig11_eta").expect("write bench_out CSV");
    println!(
        "\npaper: decreasing η from 1 to 1/4 improves perf/W by ~1.9x \
         for workloads with t_c/t_d << 1"
    );
}
