//! Offloaded write path: YCSB-A (50% update) and YCSB-B (5% update)
//! mixed read-write serving over the hash index, on the five compared
//! systems (pulse, pulse-acc, live, cache, rpc).
//!
//! What each system pays per offloaded write:
//!  * PULSE / PULSE-ACC / live — the dirty window streams back out of
//!    the accelerator (2× streamed words in both the memory-pipeline
//!    occupancy and the η offload estimate, `isa/cost.rs`), and
//!    `mem_bytes` counts the write-back bytes;
//!  * cache — write-through invalidation: every dirtied page is
//!    flushed over the network and dropped from the LRU (the next read
//!    refaults), the regime where caching fares worst (Maruf &
//!    Chowdhury, *Memory Disaggregation: Advances and Open
//!    Challenges*);
//!  * rpc — the memory-node CPU applies the store locally; the RPC
//!    model's per-op cost is unchanged (reads and writes cost one RPC
//!    either way).
//!
//! The bench asserts the headline: pulse ops/s >= cache ops/s on the
//! YCSB-A mix (the acceptance bar for the write path).
//!
//! Open-loop note: `serve_batch` on the live engine issues ops *by
//! reference* since this PR (before: one `Op::clone` per issue inside
//! the timed region); `benches/live_throughput.rs` records the
//! clone-vs-borrow issue rates that quantify the before/after.
//!
//! Output: table + `bench_out/BENCH_write_path.json`.

use pulse::backend::TraversalBackend;
use pulse::bench_support::{
    build_write_mix_ops, fmt_kops, fmt_us, make_backend, save_json, Table,
    WriteMixSpec,
};
use pulse::rack::RackConfig;
use pulse::util::json::Json;
use pulse::workloads::YcsbSpec;

const NODES: usize = 4;
const GRANULARITY: u64 = 1 << 20;
const OPS: u64 = 4_000;
const CONC: usize = 32;

const BACKENDS: [&str; 5] = ["pulse", "pulse-acc", "live", "cache", "rpc"];
const MIXES: [(YcsbSpec, &str); 2] =
    [(YcsbSpec::A, "ycsb-a"), (YcsbSpec::B, "ycsb-b")];

fn main() -> std::io::Result<()> {
    let spec = WriteMixSpec { ops: OPS, ..Default::default() };
    let mut tbl = Table::new(
        "offloaded write path: YCSB-A/B read-write mixes x five systems",
        &[
            "mix", "backend", "kops/s", "p50 us", "p95 us", "p99 us",
            "iters/op", "mem MB", "traps",
        ],
    );
    let mut rows: Vec<Json> = Vec::new();
    let mut tput: std::collections::BTreeMap<(String, String), f64> =
        std::collections::BTreeMap::new();

    for (mix, mix_name) in MIXES {
        for kind in BACKENDS {
            let mut backend =
                make_backend(kind, RackConfig::bench(NODES, GRANULARITY));
            let ops =
                build_write_mix_ops(backend.rack_mut(), mix, &spec);
            let rep = backend.serve_batch(&ops, CONC);
            assert_eq!(rep.completed, OPS, "{mix_name}/{kind} lost ops");
            assert_eq!(rep.trapped, 0, "{mix_name}/{kind} trapped");
            let (p50, p95, p99) = rep.latency_percentiles();
            let iters_per_op =
                rep.total_iters as f64 / rep.completed as f64;
            tbl.row(&[
                mix_name.to_string(),
                backend.name().to_string(),
                fmt_kops(rep.tput_ops_per_s),
                fmt_us(p50 as f64),
                fmt_us(p95 as f64),
                fmt_us(p99 as f64),
                format!("{iters_per_op:.1}"),
                format!("{:.2}", rep.mem_bytes as f64 / 1e6),
                format!("{}", rep.trapped),
            ]);
            let mut row = Json::obj();
            row.set("mix", mix_name)
                .set("backend", backend.name())
                .set("ops", rep.completed)
                .set("ops_per_s", rep.tput_ops_per_s)
                .set("p50_ns", p50)
                .set("p95_ns", p95)
                .set("p99_ns", p99)
                .set("mean_ns", rep.latency.mean())
                .set("iters_per_op", iters_per_op)
                .set("mem_bytes", rep.mem_bytes)
                .set("trapped", rep.trapped);
            rows.push(row);
            tput.insert(
                (mix_name.to_string(), kind.to_string()),
                rep.tput_ops_per_s,
            );
        }
    }

    tbl.print();
    println!(
        "\nnote: DES rows are virtual time, live rows wall clock, \
         cache/rpc rows analytic models over real traces — compare \
         shapes within a backend family, not columns across families. \
         mem MB counts DRAM bytes served including dirty write-backs."
    );

    // the write-path acceptance bar
    let pulse_a = tput[&("ycsb-a".to_string(), "pulse".to_string())];
    let cache_a = tput[&("ycsb-a".to_string(), "cache".to_string())];
    assert!(
        pulse_a >= cache_a,
        "write path regression: pulse {pulse_a:.0} ops/s < cache \
         {cache_a:.0} ops/s on YCSB-A"
    );
    println!(
        "YCSB-A: pulse {:.1} kops/s vs cache {:.1} kops/s (>= holds)",
        pulse_a / 1e3,
        cache_a / 1e3
    );

    let mut j = Json::obj();
    j.set("bench", "write_path")
        .set("nodes", NODES as u64)
        .set("ops", OPS)
        .set("conc", CONC as u64)
        .set("keys", spec.keys)
        .set("zipf", if spec.zipf { 1u64 } else { 0u64 })
        .set("rows", rows);
    save_json("BENCH_write_path", &j)?;
    Ok(())
}
