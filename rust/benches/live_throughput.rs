//! Live-engine throughput: real-core scaling of shard count × offered
//! load (wall clock, not virtual time).
//!
//! Every configuration serves the same pre-materialized YCSB-C-style
//! hash-lookup batch through `LiveBackend` (one worker thread per
//! memory node, bounded queues, router dispatch) and records wall
//! ops/s plus the p50/p95/p99 latency triple from `util::hist`.
//! Expected shape on a >=4-core host: ops/s grows with shard count at
//! saturating load (the acceptance bar is >=1.5x from 1 -> 4 shards);
//! single-op latency *rises* slightly with shards (queue hop + cache
//! traffic), which is the latency-vs-throughput trade the paper's
//! Fig. 7 panels split. A `pulse` DES row is printed for reference:
//! its throughput is virtual time (modeled hardware), not comparable
//! wall clock — the interesting live column is scaling, not absolute
//! ops/s.
//!
//! Output: table + `bench_out/BENCH_live.json`.

use pulse::backend::TraversalBackend;
use pulse::bench_support::{save_json, Table};
use pulse::ds::HashMapDs;
use pulse::isa::SP_WORDS;
use pulse::live::LiveBackend;
use pulse::rack::{Op, Rack, RackConfig};
use pulse::util::json::Json;
use pulse::util::prng::Rng;
use pulse::util::zipf::KeyChooser;

const KEYS: u64 = 120_000;
const BUCKETS: usize = 2_048; // ~58-node chains => ~30 iters/op avg
const OPS: u64 = 30_000;
const WARMUP: u64 = 2_000;
const SHARDS: [usize; 3] = [1, 2, 4];
const LOADS: [usize; 3] = [1, 16, 128];

fn build_ops(rack: &mut Rack) -> Vec<Op> {
    let mut m = HashMapDs::build(rack, BUCKETS);
    for k in 0..KEYS as i64 {
        m.insert(rack, k, k * 7);
    }
    let prog = m.find_program();
    let chooser = KeyChooser::scrambled_zipfian(KEYS);
    let mut rng = Rng::new(0x11FE);
    (0..OPS + WARMUP)
        .map(|_| {
            let key = chooser.next(&mut rng) as i64;
            let mut sp = [0i64; SP_WORDS];
            sp[0] = key;
            Op::new(prog.clone(), m.bucket_ptr(key), sp)
        })
        .collect()
}

fn main() -> std::io::Result<()> {
    let mut tbl = Table::new(
        "live engine: wall ops/s and latency vs shards x offered load",
        &[
            "shards", "conc", "ops/s", "p50 us", "p95 us", "p99 us",
            "iters/op", "fwd/op",
        ],
    );
    let mut rows: Vec<Json> = Vec::new();
    // rate[shards] at the highest offered load (for the scaling line)
    let mut peak_rate = [0f64; 5];

    for &shards in &SHARDS {
        let mut backend =
            LiveBackend::new(Rack::new(RackConfig::bench(shards, 1 << 20)));
        let ops = build_ops(backend.rack_mut());
        let (warm, timed) = ops.split_at(WARMUP as usize);
        for &conc in &LOADS {
            backend.serve_batch(warm, conc); // populate caches/threads
            let rep = backend.serve_batch(timed, conc);
            assert_eq!(rep.completed, OPS, "{shards} shards lost ops");
            assert_eq!(rep.trapped, 0);
            let (p50, p95, p99) = rep.latency_percentiles();
            let iters_per_op =
                rep.total_iters as f64 / rep.completed as f64;
            let run = backend.last_run().unwrap();
            let fwd_per_op =
                run.total_forwards() as f64 / rep.completed as f64;
            tbl.row(&[
                shards.to_string(),
                conc.to_string(),
                format!("{:.0}", rep.tput_ops_per_s),
                format!("{:.1}", p50 as f64 / 1e3),
                format!("{:.1}", p95 as f64 / 1e3),
                format!("{:.1}", p99 as f64 / 1e3),
                format!("{iters_per_op:.1}"),
                format!("{fwd_per_op:.2}"),
            ]);
            let mut row = Json::obj();
            row.set("shards", shards)
                .set("conc", conc)
                .set("ops", rep.completed)
                .set("ops_per_s", rep.tput_ops_per_s)
                .set("p50_ns", p50)
                .set("p95_ns", p95)
                .set("p99_ns", p99)
                .set("mean_ns", rep.latency.mean())
                .set("iters_per_op", iters_per_op)
                .set("forwards_per_op", fwd_per_op)
                .set("engine", run.to_json());
            rows.push(row);
            if conc == *LOADS.last().unwrap() {
                peak_rate[shards] = rep.tput_ops_per_s;
            }
        }
    }

    tbl.print();

    let scaling = if peak_rate[1] > 0.0 {
        peak_rate[4] / peak_rate[1]
    } else {
        0.0
    };
    println!(
        "\nscaling 1 -> 4 shards at conc={}: {scaling:.2}x \
         (acceptance bar: >=1.5x on a 4-core host)",
        LOADS.last().unwrap()
    );

    // before/after of the serve_batch borrowing fast path: `serve`
    // with a cloning generator over the same slice reproduces the old
    // serve_batch behaviour (one Op::clone per issue inside the timed
    // region); `serve_batch` now issues by reference. Both rates are
    // recorded so the win is visible in every run's output.
    let (clone_rate, borrow_rate) = {
        let conc = *LOADS.last().unwrap();
        let mut b =
            LiveBackend::new(Rack::new(RackConfig::bench(4, 1 << 20)));
        let ops = build_ops(b.rack_mut());
        let (warm, timed) = ops.split_at(WARMUP as usize);
        b.serve_batch(warm, conc);
        let cloned =
            b.serve(&mut |i| timed.get(i as usize).cloned(), conc);
        b.serve_batch(warm, conc);
        let borrowed = b.serve_batch(timed, conc);
        assert_eq!(cloned.completed, borrowed.completed);
        (cloned.tput_ops_per_s, borrowed.tput_ops_per_s)
    };
    println!(
        "serve_batch issue path: clone-per-op {clone_rate:.0} ops/s vs \
         borrow-from-slice {borrow_rate:.0} ops/s ({:.2}x)",
        borrow_rate / clone_rate.max(1e-9)
    );

    // DES reference on the same workload (virtual time; context only)
    let mut des = Rack::new(RackConfig::bench(4, 1 << 20));
    let des_ops = build_ops(&mut des);
    let rep = TraversalBackend::serve_batch(
        &mut des,
        &des_ops[WARMUP as usize..],
        *LOADS.last().unwrap(),
    );
    let (dp50, dp95, dp99) = rep.latency_percentiles();
    println!(
        "reference pulse DES (4 nodes, virtual time): {:.0} ops/s \
         p50={:.1}us p95={:.1}us p99={:.1}us",
        rep.tput_ops_per_s,
        dp50 as f64 / 1e3,
        dp95 as f64 / 1e3,
        dp99 as f64 / 1e3
    );

    let mut j = Json::obj();
    j.set("bench", "live_throughput")
        .set("workload", "ycsb-c/zipf hash lookups")
        .set("keys", KEYS)
        .set("buckets", BUCKETS as u64)
        .set("ops", OPS)
        .set("host_cores", std::thread::available_parallelism()
            .map(|n| n.get() as u64)
            .unwrap_or(0))
        .set("rows", rows)
        .set("scaling_1_to_4_shards", scaling)
        .set("batch_issue_clone_ops_per_s", clone_rate)
        .set("batch_issue_borrow_ops_per_s", borrow_rate)
        .set("des_reference_ops_per_s", rep.tput_ops_per_s);
    save_json("BENCH_live", &j)?;
    Ok(())
}
