//! Scenario expansion bench (fig7 companion): the three new traversal
//! workloads — YCSB-E-style scans over the **skip list**, point lookups
//! over the **256-way radix trie**, and bounded **k-hop graph walks** —
//! served on the four backend families behind the unified trait:
//! the rack DES (PULSE + PULSE-ACC), the live multi-threaded engine,
//! the swap-cache baseline, and the RPC baseline.
//!
//! Reported per (workload, backend): ops/s and the p50/p95/p99 latency
//! triple. DES rows are virtual time, live rows wall clock, model rows
//! analytic — same caveat as fig7: compare *shapes*, not absolute
//! columns across execution models.
//!
//! Output: table + `bench_out/BENCH_scenarios.json`.

use pulse::backend::TraversalBackend;
use pulse::bench_support::{
    build_scenario_ops, fmt_kops, fmt_us, make_backend, save_json,
    ScenarioSpec, Table,
};
use pulse::rack::RackConfig;
use pulse::util::json::Json;

const NODES: usize = 4;
const GRANULARITY: u64 = 1 << 20;
const OPS: u64 = 4_000;
const CONC: usize = 32;

const BACKENDS: [&str; 5] = ["pulse", "pulse-acc", "live", "cache", "rpc"];
const WORKLOADS: [&str; 3] = ["skiplist-e", "trie-lookup", "graph-khop"];

fn spec() -> ScenarioSpec {
    ScenarioSpec { ops: OPS, ..Default::default() }
}

fn main() -> std::io::Result<()> {
    let mut tbl = Table::new(
        "scenario expansion: new workloads x four backend families",
        &[
            "workload", "backend", "kops/s", "p50 us", "p95 us", "p99 us",
            "iters/op", "cross/op",
        ],
    );
    let mut rows: Vec<Json> = Vec::new();

    for workload in WORKLOADS {
        for kind in BACKENDS {
            let mut backend =
                make_backend(kind, RackConfig::bench(NODES, GRANULARITY));
            let ops =
                build_scenario_ops(backend.rack_mut(), workload, &spec());
            let rep = backend.serve_batch(&ops, CONC);
            assert_eq!(rep.completed, OPS, "{workload}/{kind} lost ops");
            assert_eq!(rep.trapped, 0, "{workload}/{kind} trapped");
            let (p50, p95, p99) = rep.latency_percentiles();
            let iters_per_op =
                rep.total_iters as f64 / rep.completed as f64;
            let cross_per_op =
                rep.cross_node_requests as f64 / rep.completed as f64;
            tbl.row(&[
                workload.to_string(),
                backend.name().to_string(),
                fmt_kops(rep.tput_ops_per_s),
                fmt_us(p50 as f64),
                fmt_us(p95 as f64),
                fmt_us(p99 as f64),
                format!("{iters_per_op:.1}"),
                format!("{cross_per_op:.2}"),
            ]);
            let mut row = Json::obj();
            row.set("workload", workload)
                .set("backend", backend.name())
                .set("ops", rep.completed)
                .set("ops_per_s", rep.tput_ops_per_s)
                .set("p50_ns", p50)
                .set("p95_ns", p95)
                .set("p99_ns", p99)
                .set("mean_ns", rep.latency.mean())
                .set("iters_per_op", iters_per_op)
                .set("cross_node_per_op", cross_per_op);
            rows.push(row);
        }
    }

    tbl.print();
    println!(
        "\nnote: DES rows are virtual time, live rows wall clock, \
         cache/rpc rows analytic models over real traces — compare \
         shapes within a backend family, not columns across families."
    );

    let s = spec();
    let mut j = Json::obj();
    j.set("bench", "scenarios")
        .set("nodes", NODES as u64)
        .set("ops", OPS)
        .set("conc", CONC as u64)
        .set("keys_per_workload", s.keys)
        .set("max_scan", s.max_scan)
        .set("graph_max_degree", s.max_degree)
        .set("khop_max", s.max_hops as u64)
        .set("rows", rows);
    save_json("BENCH_scenarios", &j)?;
    Ok(())
}
