//! Fig. 2: why pointer traversals need acceleration.
//!   (a) fraction of op time spent in pointer traversals under a
//!       swap-based cache, vs cache size (6.25% .. 100% of WSS);
//!   (b) % of requests crossing memory nodes at least once, vs
//!       allocation granularity (4 memory nodes);
//!   (c) CDF of per-request node crossings.

use pulse::baselines::cache::{trace_op, CachedSwapSim};
use pulse::bench_support::{bench_rack, Table};
use pulse::ds::{BPlusTree, HashMapDs};
use pulse::isa::SP_WORDS;
use pulse::util::prng::Rng;

fn main() {
    fig2a();
    fig2bc();
}

/// (a) traversal-time fraction vs cache size.
fn fig2a() {
    let mut tbl = Table::new(
        "Fig. 2a: % of op time in pointer traversal (swap cache)",
        &["app", "cache %WSS", "traversal %", "hit rate"],
    );
    for app in ["webservice", "wiredtiger", "btrdb"] {
        let mut rack = bench_rack(1, 1 << 20);
        // working set + per-op trace generator
        let (wss, traces): (u64, Vec<(Vec<u64>, u64, f64)>) = match app {
            "webservice" => {
                let mut m = HashMapDs::build(&mut rack, 512);
                let mut objs = Vec::new();
                for k in 0..2000 {
                    let a = rack.alloc(8192);
                    m.insert(&mut rack, k, a as i64);
                    objs.push(a);
                }
                let prog = m.find_program();
                let mut rng = Rng::new(3);
                let mut ts = Vec::new();
                for _ in 0..400 {
                    let k = rng.below(2000) as i64;
                    let mut sp = [0i64; SP_WORDS];
                    sp[0] = k;
                    let (out, t) = trace_op(
                        &mut rack,
                        &prog,
                        m.bucket_ptr(k),
                        sp,
                        0,
                    );
                    // the hash value IS the 8 KB object's address:
                    // its two pages are part of the op's footprint
                    let mut pages = t.pages.clone();
                    let obj = out[1] as u64;
                    pages.push(obj / 4096);
                    pages.push(obj / 4096 + 1);
                    ts.push((pages, t.iters as u64, 50_000.0));
                }
                (0, ts) // WSS measured from distinct pages below
            }
            _ => {
                let n: i64 = 30_000;
                let pairs: Vec<(i64, i64)> =
                    (0..n).map(|i| (i, i)).collect();
                let t = BPlusTree::build_sorted(&mut rack, &pairs, 7);
                let prog = if app == "btrdb" {
                    t.sum_program()
                } else {
                    t.get_program()
                };
                let mut rng = Rng::new(4);
                let mut ts = Vec::new();
                for _ in 0..300 {
                    let mut sp = [0i64; SP_WORDS];
                    let start = if app == "btrdb" {
                        let k = rng.below((n - 300) as u64) as i64;
                        sp[0] = k + 240; // 240-key window
                        t.locate(&mut rack, k)
                    } else {
                        sp[0] = rng.below(n as u64) as i64;
                        t.root
                    };
                    let (_o, tr) =
                        trace_op(&mut rack, &prog, start, sp, 0);
                    ts.push((tr.pages.clone(), tr.iters as u64, 3_000.0));
                }
                (0, ts) // WSS measured from distinct pages below
            }
        };

        // working set = distinct pages actually touched
        let distinct: std::collections::HashSet<u64> = traces
            .iter()
            .flat_map(|(p, _, _)| p.iter().copied())
            .collect();
        let wss = wss.max(distinct.len() as u64 * 4096);
        for pct in [6.25f64, 12.5, 25.0, 50.0, 100.0] {
            let cache = ((wss as f64) * pct / 100.0) as u64;
            let mut sim = CachedSwapSim::new(cache.max(4096));
            // two passes: warm, then measure
            for round in 0..2 {
                let mut trav_ns = 0f64;
                let mut cpu_ns = 0f64;
                for (pages, _iters, cpu) in &traces {
                    for &p in pages {
                        let t = if sim.access(p) {
                            80.0
                        } else {
                            sim.fault_ns() as f64
                        };
                        trav_ns += t;
                    }
                    cpu_ns += cpu;
                }
                if round == 1 {
                    let frac = trav_ns / (trav_ns + cpu_ns) * 100.0;
                    tbl.row(&[
                        app.to_string(),
                        format!("{pct}"),
                        format!("{frac:.1}"),
                        format!("{:.2}", sim.hit_rate()),
                    ]);
                }
            }
        }
    }
    tbl.print();
    tbl.save_csv("fig2a_traversal_fraction").expect("write bench_out CSV");
}

/// (b) + (c): cross-node requests vs granularity; crossing CDF.
fn fig2bc() {
    let mut tbl = Table::new(
        "Fig. 2b: % requests crossing nodes (4 memory nodes)",
        &["app", "granularity", "% crossing >=1", "avg crossings"],
    );
    let mut cdf = Table::new(
        "Fig. 2c: CDF of node crossings per request (64 KB granularity)",
        &["app", "p50", "p90", "p99", "max"],
    );
    for app in ["wiredtiger", "btrdb"] {
        for gran in [64u64 << 10, 256 << 10, 1 << 20, 8 << 20] {
            let mut rack = bench_rack(4, gran);
            let n: i64 = 40_000;
            // BTrDB keys are time-ordered; WiredTiger random-ish order
            // is emulated by hashing the key order during build.
            let pairs: Vec<(i64, i64)> = if app == "btrdb" {
                (0..n).map(|i| (i, i)).collect()
            } else {
                (0..n).map(|i| (i, i * 7)).collect()
            };
            let t = BPlusTree::build_sorted(&mut rack, &pairs, 7);
            let mut rng = Rng::new(9);
            let mut crossing = 0usize;
            let total = 300usize;
            let mut hist = pulse::util::hist::Histogram::new();
            for _ in 0..total {
                let (prog, start, mut sp) = if app == "btrdb" {
                    let k = rng.below((n - 960) as u64) as i64;
                    let mut sp = [0i64; SP_WORDS];
                    sp[0] = k + 960;
                    (t.sum_program(), t.locate(&mut rack, k), sp)
                } else {
                    let mut sp = [0i64; SP_WORDS];
                    sp[0] = rng.below(n as u64) as i64;
                    (t.get_program(), t.root, sp)
                };
                sp[3] = 0;
                let (_o, tr) = trace_op(&mut rack, &prog, start, sp, 0);
                if tr.crossings > 0 {
                    crossing += 1;
                }
                hist.record(tr.crossings as u64);
            }
            tbl.row(&[
                app.to_string(),
                human(gran),
                format!("{:.0}", crossing as f64 / total as f64 * 100.0),
                format!("{:.2}", hist.mean()),
            ]);
            if gran == 64 << 10 {
                cdf.row(&[
                    app.to_string(),
                    hist.quantile(0.5).to_string(),
                    hist.quantile(0.9).to_string(),
                    hist.quantile(0.99).to_string(),
                    hist.max().to_string(),
                ]);
            }
        }
    }
    tbl.print();
    tbl.save_csv("fig2b_crossings").expect("write bench_out CSV");
    cdf.print();
    cdf.save_csv("fig2c_crossing_cdf").expect("write bench_out CSV");
}

fn human(b: u64) -> String {
    if b >= 1 << 20 {
        format!("{}MB", b >> 20)
    } else {
        format!("{}KB", b >> 10)
    }
}
