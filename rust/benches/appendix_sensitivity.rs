//! Appendix C.2 sensitivity studies:
//!   * access pattern (Zipf vs uniform, with/without library cache);
//!   * write fraction (write-back path vs read-only);
//!   * traversal length (linked-list latency linearity);
//!   * allocation policy (partitioned vs random, 2 nodes);
//!   * number of memory pipelines needed to saturate node bandwidth.

use pulse::accel::{AccelConfig, AccelSim, IterTrace};
use pulse::bench_support::{bench_rack, fmt_us, Table};
use pulse::ds::{BPlusTree, ForwardList, HashMapDs};
use pulse::isa::SP_WORDS;
use pulse::mem::AllocPolicy;
use pulse::rack::{Op, Rack, RackConfig};
use pulse::sim::LatencyModel;
use pulse::util::prng::Rng;
use pulse::workloads::{YcsbOp, YcsbSpec, YcsbWorkload};

fn main() {
    access_pattern();
    write_fraction();
    traversal_length();
    allocation_policy();
    memory_pipelines();
}

/// Zipf vs uniform, with a warm library cache at the CPU node.
fn access_pattern() {
    let mut tbl = Table::new(
        "Access pattern: library cache effect (1 node, warm cache)",
        &["pattern", "mean lat us", "cache-hit iters", "offloads"],
    );
    for (name, zipf) in [("zipfian", true), ("uniform", false)] {
        let mut cfg = RackConfig {
            nodes: 1,
            node_capacity: 512 << 20,
            granularity: 1 << 20,
            ..Default::default()
        };
        cfg.dispatch.cache_bytes = 4 << 20;
        let mut rack = Rack::new(cfg);
        let mut m = HashMapDs::build(&mut rack, 8192);
        for k in 0..8192 {
            m.insert(&mut rack, k, k);
        }
        // warm: library caches the images it wrote (§2.3)
        for k in 0..8192i64 {
            let b = m.bucket_ptr(k);
            let mut img = [0i64; 3];
            rack.read_words(b, &mut img);
            rack.dispatch.cache.insert(b, &img);
            if img[2] != 0 {
                let mut c = [0i64; 3];
                rack.read_words(img[2] as u64, &mut c);
                rack.dispatch.cache.insert(img[2] as u64, &c);
            }
        }
        let mut w = YcsbWorkload::new(YcsbSpec::C, 8192, zipf, 3);
        let prog = m.find_program();
        let buckets: Vec<u64> =
            (0..8192).map(|k| m.bucket_ptr(k)).collect();
        let mut ops = move |i: u64| {
            if i >= 1000 {
                return None;
            }
            let k = match w.next_op() {
                YcsbOp::Read(k) => k as i64,
                _ => 0,
            };
            let mut sp = [0i64; SP_WORDS];
            sp[0] = k;
            Some(Op::new(prog.clone(), buckets[k as usize], sp))
        };
        let rep = rack.serve(move |i| ops(i), 8);
        tbl.row(&[
            name.to_string(),
            fmt_us(rep.latency.mean()),
            rack.dispatch.stats.cache_hit_iters.to_string(),
            rack.dispatch.stats.offloaded.to_string(),
        ]);
    }
    tbl.print();
    tbl.save_csv("appendix_access_pattern").expect("write bench_out CSV");
}

/// Write fraction sweep: offloaded update-in-place vs read.
fn write_fraction() {
    let mut tbl = Table::new(
        "Writes: offloaded update-in-place (write-back path)",
        &["write %", "mean lat us", "tput kops/s"],
    );
    for wr_pct in [0u64, 10, 25, 50] {
        let mut rack = bench_rack(1, 1 << 20);
        let mut m = HashMapDs::build(&mut rack, 2048);
        for k in 0..2048 {
            m.insert(&mut rack, k, k);
        }
        let find = m.find_program();
        let update = m.update_program();
        let buckets: Vec<u64> =
            (0..2048).map(|k| m.bucket_ptr(k)).collect();
        let mut rng = Rng::new(5);
        let mut ops = move |i: u64| {
            if i >= 800 {
                return None;
            }
            let k = rng.below(2048) as i64;
            let mut sp = [0i64; SP_WORDS];
            sp[0] = k;
            if rng.below(100) < wr_pct {
                sp[1] = k * 10;
                Some(Op::new(update.clone(), buckets[k as usize], sp))
            } else {
                Some(Op::new(find.clone(), buckets[k as usize], sp))
            }
        };
        let rep = rack.serve(move |i| ops(i), 16);
        tbl.row(&[
            wr_pct.to_string(),
            fmt_us(rep.latency.mean()),
            format!("{:.1}", rep.tput_ops_per_s / 1e3),
        ]);
    }
    tbl.print();
    tbl.save_csv("appendix_writes").expect("write bench_out CSV");
}

/// Linked-list latency scales linearly in traversal length.
fn traversal_length() {
    let mut tbl = Table::new(
        "Traversal length: linked-list walk (single node)",
        &["nodes traversed", "mean lat us", "ns/hop"],
    );
    let mut rack = bench_rack(1, 8 << 20);
    let mut list = ForwardList::new();
    for i in 0..6000 {
        list.push(&mut rack, i);
    }
    let prog = list.sum_program();
    for len in [100u64, 500, 1000, 2000, 4000] {
        // sum the first `len` nodes by bounding max_iters
        let mut cfg_rack = bench_rack(1, 8 << 20);
        let mut l2 = ForwardList::new();
        for i in 0..len {
            l2.push(&mut cfg_rack, i as i64);
        }
        let head = l2.head;
        let p = prog.clone();
        let mut sent = 0;
        let rep = cfg_rack.serve(
            move |_| {
                sent += 1;
                if sent > 20 {
                    return None;
                }
                Some(Op::new(p.clone(), head, [0i64; SP_WORDS]))
            },
            1,
        );
        tbl.row(&[
            len.to_string(),
            fmt_us(rep.latency.mean()),
            format!("{:.0}", rep.latency.mean() / len as f64),
        ]);
    }
    tbl.print();
    tbl.save_csv("appendix_traversal_length").expect("write bench_out CSV");
}

/// Partitioned vs random allocation for distributed B+Trees.
fn allocation_policy() {
    let mut tbl = Table::new(
        "Allocation policy: B+Tree lookups, 2 nodes, 64 KB slabs",
        &["policy", "mean lat us", "cross-node reqs"],
    );
    for (name, policy) in [
        ("partitioned", AllocPolicy::Contiguous),
        ("uniform", AllocPolicy::RoundRobin),
        ("random", AllocPolicy::Random),
    ] {
        let mut cfg = RackConfig {
            nodes: 2,
            node_capacity: 512 << 20,
            granularity: 64 << 10,
            policy,
            ..Default::default()
        };
        cfg.seed = 11;
        let mut rack = Rack::new(cfg);
        let pairs: Vec<(i64, i64)> =
            (0..60_000).map(|i| (i, i)).collect();
        let t = BPlusTree::build_sorted(&mut rack, &pairs, 7);
        let prog = t.get_program();
        let root = t.root;
        let mut rng = Rng::new(3);
        let mut ops = move |i: u64| {
            if i >= 300 {
                return None;
            }
            let mut sp = [0i64; SP_WORDS];
            sp[0] = rng.below(60_000) as i64;
            Some(Op::new(prog.clone(), root, sp))
        };
        let rep = rack.serve(move |i| ops(i), 4);
        tbl.row(&[
            name.to_string(),
            fmt_us(rep.latency.mean()),
            rep.cross_node_requests.to_string(),
        ]);
    }
    tbl.print();
    tbl.save_csv("appendix_alloc_policy").expect("write bench_out CSV");
}

/// Memory pipelines needed to saturate the node's 25 GB/s.
fn memory_pipelines() {
    let mut tbl = Table::new(
        "Memory pipelines vs achieved bandwidth (linked-list walk)",
        &["n mem pipes", "GB/s", "of 25 GB/s"],
    );
    let tr = vec![IterTrace { words: 32, instrs: 4, dirty: false }; 64];
    for n in [1usize, 2, 4, 8] {
        let cfg = AccelConfig { m_logic: 1, n_mem: n, coupled: false };
        let mut sim = AccelSim::new(cfg, LatencyModel::default());
        let visits: Vec<_> = (0..256)
            .map(|i| pulse::accel::des::VisitSpec {
                arrive: i,
                trace: tr.clone(),
            })
            .collect();
        let done = sim.run(&visits);
        let makespan = *done.iter().max().unwrap() as f64;
        let bytes = 256.0 * 64.0 * 32.0 * 8.0;
        let gbps = bytes / makespan;
        tbl.row(&[
            n.to_string(),
            format!("{gbps:.1}"),
            format!("{:.0}%", gbps / 25.0 * 100.0),
        ]);
    }
    tbl.print();
    tbl.save_csv("appendix_mem_pipelines").expect("write bench_out CSV");
}
