//! Fig. 9: impact of in-network distributed traversals — PULSE vs
//! PULSE-ACC (which returns to the CPU node on every crossing), both
//! driven through the `TraversalBackend` trait (closed-loop `serve` for
//! latency, open-loop `serve_batch` for the saturation run).
//! Expected: identical at 1 node; ACC 1.02–1.15× higher latency at 2
//! nodes; identical throughput (memory-bandwidth bound either way).

use pulse::backend::TraversalBackend;
use pulse::bench_support::{fmt_kops, fmt_us, make_backend, Table, SEC};
use pulse::rack::{Op, RackConfig};
use pulse::workloads::{YcsbSpec, YcsbWorkload};

fn run(app: &str, nodes: usize, kind: &str) -> (f64, f64, u64) {
    let mut cfg = RackConfig::bench(nodes, 64 << 10);
    cfg.seed = 7;
    let mut backend = make_backend(kind, cfg);
    match app {
        "wiredtiger" => {
            let a = pulse::apps::WiredTigerApp::build(
                backend.rack_mut(),
                60_000,
                5,
            );
            let w = YcsbWorkload::new(YcsbSpec::E, 60_000, true, 9)
                .with_max_scan(60);
            let mut lat_ops = a.op_stream(w, 200);
            let lat = backend.serve(&mut lat_ops, 2);
            let mut w2 = YcsbWorkload::new(YcsbSpec::E, 60_000, true, 9)
                .with_max_scan(60);
            let batch: Vec<Op> =
                (0..600).map(|_| a.make_op(&w2.next_op())).collect();
            let tput = backend.serve_batch(&batch, 128);
            (
                lat.latency.mean(),
                tput.tput_ops_per_s,
                lat.cross_node_requests,
            )
        }
        _ => {
            let a = pulse::apps::BtrDbApp::build(
                backend.rack_mut(),
                40_000,
                5,
            );
            let mut lat_ops = a.op_stream(2 * SEC, 200, 9);
            let lat = backend.serve(&mut lat_ops, 2);
            let mut gen = a.op_stream(2 * SEC, 600, 11);
            let batch: Vec<Op> =
                (0..600u64).map_while(|i| gen(i)).collect();
            let tput = backend.serve_batch(&batch, 128);
            (
                lat.latency.mean(),
                tput.tput_ops_per_s,
                lat.cross_node_requests,
            )
        }
    }
}

fn main() -> std::io::Result<()> {
    let mut tbl = Table::new(
        "Fig. 9: PULSE vs PULSE-ACC",
        &[
            "app",
            "nodes",
            "PULSE lat us",
            "ACC lat us",
            "ACC/PULSE",
            "PULSE kops",
            "ACC kops",
        ],
    );
    for app in ["wiredtiger", "btrdb"] {
        for nodes in [1usize, 2] {
            let (pl, pt, _cross) = run(app, nodes, "pulse");
            let (al, at, _) = run(app, nodes, "pulse-acc");
            tbl.row(&[
                app.to_string(),
                nodes.to_string(),
                fmt_us(pl),
                fmt_us(al),
                format!("{:.2}", al / pl),
                fmt_kops(pt),
                fmt_kops(at),
            ]);
        }
    }
    tbl.print();
    tbl.save_csv("fig9_pulse_vs_acc")?;
    Ok(())
}
