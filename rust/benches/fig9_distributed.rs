//! Fig. 9: impact of in-network distributed traversals — PULSE vs
//! PULSE-ACC (which returns to the CPU node on every crossing).
//! Expected: identical at 1 node; ACC 1.02–1.15× higher latency at 2
//! nodes; identical throughput (memory-bandwidth bound either way).

use pulse::bench_support::{fmt_kops, fmt_us, Table};
use pulse::rack::{Rack, RackConfig};
use pulse::workloads::{YcsbSpec, YcsbWorkload};

fn run(app: &str, nodes: usize, in_network: bool) -> (f64, f64, u64) {
    let mut cfg = RackConfig {
        nodes,
        node_capacity: 1 << 30,
        granularity: 64 << 10,
        in_network_routing: in_network,
        ..Default::default()
    };
    cfg.seed = 7;
    let mut rack = Rack::new(cfg);
    match app {
        "wiredtiger" => {
            let a = pulse::apps::WiredTigerApp::build(&mut rack, 60_000, 5);
            let w = YcsbWorkload::new(YcsbSpec::E, 60_000, true, 9)
                .with_max_scan(60);
            let mut lat_ops = a.op_stream(w, 200);
            let lat = rack.serve(move |i| lat_ops(i), 2);
            let w2 = YcsbWorkload::new(YcsbSpec::E, 60_000, true, 9)
                .with_max_scan(60);
            let mut tput_ops = a.op_stream(w2, 600);
            let tput = rack.serve(move |i| tput_ops(i), 128);
            (
                lat.latency.mean(),
                tput.tput_ops_per_s,
                lat.cross_node_requests,
            )
        }
        _ => {
            let a = pulse::apps::BtrDbApp::build(&mut rack, 40_000, 5);
            let mut lat_ops =
                a.op_stream(2 * pulse::bench_support::SEC, 200, 9);
            let lat = rack.serve(move |i| lat_ops(i), 2);
            let mut tput_ops =
                a.op_stream(2 * pulse::bench_support::SEC, 600, 11);
            let tput = rack.serve(move |i| tput_ops(i), 128);
            (
                lat.latency.mean(),
                tput.tput_ops_per_s,
                lat.cross_node_requests,
            )
        }
    }
}

fn main() {
    let mut tbl = Table::new(
        "Fig. 9: PULSE vs PULSE-ACC",
        &[
            "app",
            "nodes",
            "PULSE lat us",
            "ACC lat us",
            "ACC/PULSE",
            "PULSE kops",
            "ACC kops",
        ],
    );
    for app in ["wiredtiger", "btrdb"] {
        for nodes in [1usize, 2] {
            let (pl, pt, _cross) = run(app, nodes, true);
            let (al, at, _) = run(app, nodes, false);
            tbl.row(&[
                app.to_string(),
                nodes.to_string(),
                fmt_us(pl),
                fmt_us(al),
                format!("{:.2}", al / pl),
                fmt_kops(pt),
                fmt_kops(at),
            ]);
        }
    }
    tbl.print();
    tbl.save_csv("fig9_pulse_vs_acc");
}
