//! Fig. 12: PULSE over CXL (paper §7) — workload slowdown on
//! CXL-attached memory vs local DRAM, with and without PULSE, for
//! single-node and 4-node (CXL-switch) setups.

use pulse::bench_support::Table;
use pulse::cxl::{evaluate, CxlParams};

fn main() {
    let mut tbl = Table::new(
        "Fig. 12: slowdown vs local DRAM on CXL memory",
        &["app", "nodes", "CXL", "CXL+PULSE", "PULSE benefit"],
    );
    // per-app traversal profiles (iterations, instrs/iter, CPU ns)
    let apps = [
        ("webservice", 48.0, 14.0, 50_000.0, 0.30),
        ("wiredtiger", 70.0, 40.0, 3_000.0, 0.15),
        ("btrdb", 120.0, 36.0, 1_000.0, 0.25),
    ];
    for (name, iters, instrs, cpu_ns, hit) in apps {
        for nodes in [1usize, 4] {
            let p = CxlParams {
                cache_hit: hit,
                nodes,
                cross_frac: if nodes > 1 { 0.2 } else { 0.0 },
                ..Default::default()
            };
            let out = evaluate(&p, iters, instrs, cpu_ns);
            tbl.row(&[
                name.to_string(),
                nodes.to_string(),
                format!("{:.2}x", out.slowdown_plain()),
                format!("{:.2}x", out.slowdown_pulse()),
                format!("{:.2}x", out.pulse_benefit()),
            ]);
        }
    }
    tbl.print();
    tbl.save_csv("fig12_cxl").expect("write bench_out CSV");
    println!(
        "\npaper: PULSE reduces CXL slowdown 3-5x (4 nodes), \
         4.2-5.2x (1 node); our conservative Ethernet-class crossing \
         compresses the single-node benefit (see EXPERIMENTS.md)"
    );
}
