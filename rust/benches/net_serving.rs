//! Network serving: wall ops/s + client latency of the TCP wire tier
//! (`srv`) over loopback, swept across shard count × connection count
//! × backend.
//!
//! Every configuration starts a real server (`Server::bind` on an
//! ephemeral loopback port), drives it with the real load generator
//! (same YCSB-C hash-lookup stream, closed loop, depth 16 per
//! connection), and records what the *client* observed: wall ops/s
//! and p50/p95/p99 latency, plus the overload counters which must be
//! zero at these sub-saturating sizes (self-asserted — a BUSY or
//! decode error here is a bug, not load).
//!
//! Expected shape: the live backend scales with shards (real worker
//! threads) and with connections until the engine window saturates;
//! the inline backends (pulse DES / cache model serve through the
//! single-threaded functional substrate over the wire) stay flat in
//! shards — the spread between the two is the serving tier's
//! parallelism win, the wire-level analogue of BENCH_live's scaling
//! line.
//!
//! Output: table + `bench_out/BENCH_net.json`.

use pulse::bench_support::{
    build_serving_ops, fmt_us, make_backend, save_json, ServingSpec,
    Table,
};
use pulse::rack::{Rack, RackConfig};
use pulse::srv::{run_loadgen, LoadgenConfig, Server, SrvConfig};
use pulse::util::json::Json;

const OPS: u64 = 4_000;
const KEYS: u64 = 20_000;
const DEPTH: usize = 16;
const CONNS: [usize; 3] = [1, 4, 8];
const SHARDS: [usize; 3] = [1, 2, 4];

fn spec() -> ServingSpec {
    ServingSpec {
        workload: "mix-c".into(),
        keys: KEYS,
        ops: OPS,
        ..ServingSpec::default()
    }
}

/// One server+loadgen round trip; returns the JSON row.
fn run_config(kind: &str, shards: usize, conns: usize, tbl: &mut Table) -> Json {
    let cfg = RackConfig::bench(shards, 1 << 20);
    let mut backend = make_backend(kind, cfg.clone());
    let s = spec();
    let _ = build_serving_ops(backend.rack_mut(), &s);
    let (server, handle) = Server::bind(
        backend,
        "127.0.0.1:0",
        SrvConfig { window: 256, ..SrvConfig::default() },
    )
    .expect("bind ephemeral loopback port");
    let join = std::thread::spawn(move || server.run());

    let mut shadow = Rack::new(cfg);
    let ops = build_serving_ops(&mut shadow, &s);
    let report = run_loadgen(
        &LoadgenConfig {
            addr: handle.addr().to_string(),
            conns,
            depth: DEPTH,
            ..LoadgenConfig::default()
        },
        ops,
    )
    .expect("loadgen run");
    handle.shutdown();
    let summary = join.join().expect("server thread");

    assert_eq!(report.completed, OPS, "{kind}/{shards}/{conns} lost ops");
    assert_eq!(
        report.busy, 0,
        "{kind}/{shards}/{conns}: BUSY at sub-saturating load"
    );
    assert_eq!(report.errors, 0);
    assert_eq!(summary.srv.decode_errors, 0);

    tbl.row(&[
        kind.to_string(),
        shards.to_string(),
        conns.to_string(),
        format!("{:.0}", report.ops_per_s),
        fmt_us(report.latency.p50() as f64),
        fmt_us(report.latency.p95() as f64),
        fmt_us(report.latency.p99() as f64),
        format!("{:.0}", summary.srv.e2e_p50_ns as f64 / 1e3),
        summary.srv.busy.to_string(),
    ]);
    let mut row = Json::obj();
    row.set("backend", kind)
        .set("shards", shards)
        .set("conns", conns)
        .set("depth", DEPTH)
        .set("ops", report.completed)
        .set("ops_per_s", report.ops_per_s)
        .set("client_p50_ns", report.latency.p50())
        .set("client_p95_ns", report.latency.p95())
        .set("client_p99_ns", report.latency.p99())
        .set("client_mean_ns", report.latency.mean())
        .set("busy", report.busy)
        .set("errors", report.errors)
        .set("server", summary.srv.to_json())
        .set("engine", summary.engine.run.to_json());
    row
}

fn main() -> std::io::Result<()> {
    let mut tbl = Table::new(
        "wire serving over loopback: ops/s + client latency \
         (shards x conns x backend)",
        &[
            "backend", "shards", "conns", "ops/s", "p50 us", "p95 us",
            "p99 us", "srv p50", "busy",
        ],
    );
    let mut rows: Vec<Json> = Vec::new();
    let mut live_peak = [0f64; 5];

    // live: the full shard x conn sweep (real worker threads)
    for &shards in &SHARDS {
        for &conns in &CONNS {
            let row = run_config("live", shards, conns, &mut tbl);
            if conns == *CONNS.last().unwrap() {
                live_peak[shards] = row
                    .get("ops_per_s")
                    .and_then(Json::as_f64)
                    .unwrap_or(0.0);
            }
            rows.push(row);
        }
    }
    // inline backends: conn sweep at the standard 2-node rack (their
    // wire serving is single-threaded regardless of shards)
    for kind in ["pulse", "cache"] {
        for &conns in &CONNS {
            rows.push(run_config(kind, 2, conns, &mut tbl));
        }
    }

    tbl.print();
    let scaling = if live_peak[1] > 0.0 {
        live_peak[4] / live_peak[1]
    } else {
        0.0
    };
    println!(
        "\nlive wire scaling 1 -> 4 shards at conns={}: {scaling:.2}x",
        CONNS.last().unwrap()
    );

    let mut j = Json::obj();
    j.set("bench", "net_serving")
        .set("workload", "mix-c (YCSB-C hash lookups over TCP loopback)")
        .set("keys", KEYS)
        .set("ops_per_config", OPS)
        .set("depth", DEPTH)
        .set(
            "host_cores",
            std::thread::available_parallelism()
                .map(|n| n.get() as u64)
                .unwrap_or(0),
        )
        .set("rows", rows)
        .set("live_scaling_1_to_4_shards", scaling);
    save_json("BENCH_net", &j)?;
    Ok(())
}
