//! Network serving: wall ops/s + client latency of the TCP wire tier
//! (`srv`) over loopback, swept across shard count × connection count
//! × backend.
//!
//! Every configuration starts a real server (`Server::bind` on an
//! ephemeral loopback port), drives it with the real load generator
//! (same YCSB-C hash-lookup stream, closed loop, depth 16 per
//! connection), and records what the *client* observed: wall ops/s
//! and p50/p95/p99 latency, plus the overload counters which must be
//! zero at these sub-saturating sizes (self-asserted — a BUSY or
//! decode error here is a bug, not load).
//!
//! Expected shape: the live backend scales with shards (real worker
//! threads) and with connections until the engine window saturates;
//! the inline backends (pulse DES / cache model serve through the
//! single-threaded functional substrate over the wire) stay flat in
//! shards — the spread between the two is the serving tier's
//! parallelism win, the wire-level analogue of BENCH_live's scaling
//! line.
//!
//! A second sweep raises the connection count to 256 and 1024 and
//! runs each size through both serving tiers — the event-loop runtime
//! (`srv::runtime`, the default) and the legacy
//! two-threads-per-connection model (`legacy_threads: true`) —
//! recording old-vs-new ops/s and client p99 side by side. BUSY is
//! recorded, not asserted zero, at these saturating sizes.
//!
//! Output: table + `bench_out/BENCH_net.json`.

use pulse::bench_support::{
    build_serving_ops, fmt_us, make_backend, save_json, ServingSpec,
    Table,
};
use pulse::rack::{Rack, RackConfig};
use pulse::srv::{run_loadgen, LoadgenConfig, Server, SrvConfig};
use pulse::util::json::Json;

const OPS: u64 = 4_000;
const KEYS: u64 = 20_000;
const DEPTH: usize = 16;
const CONNS: [usize; 3] = [1, 4, 8];
const SHARDS: [usize; 3] = [1, 2, 4];

// high-connection sweep: the event-loop runtime vs the legacy
// two-threads-per-connection tier at connection counts where thread
// pairs stop being free
const HIGH_CONNS: [usize; 2] = [256, 1024];
const HIGH_OPS: u64 = 8_192;
const HIGH_DEPTH: usize = 2;

fn spec() -> ServingSpec {
    ServingSpec {
        workload: "mix-c".into(),
        keys: KEYS,
        ops: OPS,
        ..ServingSpec::default()
    }
}

/// One server+loadgen round trip; returns the JSON row.
fn run_config(kind: &str, shards: usize, conns: usize, tbl: &mut Table) -> Json {
    let cfg = RackConfig::bench(shards, 1 << 20);
    let mut backend = make_backend(kind, cfg.clone());
    let s = spec();
    let _ = build_serving_ops(backend.rack_mut(), &s);
    let (server, handle) = Server::bind(
        backend,
        "127.0.0.1:0",
        SrvConfig { window: 256, ..SrvConfig::default() },
    )
    .expect("bind ephemeral loopback port");
    let join = std::thread::spawn(move || server.run());

    let mut shadow = Rack::new(cfg);
    let ops = build_serving_ops(&mut shadow, &s);
    let report = run_loadgen(
        &LoadgenConfig {
            addr: handle.addr().to_string(),
            conns,
            depth: DEPTH,
            ..LoadgenConfig::default()
        },
        ops,
    )
    .expect("loadgen run");
    handle.shutdown();
    let summary = join.join().expect("server thread");

    assert_eq!(report.completed, OPS, "{kind}/{shards}/{conns} lost ops");
    assert_eq!(
        report.busy, 0,
        "{kind}/{shards}/{conns}: BUSY at sub-saturating load"
    );
    assert_eq!(report.errors, 0);
    assert_eq!(summary.srv.decode_errors, 0);

    tbl.row(&[
        kind.to_string(),
        shards.to_string(),
        conns.to_string(),
        format!("{:.0}", report.ops_per_s),
        fmt_us(report.latency.p50() as f64),
        fmt_us(report.latency.p95() as f64),
        fmt_us(report.latency.p99() as f64),
        format!("{:.0}", summary.srv.e2e_p50_ns as f64 / 1e3),
        summary.srv.busy.to_string(),
    ]);
    let mut row = Json::obj();
    row.set("backend", kind)
        .set("shards", shards)
        .set("conns", conns)
        .set("depth", DEPTH)
        .set("ops", report.completed)
        .set("ops_per_s", report.ops_per_s)
        .set("client_p50_ns", report.latency.p50())
        .set("client_p95_ns", report.latency.p95())
        .set("client_p99_ns", report.latency.p99())
        .set("client_mean_ns", report.latency.mean())
        .set("busy", report.busy)
        .set("errors", report.errors)
        .set("server", summary.srv.to_json())
        .set("engine", summary.engine.run.to_json())
        .set("live", live_counters_json(&summary.backend))
        .set("registry", summary.registry);
    row
}

/// The live engine's dataplane counters as a JSON fragment (all zero
/// for the inline backends).
fn live_counters_json(b: &pulse::backend::BackendMetrics) -> Json {
    let mut j = Json::obj();
    j.set("forwards", b.live_forwards)
        .set("yields", b.live_yields)
        .set("traps", b.live_traps)
        .set("drops", b.live_drops)
        .set("max_queue_depth", b.live_max_queue_depth);
    j
}

/// One old-vs-new round trip at high connection count. Unlike the
/// sub-saturating sweep, BUSY is *recorded*, not asserted zero — a
/// thousand closed-loop connections may legitimately brush the window
/// — but accounting must stay exact and decode-clean.
fn run_high_conn(legacy: bool, conns: usize, tbl: &mut Table) -> Json {
    let shards = 2;
    let cfg = RackConfig::bench(shards, 1 << 20);
    let mut backend = make_backend("live", cfg.clone());
    let s = ServingSpec {
        workload: "mix-c".into(),
        keys: KEYS,
        ops: HIGH_OPS,
        ..ServingSpec::default()
    };
    let _ = build_serving_ops(backend.rack_mut(), &s);
    let (server, handle) = Server::bind(
        backend,
        "127.0.0.1:0",
        SrvConfig {
            // window sized to the offered in-flight load: the sweep
            // measures the serving tier, not admission shedding
            window: (conns * HIGH_DEPTH).max(256),
            legacy_threads: legacy,
            ..SrvConfig::default()
        },
    )
    .expect("bind ephemeral loopback port");
    let join = std::thread::spawn(move || server.run());

    let mut shadow = Rack::new(cfg);
    let ops = build_serving_ops(&mut shadow, &s);
    let report = run_loadgen(
        &LoadgenConfig {
            addr: handle.addr().to_string(),
            conns,
            depth: HIGH_DEPTH,
            ..LoadgenConfig::default()
        },
        ops,
    )
    .expect("loadgen run");
    handle.shutdown();
    let summary = join.join().expect("server thread");

    let mode = if legacy { "legacy" } else { "evloop" };
    assert_eq!(
        report.completed + report.busy,
        HIGH_OPS,
        "{mode}/{conns}: op accounting is not a partition"
    );
    assert_eq!(report.errors, 0, "{mode}/{conns}: protocol errors");
    assert_eq!(summary.srv.decode_errors, 0);

    tbl.row(&[
        format!("live/{mode}"),
        shards.to_string(),
        conns.to_string(),
        format!("{:.0}", report.ops_per_s),
        fmt_us(report.latency.p50() as f64),
        fmt_us(report.latency.p95() as f64),
        fmt_us(report.latency.p99() as f64),
        format!("{:.0}", summary.srv.e2e_p50_ns as f64 / 1e3),
        report.busy.to_string(),
    ]);
    let mut row = Json::obj();
    row.set("backend", "live")
        .set("mode", mode)
        .set("shards", shards)
        .set("conns", conns)
        .set("depth", HIGH_DEPTH)
        .set("ops", report.completed)
        .set("ops_per_s", report.ops_per_s)
        .set("client_p50_ns", report.latency.p50())
        .set("client_p95_ns", report.latency.p95())
        .set("client_p99_ns", report.latency.p99())
        .set("client_mean_ns", report.latency.mean())
        .set("busy", report.busy)
        .set("errors", report.errors)
        .set("serving_ms", summary.serving_ms)
        .set("drain_ms", summary.drain_ms)
        .set("server", summary.srv.to_json())
        .set("engine", summary.engine.run.to_json())
        .set("live", live_counters_json(&summary.backend))
        .set("registry", summary.registry);
    row
}

fn main() -> std::io::Result<()> {
    let mut tbl = Table::new(
        "wire serving over loopback: ops/s + client latency \
         (shards x conns x backend)",
        &[
            "backend", "shards", "conns", "ops/s", "p50 us", "p95 us",
            "p99 us", "srv p50", "busy",
        ],
    );
    let mut rows: Vec<Json> = Vec::new();
    let mut live_peak = [0f64; 5];

    // live: the full shard x conn sweep (real worker threads)
    for &shards in &SHARDS {
        for &conns in &CONNS {
            let row = run_config("live", shards, conns, &mut tbl);
            if conns == *CONNS.last().unwrap() {
                live_peak[shards] = row
                    .get("ops_per_s")
                    .and_then(Json::as_f64)
                    .unwrap_or(0.0);
            }
            rows.push(row);
        }
    }
    // inline backends: conn sweep at the standard 2-node rack (their
    // wire serving is single-threaded regardless of shards)
    for kind in ["pulse", "cache"] {
        for &conns in &CONNS {
            rows.push(run_config(kind, 2, conns, &mut tbl));
        }
    }

    // old-vs-new at ≥1k connections: the event-loop runtime against
    // the legacy thread-pair tier, same stream, same window
    let mut high_rows: Vec<Json> = Vec::new();
    for &conns in &HIGH_CONNS {
        for legacy in [true, false] {
            high_rows.push(run_high_conn(legacy, conns, &mut tbl));
        }
    }

    tbl.print();
    let pick = |mode: &str, conns: usize, key: &str| {
        high_rows
            .iter()
            .find(|r| {
                r.get("mode").and_then(Json::as_str) == Some(mode)
                    && r.get("conns").and_then(Json::as_f64)
                        == Some(conns as f64)
            })
            .and_then(|r| r.get(key).and_then(Json::as_f64))
            .unwrap_or(0.0)
    };
    for &conns in &HIGH_CONNS {
        let old_tput = pick("legacy", conns, "ops_per_s");
        let new_tput = pick("evloop", conns, "ops_per_s");
        let old_p99 = pick("legacy", conns, "client_p99_ns");
        let new_p99 = pick("evloop", conns, "client_p99_ns");
        println!(
            "evloop vs legacy at {conns} conns: {:.2}x ops/s \
             ({:.0} vs {:.0}), p99 {:.1}us vs {:.1}us",
            if old_tput > 0.0 { new_tput / old_tput } else { 0.0 },
            new_tput,
            old_tput,
            new_p99 / 1e3,
            old_p99 / 1e3,
        );
    }

    let scaling = if live_peak[1] > 0.0 {
        live_peak[4] / live_peak[1]
    } else {
        0.0
    };
    println!(
        "\nlive wire scaling 1 -> 4 shards at conns={}: {scaling:.2}x",
        CONNS.last().unwrap()
    );

    let mut j = Json::obj();
    j.set("bench", "net_serving")
        .set("workload", "mix-c (YCSB-C hash lookups over TCP loopback)")
        .set("keys", KEYS)
        .set("ops_per_config", OPS)
        .set("depth", DEPTH)
        .set(
            "host_cores",
            std::thread::available_parallelism()
                .map(|n| n.get() as u64)
                .unwrap_or(0),
        )
        .set("rows", rows)
        .set("high_conn_rows", high_rows)
        .set("live_scaling_1_to_4_shards", scaling);
    save_json("BENCH_net", &j)?;
    Ok(())
}
