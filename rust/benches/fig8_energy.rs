//! Fig. 8: energy per operation at memory-bandwidth-saturating load.
//! The saturation run drives PULSE through the `TraversalBackend`
//! trait's open-loop `serve_batch` path; the RPC-family throughputs
//! come from their calibrated models over the measured workload stats.
//! Expected shape: PULSE 4.5–5× below RPC; PULSE-ASIC a further ~6.3–7×;
//! RPC-ARM can exceed RPC (WebService).
//!
//! Note (post PR 1 double-counted-iters fix): `stats_from_report`'s
//! `avg_iters` is now single-counted (≈half the seed's value), so the
//! RPC-family model latencies drop and their modeled throughputs rise
//! accordingly — the RPC/ARM/Cache+RPC energy-per-op columns shift
//! *down* versus the seed run while the PULSE columns (driven by the
//! DES saturation throughput, which never consumed the double count)
//! hold; the paper-relative ordering above is preserved. Derived
//! analytically; re-verify numerically on a host with a Rust
//! toolchain.

use pulse::accel::AccelConfig;
use pulse::backend::TraversalBackend;
use pulse::baselines::{RpcKind, RpcModel};
use pulse::bench_support::{
    build_app, make_backend, stats_from_report, Table,
};
use pulse::energy::{EnergySystem, PowerModel};
use pulse::rack::RackConfig;

fn main() -> std::io::Result<()> {
    let mut tbl = Table::new(
        "Fig. 8: energy per operation, µJ",
        &["app", "PULSE", "PULSE-ASIC", "RPC", "RPC-ARM", "Cache+RPC"],
    );
    let power = PowerModel::default();
    let cfg = AccelConfig::paper_default();

    for app_name in ["webservice", "wiredtiger", "btrdb"] {
        let mut backend =
            make_backend("pulse", RackConfig::bench(4, 64 << 10));
        let app = build_app(backend.rack_mut(), app_name, 7);
        let ops = app.materialize_ops(600, true, 2, 11);
        let rep = backend.serve_batch(&ops, 256);
        let stats = stats_from_report(
            &rep,
            app.words_per_iter(),
            app.resp_bytes(),
            app.cpu_post_ns(),
        );
        // per-node throughputs at saturation
        let pulse_tput = rep.tput_ops_per_s / 4.0;
        let rpc_tput =
            RpcModel::new(RpcKind::Rpc).tput_ops_per_s(&stats, 1);
        let arm_tput =
            RpcModel::new(RpcKind::RpcArm).tput_ops_per_s(&stats, 1);
        let crpc_tput =
            RpcModel::new(RpcKind::CacheRpc).tput_ops_per_s(&stats, 1);

        let e = |sys, tput| {
            format!(
                "{:.2}",
                power.energy_per_op_uj(sys, &cfg, tput)
            )
        };
        tbl.row(&[
            app_name.to_string(),
            e(EnergySystem::Pulse, pulse_tput),
            e(EnergySystem::PulseAsic, pulse_tput),
            e(EnergySystem::Rpc, rpc_tput),
            e(EnergySystem::RpcArm, arm_tput),
            e(EnergySystem::CacheRpc, crpc_tput),
        ]);
    }
    tbl.print();
    tbl.save_csv("fig8_energy")?;

    // node-power summary for the record
    println!("\nnode power model (W):");
    println!(
        "  PULSE FPGA {:.1}  PULSE-ASIC {:.1}  RPC(Xeon) {:.1}  ARM {:.1}",
        power.pulse_node_w(&cfg),
        power.pulse_asic_node_w(&cfg),
        power.rpc_node_w(),
        power.arm_node_w()
    );
    println!(
        "  equal-throughput energy ratio RPC/PULSE = {:.1}x, \
         PULSE/ASIC = {:.1}x",
        power.rpc_node_w() / power.pulse_node_w(&cfg),
        power.pulse_node_w(&cfg) / power.pulse_asic_node_w(&cfg)
    );
    Ok(())
}
