//! Fig. 7 (+ Table 3): application latency & throughput for the five
//! compared systems across 1–4 memory nodes.
//!
//! All systems are driven through the unified `TraversalBackend` trait:
//! PULSE runs the full rack DES; Cache/RPC/RPC-ARM/Cache+RPC replay the
//! same functional traversals (each backend owns an identical rack
//! layout) under their calibrated execution models (see DESIGN.md §2).
//! Expected shape (paper): PULSE 9–34× lower latency and 28–171× higher
//! throughput than Cache; RPC ≈ 1–1.4× lower latency than PULSE on one
//! node; PULSE 1.1–1.36× higher throughput than RPC on multi-node.
//!
//! Table 3 note (post PR 1 double-counted-iters fix): `total_iters` is
//! now single-counted — LogicDone is the only source for offloaded
//! work — so the iters/req column reads ≈half the seed's values and
//! now matches the functional per-op iteration count. The latency and
//! throughput panels are unaffected (the DES clock never consumed the
//! double count); only this profile column shifted. Derived
//! analytically; re-verify numerically on a host with a Rust
//! toolchain.

use pulse::backend::TraversalBackend;
use pulse::bench_support::{
    build_app, fmt_kops, fmt_us, make_backend, Table,
};
use pulse::rack::RackConfig;

const SYSTEMS: [&str; 5] = ["pulse", "rpc", "rpc-arm", "cache-rpc", "cache"];

fn main() -> std::io::Result<()> {
    let mut lat_tbl = Table::new(
        "Fig. 7 (top): mean latency, us",
        &["app", "nodes", "PULSE", "RPC", "RPC-ARM", "Cache+RPC", "Cache"],
    );
    let mut tput_tbl = Table::new(
        "Fig. 7 (bottom): throughput, kops/s",
        &["app", "nodes", "PULSE", "RPC", "RPC-ARM", "Cache+RPC", "Cache"],
    );
    let mut t3 = Table::new(
        "Table 3: workload profiles",
        &["app", "t_c/t_d", "iters/req"],
    );

    for app_name in ["webservice", "wiredtiger", "btrdb"] {
        for nodes in [1usize, 2, 3, 4] {
            let mut lat_row =
                vec![app_name.to_string(), nodes.to_string()];
            let mut tput_row = lat_row.clone();
            for sys in SYSTEMS {
                let ops: u64 = match app_name {
                    "webservice" => 2400,
                    _ => 1000,
                };
                // the model backends re-trace every op; keep their run
                // short (their latency/throughput are analytic anyway)
                let ops = if sys == "pulse" { ops } else { ops / 4 };
                let mut backend =
                    make_backend(sys, RackConfig::bench(nodes, 64 << 10));
                let app = build_app(backend.rack_mut(), app_name, 7);
                // latency at light load, throughput at saturation — the
                // standard split the paper's Fig. 7 panels use. The
                // Cache baseline's latency panel runs on a separate
                // backend so its LRU starts cold for both panels, as
                // the old per-cell sim did; the DES/model backends get
                // identical results from one shared backend.
                let lat_rep = if sys == "cache" {
                    let mut cold = make_backend(
                        sys,
                        RackConfig::bench(nodes, 64 << 10),
                    );
                    let a2 = build_app(cold.rack_mut(), app_name, 7);
                    a2.serve_on(&mut *cold, ops / 8, 2, true, 2, 11)
                } else {
                    app.serve_on(&mut *backend, ops / 8, 2, true, 2, 11)
                };
                let rep =
                    app.serve_on(&mut *backend, ops, 256, true, 2, 13);
                assert_eq!(rep.completed, ops, "{sys}/{app_name}/{nodes}");
                lat_row.push(fmt_us(lat_rep.latency.mean()));
                tput_row.push(fmt_kops(rep.tput_ops_per_s));

                if sys == "pulse" && nodes == 1 {
                    t3.row(&[
                        app_name.to_string(),
                        format!("{:.2}", profile_ratio(&app)),
                        format!(
                            "{:.0}",
                            rep.total_iters as f64
                                / rep.completed.max(1) as f64
                        ),
                    ]);
                }
            }
            lat_tbl.row(&lat_row);
            tput_tbl.row(&tput_row);
        }
    }

    t3.print();
    lat_tbl.print();
    lat_tbl.save_csv("fig7_latency")?;
    tput_tbl.print();
    tput_tbl.save_csv("fig7_throughput")?;

    println!("\nheadline checks (full map in EXPERIMENTS.md):");
    println!("  - PULSE vs Cache latency/throughput gaps printed above");
    println!("  - RPC single-node latency should sit near/below PULSE");
    Ok(())
}

fn profile_ratio(app: &pulse::bench_support::BenchApp) -> f64 {
    use pulse::bench_support::BenchApp;
    match app {
        BenchApp::Web(a) => a.profile().ratio,
        BenchApp::Wt(a) => a.profile().ratio,
        BenchApp::Bt(a) => a.profile(2 * pulse::bench_support::SEC).ratio,
    }
}
