//! Fig. 7 (+ Table 3): application latency & throughput for the five
//! compared systems across 1–4 memory nodes.
//!
//! PULSE numbers come from the full rack DES (functional traversals +
//! pipeline/network timing); baselines reuse the measured workload
//! stats with their calibrated execution models (see DESIGN.md §2).
//! Expected shape (paper): PULSE 9–34× lower latency and 28–171× higher
//! throughput than Cache; RPC ≈ 1–1.4× lower latency than PULSE on one
//! node; PULSE 1.1–1.36× higher throughput than RPC on multi-node.

use pulse::baselines::{cache::CachedSwapSim, RpcKind, RpcModel};
use pulse::bench_support::{
    bench_rack, build_app, fmt_kops, fmt_us, stats_from_report, Table,
};

fn main() {
    let mut lat_tbl = Table::new(
        "Fig. 7 (top): mean latency, us",
        &["app", "nodes", "PULSE", "RPC", "RPC-ARM", "Cache+RPC", "Cache"],
    );
    let mut tput_tbl = Table::new(
        "Fig. 7 (bottom): throughput, kops/s",
        &["app", "nodes", "PULSE", "RPC", "RPC-ARM", "Cache+RPC", "Cache"],
    );
    let mut t3 = Table::new(
        "Table 3: workload profiles",
        &["app", "t_c/t_d", "iters/req"],
    );

    for app_name in ["webservice", "wiredtiger", "btrdb"] {
        for nodes in [1usize, 2, 3, 4] {
            let mut rack = bench_rack(nodes, 64 << 10);
            let app = build_app(&mut rack, app_name, 7);
            let ops = match app_name {
                "webservice" => 2400,
                _ => 1000,
            };
            // latency at light load, throughput at saturation — the
            // standard split the paper's Fig. 7 panels use.
            let lat_rep = app.serve(&mut rack, ops / 8, 2, true, 2, 11);
            let rep = app.serve(&mut rack, ops, 256, true, 2, 13);
            assert_eq!(rep.completed, ops, "{app_name}/{nodes}");

            let stats = stats_from_report(
                &rep,
                app.words_per_iter(),
                app.resp_bytes(),
                app.cpu_post_ns(),
            );
            if nodes == 1 {
                t3.row(&[
                    app_name.to_string(),
                    format!("{:.2}", profile_ratio(&app)),
                    format!("{:.0}", stats.avg_iters),
                ]);
            }

            let rpc = RpcModel::new(RpcKind::Rpc).metrics(&stats, nodes);
            let arm =
                RpcModel::new(RpcKind::RpcArm).metrics(&stats, nodes);
            let mut crpc_model = RpcModel::new(RpcKind::CacheRpc);
            crpc_model.cache_hit_rate = 0.05; // poor locality (paper)
            let crpc = crpc_model.metrics(&stats, nodes);

            // Cache baseline: swap sim over real page traces
            let (cache_lat, cache_tput) =
                cache_numbers(&mut rack, &app, &stats);

            lat_tbl.row(&[
                app_name.to_string(),
                nodes.to_string(),
                fmt_us(lat_rep.latency.mean()),
                fmt_us(rpc.avg_latency_ns),
                fmt_us(arm.avg_latency_ns),
                fmt_us(crpc.avg_latency_ns),
                fmt_us(cache_lat),
            ]);
            tput_tbl.row(&[
                app_name.to_string(),
                nodes.to_string(),
                fmt_kops(rep.tput_ops_per_s),
                fmt_kops(rpc.tput_ops_per_s),
                fmt_kops(arm.tput_ops_per_s),
                fmt_kops(crpc.tput_ops_per_s),
                fmt_kops(cache_tput),
            ]);
        }
    }

    t3.print();
    lat_tbl.print();
    lat_tbl.save_csv("fig7_latency");
    tput_tbl.print();
    tput_tbl.save_csv("fig7_throughput");

    println!("\nheadline checks (full map in EXPERIMENTS.md):");
    println!("  - PULSE vs Cache latency/throughput gaps printed above");
    println!("  - RPC single-node latency should sit near/below PULSE");
}

fn profile_ratio(app: &pulse::bench_support::BenchApp) -> f64 {
    use pulse::bench_support::BenchApp;
    match app {
        BenchApp::Web(a) => a.profile().ratio,
        BenchApp::Wt(a) => a.profile().ratio,
        BenchApp::Bt(a) => a.profile(2 * pulse::bench_support::SEC).ratio,
    }
}

/// Run the swap-cache baseline over real traversal page traces.
fn cache_numbers(
    rack: &mut pulse::rack::Rack,
    app: &pulse::bench_support::BenchApp,
    stats: &pulse::baselines::WorkloadStats,
) -> (f64, f64) {
    use pulse::baselines::cache::trace_op;
    use pulse::bench_support::BenchApp;
    use pulse::isa::SP_WORDS;

    // cache sized at ~25% of the bench-scale working set (the paper
    // runs 2 GB caches against much larger datasets; the cache:WSS
    // ratio is what shapes the result)
    let mut sim = CachedSwapSim::new(4 << 20);
    let mut total_ns = 0u64;
    let mut pages_per_op = 0.0;
    let n = 150u64;
    let mut rng = pulse::util::prng::Rng::new(77);
    for _ in 0..n {
        let (iter, start, sp, extra) = match app {
            BenchApp::Web(a) => {
                let uid = rng.below(a.users) as i64;
                let mut sp = [0i64; SP_WORDS];
                sp[0] = uid;
                (a.index.find_program(), a.index.bucket_ptr(uid), sp, 8192)
            }
            BenchApp::Wt(a) => {
                let k = rng.below(a.keys) as i64;
                let mut sp = [0i64; SP_WORDS];
                sp[0] = k;
                (a.tree.get_program(), a.tree.root, sp, 240 * 50)
            }
            BenchApp::Bt(a) => {
                let mut sp = [0i64; SP_WORDS];
                sp[0] = i64::MAX / 2;
                sp[3] = 0;
                (a.tree.sum_program(), a.tree.first_leaf, sp, 0)
            }
        };
        let (_out, trace) = trace_op(rack, &iter, start, sp, extra);
        pages_per_op += trace.pages.len() as f64 / n as f64;
        total_ns += sim.op_latency_ns(&trace, stats.cpu_post_ns);
    }
    let lat = total_ns as f64 / n as f64;
    let tput = sim.tput_bound_ops_per_s(pages_per_op);
    (lat, tput)
}
