//! §Perf hot-path microbenchmarks (wall-clock, not virtual time):
//!   * native logic-pipeline interpreter (iterations/s);
//!   * full rack DES serving rate (DES events are the L3 hot loop);
//!   * XLA batched logic engine (lane-iterations/s through PJRT).
//! Results go to EXPERIMENTS.md §Perf; see DESIGN.md §6 for targets.

use pulse::bench_support::{bench_rack, build_app, Table};
use pulse::interp::{logic_pass, Workspace};
use pulse::isa::Status;
use std::time::Instant;

fn main() {
    let mut tbl = Table::new(
        "§Perf hot paths (wall clock)",
        &["path", "metric", "value"],
    );

    // 1. native interpreter: steady-state chain walk
    {
        let p = pulse::testgen::list_find_program();
        let mut w = Workspace::new();
        w.sp[0] = 1; // never matches data below -> walks forever
        w.data[0] = 0;
        w.data[2] = 0x1000;
        let rounds = 3_000_000u64;
        let t0 = Instant::now();
        let mut acc = 0u64;
        for _ in 0..rounds {
            w.regs = [0; pulse::isa::NREG];
            w.regs[0] = 0x1000;
            let r = logic_pass(&p, &mut w);
            acc += r.steps as u64;
            debug_assert_eq!(r.status, Status::NextIter);
        }
        let dt = t0.elapsed().as_secs_f64();
        tbl.row(&[
            "native interpreter".into(),
            "logic passes/s".into(),
            format!("{:.1}M (checksum {})", rounds as f64 / dt / 1e6, acc % 97),
        ]);
        tbl.row(&[
            "native interpreter".into(),
            "instr/s".into(),
            format!("{:.0}M", acc as f64 / dt / 1e6),
        ]);
    }

    // 2. rack DES end-to-end serving rate (wall clock)
    {
        let mut rack = bench_rack(4, 64 << 10);
        let app = build_app(&mut rack, "wiredtiger", 7);
        let t0 = Instant::now();
        let rep = app.serve(&mut rack, 3_000, 128, true, 2, 13);
        let dt = t0.elapsed().as_secs_f64();
        tbl.row(&[
            "rack DES".into(),
            "ops/s (wall)".into(),
            format!("{:.0}k", rep.completed as f64 / dt / 1e3),
        ]);
        tbl.row(&[
            "rack DES".into(),
            "iterations/s (wall)".into(),
            format!("{:.2}M", rep.total_iters as f64 / dt / 1e6),
        ]);
        tbl.row(&[
            "rack DES".into(),
            "sim speed (virtual/wall)".into(),
            format!(
                "{:.2}x",
                rep.makespan_ns as f64 / 1e9 / dt
            ),
        ]);
    }

    // 3. XLA batched logic engine via PJRT (only with the xla feature)
    #[cfg(feature = "xla")]
    {
        use pulse::accel::XlaBatchEngine;
        use pulse::runtime::PjrtRuntime;
        use pulse::util::prng::Rng;
        match PjrtRuntime::new(PjrtRuntime::default_dir())
            .and_then(|rt| rt.load_logic_step(256))
        {
            Ok(exe) => {
                let eng = XlaBatchEngine::xla(&exe);
                let p = pulse::testgen::list_find_program();
                let mut rng = Rng::new(2);
                let ws: Vec<Workspace> = (0..256)
                    .map(|_| {
                        let mut w =
                            pulse::testgen::random_workspace(&mut rng);
                        w.data[2] = 0; // ensure termination
                        w
                    })
                    .collect();
                // warm-up
                let _ = eng.step(&p, &mut ws.clone()).unwrap();
                let rounds = 50;
                let t0 = Instant::now();
                for _ in 0..rounds {
                    let mut batch = ws.clone();
                    let _ = eng.step(&p, &mut batch).unwrap();
                }
                let dt = t0.elapsed().as_secs_f64();
                let lane_passes = rounds as f64 * 256.0;
                tbl.row(&[
                    "XLA engine (b=256)".into(),
                    "lane passes/s".into(),
                    format!("{:.0}k", lane_passes / dt / 1e3),
                ]);
                tbl.row(&[
                    "XLA engine (b=256)".into(),
                    "batch call latency".into(),
                    format!("{:.2} ms", dt / rounds as f64 * 1e3),
                ]);
            }
            Err(e) => {
                tbl.row(&[
                    "XLA engine".into(),
                    "skipped".into(),
                    format!("{e:#}"),
                ]);
            }
        }
    }
    #[cfg(not(feature = "xla"))]
    tbl.row(&[
        "XLA engine".into(),
        "skipped".into(),
        "build with --features xla".into(),
    ]);

    tbl.print();
    tbl.save_csv("perf_hotpath").expect("write bench_out CSV");
}
