//! Appendix C.1 (Fig. 2 extended): network and memory bandwidth
//! utilization. Expected shape: PULSE/RPC sustain high memory-bandwidth
//! use; the swap-cache baseline trickles (<1 Gbps network); WebService
//! becomes network-bound at 3–4 nodes due to its 8 KB responses.

use pulse::bench_support::{bench_rack, build_app, Table};

fn main() {
    let mut tbl = Table::new(
        "Appendix Fig. 2: PULSE bandwidth utilization",
        &[
            "app",
            "nodes",
            "mem GB/s",
            "mem util",
            "net Gbps",
            "net util",
        ],
    );
    for app_name in ["webservice", "wiredtiger", "btrdb"] {
        for nodes in [1usize, 2, 3, 4] {
            let mut rack = bench_rack(nodes, 64 << 10);
            let app = build_app(&mut rack, app_name, 7);
            let rep = app.serve(&mut rack, 800, 256, true, 2, 11);
            let mem_gbps = rep.mem_bytes as f64
                / rep.makespan_ns.max(1) as f64;
            let net_gbps = rep.net_bytes as f64 * 8.0
                / rep.makespan_ns.max(1) as f64;
            tbl.row(&[
                app_name.to_string(),
                nodes.to_string(),
                format!("{mem_gbps:.2}"),
                format!("{:.2}", mem_gbps / (25.0 * nodes as f64)),
                format!("{net_gbps:.1}"),
                format!("{:.2}", net_gbps / 100.0),
            ]);
        }
    }
    tbl.print();
    tbl.save_csv("appendix_bandwidth");
    println!(
        "\n(swap-cache comparison: its fault pipeline sustains only a \
         few Gbps — see fig7's Cache throughput column)"
    );
}
