//! Appendix C.1 (Fig. 2 extended): network and memory bandwidth
//! utilization, measured through the `TraversalBackend` trait's batched
//! serving path. Expected shape: PULSE/RPC sustain high memory-bandwidth
//! use; the swap-cache baseline trickles (<1 Gbps network); WebService
//! becomes network-bound at 3–4 nodes due to its 8 KB responses.

use pulse::backend::TraversalBackend;
use pulse::bench_support::{build_app, make_backend, Table};
use pulse::rack::RackConfig;

fn main() -> std::io::Result<()> {
    let mut tbl = Table::new(
        "Appendix Fig. 2: PULSE bandwidth utilization",
        &[
            "app",
            "nodes",
            "mem GB/s",
            "mem util",
            "net Gbps",
            "net util",
        ],
    );
    for app_name in ["webservice", "wiredtiger", "btrdb"] {
        for nodes in [1usize, 2, 3, 4] {
            let mut backend =
                make_backend("pulse", RackConfig::bench(nodes, 64 << 10));
            let app = build_app(backend.rack_mut(), app_name, 7);
            let ops = app.materialize_ops(800, true, 2, 11);
            let rep = backend.serve_batch(&ops, 256);
            let mem_gbps = rep.mem_bytes as f64
                / rep.makespan_ns.max(1) as f64;
            let net_gbps = rep.net_bytes as f64 * 8.0
                / rep.makespan_ns.max(1) as f64;
            tbl.row(&[
                app_name.to_string(),
                nodes.to_string(),
                format!("{mem_gbps:.2}"),
                format!("{:.2}", mem_gbps / (25.0 * nodes as f64)),
                format!("{net_gbps:.1}"),
                format!("{:.2}", net_gbps / 100.0),
            ]);
        }
    }
    tbl.print();
    tbl.save_csv("appendix_bandwidth")?;
    println!(
        "\n(swap-cache comparison: its fault pipeline sustains only a \
         few Gbps — see fig7's Cache throughput column)"
    );
    Ok(())
}
