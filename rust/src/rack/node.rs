//! Memory-node model for the DES: per-node pipeline reservations
//! (m logic / n memory pipelines + workspaces, paper §4.2), the
//! in-flight job state, and the functional iteration executed when a
//! memory-pipeline reservation completes.

use std::collections::VecDeque;

use crate::accel::{AccelConfig, Accelerator};
use crate::interp::{logic_pass, Workspace};
use crate::isa::Status;
use crate::mem::NodeId;
use crate::net::TraversalMsg;
use crate::sim::{EventQueue, LatencyModel, Ns};

use super::events::Ev;

/// In-flight request state at a memory node / on the wire.
pub(crate) struct NodeJob {
    pub msg: TraversalMsg,
    /// dynamic steps of the pass executed at MemDone (for LogicDone).
    pub steps: u32,
    /// `iters_done` when the job arrived at its current node; the
    /// departure-time delta is the visit's iteration count (what the
    /// tracer records as one `Visit` span).
    pub arrival_iters: u32,
}

/// Outcome of one functional iteration at a node.
pub(crate) enum IterResult {
    Logic(u32),
    Bounce,
    Fault,
}

/// Per-node DES state: free pipeline counts, wait queues, and the slot
/// table of resident jobs.
pub(crate) struct NodeState {
    pub mem_free: usize,
    pub logic_free: usize,
    pub ws_free: usize,
    pub mem_wait: VecDeque<usize>,
    pub logic_wait: VecDeque<usize>,
    pub admit_wait: VecDeque<Box<NodeJob>>,
    pub slots: Vec<Option<Box<NodeJob>>>,
}

impl NodeState {
    pub fn new(cfg: &AccelConfig) -> Self {
        Self {
            mem_free: cfg.n_mem,
            logic_free: cfg.m_logic,
            ws_free: cfg.workspaces(),
            mem_wait: VecDeque::new(),
            logic_wait: VecDeque::new(),
            admit_wait: VecDeque::new(),
            slots: Vec::new(),
        }
    }

    /// Reset for a fresh serve run, keeping the slot table's capacity
    /// (the batched serving path reuses this allocation).
    pub fn reset(&mut self, cfg: &AccelConfig) {
        self.mem_free = cfg.n_mem;
        self.logic_free = cfg.m_logic;
        self.ws_free = cfg.workspaces();
        self.mem_wait.clear();
        self.logic_wait.clear();
        self.admit_wait.clear();
        self.slots.clear();
    }

    pub fn put(&mut self, job: Box<NodeJob>) -> usize {
        if let Some(i) = self.slots.iter().position(|s| s.is_none()) {
            self.slots[i] = Some(job);
            i
        } else {
            self.slots.push(Some(job));
            self.slots.len() - 1
        }
    }
}

/// Latency of the aggregated load: fixed path (TCAM + memory
/// controller + interconnect) + random-burst streaming.
pub(crate) fn mem_latency_for(lat: &LatencyModel, job: &NodeJob) -> Ns {
    lat.mem_pipe_ns(
        job.msg.program.load_words as usize,
        job.msg.program.writes_data,
    )
}

/// Occupancy of the memory pipeline: the streaming slot only. The
/// controller overlaps row activations across outstanding bursts,
/// so the fixed 179 ns is *latency*, not serialization — this is
/// what lets n pipelines reach the 25 GB/s the paper saturates.
pub(crate) fn mem_occupancy_for(job: &NodeJob) -> Ns {
    let words = job.msg.program.load_words as u64;
    let wb = if job.msg.program.writes_data { 2 } else { 1 };
    // 1.28 ns per 8 B word at 6.25 GB/s per pipeline + issue slot
    (words * wb * 13 / 10).max(4)
}

/// Reserve a memory pipeline for `slot` (or queue it) at time `t`.
pub(crate) fn start_mem_phase(
    lat: &LatencyModel,
    q: &mut EventQueue<Ev>,
    ns: &mut NodeState,
    node: NodeId,
    slot: usize,
    t: Ns,
) {
    if ns.mem_free > 0 {
        ns.mem_free -= 1;
        grant_mem(lat, q, ns, node, slot, t);
    } else {
        ns.mem_wait.push_back(slot);
    }
}

pub(crate) fn grant_mem(
    lat: &LatencyModel,
    q: &mut EventQueue<Ev>,
    ns: &mut NodeState,
    node: NodeId,
    slot: usize,
    t: Ns,
) {
    let job = ns.slots[slot].as_ref().unwrap();
    let occ = mem_occupancy_for(job);
    let latn = mem_latency_for(lat, job);
    q.push(t + occ, Ev::MemFree { node });
    q.push(t + latn.max(occ), Ev::MemDone { node, slot });
}

/// One *functional* iteration (translate, fetch, logic) for the job.
/// `ws` is the rack's reusable workspace (hot path: no per-iteration
/// allocation or zeroing beyond the loaded window).
pub(crate) fn one_iteration(
    accel: &mut Accelerator,
    ws: &mut Workspace,
    job: &mut NodeJob,
) -> IterResult {
    use crate::mem::translate::TranslateError;
    let words = job.msg.program.load_words as usize;
    if job.msg.iters_done >= job.msg.max_iters {
        job.msg.status = Status::Running; // yield marker
        return IterResult::Fault;
    }
    let local = match accel.table.translate(
        job.msg.cur_ptr,
        (words * 8) as u64,
        false,
    ) {
        Ok(off) => off,
        Err(TranslateError::NotLocal) => {
            job.msg.node_crossings += 1;
            accel.bounces += 1;
            job.msg.status = Status::Running;
            return IterResult::Bounce;
        }
        Err(TranslateError::Protection) => {
            job.msg.status = Status::Trap;
            accel.traps += 1;
            return IterResult::Fault;
        }
    };
    ws.sp.copy_from_slice(&job.msg.sp);
    ws.regs = [0; crate::isa::NREG];
    ws.set_cur_ptr(job.msg.cur_ptr);
    accel.region.read_words(local, &mut ws.data[..words]);
    ws.data[words..].iter_mut().for_each(|w| *w = 0);
    let pass = logic_pass(&job.msg.program, ws);
    accel.iterations += 1;
    job.msg.iters_done += 1;
    if job.msg.program.writes_data {
        if let Ok(off) = accel.table.translate(
            job.msg.cur_ptr,
            (words * 8) as u64,
            true,
        ) {
            accel.region.write_words(off, &ws.data[..words]);
        } else {
            job.msg.status = Status::Trap;
            return IterResult::Fault;
        }
    }
    job.msg.sp.copy_from_slice(&ws.sp);
    job.steps = pass.steps;
    match pass.status {
        Status::NextIter => {
            job.msg.cur_ptr = ws.cur_ptr();
            job.msg.status = Status::Running;
            IterResult::Logic(pass.steps)
        }
        Status::Return => {
            job.msg.status = Status::Return;
            IterResult::Logic(pass.steps)
        }
        _ => {
            job.msg.status = Status::Trap;
            accel.traps += 1;
            IterResult::Logic(pass.steps)
        }
    }
}

/// Release `slot`, admit a waiting job if any, and send the departing
/// message up the node's link toward the switch.
#[allow(clippy::too_many_arguments)]
pub(crate) fn depart_node(
    q: &mut EventQueue<Ev>,
    lat: &LatencyModel,
    ns: &mut NodeState,
    link_up: &mut crate::net::Link,
    node: NodeId,
    slot: usize,
    now: Ns,
    bounce: bool,
) {
    let mut job = ns.slots[slot].take().unwrap();
    if let Some(j) = ns.admit_wait.pop_front() {
        let s = ns.put(j);
        start_mem_phase(lat, q, ns, node, s, now + lat.accel_sched_ns as Ns);
    } else {
        ns.ws_free += 1;
    }
    let t = now + lat.accel_net_stack_ns as Ns;
    if !bounce {
        job.msg.kind = crate::net::MsgKind::Response;
    }
    let bytes = job.msg.wire_size();
    if let Some(at) = link_up.send(t, bytes) {
        q.push(at, Ev::AtSwitch { job, from_node: true });
    }
}
