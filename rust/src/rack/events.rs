//! The rack's discrete-event serving loop.
//!
//! Functional execution and timing advance together (see the module
//! docs in `rack/`): a request's aggregated LOAD really reads node
//! DRAM when its memory-pipeline reservation completes, the logic pass
//! really executes the ISA, bounces re-route through the switch, and
//! losses trigger dispatch-engine retransmissions.
//!
//! Two entry points share one implementation:
//! * `serve` — closed-loop: `concurrency` outstanding ops drawn from a
//!   generator closure (op construction is part of the timed run);
//! * `serve_batch` — open-loop over pre-materialized ops, reusing the
//!   rack's event queue / node-state / run-table scratch across calls
//!   (the batched throughput path exposed via `TraversalBackend`).

use std::collections::HashMap;

use crate::dispatch::{Disposition, ResponseAction};
use crate::isa::{Status, SP_WORDS};
use crate::mem::NodeId;
use crate::net::{MsgKind, RequestId};
use crate::obs::{Span, SpanKind, TraceRing};
use crate::sim::{EventQueue, Ns};
use crate::switch::Route;

use super::node::{
    depart_node, one_iteration, start_mem_phase, grant_mem, IterResult,
    NodeJob, NodeState,
};
use super::request::{Op, OpRun};
use super::stats::ServeReport;
use super::Rack;

/// Emit one trace span for `run` into the serve-local ring, stamped
/// with virtual sim time, advancing the op's causal counter. Untraced
/// ops pay one bool test. (Timestamps are excluded from conformance
/// identity — DES spans carry virtual ns, live spans wall ns.)
#[inline]
fn emit_run(ring: &mut TraceRing, run: &mut OpRun, t_ns: Ns, kind: SpanKind) {
    if run.traced {
        ring.push(Span { op: run.op_index, k: run.trace_k, t_ns, kind });
        run.trace_k += 1;
    }
}

/// DES event kinds.
pub(crate) enum Ev {
    AtSwitch { job: Box<NodeJob>, from_node: bool },
    AtNode { node: NodeId, job: Box<NodeJob> },
    /// Memory pipeline's *occupancy* ended (streaming slot free).
    MemFree { node: NodeId },
    /// The aggregated load's *latency* elapsed (data in the workspace).
    MemDone { node: NodeId, slot: usize },
    LogicDone { node: NodeId, slot: usize },
    AtCpu { job: Box<NodeJob> },
    TimeoutScan,
    Issue,
}

/// Reusable per-serve scratch state. Held by the `Rack` so repeated
/// `serve_batch` calls skip the allocation of the event queue, the
/// per-node slot tables, and the in-flight run map.
#[derive(Default)]
pub(crate) struct ServeScratch {
    pub q: EventQueue<Ev>,
    pub nodes: Vec<NodeState>,
    pub runs: HashMap<RequestId, OpRun>,
}

impl Rack {
    /// Closed-loop serving: `concurrency` outstanding logical ops drawn
    /// from `ops`; full DES with network, pipelines, loss, retransmit.
    pub fn serve(
        &mut self,
        mut ops: impl FnMut(u64) -> Option<Op>,
        concurrency: usize,
    ) -> ServeReport {
        self.serve_impl(&mut ops, concurrency)
    }

    /// Open-loop serving of a pre-materialized batch. Equivalent DES to
    /// `serve`, but op *generation* (workload sampling, key choosing,
    /// stage construction) happens outside the timed region and the
    /// scratch structures are reused across calls — the batched
    /// throughput lever of the `TraversalBackend` trait. Each issue
    /// still clones its `Op` out of the slice (cheap: the compiled
    /// program is behind an `Arc`), so the win is generation + scratch,
    /// not zero-copy issue.
    pub fn serve_batch(&mut self, ops: &[Op], concurrency: usize) -> ServeReport {
        self.serve_impl(&mut |i| ops.get(i as usize).cloned(), concurrency)
    }

    fn serve_impl(
        &mut self,
        ops: &mut dyn FnMut(u64) -> Option<Op>,
        concurrency: usize,
    ) -> ServeReport {
        let wall_start = std::time::Instant::now();
        // each run restarts virtual time at 0: clear link egress-queue
        // state from prior runs
        self.link_cpu_up.reset();
        self.link_cpu_down.reset();
        for l in self
            .links_node_down
            .iter_mut()
            .chain(self.links_node_up.iter_mut())
        {
            l.reset();
        }
        let mut scratch = std::mem::take(&mut self.scratch);
        scratch.q.clear();
        scratch.runs.clear();
        scratch.nodes.truncate(self.cfg.nodes);
        for ns in scratch.nodes.iter_mut() {
            ns.reset(&self.cfg.accel);
        }
        while scratch.nodes.len() < self.cfg.nodes {
            scratch.nodes.push(NodeState::new(&self.cfg.accel));
        }

        let mut report = ServeReport::default();
        let mut issued = 0u64;
        let mut inflight = 0usize;
        let mut done = false;
        let timeout = self.cfg.dispatch.timeout_ns;
        // serve-local span ring; zero-capacity (no allocation) when
        // tracing is disabled, parked on the tracer after the run
        let mut ring = self.tracer.make_ring();

        for _ in 0..concurrency {
            scratch.q.push(0, Ev::Issue);
        }
        scratch.q.push(timeout / 2, Ev::TimeoutScan);

        while let Some((now, ev)) = scratch.q.pop() {
            match ev {
                Ev::Issue => {
                    let Some(op) = ops(issued) else {
                        done = true;
                        continue;
                    };
                    // admission index consumed even by trapped ops —
                    // mirrors the live coordinator, so sampled indices
                    // pick the same ops on both backends
                    let op_index = issued;
                    issued += 1;
                    // admission-time shape check: a malformed op (e.g.
                    // a repeat stage with out-of-range repeat_while
                    // words) is trapped here instead of panicking the
                    // DES mid-run
                    if op.validate().is_err() {
                        report.record_admission_trap();
                        scratch.q.push(now, Ev::Issue);
                        continue;
                    }
                    inflight += 1;
                    let mut run = OpRun::new(op, now);
                    run.op_index = op_index;
                    run.traced = self.tracer.sampled(op_index);
                    self.launch_stage(
                        now,
                        run,
                        [0i64; SP_WORDS],
                        None,
                        &mut scratch.q,
                        &mut report,
                        &mut inflight,
                        done,
                        &mut scratch.runs,
                        &mut ring,
                    );
                }
                Ev::AtSwitch { job, from_node } => {
                    let t = now + self.switch.pipeline_ns();
                    match self.switch.route(&job.msg, from_node) {
                        Route::MemNode(n) => {
                            // node-originated request still Running =>
                            // an in-network forward (the half-RTT hop
                            // the live shard takes peer-to-peer)
                            if from_node {
                                if let Some(run) =
                                    scratch.runs.get_mut(&job.msg.id)
                                {
                                    emit_run(
                                        &mut ring,
                                        run,
                                        now,
                                        SpanKind::Forward { to: n as u32 },
                                    );
                                }
                            }
                            let bytes = job.msg.wire_size();
                            if let Some(at) = self.links_node_down
                                [n as usize]
                                .send(t, bytes)
                            {
                                scratch
                                    .q
                                    .push(at, Ev::AtNode { node: n, job });
                            }
                        }
                        Route::CpuNode(_) => {
                            let extra = scratch
                                .runs
                                .get(&job.msg.id)
                                .map(|r| {
                                    r.op.stages[r.stage_idx]
                                        .object_read_bytes
                                })
                                .unwrap_or(0);
                            let bytes =
                                job.msg.wire_size() + extra as usize;
                            if let Some(at) =
                                self.link_cpu_down.send(t, bytes)
                            {
                                scratch.q.push(at, Ev::AtCpu { job });
                            }
                        }
                        Route::Invalid(_) => {
                            let mut job = job;
                            job.msg.status = Status::Trap;
                            job.msg.kind = MsgKind::Response;
                            let bytes = job.msg.wire_size();
                            if let Some(at) =
                                self.link_cpu_down.send(t, bytes)
                            {
                                scratch.q.push(at, Ev::AtCpu { job });
                            }
                        }
                    }
                }
                Ev::AtNode { node, mut job } => {
                    // visit accounting baseline: iterations executed at
                    // this node = iters_done at departure minus this
                    job.arrival_iters = job.msg.iters_done;
                    let ns = &mut scratch.nodes[node as usize];
                    let t = now + self.lat.accel_net_stack_ns as Ns;
                    if ns.ws_free > 0 {
                        ns.ws_free -= 1;
                        let slot = ns.put(job);
                        start_mem_phase(
                            &self.lat,
                            &mut scratch.q,
                            ns,
                            node,
                            slot,
                            t + self.lat.accel_sched_ns as Ns,
                        );
                    } else {
                        ns.admit_wait.push_back(job);
                    }
                }
                Ev::MemFree { node } => {
                    let ns = &mut scratch.nodes[node as usize];
                    if let Some(w) = ns.mem_wait.pop_front() {
                        grant_mem(&self.lat, &mut scratch.q, ns, node, w, now);
                    } else {
                        ns.mem_free += 1;
                    }
                }
                Ev::MemDone { node, slot } => {
                    let job = scratch.nodes[node as usize].slots[slot]
                        .as_mut()
                        .unwrap();
                    let one = one_iteration(
                        &mut self.memnodes[node as usize],
                        &mut self.des_ws,
                        job,
                    );
                    match one {
                        IterResult::Logic(steps) => {
                            // DRAM was actually touched only when the
                            // iteration executed (bounces/faults return
                            // before the aggregated load); dirty
                            // windows stream back out, doubling the
                            // bytes the node's DRAM served
                            report.mem_bytes +=
                                job.msg.program.dram_bytes_per_iter();
                            let dur = self.lat.logic_ns(steps).max(1);
                            let ns = &mut scratch.nodes[node as usize];
                            if ns.logic_free > 0 {
                                ns.logic_free -= 1;
                                scratch.q.push(
                                    now + dur,
                                    Ev::LogicDone { node, slot },
                                );
                            } else {
                                ns.logic_wait.push_back(slot);
                            }
                        }
                        IterResult::Bounce | IterResult::Fault => {
                            // the visit ends here (before depart_node
                            // takes the slot): record it
                            {
                                let job = scratch.nodes[node as usize]
                                    .slots[slot]
                                    .as_ref()
                                    .unwrap();
                                if let Some(run) =
                                    scratch.runs.get_mut(&job.msg.id)
                                {
                                    let iters = job.msg.iters_done
                                        - job.arrival_iters;
                                    let dram = iters as u64
                                        * job.msg
                                            .program
                                            .dram_bytes_per_iter();
                                    emit_run(
                                        &mut ring,
                                        run,
                                        now,
                                        SpanKind::Visit {
                                            shard: node as u32,
                                            iters,
                                            dram_bytes: dram,
                                        },
                                    );
                                }
                            }
                            depart_node(
                                &mut scratch.q,
                                &self.lat,
                                &mut scratch.nodes[node as usize],
                                &mut self.links_node_up[node as usize],
                                node,
                                slot,
                                now,
                                matches!(one, IterResult::Bounce)
                                    && self.cfg.in_network_routing,
                            );
                        }
                    }
                }
                Ev::LogicDone { node, slot } => {
                    {
                        let ns = &mut scratch.nodes[node as usize];
                        if let Some(w) = ns.logic_wait.pop_front() {
                            let steps =
                                ns.slots[w].as_ref().unwrap().steps;
                            let dur = self.lat.logic_ns(steps).max(1);
                            scratch.q.push(
                                now + dur,
                                Ev::LogicDone { node, slot: w },
                            );
                        } else {
                            ns.logic_free += 1;
                        }
                    }
                    report.total_iters += 1;
                    let st = scratch.nodes[node as usize].slots[slot]
                        .as_ref()
                        .unwrap()
                        .msg
                        .status;
                    match st {
                        Status::Running => {
                            let t = now + self.lat.accel_sched_ns as Ns;
                            start_mem_phase(
                                &self.lat,
                                &mut scratch.q,
                                &mut scratch.nodes[node as usize],
                                node,
                                slot,
                                t,
                            );
                        }
                        _ => {
                            // traversal finished on this node: close
                            // out the visit before the slot departs
                            {
                                let job = scratch.nodes[node as usize]
                                    .slots[slot]
                                    .as_ref()
                                    .unwrap();
                                if let Some(run) =
                                    scratch.runs.get_mut(&job.msg.id)
                                {
                                    let iters = job.msg.iters_done
                                        - job.arrival_iters;
                                    let dram = iters as u64
                                        * job.msg
                                            .program
                                            .dram_bytes_per_iter();
                                    emit_run(
                                        &mut ring,
                                        run,
                                        now,
                                        SpanKind::Visit {
                                            shard: node as u32,
                                            iters,
                                            dram_bytes: dram,
                                        },
                                    );
                                }
                            }
                            depart_node(
                                &mut scratch.q,
                                &self.lat,
                                &mut scratch.nodes[node as usize],
                                &mut self.links_node_up[node as usize],
                                node,
                                slot,
                                now,
                                false,
                            );
                        }
                    }
                }
                Ev::AtCpu { mut job } => {
                    job.msg.kind = MsgKind::Response;
                    // PULSE-ACC: bounced traversal re-issued by the CPU.
                    if job.msg.status == Status::Running
                        && job.msg.iters_done < job.msg.max_iters
                        && !self.cfg.in_network_routing
                    {
                        if let Some(run) = scratch.runs.get_mut(&job.msg.id)
                        {
                            run.cross_ns +=
                                2 * self.lat.host_net_stack_ns as Ns;
                            emit_run(&mut ring, run, now, SpanKind::Bounce);
                        }
                        job.msg.kind = MsgKind::Request;
                        let t = now + self.lat.host_net_stack_ns as Ns;
                        let bytes = job.msg.wire_size();
                        if let Some(at) = self.link_cpu_up.send(t, bytes) {
                            scratch.q.push(
                                at,
                                Ev::AtSwitch { job, from_node: false },
                            );
                        }
                        continue;
                    }
                    match self.dispatch.on_response(job.msg.clone(), now) {
                        ResponseAction::Done {
                            id,
                            status,
                            sp,
                            iters: _,
                            crossings,
                        } => {
                            let Some(mut run) = scratch.runs.remove(&id)
                            else {
                                continue; // stale retransmit duplicate
                            };
                            run.crossings_total += crossings;
                            // offloaded iterations were already counted
                            // once per LogicDone; run.iters_total only
                            // accumulates CPU-local work (library cache
                            // completions, run_on_cpu fallback)
                            if status == Status::Trap {
                                report.trapped += 1;
                            }
                            self.advance_op(
                                now,
                                run,
                                sp,
                                status == Status::Trap,
                                &mut scratch.q,
                                &mut report,
                                &mut inflight,
                                done,
                                &mut scratch.runs,
                                &mut ring,
                            );
                        }
                        ResponseAction::Continue(msg) => {
                            // yielded traversal: fresh budget, re-send.
                            // `msg.max_iters` is the re-granted total
                            // (the dispatch engine already boosted it),
                            // same payload the live coordinator records.
                            if let Some(run) =
                                scratch.runs.get_mut(&msg.id)
                            {
                                emit_run(
                                    &mut ring,
                                    run,
                                    now,
                                    SpanKind::Boost {
                                        grant: msg.max_iters,
                                    },
                                );
                            }
                            let t =
                                now + self.lat.host_net_stack_ns as Ns;
                            let bytes = msg.wire_size();
                            let job = Box::new(NodeJob {
                                msg,
                                steps: 0,
                                arrival_iters: 0,
                            });
                            if let Some(at) =
                                self.link_cpu_up.send(t, bytes)
                            {
                                scratch.q.push(
                                    at,
                                    Ev::AtSwitch {
                                        job,
                                        from_node: false,
                                    },
                                );
                            }
                        }
                    }
                }
                Ev::TimeoutScan => {
                    for msg in self.dispatch.collect_retransmits(now) {
                        report.retransmits += 1;
                        let job = Box::new(NodeJob {
                            msg,
                            steps: 0,
                            arrival_iters: 0,
                        });
                        let bytes = job.msg.wire_size();
                        if let Some(t) = self.link_cpu_up.send(now, bytes)
                        {
                            scratch.q.push(
                                t,
                                Ev::AtSwitch { job, from_node: false },
                            );
                        }
                    }
                    if !(done && inflight == 0) {
                        scratch.q.push(now + timeout / 2, Ev::TimeoutScan);
                    }
                }
            }
            if done && inflight == 0 && scratch.q.is_empty() {
                break;
            }
        }

        report.net_bytes =
            self.link_cpu_up.stats.bytes + self.link_cpu_down.stats.bytes;
        if report.makespan_ns > 0 {
            report.tput_ops_per_s = report.completed as f64
                / (report.makespan_ns as f64 / 1e9);
        }
        report.wall_ms = wall_start.elapsed().as_secs_f64() * 1e3;
        self.tracer.park(ring);
        self.scratch = scratch;
        self.totals.merge(&report);
        report
    }

    /// Issue the current stage of `run` (possibly completing the whole
    /// op synchronously via the library cache / CPU fallback).
    #[allow(clippy::too_many_arguments)]
    fn launch_stage(
        &mut self,
        now: Ns,
        mut run: OpRun,
        prev_sp: [i64; SP_WORDS],
        repeat_from: Option<[i64; SP_WORDS]>,
        q: &mut EventQueue<Ev>,
        report: &mut ServeReport,
        inflight: &mut usize,
        done: bool,
        runs: &mut HashMap<RequestId, OpRun>,
        ring: &mut TraceRing,
    ) {
        let stage = &run.op.stages[run.stage_idx];
        let (start, sp) = stage.resolve(&prev_sp, repeat_from);
        if start == 0 {
            // degenerate stage (e.g. empty structure): skip forward
            // (no Dispatch span — nothing was dispatched; the live
            // coordinator skips it identically)
            self.advance_op(
                now, run, sp, false, q, report, inflight, done, runs, ring,
            );
            return;
        }
        match self.dispatch.submit(&stage.iter, start, sp, now) {
            Disposition::CompletedLocally { status, sp, iters } => {
                // a trap mid-cache is terminal and honest, exactly
                // like the offloaded and CPU-fallback paths
                if status == Status::Trap {
                    report.trapped += 1;
                }
                run.iters_total += iters;
                self.advance_op(
                    now,
                    run,
                    sp,
                    status == Status::Trap,
                    q,
                    report,
                    inflight,
                    done,
                    runs,
                    ring,
                );
            }
            Disposition::RunOnCpu => {
                let (st, sp, iters) =
                    self.run_on_cpu(&stage.iter, start, sp);
                if st == Status::Trap {
                    report.trapped += 1;
                }
                // remote reads: one RTT per iteration, charged virtually
                // by shifting the op's birth time back.
                let rtt = 2 * self.lat.one_way_ns(298)
                    + self.lat.cpu_dram_ns as Ns;
                run.iters_total += iters;
                run.born = run.born.saturating_sub(iters as u64 * rtt);
                self.advance_op(
                    now,
                    run,
                    sp,
                    st == Status::Trap,
                    q,
                    report,
                    inflight,
                    done,
                    runs,
                    ring,
                );
            }
            Disposition::Offload(msg) => {
                emit_run(
                    ring,
                    &mut run,
                    now,
                    SpanKind::Dispatch { stage: run.stage_idx as u32 },
                );
                let id = msg.id;
                runs.insert(id, run);
                let bytes = msg.wire_size();
                let job = Box::new(NodeJob {
                    msg,
                    steps: 0,
                    arrival_iters: 0,
                });
                if let Some(t) = self.link_cpu_up.send(now, bytes) {
                    q.push(t, Ev::AtSwitch { job, from_node: false });
                }
                // if dropped, the TimeoutScan resends from dispatch state
            }
        }
    }

    /// A stage finished with final scratchpad `sp` — repeat it, move to
    /// the next stage, or complete the op. A `trapped` stage is
    /// terminal for the whole op: repeating it would re-issue the same
    /// faulting continuation forever (the scratchpad's repeat words are
    /// exactly as they were when the stage faulted), and later stages
    /// would chain off a poisoned scratchpad.
    #[allow(clippy::too_many_arguments)]
    fn advance_op(
        &mut self,
        now: Ns,
        mut run: OpRun,
        sp: [i64; SP_WORDS],
        trapped: bool,
        q: &mut EventQueue<Ev>,
        report: &mut ServeReport,
        inflight: &mut usize,
        done: bool,
        runs: &mut HashMap<RequestId, OpRun>,
        ring: &mut TraceRing,
    ) {
        let stage = &run.op.stages[run.stage_idx];
        if !trapped && stage.wants_repeat(&sp) {
            let t = now + self.lat.host_net_stack_ns as Ns;
            self.launch_stage(
                t, run, sp, Some(sp), q, report, inflight, done, runs, ring,
            );
            return;
        }
        if !trapped && run.stage_idx + 1 < run.op.stages.len() {
            run.stage_idx += 1;
            let t = now + self.lat.host_net_stack_ns as Ns;
            self.launch_stage(
                t, run, sp, None, q, report, inflight, done, runs, ring,
            );
            return;
        }
        // op complete
        emit_run(ring, &mut run, now, SpanKind::Finish { trapped });
        let fin = now + run.op.cpu_post_ns;
        report.completed += 1;
        report.latency.record((fin - run.born).max(1));
        report.crossings.record(run.crossings_total as u64);
        if run.crossings_total > 0 {
            report.cross_node_requests += 1;
            report.cross_latency_ns.record(run.cross_ns.max(1));
        }
        report.total_iters += run.iters_total as u64;
        report.makespan_ns = report.makespan_ns.max(fin);
        *inflight -= 1;
        if !done {
            q.push(fin, Ev::Issue);
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::ds::{ForwardList, HashMapDs};
    use crate::isa::SP_WORDS;
    use crate::rack::{Op, Rack, RackConfig, Stage, StartAddr};

    fn small_cfg(nodes: usize) -> RackConfig {
        RackConfig::small(nodes)
    }

    #[test]
    fn serve_completes_all_ops_single_node() {
        let mut r = Rack::new(small_cfg(1));
        let mut m = HashMapDs::build(&mut r, 256);
        for i in 0..1000 {
            m.insert(&mut r, i, i * 2);
        }
        let prog = m.find_program();
        let ops: Vec<Op> = (0..200)
            .map(|i| {
                let key = i % 1000;
                let mut sp = [0i64; SP_WORDS];
                sp[0] = key;
                Op::new(prog.clone(), m.bucket_ptr(key), sp)
            })
            .collect();
        let mut it = ops.into_iter();
        let report = r.serve(move |_| it.next(), 8);
        assert_eq!(report.completed, 200);
        assert_eq!(report.trapped, 0);
        assert!(report.latency.p50() > 1_000, "{}", report.latency.p50());
        assert!(report.tput_ops_per_s > 1000.0);
    }

    #[test]
    fn serve_batch_matches_closed_loop_results() {
        let mut r = Rack::new(small_cfg(1));
        let mut m = HashMapDs::build(&mut r, 256);
        for i in 0..500 {
            m.insert(&mut r, i, i * 3);
        }
        let prog = m.find_program();
        let ops: Vec<Op> = (0..150)
            .map(|i| {
                let key = i % 500;
                let mut sp = [0i64; SP_WORDS];
                sp[0] = key;
                Op::new(prog.clone(), m.bucket_ptr(key), sp)
            })
            .collect();
        let batch = r.serve_batch(&ops, 8);
        assert_eq!(batch.completed, 150);
        assert_eq!(batch.trapped, 0);
        // same ops through the closed loop: identical virtual timing
        let mut it = ops.clone().into_iter();
        let closed = r.serve(move |_| it.next(), 8);
        assert_eq!(closed.completed, batch.completed);
        assert_eq!(closed.makespan_ns, batch.makespan_ns);
        assert_eq!(closed.latency.p50(), batch.latency.p50());
        // scratch reuse across repeated batch runs stays consistent
        let again = r.serve_batch(&ops, 8);
        assert_eq!(again.completed, 150);
        assert_eq!(again.makespan_ns, batch.makespan_ns);
    }

    #[test]
    fn serve_handles_distributed_traversals() {
        let mut cfg = small_cfg(4);
        cfg.granularity = 4096;
        let mut r = Rack::new(cfg);
        let mut l = ForwardList::new();
        for i in 0..3000 {
            l.push(&mut r, i);
        }
        let prog = l.find_program();
        let head = l.head;
        let mut n = 0;
        let report = r.serve(
            move |_| {
                n += 1;
                if n > 50 {
                    return None;
                }
                let mut sp = [0i64; SP_WORDS];
                sp[0] = 2500 + n; // deep in the list => crosses nodes
                Some(Op::new(prog.clone(), head, sp))
            },
            4,
        );
        assert_eq!(report.completed, 50);
        assert!(report.cross_node_requests > 0, "no cross-node traffic");
        assert!(report.crossings.max() >= 1);
    }

    #[test]
    fn pulse_acc_has_higher_latency_than_pulse() {
        let build = |in_network: bool| {
            let mut cfg = small_cfg(4);
            cfg.granularity = 4096;
            cfg.in_network_routing = in_network;
            let mut r = Rack::new(cfg);
            let mut l = ForwardList::new();
            for i in 0..4000 {
                l.push(&mut r, i);
            }
            let prog = l.find_program();
            let head = l.head;
            let mut n = 0;
            r.serve(
                move |_| {
                    n += 1;
                    if n > 40 {
                        return None;
                    }
                    let mut sp = [0i64; SP_WORDS];
                    sp[0] = 3500 + (n % 400);
                    Some(Op::new(prog.clone(), head, sp))
                },
                1,
            )
        };
        let pulse = build(true);
        let acc = build(false);
        assert_eq!(pulse.completed, acc.completed);
        assert!(
            acc.latency.mean() > pulse.latency.mean(),
            "PULSE {} vs ACC {}",
            pulse.latency.mean(),
            acc.latency.mean()
        );
    }

    #[test]
    fn lossy_links_recover_via_retransmission() {
        let mut cfg = small_cfg(2);
        cfg.loss = 0.05;
        cfg.dispatch.timeout_ns = 100_000;
        let mut r = Rack::new(cfg);
        let mut m = HashMapDs::build(&mut r, 64);
        for i in 0..200 {
            m.insert(&mut r, i, i);
        }
        let prog = m.find_program();
        let buckets: Vec<_> = (0..200).map(|k| m.bucket_ptr(k)).collect();
        let mut n = 0;
        let report = r.serve(
            move |_| {
                n += 1;
                if n > 300 {
                    return None;
                }
                let key = n % 200;
                let mut sp = [0i64; SP_WORDS];
                sp[0] = key;
                Some(Op::new(
                    prog.clone(),
                    buckets[key as usize],
                    sp,
                ))
            },
            8,
        );
        assert_eq!(report.completed, 300, "ops lost despite retransmit");
        assert!(report.retransmits > 0, "loss never triggered retransmit");
    }

    #[test]
    fn multi_stage_op_chains_through_sp() {
        // stage 1: hash find returns value (an address) in sp[1];
        // stage 2: list-sum from that address.
        let mut r = Rack::new(small_cfg(2));
        let mut l = ForwardList::new();
        for i in 1..=10 {
            l.push(&mut r, i);
        }
        let mut m = HashMapDs::build(&mut r, 16);
        m.insert(&mut r, 42, l.head as i64);

        let mut sp0 = [0i64; SP_WORDS];
        sp0[0] = 42;
        let stage1 =
            Stage::new(m.find_program(), m.bucket_ptr(42), sp0);
        let mut stage2 = Stage::new(
            l.sum_program(),
            0,
            [0i64; SP_WORDS],
        );
        stage2.start = StartAddr::FromPrevSp(1);
        let op = Op {
            stages: vec![stage1, stage2],
            cpu_post_ns: 500,
        };
        // functional check first
        let sp = r.run_op_functional(&op);
        assert_eq!(sp[3], 55); // sum 1..=10
        // DES check
        let mut sent = false;
        let report = r.serve(
            move |_| {
                if sent {
                    None
                } else {
                    sent = true;
                    Some(op.clone())
                }
            },
            1,
        );
        assert_eq!(report.completed, 1);
        assert_eq!(report.trapped, 0);
    }
}
