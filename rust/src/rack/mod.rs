//! The rack: CPU node + programmable switch + memory nodes, composed
//! into one discrete-event simulation (paper §6 testbed: 1 compute node,
//! up to 4 memory nodes behind a Tofino switch).
//!
//! Functional execution and timing advance together in one event loop:
//! a request's aggregated LOAD really reads the node's DRAM when its
//! memory-pipeline reservation completes, the logic pass really executes
//! the ISA (its dynamic instruction count feeds the logic-pipeline
//! reservation), bounces really re-route through the switch, and losses
//! really trigger dispatch-engine retransmissions.
//!
//! Application operations are *stage chains*: e.g. WiredTiger's YCSB-E
//! scan = locate-traversal → scan-traversal (repeating while the
//! scratchpad publishes a continuation leaf), plus per-stage bulk reads
//! (WebService's 8 KB object fetch) and CPU post-processing
//! (encrypt+compress), so one logical op maps to the same sequence of
//! network requests as on the real system.
//!
//! `in_network_routing = false` turns the rack into PULSE-ACC (paper
//! §6.2 Fig. 9): non-local pointers return to the CPU node instead of
//! being re-routed at the switch.

use std::collections::{HashMap, VecDeque};
use std::sync::Arc;

use crate::accel::{AccelConfig, Accelerator, VisitEnd};
use crate::compiler::CompiledIter;
use crate::dispatch::{DispatchConfig, DispatchEngine, Disposition, ResponseAction};
use crate::interp::logic_pass;
use crate::isa::{Status, NREG, SP_WORDS};
use crate::mem::{AllocPolicy, GAddr, NodeId, RackAllocator, RangeTable, Region};
use crate::net::{Link, MsgKind, RequestId, TraversalMsg};
use crate::sim::{EventQueue, LatencyModel, Ns};
use crate::switch::{Route, Switch};
use crate::util::hist::Histogram;

#[derive(Debug, Clone)]
pub struct RackConfig {
    pub nodes: usize,
    pub node_capacity: u64,
    pub granularity: u64,
    pub policy: AllocPolicy,
    pub accel: AccelConfig,
    pub dispatch: DispatchConfig,
    /// Packet loss probability per hop.
    pub loss: f64,
    /// PULSE (true) vs PULSE-ACC (false), §6.2.
    pub in_network_routing: bool,
    pub tcam_entries: usize,
    pub seed: u64,
}

impl Default for RackConfig {
    fn default() -> Self {
        Self {
            nodes: 4,
            node_capacity: 1 << 30,
            granularity: 64 << 20,
            policy: AllocPolicy::RoundRobin,
            accel: AccelConfig::paper_default(),
            dispatch: DispatchConfig::default(),
            loss: 0.0,
            in_network_routing: true,
            tcam_entries: 1 << 16,
            seed: 42,
        }
    }
}

/// Where a stage's start pointer comes from.
#[derive(Debug, Clone, Copy)]
pub enum StartAddr {
    Fixed(GAddr),
    /// Read from the previous stage's final scratchpad word.
    FromPrevSp(u32),
}

/// One traversal stage of an application operation.
#[derive(Clone)]
pub struct Stage {
    pub iter: Arc<CompiledIter>,
    pub start: StartAddr,
    pub sp: [i64; SP_WORDS],
    /// Carry the previous stage's final scratchpad into this stage
    /// (overriding `sp`), with `sp_overrides` applied on top.
    pub carry_sp: bool,
    pub sp_overrides: Vec<(u32, i64)>,
    /// Extra bulk payload on this stage's response (e.g. the 8 KB
    /// WebService object riding back with the reply).
    pub object_read_bytes: u32,
    /// Re-issue this stage while sp[word0] != 0 && sp[word1] > 0
    /// (continuation leaf + remaining counter for scans), re-applying
    /// `sp_overrides` each round.
    pub repeat_while: Option<(u32, u32)>,
}

impl Stage {
    pub fn new(iter: Arc<CompiledIter>, start: GAddr, sp: [i64; SP_WORDS]) -> Self {
        Self {
            iter,
            start: StartAddr::Fixed(start),
            sp,
            carry_sp: false,
            sp_overrides: Vec::new(),
            object_read_bytes: 0,
            repeat_while: None,
        }
    }
}

/// One application operation for the serving loop.
#[derive(Clone)]
pub struct Op {
    pub stages: Vec<Stage>,
    /// CPU-side post-processing time (e.g. encrypt+compress), calibrated
    /// by really running it in the app layer.
    pub cpu_post_ns: Ns,
}

impl Op {
    pub fn new(iter: Arc<CompiledIter>, start: GAddr, sp: [i64; SP_WORDS]) -> Self {
        Self { stages: vec![Stage::new(iter, start, sp)], cpu_post_ns: 0 }
    }
}

#[derive(Debug, Default)]
pub struct ServeReport {
    pub completed: u64,
    pub trapped: u64,
    pub makespan_ns: Ns,
    pub latency: Histogram,
    pub crossings: Histogram,
    pub total_iters: u64,
    pub cross_node_requests: u64,
    /// Virtual-time throughput, operations per second.
    pub tput_ops_per_s: f64,
    /// Bytes moved over the CPU<->switch links (network utilization).
    pub net_bytes: u64,
    /// Bytes served from node DRAM (memory-bandwidth utilization).
    pub mem_bytes: u64,
    pub retransmits: u64,
    /// Time spent on cross-node continuation per affected request
    /// (Fig. 7 darker stack segment).
    pub cross_latency_ns: Histogram,
    /// Wall-clock time of the functional+DES execution (perf metric).
    pub wall_ms: f64,
}

impl ServeReport {
    /// Memory-bandwidth utilization vs the paper's 25 GB/s per node cap.
    pub fn mem_bw_util(&self, nodes: usize) -> f64 {
        if self.makespan_ns == 0 {
            return 0.0;
        }
        let gbps = self.mem_bytes as f64 / self.makespan_ns as f64;
        gbps / (25.0 * nodes as f64 / 8.0 * 8.0) // GB/s per ns == B/ns
    }

    /// Network utilization vs 100 Gbps.
    pub fn net_bw_util(&self) -> f64 {
        if self.makespan_ns == 0 {
            return 0.0;
        }
        (self.net_bytes as f64 / self.makespan_ns as f64) / 12.5
    }
}

/// Tracks one logical op across its stages + retries.
struct OpRun {
    op: Op,
    stage_idx: usize,
    born: Ns,
    cross_ns: Ns,
    crossings_total: u32,
    iters_total: u32,
}

/// In-flight request state at a memory node / on the wire.
struct NodeJob {
    msg: TraversalMsg,
    /// dynamic steps of the pass executed at MemDone (for LogicDone).
    steps: u32,
}

enum Ev {
    AtSwitch { job: Box<NodeJob>, from_node: bool },
    AtNode { node: NodeId, job: Box<NodeJob> },
    /// Memory pipeline's *occupancy* ended (streaming slot free).
    MemFree { node: NodeId },
    /// The aggregated load's *latency* elapsed (data in the workspace).
    MemDone { node: NodeId, slot: usize },
    LogicDone { node: NodeId, slot: usize },
    AtCpu { job: Box<NodeJob> },
    TimeoutScan,
    Issue,
}

struct NodeState {
    mem_free: usize,
    logic_free: usize,
    ws_free: usize,
    mem_wait: VecDeque<usize>,
    logic_wait: VecDeque<usize>,
    admit_wait: VecDeque<Box<NodeJob>>,
    slots: Vec<Option<Box<NodeJob>>>,
}

impl NodeState {
    fn new(cfg: &AccelConfig) -> Self {
        Self {
            mem_free: cfg.n_mem,
            logic_free: cfg.m_logic,
            ws_free: cfg.workspaces(),
            mem_wait: VecDeque::new(),
            logic_wait: VecDeque::new(),
            admit_wait: VecDeque::new(),
            slots: Vec::new(),
        }
    }

    fn put(&mut self, job: Box<NodeJob>) -> usize {
        if let Some(i) = self.slots.iter().position(|s| s.is_none()) {
            self.slots[i] = Some(job);
            i
        } else {
            self.slots.push(Some(job));
            self.slots.len() - 1
        }
    }
}

pub struct Rack {
    pub cfg: RackConfig,
    pub lat: LatencyModel,
    pub alloc: RackAllocator,
    pub switch: Switch,
    pub memnodes: Vec<Accelerator>,
    pub dispatch: DispatchEngine,
    link_cpu_up: Link,
    link_cpu_down: Link,
    links_node_down: Vec<Link>,
    links_node_up: Vec<Link>,
    published_slabs: usize,
}

impl Rack {
    pub fn new(cfg: RackConfig) -> Self {
        let lat = LatencyModel::default();
        let alloc = RackAllocator::new(
            cfg.nodes,
            cfg.node_capacity,
            cfg.granularity,
            cfg.policy,
            cfg.seed,
        );
        let switch = Switch::new(alloc.switch_map.clone(), &lat);
        let memnodes = (0..cfg.nodes)
            .map(|n| {
                Accelerator::new(
                    n as NodeId,
                    Region::new(cfg.node_capacity as usize),
                    RangeTable::new(cfg.tcam_entries),
                    cfg.accel,
                )
            })
            .collect();
        let dispatch = DispatchEngine::new(0, cfg.dispatch);
        let mk = |seed| Link::from_model(&lat, cfg.loss, seed);
        Self {
            link_cpu_up: mk(cfg.seed ^ 1),
            link_cpu_down: mk(cfg.seed ^ 2),
            links_node_down: (0..cfg.nodes)
                .map(|i| mk(cfg.seed ^ (0x10 + i as u64)))
                .collect(),
            links_node_up: (0..cfg.nodes)
                .map(|i| mk(cfg.seed ^ (0x20 + i as u64)))
                .collect(),
            cfg,
            lat,
            alloc,
            switch,
            memnodes,
            dispatch,
            published_slabs: 0,
        }
    }

    /// Allocate on the rack and keep switch + TCAM tables in sync.
    pub fn alloc(&mut self, size: u64) -> GAddr {
        let a = self.alloc.alloc(size);
        self.publish_new_slabs();
        a
    }

    pub fn alloc_on(&mut self, node: NodeId, size: u64) -> GAddr {
        let a = self.alloc.alloc_on(node, size);
        self.publish_new_slabs();
        a
    }

    fn publish_new_slabs(&mut self) {
        if self.alloc.slabs_allocated as usize == self.published_slabs {
            return;
        }
        self.switch.update_map(self.alloc.switch_map.clone());
        for n in 0..self.cfg.nodes {
            for &(base, len, local) in &self.alloc.node_ranges[n] {
                let _ = self.memnodes[n].table.insert(
                    base,
                    len,
                    local,
                    crate::mem::Perms::RW,
                );
            }
        }
        self.published_slabs = self.alloc.slabs_allocated as usize;
    }

    /// Host-side write (data-structure build + mutation path).
    pub fn write_words(&mut self, addr: GAddr, words: &[i64]) {
        let node = self.alloc.owner(addr).expect("write to unmapped addr");
        let accel = &mut self.memnodes[node as usize];
        let off = accel
            .table
            .translate(addr, (words.len() * 8) as u64, true)
            .expect("host write failed translation");
        accel.region.write_words(off, words);
    }

    pub fn read_words(&mut self, addr: GAddr, out: &mut [i64]) {
        let node = self.alloc.owner(addr).expect("read of unmapped addr");
        let accel = &mut self.memnodes[node as usize];
        let off = accel
            .table
            .translate(addr, (out.len() * 8) as u64, false)
            .expect("host read failed translation");
        accel.region.read_words(off, out);
    }

    /// Purely functional traversal (no timing) — correctness paths,
    /// data-structure APIs, the quickstart example. Same
    /// dispatch/switch/visit logic as the DES.
    pub fn traverse(
        &mut self,
        iter: &CompiledIter,
        start: GAddr,
        sp: [i64; SP_WORDS],
    ) -> (Status, [i64; SP_WORDS], u32) {
        match self.dispatch.submit(iter, start, sp, 0) {
            Disposition::CompletedLocally { sp, iters } => {
                (Status::Return, sp, iters)
            }
            Disposition::RunOnCpu => self.run_on_cpu(iter, start, sp),
            Disposition::Offload(mut msg) => {
                let mut budget_boosts = 0;
                let mut from_node = false;
                loop {
                    let node = match self.switch.route(&msg, from_node) {
                        Route::MemNode(n) => n,
                        Route::Invalid(_) => {
                            return (Status::Trap, msg.sp, msg.iters_done)
                        }
                        Route::CpuNode(_) => unreachable!(),
                    };
                    let out = self.memnodes[node as usize].visit(&mut msg);
                    match out.end {
                        VisitEnd::Done(st) => {
                            return (st, msg.sp, msg.iters_done)
                        }
                        VisitEnd::NotLocal => {
                            from_node = true;
                            continue;
                        }
                        VisitEnd::Yield => {
                            budget_boosts += 1;
                            if budget_boosts > 4096 {
                                return (Status::Trap, msg.sp, msg.iters_done);
                            }
                            msg.max_iters += self.cfg.dispatch.max_iters;
                        }
                    }
                }
            }
        }
    }

    /// CPU fallback for non-offloadable iterators: one remote read per
    /// pointer hop (paper §4.1).
    fn run_on_cpu(
        &mut self,
        iter: &CompiledIter,
        start: GAddr,
        sp: [i64; SP_WORDS],
    ) -> (Status, [i64; SP_WORDS], u32) {
        let mut ws = crate::interp::Workspace::new();
        ws.sp.copy_from_slice(&sp);
        let words = iter.program.load_words as usize;
        let mut cur = start;
        let mut iters = 0u32;
        loop {
            let mut buf = vec![0i64; words];
            self.read_words(cur, &mut buf);
            ws.regs = [0; NREG];
            ws.set_cur_ptr(cur);
            ws.data[..words].copy_from_slice(&buf);
            ws.data[words..].iter_mut().for_each(|w| *w = 0);
            let pass = logic_pass(&iter.program, &mut ws);
            iters += 1;
            match pass.status {
                Status::NextIter => cur = ws.cur_ptr(),
                s => {
                    let mut out = [0i64; SP_WORDS];
                    out.copy_from_slice(&ws.sp);
                    return (s, out, iters);
                }
            }
        }
    }

    /// Functional multi-stage op (reference for the DES path; used by
    /// tests to check stage plumbing).
    pub fn run_op_functional(&mut self, op: &Op) -> [i64; SP_WORDS] {
        let mut prev_sp = [0i64; SP_WORDS];
        for (si, stage) in op.stages.iter().enumerate() {
            let mut start = match stage.start {
                StartAddr::Fixed(a) => a,
                StartAddr::FromPrevSp(w) => prev_sp[w as usize] as GAddr,
            };
            let mut sp =
                if stage.carry_sp { prev_sp } else { stage.sp };
            loop {
                for &(w, v) in &stage.sp_overrides {
                    sp[w as usize] = v;
                }
                let (_st, out, _) = self.traverse(&stage.iter, start, sp);
                sp = out;
                if let Some((aw, gw)) = stage.repeat_while {
                    let next = sp[aw as usize] as GAddr;
                    if next != 0 && sp[gw as usize] > 0 {
                        start = next;
                        continue;
                    }
                }
                break;
            }
            prev_sp = sp;
            let _ = si;
        }
        prev_sp
    }

    /// Closed-loop serving: `concurrency` outstanding logical ops drawn
    /// from `ops`; full DES with network, pipelines, loss, retransmit.
    pub fn serve(
        &mut self,
        mut ops: impl FnMut(u64) -> Option<Op>,
        concurrency: usize,
    ) -> ServeReport {
        let wall_start = std::time::Instant::now();
        // each serve() run restarts virtual time at 0: clear link
        // egress-queue state from prior runs
        self.link_cpu_up.reset();
        self.link_cpu_down.reset();
        for l in self
            .links_node_down
            .iter_mut()
            .chain(self.links_node_up.iter_mut())
        {
            l.reset();
        }
        let mut q: EventQueue<Ev> = EventQueue::new();
        let mut nodes: Vec<NodeState> = (0..self.cfg.nodes)
            .map(|_| NodeState::new(&self.cfg.accel))
            .collect();
        let mut report = ServeReport::default();
        let mut issued = 0u64;
        let mut inflight = 0usize;
        let mut done = false;
        let timeout = self.cfg.dispatch.timeout_ns;
        let mut runs: HashMap<RequestId, OpRun> = HashMap::new();

        for _ in 0..concurrency {
            q.push(0, Ev::Issue);
        }
        q.push(timeout / 2, Ev::TimeoutScan);

        while let Some((now, ev)) = q.pop() {
            match ev {
                Ev::Issue => {
                    let Some(op) = ops(issued) else {
                        done = true;
                        continue;
                    };
                    issued += 1;
                    inflight += 1;
                    let run = OpRun {
                        op,
                        stage_idx: 0,
                        born: now,
                        cross_ns: 0,
                        crossings_total: 0,
                        iters_total: 0,
                    };
                    self.launch_stage(
                        now,
                        run,
                        [0i64; SP_WORDS],
                        None,
                        &mut q,
                        &mut report,
                        &mut inflight,
                        done,
                        &mut runs,
                    );
                }
                Ev::AtSwitch { job, from_node } => {
                    let t = now + self.switch.pipeline_ns();
                    match self.switch.route(&job.msg, from_node) {
                        Route::MemNode(n) => {
                            let bytes = job.msg.wire_size();
                            if let Some(at) = self.links_node_down
                                [n as usize]
                                .send(t, bytes)
                            {
                                q.push(at, Ev::AtNode { node: n, job });
                            }
                        }
                        Route::CpuNode(_) => {
                            let extra = runs
                                .get(&job.msg.id)
                                .map(|r| {
                                    r.op.stages[r.stage_idx]
                                        .object_read_bytes
                                })
                                .unwrap_or(0);
                            let bytes =
                                job.msg.wire_size() + extra as usize;
                            if let Some(at) =
                                self.link_cpu_down.send(t, bytes)
                            {
                                q.push(at, Ev::AtCpu { job });
                            }
                        }
                        Route::Invalid(_) => {
                            let mut job = job;
                            job.msg.status = Status::Trap;
                            job.msg.kind = MsgKind::Response;
                            let bytes = job.msg.wire_size();
                            if let Some(at) =
                                self.link_cpu_down.send(t, bytes)
                            {
                                q.push(at, Ev::AtCpu { job });
                            }
                        }
                    }
                }
                Ev::AtNode { node, job } => {
                    let ns = &mut nodes[node as usize];
                    let t = now + self.lat.accel_net_stack_ns as Ns;
                    if ns.ws_free > 0 {
                        ns.ws_free -= 1;
                        let slot = ns.put(job);
                        Self::start_mem_phase(
                            &self.lat,
                            &mut q,
                            ns,
                            node,
                            slot,
                            t + self.lat.accel_sched_ns as Ns,
                        );
                    } else {
                        ns.admit_wait.push_back(job);
                    }
                }
                Ev::MemFree { node } => {
                    let ns = &mut nodes[node as usize];
                    if let Some(w) = ns.mem_wait.pop_front() {
                        Self::grant_mem(&self.lat, &mut q, ns, node, w, now);
                    } else {
                        ns.mem_free += 1;
                    }
                }
                Ev::MemDone { node, slot } => {
                    let job = nodes[node as usize].slots[slot]
                        .as_mut()
                        .unwrap();
                    let accel = &mut self.memnodes[node as usize];
                    let one = Self::one_iteration(accel, job);
                    report.mem_bytes +=
                        job.msg.program.load_words as u64 * 8;
                    match one {
                        IterResult::Logic(steps) => {
                            let dur = self.lat.logic_ns(steps).max(1);
                            let ns = &mut nodes[node as usize];
                            if ns.logic_free > 0 {
                                ns.logic_free -= 1;
                                q.push(
                                    now + dur,
                                    Ev::LogicDone { node, slot },
                                );
                            } else {
                                ns.logic_wait.push_back(slot);
                            }
                        }
                        IterResult::Bounce | IterResult::Fault => {
                            Self::depart_node(
                                &mut q,
                                &self.lat,
                                &mut nodes[node as usize],
                                &mut self.links_node_up[node as usize],
                                node,
                                slot,
                                now,
                                matches!(one, IterResult::Bounce)
                                    && self.cfg.in_network_routing,
                            );
                        }
                    }
                }
                Ev::LogicDone { node, slot } => {
                    {
                        let ns = &mut nodes[node as usize];
                        if let Some(w) = ns.logic_wait.pop_front() {
                            let steps =
                                ns.slots[w].as_ref().unwrap().steps;
                            let dur = self.lat.logic_ns(steps).max(1);
                            q.push(
                                now + dur,
                                Ev::LogicDone { node, slot: w },
                            );
                        } else {
                            ns.logic_free += 1;
                        }
                    }
                    report.total_iters += 1;
                    let st = nodes[node as usize].slots[slot]
                        .as_ref()
                        .unwrap()
                        .msg
                        .status;
                    match st {
                        Status::Running => {
                            let t = now + self.lat.accel_sched_ns as Ns;
                            Self::start_mem_phase(
                                &self.lat,
                                &mut q,
                                &mut nodes[node as usize],
                                node,
                                slot,
                                t,
                            );
                        }
                        _ => {
                            Self::depart_node(
                                &mut q,
                                &self.lat,
                                &mut nodes[node as usize],
                                &mut self.links_node_up[node as usize],
                                node,
                                slot,
                                now,
                                false,
                            );
                        }
                    }
                }
                Ev::AtCpu { mut job } => {
                    job.msg.kind = MsgKind::Response;
                    // PULSE-ACC: bounced traversal re-issued by the CPU.
                    if job.msg.status == Status::Running
                        && job.msg.iters_done < job.msg.max_iters
                        && !self.cfg.in_network_routing
                    {
                        if let Some(run) = runs.get_mut(&job.msg.id) {
                            run.cross_ns +=
                                2 * self.lat.host_net_stack_ns as Ns;
                        }
                        job.msg.kind = MsgKind::Request;
                        let t = now + self.lat.host_net_stack_ns as Ns;
                        let bytes = job.msg.wire_size();
                        if let Some(at) = self.link_cpu_up.send(t, bytes) {
                            q.push(
                                at,
                                Ev::AtSwitch { job, from_node: false },
                            );
                        }
                        continue;
                    }
                    match self.dispatch.on_response(job.msg.clone(), now) {
                        ResponseAction::Done { id, status, sp, iters, crossings } => {
                            let Some(mut run) = runs.remove(&id) else {
                                continue; // stale retransmit duplicate
                            };
                            run.crossings_total += crossings;
                            run.iters_total = iters;
                            if status == Status::Trap {
                                report.trapped += 1;
                            }
                            self.advance_op(
                                now,
                                run,
                                sp,
                                &mut q,
                                &mut report,
                                &mut inflight,
                                done,
                                &mut runs,
                            );
                        }
                        ResponseAction::Continue(msg) => {
                            // yielded traversal: fresh budget, re-send
                            let t =
                                now + self.lat.host_net_stack_ns as Ns;
                            let bytes = msg.wire_size();
                            let job =
                                Box::new(NodeJob { msg, steps: 0 });
                            if let Some(at) =
                                self.link_cpu_up.send(t, bytes)
                            {
                                q.push(
                                    at,
                                    Ev::AtSwitch {
                                        job,
                                        from_node: false,
                                    },
                                );
                            }
                        }
                    }
                }
                Ev::TimeoutScan => {
                    for msg in self.dispatch.collect_retransmits(now) {
                        report.retransmits += 1;
                        let job = Box::new(NodeJob { msg, steps: 0 });
                        let bytes = job.msg.wire_size();
                        if let Some(t) = self.link_cpu_up.send(now, bytes)
                        {
                            q.push(
                                t,
                                Ev::AtSwitch { job, from_node: false },
                            );
                        }
                    }
                    if !(done && inflight == 0) {
                        q.push(now + timeout / 2, Ev::TimeoutScan);
                    }
                }
            }
            if done && inflight == 0 && q.is_empty() {
                break;
            }
        }

        report.net_bytes =
            self.link_cpu_up.stats.bytes + self.link_cpu_down.stats.bytes;
        if report.makespan_ns > 0 {
            report.tput_ops_per_s = report.completed as f64
                / (report.makespan_ns as f64 / 1e9);
        }
        report.wall_ms = wall_start.elapsed().as_secs_f64() * 1e3;
        report
    }

    /// Issue the current stage of `run` (possibly completing the whole
    /// op synchronously via the library cache / CPU fallback).
    #[allow(clippy::too_many_arguments)]
    fn launch_stage(
        &mut self,
        now: Ns,
        mut run: OpRun,
        prev_sp: [i64; SP_WORDS],
        repeat_from: Option<[i64; SP_WORDS]>,
        q: &mut EventQueue<Ev>,
        report: &mut ServeReport,
        inflight: &mut usize,
        done: bool,
        runs: &mut HashMap<RequestId, OpRun>,
    ) {
        let stage = &run.op.stages[run.stage_idx];
        let start = match (repeat_from, stage.start) {
            (Some(sp), _) => {
                let (aw, _) = stage.repeat_while.unwrap();
                sp[aw as usize] as GAddr
            }
            (None, StartAddr::Fixed(a)) => a,
            (None, StartAddr::FromPrevSp(w)) => prev_sp[w as usize] as GAddr,
        };
        let mut sp = match (repeat_from, stage.carry_sp) {
            (Some(s), _) => s,
            (None, true) => prev_sp,
            (None, false) => stage.sp,
        };
        for &(w, v) in &stage.sp_overrides {
            sp[w as usize] = v;
        }
        if start == 0 {
            // degenerate stage (e.g. empty structure): skip forward
            self.advance_op(now, run, sp, q, report, inflight, done, runs);
            return;
        }
        match self.dispatch.submit(&stage.iter, start, sp, now) {
            Disposition::CompletedLocally { sp, iters } => {
                run.iters_total += iters;
                self.advance_op(now, run, sp, q, report, inflight, done, runs);
            }
            Disposition::RunOnCpu => {
                let (_st, sp, iters) =
                    self.run_on_cpu(&stage.iter, start, sp);
                // remote reads: one RTT per iteration, charged virtually.
                let rtt = 2 * self.lat.one_way_ns(298)
                    + self.lat.cpu_dram_ns as Ns;
                run.iters_total += iters;
                run.born = run.born.min(now); // unchanged; latency below
                let fin = now + iters as u64 * rtt;
                // model as an instantaneous functional result at `fin`
                run.cross_ns += 0;
                let mut run = run;
                run.op.cpu_post_ns += 0;
                // advance after the virtual delay
                // (simplified: advance now, fold delay into born shift)
                run.born = run.born.saturating_sub(fin - now);
                self.advance_op(now, run, sp, q, report, inflight, done, runs);
            }
            Disposition::Offload(msg) => {
                let id = msg.id;
                runs.insert(id, run);
                let bytes = msg.wire_size();
                let job = Box::new(NodeJob { msg, steps: 0 });
                if let Some(t) = self.link_cpu_up.send(now, bytes) {
                    q.push(t, Ev::AtSwitch { job, from_node: false });
                }
                // if dropped, the TimeoutScan resends from dispatch state
            }
        }
    }

    /// A stage finished with final scratchpad `sp` — repeat it, move to
    /// the next stage, or complete the op.
    #[allow(clippy::too_many_arguments)]
    fn advance_op(
        &mut self,
        now: Ns,
        mut run: OpRun,
        sp: [i64; SP_WORDS],
        q: &mut EventQueue<Ev>,
        report: &mut ServeReport,
        inflight: &mut usize,
        done: bool,
        runs: &mut HashMap<RequestId, OpRun>,
    ) {
        let stage = &run.op.stages[run.stage_idx];
        if let Some((aw, gw)) = stage.repeat_while {
            if sp[aw as usize] != 0 && sp[gw as usize] > 0 {
                let t = now + self.lat.host_net_stack_ns as Ns;
                self.launch_stage(
                    t, run, sp, Some(sp), q, report, inflight, done, runs,
                );
                return;
            }
        }
        if run.stage_idx + 1 < run.op.stages.len() {
            run.stage_idx += 1;
            let t = now + self.lat.host_net_stack_ns as Ns;
            self.launch_stage(
                t, run, sp, None, q, report, inflight, done, runs,
            );
            return;
        }
        // op complete
        let fin = now + run.op.cpu_post_ns;
        report.completed += 1;
        report.latency.record((fin - run.born).max(1));
        report.crossings.record(run.crossings_total as u64);
        if run.crossings_total > 0 {
            report.cross_node_requests += 1;
            report.cross_latency_ns.record(run.cross_ns.max(1));
        }
        report.total_iters += run.iters_total as u64;
        report.makespan_ns = report.makespan_ns.max(fin);
        *inflight -= 1;
        if !done {
            q.push(fin, Ev::Issue);
        }
    }

    /// Latency of the aggregated load: fixed path (TCAM + memory
    /// controller + interconnect) + random-burst streaming.
    fn mem_latency_for(lat: &LatencyModel, job: &NodeJob) -> Ns {
        lat.mem_pipe_ns(
            job.msg.program.load_words as usize,
            job.msg.program.writes_data,
        )
    }

    /// Occupancy of the memory pipeline: the streaming slot only. The
    /// controller overlaps row activations across outstanding bursts,
    /// so the fixed 179 ns is *latency*, not serialization — this is
    /// what lets n pipelines reach the 25 GB/s the paper saturates.
    fn mem_occupancy_for(_lat: &LatencyModel, job: &NodeJob) -> Ns {
        let words = job.msg.program.load_words as u64;
        let wb = if job.msg.program.writes_data { 2 } else { 1 };
        // 1.28 ns per 8 B word at 6.25 GB/s per pipeline + issue slot
        (words * wb * 13 / 10).max(4)
    }

    fn start_mem_phase(
        lat: &LatencyModel,
        q: &mut EventQueue<Ev>,
        ns: &mut NodeState,
        node: NodeId,
        slot: usize,
        t: Ns,
    ) {
        if ns.mem_free > 0 {
            ns.mem_free -= 1;
            Self::grant_mem(lat, q, ns, node, slot, t);
        } else {
            ns.mem_wait.push_back(slot);
        }
    }

    fn grant_mem(
        lat: &LatencyModel,
        q: &mut EventQueue<Ev>,
        ns: &mut NodeState,
        node: NodeId,
        slot: usize,
        t: Ns,
    ) {
        let job = ns.slots[slot].as_ref().unwrap();
        let occ = Self::mem_occupancy_for(lat, job);
        let latn = Self::mem_latency_for(lat, job);
        q.push(t + occ, Ev::MemFree { node });
        q.push(t + latn.max(occ), Ev::MemDone { node, slot });
    }

    /// One *functional* iteration (translate, fetch, logic) for the job.
    fn one_iteration(accel: &mut Accelerator, job: &mut NodeJob) -> IterResult {
        use crate::mem::translate::TranslateError;
        let words = job.msg.program.load_words as usize;
        if job.msg.iters_done >= job.msg.max_iters {
            job.msg.status = Status::Running; // yield marker
            return IterResult::Fault;
        }
        let local = match accel.table.translate(
            job.msg.cur_ptr,
            (words * 8) as u64,
            false,
        ) {
            Ok(off) => off,
            Err(TranslateError::NotLocal) => {
                job.msg.node_crossings += 1;
                accel.bounces += 1;
                job.msg.status = Status::Running;
                return IterResult::Bounce;
            }
            Err(TranslateError::Protection) => {
                job.msg.status = Status::Trap;
                accel.traps += 1;
                return IterResult::Fault;
            }
        };
        let mut ws = crate::interp::Workspace::new();
        ws.sp.copy_from_slice(&job.msg.sp);
        ws.set_cur_ptr(job.msg.cur_ptr);
        accel.region.read_words(local, &mut ws.data[..words]);
        let pass = logic_pass(&job.msg.program, &mut ws);
        accel.iterations += 1;
        job.msg.iters_done += 1;
        if job.msg.program.writes_data {
            if let Ok(off) = accel.table.translate(
                job.msg.cur_ptr,
                (words * 8) as u64,
                true,
            ) {
                accel.region.write_words(off, &ws.data[..words]);
            } else {
                job.msg.status = Status::Trap;
                return IterResult::Fault;
            }
        }
        job.msg.sp.copy_from_slice(&ws.sp);
        job.steps = pass.steps;
        match pass.status {
            Status::NextIter => {
                job.msg.cur_ptr = ws.cur_ptr();
                job.msg.status = Status::Running;
                IterResult::Logic(pass.steps)
            }
            Status::Return => {
                job.msg.status = Status::Return;
                IterResult::Logic(pass.steps)
            }
            _ => {
                job.msg.status = Status::Trap;
                accel.traps += 1;
                IterResult::Logic(pass.steps)
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn depart_node(
        q: &mut EventQueue<Ev>,
        lat: &LatencyModel,
        ns: &mut NodeState,
        link_up: &mut Link,
        node: NodeId,
        slot: usize,
        now: Ns,
        bounce: bool,
    ) {
        let mut job = ns.slots[slot].take().unwrap();
        if let Some(j) = ns.admit_wait.pop_front() {
            let s = ns.put(j);
            Self::start_mem_phase(
                lat,
                q,
                ns,
                node,
                s,
                now + lat.accel_sched_ns as Ns,
            );
        } else {
            ns.ws_free += 1;
        }
        let t = now + lat.accel_net_stack_ns as Ns;
        if !bounce {
            job.msg.kind = MsgKind::Response;
        }
        let bytes = job.msg.wire_size();
        if let Some(at) = link_up.send(t, bytes) {
            q.push(at, Ev::AtSwitch { job, from_node: true });
        }
    }
}

enum IterResult {
    Logic(u32),
    Bounce,
    Fault,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ds::{ForwardList, HashMapDs};

    fn small_cfg(nodes: usize) -> RackConfig {
        RackConfig {
            nodes,
            node_capacity: 32 << 20,
            granularity: 1 << 20,
            ..Default::default()
        }
    }

    #[test]
    fn serve_completes_all_ops_single_node() {
        let mut r = Rack::new(small_cfg(1));
        let mut m = HashMapDs::build(&mut r, 256);
        for i in 0..1000 {
            m.insert(&mut r, i, i * 2);
        }
        let prog = m.find_program();
        let ops: Vec<Op> = (0..200)
            .map(|i| {
                let key = i % 1000;
                let mut sp = [0i64; SP_WORDS];
                sp[0] = key;
                Op::new(prog.clone(), m.bucket_ptr(key), sp)
            })
            .collect();
        let mut it = ops.into_iter();
        let report = r.serve(move |_| it.next(), 8);
        assert_eq!(report.completed, 200);
        assert_eq!(report.trapped, 0);
        assert!(report.latency.p50() > 1_000, "{}", report.latency.p50());
        assert!(report.tput_ops_per_s > 1000.0);
    }

    #[test]
    fn serve_handles_distributed_traversals() {
        let mut cfg = small_cfg(4);
        cfg.granularity = 4096;
        let mut r = Rack::new(cfg);
        let mut l = ForwardList::new();
        for i in 0..3000 {
            l.push(&mut r, i);
        }
        let prog = l.find_program();
        let head = l.head;
        let mut n = 0;
        let report = r.serve(
            move |_| {
                n += 1;
                if n > 50 {
                    return None;
                }
                let mut sp = [0i64; SP_WORDS];
                sp[0] = 2500 + n; // deep in the list => crosses nodes
                Some(Op::new(prog.clone(), head, sp))
            },
            4,
        );
        assert_eq!(report.completed, 50);
        assert!(report.cross_node_requests > 0, "no cross-node traffic");
        assert!(report.crossings.max() >= 1);
    }

    #[test]
    fn pulse_acc_has_higher_latency_than_pulse() {
        let build = |in_network: bool| {
            let mut cfg = small_cfg(4);
            cfg.granularity = 4096;
            cfg.in_network_routing = in_network;
            let mut r = Rack::new(cfg);
            let mut l = ForwardList::new();
            for i in 0..4000 {
                l.push(&mut r, i);
            }
            let prog = l.find_program();
            let head = l.head;
            let mut n = 0;
            let report = r.serve(
                move |_| {
                    n += 1;
                    if n > 40 {
                        return None;
                    }
                    let mut sp = [0i64; SP_WORDS];
                    sp[0] = 3500 + (n % 400);
                    Some(Op::new(prog.clone(), head, sp))
                },
                1,
            );
            report
        };
        let pulse = build(true);
        let acc = build(false);
        assert_eq!(pulse.completed, acc.completed);
        assert!(
            acc.latency.mean() > pulse.latency.mean(),
            "PULSE {} vs ACC {}",
            pulse.latency.mean(),
            acc.latency.mean()
        );
    }

    #[test]
    fn lossy_links_recover_via_retransmission() {
        let mut cfg = small_cfg(2);
        cfg.loss = 0.05;
        cfg.dispatch.timeout_ns = 100_000;
        let mut r = Rack::new(cfg);
        let mut m = HashMapDs::build(&mut r, 64);
        for i in 0..200 {
            m.insert(&mut r, i, i);
        }
        let prog = m.find_program();
        let buckets: Vec<_> = (0..200).map(|k| m.bucket_ptr(k)).collect();
        let mut n = 0;
        let report = r.serve(
            move |_| {
                n += 1;
                if n > 300 {
                    return None;
                }
                let key = n % 200;
                let mut sp = [0i64; SP_WORDS];
                sp[0] = key;
                Some(Op::new(
                    prog.clone(),
                    buckets[key as usize],
                    sp,
                ))
            },
            8,
        );
        assert_eq!(report.completed, 300, "ops lost despite retransmit");
        assert!(report.retransmits > 0, "loss never triggered retransmit");
    }

    #[test]
    fn multi_stage_op_chains_through_sp() {
        // stage 1: hash find returns value (an address) in sp[1];
        // stage 2: list-sum from that address.
        let mut r = Rack::new(small_cfg(2));
        let mut l = ForwardList::new();
        for i in 1..=10 {
            l.push(&mut r, i);
        }
        let mut m = HashMapDs::build(&mut r, 16);
        m.insert(&mut r, 42, l.head as i64);

        let mut sp0 = [0i64; SP_WORDS];
        sp0[0] = 42;
        let stage1 =
            Stage::new(m.find_program(), m.bucket_ptr(42), sp0);
        let mut stage2 = Stage::new(
            l.sum_program(),
            0,
            [0i64; SP_WORDS],
        );
        stage2.start = StartAddr::FromPrevSp(1);
        let op = Op {
            stages: vec![stage1, stage2],
            cpu_post_ns: 500,
        };
        // functional check first
        let sp = r.run_op_functional(&op);
        assert_eq!(sp[3], 55); // sum 1..=10
        // DES check
        let mut sent = false;
        let report = r.serve(
            move |_| {
                if sent {
                    None
                } else {
                    sent = true;
                    Some(op.clone())
                }
            },
            1,
        );
        assert_eq!(report.completed, 1);
        assert_eq!(report.trapped, 0);
    }
}
