//! The rack: CPU node + programmable switch + memory nodes, composed
//! into one discrete-event simulation (paper §6 testbed: 1 compute node,
//! up to 4 memory nodes behind a Tofino switch).
//!
//! This module is the wiring layer only; the runtime is split into
//! focused submodules (see `rack/README.md` for the full map):
//!
//! * [`config`] — `RackConfig` + presets (paper §6 testbed parameters);
//! * [`request`] — stage chains (`Stage`/`StartAddr`/`Op`) and per-op
//!   DES run state;
//! * [`node`] — the memory-node model: pipeline reservations and the
//!   functional iteration (paper §4.2);
//! * [`events`] — the discrete-event serving loop (`serve`,
//!   `serve_batch`) over network, switch, and node events (paper §5);
//! * [`stats`] — `ServeReport` and bandwidth-utilization helpers.
//!
//! `in_network_routing = false` turns the rack into PULSE-ACC (paper
//! §6.2 Fig. 9): non-local pointers return to the CPU node instead of
//! being re-routed at the switch.
//!
//! The rack is also a [`crate::backend::TraversalBackend`], the shared
//! interface all compared systems (PULSE, PULSE-ACC, Cache, RPC) are
//! driven through.

pub mod config;
mod events;
mod node;
pub mod request;
pub mod stats;

pub use config::RackConfig;
pub use request::{Op, Stage, StartAddr};
pub use stats::ServeReport;

use crate::accel::{Accelerator, VisitEnd};
use crate::compiler::CompiledIter;
use crate::dispatch::{DispatchEngine, Disposition};
use crate::interp::{logic_pass, Workspace};
use crate::isa::{Status, NREG, SP_WORDS};
use crate::mem::{GAddr, NodeId, RackAllocator, RangeTable, Region};
use crate::net::Link;
use crate::sim::LatencyModel;
use crate::switch::{Route, Switch};

use events::ServeScratch;

pub struct Rack {
    pub cfg: RackConfig,
    pub lat: LatencyModel,
    pub alloc: RackAllocator,
    pub switch: Switch,
    pub memnodes: Vec<Accelerator>,
    pub dispatch: DispatchEngine,
    pub(crate) link_cpu_up: Link,
    pub(crate) link_cpu_down: Link,
    pub(crate) links_node_down: Vec<Link>,
    pub(crate) links_node_up: Vec<Link>,
    published_slabs: usize,
    /// Reusable DES scratch (event queue, node states, run table).
    pub(crate) scratch: ServeScratch,
    /// Reusable functional workspace for the DES iteration hot path.
    pub(crate) des_ws: Workspace,
    /// Cumulative metrics across all serve runs (backend accounting).
    pub(crate) totals: ServeReport,
}

impl Rack {
    pub fn new(cfg: RackConfig) -> Self {
        let lat = LatencyModel::default();
        let alloc = RackAllocator::new(
            cfg.nodes,
            cfg.node_capacity,
            cfg.granularity,
            cfg.policy,
            cfg.seed,
        );
        let switch = Switch::new(alloc.switch_map.clone(), &lat);
        let memnodes = (0..cfg.nodes)
            .map(|n| {
                Accelerator::new(
                    n as NodeId,
                    Region::new(cfg.node_capacity as usize),
                    RangeTable::new(cfg.tcam_entries),
                    cfg.accel,
                )
            })
            .collect();
        let dispatch = DispatchEngine::new(0, cfg.dispatch);
        let mk = |seed| Link::from_model(&lat, cfg.loss, seed);
        Self {
            link_cpu_up: mk(cfg.seed ^ 1),
            link_cpu_down: mk(cfg.seed ^ 2),
            links_node_down: (0..cfg.nodes)
                .map(|i| mk(cfg.seed ^ (0x10 + i as u64)))
                .collect(),
            links_node_up: (0..cfg.nodes)
                .map(|i| mk(cfg.seed ^ (0x20 + i as u64)))
                .collect(),
            cfg,
            lat,
            alloc,
            switch,
            memnodes,
            dispatch,
            published_slabs: 0,
            scratch: ServeScratch::default(),
            des_ws: Workspace::new(),
            totals: ServeReport::default(),
        }
    }

    /// Cumulative metrics over every serve run on this rack.
    pub fn cumulative(&self) -> &ServeReport {
        &self.totals
    }

    /// Allocate on the rack and keep switch + TCAM tables in sync.
    pub fn alloc(&mut self, size: u64) -> GAddr {
        let a = self.alloc.alloc(size);
        self.publish_new_slabs();
        a
    }

    pub fn alloc_on(&mut self, node: NodeId, size: u64) -> GAddr {
        let a = self.alloc.alloc_on(node, size);
        self.publish_new_slabs();
        a
    }

    fn publish_new_slabs(&mut self) {
        if self.alloc.slabs_allocated as usize == self.published_slabs {
            return;
        }
        self.switch.update_map(self.alloc.switch_map.clone());
        for n in 0..self.cfg.nodes {
            for &(base, len, local) in &self.alloc.node_ranges[n] {
                let _ = self.memnodes[n].table.insert(
                    base,
                    len,
                    local,
                    crate::mem::Perms::RW,
                );
            }
        }
        self.published_slabs = self.alloc.slabs_allocated as usize;
    }

    /// Host-side write (data-structure build + mutation path).
    pub fn write_words(&mut self, addr: GAddr, words: &[i64]) {
        let node = self.alloc.owner(addr).expect("write to unmapped addr");
        let accel = &mut self.memnodes[node as usize];
        let off = accel
            .table
            .translate(addr, (words.len() * 8) as u64, true)
            .expect("host write failed translation");
        accel.region.write_words(off, words);
    }

    pub fn read_words(&mut self, addr: GAddr, out: &mut [i64]) {
        let node = self.alloc.owner(addr).expect("read of unmapped addr");
        let accel = &mut self.memnodes[node as usize];
        let off = accel
            .table
            .translate(addr, (out.len() * 8) as u64, false)
            .expect("host read failed translation");
        accel.region.read_words(off, out);
    }

    /// Purely functional traversal (no timing) — correctness paths,
    /// data-structure APIs, the quickstart example. Same
    /// dispatch/switch/visit logic as the DES.
    pub fn traverse(
        &mut self,
        iter: &CompiledIter,
        start: GAddr,
        sp: [i64; SP_WORDS],
    ) -> (Status, [i64; SP_WORDS], u32) {
        match self.dispatch.submit(iter, start, sp, 0) {
            Disposition::CompletedLocally { sp, iters } => {
                (Status::Return, sp, iters)
            }
            Disposition::RunOnCpu => self.run_on_cpu(iter, start, sp),
            Disposition::Offload(mut msg) => {
                let mut budget_boosts = 0;
                let mut from_node = false;
                loop {
                    let node = match self.switch.route(&msg, from_node) {
                        Route::MemNode(n) => n,
                        Route::Invalid(_) => {
                            return (Status::Trap, msg.sp, msg.iters_done)
                        }
                        Route::CpuNode(_) => unreachable!(),
                    };
                    let out = self.memnodes[node as usize].visit(&mut msg);
                    match out.end {
                        VisitEnd::Done(st) => {
                            return (st, msg.sp, msg.iters_done)
                        }
                        VisitEnd::NotLocal => {
                            from_node = true;
                            continue;
                        }
                        VisitEnd::Yield => {
                            budget_boosts += 1;
                            if budget_boosts > 4096 {
                                return (Status::Trap, msg.sp, msg.iters_done);
                            }
                            msg.max_iters += self.cfg.dispatch.max_iters;
                        }
                    }
                }
            }
        }
    }

    /// CPU fallback for non-offloadable iterators: one remote read per
    /// pointer hop (paper §4.1).
    pub(crate) fn run_on_cpu(
        &mut self,
        iter: &CompiledIter,
        start: GAddr,
        sp: [i64; SP_WORDS],
    ) -> (Status, [i64; SP_WORDS], u32) {
        let mut ws = Workspace::new();
        ws.sp.copy_from_slice(&sp);
        let words = iter.program.load_words as usize;
        let mut cur = start;
        let mut iters = 0u32;
        let mut buf = vec![0i64; words];
        loop {
            self.read_words(cur, &mut buf);
            ws.regs = [0; NREG];
            ws.set_cur_ptr(cur);
            ws.data[..words].copy_from_slice(&buf);
            ws.data[words..].iter_mut().for_each(|w| *w = 0);
            let pass = logic_pass(&iter.program, &mut ws);
            iters += 1;
            match pass.status {
                Status::NextIter => cur = ws.cur_ptr(),
                s => {
                    let mut out = [0i64; SP_WORDS];
                    out.copy_from_slice(&ws.sp);
                    return (s, out, iters);
                }
            }
        }
    }

    /// Functional multi-stage op (reference for the DES path; used by
    /// tests and the baseline trace collectors to check stage plumbing).
    pub fn run_op_functional(&mut self, op: &Op) -> [i64; SP_WORDS] {
        let mut prev_sp = [0i64; SP_WORDS];
        for stage in &op.stages {
            let mut repeat_from = None;
            loop {
                let (start, sp) = stage.resolve(&prev_sp, repeat_from);
                let (_st, out, _) = self.traverse(&stage.iter, start, sp);
                if stage.wants_repeat(&out) {
                    repeat_from = Some(out);
                    continue;
                }
                prev_sp = out;
                break;
            }
        }
        prev_sp
    }
}
