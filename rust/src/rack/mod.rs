//! The rack: CPU node + programmable switch + memory nodes, composed
//! into one discrete-event simulation (paper §6 testbed: 1 compute node,
//! up to 4 memory nodes behind a Tofino switch).
//!
//! This module is the wiring layer only; the runtime is split into
//! focused submodules (see `rack/README.md` for the full map):
//!
//! * [`config`] — `RackConfig` + presets (paper §6 testbed parameters);
//! * [`request`] — stage chains (`Stage`/`StartAddr`/`Op`) and per-op
//!   DES run state;
//! * [`node`] — the memory-node model: pipeline reservations and the
//!   functional iteration (paper §4.2);
//! * [`events`] — the discrete-event serving loop (`serve`,
//!   `serve_batch`) over network, switch, and node events (paper §5);
//! * [`stats`] — `ServeReport` and bandwidth-utilization helpers.
//!
//! `in_network_routing = false` turns the rack into PULSE-ACC (paper
//! §6.2 Fig. 9): non-local pointers return to the CPU node instead of
//! being re-routed at the switch.
//!
//! The rack is also a [`crate::backend::TraversalBackend`], the shared
//! interface all compared systems (PULSE, PULSE-ACC, Cache, RPC) are
//! driven through.

pub mod config;
mod events;
mod node;
pub mod request;
pub mod stats;

pub use config::RackConfig;
pub use request::{Op, OpShapeError, Stage, StartAddr};
pub use stats::ServeReport;

use crate::accel::{Accelerator, VisitEnd};
use crate::compiler::CompiledIter;
use crate::dispatch::{DispatchEngine, Disposition};
use crate::interp::{logic_pass, Workspace};
use crate::isa::{Status, NREG, SP_WORDS};
use crate::mem::{GAddr, NodeId, RackAllocator, RangeTable, Region};
use crate::net::{Link, TraversalMsg};
use crate::obs::{
    OpTrace, SpanKind, Trace, TraceConfig, Tracer, TracerStats,
};
use crate::sim::LatencyModel;
use crate::switch::{Route, Switch};

use events::ServeScratch;

/// Why a host-side memory access failed (the CPU node touching rack
/// memory directly: builds, `run_on_cpu`, baseline tracers).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HostAccessError {
    /// No slab owns this address.
    Unmapped(GAddr),
    /// Owned, but translation failed (range boundary / protection).
    Fault(GAddr),
}

impl std::fmt::Display for HostAccessError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HostAccessError::Unmapped(a) => {
                write!(f, "access to unmapped addr {a:#x}")
            }
            HostAccessError::Fault(a) => {
                write!(f, "translation fault at {a:#x}")
            }
        }
    }
}

/// Full result of a budgeted functional traversal
/// ([`Rack::traverse_budgeted`]): terminal status, final scratchpad,
/// and the accounting the serving tier surfaces (iterations, node
/// crossings, whether the traversal went over the offload path at
/// all — CPU fallback and cache-local completion move no link bytes).
#[derive(Debug, Clone, Copy)]
pub struct TraverseOutcome {
    pub status: Status,
    pub sp: [i64; SP_WORDS],
    pub iters: u32,
    pub crossings: u32,
    pub offloaded: bool,
}

pub struct Rack {
    pub cfg: RackConfig,
    pub lat: LatencyModel,
    pub alloc: RackAllocator,
    pub switch: Switch,
    pub memnodes: Vec<Accelerator>,
    pub dispatch: DispatchEngine,
    pub(crate) link_cpu_up: Link,
    pub(crate) link_cpu_down: Link,
    pub(crate) links_node_down: Vec<Link>,
    pub(crate) links_node_up: Vec<Link>,
    published_slabs: usize,
    /// Reusable DES scratch (event queue, node states, run table).
    pub(crate) scratch: ServeScratch,
    /// Reusable functional workspace for the DES iteration hot path.
    pub(crate) des_ws: Workspace,
    /// Reusable window buffer for `run_on_cpu` (clear-don't-free: the
    /// CPU-fallback path must not pay a heap allocation per op).
    cpu_buf: Vec<i64>,
    /// Cumulative metrics across all serve runs (backend accounting).
    pub(crate) totals: ServeReport,
    /// Sampled traversal tracer (disabled by default; see `obs/`).
    /// DES serves emit spans stamped with virtual sim time.
    pub(crate) tracer: Tracer,
}

impl Rack {
    pub fn new(cfg: RackConfig) -> Self {
        let lat = LatencyModel::default();
        let mut alloc = RackAllocator::new(
            cfg.nodes,
            cfg.node_capacity,
            cfg.granularity,
            cfg.policy,
            cfg.seed,
        );
        let switch = Switch::new(alloc.publish_map(), &lat);
        let memnodes = (0..cfg.nodes)
            .map(|n| {
                Accelerator::new(
                    n as NodeId,
                    Region::new(cfg.node_capacity as usize),
                    RangeTable::new(cfg.tcam_entries),
                    cfg.accel,
                )
            })
            .collect();
        let dispatch = DispatchEngine::new(0, cfg.dispatch);
        let mk = |seed| Link::from_model(&lat, cfg.loss, seed);
        Self {
            link_cpu_up: mk(cfg.seed ^ 1),
            link_cpu_down: mk(cfg.seed ^ 2),
            links_node_down: (0..cfg.nodes)
                .map(|i| mk(cfg.seed ^ (0x10 + i as u64)))
                .collect(),
            links_node_up: (0..cfg.nodes)
                .map(|i| mk(cfg.seed ^ (0x20 + i as u64)))
                .collect(),
            cfg,
            lat,
            alloc,
            switch,
            memnodes,
            dispatch,
            published_slabs: 0,
            scratch: ServeScratch::default(),
            des_ws: Workspace::new(),
            cpu_buf: Vec::new(),
            totals: ServeReport::default(),
            tracer: Tracer::disabled(),
        }
    }

    /// Cumulative metrics over every serve run on this rack.
    pub fn cumulative(&self) -> &ServeReport {
        &self.totals
    }

    /// Enable sampled tracing for subsequent serves (see `obs/`). DES
    /// spans are stamped with virtual sim nanoseconds; the span
    /// *sequence* is executor-independent (the conformance contract).
    pub fn enable_trace(&mut self, cfg: TraceConfig) {
        self.tracer = Tracer::new(cfg);
    }

    /// Tracer overhead counters (all zero while tracing is disabled —
    /// the zero-cost contract pinned in `tests/conformance.rs`).
    pub fn tracer_stats(&self) -> TracerStats {
        self.tracer.stats()
    }

    /// Drain spans recorded since the last drain, in causal order.
    pub fn take_trace(&mut self) -> Trace {
        self.tracer.drain()
    }

    /// Aggregate link-layer counters across every segment (CPU up/down
    /// plus all per-node links). `dropped` is the loss the dispatch
    /// engine had to retransmit around — surfaced through
    /// `BackendMetrics.net_dropped` so overload is observable.
    pub fn link_totals(&self) -> crate::net::LinkStats {
        let mut t = crate::net::LinkStats::default();
        let links = [&self.link_cpu_up, &self.link_cpu_down]
            .into_iter()
            .chain(self.links_node_down.iter())
            .chain(self.links_node_up.iter());
        for l in links {
            t.messages += l.stats.messages;
            t.bytes += l.stats.bytes;
            t.dropped += l.stats.dropped;
        }
        t
    }

    /// Allocate on the rack and keep switch + TCAM tables in sync.
    pub fn alloc(&mut self, size: u64) -> GAddr {
        let a = self.alloc.alloc(size);
        self.publish_new_slabs();
        a
    }

    pub fn alloc_on(&mut self, node: NodeId, size: u64) -> GAddr {
        let a = self.alloc.alloc_on(node, size);
        self.publish_new_slabs();
        a
    }

    fn publish_new_slabs(&mut self) {
        if self.alloc.slabs_allocated as usize == self.published_slabs {
            return;
        }
        self.switch.update_map(self.alloc.publish_map());
        for n in 0..self.cfg.nodes {
            for &(base, len, local) in &self.alloc.node_ranges[n] {
                let _ = self.memnodes[n].table.insert(
                    base,
                    len,
                    local,
                    crate::mem::Perms::RW,
                );
            }
        }
        self.published_slabs = self.alloc.slabs_allocated as usize;
    }

    /// Fallible host-side write. Serving paths (`run_on_cpu`, the
    /// baseline tracers) use this and turn failures into trapped ops
    /// surfaced through `ServeReport.trapped`, matching the trap
    /// behaviour of the offloaded path — a stray pointer must never
    /// panic a serving loop.
    pub fn try_write_words(
        &mut self,
        addr: GAddr,
        words: &[i64],
    ) -> Result<(), HostAccessError> {
        let node = self
            .alloc
            .owner(addr)
            .ok_or(HostAccessError::Unmapped(addr))?;
        let accel = &mut self.memnodes[node as usize];
        let off = accel
            .table
            .translate(addr, (words.len() * 8) as u64, true)
            .map_err(|_| HostAccessError::Fault(addr))?;
        accel.region.write_words(off, words);
        Ok(())
    }

    /// Fallible host-side read; see [`Rack::try_write_words`].
    pub fn try_read_words(
        &mut self,
        addr: GAddr,
        out: &mut [i64],
    ) -> Result<(), HostAccessError> {
        let node = self
            .alloc
            .owner(addr)
            .ok_or(HostAccessError::Unmapped(addr))?;
        let accel = &mut self.memnodes[node as usize];
        let off = accel
            .table
            .translate(addr, (out.len() * 8) as u64, false)
            .map_err(|_| HostAccessError::Fault(addr))?;
        accel.region.read_words(off, out);
        Ok(())
    }

    /// Host-side write (data-structure build + mutation path). Panics
    /// on unmapped addresses — build code addressing memory it never
    /// allocated is a programming error; serving paths use
    /// [`Rack::try_write_words`] and trap instead.
    pub fn write_words(&mut self, addr: GAddr, words: &[i64]) {
        self.try_write_words(addr, words)
            .unwrap_or_else(|e| panic!("host write: {e}"));
    }

    pub fn read_words(&mut self, addr: GAddr, out: &mut [i64]) {
        self.try_read_words(addr, out)
            .unwrap_or_else(|e| panic!("host read: {e}"));
    }

    /// Purely functional traversal (no timing) — correctness paths,
    /// data-structure APIs, the quickstart example. Same
    /// dispatch/switch/visit logic as the DES.
    pub fn traverse(
        &mut self,
        iter: &CompiledIter,
        start: GAddr,
        sp: [i64; SP_WORDS],
    ) -> (Status, [i64; SP_WORDS], u32) {
        let o = self.traverse_budgeted(iter, start, sp, 0, 4096);
        (o.status, o.sp, o.iters)
    }

    /// Functional traversal with *live-engine* semantics: always
    /// offloaded — no η offload test, no CPU fallback, no library
    /// cache (the live shards are general-purpose cores, so none of
    /// those apply) — with an explicit initial budget (0 = the
    /// dispatch grant) and yield-continuation cap. This is the
    /// serving tier's inline executor: for any wire request it
    /// produces the same terminal status, scratchpad, iteration count,
    /// and crossings as the sharded dataplane, including for programs
    /// the dispatch engine would have kept on the CPU.
    pub fn traverse_offloaded(
        &mut self,
        iter: &CompiledIter,
        start: GAddr,
        sp: [i64; SP_WORDS],
        budget: u32,
        max_boosts: u32,
    ) -> TraverseOutcome {
        let grant = self.cfg.dispatch.max_iters;
        let msg = TraversalMsg::request(
            crate::net::RequestId { cpu_node: 0, seq: 0 },
            std::sync::Arc::clone(&iter.program),
            start,
            sp,
            if budget != 0 { budget } else { grant },
        );
        self.drive_offloaded(msg, max_boosts, None)
    }

    /// [`Rack::traverse_offloaded`] with span emission into a caller-
    /// owned [`OpTrace`] (the wire tier's inline executor; `tracer`
    /// supplies the timestamps). Emits `visit`/`forward`/`bounce`/
    /// `boost` hops; the caller brackets with `dispatch` and `finish`.
    pub fn traverse_offloaded_traced(
        &mut self,
        iter: &CompiledIter,
        start: GAddr,
        sp: [i64; SP_WORDS],
        budget: u32,
        max_boosts: u32,
        trace: Option<(&mut OpTrace<'_>, &Tracer)>,
    ) -> TraverseOutcome {
        let grant = self.cfg.dispatch.max_iters;
        let msg = TraversalMsg::request(
            crate::net::RequestId { cpu_node: 0, seq: 0 },
            std::sync::Arc::clone(&iter.program),
            start,
            sp,
            if budget != 0 { budget } else { grant },
        );
        self.drive_offloaded(msg, max_boosts, trace)
    }

    /// Drive one offloaded message to its terminal status: route at
    /// the switch, visit memory nodes, follow bounces, re-grant on
    /// yields up to `max_boosts`. The single definition behind both
    /// functional offload paths ([`Rack::traverse_budgeted`] and
    /// [`Rack::traverse_offloaded`]) — the wire tier's inline-vs-
    /// sharded parity depends on there being exactly one copy of this
    /// state machine.
    fn drive_offloaded(
        &mut self,
        mut msg: TraversalMsg,
        max_boosts: u32,
        mut trace: Option<(&mut OpTrace<'_>, &Tracer)>,
    ) -> TraverseOutcome {
        let mut budget_boosts = 0;
        let mut from_node = false;
        let in_network = self.cfg.in_network_routing;
        // a non-local hop's forward span is emitted after the *next*
        // route resolves, so it can name the receiving shard
        let mut pending_forward = false;
        let status = loop {
            let node = match self.switch.route(&msg, from_node) {
                Route::MemNode(n) => n,
                Route::Invalid(_) => break Status::Trap,
                Route::CpuNode(_) => unreachable!(),
            };
            if pending_forward {
                pending_forward = false;
                if let Some((ot, tr)) = trace.as_mut() {
                    ot.push(
                        tr.now_ns(),
                        SpanKind::Forward { to: node as u32 },
                    );
                }
            }
            let out = self.memnodes[node as usize].visit(&mut msg);
            if let Some((ot, tr)) = trace.as_mut() {
                let dram = out.iters as u64
                    * msg.program.dram_bytes_per_iter();
                ot.push(
                    tr.now_ns(),
                    SpanKind::Visit {
                        shard: node as u32,
                        iters: out.iters,
                        dram_bytes: dram,
                    },
                );
            }
            match out.end {
                VisitEnd::Done(st) => break st,
                VisitEnd::NotLocal => {
                    from_node = true;
                    if in_network {
                        pending_forward = true;
                    } else if let Some((ot, tr)) = trace.as_mut() {
                        // PULSE-ACC: the hop goes back through the
                        // dispatcher, same as the live engine's bounce
                        ot.push(tr.now_ns(), SpanKind::Bounce);
                    }
                    continue;
                }
                VisitEnd::Yield => {
                    budget_boosts += 1;
                    if budget_boosts > max_boosts {
                        break Status::Trap;
                    }
                    msg.max_iters += self.cfg.dispatch.max_iters;
                    if let Some((ot, tr)) = trace.as_mut() {
                        // grant = the new total budget after the boost
                        ot.push(
                            tr.now_ns(),
                            SpanKind::Boost { grant: msg.max_iters },
                        );
                    }
                }
            }
        };
        TraverseOutcome {
            status,
            sp: msg.sp,
            iters: msg.iters_done,
            crossings: msg.node_crossings,
            offloaded: true,
        }
    }

    /// [`Rack::traverse`] with an explicit initial iteration budget
    /// (0 = the dispatch grant) and yield-continuation cap — the
    /// *in-process* functional path with full dispatch-engine
    /// semantics (η offload test, CPU fallback, library cache). The
    /// budget applies from the first iteration, including the cache
    /// prefix walk (`dispatch.submit_detached`); it does not apply to
    /// CPU-fallback iterators, which run to completion (bounded only
    /// by `run_on_cpu`'s runaway guard). The wire tier's inline
    /// executor does NOT use this: it serves through
    /// [`Rack::traverse_offloaded`], whose always-offload semantics
    /// match the sharded dataplane.
    pub fn traverse_budgeted(
        &mut self,
        iter: &CompiledIter,
        start: GAddr,
        sp: [i64; SP_WORDS],
        budget: u32,
        max_boosts: u32,
    ) -> TraverseOutcome {
        match self.dispatch.submit_detached(iter, start, sp, budget) {
            Disposition::CompletedLocally { status, sp, iters } => {
                TraverseOutcome {
                    status,
                    sp,
                    iters,
                    crossings: 0,
                    offloaded: false,
                }
            }
            Disposition::RunOnCpu => {
                let (status, sp, iters) =
                    self.run_on_cpu(iter, start, sp);
                TraverseOutcome {
                    status,
                    sp,
                    iters,
                    crossings: 0,
                    offloaded: false,
                }
            }
            Disposition::Offload(msg) => {
                self.drive_offloaded(msg, max_boosts, None)
            }
        }
    }

    /// CPU fallback for non-offloadable iterators: one remote read per
    /// pointer hop (paper §4.1). Mutating iterators write the dirty
    /// window back with one remote write per hop; a pointer into
    /// unmapped memory traps the traversal (never panics the loop).
    /// Bounded by a runaway guard sized like the offload path's
    /// maximum legitimate work (grant × (default boost cap + 1)): a
    /// cyclic pointer chain traps instead of pinning the caller — on
    /// the wire tier's inline executor, a single client-registered
    /// cyclic program would otherwise wedge the engine forever.
    pub(crate) fn run_on_cpu(
        &mut self,
        iter: &CompiledIter,
        start: GAddr,
        sp: [i64; SP_WORDS],
    ) -> (Status, [i64; SP_WORDS], u32) {
        let cap = self
            .cfg
            .dispatch
            .max_iters
            .saturating_mul(4097)
            .max(1 << 20);
        let mut ws = Workspace::new();
        ws.sp.copy_from_slice(&sp);
        let words = iter.program.load_words as usize;
        let mut cur = start;
        let mut iters = 0u32;
        // detach the reusable buffer so `try_read_words` can borrow
        // `self`; restored below (Vec::new() does not allocate)
        let mut buf = std::mem::take(&mut self.cpu_buf);
        buf.clear();
        buf.resize(words, 0);
        let res = loop {
            let mut out = [0i64; SP_WORDS];
            if iters >= cap {
                out.copy_from_slice(&ws.sp);
                break (Status::Trap, out, iters);
            }
            if self.try_read_words(cur, &mut buf).is_err() {
                out.copy_from_slice(&ws.sp);
                break (Status::Trap, out, iters);
            }
            ws.regs = [0; NREG];
            ws.set_cur_ptr(cur);
            ws.data[..words].copy_from_slice(&buf);
            ws.data[words..].iter_mut().for_each(|w| *w = 0);
            let pass = logic_pass(&iter.program, &mut ws);
            iters += 1;
            if iter.program.writes_data
                && self.try_write_words(cur, &ws.data[..words]).is_err()
            {
                out.copy_from_slice(&ws.sp);
                break (Status::Trap, out, iters);
            }
            match pass.status {
                Status::NextIter => cur = ws.cur_ptr(),
                s => {
                    out.copy_from_slice(&ws.sp);
                    break (s, out, iters);
                }
            }
        };
        self.cpu_buf = buf;
        res
    }

    /// Functional multi-stage op (reference for the DES path; used by
    /// tests and the baseline trace collectors to check stage plumbing).
    /// A trap is terminal for the whole op, exactly as in the DES, the
    /// live engine, and `trace_full_op` — repeating a faulted stage
    /// would re-issue the same continuation forever.
    pub fn run_op_functional(&mut self, op: &Op) -> [i64; SP_WORDS] {
        let mut prev_sp = [0i64; SP_WORDS];
        for stage in &op.stages {
            let mut repeat_from = None;
            loop {
                let (start, sp) = stage.resolve(&prev_sp, repeat_from);
                if start == 0 {
                    // degenerate stage: skip forward
                    prev_sp = sp;
                    break;
                }
                let (st, out, _) = self.traverse(&stage.iter, start, sp);
                if st == Status::Trap {
                    return out;
                }
                if stage.wants_repeat(&out) {
                    repeat_from = Some(out);
                    continue;
                }
                prev_sp = out;
                break;
            }
        }
        prev_sp
    }
}
