//! Application-operation plumbing: stage chains and per-op run state.
//!
//! An application operation is a *stage chain* (paper §6 apps): each
//! stage is one offloaded traversal, with scratchpad carried or
//! overridden between stages, optional bulk payloads on the response,
//! and `repeat_while` continuation rounds for scans.

use std::sync::Arc;

use crate::compiler::CompiledIter;
use crate::isa::{Diag, DiagKind, Severity, SP_WORDS};
use crate::mem::GAddr;
use crate::sim::Ns;

/// Where a stage's start pointer comes from.
#[derive(Debug, Clone, Copy)]
pub enum StartAddr {
    Fixed(GAddr),
    /// Read from the previous stage's final scratchpad word.
    FromPrevSp(u32),
}

/// One traversal stage of an application operation.
#[derive(Clone)]
pub struct Stage {
    pub iter: Arc<CompiledIter>,
    pub start: StartAddr,
    pub sp: [i64; SP_WORDS],
    /// Carry the previous stage's final scratchpad into this stage
    /// (overriding `sp`), with `sp_overrides` applied on top.
    pub carry_sp: bool,
    pub sp_overrides: Vec<(u32, i64)>,
    /// Extra bulk payload on this stage's response (e.g. the 8 KB
    /// WebService object riding back with the reply).
    pub object_read_bytes: u32,
    /// Re-issue this stage while sp[word0] != 0 && sp[word1] > 0
    /// (continuation leaf + remaining counter for scans), re-applying
    /// `sp_overrides` each round.
    pub repeat_while: Option<(u32, u32)>,
}

impl Stage {
    pub fn new(iter: Arc<CompiledIter>, start: GAddr, sp: [i64; SP_WORDS]) -> Self {
        Self {
            iter,
            start: StartAddr::Fixed(start),
            sp,
            carry_sp: false,
            sp_overrides: Vec::new(),
            object_read_bytes: 0,
            repeat_while: None,
        }
    }

    /// Resolve this stage's start pointer and initial scratchpad, given
    /// the previous stage's final scratchpad and an optional repeat
    /// continuation. Shared by the functional path, the DES, and the
    /// baseline trace collectors.
    ///
    /// Total on malformed shapes: a repeat continuation on a stage with
    /// no `repeat_while`, or any out-of-range scratchpad word, resolves
    /// to start 0 (the degenerate-stage skip every executor already
    /// handles) instead of panicking — admission-time [`Op::validate`]
    /// is the loud path, this is the safety net.
    pub fn resolve(
        &self,
        prev_sp: &[i64; SP_WORDS],
        repeat_from: Option<[i64; SP_WORDS]>,
    ) -> (GAddr, [i64; SP_WORDS]) {
        let start = match (repeat_from, self.start) {
            (Some(sp), _) => match self.repeat_while {
                Some((aw, _)) if (aw as usize) < SP_WORDS => {
                    sp[aw as usize] as GAddr
                }
                _ => 0,
            },
            (None, StartAddr::Fixed(a)) => a,
            (None, StartAddr::FromPrevSp(w)) if (w as usize) < SP_WORDS => {
                prev_sp[w as usize] as GAddr
            }
            (None, StartAddr::FromPrevSp(_)) => 0,
        };
        let mut sp = match (repeat_from, self.carry_sp) {
            (Some(s), _) => s,
            (None, true) => *prev_sp,
            (None, false) => self.sp,
        };
        for &(w, v) in &self.sp_overrides {
            if (w as usize) < SP_WORDS {
                sp[w as usize] = v;
            }
        }
        (start, sp)
    }

    /// Whether `sp` asks for another continuation round of this stage.
    /// Out-of-range repeat words never repeat (see [`Op::validate`]).
    pub fn wants_repeat(&self, sp: &[i64; SP_WORDS]) -> bool {
        match self.repeat_while {
            Some((aw, gw))
                if (aw as usize) < SP_WORDS && (gw as usize) < SP_WORDS =>
            {
                sp[aw as usize] != 0 && sp[gw as usize] > 0
            }
            _ => false,
        }
    }

    /// Admission-time shape check for one stage.
    fn validate(&self) -> Result<(), OpShapeError> {
        if let StartAddr::FromPrevSp(w) = self.start {
            if w as usize >= SP_WORDS {
                return Err(OpShapeError::StartWordOutOfRange(w));
            }
        }
        if let Some((aw, gw)) = self.repeat_while {
            if aw as usize >= SP_WORDS || gw as usize >= SP_WORDS {
                return Err(OpShapeError::RepeatWordOutOfRange(aw, gw));
            }
        }
        for &(w, _) in &self.sp_overrides {
            if w as usize >= SP_WORDS {
                return Err(OpShapeError::OverrideWordOutOfRange(w));
            }
        }
        Ok(())
    }
}

/// Why an op was rejected at admission (both the DES and the live
/// coordinator trap the op instead of letting a malformed shape panic
/// the whole serving loop).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpShapeError {
    /// Op has no stages at all.
    NoStages,
    /// `StartAddr::FromPrevSp` references a scratchpad word ≥ SP_WORDS.
    StartWordOutOfRange(u32),
    /// `repeat_while` references a scratchpad word ≥ SP_WORDS.
    RepeatWordOutOfRange(u32, u32),
    /// An `sp_overrides` entry references a word ≥ SP_WORDS.
    OverrideWordOutOfRange(u32),
}

impl std::fmt::Display for OpShapeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            OpShapeError::NoStages => write!(f, "op has no stages"),
            OpShapeError::StartWordOutOfRange(w) => {
                write!(f, "FromPrevSp word {w} out of range")
            }
            OpShapeError::RepeatWordOutOfRange(a, g) => {
                write!(f, "repeat_while words ({a},{g}) out of range")
            }
            OpShapeError::OverrideWordOutOfRange(w) => {
                write!(f, "sp_override word {w} out of range")
            }
        }
    }
}

/// One application operation for the serving loop.
#[derive(Clone)]
pub struct Op {
    pub stages: Vec<Stage>,
    /// CPU-side post-processing time (e.g. encrypt+compress), calibrated
    /// by really running it in the app layer.
    pub cpu_post_ns: Ns,
}

impl Op {
    pub fn new(iter: Arc<CompiledIter>, start: GAddr, sp: [i64; SP_WORDS]) -> Self {
        Self { stages: vec![Stage::new(iter, start, sp)], cpu_post_ns: 0 }
    }

    /// Shape validation, run once at admission by every serving loop
    /// (DES `Ev::Issue`, live coordinator `pump`): a malformed op is
    /// reported as one trapped completion instead of panicking mid-DES.
    pub fn validate(&self) -> Result<(), OpShapeError> {
        if self.stages.is_empty() {
            return Err(OpShapeError::NoStages);
        }
        for stage in &self.stages {
            stage.validate()?;
        }
        Ok(())
    }

    /// Static lint over the whole stage chain: every stage's analyzer
    /// diagnostics, plus the chain-level **progress analysis** — a
    /// `repeat_while` stage whose program on no path updates the
    /// continuation pointer or the guard counter, and whose
    /// `sp_overrides` (re-applied every round) don't pin the predicate
    /// off, is a guaranteed-infinite loop under budget:
    /// `NoProgressRepeat`, Deny.
    pub fn lint(&self) -> Vec<Diag> {
        let mut out = Vec::new();
        for (si, stage) in self.stages.iter().enumerate() {
            let analysis = crate::isa::analyze(
                &stage.iter.program,
                stage.iter.sp_inputs,
            );
            if let Some((aw, gw)) = stage.repeat_while {
                if (aw as usize) < SP_WORDS && (gw as usize) < SP_WORDS {
                    let may_update = analysis.sp_dyn_write
                        || analysis.sp_writes & (1 << aw) != 0
                        || analysis.sp_writes & (1 << gw) != 0;
                    let pinned_off = stage.sp_overrides.iter().any(
                        |&(w, v)| {
                            (w == aw && v == 0) || (w == gw && v <= 0)
                        },
                    );
                    if !may_update && !pinned_off {
                        out.push(Diag {
                            pc: 0,
                            severity: Severity::Deny,
                            kind: DiagKind::NoProgressRepeat {
                                stage: si,
                                addr_word: aw,
                                guard_word: gw,
                            },
                            rendered_instr: format!(
                                "repeat_while(sp[{aw}] != 0 && \
                                 sp[{gw}] > 0)"
                            ),
                        });
                    }
                }
            }
            out.extend(analysis.diags);
        }
        out
    }
}

/// Tracks one logical op across its stages + retries (DES-side state).
pub(crate) struct OpRun {
    pub op: Op,
    pub stage_idx: usize,
    pub born: Ns,
    pub cross_ns: Ns,
    pub crossings_total: u32,
    pub iters_total: u32,
    /// Admission index (trace identity; mirrors the live coordinator's
    /// slot `op_index` so DES and live traces align span-for-span).
    pub op_index: u64,
    /// Causal span counter: next span emitted for this op uses this k.
    pub trace_k: u32,
    /// Whether this op was sampled for tracing.
    pub traced: bool,
}

impl OpRun {
    pub fn new(op: Op, born: Ns) -> Self {
        Self {
            op,
            stage_idx: 0,
            born,
            cross_ns: 0,
            crossings_total: 0,
            iters_total: 0,
            op_index: 0,
            trace_k: 0,
            traced: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::IterBuilder;

    fn any_iter() -> Arc<CompiledIter> {
        let mut b = IterBuilder::new();
        let v = b.field(0);
        b.sp_store(1, v);
        b.ret();
        Arc::new(b.finish().unwrap())
    }

    #[test]
    fn resolve_without_repeat_while_is_total() {
        // a repeat continuation on a stage lacking repeat_while used to
        // panic ("repeat without repeat_while"); it must now resolve to
        // the degenerate start 0 that every executor skips gracefully
        let stage = Stage::new(any_iter(), 0x1000, [0i64; SP_WORDS]);
        let cont = [7i64; SP_WORDS];
        let (start, _sp) = stage.resolve(&[0i64; SP_WORDS], Some(cont));
        assert_eq!(start, 0);
        assert!(!stage.wants_repeat(&cont));
    }

    #[test]
    fn out_of_range_words_resolve_degenerately() {
        let mut stage = Stage::new(any_iter(), 0x1000, [0i64; SP_WORDS]);
        stage.start = StartAddr::FromPrevSp(SP_WORDS as u32 + 5);
        stage.repeat_while = Some((SP_WORDS as u32, 2));
        stage.sp_overrides = vec![(SP_WORDS as u32 + 1, 9)];
        let prev = [3i64; SP_WORDS];
        let (start, sp) = stage.resolve(&prev, None);
        assert_eq!(start, 0);
        assert_eq!(sp, [0i64; SP_WORDS]); // OOB override dropped
        assert!(!stage.wants_repeat(&prev));
        let (start, _) = stage.resolve(&prev, Some(prev));
        assert_eq!(start, 0);
    }

    #[test]
    fn validate_flags_malformed_shapes() {
        let ok = Op::new(any_iter(), 0x1000, [0i64; SP_WORDS]);
        assert!(ok.validate().is_ok());

        let empty = Op { stages: vec![], cpu_post_ns: 0 };
        assert_eq!(empty.validate(), Err(OpShapeError::NoStages));

        let mut bad = Op::new(any_iter(), 0x1000, [0i64; SP_WORDS]);
        bad.stages[0].repeat_while = Some((99, 2));
        assert_eq!(
            bad.validate(),
            Err(OpShapeError::RepeatWordOutOfRange(99, 2))
        );

        let mut bad = Op::new(any_iter(), 0x1000, [0i64; SP_WORDS]);
        bad.stages[0].start = StartAddr::FromPrevSp(64);
        assert_eq!(
            bad.validate(),
            Err(OpShapeError::StartWordOutOfRange(64))
        );

        let mut bad = Op::new(any_iter(), 0x1000, [0i64; SP_WORDS]);
        bad.stages[0].sp_overrides = vec![(0, 1), (77, 2)];
        assert_eq!(
            bad.validate(),
            Err(OpShapeError::OverrideWordOutOfRange(77))
        );
    }
}
