//! Application-operation plumbing: stage chains and per-op run state.
//!
//! An application operation is a *stage chain* (paper §6 apps): each
//! stage is one offloaded traversal, with scratchpad carried or
//! overridden between stages, optional bulk payloads on the response,
//! and `repeat_while` continuation rounds for scans.

use std::sync::Arc;

use crate::compiler::CompiledIter;
use crate::isa::SP_WORDS;
use crate::mem::GAddr;
use crate::sim::Ns;

/// Where a stage's start pointer comes from.
#[derive(Debug, Clone, Copy)]
pub enum StartAddr {
    Fixed(GAddr),
    /// Read from the previous stage's final scratchpad word.
    FromPrevSp(u32),
}

/// One traversal stage of an application operation.
#[derive(Clone)]
pub struct Stage {
    pub iter: Arc<CompiledIter>,
    pub start: StartAddr,
    pub sp: [i64; SP_WORDS],
    /// Carry the previous stage's final scratchpad into this stage
    /// (overriding `sp`), with `sp_overrides` applied on top.
    pub carry_sp: bool,
    pub sp_overrides: Vec<(u32, i64)>,
    /// Extra bulk payload on this stage's response (e.g. the 8 KB
    /// WebService object riding back with the reply).
    pub object_read_bytes: u32,
    /// Re-issue this stage while sp[word0] != 0 && sp[word1] > 0
    /// (continuation leaf + remaining counter for scans), re-applying
    /// `sp_overrides` each round.
    pub repeat_while: Option<(u32, u32)>,
}

impl Stage {
    pub fn new(iter: Arc<CompiledIter>, start: GAddr, sp: [i64; SP_WORDS]) -> Self {
        Self {
            iter,
            start: StartAddr::Fixed(start),
            sp,
            carry_sp: false,
            sp_overrides: Vec::new(),
            object_read_bytes: 0,
            repeat_while: None,
        }
    }

    /// Resolve this stage's start pointer and initial scratchpad, given
    /// the previous stage's final scratchpad and an optional repeat
    /// continuation. Shared by the functional path, the DES, and the
    /// baseline trace collectors.
    pub fn resolve(
        &self,
        prev_sp: &[i64; SP_WORDS],
        repeat_from: Option<[i64; SP_WORDS]>,
    ) -> (GAddr, [i64; SP_WORDS]) {
        let start = match (repeat_from, self.start) {
            (Some(sp), _) => {
                let (aw, _) = self.repeat_while.expect("repeat without repeat_while");
                sp[aw as usize] as GAddr
            }
            (None, StartAddr::Fixed(a)) => a,
            (None, StartAddr::FromPrevSp(w)) => prev_sp[w as usize] as GAddr,
        };
        let mut sp = match (repeat_from, self.carry_sp) {
            (Some(s), _) => s,
            (None, true) => *prev_sp,
            (None, false) => self.sp,
        };
        for &(w, v) in &self.sp_overrides {
            sp[w as usize] = v;
        }
        (start, sp)
    }

    /// Whether `sp` asks for another continuation round of this stage.
    pub fn wants_repeat(&self, sp: &[i64; SP_WORDS]) -> bool {
        match self.repeat_while {
            Some((aw, gw)) => sp[aw as usize] != 0 && sp[gw as usize] > 0,
            None => false,
        }
    }
}

/// One application operation for the serving loop.
#[derive(Clone)]
pub struct Op {
    pub stages: Vec<Stage>,
    /// CPU-side post-processing time (e.g. encrypt+compress), calibrated
    /// by really running it in the app layer.
    pub cpu_post_ns: Ns,
}

impl Op {
    pub fn new(iter: Arc<CompiledIter>, start: GAddr, sp: [i64; SP_WORDS]) -> Self {
        Self { stages: vec![Stage::new(iter, start, sp)], cpu_post_ns: 0 }
    }
}

/// Tracks one logical op across its stages + retries (DES-side state).
pub(crate) struct OpRun {
    pub op: Op,
    pub stage_idx: usize,
    pub born: Ns,
    pub cross_ns: Ns,
    pub crossings_total: u32,
    pub iters_total: u32,
}

impl OpRun {
    pub fn new(op: Op, born: Ns) -> Self {
        Self {
            op,
            stage_idx: 0,
            born,
            cross_ns: 0,
            crossings_total: 0,
            iters_total: 0,
        }
    }
}
