//! Rack configuration: topology, allocation, accelerator + dispatch
//! parameters, and the PULSE / PULSE-ACC switch (paper §6 testbed).

use crate::accel::AccelConfig;
use crate::dispatch::DispatchConfig;
use crate::mem::AllocPolicy;

#[derive(Debug, Clone)]
pub struct RackConfig {
    pub nodes: usize,
    pub node_capacity: u64,
    pub granularity: u64,
    pub policy: AllocPolicy,
    pub accel: AccelConfig,
    pub dispatch: DispatchConfig,
    /// Packet loss probability per hop.
    pub loss: f64,
    /// PULSE (true) vs PULSE-ACC (false), §6.2.
    pub in_network_routing: bool,
    pub tcam_entries: usize,
    pub seed: u64,
}

impl Default for RackConfig {
    fn default() -> Self {
        Self {
            nodes: 4,
            node_capacity: 1 << 30,
            granularity: 64 << 20,
            policy: AllocPolicy::RoundRobin,
            accel: AccelConfig::paper_default(),
            dispatch: DispatchConfig::default(),
            loss: 0.0,
            in_network_routing: true,
            tcam_entries: 1 << 16,
            seed: 42,
        }
    }
}

impl RackConfig {
    /// Small rack for unit tests: 32 MB nodes, 1 MB slabs.
    pub fn small(nodes: usize) -> Self {
        Self {
            nodes,
            node_capacity: 32 << 20,
            granularity: 1 << 20,
            ..Default::default()
        }
    }

    /// Standard bench-scale rack (1 GB nodes) at a given granularity.
    pub fn bench(nodes: usize, granularity: u64) -> Self {
        Self { nodes, node_capacity: 1 << 30, granularity, ..Default::default() }
    }

    /// PULSE-ACC variant of this config (§6.2 Fig. 9): crossings return
    /// to the CPU node instead of re-routing at the switch.
    pub fn acc(mut self) -> Self {
        self.in_network_routing = false;
        self
    }
}
