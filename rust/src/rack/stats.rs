//! Serving metrics: the per-run `ServeReport` and its bandwidth
//! utilization helpers (paper Fig. 7/9 latency + throughput panels,
//! Appendix C.1 bandwidth figures).

use crate::sim::Ns;
use crate::util::hist::Histogram;

#[derive(Debug, Default, Clone)]
pub struct ServeReport {
    pub completed: u64,
    pub trapped: u64,
    pub makespan_ns: Ns,
    pub latency: Histogram,
    pub crossings: Histogram,
    pub total_iters: u64,
    pub cross_node_requests: u64,
    /// Virtual-time throughput, operations per second.
    pub tput_ops_per_s: f64,
    /// Bytes moved over the CPU<->switch links (network utilization).
    pub net_bytes: u64,
    /// Bytes served from node DRAM (memory-bandwidth utilization).
    pub mem_bytes: u64,
    pub retransmits: u64,
    /// Time spent on cross-node continuation per affected request
    /// (Fig. 7 darker stack segment).
    pub cross_latency_ns: Histogram,
    /// Wall-clock time of the functional+DES execution (perf metric).
    pub wall_ms: f64,
}

impl ServeReport {
    /// Tail-latency percentiles (p50, p95, p99) in nanoseconds — the
    /// standard triple every backend reports (fed from `util::hist`).
    pub fn latency_percentiles(&self) -> (u64, u64, u64) {
        (self.latency.p50(), self.latency.p95(), self.latency.p99())
    }

    /// Account one op rejected by admission-time shape validation
    /// (`Op::validate`): a trapped completion with a nominal 1 ns
    /// latency sample. One definition shared by the DES issue loop,
    /// the live coordinator, and the baseline trace loop so their trap
    /// counts can never drift apart (the conformance suite compares
    /// them across backends).
    pub fn record_admission_trap(&mut self) {
        self.completed += 1;
        self.trapped += 1;
        self.latency.record(1);
        self.crossings.record(0);
    }

    /// Memory-bandwidth utilization vs the paper's 25 GB/s per node cap.
    pub fn mem_bw_util(&self, nodes: usize) -> f64 {
        if self.makespan_ns == 0 {
            return 0.0;
        }
        let gbps = self.mem_bytes as f64 / self.makespan_ns as f64;
        gbps / (25.0 * nodes as f64) // B/ns == GB/s, cap 25 GB/s/node
    }

    /// Network utilization vs 100 Gbps.
    pub fn net_bw_util(&self) -> f64 {
        if self.makespan_ns == 0 {
            return 0.0;
        }
        (self.net_bytes as f64 / self.makespan_ns as f64) / 12.5
    }

    /// Fold another run's metrics into this cumulative report (the
    /// `TraversalBackend::metrics` accumulation path). Throughput is
    /// re-derived from the summed makespan, which treats runs as
    /// back-to-back — good enough for cumulative accounting.
    pub fn merge(&mut self, other: &ServeReport) {
        self.completed += other.completed;
        self.trapped += other.trapped;
        self.makespan_ns += other.makespan_ns;
        self.latency.merge(&other.latency);
        self.crossings.merge(&other.crossings);
        self.total_iters += other.total_iters;
        self.cross_node_requests += other.cross_node_requests;
        self.net_bytes += other.net_bytes;
        self.mem_bytes += other.mem_bytes;
        self.retransmits += other.retransmits;
        self.cross_latency_ns.merge(&other.cross_latency_ns);
        self.wall_ms += other.wall_ms;
        if self.makespan_ns > 0 {
            self.tput_ops_per_s =
                self.completed as f64 / (self.makespan_ns as f64 / 1e9);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_accumulates_and_rederives_tput() {
        let mut a = ServeReport {
            completed: 100,
            makespan_ns: 1_000_000,
            ..Default::default()
        };
        a.latency.record(1000);
        let mut b = ServeReport {
            completed: 300,
            makespan_ns: 3_000_000,
            ..Default::default()
        };
        b.latency.record(2000);
        a.merge(&b);
        assert_eq!(a.completed, 400);
        assert_eq!(a.makespan_ns, 4_000_000);
        assert_eq!(a.latency.count(), 2);
        // 400 ops over 4 ms of summed makespan = 100k ops/s
        assert!((a.tput_ops_per_s - 1e5).abs() < 1.0, "{}", a.tput_ops_per_s);
    }

    #[test]
    fn percentile_triple_is_ordered() {
        let mut r = ServeReport::default();
        for v in 1..=1000u64 {
            r.latency.record(v * 100);
        }
        let (p50, p95, p99) = r.latency_percentiles();
        assert!(p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
        assert!(p50 > 0);
    }

    #[test]
    fn utilization_is_zero_on_empty_report() {
        let r = ServeReport::default();
        assert_eq!(r.mem_bw_util(4), 0.0);
        assert_eq!(r.net_bw_util(), 0.0);
    }
}
