//! CXL interconnect model (paper §7, Fig. 12).
//!
//! Following Pond [101]: 10–20 ns L3, ~80 ns local DRAM, ~300 ns
//! CXL-attached memory, 256 B access granularity. The experiment
//! replays application traversal profiles on three configurations:
//!
//! * local DRAM (the baseline the slowdown is normalized to);
//! * CXL without PULSE: every pointer hop is a CXL-latency load from
//!   the CPU (plus a 2 GB CPU-side cache absorbing hot lines);
//! * CXL with PULSE: the traversal executes at the memory device behind
//!   a CXL switch carrying PULSE routing logic; the CPU pays one
//!   request/response crossing (conservatively priced at our Ethernet
//!   switch + FPGA latencies, as the paper does).

use crate::sim::{LatencyModel, Ns};

#[derive(Debug, Clone, Copy)]
pub struct CxlParams {
    pub l3_ns: f64,
    pub dram_ns: f64,
    pub cxl_ns: f64,
    /// probability a pointer hop hits the CPU-side cache (2 GB over the
    /// working set; measured per workload with the swap/object cache
    /// sims and passed in here).
    pub cache_hit: f64,
    /// number of memory nodes (4-node setups add switch crossings for
    /// the fraction of hops that change nodes).
    pub nodes: usize,
    /// fraction of hops that cross node boundaries (from traces).
    pub cross_frac: f64,
}

impl Default for CxlParams {
    fn default() -> Self {
        Self {
            l3_ns: 15.0,
            dram_ns: 80.0,
            cxl_ns: 300.0,
            cache_hit: 0.3,
            nodes: 1,
            cross_frac: 0.0,
        }
    }
}

/// Per-op execution times (ns) for a workload profile of `iters`
/// pointer hops + `compute_ns` CPU work.
#[derive(Debug, Clone, Copy)]
pub struct CxlOutcome {
    pub local_ns: f64,
    pub cxl_ns: f64,
    pub cxl_pulse_ns: f64,
}

impl CxlOutcome {
    pub fn slowdown_plain(&self) -> f64 {
        self.cxl_ns / self.local_ns
    }

    pub fn slowdown_pulse(&self) -> f64 {
        self.cxl_pulse_ns / self.local_ns
    }

    /// How much PULSE shrinks the CXL slowdown (paper: 3–5× at 4 nodes,
    /// 4.2–5.2× single-node — see EXPERIMENTS.md for our calibration
    /// notes; the conservative Ethernet-class crossing overhead we keep
    /// per the paper's own methodology compresses the ratio somewhat).
    pub fn pulse_benefit(&self) -> f64 {
        self.slowdown_plain() / self.slowdown_pulse()
    }
}

pub fn evaluate(
    p: &CxlParams,
    iters: f64,
    per_iter_instrs: f64,
    compute_ns: f64,
) -> CxlOutcome {
    let lat = LatencyModel::default();
    // local DRAM: every hop misses through L3 into DRAM
    let hop_local = p.cache_hit * p.l3_ns
        + (1.0 - p.cache_hit) * (p.l3_ns + p.dram_ns);
    let local_ns = iters * hop_local + compute_ns;

    // CXL without PULSE: misses go through L3 to CXL memory
    let hop_cxl = p.cache_hit * p.l3_ns
        + (1.0 - p.cache_hit) * (p.l3_ns + p.cxl_ns);
    let cxl_ns = iters * hop_cxl + compute_ns;

    // CXL with PULSE: one device crossing (conservative Ethernet-class
    // switch + accelerator overhead), then hops run at device-local
    // DRAM speed: the accelerator sits on the memory device, so its
    // aggregated load costs DRAM latency + TCAM + logic, not a CXL
    // fabric crossing. Cross-node hops pay the CXL switch again.
    let crossing: Ns = lat.accel_request_overhead_ns();
    let cached_iters = iters * p.cache_hit; // served before offload
    let dev_iters = iters - cached_iters;
    // Per-hop at the device: DRAM + TCAM; under pipelined load the
    // logic pipeline overlaps with other requests' fetches (η < 1,
    // Fig. 4), leaving ~25% of t_c exposed on the critical path.
    let per_iter_dev = p.dram_ns
        + lat.accel_tcam_ns
        + 0.25 * per_iter_instrs * lat.accel_instr_ns;
    let cross_hops = if p.nodes > 1 { dev_iters * p.cross_frac } else { 0.0 };
    let cxl_pulse_ns = cached_iters * p.l3_ns
        + 2.0 * p.cxl_ns // request/response over the CXL fabric
        + crossing as f64
        + dev_iters * per_iter_dev
        + cross_hops * (p.cxl_ns + lat.switch_pipeline_ns)
        + compute_ns;

    CxlOutcome { local_ns, cxl_ns, cxl_pulse_ns }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cxl_slows_down_traversals() {
        let out = evaluate(&CxlParams::default(), 50.0, 10.0, 1000.0);
        assert!(out.slowdown_plain() > 2.0, "{}", out.slowdown_plain());
    }

    #[test]
    fn pulse_reduces_cxl_slowdown_in_paper_band() {
        // single-node: paper reports 4.2–5.2× benefit
        let p = CxlParams { cache_hit: 0.25, ..Default::default() };
        let out = evaluate(&p, 120.0, 12.0, 500.0);
        let b = out.pulse_benefit();
        assert!((2.0..8.0).contains(&b), "benefit {b}");
        assert!(out.slowdown_pulse() < out.slowdown_plain());
    }

    #[test]
    fn four_node_benefit_smaller_than_single_node() {
        let single = evaluate(
            &CxlParams { nodes: 1, ..Default::default() },
            100.0,
            10.0,
            500.0,
        );
        let four = evaluate(
            &CxlParams {
                nodes: 4,
                cross_frac: 0.25,
                ..Default::default()
            },
            100.0,
            10.0,
            500.0,
        );
        assert!(four.pulse_benefit() < single.pulse_benefit());
    }

    #[test]
    fn short_traversals_gain_less() {
        let p = CxlParams::default();
        let short = evaluate(&p, 3.0, 10.0, 500.0);
        let long = evaluate(&p, 200.0, 10.0, 500.0);
        assert!(long.pulse_benefit() > short.pulse_benefit());
    }
}
