//! Programmable network switch (paper §5, Fig. 6).
//!
//! The switch holds the *coarse* half of PULSE's hierarchical address
//! translation: a range-partitioned map from global VA to owning memory
//! node. Routing logic inspects the `cur_ptr` field of PULSE requests at
//! line rate and forwards each to its owner; responses go back to the
//! originating CPU node. A memory node that discovers a non-local
//! pointer mid-traversal "bounces" the request to the switch, which
//! re-routes it to the correct node (steps 4–6 in Fig. 6) — this is the
//! in-network distributed-traversal mechanism that saves half an RTT +
//! CPU-node software time versus returning to the CPU node (PULSE-ACC).

use std::sync::Arc;

use crate::mem::{GAddr, NodeId, RangeMap};
use crate::net::{MsgKind, TraversalMsg};
use crate::sim::{LatencyModel, Ns};

/// Where the switch forwards a message.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Route {
    /// Forward to a memory node's accelerator.
    MemNode(NodeId),
    /// Deliver to the originating CPU node.
    CpuNode(u16),
    /// `cur_ptr` maps to no node: the pointer is invalid — notify the
    /// CPU node with a trap response (paper §5: "or notify the CPU node
    /// if the pointer is invalid").
    Invalid(u16),
}

#[derive(Debug, Default, Clone, Copy)]
pub struct SwitchStats {
    pub routed_requests: u64,
    pub routed_responses: u64,
    /// Requests re-routed node->node without CPU involvement — the
    /// distributed-traversal fast path.
    pub reroutes: u64,
    pub invalid: u64,
}

#[derive(Debug)]
pub struct Switch {
    /// Shared snapshot of the allocator's coarse map: installing or
    /// republishing it is an Arc pointer swap, never a deep copy.
    map: Arc<RangeMap>,
    pipeline_ns: Ns,
    pub stats: SwitchStats,
}

impl Switch {
    pub fn new(
        map: impl Into<Arc<RangeMap>>,
        lat: &LatencyModel,
    ) -> Self {
        Self {
            map: map.into(),
            pipeline_ns: lat.switch_pipeline_ns as Ns,
            stats: SwitchStats::default(),
        }
    }

    /// Replace the coarse map (allocation growth re-publishes ranges).
    pub fn update_map(&mut self, map: impl Into<Arc<RangeMap>>) {
        self.map = map.into();
    }

    pub fn owner(&self, addr: GAddr) -> Option<NodeId> {
        self.map.lookup(addr)
    }

    /// Route one message. `from_mem_node` marks node->switch bounces so
    /// re-routes can be counted separately from fresh requests.
    pub fn route(
        &mut self,
        msg: &TraversalMsg,
        from_mem_node: bool,
    ) -> Route {
        match msg.kind {
            MsgKind::Response => {
                self.stats.routed_responses += 1;
                Route::CpuNode(msg.id.cpu_node)
            }
            MsgKind::Request => match self.map.lookup(msg.cur_ptr) {
                Some(node) => {
                    self.stats.routed_requests += 1;
                    if from_mem_node {
                        self.stats.reroutes += 1;
                    }
                    Route::MemNode(node)
                }
                None => {
                    self.stats.invalid += 1;
                    Route::Invalid(msg.id.cpu_node)
                }
            },
        }
    }

    /// Time spent in the switch pipeline per message.
    pub fn pipeline_ns(&self) -> Ns {
        self.pipeline_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Status;
    use crate::net::RequestId;

    fn msg(cur_ptr: u64) -> TraversalMsg {
        TraversalMsg::request(
            RequestId { cpu_node: 1, seq: 1 },
            pulse_test_program(),
            cur_ptr,
            [0i64; 32],
            64,
        )
    }

    fn pulse_test_program() -> crate::isa::Program {
        let mut a = crate::isa::Asm::new();
        a.ret();
        a.finish(1).unwrap()
    }

    fn switch_with_two_nodes() -> Switch {
        let mut map = RangeMap::new();
        map.insert(0x1000, 0x1000, 0);
        map.insert(0x2000, 0x1000, 1);
        Switch::new(map, &LatencyModel::default())
    }

    #[test]
    fn routes_requests_by_cur_ptr() {
        let mut s = switch_with_two_nodes();
        assert_eq!(s.route(&msg(0x1800), false), Route::MemNode(0));
        assert_eq!(s.route(&msg(0x2800), false), Route::MemNode(1));
        assert_eq!(s.stats.routed_requests, 2);
        assert_eq!(s.stats.reroutes, 0);
    }

    #[test]
    fn bounced_request_counts_as_reroute() {
        let mut s = switch_with_two_nodes();
        assert_eq!(s.route(&msg(0x2000), true), Route::MemNode(1));
        assert_eq!(s.stats.reroutes, 1);
    }

    #[test]
    fn responses_go_to_cpu_node() {
        let mut s = switch_with_two_nodes();
        let r = msg(0x1000).into_response(Status::Return);
        assert_eq!(s.route(&r, true), Route::CpuNode(1));
        assert_eq!(s.stats.routed_responses, 1);
    }

    #[test]
    fn invalid_pointer_notifies_cpu() {
        let mut s = switch_with_two_nodes();
        assert_eq!(s.route(&msg(0x9000), true), Route::Invalid(1));
        assert_eq!(s.stats.invalid, 1);
    }
}
