//! The PULSE "dispatch-engine compiler" (paper §3 + §4.1).
//!
//! Data-structure library developers express `next()`/`end()` through
//! the structured `IterBuilder` DSL; lowering performs the paper's
//! analyses:
//!
//! * **Load aggregation** — every `field(k)` access is tracked and the
//!   per-iteration aggregated LOAD size (`load_words`, ≤ 256 B) is
//!   inferred, so `cur_ptr->key`, `->value`, `->next` cost one fetch.
//! * **Bounded computation** — only structured *forward* control flow is
//!   expressible (`if_*` blocks, `for_fixed` unrolled loops); the
//!   verifier re-checks the invariants.
//! * **Offloadability** — `CostModel::offloadable` implements the
//!   `t_c ≤ η·t_d` test; non-offloadable code falls back to CPU-side
//!   execution with remote reads (`dispatch::Engine`).
//!
//! This plays the role of the paper's LLVM (Sparc backend) passes; see
//! DESIGN.md §2 for the substitution note.

pub mod builder;

pub use builder::{IterBuilder, Val};

use std::sync::Arc;

use crate::isa::{CostModel, Diag, Program, VerifyError, SP_INPUTS_ALL};

/// Why `IterBuilder::finish` rejected a program: either the structural
/// verifier or the abstract-interpretation analyzer (`isa::analyze`)
/// said no. Compile-time is the first of the three enforcement layers
/// (compile → wire admission → `pulse lint`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CompileError {
    Verify(VerifyError),
    /// Deny-severity analyzer diagnostics (certain trap / no-progress).
    Deny(Vec<Diag>),
}

impl std::fmt::Display for CompileError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CompileError::Verify(e) => write!(f, "verify failed: {e}"),
            CompileError::Deny(diags) => {
                write!(f, "analysis denied the program:")?;
                for d in diags {
                    write!(f, " [{d}]")?;
                }
                Ok(())
            }
        }
    }
}

impl std::error::Error for CompileError {}

/// A compiled iterator: the offloadable program plus its cost estimate.
///
/// The program is `Arc`-shared from here on out: every `TraversalMsg`
/// dispatched from this iterator bumps a refcount instead of deep-
/// copying the instruction stream (compile once, share everywhere —
/// the in-process analogue of the wire tier's register-once protocol).
#[derive(Debug, Clone)]
pub struct CompiledIter {
    pub program: Arc<Program>,
    pub t_c_ns: f64,
    pub t_d_ns: f64,
    /// Host-seeded scratchpad words (the analyzer's `sp_inputs` mask).
    /// Builder-made iterators carry the mask their scenario declared;
    /// `new` defaults to `SP_INPUTS_ALL`, the right admission posture
    /// for wire-registered programs (the REQUEST frame ships the full
    /// 256 B scratchpad, so any word may legitimately be read).
    pub sp_inputs: u32,
}

impl CompiledIter {
    pub fn new(program: Program) -> Self {
        let cost = CostModel::default().cost(&program);
        Self {
            program: Arc::new(program),
            t_c_ns: cost.t_c_ns,
            t_d_ns: cost.t_d_ns,
            sp_inputs: SP_INPUTS_ALL,
        }
    }

    /// The paper's offload predicate (§4.1).
    pub fn offloadable(&self, eta: f64) -> bool {
        self.t_c_ns <= eta * self.t_d_ns
    }

    pub fn ratio(&self) -> f64 {
        self.t_c_ns / self.t_d_ns
    }
}
