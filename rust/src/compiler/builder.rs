//! Structured iterator builder: the DSL surface of the PULSE compiler.

use super::{CompileError, CompiledIter};
use crate::isa::{analyze, Asm, Program, DATA_WORDS, NREG, SP_WORDS};

/// A value handle — a register holding a computed value. Copy-type and
/// immutable-by-convention (re-assignments produce new handles), which
/// keeps lowering trivially SSA-ish.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Val(u8);

/// A forward block label (see `IterBuilder::make_label`).
#[derive(Debug, Clone, Copy)]
pub struct BlockLabel(crate::isa::asm::Label);

/// Structured builder for one iterator body (`next()` + `end()` fused,
/// as the accelerator executes them: compute, then either advance via
/// `advance()` or finish via `ret()`).
pub struct IterBuilder {
    asm: Asm,
    next_reg: u8,
    max_field: i64,
    writes: bool,
    /// Host-seeded scratchpad words (analyzer `sp_inputs`): reads of
    /// declared words are not `ReadBeforeWrite`.
    sp_inputs: u32,
}

impl Default for IterBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl IterBuilder {
    pub fn new() -> Self {
        Self {
            asm: Asm::new(),
            next_reg: 1,
            max_field: 0,
            writes: false,
            sp_inputs: 0,
        }
    }

    fn alloc(&mut self) -> Val {
        assert!(
            (self.next_reg as usize) < NREG,
            "iterator body needs more than {} temporaries",
            NREG - 2
        );
        let v = Val(self.next_reg);
        self.next_reg += 1;
        v
    }

    /// The current pointer (r0).
    pub fn cur_ptr(&mut self) -> Val {
        let v = self.alloc();
        self.asm.mov(v.0, 0);
        v
    }

    pub fn imm(&mut self, k: i64) -> Val {
        let v = self.alloc();
        self.asm.movi(v.0, k);
        v
    }

    /// `data[word]` — a field of the node at `cur_ptr` (word = byte
    /// offset / 8). Tracked for load aggregation.
    pub fn field(&mut self, word: u32) -> Val {
        assert!((word as usize) < DATA_WORDS);
        self.max_field = self.max_field.max(word as i64);
        let v = self.alloc();
        self.asm.ldd(v.0, word as i64);
        v
    }

    /// `data[idx + base]` with a runtime index (e.g. B-Tree key arrays).
    /// `span_hint` is the largest word the access may reach — required
    /// for load aggregation.
    pub fn field_dyn(&mut self, idx: Val, base: u32, span_hint: u32) -> Val {
        assert!((span_hint as usize) < DATA_WORDS);
        self.max_field = self.max_field.max(span_hint as i64);
        let v = self.alloc();
        self.asm.ldx(v.0, idx.0, base as i64);
        v
    }

    /// Store to a node field (marks the traversal as mutating).
    pub fn store_field(&mut self, word: u32, v: Val) {
        assert!((word as usize) < DATA_WORDS);
        self.max_field = self.max_field.max(word as i64);
        self.writes = true;
        self.asm.std_(v.0, word as i64);
    }

    pub fn store_field_dyn(&mut self, idx: Val, base: u32, span_hint: u32, v: Val) {
        assert!((span_hint as usize) < DATA_WORDS);
        self.max_field = self.max_field.max(span_hint as i64);
        self.writes = true;
        self.asm.stx(v.0, idx.0, base as i64);
    }

    /// Declare a scratchpad word as host-seeded: the caller's `init()`
    /// fills it before the first iteration, so the analyzer's
    /// read-before-write pass treats it as initialized.
    pub fn declare_sp_input(&mut self, word: u32) {
        assert!((word as usize) < SP_WORDS);
        self.sp_inputs |= 1 << word;
    }

    /// Declare the half-open range `lo..hi` as host-seeded (bulk draw
    /// buffers like the graph walk's `sp[8..]`).
    pub fn declare_sp_input_range(&mut self, lo: u32, hi: u32) {
        assert!(lo <= hi && (hi as usize) <= SP_WORDS);
        for w in lo..hi {
            self.declare_sp_input(w);
        }
    }

    /// Declare + read a host-seeded scratchpad word in one step (the
    /// idiomatic first read of a traversal argument).
    pub fn sp_input(&mut self, word: u32) -> Val {
        self.declare_sp_input(word);
        self.sp(word)
    }

    /// Scratchpad read / write (the iterator's persistent state, §3).
    pub fn sp(&mut self, word: u32) -> Val {
        assert!((word as usize) < SP_WORDS);
        let v = self.alloc();
        self.asm.spl(v.0, word as i64);
        v
    }

    pub fn sp_store(&mut self, word: u32, v: Val) {
        assert!((word as usize) < SP_WORDS);
        self.asm.sps(v.0, word as i64);
    }

    pub fn sp_dyn(&mut self, idx: Val, base: u32) -> Val {
        let v = self.alloc();
        self.asm.splx(v.0, idx.0, base as i64);
        v
    }

    pub fn sp_store_dyn(&mut self, idx: Val, base: u32, v: Val) {
        self.asm.spsx(v.0, idx.0, base as i64);
    }

    // ---- arithmetic ------------------------------------------------------
    pub fn add(&mut self, a: Val, b: Val) -> Val {
        let v = self.alloc();
        self.asm.add(v.0, a.0, b.0);
        v
    }

    pub fn sub(&mut self, a: Val, b: Val) -> Val {
        let v = self.alloc();
        self.asm.sub(v.0, a.0, b.0);
        v
    }

    pub fn mul(&mut self, a: Val, b: Val) -> Val {
        let v = self.alloc();
        self.asm.mul(v.0, a.0, b.0);
        v
    }

    pub fn div(&mut self, a: Val, b: Val) -> Val {
        let v = self.alloc();
        self.asm.div(v.0, a.0, b.0);
        v
    }

    pub fn and(&mut self, a: Val, b: Val) -> Val {
        let v = self.alloc();
        self.asm.and(v.0, a.0, b.0);
        v
    }

    /// `a mod b` for non-negative `a` and positive `b` (lowered as
    /// `a - (a / b) * b`; DIV traps on b == 0 like every engine). The
    /// data-dependent dispatch primitive of the fan-out traversals
    /// (graph k-hop neighbor selection).
    pub fn modu(&mut self, a: Val, b: Val) -> Val {
        let q = self.div(a, b);
        let qb = self.mul(q, b);
        self.sub(a, qb)
    }

    pub fn addi(&mut self, a: Val, k: i64) -> Val {
        let v = self.alloc();
        self.asm.addi(v.0, a.0, k);
        v
    }

    pub fn shl(&mut self, a: Val, k: i64) -> Val {
        let v = self.alloc();
        self.asm.shl(v.0, a.0, k);
        v
    }

    pub fn shr(&mut self, a: Val, k: i64) -> Val {
        let v = self.alloc();
        self.asm.shr(v.0, a.0, k);
        v
    }

    /// Overwrite an existing handle (for loop-carried updates inside
    /// `for_fixed`; use sparingly).
    pub fn assign(&mut self, dst: Val, src: Val) {
        self.asm.mov(dst.0, src.0);
    }

    /// In-place `dst += k` (single ADDI; loop counters in unrolled
    /// scans — saves a temp + a MOV over `addi` + `assign`).
    pub fn add_assign(&mut self, dst: Val, k: i64) {
        self.asm.addi(dst.0, dst.0, k);
    }

    /// In-place `dst += src` (single 3-reg ADD).
    pub fn add_to(&mut self, dst: Val, src: Val) {
        self.asm.add(dst.0, dst.0, src.0);
    }

    pub fn assign_imm(&mut self, dst: Val, k: i64) {
        self.asm.movi(dst.0, k);
    }

    // ---- structured control (forward-only by construction) ---------------
    fn if_impl(
        &mut self,
        invert_jump: impl FnOnce(&mut Asm, crate::isa::asm::Label),
        then: impl FnOnce(&mut Self),
    ) {
        let skip = self.asm.label();
        invert_jump(&mut self.asm, skip);
        then(self);
        self.asm.bind(skip);
    }

    pub fn if_eq(&mut self, a: Val, b: Val, then: impl FnOnce(&mut Self)) {
        self.if_impl(|asm, l| { asm.jne(a.0, b.0, l); }, then);
    }

    pub fn if_ne(&mut self, a: Val, b: Val, then: impl FnOnce(&mut Self)) {
        self.if_impl(|asm, l| { asm.jeq(a.0, b.0, l); }, then);
    }

    pub fn if_lt(&mut self, a: Val, b: Val, then: impl FnOnce(&mut Self)) {
        self.if_impl(|asm, l| { asm.jge(a.0, b.0, l); }, then);
    }

    pub fn if_le(&mut self, a: Val, b: Val, then: impl FnOnce(&mut Self)) {
        self.if_impl(|asm, l| { asm.jgt(a.0, b.0, l); }, then);
    }

    pub fn if_gt(&mut self, a: Val, b: Val, then: impl FnOnce(&mut Self)) {
        self.if_impl(|asm, l| { asm.jle(a.0, b.0, l); }, then);
    }

    pub fn if_ge(&mut self, a: Val, b: Val, then: impl FnOnce(&mut Self)) {
        self.if_impl(|asm, l| { asm.jlt(a.0, b.0, l); }, then);
    }

    /// if/else; both arms must be terminal-free straight-line blocks or
    /// end with ret()/advance() in *both* arms.
    pub fn if_else_lt(
        &mut self,
        a: Val,
        b: Val,
        then: impl FnOnce(&mut Self),
        els: impl FnOnce(&mut Self),
    ) {
        let else_l = self.asm.label();
        let end_l = self.asm.label();
        self.asm.jge(a.0, b.0, else_l);
        then(self);
        self.asm.jmp(end_l);
        self.asm.bind(else_l);
        els(self);
        self.asm.bind(end_l);
    }

    // ---- shared exit blocks (forward-only, one bind per label) -----------
    /// A forward label for shared exit blocks in unrolled scans; jump to
    /// it from many sites with `br_*`, bind it once at the end.
    pub fn make_label(&mut self) -> BlockLabel {
        BlockLabel(self.asm.label())
    }

    pub fn bind_label(&mut self, l: BlockLabel) {
        self.asm.bind(l.0);
    }

    pub fn br_gt(&mut self, a: Val, b: Val, l: &BlockLabel) {
        self.asm.jgt(a.0, b.0, l.0);
    }

    pub fn br_ge(&mut self, a: Val, b: Val, l: &BlockLabel) {
        self.asm.jge(a.0, b.0, l.0);
    }

    pub fn br_eq(&mut self, a: Val, b: Val, l: &BlockLabel) {
        self.asm.jeq(a.0, b.0, l.0);
    }

    pub fn br_always(&mut self, l: &BlockLabel) {
        self.asm.jmp(l.0);
    }

    /// Bounded loop, unrolled at compile time (the paper's "loops that
    /// can be unrolled to a fixed number of instructions", §3). The body
    /// receives the iteration constant.
    pub fn for_fixed(&mut self, n: usize, mut body: impl FnMut(&mut Self, usize)) {
        for k in 0..n {
            body(self, k);
        }
    }

    /// Reserve a mutable temporary initialized to an immediate —
    /// loop-carried variables for `for_fixed`.
    pub fn var(&mut self, init: i64) -> Val {
        self.imm(init)
    }

    /// Register-pressure control for unrolled loops: snapshot the
    /// allocator, then release everything allocated after the snapshot
    /// (handles created in-between must not be used afterwards).
    pub fn temp_mark(&self) -> u8 {
        self.next_reg
    }

    pub fn temp_release(&mut self, mark: u8) {
        debug_assert!(mark <= self.next_reg);
        self.next_reg = mark;
    }

    // ---- terminals --------------------------------------------------------
    /// End this iteration, continuing at `next` (emits `r0 = next; NEXT`).
    pub fn advance(&mut self, next: Val) {
        self.asm.mov(0, next.0);
        self.asm.next();
    }

    /// End the traversal; the scratchpad is returned to the caller.
    pub fn ret(&mut self) {
        self.asm.ret();
    }

    pub fn trap(&mut self) {
        self.asm.trap();
    }

    /// Lower + verify + analyze. `load_words` is inferred from the
    /// aggregated field accesses; Deny-severity analyzer diagnostics
    /// (certain trap on a reachable path) fail the build fast, before
    /// the program can reach any executor.
    pub fn finish(self) -> Result<CompiledIter, CompileError> {
        let load_words = (self.max_field + 1).clamp(1, DATA_WORDS as i64) as u8;
        let program: Program =
            self.asm.finish(load_words).map_err(CompileError::Verify)?;
        let analysis = analyze(&program, self.sp_inputs);
        if analysis.has_deny() {
            return Err(CompileError::Deny(analysis.diags));
        }
        let mut it = CompiledIter::new(program);
        it.sp_inputs = self.sp_inputs;
        Ok(it)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interp::{logic_pass, Workspace};
    use crate::isa::Status;

    /// The canonical hash-bucket chain walk (paper Listing 3) written in
    /// the DSL.
    fn build_list_find() -> CompiledIter {
        let mut b = IterBuilder::new();
        let key = b.sp(0);
        let nkey = b.field(0);
        b.if_eq(key, nkey, |b| {
            let val = b.field(1);
            b.sp_store(1, val);
            b.ret();
        });
        let next = b.field(2);
        let zero = b.imm(0);
        b.if_eq(next, zero, |b| {
            let nf = b.imm(i64::MAX);
            b.sp_store(2, nf);
            b.ret();
        });
        b.advance(next);
        b.finish().unwrap()
    }

    #[test]
    fn load_aggregation_infers_window() {
        let it = build_list_find();
        assert_eq!(it.program.load_words, 3); // fields 0..=2
        assert!(!it.program.writes_data);
    }

    #[test]
    fn list_find_lowering_executes_correctly() {
        let it = build_list_find();
        // found case
        let mut w = Workspace::new();
        w.sp[0] = 5;
        w.data[0] = 5;
        w.data[1] = 99;
        let r = logic_pass(&it.program, &mut w);
        assert_eq!(r.status, Status::Return);
        assert_eq!(w.sp[1], 99);
        // walk case
        let mut w = Workspace::new();
        w.sp[0] = 5;
        w.data[0] = 4;
        w.data[2] = 0xBEEF;
        let r = logic_pass(&it.program, &mut w);
        assert_eq!(r.status, Status::NextIter);
        assert_eq!(w.cur_ptr(), 0xBEEF);
        // not-found case
        let mut w = Workspace::new();
        w.sp[0] = 5;
        w.data[0] = 4;
        w.data[2] = 0;
        let r = logic_pass(&it.program, &mut w);
        assert_eq!(r.status, Status::Return);
        assert_eq!(w.sp[2], i64::MAX);
    }

    #[test]
    fn offloadability_matches_cost_model() {
        let it = build_list_find();
        assert!(it.offloadable(0.75));
        assert!(it.ratio() < 0.5);
        // a compute-monster body is rejected
        let mut b = IterBuilder::new();
        let x = b.imm(3);
        let mark = b.temp_mark();
        for _ in 0..11 {
            let y = b.mul(x, x);
            let z = b.add(y, x);
            b.assign(x, z);
            b.temp_release(mark); // reuse temps across unrolled steps
        }
        b.sp_store(0, x);
        b.ret();
        let it = b.finish().unwrap();
        assert!(!it.offloadable(0.75), "ratio {}", it.ratio());
    }

    #[test]
    fn if_else_both_arms() {
        let mut b = IterBuilder::new();
        let x = b.sp(0);
        let y = b.sp(1);
        b.if_else_lt(
            x,
            y,
            |b| {
                let m = b.imm(111);
                b.sp_store(2, m);
            },
            |b| {
                let m = b.imm(222);
                b.sp_store(2, m);
            },
        );
        b.ret();
        let it = b.finish().unwrap();
        for (x, y, want) in [(1, 5, 111), (5, 1, 222), (3, 3, 222)] {
            let mut w = Workspace::new();
            w.sp[0] = x;
            w.sp[1] = y;
            logic_pass(&it.program, &mut w);
            assert_eq!(w.sp[2], want, "x={x} y={y}");
        }
    }

    #[test]
    fn for_fixed_unrolls_btree_scan() {
        // find first of 4 keys >= needle; sp[1] = index.
        let mut b = IterBuilder::new();
        let needle = b.sp(0);
        let idx = b.var(4); // sentinel: "none"
        let mark = b.temp_mark();
        b.for_fixed(4, |b, k| {
            let key = b.field(4 + k as u32);
            let kk = b.imm(k as i64);
            // only record the first hit: idx == 4 && key >= needle
            let four = b.imm(4);
            b.if_eq(idx, four, |b| {
                b.if_ge(key, needle, |b| {
                    b.assign(idx, kk);
                });
            });
            b.temp_release(mark); // reuse unrolled temps
        });
        b.sp_store(1, idx);
        b.ret();
        let it = b.finish().unwrap();
        assert_eq!(it.program.load_words, 8);

        let mut w = Workspace::new();
        w.sp[0] = 25;
        w.data[4..8].copy_from_slice(&[10, 20, 30, 40]);
        let r = logic_pass(&it.program, &mut w);
        assert_eq!(r.status, Status::Return);
        assert_eq!(w.sp[1], 2);
    }

    #[test]
    fn store_marks_writes() {
        let mut b = IterBuilder::new();
        let v = b.imm(1);
        b.store_field(0, v);
        b.ret();
        let it = b.finish().unwrap();
        assert!(it.program.writes_data);
    }

    #[test]
    fn finish_denies_certain_traps() {
        // a provable div-by-zero fails the build, not the executor
        let mut b = IterBuilder::new();
        let x = b.imm(5);
        let z = b.imm(0);
        let q = b.div(x, z);
        b.sp_store(1, q);
        b.ret();
        match b.finish() {
            Err(super::CompileError::Deny(diags)) => {
                assert!(!diags.is_empty());
                assert_eq!(diags[0].kind.name(), "PossibleDivByZero");
            }
            other => panic!("expected Deny, got {other:?}"),
        }
    }

    #[test]
    fn sp_input_declarations_reach_the_compiled_iter() {
        let mut b = IterBuilder::new();
        let k = b.sp_input(0);
        b.declare_sp_input_range(8, 10);
        b.sp_store(1, k);
        b.ret();
        let it = b.finish().unwrap();
        assert_eq!(it.sp_inputs, (1 << 0) | (1 << 8) | (1 << 9));
    }
}
