//! Object cache of node images at the CPU node (AIFM-style library
//! cache, paper §2.3 / Appendix C.2).
//!
//! Clock (second-chance) eviction: O(1) insert/get at the 2 GB scales
//! the paper evaluates. Keys are node addresses; values are the node's
//! aggregated-load image (≤ 32 words).

use crate::mem::GAddr;
use std::collections::HashMap;

#[derive(Debug)]
struct Slot {
    addr: GAddr,
    image: Vec<i64>,
    referenced: bool,
}

#[derive(Debug)]
pub struct ObjectCache {
    capacity_bytes: u64,
    used_bytes: u64,
    slots: Vec<Option<Slot>>,
    index: HashMap<GAddr, usize>,
    hand: usize,
    free: Vec<usize>,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

/// Approximate per-entry overhead (hash entry + slot bookkeeping).
const ENTRY_OVERHEAD: u64 = 64;

impl ObjectCache {
    pub fn new(capacity_bytes: u64) -> Self {
        Self {
            capacity_bytes,
            used_bytes: 0,
            slots: Vec::new(),
            index: HashMap::new(),
            hand: 0,
            free: Vec::new(),
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    pub fn capacity(&self) -> u64 {
        self.capacity_bytes
    }

    pub fn len(&self) -> usize {
        self.index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    fn entry_size(image_words: usize) -> u64 {
        ENTRY_OVERHEAD + (image_words * 8) as u64
    }

    pub fn get(&mut self, addr: GAddr) -> Option<&[i64]> {
        match self.index.get(&addr) {
            Some(&i) => {
                self.hits += 1;
                let slot = self.slots[i].as_mut().unwrap();
                slot.referenced = true;
                Some(&self.slots[i].as_ref().unwrap().image)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    pub fn contains(&self, addr: GAddr) -> bool {
        self.index.contains_key(&addr)
    }

    pub fn insert(&mut self, addr: GAddr, image: &[i64]) {
        if self.capacity_bytes == 0 {
            return;
        }
        let size = Self::entry_size(image.len());
        if size > self.capacity_bytes {
            return;
        }
        if let Some(&i) = self.index.get(&addr) {
            // update in place
            let slot = self.slots[i].as_mut().unwrap();
            self.used_bytes -= Self::entry_size(slot.image.len());
            slot.image = image.to_vec();
            slot.referenced = true;
            self.used_bytes += size;
            self.evict_to_fit();
            return;
        }
        let idx = if let Some(i) = self.free.pop() {
            i
        } else {
            self.slots.push(None);
            self.slots.len() - 1
        };
        self.slots[idx] = Some(Slot {
            addr,
            image: image.to_vec(),
            referenced: true,
        });
        self.index.insert(addr, idx);
        self.used_bytes += size;
        self.evict_to_fit();
    }

    pub fn invalidate(&mut self, addr: GAddr) {
        if let Some(i) = self.index.remove(&addr) {
            if let Some(slot) = self.slots[i].take() {
                self.used_bytes -= Self::entry_size(slot.image.len());
            }
            self.free.push(i);
        }
    }

    fn evict_to_fit(&mut self) {
        let mut spins = 0usize;
        while self.used_bytes > self.capacity_bytes
            && !self.slots.is_empty()
        {
            self.hand = (self.hand + 1) % self.slots.len();
            let Some(slot) = self.slots[self.hand].as_mut() else {
                spins += 1;
                if spins > 2 * self.slots.len() + 2 {
                    break;
                }
                continue;
            };
            if slot.referenced {
                slot.referenced = false;
                spins += 1;
                if spins > 2 * self.slots.len() + 2 {
                    // all referenced: force-evict current
                    let s = self.slots[self.hand].take().unwrap();
                    self.index.remove(&s.addr);
                    self.used_bytes -= Self::entry_size(s.image.len());
                    self.free.push(self.hand);
                    self.evictions += 1;
                    spins = 0;
                }
                continue;
            }
            let s = self.slots[self.hand].take().unwrap();
            self.index.remove(&s.addr);
            self.used_bytes -= Self::entry_size(s.image.len());
            self.free.push(self.hand);
            self.evictions += 1;
            spins = 0;
        }
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_round_trip() {
        let mut c = ObjectCache::new(1 << 16);
        c.insert(0x1000, &[1, 2, 3]);
        assert_eq!(c.get(0x1000), Some(&[1i64, 2, 3][..]));
        assert_eq!(c.get(0x2000), None);
        assert_eq!(c.hits, 1);
        assert_eq!(c.misses, 1);
    }

    #[test]
    fn update_replaces_image() {
        let mut c = ObjectCache::new(1 << 16);
        c.insert(0x1000, &[1]);
        c.insert(0x1000, &[9, 9]);
        assert_eq!(c.get(0x1000), Some(&[9i64, 9][..]));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn eviction_respects_capacity() {
        // room for ~4 entries of 3 words (64 + 24 = 88 bytes each)
        let mut c = ObjectCache::new(360);
        for i in 0..16u64 {
            c.insert(0x1000 + i * 0x100, &[i as i64, 0, 0]);
        }
        assert!(c.len() <= 4, "len {}", c.len());
        assert!(c.evictions >= 12);
    }

    #[test]
    fn clock_favors_hot_entries() {
        let mut c = ObjectCache::new(500); // ~6 entries
        c.insert(0x1000, &[42]);
        // touch the hot entry before every insert of a cold one; clock
        // (second chance) should keep it resident most of the time.
        let mut hot_hits = 0;
        for j in 0..200u64 {
            if c.get(0x1000).is_some() {
                hot_hits += 1;
            } else {
                c.insert(0x1000, &[42]); // refill after unlucky eviction
            }
            c.insert(0x9000 + j * 0x100, &[j as i64]);
        }
        assert!(hot_hits > 120, "hot entry hit only {hot_hits}/200");
    }

    #[test]
    fn zero_capacity_disables() {
        let mut c = ObjectCache::new(0);
        c.insert(0x1000, &[1]);
        assert!(c.is_empty());
        assert_eq!(c.get(0x1000), None);
    }

    #[test]
    fn invalidate_frees_space() {
        let mut c = ObjectCache::new(1 << 16);
        c.insert(0x1000, &[1, 2, 3, 4]);
        c.invalidate(0x1000);
        assert!(!c.contains(0x1000));
        assert_eq!(c.used_bytes, 0);
    }
}
