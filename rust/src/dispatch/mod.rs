//! CPU-node dispatch engine (paper §4.1).
//!
//! Responsibilities:
//! * offload decision per compiled iterator (`t_c ≤ η·t_d`);
//! * request construction (request id = CPU node id + local counter);
//! * timeout-based retransmission over the lossy transport;
//! * continuation of yielded traversals (max-iteration bound, §3);
//! * the AIFM-style transparent library cache (§2.3 "adapts the caching
//!   scheme from prior work [127]"): hot node images cached at the CPU
//!   node let the engine run iterations locally and offload only the
//!   cold remainder (Appendix C.2 access-pattern study).

// Hot-path modules keep clones honest: a clone the borrow checker
// would let us drop is a bug here, not a style nit.
#![deny(clippy::redundant_clone)]

pub mod cache;

pub use cache::ObjectCache;

use crate::compiler::CompiledIter;
use crate::interp::{logic_pass, Workspace};
use crate::isa::{CostModel, Status, SP_WORDS};
use crate::net::{RequestId, TraversalMsg};
use crate::sim::Ns;
use std::collections::HashMap;

#[derive(Debug, Clone, Copy)]
pub struct DispatchConfig {
    /// Accelerator η used for the offload decision.
    pub eta: f64,
    /// Per-request iteration budget before yield (§3).
    pub max_iters: u32,
    /// Retransmit timeout.
    pub timeout_ns: Ns,
    /// Library-cache capacity in bytes (0 = disabled).
    pub cache_bytes: u64,
}

impl Default for DispatchConfig {
    fn default() -> Self {
        Self {
            eta: crate::isa::DEFAULT_ETA,
            max_iters: 4096,
            timeout_ns: 2_000_000, // 2 ms
            cache_bytes: 0,
        }
    }
}

#[derive(Debug, Default, Clone, Copy)]
pub struct DispatchStats {
    pub offloaded: u64,
    pub local_fallback: u64,
    pub retransmits: u64,
    pub continuations: u64,
    pub cache_hit_iters: u64,
    pub cache_miss_iters: u64,
}

/// What to do with a submitted traversal.
#[derive(Debug)]
pub enum Disposition {
    /// Ship to the accelerator via the switch.
    Offload(TraversalMsg),
    /// Completed entirely from the CPU-side cache.
    CompletedLocally {
        /// Terminal status of the cached walk: `Return`, or `Trap`
        /// when the program faulted mid-cache (a trap is terminal and
        /// honest everywhere — a cached walk is no exception).
        status: Status,
        sp: [i64; SP_WORDS],
        iters: u32,
    },
    /// Iterator not offloadable (t_c > η·t_d): the caller must run it on
    /// the CPU with remote reads (one round trip per pointer hop).
    RunOnCpu,
}

#[derive(Debug)]
struct Pending {
    msg: TraversalMsg,
    sent_at: Ns,
}

#[derive(Debug)]
pub struct DispatchEngine {
    pub cpu_node: u16,
    cfg: DispatchConfig,
    cost: CostModel,
    seq: u64,
    pending: HashMap<RequestId, Pending>,
    pub cache: ObjectCache,
    pub stats: DispatchStats,
    ws: Workspace,
}

impl DispatchEngine {
    pub fn new(cpu_node: u16, cfg: DispatchConfig) -> Self {
        Self {
            cpu_node,
            cost: CostModel::default(),
            seq: 0,
            pending: HashMap::new(),
            cache: ObjectCache::new(cfg.cache_bytes),
            cfg,
            stats: DispatchStats::default(),
            ws: Workspace::new(),
        }
    }

    pub fn cfg(&self) -> DispatchConfig {
        self.cfg
    }

    /// Budget added to a yielded traversal per continuation round.
    /// `on_response` is the *only* re-grant site, and a `Boost` trace
    /// span records the resulting total (`msg.max_iters` after the
    /// grant) — so a traced op's boost sequence is always
    /// `initial + k * grant_step()` on every backend.
    pub fn grant_step(&self) -> u32 {
        self.cfg.max_iters
    }

    /// Submit a traversal. Runs the offload test, then walks the cached
    /// prefix locally; offloads the remainder (or completes locally),
    /// parking a retransmission slot the DES clears via `on_response`.
    pub fn submit(
        &mut self,
        iter: &CompiledIter,
        start: u64,
        sp: [i64; SP_WORDS],
        now: Ns,
    ) -> Disposition {
        self.submit_inner(iter, start, sp, now, 0, true)
    }

    /// Budgeted, non-parking submission for callers that drive the
    /// offloaded message to completion synchronously themselves
    /// (`Rack::traverse_budgeted`, i.e. the in-process functional
    /// path): no retransmission slot is parked — there is nothing to
    /// retransmit and no response event that would ever clear it —
    /// and the budget (0 = the configured grant) applies from the
    /// first iteration, including the library-cache prefix walk, so a
    /// per-request budget cannot be bypassed by cached execution.
    /// (The wire tier's inline executor bypasses the dispatch engine
    /// entirely via `Rack::traverse_offloaded`.)
    pub fn submit_detached(
        &mut self,
        iter: &CompiledIter,
        start: u64,
        sp: [i64; SP_WORDS],
        budget: u32,
    ) -> Disposition {
        self.submit_inner(iter, start, sp, 0, budget, false)
    }

    fn submit_inner(
        &mut self,
        iter: &CompiledIter,
        start: u64,
        sp: [i64; SP_WORDS],
        now: Ns,
        budget: u32,
        park: bool,
    ) -> Disposition {
        if !self.cost.offloadable(&iter.program, self.cfg.eta) {
            self.stats.local_fallback += 1;
            return Disposition::RunOnCpu;
        }
        let id = RequestId { cpu_node: self.cpu_node, seq: self.seq };
        self.seq += 1;
        let mut msg = TraversalMsg::request(
            id,
            std::sync::Arc::clone(&iter.program),
            start,
            sp,
            if budget != 0 { budget } else { self.cfg.max_iters },
        );

        // Library cache: execute iterations locally while node images
        // are cached.
        if self.cache.capacity() > 0 {
            if let Some(status) = self.walk_cached(&mut msg) {
                return Disposition::CompletedLocally {
                    status,
                    sp: msg.sp,
                    iters: msg.iters_done,
                };
            }
        }

        self.stats.offloaded += 1;
        if park {
            self.pending
                .insert(id, Pending { msg: msg.clone(), sent_at: now });
        }
        Disposition::Offload(msg)
    }

    /// Walk iterations from the cache; returns Some(status) if the whole
    /// traversal completed locally, None when it must be offloaded from
    /// the current `msg` state.
    fn walk_cached(&mut self, msg: &mut TraversalMsg) -> Option<Status> {
        let words = msg.program.load_words as usize;
        loop {
            if msg.iters_done >= msg.max_iters {
                // budget spent mid-walk: this is a yield, not a
                // completion — offload the continuation so the normal
                // grant/boost machinery decides (reporting Return here
                // would hand back a silently truncated scratchpad;
                // the accelerator yields immediately on arrival since
                // iters_done >= max_iters)
                return None;
            }
            let Some(image) = self.cache.get(msg.cur_ptr) else {
                if msg.iters_done > 0 {
                    self.stats.cache_miss_iters += 1;
                }
                return None;
            };
            // Mutating traversals cannot run out of the read cache.
            if msg.program.writes_data {
                return None;
            }
            self.stats.cache_hit_iters += 1;
            self.ws.sp.copy_from_slice(&msg.sp);
            self.ws.regs = [0; crate::isa::NREG];
            self.ws.set_cur_ptr(msg.cur_ptr);
            self.ws.data[..words.min(image.len())]
                .copy_from_slice(&image[..words.min(image.len())]);
            self.ws.data[words.min(image.len())..]
                .iter_mut()
                .for_each(|w| *w = 0);
            let pass = logic_pass(&msg.program, &mut self.ws);
            msg.iters_done += 1;
            msg.sp.copy_from_slice(&self.ws.sp);
            match pass.status {
                Status::NextIter => {
                    msg.cur_ptr = self.ws.cur_ptr();
                }
                s => return Some(s),
            }
        }
    }

    /// A response arrived: clear the pending slot. Returns the final
    /// scratchpad for completed traversals, or the continuation request
    /// when the traversal yielded (budget) and must be re-issued.
    pub fn on_response(
        &mut self,
        mut msg: TraversalMsg,
        now: Ns,
    ) -> ResponseAction {
        self.pending.remove(&msg.id);
        match msg.status {
            Status::Return | Status::Trap => ResponseAction::Done {
                id: msg.id,
                status: msg.status,
                sp: msg.sp,
                iters: msg.iters_done,
                crossings: msg.node_crossings,
            },
            _ => {
                // Yielded: grant a fresh budget and re-issue from the
                // embedded continuation state (paper §3).
                self.stats.continuations += 1;
                msg.kind = crate::net::MsgKind::Request;
                msg.max_iters += self.cfg.max_iters;
                msg.status = Status::Running;
                self.pending.insert(
                    msg.id,
                    Pending { msg: msg.clone(), sent_at: now },
                );
                ResponseAction::Continue(msg)
            }
        }
    }

    /// Collect requests whose timeout expired (packet was dropped) for
    /// retransmission. Updates their send timestamps.
    pub fn collect_retransmits(&mut self, now: Ns) -> Vec<TraversalMsg> {
        let mut out = Vec::new();
        for p in self.pending.values_mut() {
            if now.saturating_sub(p.sent_at) >= self.cfg.timeout_ns {
                p.sent_at = now;
                self.stats.retransmits += 1;
                out.push(p.msg.clone());
            }
        }
        out
    }

    pub fn pending_count(&self) -> usize {
        self.pending.len()
    }
}

/// Result of processing a response.
#[derive(Debug)]
pub enum ResponseAction {
    Done {
        id: RequestId,
        status: Status,
        sp: [i64; SP_WORDS],
        iters: u32,
        crossings: u32,
    },
    /// Re-issue this continuation request.
    Continue(TraversalMsg),
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::IterBuilder;

    fn list_find_iter() -> CompiledIter {
        let mut b = IterBuilder::new();
        let key = b.sp(0);
        let nkey = b.field(0);
        b.if_eq(key, nkey, |b| {
            let val = b.field(1);
            b.sp_store(1, val);
            b.ret();
        });
        let next = b.field(2);
        let zero = b.imm(0);
        b.if_eq(next, zero, |b| {
            let nf = b.imm(i64::MAX);
            b.sp_store(2, nf);
            b.ret();
        });
        b.advance(next);
        b.finish().unwrap()
    }

    fn compute_heavy_iter() -> CompiledIter {
        let mut b = IterBuilder::new();
        let x = b.imm(3);
        let mark = b.temp_mark();
        for _ in 0..12 {
            let y = b.mul(x, x);
            let z = b.add(y, x);
            b.assign(x, z);
            b.temp_release(mark);
        }
        b.sp_store(0, x);
        b.ret();
        b.finish().unwrap()
    }

    #[test]
    fn offloads_memory_bound_iterators() {
        let mut d = DispatchEngine::new(0, DispatchConfig::default());
        let it = list_find_iter();
        match d.submit(&it, 0x1000, [0; SP_WORDS], 0) {
            Disposition::Offload(msg) => {
                assert_eq!(msg.cur_ptr, 0x1000);
                assert_eq!(msg.id.seq, 0);
            }
            other => panic!("expected offload, got {other:?}"),
        }
        assert_eq!(d.stats.offloaded, 1);
        assert_eq!(d.pending_count(), 1);
    }

    /// Zero-copy dispatch invariant: the offloaded message (and its
    /// parked retransmit copy) share the compiled iterator's program
    /// Arc — no deep clone anywhere on the submit path.
    #[test]
    fn offloaded_message_shares_the_iterators_program() {
        use std::sync::Arc;
        let mut cfg = DispatchConfig::default();
        cfg.timeout_ns = 1000;
        let mut d = DispatchEngine::new(0, cfg);
        let it = list_find_iter();
        let msg = match d.submit(&it, 0x1000, [0; SP_WORDS], 0) {
            Disposition::Offload(m) => m,
            other => panic!("expected offload, got {other:?}"),
        };
        assert!(Arc::ptr_eq(&msg.program, &it.program));
        let retrans = d.collect_retransmits(5000);
        assert_eq!(retrans.len(), 1);
        assert!(Arc::ptr_eq(&retrans[0].program, &it.program));
    }

    #[test]
    fn rejects_compute_heavy_iterators() {
        let mut d = DispatchEngine::new(0, DispatchConfig::default());
        let it = compute_heavy_iter();
        assert!(matches!(
            d.submit(&it, 0x1000, [0; SP_WORDS], 0),
            Disposition::RunOnCpu
        ));
        assert_eq!(d.stats.local_fallback, 1);
    }

    #[test]
    fn request_ids_are_sequential() {
        let mut d = DispatchEngine::new(7, DispatchConfig::default());
        let it = list_find_iter();
        for want in 0..3 {
            if let Disposition::Offload(m) =
                d.submit(&it, 0x1000, [0; SP_WORDS], 0)
            {
                assert_eq!(m.id.cpu_node, 7);
                assert_eq!(m.id.seq, want);
            } else {
                panic!()
            }
        }
    }

    #[test]
    fn retransmit_after_timeout() {
        let mut cfg = DispatchConfig::default();
        cfg.timeout_ns = 1000;
        let mut d = DispatchEngine::new(0, cfg);
        let it = list_find_iter();
        let _ = d.submit(&it, 0x1000, [0; SP_WORDS], 0);
        assert!(d.collect_retransmits(500).is_empty());
        let r = d.collect_retransmits(1500);
        assert_eq!(r.len(), 1);
        assert_eq!(d.stats.retransmits, 1);
        // timer reset: not immediately re-collected
        assert!(d.collect_retransmits(1600).is_empty());
    }

    #[test]
    fn response_completes_pending() {
        let mut d = DispatchEngine::new(0, DispatchConfig::default());
        let it = list_find_iter();
        let msg = match d.submit(&it, 0x1000, [0; SP_WORDS], 0) {
            Disposition::Offload(m) => m,
            _ => panic!(),
        };
        let resp = msg.into_response(Status::Return);
        match d.on_response(resp, 10) {
            ResponseAction::Done { status, .. } => {
                assert_eq!(status, Status::Return)
            }
            _ => panic!(),
        }
        assert_eq!(d.pending_count(), 0);
    }

    #[test]
    fn yielded_response_continues_with_fresh_budget() {
        let mut cfg = DispatchConfig::default();
        cfg.max_iters = 8;
        let mut d = DispatchEngine::new(0, cfg);
        let it = list_find_iter();
        let msg = match d.submit(&it, 0x1000, [0; SP_WORDS], 0) {
            Disposition::Offload(m) => m,
            _ => panic!(),
        };
        let mut y = msg;
        y.kind = crate::net::MsgKind::Response;
        y.iters_done = 8;
        y.status = Status::Running; // yield marker
        match d.on_response(y, 10) {
            ResponseAction::Continue(c) => {
                // the Boost-span contract: new total = old + grant_step
                assert_eq!(c.max_iters, 8 + d.grant_step());
                assert_eq!(c.iters_done, 8);
            }
            _ => panic!(),
        }
        assert_eq!(d.stats.continuations, 1);
        assert_eq!(d.pending_count(), 1);
    }

    #[test]
    fn cache_serves_full_traversal_locally() {
        let mut cfg = DispatchConfig::default();
        cfg.cache_bytes = 1 << 20;
        let mut d = DispatchEngine::new(0, cfg);
        let it = list_find_iter();
        // two-node chain cached: 0x1000 -> 0x2000(key=5)
        d.cache.insert(0x1000, &[1, 11, 0x2000]);
        d.cache.insert(0x2000, &[5, 55, 0]);
        let mut sp = [0i64; SP_WORDS];
        sp[0] = 5;
        match d.submit(&it, 0x1000, sp, 0) {
            Disposition::CompletedLocally { status, sp, iters } => {
                assert_eq!(status, Status::Return);
                assert_eq!(sp[1], 55);
                assert_eq!(iters, 2);
            }
            other => panic!("expected local completion, got {other:?}"),
        }
        assert_eq!(d.stats.cache_hit_iters, 2);
        assert_eq!(d.stats.offloaded, 0);
    }

    #[test]
    fn cache_prefix_then_offload_remainder() {
        let mut cfg = DispatchConfig::default();
        cfg.cache_bytes = 1 << 20;
        let mut d = DispatchEngine::new(0, cfg);
        let it = list_find_iter();
        d.cache.insert(0x1000, &[1, 11, 0x2000]); // only head cached
        let mut sp = [0i64; SP_WORDS];
        sp[0] = 5;
        match d.submit(&it, 0x1000, sp, 0) {
            Disposition::Offload(m) => {
                assert_eq!(m.cur_ptr, 0x2000); // continues from the miss
                assert_eq!(m.iters_done, 1);
            }
            other => panic!("expected offload, got {other:?}"),
        }
    }
}
