//! `obs` — observability: traversal tracing + unified metrics.
//!
//! Two instruments, both designed to cost nothing when idle:
//!
//! * [`trace`] — sampled per-op hop traces. Every executor (rack DES,
//!   live engine, persistent engine, inline serving) emits the same
//!   structured span sequence for the same op, so a trace doubles as a
//!   backend-conformance artifact. Exported as JSONL and Chrome
//!   trace-event JSON.
//! * [`registry`] — named counters/gauges/histograms with relaxed
//!   atomic hot paths, a periodic time-series snapshot sampler, and
//!   the JSON snapshot served over the wire by the STATS frame
//!   (`srv/wire.rs`) and `pulse stats --addr`.
//!
//! See `obs/README.md` for the span schema, the sampling contract, and
//! the overhead discipline.
#![deny(clippy::redundant_clone)]

pub mod registry;
pub mod trace;

pub use registry::{
    snapshot_rates, AtomicHist, Counter, Instrument, MetricsRegistry,
    SnapshotSampler,
};
pub use trace::{
    OpTrace, Span, SpanKind, Trace, TraceConfig, TraceRing, Tracer,
    TracerStats,
};
