//! Traversal tracer: sampled per-op hop traces as structured spans.
//!
//! Every op served by any executor can carry a trace: a causally
//! ordered sequence of [`Span`]s recording where the traversal went
//! (dispatch → shard visit → forward/bounce → boost → finish). The
//! sequence is **identical in shape across executors** — the rack DES,
//! the live threaded engine, the persistent engine, and inline serving
//! all emit the same `(op, kind)` stream for the same seeded workload
//! under serialized serving — which makes a trace a backend-conformance
//! artifact, not just a debugging aid (pinned in `tests/conformance.rs`).
//!
//! Ordering contract: spans are keyed `(op, k)` where `op` is the op's
//! admission index and `k` is a per-op monotone emission counter that
//! travels *with the traversal* (in `LiveJob` across shard threads, in
//! `OpRun` through the DES). Sorting by `(op, k)` therefore recovers
//! the causal hop order regardless of which thread's ring buffer a
//! span landed in. Timestamps (`t_ns`) are informational — wall-clock
//! on the live engine, virtual sim time on the DES — and are excluded
//! from the conformance identity.
//!
//! Overhead contract: with sampling disabled (the default) the tracer
//! adds **zero allocations** to the timed region — `make_ring` returns
//! a zero-capacity ring (a `Vec::new()`, which does not allocate),
//! `sampled()` is `false` for every op so no emission site is reached,
//! and the counters in [`Tracer::stats`] stay at zero (asserted in
//! `tests/conformance.rs`). Rings are preallocated outside the timed
//! region when sampling is enabled; a full ring overwrites its oldest
//! span and counts the loss instead of allocating.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::Json;

/// What happened at one hop of a traversal. Payloads carry only
/// schedule-independent facts (shard ids, iteration counts, byte
/// counts) so the span stream is deterministic under serialized
/// serving; see the module docs for the conformance contract.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// The dispatcher launched stage `stage` of the op (the routing
    /// target is visible as the following `Visit`'s shard).
    Dispatch { stage: u32 },
    /// A near-memory accelerator visit: `iters` iterations executed on
    /// `shard`, reading `dram_bytes` from its DRAM (0-iteration visits
    /// happen when a forwarded traversal arrives with spent budget).
    Visit { shard: u32, iters: u32, dram_bytes: u64 },
    /// In-network forward to shard `to` (PULSE mode; the source shard
    /// is the preceding `Visit`).
    Forward { to: u32 },
    /// Bounce back through the dispatcher (PULSE-ACC mode).
    Bounce,
    /// Budget-exhaustion yield answered with a boost: `grant` is the
    /// new total iteration budget after the re-grant.
    Boost { grant: u32 },
    /// Terminal completion; `trapped` mirrors the op's final status.
    Finish { trapped: bool },
}

impl SpanKind {
    pub fn name(&self) -> &'static str {
        match self {
            SpanKind::Dispatch { .. } => "dispatch",
            SpanKind::Visit { .. } => "visit",
            SpanKind::Forward { .. } => "forward",
            SpanKind::Bounce => "bounce",
            SpanKind::Boost { .. } => "boost",
            SpanKind::Finish { .. } => "finish",
        }
    }
}

/// One hop of one traced op. `Copy` so rings move spans without
/// allocation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Span {
    /// Admission index of the op this span belongs to.
    pub op: u64,
    /// Causal emission counter within the op (0 = first span).
    pub k: u32,
    /// Emission time: wall ns since the tracer's epoch (live), or
    /// virtual sim ns (DES). Not part of the conformance identity.
    pub t_ns: u64,
    pub kind: SpanKind,
}

impl Span {
    fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("op", self.op)
            .set("k", self.k as u64)
            .set("t_ns", self.t_ns)
            .set("kind", self.kind.name());
        match self.kind {
            SpanKind::Dispatch { stage } => {
                j.set("stage", stage as u64);
            }
            SpanKind::Visit { shard, iters, dram_bytes } => {
                j.set("shard", shard as u64)
                    .set("iters", iters as u64)
                    .set("dram_bytes", dram_bytes);
            }
            SpanKind::Forward { to } => {
                j.set("to", to as u64);
            }
            SpanKind::Bounce => {}
            SpanKind::Boost { grant } => {
                j.set("grant", grant as u64);
            }
            SpanKind::Finish { trapped } => {
                j.set("trapped", trapped);
            }
        }
        j
    }
}

/// Tracer configuration. `Copy` so it can ride in `EngineConfig`.
#[derive(Debug, Clone, Copy)]
pub struct TraceConfig {
    /// Sample 1 in N ops (1 = every op). 0 is treated as 1.
    pub sample_every: u64,
    /// Seed of the deterministic sampling hash: the same (seed,
    /// op index) pair samples identically on every executor.
    pub seed: u64,
    /// Span capacity of each per-thread ring buffer.
    pub ring_capacity: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        Self { sample_every: 1, seed: 0, ring_capacity: 64 * 1024 }
    }
}

/// Bounded span buffer owned by one emitting thread (a shard, the
/// coordinator, the DES loop). Overwrites its oldest span when full —
/// never allocates after construction.
#[derive(Debug, Default)]
pub struct TraceRing {
    buf: Vec<Span>,
    cap: usize,
    /// Next write position once `buf.len() == cap`.
    head: usize,
    /// Spans overwritten because the ring was full.
    dropped: u64,
}

impl TraceRing {
    /// A ring that records nothing (the disabled-tracer ring).
    /// `Vec::new()` does not allocate.
    pub fn empty() -> Self {
        Self::default()
    }

    fn with_capacity(cap: usize) -> Self {
        Self { buf: Vec::with_capacity(cap), cap, head: 0, dropped: 0 }
    }

    #[inline]
    pub fn push(&mut self, span: Span) {
        if self.cap == 0 {
            self.dropped += 1;
            return;
        }
        if self.buf.len() < self.cap {
            self.buf.push(span);
        } else {
            self.buf[self.head] = span;
            self.head = (self.head + 1) % self.cap;
            self.dropped += 1;
        }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
}

/// Counters for the zero-overhead assertion: all three stay 0 when
/// sampling is disabled.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TracerStats {
    /// Spans currently parked (recorded and retrievable via `drain`).
    pub recorded: u64,
    /// Spans lost to full or zero-capacity rings.
    pub dropped: u64,
    /// Rings preallocated by `make_ring` (0 when disabled).
    pub rings_allocated: u64,
}

/// Per-run trace collector shared by every emitting thread of one
/// executor. Emitters obtain a private [`TraceRing`] before the timed
/// region (`make_ring`), push spans lock-free into it, and park it
/// back when done; `drain` merges and causally orders everything.
#[derive(Debug)]
pub struct Tracer {
    cfg: Option<TraceConfig>,
    epoch: Instant,
    parked: Mutex<Vec<TraceRing>>,
    dropped: AtomicU64,
    rings_allocated: AtomicU64,
    recorded: AtomicU64,
}

impl Default for Tracer {
    fn default() -> Self {
        Self::disabled()
    }
}

/// splitmix64 finalizer: the deterministic sampling hash.
fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e3779b97f4a7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

impl Tracer {
    pub fn disabled() -> Self {
        Self {
            cfg: None,
            epoch: Instant::now(),
            parked: Mutex::new(Vec::new()),
            dropped: AtomicU64::new(0),
            rings_allocated: AtomicU64::new(0),
            recorded: AtomicU64::new(0),
        }
    }

    pub fn new(cfg: TraceConfig) -> Self {
        Self { cfg: Some(cfg), ..Self::disabled() }
    }

    pub fn enabled(&self) -> bool {
        self.cfg.is_some()
    }

    /// Deterministic 1-in-N sampling decision, pure in (seed, op
    /// index): the same op index samples identically on every
    /// executor, which is what makes cross-backend trace comparison
    /// possible. Always `false` when disabled.
    #[inline]
    pub fn sampled(&self, op_index: u64) -> bool {
        match self.cfg {
            None => false,
            Some(c) => {
                let n = c.sample_every.max(1);
                n == 1 || mix64(c.seed ^ op_index) % n == 0
            }
        }
    }

    /// Preallocate a ring for one emitting thread. Call OUTSIDE the
    /// timed region. Returns a zero-capacity (allocation-free) ring
    /// when disabled.
    pub fn make_ring(&self) -> TraceRing {
        match self.cfg {
            None => TraceRing::empty(),
            Some(c) => {
                self.rings_allocated.fetch_add(1, Ordering::Relaxed);
                TraceRing::with_capacity(c.ring_capacity.max(1))
            }
        }
    }

    /// Park a finished ring for later draining. A disabled tracer's
    /// empty ring is discarded without touching the mutex.
    pub fn park(&self, ring: TraceRing) {
        self.dropped.fetch_add(ring.dropped, Ordering::Relaxed);
        if !self.enabled() {
            return;
        }
        self.recorded
            .fetch_add(ring.buf.len() as u64, Ordering::Relaxed);
        self.parked.lock().unwrap().push(ring);
    }

    /// Wall ns since the tracer's construction (live executors; the
    /// DES stamps virtual sim time instead).
    #[inline]
    pub fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    pub fn stats(&self) -> TracerStats {
        TracerStats {
            recorded: self.recorded.load(Ordering::Relaxed),
            dropped: self.dropped.load(Ordering::Relaxed),
            rings_allocated: self.rings_allocated.load(Ordering::Relaxed),
        }
    }

    /// Merge every parked ring into one causally ordered [`Trace`]
    /// and reset the recorded counter. Call after the run.
    pub fn drain(&self) -> Trace {
        let mut rings = Vec::new();
        std::mem::swap(&mut rings, &mut self.parked.lock().unwrap());
        let mut spans: Vec<Span> = Vec::new();
        for r in rings {
            // unwrap the ring's overwrite rotation back to push order
            let (tail, head) = r.buf.split_at(r.head.min(r.buf.len()));
            spans.extend_from_slice(head);
            spans.extend_from_slice(tail);
        }
        self.recorded.store(0, Ordering::Relaxed);
        spans.sort_by_key(|s| (s.op, s.k));
        Trace { spans }
    }
}

/// Per-op emission handle: binds an op's identity and its causal
/// counter to a ring, so emission sites are one `push(kind)` call.
/// Used by the single-threaded executors (DES, inline serving); the
/// live engine threads the counter through `LiveJob` instead.
pub struct OpTrace<'a> {
    pub ring: &'a mut TraceRing,
    pub op: u64,
    pub k: u32,
}

impl OpTrace<'_> {
    #[inline]
    pub fn push(&mut self, t_ns: u64, kind: SpanKind) {
        self.ring.push(Span { op: self.op, k: self.k, t_ns, kind });
        self.k += 1;
    }
}

/// A drained, causally ordered trace.
#[derive(Debug, Default)]
pub struct Trace {
    pub spans: Vec<Span>,
}

impl Trace {
    pub fn len(&self) -> usize {
        self.spans.len()
    }

    pub fn is_empty(&self) -> bool {
        self.spans.is_empty()
    }

    /// The schedule-independent identity of the trace: `(op, kind)` in
    /// causal order, timestamps excluded. Two executors serving the
    /// same seeded workload serialized must produce equal identities
    /// (the conformance contract).
    pub fn identity(&self) -> Vec<(u64, SpanKind)> {
        self.spans.iter().map(|s| (s.op, s.kind)).collect()
    }

    /// One JSON object per line (the `--trace-out` format).
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for s in &self.spans {
            out.push_str(&s.to_json().render());
            out.push('\n');
        }
        out
    }

    /// Chrome trace-event format (chrome://tracing, Perfetto): one
    /// instant event per span, one track (`tid`) per op.
    pub fn to_chrome(&self) -> String {
        let mut events = Vec::with_capacity(self.spans.len());
        for s in &self.spans {
            let mut e = Json::obj();
            e.set("name", s.kind.name())
                .set("ph", "i")
                .set("s", "t")
                .set("ts", s.t_ns as f64 / 1e3)
                .set("pid", 0u64)
                .set("tid", s.op);
            let mut args = s.to_json();
            if let Json::Obj(m) = &mut args {
                m.remove("t_ns");
            }
            e.set("args", args);
            events.push(e);
        }
        Json::Arr(events).render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(op: u64, k: u32, kind: SpanKind) -> Span {
        Span { op, k, t_ns: 7, kind }
    }

    #[test]
    fn disabled_tracer_counts_nothing_and_allocates_nothing() {
        let t = Tracer::disabled();
        assert!(!t.enabled());
        assert!(!t.sampled(0));
        let mut r = t.make_ring();
        assert_eq!(r.buf.capacity(), 0, "disabled ring must not allocate");
        r.push(span(0, 0, SpanKind::Bounce));
        t.park(r);
        let s = t.stats();
        assert_eq!(s.recorded, 0);
        assert_eq!(s.rings_allocated, 0);
        // the push was counted as dropped, never stored
        assert_eq!(s.dropped, 1);
        assert!(t.drain().is_empty());
    }

    #[test]
    fn sampling_is_deterministic_and_hits_roughly_one_in_n() {
        let t = Tracer::new(TraceConfig {
            sample_every: 8,
            seed: 0xDECAF,
            ring_capacity: 16,
        });
        let picks: Vec<bool> = (0..10_000).map(|i| t.sampled(i)).collect();
        let again: Vec<bool> = (0..10_000).map(|i| t.sampled(i)).collect();
        assert_eq!(picks, again, "sampling must be pure");
        let hits = picks.iter().filter(|&&b| b).count();
        assert!(
            (800..1700).contains(&hits),
            "1-in-8 of 10k sampled {hits} ops"
        );
        // sample_every = 1 takes everything
        let all = Tracer::new(TraceConfig {
            sample_every: 1,
            ..TraceConfig::default()
        });
        assert!((0..100).all(|i| all.sampled(i)));
    }

    #[test]
    fn drain_merges_rings_in_causal_order() {
        let t = Tracer::new(TraceConfig {
            sample_every: 1,
            seed: 0,
            ring_capacity: 8,
        });
        // op 1's spans land in two different rings (coordinator +
        // shard), out of order between rings
        let mut a = t.make_ring();
        let mut b = t.make_ring();
        a.push(span(1, 0, SpanKind::Dispatch { stage: 0 }));
        b.push(span(1, 1, SpanKind::Visit {
            shard: 2,
            iters: 5,
            dram_bytes: 80,
        }));
        b.push(span(0, 1, SpanKind::Finish { trapped: false }));
        a.push(span(0, 0, SpanKind::Dispatch { stage: 0 }));
        a.push(span(1, 2, SpanKind::Finish { trapped: false }));
        t.park(a);
        t.park(b);
        assert_eq!(t.stats().recorded, 5);
        assert_eq!(t.stats().rings_allocated, 2);
        let trace = t.drain();
        let ids = trace.identity();
        assert_eq!(ids, vec![
            (0, SpanKind::Dispatch { stage: 0 }),
            (0, SpanKind::Finish { trapped: false }),
            (1, SpanKind::Dispatch { stage: 0 }),
            (1, SpanKind::Visit { shard: 2, iters: 5, dram_bytes: 80 }),
            (1, SpanKind::Finish { trapped: false }),
        ]);
        // drain resets the recorded count
        assert_eq!(t.stats().recorded, 0);
        assert!(t.drain().is_empty());
    }

    #[test]
    fn full_ring_overwrites_oldest_and_counts_drops() {
        let t = Tracer::new(TraceConfig {
            sample_every: 1,
            seed: 0,
            ring_capacity: 4,
        });
        let mut r = t.make_ring();
        for k in 0..10u32 {
            r.push(span(0, k, SpanKind::Bounce));
        }
        assert_eq!(r.len(), 4);
        t.park(r);
        assert_eq!(t.stats().dropped, 6);
        let trace = t.drain();
        // the newest 4 spans survive, in order
        let ks: Vec<u32> = trace.spans.iter().map(|s| s.k).collect();
        assert_eq!(ks, vec![6, 7, 8, 9]);
    }

    #[test]
    fn jsonl_rows_parse_and_round_trip_schema() {
        let t = Tracer::new(TraceConfig::default());
        let mut r = t.make_ring();
        let mut ot = OpTrace { ring: &mut r, op: 3, k: 0 };
        ot.push(10, SpanKind::Dispatch { stage: 0 });
        ot.push(20, SpanKind::Visit { shard: 1, iters: 9, dram_bytes: 144 });
        ot.push(30, SpanKind::Forward { to: 0 });
        ot.push(40, SpanKind::Bounce);
        ot.push(50, SpanKind::Boost { grant: 8192 });
        ot.push(60, SpanKind::Finish { trapped: true });
        t.park(r);
        let trace = t.drain();
        let jsonl = trace.to_jsonl();
        let mut kinds = Vec::new();
        for line in jsonl.lines() {
            let j = Json::parse(line).expect("every row parses");
            kinds.push(j.get("kind").unwrap().as_str().unwrap().to_string());
            assert_eq!(j.get("op").unwrap().as_f64(), Some(3.0));
            assert!(j.get("k").is_some() && j.get("t_ns").is_some());
        }
        assert_eq!(
            kinds,
            ["dispatch", "visit", "forward", "bounce", "boost", "finish"]
        );
        // chrome export is one valid JSON array with one event per span
        let chrome = Json::parse(&trace.to_chrome()).expect("chrome json");
        match chrome {
            Json::Arr(evs) => {
                assert_eq!(evs.len(), 6);
                assert_eq!(
                    evs[1].get("name").and_then(|n| n.as_str()),
                    Some("visit")
                );
            }
            other => panic!("expected array, got {other:?}"),
        }
    }
}
