//! Unified metrics registry: named counters / gauges / histograms with
//! relaxed-atomic hot paths, plus a periodic snapshot sampler that
//! turns the registry into time-series JSONL during a serve run.
//!
//! Discipline matches `live::queue`: every mutation on a serving hot
//! path is a single relaxed atomic RMW on its own handle (counters are
//! cache-line padded), and all aggregation cost lives in `snapshot()`,
//! which only observers pay. The existing ad-hoc metric structs
//! (`SrvMetrics`, `LiveRunStats`, queue stats) register *into* a
//! registry as gauges over their own atomics — their hot paths don't
//! change, they just become observable by name.

use std::collections::BTreeMap;
use std::io::{self, Write as _};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::util::cache::CachePadded;
use crate::util::hist::Histogram;
use crate::util::json::Json;

/// Monotone counter handle. Clones share the cell; increments are
/// relaxed RMWs on a dedicated cache line.
#[derive(Debug, Clone)]
pub struct Counter(Arc<CachePadded<AtomicU64>>);

impl Counter {
    fn new() -> Self {
        Counter(Arc::new(CachePadded::from(AtomicU64::new(0))))
    }

    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Lock-free histogram: the `util::hist::Histogram` bucket layout
/// (64 decades × 16 sub-buckets) with every slot a relaxed `AtomicU64`,
/// so many writer threads record concurrently without a mutex — the
/// fix for `SrvMetrics.e2e`'s global-`Mutex`-per-response hot path.
#[derive(Debug)]
pub struct AtomicHist {
    buckets: Vec<AtomicU64>,
    count: CachePadded<AtomicU64>,
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
}

impl Default for AtomicHist {
    fn default() -> Self {
        Self::new()
    }
}

impl AtomicHist {
    pub fn new() -> Self {
        Self {
            buckets: (0..Histogram::SLOTS)
                .map(|_| AtomicU64::new(0))
                .collect(),
            count: CachePadded::from(AtomicU64::new(0)),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
        }
    }

    #[inline]
    pub fn record(&self, v: u64) {
        self.buckets[Histogram::index_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Materialize a point-in-time `Histogram` (percentile math lives
    /// there; concurrent recording makes the snapshot approximate by
    /// at most the in-flight records).
    pub fn snapshot(&self) -> Histogram {
        let buckets: Vec<u64> = self
            .buckets
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .collect();
        Histogram::from_raw(
            buckets,
            self.count.load(Ordering::Relaxed),
            self.sum.load(Ordering::Relaxed) as f64,
            self.min.load(Ordering::Relaxed),
            self.max.load(Ordering::Relaxed),
        )
    }
}

/// One registered instrument.
#[derive(Clone)]
pub enum Instrument {
    Counter(Counter),
    /// Computed on snapshot; typically a closure over some hot
    /// struct's own relaxed atomics.
    Gauge(Arc<dyn Fn() -> f64 + Send + Sync>),
    Hist(Arc<AtomicHist>),
}

impl std::fmt::Debug for Instrument {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Instrument::Counter(c) => write!(f, "Counter({})", c.get()),
            Instrument::Gauge(_) => write!(f, "Gauge(..)"),
            Instrument::Hist(h) => write!(f, "Hist(n={})", h.count()),
        }
    }
}

/// Named instrument registry. Registration takes the mutex once per
/// instrument at setup time; the returned handles are lock-free.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    entries: Mutex<BTreeMap<String, Instrument>>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Get-or-create a counter. Re-registering a name returns the
    /// existing handle, so restarts of a serving loop keep counting.
    pub fn counter(&self, name: &str) -> Counter {
        let mut m = self.entries.lock().unwrap();
        match m.get(name) {
            Some(Instrument::Counter(c)) => c.clone(),
            _ => {
                let c = Counter::new();
                m.insert(name.to_string(), Instrument::Counter(c.clone()));
                c
            }
        }
    }

    /// Register (or replace) a computed gauge.
    pub fn gauge_fn(
        &self,
        name: &str,
        f: impl Fn() -> f64 + Send + Sync + 'static,
    ) {
        self.entries
            .lock()
            .unwrap()
            .insert(name.to_string(), Instrument::Gauge(Arc::new(f)));
    }

    /// Get-or-create a lock-free histogram.
    pub fn hist(&self, name: &str) -> Arc<AtomicHist> {
        let mut m = self.entries.lock().unwrap();
        match m.get(name) {
            Some(Instrument::Hist(h)) => h.clone(),
            _ => {
                let h = Arc::new(AtomicHist::new());
                m.insert(name.to_string(), Instrument::Hist(h.clone()));
                h
            }
        }
    }

    /// Point-in-time view of every instrument as one flat JSON object.
    /// Counters/gauges render as numbers; a histogram `h` renders as
    /// `h.count`, `h.mean`, `h.p50`, `h.p95`, `h.p99`, `h.max`.
    pub fn snapshot(&self) -> Json {
        let mut j = Json::obj();
        for (name, inst) in self.entries.lock().unwrap().iter() {
            match inst {
                Instrument::Counter(c) => {
                    j.set(name, c.get());
                }
                Instrument::Gauge(f) => {
                    let v = f();
                    j.set(name, if v.is_finite() { v } else { 0.0 });
                }
                Instrument::Hist(h) => {
                    let s = h.snapshot();
                    j.set(&format!("{name}.count"), s.count())
                        .set(&format!("{name}.mean"), s.mean())
                        .set(&format!("{name}.p50"), s.p50())
                        .set(&format!("{name}.p95"), s.p95())
                        .set(&format!("{name}.p99"), s.p99())
                        .set(&format!("{name}.max"), s.max());
                }
            }
        }
        j
    }

    /// Get-or-create a **labeled** histogram `{base}.prog{label}` with
    /// bounded cardinality: once `cap` distinct labels exist under
    /// `base`, new labels get `None` (callers fall back to the
    /// unlabeled aggregate) — a misbehaving client registering
    /// thousands of programs cannot grow the registry without bound.
    /// The cap is global per `base`, not per caller, so every
    /// connection sees the same label set.
    pub fn labeled_hist(
        &self,
        base: &str,
        label: u32,
        cap: usize,
    ) -> Option<Arc<AtomicHist>> {
        let name = format!("{base}.prog{label}");
        let prefix = format!("{base}.prog");
        let mut m = self.entries.lock().unwrap();
        if let Some(Instrument::Hist(h)) = m.get(&name) {
            return Some(h.clone());
        }
        let labels = m
            .iter()
            .filter(|(n, i)| {
                n.starts_with(&prefix)
                    && matches!(i, Instrument::Hist(_))
            })
            .count();
        if labels >= cap {
            return None;
        }
        let h = Arc::new(AtomicHist::new());
        m.insert(name, Instrument::Hist(h.clone()));
        Some(h)
    }

    /// Current counter values only (the sampler's rate base).
    fn counter_values(&self) -> BTreeMap<String, u64> {
        self.entries
            .lock()
            .unwrap()
            .iter()
            .filter_map(|(n, i)| match i {
                Instrument::Counter(c) => Some((n.clone(), c.get())),
                _ => None,
            })
            .collect()
    }
}

/// Per-interval rates from two registry snapshot JSONs (as returned
/// by [`MetricsRegistry::snapshot`] or fetched over the STATS frame):
/// for every numeric key that did not decrease over the interval,
/// emit `{name}_per_s = delta / dt`. Histogram summary fields
/// (`.mean/.p50/.p95/.p99/.max`) are skipped — they are levels, not
/// totals — while `.count` keys stay (records per second). Gauges
/// that moved down (queue depths shrinking) are skipped rather than
/// reported as negative rates. This is the rate math behind both
/// `pulse stats --watch` and `pulse top`; `SnapshotSampler` keeps its
/// cheaper in-process counter path.
pub fn snapshot_rates(prev: &Json, cur: &Json, dt_s: f64) -> Json {
    let mut rates = Json::obj();
    if dt_s <= 0.0 {
        return rates;
    }
    let (Json::Obj(p), Json::Obj(c)) = (prev, cur) else {
        return rates;
    };
    const LEVEL_SUFFIXES: [&str; 5] =
        [".mean", ".p50", ".p95", ".p99", ".max"];
    for (name, v) in c {
        if LEVEL_SUFFIXES.iter().any(|s| name.ends_with(s)) {
            continue;
        }
        let (Some(cv), Some(pv)) =
            (v.as_f64(), p.get(name).and_then(|v| v.as_f64()))
        else {
            continue;
        };
        if cv >= pv {
            rates.set(&format!("{name}_per_s"), (cv - pv) / dt_s);
        }
    }
    rates
}

/// Periodic snapshot sampler: a background thread that appends one
/// JSONL row per interval to `path` while a serve run is live —
/// `{"t_s":…, "metrics":{…snapshot…}, "rates":{"<counter>_per_s":…}}`.
/// Stop it with [`SnapshotSampler::stop`]; it writes one final row so
/// short runs still produce output.
#[derive(Debug)]
pub struct SnapshotSampler {
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl SnapshotSampler {
    pub fn start(
        registry: Arc<MetricsRegistry>,
        path: PathBuf,
        interval: Duration,
    ) -> io::Result<Self> {
        let mut file = std::fs::File::create(&path)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let interval = interval.max(Duration::from_millis(10));
        let join = std::thread::Builder::new()
            .name("pulse-stats".into())
            .spawn(move || {
                let t0 = Instant::now();
                let mut prev = registry.counter_values();
                let mut prev_t = t0;
                loop {
                    // sleep in small steps so stop() is prompt
                    let deadline = Instant::now() + interval;
                    while Instant::now() < deadline
                        && !stop2.load(Ordering::Relaxed)
                    {
                        std::thread::sleep(Duration::from_millis(10));
                    }
                    let stopping = stop2.load(Ordering::Relaxed);
                    let now = Instant::now();
                    let dt = now.duration_since(prev_t).as_secs_f64();
                    let cur = registry.counter_values();
                    let mut rates = Json::obj();
                    if dt > 0.0 {
                        for (name, v) in &cur {
                            let d = v.saturating_sub(
                                prev.get(name).copied().unwrap_or(0),
                            );
                            rates.set(
                                &format!("{name}_per_s"),
                                d as f64 / dt,
                            );
                        }
                    }
                    let mut row = Json::obj();
                    row.set("t_s", t0.elapsed().as_secs_f64())
                        .set("metrics", registry.snapshot())
                        .set("rates", rates);
                    let _ = writeln!(file, "{}", row.render());
                    let _ = file.flush();
                    prev = cur;
                    prev_t = now;
                    if stopping {
                        break;
                    }
                }
            })?;
        Ok(Self { stop, join: Some(join) })
    }

    /// Signal the thread, wait for its final row, and return.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for SnapshotSampler {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_snapshot_by_name() {
        let r = MetricsRegistry::new();
        let c = r.counter("srv.requests");
        c.add(41);
        c.inc();
        // re-registration returns the same cell
        r.counter("srv.requests").inc();
        assert_eq!(c.get(), 43);
        let side = Arc::new(AtomicU64::new(7));
        let s2 = side.clone();
        r.gauge_fn("engine.queue_depth", move || {
            s2.load(Ordering::Relaxed) as f64
        });
        let snap = r.snapshot();
        assert_eq!(
            snap.get("srv.requests").and_then(|v| v.as_f64()),
            Some(43.0)
        );
        assert_eq!(
            snap.get("engine.queue_depth").and_then(|v| v.as_f64()),
            Some(7.0)
        );
        side.store(9, Ordering::Relaxed);
        assert_eq!(
            r.snapshot().get("engine.queue_depth").and_then(|v| v.as_f64()),
            Some(9.0)
        );
    }

    #[test]
    fn atomic_hist_matches_mutex_histogram_percentiles() {
        let ah = AtomicHist::new();
        let mut h = Histogram::new();
        for v in (1..=10_000u64).map(|v| v * 3) {
            ah.record(v);
            h.record(v);
        }
        let s = ah.snapshot();
        assert_eq!(s.count(), h.count());
        assert_eq!(s.p50(), h.p50());
        assert_eq!(s.p95(), h.p95());
        assert_eq!(s.p99(), h.p99());
        assert_eq!(s.min(), h.min());
        assert_eq!(s.max(), h.max());
        assert!((s.mean() - h.mean()).abs() < 1e-9);
    }

    #[test]
    fn atomic_hist_is_safe_under_concurrent_writers() {
        let ah = Arc::new(AtomicHist::new());
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let ah = ah.clone();
                s.spawn(move || {
                    for i in 0..5_000u64 {
                        ah.record(t * 1_000 + (i % 997) + 1);
                    }
                });
            }
        });
        let snap = ah.snapshot();
        assert_eq!(snap.count(), 20_000);
        assert!(snap.min() >= 1 && snap.max() <= 4_997);
    }

    #[test]
    fn hist_snapshot_renders_percentile_fields() {
        let r = MetricsRegistry::new();
        let h = r.hist("srv.e2e_ns");
        for v in 1..=100u64 {
            h.record(v * 100);
        }
        let snap = r.snapshot();
        assert_eq!(
            snap.get("srv.e2e_ns.count").and_then(|v| v.as_f64()),
            Some(100.0)
        );
        assert!(snap.get("srv.e2e_ns.p99").is_some());
        assert!(snap.get("srv.e2e_ns.mean").is_some());
    }

    #[test]
    fn labeled_hists_are_capped_and_stable() {
        let r = MetricsRegistry::new();
        let a = r.labeled_hist("srv.e2e", 0, 2).expect("under cap");
        let b = r.labeled_hist("srv.e2e", 1, 2).expect("under cap");
        // cap reached: a third label is refused…
        assert!(r.labeled_hist("srv.e2e", 2, 2).is_none());
        // …but existing labels keep resolving to the same cell
        a.record(10);
        r.labeled_hist("srv.e2e", 0, 2).unwrap().record(20);
        assert_eq!(a.count(), 2);
        b.record(5);
        // an unrelated base has its own budget
        assert!(r.labeled_hist("engine.execute", 9, 2).is_some());
        let snap = r.snapshot();
        assert_eq!(
            snap.get("srv.e2e.prog0.count").and_then(|v| v.as_f64()),
            Some(2.0)
        );
        assert_eq!(
            snap.get("srv.e2e.prog1.count").and_then(|v| v.as_f64()),
            Some(1.0)
        );
        assert!(snap.get("srv.e2e.prog2.count").is_none());
    }

    #[test]
    fn snapshot_rates_deltas_counters_and_skips_levels() {
        let mut prev = Json::obj();
        prev.set("srv.requests", 100.0)
            .set("srv.e2e.p99", 5_000.0)
            .set("srv.e2e.count", 10.0)
            .set("engine.inbox.depth", 8.0);
        let mut cur = Json::obj();
        cur.set("srv.requests", 300.0)
            .set("srv.e2e.p99", 9_000.0)
            .set("srv.e2e.count", 50.0)
            .set("engine.inbox.depth", 2.0) // gauge moved down
            .set("srv.busy", 4.0); // new key, no prev: skipped
        let rates = snapshot_rates(&prev, &cur, 2.0);
        assert_eq!(
            rates.get("srv.requests_per_s").and_then(|v| v.as_f64()),
            Some(100.0)
        );
        assert_eq!(
            rates.get("srv.e2e.count_per_s").and_then(|v| v.as_f64()),
            Some(20.0)
        );
        assert!(rates.get("srv.e2e.p99_per_s").is_none());
        assert!(rates.get("engine.inbox.depth_per_s").is_none());
        assert!(rates.get("srv.busy_per_s").is_none());
        // degenerate interval yields no rates at all
        assert!(matches!(
            snapshot_rates(&prev, &cur, 0.0),
            Json::Obj(m) if m.is_empty()
        ));
    }

    #[test]
    fn sampler_emits_parseable_rows_with_rates() {
        let dir = std::env::temp_dir()
            .join(format!("pulse_obs_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("stats.jsonl");
        let reg = Arc::new(MetricsRegistry::new());
        let c = reg.counter("ops.completed");
        let sampler = SnapshotSampler::start(
            reg.clone(),
            path.clone(),
            Duration::from_millis(30),
        )
        .unwrap();
        for _ in 0..50 {
            c.inc();
            std::thread::sleep(Duration::from_millis(2));
        }
        sampler.stop();
        let text = std::fs::read_to_string(&path).unwrap();
        let rows: Vec<Json> = text
            .lines()
            .map(|l| Json::parse(l).expect("row parses"))
            .collect();
        assert!(!rows.is_empty(), "sampler wrote no rows");
        let last = rows.last().unwrap();
        assert_eq!(
            last.get("metrics")
                .and_then(|m| m.get("ops.completed"))
                .and_then(|v| v.as_f64()),
            Some(50.0)
        );
        assert!(last.get("t_s").and_then(|v| v.as_f64()).unwrap() > 0.0);
        // some row observed a nonzero rate while the counter moved
        assert!(rows.iter().any(|r| {
            r.get("rates")
                .and_then(|m| m.get("ops.completed_per_s"))
                .and_then(|v| v.as_f64())
                .is_some_and(|v| v > 0.0)
        }));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
