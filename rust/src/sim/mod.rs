//! Virtual-time simulation substrate.
//!
//! The rack runs as a discrete-event simulation over a nanosecond
//! virtual clock: hardware components contribute calibrated latencies
//! (Fig. 10 of the paper for the accelerator; §6 setup for network/CPU),
//! while all *functional* work (ISA execution, data-structure traversal,
//! compression/encryption) really executes. Wall-clock performance of
//! the hot paths is reported separately in EXPERIMENTS.md §Perf.

pub mod latency;

pub use latency::LatencyModel;

/// Nanoseconds of virtual time.
pub type Ns = u64;

/// A monotonically advancing virtual clock.
#[derive(Debug, Default, Clone, Copy)]
pub struct Clock {
    now: Ns,
}

impl Clock {
    pub fn new() -> Self {
        Self { now: 0 }
    }

    pub fn now(&self) -> Ns {
        self.now
    }

    pub fn advance(&mut self, dt: Ns) -> Ns {
        self.now += dt;
        self.now
    }

    /// Move the clock forward to `t` if `t` is later.
    pub fn advance_to(&mut self, t: Ns) -> Ns {
        self.now = self.now.max(t);
        self.now
    }
}

/// Min-heap event queue for the accelerator/rack DES.
#[derive(Debug)]
pub struct EventQueue<T> {
    heap: std::collections::BinaryHeap<Entry<T>>,
    seq: u64,
}

#[derive(Debug)]
struct Entry<T> {
    at: Ns,
    seq: u64,
    payload: T,
}

impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<T> Eq for Entry<T> {}
impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Reverse for min-heap; seq breaks ties FIFO.
        other
            .at
            .cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> EventQueue<T> {
    pub fn new() -> Self {
        Self { heap: std::collections::BinaryHeap::new(), seq: 0 }
    }

    pub fn push(&mut self, at: Ns, payload: T) {
        self.heap.push(Entry { at, seq: self.seq, payload });
        self.seq += 1;
    }

    pub fn pop(&mut self) -> Option<(Ns, T)> {
        self.heap.pop().map(|e| (e.at, e.payload))
    }

    /// Drop all pending events, keeping the heap's allocation (the
    /// rack's batched serving path reuses the queue across runs).
    pub fn clear(&mut self) {
        self.heap.clear();
        self.seq = 0;
    }

    pub fn peek_time(&self) -> Option<Ns> {
        self.heap.peek().map(|e| e.at)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_advances() {
        let mut c = Clock::new();
        assert_eq!(c.now(), 0);
        c.advance(100);
        c.advance_to(50); // no-op backwards
        assert_eq!(c.now(), 100);
        c.advance_to(250);
        assert_eq!(c.now(), 250);
    }

    #[test]
    fn event_queue_orders_by_time_then_fifo() {
        let mut q = EventQueue::new();
        q.push(30, "c");
        q.push(10, "a1");
        q.push(10, "a2");
        q.push(20, "b");
        assert_eq!(q.pop(), Some((10, "a1")));
        assert_eq!(q.pop(), Some((10, "a2")));
        assert_eq!(q.pop(), Some((20, "b")));
        assert_eq!(q.peek_time(), Some(30));
        assert_eq!(q.pop(), Some((30, "c")));
        assert!(q.is_empty());
    }
}
