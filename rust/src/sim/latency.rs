//! Calibrated latency constants.
//!
//! Accelerator-side values come straight from the paper's measured
//! breakdown (Fig. 10, WebService on the U250 prototype); network, CPU
//! and CXL values from §6's testbed description and §7's CXL model
//! (following Pond [101]).

use super::Ns;

/// One accelerator's component latencies + the rack's network/CPU model.
#[derive(Debug, Clone)]
pub struct LatencyModel {
    // --- PULSE accelerator (Fig. 10) ------------------------------------
    /// FPGA network stack, per request arrival or departure: 426.3 ns.
    pub accel_net_stack_ns: f64,
    /// Scheduler decision: 5.1 ns.
    pub accel_sched_ns: f64,
    /// TCAM range translation: 22 ns.
    pub accel_tcam_ns: f64,
    /// Memory controller (row activation + fetch): 110 ns.
    pub accel_memctrl_ns: f64,
    /// Pipeline interconnect crossing: 47 ns.
    pub accel_interconnect_ns: f64,
    /// Logic pipeline, per instruction (250 MHz): 4 ns.
    pub accel_instr_ns: f64,
    /// DRAM streaming time per 8 B word past the fixed controller cost
    /// (6.25 GB/s per pipeline => 25 GB/s per node across 4 pipes).
    /// NOTE: the dispatch engine's offload *estimate* (`isa::CostModel`)
    /// deliberately uses a ~2.5× more conservative per-word figure — it
    /// is a static worst-case bound, which is how the paper's Table 3
    /// ratios (hash ≈ low, B+Tree ≈ 0.6-0.7 < η) emerge while the
    /// hardware still saturates bandwidth.
    pub accel_word_ns: f64,

    // --- network (§6 testbed: 100 Gbps, ToR switch) -----------------------
    /// One-way host NIC -> switch or switch -> NIC propagation+serdes.
    pub net_hop_ns: f64,
    /// Programmable switch pipeline (Tofino): routing a PULSE request.
    pub switch_pipeline_ns: f64,
    /// Host software (DPDK UDP stack) per send or receive.
    pub host_net_stack_ns: f64,
    /// Link bandwidth in bits per ns (100 Gbps = 12.5 B/ns).
    pub link_bytes_per_ns: f64,

    // --- CPU-side costs (RPC baselines, dispatch engine) ------------------
    /// Xeon 6240-class: per pointer-dereference iteration on the memnode
    /// CPU (cache-missing DRAM access ~80 ns + loop overhead).
    pub cpu_dram_ns: f64,
    /// Per ALU-ish instruction at 2.6 GHz (superscalar ≈ 3 IPC).
    pub cpu_instr_ns: f64,
    /// BlueField-2 ARM A72 slowdown factor vs the Xeon (paper §2.2:
    /// "processing speeds far slower"; Clio [74] measures ~3-4x).
    pub arm_slowdown: f64,
    /// Page fault handling (swap-based cache, Fastswap): kernel+driver.
    pub pagefault_sw_ns: f64,
}

impl Default for LatencyModel {
    fn default() -> Self {
        Self {
            accel_net_stack_ns: 426.3,
            accel_sched_ns: 5.1,
            accel_tcam_ns: 22.0,
            accel_memctrl_ns: 110.0,
            accel_interconnect_ns: 47.0,
            accel_instr_ns: 4.0,
            accel_word_ns: 1.28,
            net_hop_ns: 1000.0,
            switch_pipeline_ns: 600.0,
            host_net_stack_ns: 1500.0,
            link_bytes_per_ns: 12.5,
            cpu_dram_ns: 80.0,
            cpu_instr_ns: 0.128, // 1/(2.6GHz * 3 IPC)
            arm_slowdown: 3.5,
            pagefault_sw_ns: 3500.0,
        }
    }
}

impl LatencyModel {
    /// Serialization time for `bytes` on the 100 Gbps link.
    pub fn wire_ns(&self, bytes: usize) -> Ns {
        (bytes as f64 / self.link_bytes_per_ns).ceil() as Ns
    }

    /// One-way host -> (switch) -> host latency for a packet of `bytes`,
    /// including both NIC hops and the switch pipeline. This is the
    /// "5-10 µs network latency" per crossing the paper cites once host
    /// stacks are included.
    pub fn one_way_ns(&self, bytes: usize) -> Ns {
        (self.host_net_stack_ns
            + self.net_hop_ns
            + self.switch_pipeline_ns
            + self.net_hop_ns) as Ns
            + self.wire_ns(bytes)
    }

    /// Memory-node accelerator: fixed memory-pipeline time for an
    /// aggregated load of `words` 8 B words (+ write-back if `dirty`).
    pub fn mem_pipe_ns(&self, words: usize, dirty: bool) -> Ns {
        let stream = self.accel_word_ns * words as f64
            * if dirty { 2.0 } else { 1.0 };
        (self.accel_tcam_ns
            + self.accel_memctrl_ns
            + self.accel_interconnect_ns
            + stream) as Ns
    }

    /// Logic pipeline time for `instrs` dynamic instructions.
    pub fn logic_ns(&self, instrs: u32) -> Ns {
        (self.accel_instr_ns * instrs as f64) as Ns
    }

    /// In-accelerator request overhead (network stack in + out + sched).
    pub fn accel_request_overhead_ns(&self) -> Ns {
        (2.0 * self.accel_net_stack_ns + self.accel_sched_ns) as Ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig10_component_sum() {
        let m = LatencyModel::default();
        // Fig. 10 single-iteration path: sched 5.1 + tcam 22 +
        // memctrl 110 + interconnect 47 + logic 10 ≈ 194 ns.
        let iter = m.accel_sched_ns
            + m.accel_tcam_ns
            + m.accel_memctrl_ns
            + m.accel_interconnect_ns
            + 10.0;
        assert!((iter - 194.1).abs() < 1.0, "{iter}");
    }

    #[test]
    fn one_way_is_microseconds() {
        let m = LatencyModel::default();
        let t = m.one_way_ns(512);
        assert!(t > 3_000 && t < 10_000, "{t}");
    }

    #[test]
    fn wire_time_scales_with_size() {
        let m = LatencyModel::default();
        assert!(m.wire_ns(8192) > m.wire_ns(64));
        // 8 KB at 12.5 B/ns ≈ 656 ns
        assert_eq!(m.wire_ns(8192), 656);
    }

    #[test]
    fn mem_pipe_writeback_costs_more() {
        let m = LatencyModel::default();
        assert!(m.mem_pipe_ns(32, true) > m.mem_pipe_ns(32, false));
        // fixed part matches fig10: 22+110+47 = 179
        assert_eq!(m.mem_pipe_ns(0, false), 179);
    }
}
