//! Directed graph (adjacency lists) with bounded k-hop neighbor walks —
//! the first traversal in the repo whose next pointer has *data-
//! dependent fan-out*: the neighbor taken at each vertex is
//! `draws[hop] mod out_degree`, where the degree is read from the
//! vertex itself. Surveys of disaggregated memory single out exactly
//! this access pattern (graph walks) as the one caching handles worst,
//! which is why it joins the scenario set.
//!
//! Layouts:
//!   vertex (4 words): `[id(0), value(1), out_degree(2), adj(3)]`
//!   adjacency array: `out_degree` neighbor addresses + 3 pad words
//!   (the 4-word window read at the last slot stays in-allocation).
//!
//! The walk alternates vertex visits and adjacency-slot visits (phase
//! bit in sp[4], same trick as the radix trie): a vertex visit
//! accumulates `value` into sp[3], records `id` in sp[RESULT], consumes
//! one hop from sp[7], picks `slot = adj + 8·(draw mod degree)` and
//! advances into the array; the slot visit advances to the neighbor.
//! The per-hop draws are pre-seeded into sp[8..8+k] by the host
//! (`init()` computes them from the workload RNG), indexed by the
//! remaining-hop counter — so the host reference walk and every engine
//! replay the identical neighbor sequence, bit for bit.
//!
//! The walk ends after k hops or at a sink (degree 0); the final
//! scratchpad carries `sum(value)` over the k+1 visited vertices and
//! the last vertex id.

use std::sync::Arc;

use super::{SP_ACC_CNT, SP_ACC_SUM, SP_BUF_BASE, SP_BUF_LEN, SP_CURSOR, SP_RESULT};
use crate::compiler::{CompiledIter, IterBuilder};
use crate::isa::SP_WORDS;
use crate::mem::GAddr;
use crate::rack::{Op, Rack};
use crate::util::prng::Rng;

const V_WORDS: usize = 4;
/// Window is 4 words: pad adjacency arrays so the read at the last
/// slot stays inside the allocation.
const ADJ_PAD: usize = 3;

/// Remaining-hop counter.
pub const SP_HOPS: u32 = SP_CURSOR;
/// Phase bit: 0 = at a vertex, 1 = at an adjacency slot.
pub const SP_PHASE: u32 = SP_ACC_CNT;
/// Maximum hops per walk (one scratchpad draw per hop).
pub const MAX_HOPS: usize = SP_BUF_LEN;

/// Bounded k-hop walk. sp[HOPS] = k, sp[8..8+k] = non-negative draws
/// (indexed by remaining hops - 1), sp[ACC_SUM] accumulates values,
/// sp[RESULT] tracks the last vertex id.
pub fn khop_iter() -> CompiledIter {
    let mut b = IterBuilder::new();
    // The draw buffer sp[BUF_BASE..BUF_BASE+MAX_HOPS] is host-seeded and
    // read via a dynamic (Splx) index — declare the whole range.
    b.declare_sp_input_range(SP_BUF_BASE, SP_BUF_BASE + SP_BUF_LEN as u32);
    let phase = b.sp_input(SP_PHASE);
    let zero = b.imm(0);
    let one = b.imm(1);
    b.if_eq(phase, zero, |b| {
        // vertex visit: aggregate, then dispatch on degree
        let mark = b.temp_mark();
        let id = b.field(0);
        b.sp_store(SP_RESULT, id);
        let v = b.field(1);
        let sum = b.sp_input(SP_ACC_SUM);
        b.add_to(sum, v);
        b.sp_store(SP_ACC_SUM, sum);
        b.temp_release(mark);
        let hops = b.sp_input(SP_HOPS);
        b.if_le(hops, zero, |b| b.ret());
        let deg = b.field(2);
        b.if_eq(deg, zero, |b| b.ret()); // sink
        let h2 = b.addi(hops, -1);
        b.sp_store(SP_HOPS, h2);
        let draw = b.sp_dyn(h2, SP_BUF_BASE);
        let idx = b.modu(draw, deg);
        let off = b.shl(idx, 3);
        let aptr = b.field(3);
        let slot = b.add(aptr, off);
        b.sp_store(SP_PHASE, one);
        b.advance(slot);
    });
    // slot visit: follow the chosen neighbor
    let nxt = b.field(0);
    b.if_eq(nxt, zero, |b| b.trap()); // corrupt adjacency — never legal
    b.sp_store(SP_PHASE, zero);
    b.advance(nxt);
    b.finish().expect("graph khop")
}

pub struct AdjGraph {
    /// Vertex index -> global address.
    pub verts: Vec<GAddr>,
    khop_p: Arc<CompiledIter>,
}

impl AdjGraph {
    /// Random directed graph: `n` vertices, out-degree uniform in
    /// [0, max_deg], neighbors uniform over all vertices (self-loops
    /// allowed — they are harmless for walks). Values are seeded.
    pub fn build(rack: &mut Rack, n: usize, max_deg: usize, seed: u64) -> Self {
        assert!(n > 0);
        let mut rng = Rng::with_stream(seed, 0x6AF);
        let verts: Vec<GAddr> = (0..n)
            .map(|i| {
                let a = rack.alloc((V_WORDS * 8) as u64);
                let value = (rng.next_i64() >> 16).wrapping_add(i as i64);
                rack.write_words(a, &[i as i64, value, 0, 0]);
                a
            })
            .collect();
        for &va in verts.iter() {
            let deg = rng.below(max_deg as u64 + 1) as usize;
            let mut hdr = [0i64; V_WORDS];
            rack.read_words(va, &mut hdr);
            hdr[2] = deg as i64;
            if deg > 0 {
                let adj = rack.alloc(((deg + ADJ_PAD) * 8) as u64);
                let mut slots: Vec<i64> = (0..deg)
                    .map(|_| verts[rng.below(n as u64) as usize] as i64)
                    .collect();
                slots.resize(deg + ADJ_PAD, 0);
                rack.write_words(adj, &slots);
                hdr[3] = adj as i64;
            }
            rack.write_words(va, &hdr);
        }
        Self { verts, khop_p: Arc::new(khop_iter()) }
    }

    pub fn khop_program(&self) -> Arc<CompiledIter> {
        self.khop_p.clone()
    }

    pub fn vertices(&self) -> usize {
        self.verts.len()
    }

    /// `init()` for a walk: seed the scratchpad with hops + draws.
    fn walk_sp(hops: u32, draws: &[i64]) -> [i64; SP_WORDS] {
        assert!(hops as usize <= MAX_HOPS && draws.len() >= hops as usize);
        let mut sp = [0i64; SP_WORDS];
        sp[SP_HOPS as usize] = hops as i64;
        for (i, &d) in draws.iter().take(hops as usize).enumerate() {
            assert!(d >= 0, "draws must be non-negative");
            sp[SP_BUF_BASE as usize + i] = d;
        }
        sp
    }

    /// Single-stage k-hop op (conformance / bench streams).
    pub fn khop_op(&self, start: usize, hops: u32, draws: &[i64]) -> Op {
        Op::new(
            self.khop_p.clone(),
            self.verts[start % self.verts.len()],
            Self::walk_sp(hops, draws),
        )
    }

    /// Offloaded walk: (sum of visited values, last vertex id).
    pub fn khop(
        &self,
        rack: &mut Rack,
        start: usize,
        hops: u32,
        draws: &[i64],
    ) -> (i64, i64) {
        let sp = Self::walk_sp(hops, draws);
        let (_st, sp, _) =
            rack.traverse(&self.khop_p, self.verts[start % self.verts.len()], sp);
        (sp[SP_ACC_SUM as usize], sp[SP_RESULT as usize])
    }

    /// Host reference walk — mirrors the program's arithmetic exactly
    /// (remaining-hop indexed draws, truncating div-based modulo).
    pub fn host_khop(
        &self,
        rack: &mut Rack,
        start: usize,
        hops: u32,
        draws: &[i64],
    ) -> (i64, i64) {
        let mut cur = self.verts[start % self.verts.len()];
        let mut sum = 0i64;
        let mut last;
        let mut remaining = hops as i64;
        loop {
            let mut v = [0i64; V_WORDS];
            rack.read_words(cur, &mut v);
            last = v[0];
            sum = sum.wrapping_add(v[1]);
            if remaining <= 0 || v[2] == 0 {
                return (sum, last);
            }
            remaining -= 1;
            let draw = draws[remaining as usize];
            let idx = draw % v[2];
            let mut w = [0i64; 1];
            rack.read_words(v[3] as GAddr + idx as u64 * 8, &mut w);
            cur = w[0] as GAddr;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::DEFAULT_ETA;
    use crate::rack::RackConfig;

    fn rack() -> Rack {
        Rack::new(RackConfig {
            nodes: 2,
            node_capacity: 64 << 20,
            granularity: 1 << 20,
            ..Default::default()
        })
    }

    fn draws(rng: &mut Rng, n: usize) -> Vec<i64> {
        (0..n).map(|_| (rng.next_u64() >> 1) as i64).collect()
    }

    #[test]
    fn offloaded_walk_matches_host_walk() {
        let mut r = rack();
        let g = AdjGraph::build(&mut r, 500, 6, 42);
        let mut rng = Rng::new(7);
        for case in 0..60 {
            let start = rng.below(500) as usize;
            let hops = 1 + rng.below(MAX_HOPS as u64 - 1) as u32;
            let d = draws(&mut rng, hops as usize);
            assert_eq!(
                g.khop(&mut r, start, hops, &d),
                g.host_khop(&mut r, start, hops, &d),
                "case {case} start {start} hops {hops}"
            );
        }
    }

    #[test]
    fn zero_hop_walk_reads_only_the_start() {
        let mut r = rack();
        let g = AdjGraph::build(&mut r, 50, 4, 1);
        let (sum, last) = g.khop(&mut r, 7, 0, &[]);
        let (hsum, hlast) = g.host_khop(&mut r, 7, 0, &[]);
        assert_eq!((sum, last), (hsum, hlast));
        assert_eq!(last, 7);
    }

    #[test]
    fn sinks_end_walks_early() {
        let mut r = rack();
        // max_deg 1: plenty of degree-0 sinks
        let g = AdjGraph::build(&mut r, 200, 1, 9);
        let mut rng = Rng::new(3);
        for _ in 0..40 {
            let start = rng.below(200) as usize;
            let d = draws(&mut rng, 10);
            assert_eq!(
                g.khop(&mut r, start, 10, &d),
                g.host_khop(&mut r, start, 10, &d)
            );
        }
    }

    #[test]
    fn walks_cross_memory_nodes() {
        let mut r = Rack::new(RackConfig {
            nodes: 4,
            node_capacity: 64 << 20,
            granularity: 4096,
            ..Default::default()
        });
        let g = AdjGraph::build(&mut r, 2000, 5, 11);
        let mut rng = Rng::new(5);
        let mut ops = Vec::new();
        for _ in 0..30 {
            let start = rng.below(2000) as usize;
            let d = draws(&mut rng, 12);
            let op = g.khop_op(start, 12, &d);
            let sp = r.run_op_functional(&op);
            let (hsum, hlast) = g.host_khop(&mut r, start, 12, &d);
            assert_eq!(sp[SP_ACC_SUM as usize], hsum);
            assert_eq!(sp[SP_RESULT as usize], hlast);
            ops.push(op);
        }
        // tiny slabs spread the 2000 vertices over all four nodes: the
        // DES must see real cross-node traversal traffic
        let rep = r.serve_batch(&ops, 4);
        assert_eq!(rep.completed, 30);
        assert_eq!(rep.trapped, 0);
        assert!(
            rep.cross_node_requests > 0,
            "k-hop walks never crossed memory nodes"
        );
    }

    #[test]
    fn program_sits_near_the_offload_boundary() {
        let it = khop_iter();
        assert!(it.offloadable(DEFAULT_ETA), "ratio {}", it.ratio());
        // the fan-out dispatch makes this the most compute-heavy
        // iterator in the repo — BTrDB-like, close to the η boundary
        assert!(it.ratio() > 0.5, "ratio {}", it.ratio());
        assert_eq!(it.program.load_words, 4);
    }
}
