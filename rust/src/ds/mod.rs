//! Data structures ported to the PULSE iterator model (paper §3,
//! Table 1/Table 5, Appendix B): the 13 STL / Boost / Google-BTree
//! structures of the paper, the B+Tree behind the WiredTiger and BTrDB
//! applications, plus three scenario-expansion structures that push the
//! model past the paper's set (fence-key towers, huge fan-out, data-
//! dependent fan-out).
//!
//! Family table (traversal → module; "mutating programs" are the
//! offloaded *write* traversals — `writes_data` stages whose dirty
//! windows stream back into node DRAM, pinned by the mixed read-write
//! conformance suite):
//!
//! | family                      | module      | offloaded traversal      | mutating programs        |
//! |-----------------------------|-------------|--------------------------|--------------------------|
//! | std::forward_list / list    | `list`      | chain find / chain sum   | push_front (sentinel)    |
//! | unordered_map / set         | `hashmap`   | bucket-chain find/update | put on existing key      |
//! | boost::bimap                | `bimap`     | chain find (both dirs)   | —                        |
//! | map/set/multi* + AVL/splay/ | `bst`       | lower_bound walk         | —                        |
//! |   scapegoat (Boost)         |             |                          |                          |
//! | Google cpp-btree            | `btree`     | internal_locate descend  | —                        |
//! | B+Tree (WiredTiger/BTrDB)   | `bplustree` | get / locate / scan / sum| leaf value update        |
//! | skip list (towers)          | `skiplist`  | find / locate / scan     | —                        |
//! | 256-way radix trie (ART)    | `radixtrie` | byte-dispatch lookup     | —                        |
//! | directed graph (adj. lists) | `graph`     | bounded k-hop walk       | —                        |
//!
//! Every structure here is also registered in
//! `testgen::StructureKind` and pinned by the cross-backend
//! differential suite (`rust/tests/conformance.rs`); see
//! `rust/src/rack/README.md` ("Adding a scenario") for the checklist.
//!
//! Each structure provides:
//! * host-side build/mutation through the `Rack` (allocation + writes go
//!   through the normal translation path);
//! * compiled PULSE iterator(s) for its traversals (via the
//!   `compiler::IterBuilder` DSL — the analogue of the paper's C++ →
//!   LLVM → PULSE-ISA flow);
//! * a `verify` helper used by tests to compare offloaded results
//!   against a host-side reference walk.
//!
//! Layouts use 8 B words; word 0 of every node is the first field the
//! aggregated LOAD fetches. Null pointers are encoded as 0.
//!
//! Scratchpad conventions (shared with `python/compile/kernels/
//! programs.py`):
//!   sp[0] = search key / argument
//!   sp[1] = result value (or found-node pointer)
//!   sp[2] = status flag (KEY_NOT_FOUND)
//!   sp[3..8] = aggregation state (sum/min/max/count...)
//!   sp[8..]  = bulk result buffer (range scans)

pub mod bimap;
pub mod bplustree;
pub mod bst;
pub mod btree;
pub mod graph;
pub mod hashmap;
pub mod list;
pub mod radixtrie;
pub mod skiplist;

pub use bimap::Bimap;
pub use bplustree::BPlusTree;
pub use bst::{BstKind, BstMap};
pub use btree::GoogleBtree;
pub use graph::AdjGraph;
pub use hashmap::{HashMapDs, HashSetDs};
pub use list::{ForwardList, LinkedList};
pub use radixtrie::RadixTrie;
pub use skiplist::SkipList;

/// Scratchpad word conventions.
pub const SP_KEY: u32 = 0;
pub const SP_RESULT: u32 = 1;
pub const SP_FLAG: u32 = 2;
pub const SP_ACC_SUM: u32 = 3;
pub const SP_ACC_CNT: u32 = 4;
pub const SP_ACC_MIN: u32 = 5;
pub const SP_ACC_MAX: u32 = 6;
pub const SP_CURSOR: u32 = 7;
pub const SP_BUF_BASE: u32 = 8;
pub const SP_BUF_LEN: usize = 24;

/// Sentinel for missing keys.
pub const KEY_NOT_FOUND: i64 = i64::MAX;

/// Every built-in scenario iterator, by CLI name. One authoritative
/// list shared by `pulse inspect`, `pulse lint --all-scenarios`, the
/// CI lint smoke step, and the "all builtins analyze clean" unit test
/// in `isa::analyze` — adding a scenario here enrolls it everywhere.
pub fn builtin_iters() -> Vec<(&'static str, crate::compiler::CompiledIter)> {
    vec![
        ("list-find", list::find_iter()),
        ("list-sum", list::sum_iter()),
        ("list-push-front", list::push_front_iter()),
        ("chain-find", hashmap::chain_find_iter()),
        ("chain-update", hashmap::chain_update_iter()),
        ("bst-lower-bound", bst::lower_bound_iter()),
        ("btree-locate", btree::locate_iter()),
        ("bplustree-get", bplustree::get_iter()),
        ("bplustree-locate", bplustree::locate_iter()),
        ("bplustree-scan", bplustree::scan_iter()),
        ("bplustree-sum", bplustree::sum_iter()),
        ("bplustree-update", bplustree::update_iter()),
        ("skiplist-find", skiplist::find_iter()),
        ("skiplist-locate", skiplist::locate_iter()),
        ("skiplist-scan", skiplist::scan_iter()),
        ("radixtrie-lookup", radixtrie::lookup_iter()),
        ("graph-khop", graph::khop_iter()),
    ]
}
