//! Boost `bimap` on disaggregated memory (paper Appendix B.2, Listings
//! 6–7): bidirectional map realized as two hash indexes over shared
//! pairs; both directions use the same chain-walk program as
//! `unordered_map` (Table 5: same internal function).

use super::hashmap::HashMapDs;
use crate::rack::Rack;

pub struct Bimap {
    left: HashMapDs,  // key -> value
    right: HashMapDs, // value -> key
    pub len: usize,
}

impl Bimap {
    pub fn build(rack: &mut Rack, buckets: usize) -> Self {
        Self {
            left: HashMapDs::build(rack, buckets),
            right: HashMapDs::build(rack, buckets),
            len: 0,
        }
    }

    /// Insert a (left, right) pair; both directions become queryable.
    pub fn insert(&mut self, rack: &mut Rack, l: i64, r: i64) {
        self.left.insert(rack, l, r);
        self.right.insert(rack, r, l);
        self.len += 1;
    }

    /// Offloaded left→right lookup.
    pub fn get_by_left(&self, rack: &mut Rack, l: i64) -> Option<i64> {
        self.left.get(rack, l)
    }

    /// Offloaded right→left lookup.
    pub fn get_by_right(&self, rack: &mut Rack, r: i64) -> Option<i64> {
        self.right.get(rack, r)
    }

    /// Forward index (op construction in benches/tests).
    pub fn left_index(&self) -> &HashMapDs {
        &self.left
    }

    /// Reverse index.
    pub fn right_index(&self) -> &HashMapDs {
        &self.right
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rack::RackConfig;

    #[test]
    fn bidirectional_lookup() {
        let mut rk = Rack::new(RackConfig {
            nodes: 2,
            node_capacity: 32 << 20,
            granularity: 1 << 20,
            ..Default::default()
        });
        let mut bm = Bimap::build(&mut rk, 64);
        for i in 0..200 {
            bm.insert(&mut rk, i, 10_000 + i);
        }
        assert_eq!(bm.get_by_left(&mut rk, 42), Some(10_042));
        assert_eq!(bm.get_by_right(&mut rk, 10_042), Some(42));
        assert_eq!(bm.get_by_left(&mut rk, 999), None);
        assert_eq!(bm.get_by_right(&mut rk, 999), None);
        assert_eq!(bm.len, 200);
    }
}
