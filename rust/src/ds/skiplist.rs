//! Probabilistic skip list on disaggregated memory (scenario expansion
//! beyond the paper's Table 1 set; the canonical "tower" structure of
//! RDMA key-value stores).
//!
//! Node layout (19 words, 152 B — inside the 256 B window):
//!   `[key(0), value(1), height(2), next[0..8) (3..11),
//!     next_keys[0..8) (11..19)]`
//!
//! Every tower level stores the *successor's key* next to the successor
//! pointer (`next_keys`, i64::MAX when the pointer is null) — the fence
//! keys RDMA skip lists replicate so a traversal can decide
//! right-vs-down from the *current* node alone. That is exactly what
//! makes the search offloadable: one aggregated LOAD per iteration,
//! dynamic tower indexing via `field_dyn` on the level cursor, and no
//! peeking at the remote successor.
//!
//! Offloaded iterators:
//!  * `find_iter`   — classic search: move right while
//!                    `next_keys[lvl] <= needle`, else descend; at level
//!                    0 check the node key (sp[RESULT]/sp[FLAG]);
//!  * `locate_iter` — same walk, returns the greatest node with
//!                    key <= needle (scan entry point);
//!  * `scan_iter`   — level-0 chain scan emitting one record per
//!                    iteration into sp[8..32], yielding on a full
//!                    buffer (YCSB-E over the skip list).
//!
//! Host-side mutation (insert / remove / update-in-place) maintains the
//! fence-key invariant `next_keys[l] == key(next[l])`.

use std::sync::Arc;

use super::{KEY_NOT_FOUND, SP_BUF_BASE, SP_BUF_LEN, SP_CURSOR, SP_FLAG, SP_KEY, SP_RESULT};
use crate::compiler::{CompiledIter, IterBuilder};
use crate::isa::{Status, SP_WORDS};
use crate::mem::GAddr;
use crate::rack::{Op, Rack, Stage, StartAddr};
use crate::util::prng::Rng;

/// Tower height cap; towers are geometric(1/2), so 8 levels cover
/// ~2^8 elements per expected top-level hop.
pub const MAX_LEVEL: usize = 8;
pub const NODE_WORDS: usize = 3 + 2 * MAX_LEVEL; // 19
const NEXT0: u32 = 3;
const NKEY0: u32 = NEXT0 + MAX_LEVEL as u32; // 11

/// Search: sp[KEY] = needle, sp[CURSOR] = start level (top of the
/// list). On a hit sp[RESULT] = value, sp[FLAG] = 0; on a miss
/// sp[FLAG] = KEY_NOT_FOUND.
pub fn find_iter() -> CompiledIter {
    let mut b = IterBuilder::new();
    let needle = b.sp_input(SP_KEY);
    let lvl = b.sp_input(SP_CURSOR);
    let nk = b.field_dyn(lvl, NKEY0, NODE_WORDS as u32 - 1);
    let np = b.field_dyn(lvl, NEXT0, NKEY0 - 1);
    // fence key covers the successor: move right without touching it
    b.if_le(nk, needle, |b| b.advance(np));
    let zero = b.imm(0);
    b.if_eq(lvl, zero, |b| {
        let k = b.field(0);
        b.if_eq(k, needle, |b| {
            let v = b.field(1);
            b.sp_store(SP_RESULT, v);
            b.sp_store(SP_FLAG, zero);
            b.ret();
        });
        let nf = b.imm(KEY_NOT_FOUND);
        b.sp_store(SP_FLAG, nf);
        b.ret();
    });
    // descend: same node, one level down (costs an iteration, exactly
    // like the FPGA prototype's per-visit accounting)
    let down = b.addi(lvl, -1);
    b.sp_store(SP_CURSOR, down);
    let me = b.cur_ptr();
    b.advance(me);
    b.finish().expect("skiplist find")
}

/// Locate: identical walk, but at level 0 stores the *current node
/// address* (greatest key <= needle; the head sentinel when needle
/// precedes everything) into sp[RESULT] — the scan entry point.
pub fn locate_iter() -> CompiledIter {
    let mut b = IterBuilder::new();
    let needle = b.sp_input(SP_KEY);
    let lvl = b.sp_input(SP_CURSOR);
    let nk = b.field_dyn(lvl, NKEY0, NODE_WORDS as u32 - 1);
    let np = b.field_dyn(lvl, NEXT0, NKEY0 - 1);
    b.if_le(nk, needle, |b| b.advance(np));
    let zero = b.imm(0);
    b.if_eq(lvl, zero, |b| {
        let me = b.cur_ptr();
        b.sp_store(SP_RESULT, me);
        b.ret();
    });
    let down = b.addi(lvl, -1);
    b.sp_store(SP_CURSOR, down);
    let me = b.cur_ptr();
    b.advance(me);
    b.finish().expect("skiplist locate")
}

/// Level-0 range scan starting at a located node: sp[KEY] = lo bound,
/// sp[2] = remaining, sp[3] = emitted this round, values appended at
/// sp[8..32]. Returns with sp[RESULT] = continuation node (0 = end of
/// chain) when the buffer fills, the count is satisfied, or the chain
/// ends — the same continuation protocol as `bplustree::scan_iter`.
pub fn scan_iter() -> CompiledIter {
    let mut b = IterBuilder::new();
    let lo = b.sp_input(SP_KEY);
    let k = b.field(0);
    let np = b.field(NEXT0);
    let zero = b.imm(0);
    b.if_lt(k, lo, |b| {
        // pre-range node (head sentinel or the located predecessor)
        b.if_eq(np, zero, |b| {
            b.sp_store(SP_RESULT, zero);
            b.ret();
        });
        b.advance(np);
    });
    let v = b.field(1);
    let oc = b.sp_input(3);
    b.sp_store_dyn(oc, SP_BUF_BASE, v);
    let oc2 = b.addi(oc, 1);
    b.sp_store(3, oc2);
    let rem = b.sp_input(2);
    let rem2 = b.addi(rem, -1);
    b.sp_store(2, rem2);
    b.sp_store(SP_RESULT, np);
    b.if_eq(np, zero, |b| b.ret());
    b.if_le(rem2, zero, |b| b.ret());
    let cap = b.imm(SP_BUF_LEN as i64);
    b.if_ge(oc2, cap, |b| b.ret());
    b.advance(np);
    b.finish().expect("skiplist scan")
}

pub struct SkipList {
    pub head: GAddr,
    /// Highest level currently in use (1..=MAX_LEVEL).
    pub level: usize,
    pub len: usize,
    rng: Rng,
    find_p: Arc<CompiledIter>,
    locate_p: Arc<CompiledIter>,
    scan_p: Arc<CompiledIter>,
}

impl SkipList {
    /// Allocate the head sentinel (key = i64::MIN, full-height tower,
    /// all fence keys = i64::MAX). Application keys must satisfy
    /// `i64::MIN < key < i64::MAX`.
    pub fn new(rack: &mut Rack, seed: u64) -> Self {
        let head = rack.alloc((NODE_WORDS * 8) as u64);
        let mut node = [0i64; NODE_WORDS];
        node[0] = i64::MIN;
        node[2] = MAX_LEVEL as i64;
        for l in 0..MAX_LEVEL {
            node[NKEY0 as usize + l] = i64::MAX;
        }
        rack.write_words(head, &node);
        Self {
            head,
            level: 1,
            len: 0,
            rng: Rng::with_stream(seed, 0x51A9),
            find_p: Arc::new(find_iter()),
            locate_p: Arc::new(locate_iter()),
            scan_p: Arc::new(scan_iter()),
        }
    }

    pub fn find_program(&self) -> Arc<CompiledIter> {
        self.find_p.clone()
    }

    pub fn locate_program(&self) -> Arc<CompiledIter> {
        self.locate_p.clone()
    }

    pub fn scan_program(&self) -> Arc<CompiledIter> {
        self.scan_p.clone()
    }

    /// Level cursor the offloaded walks start from.
    pub fn start_level(&self) -> i64 {
        (self.level - 1) as i64
    }

    fn read_node(rack: &mut Rack, addr: GAddr) -> [i64; NODE_WORDS] {
        let mut n = [0i64; NODE_WORDS];
        rack.read_words(addr, &mut n);
        n
    }

    fn random_height(&mut self) -> usize {
        let mut h = 1;
        while h < MAX_LEVEL && self.rng.chance(0.5) {
            h += 1;
        }
        h
    }

    /// Insert or update-in-place (host path; maintains fence keys).
    pub fn insert(&mut self, rack: &mut Rack, key: i64, value: i64) {
        assert!(key > i64::MIN && key < i64::MAX, "reserved key {key}");
        let mut preds = [self.head; MAX_LEVEL];
        let mut cur = self.head;
        let mut node = Self::read_node(rack, cur);
        for lvl in (0..self.level).rev() {
            loop {
                let nk = node[NKEY0 as usize + lvl];
                if nk > key {
                    break;
                }
                if nk == key {
                    // key present: overwrite the value in place
                    let target = node[NEXT0 as usize + lvl] as GAddr;
                    let mut t = Self::read_node(rack, target);
                    t[1] = value;
                    rack.write_words(target, &t);
                    return;
                }
                cur = node[NEXT0 as usize + lvl] as GAddr;
                node = Self::read_node(rack, cur);
            }
            preds[lvl] = cur;
        }
        let h = self.random_height();
        let addr = rack.alloc((NODE_WORDS * 8) as u64);
        let mut fresh = [0i64; NODE_WORDS];
        fresh[0] = key;
        fresh[1] = value;
        fresh[2] = h as i64;
        for lvl in 0..MAX_LEVEL {
            fresh[NKEY0 as usize + lvl] = i64::MAX;
        }
        // splice below the predecessors first, then publish the node
        for lvl in 0..h {
            let mut p = Self::read_node(rack, preds[lvl]);
            fresh[NEXT0 as usize + lvl] = p[NEXT0 as usize + lvl];
            fresh[NKEY0 as usize + lvl] = p[NKEY0 as usize + lvl];
            p[NEXT0 as usize + lvl] = addr as i64;
            p[NKEY0 as usize + lvl] = key;
            rack.write_words(preds[lvl], &p);
        }
        rack.write_words(addr, &fresh);
        if h > self.level {
            self.level = h;
        }
        self.len += 1;
    }

    /// Remove a key (host path); false if absent.
    pub fn remove(&mut self, rack: &mut Rack, key: i64) -> bool {
        let mut preds = [self.head; MAX_LEVEL];
        let mut cur = self.head;
        let mut node = Self::read_node(rack, cur);
        for lvl in (0..self.level).rev() {
            while node[NKEY0 as usize + lvl] < key {
                cur = node[NEXT0 as usize + lvl] as GAddr;
                node = Self::read_node(rack, cur);
            }
            preds[lvl] = cur;
        }
        let p0 = Self::read_node(rack, preds[0]);
        if p0[NKEY0 as usize] != key {
            return false;
        }
        let target = p0[NEXT0 as usize] as GAddr;
        let t = Self::read_node(rack, target);
        let h = t[2] as usize;
        for lvl in 0..h {
            let mut p = Self::read_node(rack, preds[lvl]);
            if p[NEXT0 as usize + lvl] as GAddr == target {
                p[NEXT0 as usize + lvl] = t[NEXT0 as usize + lvl];
                p[NKEY0 as usize + lvl] = t[NKEY0 as usize + lvl];
                rack.write_words(preds[lvl], &p);
            }
        }
        let head = Self::read_node(rack, self.head);
        while self.level > 1 && head[NEXT0 as usize + self.level - 1] == 0 {
            self.level -= 1;
        }
        self.len -= 1;
        true
    }

    /// Single-stage find op (conformance / bench streams).
    pub fn find_op(&self, key: i64) -> Op {
        let mut sp = [0i64; SP_WORDS];
        sp[SP_KEY as usize] = key;
        sp[SP_CURSOR as usize] = self.start_level();
        Op::new(self.find_p.clone(), self.head, sp)
    }

    /// Two-stage YCSB-E-style scan op: locate the greatest key <= `lo`,
    /// then stream `count` records through the buffered scan with
    /// continuation rounds (`repeat_while`), exactly like the
    /// WiredTiger B+Tree op chain.
    pub fn scan_op(&self, lo: i64, count: usize) -> Op {
        let mut sp1 = [0i64; SP_WORDS];
        sp1[SP_KEY as usize] = lo;
        sp1[SP_CURSOR as usize] = self.start_level();
        let s1 = Stage::new(self.locate_p.clone(), self.head, sp1);
        let mut s2 = Stage::new(self.scan_p.clone(), 0, [0i64; SP_WORDS]);
        s2.start = StartAddr::FromPrevSp(SP_RESULT);
        s2.sp[SP_KEY as usize] = lo;
        s2.sp[2] = count as i64;
        s2.sp_overrides = vec![(3, 0)];
        s2.repeat_while = Some((SP_RESULT, 2));
        Op { stages: vec![s1, s2], cpu_post_ns: 0 }
    }

    /// Offloaded find.
    pub fn find(&self, rack: &mut Rack, key: i64) -> Option<i64> {
        let mut sp = [0i64; SP_WORDS];
        sp[SP_KEY as usize] = key;
        sp[SP_CURSOR as usize] = self.start_level();
        let (_st, sp, _) = rack.traverse(&self.find_p, self.head, sp);
        (sp[SP_FLAG as usize] != KEY_NOT_FOUND)
            .then_some(sp[SP_RESULT as usize])
    }

    /// Offloaded range scan: up to `count` values with key >= `lo`,
    /// draining the scratchpad buffer between continuation rounds.
    pub fn scan(&self, rack: &mut Rack, lo: i64, count: usize) -> Vec<i64> {
        let mut sp = [0i64; SP_WORDS];
        sp[SP_KEY as usize] = lo;
        sp[SP_CURSOR as usize] = self.start_level();
        let (_st, sp, _) = rack.traverse(&self.locate_p, self.head, sp);
        let mut cur = sp[SP_RESULT as usize] as GAddr;
        let mut out = Vec::with_capacity(count);
        let mut remaining = count as i64;
        while remaining > 0 && cur != 0 {
            let mut sp = [0i64; SP_WORDS];
            sp[SP_KEY as usize] = lo;
            sp[2] = remaining;
            sp[3] = 0;
            let (st, sp, _) = rack.traverse(&self.scan_p, cur, sp);
            let emitted = sp[3] as usize;
            out.extend_from_slice(
                &sp[SP_BUF_BASE as usize..SP_BUF_BASE as usize + emitted],
            );
            if st != Status::Return {
                break;
            }
            remaining -= emitted as i64;
            cur = sp[SP_RESULT as usize] as GAddr;
            if emitted == 0 && cur == 0 {
                break;
            }
        }
        out.truncate(count);
        out
    }

    /// Host reference find (level-0 chain walk; independent of towers).
    pub fn host_find(&self, rack: &mut Rack, key: i64) -> Option<i64> {
        let head = Self::read_node(rack, self.head);
        let mut cur = head[NEXT0 as usize] as GAddr;
        while cur != 0 {
            let n = Self::read_node(rack, cur);
            if n[0] == key {
                return Some(n[1]);
            }
            if n[0] > key {
                return None;
            }
            cur = n[NEXT0 as usize] as GAddr;
        }
        None
    }

    /// Host reference scan.
    pub fn host_scan(&self, rack: &mut Rack, lo: i64, count: usize) -> Vec<i64> {
        let head = Self::read_node(rack, self.head);
        let mut cur = head[NEXT0 as usize] as GAddr;
        let mut out = Vec::with_capacity(count);
        while cur != 0 && out.len() < count {
            let n = Self::read_node(rack, cur);
            if n[0] >= lo {
                out.push(n[1]);
            }
            cur = n[NEXT0 as usize] as GAddr;
        }
        out
    }

    /// Tower invariant: `next_keys[l] == key(next[l])` (i64::MAX for
    /// null), every level-l link skips only smaller towers. Test hook.
    pub fn check_invariants(&self, rack: &mut Rack) {
        let mut cur = self.head;
        while cur != 0 {
            let n = Self::read_node(rack, cur);
            let h = n[2] as usize;
            for lvl in 0..h {
                let np = n[NEXT0 as usize + lvl] as GAddr;
                let nk = n[NKEY0 as usize + lvl];
                if np == 0 {
                    assert_eq!(nk, i64::MAX, "null link with fence {nk}");
                } else {
                    let succ = Self::read_node(rack, np);
                    assert_eq!(nk, succ[0], "fence key out of sync");
                    assert!(
                        succ[2] as usize > lvl,
                        "level-{lvl} link into a shorter tower"
                    );
                }
            }
            cur = n[NEXT0 as usize] as GAddr;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::DEFAULT_ETA;
    use crate::rack::RackConfig;

    fn rack() -> Rack {
        Rack::new(RackConfig {
            nodes: 2,
            node_capacity: 32 << 20,
            granularity: 1 << 20,
            ..Default::default()
        })
    }

    #[test]
    fn find_hit_and_miss() {
        let mut r = rack();
        let mut s = SkipList::new(&mut r, 7);
        for i in 0..500 {
            s.insert(&mut r, i * 3, i * 30);
        }
        s.check_invariants(&mut r);
        for i in (0..500).step_by(17) {
            assert_eq!(s.find(&mut r, i * 3), Some(i * 30), "key {}", i * 3);
            assert_eq!(s.find(&mut r, i * 3 + 1), None);
        }
        assert_eq!(s.find(&mut r, -5), None);
        assert_eq!(s.find(&mut r, 5000), None);
    }

    #[test]
    fn offloaded_matches_host_walk() {
        let mut r = rack();
        let mut s = SkipList::new(&mut r, 11);
        for i in 0..300 {
            s.insert(&mut r, (i * 7) % 211, i);
        }
        for k in 0..230 {
            assert_eq!(s.find(&mut r, k), s.host_find(&mut r, k), "key {k}");
        }
    }

    #[test]
    fn insert_overwrites_in_place() {
        let mut r = rack();
        let mut s = SkipList::new(&mut r, 3);
        s.insert(&mut r, 42, 1);
        s.insert(&mut r, 42, 2);
        assert_eq!(s.len, 1);
        assert_eq!(s.find(&mut r, 42), Some(2));
    }

    #[test]
    fn remove_unlinks_all_levels() {
        let mut r = rack();
        let mut s = SkipList::new(&mut r, 5);
        for i in 0..200 {
            s.insert(&mut r, i, i * 10);
        }
        for i in (0..200).step_by(2) {
            assert!(s.remove(&mut r, i), "key {i}");
        }
        assert!(!s.remove(&mut r, 0));
        s.check_invariants(&mut r);
        for i in 0..200 {
            let want = (i % 2 == 1).then_some(i * 10);
            assert_eq!(s.find(&mut r, i), want, "key {i}");
            assert_eq!(s.host_find(&mut r, i), want, "host key {i}");
        }
        assert_eq!(s.len, 100);
    }

    #[test]
    fn scan_matches_host_with_continuations() {
        let mut r = rack();
        let mut s = SkipList::new(&mut r, 9);
        for i in 0..400 {
            s.insert(&mut r, i * 2, i * 20);
        }
        // > SP_BUF_LEN forces continuation rounds
        for (lo, n) in [(100, 10), (0, 100), (399, 5), (795, 50), (801, 3)] {
            assert_eq!(
                s.scan(&mut r, lo, n),
                s.host_scan(&mut r, lo, n),
                "scan {lo} +{n}"
            );
        }
    }

    #[test]
    fn scan_op_chain_runs_functionally() {
        let mut r = rack();
        let mut s = SkipList::new(&mut r, 13);
        for i in 0..200 {
            s.insert(&mut r, i, i + 1000);
        }
        let op = s.scan_op(50, 40);
        let sp = r.run_op_functional(&op);
        // the last continuation round's buffer is non-empty
        assert!(sp[3] > 0);
        assert_eq!(s.scan(&mut r, 50, 40), s.host_scan(&mut r, 50, 40));
    }

    #[test]
    fn spans_memory_nodes() {
        let mut r = Rack::new(RackConfig {
            nodes: 4,
            node_capacity: 32 << 20,
            granularity: 4096,
            ..Default::default()
        });
        let mut s = SkipList::new(&mut r, 21);
        for i in 0..1500 {
            s.insert(&mut r, i, i);
        }
        // tiny slabs spread the towers over every node
        let owners: std::collections::BTreeSet<_> = (0..r.alloc.nodes())
            .filter(|&n| r.alloc.node_used(n as u16) > 0)
            .collect();
        assert!(owners.len() >= 2, "placement not distributed");
        assert_eq!(s.find(&mut r, 1337), Some(1337));
        assert_eq!(s.find(&mut r, 1501), None);
        assert_eq!(s.scan(&mut r, 700, 30), s.host_scan(&mut r, 700, 30));
    }

    #[test]
    fn programs_are_offloadable() {
        for (name, it) in [
            ("find", find_iter()),
            ("locate", locate_iter()),
            ("scan", scan_iter()),
        ] {
            assert!(
                it.offloadable(DEFAULT_ETA),
                "{name} ratio {} too high",
                it.ratio()
            );
        }
        // the 19-word window dominates: memory-bound like the hash chain
        assert_eq!(find_iter().program.load_words as usize, NODE_WORDS);
    }
}
