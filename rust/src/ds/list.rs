//! STL `std::list` / `std::forward_list` on disaggregated memory
//! (paper Appendix B.1, Listings 4–5).
//!
//! Node layouts:
//!   forward_list: `[value, next]`          (2 words)
//!   list:         `[value, next, prev]`    (3 words)
//!
//! `std::find(first, last, value)` walks `next` until the value matches
//! or the list ends — both list types share the same internal function,
//! exactly as the paper's Table 5 notes.

use std::sync::Arc;

use super::{KEY_NOT_FOUND, SP_ACC_CNT, SP_ACC_SUM, SP_CURSOR, SP_FLAG, SP_KEY, SP_RESULT};
use crate::compiler::{CompiledIter, IterBuilder};
use crate::isa::{Status, SP_WORDS};
use crate::mem::GAddr;
use crate::rack::{Op, Rack};

/// Value stored in the sentinel head node (`with_sentinel` lists); no
/// application value may use it, so `find` walks through the sentinel.
pub const SENTINEL_VAL: i64 = i64::MIN;

pub struct ForwardList {
    pub head: GAddr,
    tail: GAddr,
    /// Sentinel head node (0 = classic head-pointer list). The sentinel
    /// is what makes *offloaded* `push_front` expressible: the list
    /// head becomes a word in rack memory the accelerator can CAS-less
    /// rewrite, instead of host-side state.
    sentinel: GAddr,
    pub len: usize,
    find: Arc<CompiledIter>,
    sum: Arc<CompiledIter>,
    push_front: Arc<CompiledIter>,
}

pub struct LinkedList {
    pub head: GAddr,
    tail: GAddr,
    pub len: usize,
    find: Arc<CompiledIter>,
}

/// `std::find` over `[value, next, ..]` nodes: sp[RESULT] = node addr on
/// hit, sp[FLAG] = KEY_NOT_FOUND on miss.
pub fn find_iter() -> CompiledIter {
    let mut b = IterBuilder::new();
    let needle = b.sp_input(SP_KEY);
    let val = b.field(0);
    b.if_eq(needle, val, |b| {
        let me = b.cur_ptr();
        b.sp_store(SP_RESULT, me);
        b.ret();
    });
    let next = b.field(1);
    let zero = b.imm(0);
    b.if_eq(next, zero, |b| {
        let nf = b.imm(KEY_NOT_FOUND);
        b.sp_store(SP_FLAG, nf);
        b.ret();
    });
    b.advance(next);
    b.finish().expect("list find iterator")
}

/// Offloaded `push_front` for sentinel-headed lists: the host
/// pre-allocates and fills the node (`[value, 0]`) and hands its
/// address in through the scratchpad; the accelerator links it in with
/// two mutating iterations, each writing back its own window:
///
///   iter 1 (at the sentinel): carry old `sentinel.next` into
///     sp[RESULT], store the new node as `sentinel.next`, flip the
///     phase bit (sp[CURSOR]), advance into the new node;
///   iter 2 (at the new node): store the carried old head as
///     `node.next`, done.
///
/// The sentinel iteration is the linearization point: once shard-side
/// execution serializes iter 1, concurrent pushes to one list produce
/// a valid chain in that serialization order (see the write-path notes
/// in `rack/README.md`).
pub fn push_front_iter() -> CompiledIter {
    let mut b = IterBuilder::new();
    let phase = b.sp_input(SP_CURSOR);
    let one = b.imm(1);
    b.if_eq(phase, one, |b| {
        // second iteration: we *are* the new node; link to old head
        let old = b.sp_input(SP_RESULT);
        b.store_field(1, old);
        b.ret();
    });
    // first iteration: at the sentinel
    let old = b.field(1);
    let newn = b.sp_input(SP_KEY);
    b.store_field(1, newn);
    b.sp_store(SP_RESULT, old);
    b.sp_store(SP_CURSOR, one);
    b.advance(newn);
    b.finish().expect("list push_front iterator")
}

/// Stateful aggregation along the chain (traversal-length study,
/// Appendix C.2): sp[SUM] += value, sp[CNT] += 1.
pub fn sum_iter() -> CompiledIter {
    let mut b = IterBuilder::new();
    let acc = b.sp_input(SP_ACC_SUM);
    let val = b.field(0);
    let acc2 = b.add(acc, val);
    b.sp_store(SP_ACC_SUM, acc2);
    let cnt = b.sp_input(SP_ACC_CNT);
    let cnt2 = b.addi(cnt, 1);
    b.sp_store(SP_ACC_CNT, cnt2);
    let next = b.field(1);
    let zero = b.imm(0);
    b.if_eq(next, zero, |b| b.ret());
    b.advance(next);
    b.finish().expect("list sum iterator")
}

impl ForwardList {
    pub fn new() -> Self {
        Self {
            head: 0,
            tail: 0,
            sentinel: 0,
            len: 0,
            find: Arc::new(find_iter()),
            sum: Arc::new(sum_iter()),
            push_front: Arc::new(push_front_iter()),
        }
    }

    /// A sentinel-headed list: `head` points at a permanent
    /// `[SENTINEL_VAL, next]` node in rack memory, which is what the
    /// offloaded `push_front` program rewrites. `find` still works
    /// unchanged (the sentinel value never matches); `sum` skips the
    /// sentinel.
    pub fn with_sentinel(rack: &mut Rack) -> Self {
        let mut l = Self::new();
        let s = rack.alloc(16);
        rack.write_words(s, &[SENTINEL_VAL, 0]);
        l.head = s;
        l.tail = s;
        l.sentinel = s;
        l
    }

    pub fn find_program(&self) -> Arc<CompiledIter> {
        self.find.clone()
    }

    pub fn sum_program(&self) -> Arc<CompiledIter> {
        self.sum.clone()
    }

    pub fn push_front_program(&self) -> Arc<CompiledIter> {
        self.push_front.clone()
    }

    pub fn sentinel(&self) -> GAddr {
        self.sentinel
    }

    /// First value-carrying node (skips the sentinel if present).
    fn first_value_node(&self, rack: &mut Rack) -> GAddr {
        if self.sentinel == 0 {
            return self.head;
        }
        let mut s = [0i64; 2];
        rack.read_words(self.sentinel, &mut s);
        s[1] as GAddr
    }

    /// Host-side pre-allocation for one offloaded `push_front`: the
    /// node is filled (`[value, next=0]`) but not yet linked. Streamed
    /// mutation plans allocate all their nodes up front so every
    /// backend sees an identical heap layout.
    pub fn prealloc_node(&self, rack: &mut Rack, value: i64) -> GAddr {
        let addr = rack.alloc(16);
        rack.write_words(addr, &[value, 0]);
        addr
    }

    /// The streamed op for one offloaded push of a pre-allocated node.
    pub fn push_front_op(&self, node: GAddr) -> Op {
        assert_ne!(self.sentinel, 0, "push_front needs a sentinel list");
        let mut sp = [0i64; SP_WORDS];
        sp[SP_KEY as usize] = node as i64;
        Op::new(self.push_front.clone(), self.sentinel, sp)
    }

    /// Offloaded push_front (prealloc + traverse); returns the node.
    pub fn push_front(&mut self, rack: &mut Rack, value: i64) -> GAddr {
        assert_ne!(self.sentinel, 0, "push_front needs a sentinel list");
        let node = self.prealloc_node(rack, value);
        let mut sp = [0i64; SP_WORDS];
        sp[SP_KEY as usize] = node as i64;
        let (st, _sp, _) = rack.traverse(&self.push_front, self.sentinel, sp);
        assert_eq!(st, Status::Return, "push_front trapped");
        self.len += 1;
        node
    }

    /// Host walk of all values in chain order (sentinel excluded).
    /// Panics on a cycle (bounded walk) — corruption, not a miss.
    pub fn host_values(&self, rack: &mut Rack) -> Vec<i64> {
        let mut out = Vec::new();
        let mut cur = self.first_value_node(rack);
        while cur != 0 {
            let mut node = [0i64; 2];
            rack.read_words(cur, &mut node);
            out.push(node[0]);
            cur = node[1] as GAddr;
            assert!(out.len() <= 1 << 22, "list chain cycle");
        }
        out
    }

    /// Structural invariants after a (possibly concurrent) mutation
    /// stream: the sentinel is intact, the chain is acyclic, and it
    /// carries exactly `expected_len` value nodes.
    pub fn check_invariants(&self, rack: &mut Rack, expected_len: usize) {
        if self.sentinel != 0 {
            let mut s = [0i64; 2];
            rack.read_words(self.sentinel, &mut s);
            assert_eq!(s[0], SENTINEL_VAL, "sentinel value clobbered");
        }
        let mut cur = self.first_value_node(rack);
        let mut n = 0usize;
        while cur != 0 {
            assert!(
                n <= expected_len,
                "chain longer than {expected_len} nodes (cycle?)"
            );
            let mut node = [0i64; 2];
            rack.read_words(cur, &mut node);
            assert_ne!(node[0], SENTINEL_VAL, "sentinel linked mid-chain");
            cur = node[1] as GAddr;
            n += 1;
        }
        assert_eq!(n, expected_len, "chain length mismatch");
    }

    /// push_back (host path).
    pub fn push(&mut self, rack: &mut Rack, value: i64) -> GAddr {
        let addr = rack.alloc(16);
        rack.write_words(addr, &[value, 0]);
        if self.head == 0 {
            self.head = addr;
        } else {
            let mut node = [0i64; 2];
            rack.read_words(self.tail, &mut node);
            node[1] = addr as i64;
            rack.write_words(self.tail, &node);
        }
        self.tail = addr;
        self.len += 1;
        addr
    }

    /// Offloaded `std::find`.
    pub fn find(&self, rack: &mut Rack, value: i64) -> Option<GAddr> {
        if self.head == 0 {
            return None;
        }
        let mut sp = [0i64; SP_WORDS];
        sp[SP_KEY as usize] = value;
        let (_st, sp, _iters) = rack.traverse(&self.find, self.head, sp);
        if sp[SP_FLAG as usize] == KEY_NOT_FOUND {
            None
        } else {
            Some(sp[SP_RESULT as usize] as GAddr)
        }
    }

    /// Offloaded whole-list sum; returns (sum, count). On sentinel
    /// lists the aggregation starts at the first value node.
    pub fn sum(&self, rack: &mut Rack) -> (i64, i64) {
        let start = self.first_value_node(rack);
        if start == 0 {
            return (0, 0);
        }
        let sp = [0i64; SP_WORDS];
        let (_st, sp, _iters) = rack.traverse(&self.sum, start, sp);
        (sp[SP_ACC_SUM as usize], sp[SP_ACC_CNT as usize])
    }

    /// Host-side reference walk (for verification).
    pub fn host_find(&self, rack: &mut Rack, value: i64) -> Option<GAddr> {
        let mut cur = self.head;
        while cur != 0 {
            let mut node = [0i64; 2];
            rack.read_words(cur, &mut node);
            if node[0] == value {
                return Some(cur);
            }
            cur = node[1] as GAddr;
        }
        None
    }
}

impl Default for ForwardList {
    fn default() -> Self {
        Self::new()
    }
}

impl LinkedList {
    pub fn new() -> Self {
        Self { head: 0, tail: 0, len: 0, find: Arc::new(find_iter()) }
    }

    pub fn find_program(&self) -> Arc<CompiledIter> {
        self.find.clone()
    }

    pub fn push_back(&mut self, rack: &mut Rack, value: i64) -> GAddr {
        let addr = rack.alloc(24);
        rack.write_words(addr, &[value, 0, self.tail as i64]);
        if self.head == 0 {
            self.head = addr;
        } else {
            let mut node = [0i64; 3];
            rack.read_words(self.tail, &mut node);
            node[1] = addr as i64;
            rack.write_words(self.tail, &node);
        }
        self.tail = addr;
        self.len += 1;
        addr
    }

    /// `std::find` — identical program to forward_list (shared internal
    /// function, Table 5).
    pub fn find(&self, rack: &mut Rack, value: i64) -> Option<GAddr> {
        if self.head == 0 {
            return None;
        }
        let mut sp = [0i64; SP_WORDS];
        sp[SP_KEY as usize] = value;
        let (_st, sp, _) = rack.traverse(&self.find, self.head, sp);
        if sp[SP_FLAG as usize] == KEY_NOT_FOUND {
            None
        } else {
            Some(sp[SP_RESULT as usize] as GAddr)
        }
    }
}

impl Default for LinkedList {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rack::RackConfig;

    fn rack() -> Rack {
        Rack::new(RackConfig {
            nodes: 2,
            node_capacity: 8 << 20,
            granularity: 1 << 20,
            ..Default::default()
        })
    }

    #[test]
    fn forward_list_find_hit_and_miss() {
        let mut r = rack();
        let mut l = ForwardList::new();
        let addrs: Vec<_> =
            (0..50).map(|i| l.push(&mut r, i * 10)).collect();
        assert_eq!(l.find(&mut r, 250), Some(addrs[25]));
        assert_eq!(l.find(&mut r, 251), None);
        assert_eq!(l.find(&mut r, 0), Some(addrs[0]));
        assert_eq!(l.find(&mut r, 490), Some(addrs[49]));
    }

    #[test]
    fn offloaded_matches_host_walk() {
        let mut r = rack();
        let mut l = ForwardList::new();
        for i in 0..100 {
            l.push(&mut r, (i * 7) % 31);
        }
        for v in 0..35 {
            assert_eq!(
                l.find(&mut r, v),
                l.host_find(&mut r, v),
                "value {v}"
            );
        }
    }

    #[test]
    fn sum_aggregates_whole_list() {
        let mut r = rack();
        let mut l = ForwardList::new();
        for i in 1..=100 {
            l.push(&mut r, i);
        }
        assert_eq!(l.sum(&mut r), (5050, 100));
    }

    #[test]
    fn linked_list_find() {
        let mut r = rack();
        let mut l = LinkedList::new();
        let addrs: Vec<_> =
            (0..20).map(|i| l.push_back(&mut r, i)).collect();
        assert_eq!(l.find(&mut r, 13), Some(addrs[13]));
        assert_eq!(l.find(&mut r, 99), None);
    }

    #[test]
    fn list_spans_memory_nodes() {
        let mut r = Rack::new(RackConfig {
            nodes: 4,
            node_capacity: 8 << 20,
            granularity: 4096, // tiny slabs force node crossings
            ..Default::default()
        });
        let mut l = ForwardList::new();
        let addrs: Vec<_> = (0..2000).map(|i| l.push(&mut r, i)).collect();
        // nodes should really be spread
        let owners: std::collections::BTreeSet<_> = addrs
            .iter()
            .map(|&a| r.alloc.owner(a).unwrap())
            .collect();
        assert!(owners.len() >= 2, "placement not distributed");
        // distributed traversal still correct
        assert_eq!(l.find(&mut r, 1777), Some(addrs[1777]));
        assert_eq!(l.find(&mut r, 2001), None);
    }

    #[test]
    fn programs_are_offloadable() {
        assert!(find_iter().offloadable(0.75));
        assert!(sum_iter().offloadable(0.75));
        let pf = push_front_iter();
        assert!(pf.offloadable(0.75), "push_front ratio {}", pf.ratio());
        assert!(pf.program.writes_data, "push_front must mark writes");
    }

    #[test]
    fn offloaded_push_front_links_at_the_head() {
        let mut r = rack();
        let mut l = ForwardList::with_sentinel(&mut r);
        l.push(&mut r, 1); // host append after the sentinel
        l.push(&mut r, 2);
        l.push_front(&mut r, 10);
        l.push_front(&mut r, 20);
        assert_eq!(l.host_values(&mut r), vec![20, 10, 1, 2]);
        assert_eq!(l.sum(&mut r), (33, 4));
        l.check_invariants(&mut r, 4);
        // find walks through the sentinel and the pushed nodes
        assert!(l.find(&mut r, 10).is_some());
        assert!(l.find(&mut r, 2).is_some());
        assert!(l.find(&mut r, 99).is_none());
    }

    #[test]
    fn push_front_into_empty_sentinel_list() {
        let mut r = rack();
        let mut l = ForwardList::with_sentinel(&mut r);
        assert_eq!(l.host_values(&mut r), Vec::<i64>::new());
        assert_eq!(l.sum(&mut r), (0, 0));
        l.check_invariants(&mut r, 0);
        let n = l.push_front(&mut r, 7);
        assert_eq!(l.host_values(&mut r), vec![7]);
        assert_eq!(l.find(&mut r, 7), Some(n));
        l.check_invariants(&mut r, 1);
    }

    #[test]
    fn streamed_push_front_ops_apply_via_functional_path() {
        let mut r = rack();
        let mut l = ForwardList::with_sentinel(&mut r);
        l.push(&mut r, 100);
        let nodes: Vec<_> =
            (0..5).map(|v| l.prealloc_node(&mut r, v)).collect();
        for n in &nodes {
            let op = l.push_front_op(*n);
            r.run_op_functional(&op);
        }
        assert_eq!(l.host_values(&mut r), vec![4, 3, 2, 1, 0, 100]);
        l.check_invariants(&mut r, 6);
    }
}
