//! STL `std::list` / `std::forward_list` on disaggregated memory
//! (paper Appendix B.1, Listings 4–5).
//!
//! Node layouts:
//!   forward_list: `[value, next]`          (2 words)
//!   list:         `[value, next, prev]`    (3 words)
//!
//! `std::find(first, last, value)` walks `next` until the value matches
//! or the list ends — both list types share the same internal function,
//! exactly as the paper's Table 5 notes.

use std::sync::Arc;

use super::{KEY_NOT_FOUND, SP_ACC_CNT, SP_ACC_SUM, SP_FLAG, SP_KEY, SP_RESULT};
use crate::compiler::{CompiledIter, IterBuilder};
use crate::isa::SP_WORDS;
use crate::mem::GAddr;
use crate::rack::Rack;

pub struct ForwardList {
    pub head: GAddr,
    tail: GAddr,
    pub len: usize,
    find: Arc<CompiledIter>,
    sum: Arc<CompiledIter>,
}

pub struct LinkedList {
    pub head: GAddr,
    tail: GAddr,
    pub len: usize,
    find: Arc<CompiledIter>,
}

/// `std::find` over `[value, next, ..]` nodes: sp[RESULT] = node addr on
/// hit, sp[FLAG] = KEY_NOT_FOUND on miss.
pub fn find_iter() -> CompiledIter {
    let mut b = IterBuilder::new();
    let needle = b.sp(SP_KEY);
    let val = b.field(0);
    b.if_eq(needle, val, |b| {
        let me = b.cur_ptr();
        b.sp_store(SP_RESULT, me);
        b.ret();
    });
    let next = b.field(1);
    let zero = b.imm(0);
    b.if_eq(next, zero, |b| {
        let nf = b.imm(KEY_NOT_FOUND);
        b.sp_store(SP_FLAG, nf);
        b.ret();
    });
    b.advance(next);
    b.finish().expect("list find iterator")
}

/// Stateful aggregation along the chain (traversal-length study,
/// Appendix C.2): sp[SUM] += value, sp[CNT] += 1.
pub fn sum_iter() -> CompiledIter {
    let mut b = IterBuilder::new();
    let acc = b.sp(SP_ACC_SUM);
    let val = b.field(0);
    let acc2 = b.add(acc, val);
    b.sp_store(SP_ACC_SUM, acc2);
    let cnt = b.sp(SP_ACC_CNT);
    let cnt2 = b.addi(cnt, 1);
    b.sp_store(SP_ACC_CNT, cnt2);
    let next = b.field(1);
    let zero = b.imm(0);
    b.if_eq(next, zero, |b| b.ret());
    b.advance(next);
    b.finish().expect("list sum iterator")
}

impl ForwardList {
    pub fn new() -> Self {
        Self {
            head: 0,
            tail: 0,
            len: 0,
            find: Arc::new(find_iter()),
            sum: Arc::new(sum_iter()),
        }
    }

    pub fn find_program(&self) -> Arc<CompiledIter> {
        self.find.clone()
    }

    pub fn sum_program(&self) -> Arc<CompiledIter> {
        self.sum.clone()
    }

    /// push_back (host path).
    pub fn push(&mut self, rack: &mut Rack, value: i64) -> GAddr {
        let addr = rack.alloc(16);
        rack.write_words(addr, &[value, 0]);
        if self.head == 0 {
            self.head = addr;
        } else {
            let mut node = [0i64; 2];
            rack.read_words(self.tail, &mut node);
            node[1] = addr as i64;
            rack.write_words(self.tail, &node);
        }
        self.tail = addr;
        self.len += 1;
        addr
    }

    /// Offloaded `std::find`.
    pub fn find(&self, rack: &mut Rack, value: i64) -> Option<GAddr> {
        if self.head == 0 {
            return None;
        }
        let mut sp = [0i64; SP_WORDS];
        sp[SP_KEY as usize] = value;
        let (_st, sp, _iters) = rack.traverse(&self.find, self.head, sp);
        if sp[SP_FLAG as usize] == KEY_NOT_FOUND {
            None
        } else {
            Some(sp[SP_RESULT as usize] as GAddr)
        }
    }

    /// Offloaded whole-list sum; returns (sum, count).
    pub fn sum(&self, rack: &mut Rack) -> (i64, i64) {
        if self.head == 0 {
            return (0, 0);
        }
        let sp = [0i64; SP_WORDS];
        let (_st, sp, _iters) = rack.traverse(&self.sum, self.head, sp);
        (sp[SP_ACC_SUM as usize], sp[SP_ACC_CNT as usize])
    }

    /// Host-side reference walk (for verification).
    pub fn host_find(&self, rack: &mut Rack, value: i64) -> Option<GAddr> {
        let mut cur = self.head;
        while cur != 0 {
            let mut node = [0i64; 2];
            rack.read_words(cur, &mut node);
            if node[0] == value {
                return Some(cur);
            }
            cur = node[1] as GAddr;
        }
        None
    }
}

impl Default for ForwardList {
    fn default() -> Self {
        Self::new()
    }
}

impl LinkedList {
    pub fn new() -> Self {
        Self { head: 0, tail: 0, len: 0, find: Arc::new(find_iter()) }
    }

    pub fn find_program(&self) -> Arc<CompiledIter> {
        self.find.clone()
    }

    pub fn push_back(&mut self, rack: &mut Rack, value: i64) -> GAddr {
        let addr = rack.alloc(24);
        rack.write_words(addr, &[value, 0, self.tail as i64]);
        if self.head == 0 {
            self.head = addr;
        } else {
            let mut node = [0i64; 3];
            rack.read_words(self.tail, &mut node);
            node[1] = addr as i64;
            rack.write_words(self.tail, &node);
        }
        self.tail = addr;
        self.len += 1;
        addr
    }

    /// `std::find` — identical program to forward_list (shared internal
    /// function, Table 5).
    pub fn find(&self, rack: &mut Rack, value: i64) -> Option<GAddr> {
        if self.head == 0 {
            return None;
        }
        let mut sp = [0i64; SP_WORDS];
        sp[SP_KEY as usize] = value;
        let (_st, sp, _) = rack.traverse(&self.find, self.head, sp);
        if sp[SP_FLAG as usize] == KEY_NOT_FOUND {
            None
        } else {
            Some(sp[SP_RESULT as usize] as GAddr)
        }
    }
}

impl Default for LinkedList {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rack::RackConfig;

    fn rack() -> Rack {
        Rack::new(RackConfig {
            nodes: 2,
            node_capacity: 8 << 20,
            granularity: 1 << 20,
            ..Default::default()
        })
    }

    #[test]
    fn forward_list_find_hit_and_miss() {
        let mut r = rack();
        let mut l = ForwardList::new();
        let addrs: Vec<_> =
            (0..50).map(|i| l.push(&mut r, i * 10)).collect();
        assert_eq!(l.find(&mut r, 250), Some(addrs[25]));
        assert_eq!(l.find(&mut r, 251), None);
        assert_eq!(l.find(&mut r, 0), Some(addrs[0]));
        assert_eq!(l.find(&mut r, 490), Some(addrs[49]));
    }

    #[test]
    fn offloaded_matches_host_walk() {
        let mut r = rack();
        let mut l = ForwardList::new();
        for i in 0..100 {
            l.push(&mut r, (i * 7) % 31);
        }
        for v in 0..35 {
            assert_eq!(
                l.find(&mut r, v),
                l.host_find(&mut r, v),
                "value {v}"
            );
        }
    }

    #[test]
    fn sum_aggregates_whole_list() {
        let mut r = rack();
        let mut l = ForwardList::new();
        for i in 1..=100 {
            l.push(&mut r, i);
        }
        assert_eq!(l.sum(&mut r), (5050, 100));
    }

    #[test]
    fn linked_list_find() {
        let mut r = rack();
        let mut l = LinkedList::new();
        let addrs: Vec<_> =
            (0..20).map(|i| l.push_back(&mut r, i)).collect();
        assert_eq!(l.find(&mut r, 13), Some(addrs[13]));
        assert_eq!(l.find(&mut r, 99), None);
    }

    #[test]
    fn list_spans_memory_nodes() {
        let mut r = Rack::new(RackConfig {
            nodes: 4,
            node_capacity: 8 << 20,
            granularity: 4096, // tiny slabs force node crossings
            ..Default::default()
        });
        let mut l = ForwardList::new();
        let addrs: Vec<_> = (0..2000).map(|i| l.push(&mut r, i)).collect();
        // nodes should really be spread
        let owners: std::collections::BTreeSet<_> = addrs
            .iter()
            .map(|&a| r.alloc.owner(a).unwrap())
            .collect();
        assert!(owners.len() >= 2, "placement not distributed");
        // distributed traversal still correct
        assert_eq!(l.find(&mut r, 1777), Some(addrs[1777]));
        assert_eq!(l.find(&mut r, 2001), None);
    }

    #[test]
    fn programs_are_offloadable() {
        assert!(find_iter().offloadable(0.75));
        assert!(sum_iter().offloadable(0.75));
    }
}
