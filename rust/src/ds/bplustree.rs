//! B+Tree with linked leaves — the index behind the WiredTiger and
//! BTrDB applications (paper §6, Table 3).
//!
//! Layout (FANOUT = 7, nodes fill 18 words ≈ 144 B of the 256 B window):
//!   internal: `[tag=0, nk, keys[7] (2..9), children[8] (9..17)]`
//!   leaf:     `[tag=1, nk, keys[7] (2..9), values[7] (9..16), next (17)]`
//! Keys are `i64::MAX`-padded so unrolled scans need no bound checks.
//!
//! Offloaded iterators:
//!  * `get_iter`      — full descend + in-leaf exact match (one request);
//!  * `locate_iter`   — descend only, returns the leaf address;
//!  * `scan_iter`     — range scan: one record per iteration into the
//!                      scratchpad buffer, yielding every `SP_BUF_LEN`
//!                      records (WiredTiger YCSB-E);
//!  * `sum_iter`      — leaf-chain aggregation `sum(values | key <= hi)`
//!                      (BTrDB windowed aggregates; count derives from
//!                      the window, min/max finalize through the
//!                      window_agg XLA artifact).

use std::sync::Arc;

use super::{KEY_NOT_FOUND, SP_ACC_SUM, SP_BUF_BASE, SP_BUF_LEN, SP_CURSOR, SP_FLAG, SP_KEY, SP_RESULT};
use crate::compiler::{CompiledIter, IterBuilder};
use crate::isa::{Status, SP_WORDS};
use crate::mem::GAddr;
use crate::rack::{Op, Rack, Stage, StartAddr};

pub const FANOUT: usize = 7;
pub const NODE_WORDS: usize = 18;
const KEYS: u32 = 2;
const VALS: u32 = 9; // leaf values / internal children
const NEXT: u32 = 17;

/// Count-of-smaller-or-equal scan over the 7 key slots; returns the
/// index register. Separators are "min key of right child", so
/// `idx = |{j : keys[j] <= needle}|` picks the covering child, and at a
/// leaf `keys[idx-1] == needle` detects exact presence.
fn emit_key_scan(b: &mut IterBuilder, needle: crate::compiler::Val) -> crate::compiler::Val {
    let idx = b.var(0);
    let mark = b.temp_mark();
    b.for_fixed(FANOUT, |b, j| {
        let k = b.field(KEYS + j as u32);
        b.if_le(k, needle, |b| b.add_assign(idx, 1));
        b.temp_release(mark);
    });
    idx
}

/// Full point lookup in one program (paper Table 3 row: WiredTiger).
pub fn get_iter() -> CompiledIter {
    let mut b = IterBuilder::new();
    let needle = b.sp_input(SP_KEY);
    let idx = emit_key_scan(&mut b, needle);
    let tag = b.field(0);
    let one = b.imm(1);
    b.if_ne(tag, one, |b| {
        // internal: descend into children[idx]
        let child = b.field_dyn(idx, VALS, NODE_WORDS as u32 - 1);
        b.advance(child);
    });
    // leaf: exact match at idx-1
    let zero = b.imm(0);
    b.if_ne(idx, zero, |b| {
        let im1 = b.addi(idx, -1);
        let k = b.field_dyn(im1, KEYS, 8);
        b.if_eq(k, needle, |b| {
            let v = b.field_dyn(im1, VALS, 15);
            b.sp_store(SP_RESULT, v);
            let z = b.imm(0);
            b.sp_store(SP_FLAG, z);
            b.ret();
        });
    });
    let nf = b.imm(KEY_NOT_FOUND);
    b.sp_store(SP_FLAG, nf);
    b.ret();
    b.finish().expect("bplus get")
}

/// Mutating point update: identical descend to [`get_iter`], but on an
/// exact leaf match the new value (sp[RESULT] on entry) is stored into
/// the leaf's value slot via the dirty write-back path. Internal-node
/// iterations write back unmodified windows — the honest cost of a
/// program-level `writes_data` flag, exactly what the cost model's 2×
/// streamed-words term charges.
pub fn update_iter() -> CompiledIter {
    let mut b = IterBuilder::new();
    let needle = b.sp_input(SP_KEY);
    let idx = emit_key_scan(&mut b, needle);
    let tag = b.field(0);
    let one = b.imm(1);
    b.if_ne(tag, one, |b| {
        // internal: descend into children[idx]
        let child = b.field_dyn(idx, VALS, NODE_WORDS as u32 - 1);
        b.advance(child);
    });
    // leaf: exact match at idx-1 overwrites values[idx-1]
    let zero = b.imm(0);
    b.if_ne(idx, zero, |b| {
        let im1 = b.addi(idx, -1);
        let k = b.field_dyn(im1, KEYS, 8);
        b.if_eq(k, needle, |b| {
            let newv = b.sp_input(SP_RESULT);
            b.store_field_dyn(im1, VALS, 15, newv);
            let z = b.imm(0);
            b.sp_store(SP_FLAG, z);
            b.ret();
        });
    });
    let nf = b.imm(KEY_NOT_FOUND);
    b.sp_store(SP_FLAG, nf);
    b.ret();
    b.finish().expect("bplus update")
}

/// Descend-only: sp[RESULT] = covering leaf address.
pub fn locate_iter() -> CompiledIter {
    let mut b = IterBuilder::new();
    let tag = b.field(0);
    let one = b.imm(1);
    b.if_eq(tag, one, |b| {
        let me = b.cur_ptr();
        b.sp_store(SP_RESULT, me);
        b.ret();
    });
    let needle = b.sp_input(SP_KEY);
    let idx = emit_key_scan(&mut b, needle);
    let child = b.field_dyn(idx, VALS, NODE_WORDS as u32 - 1);
    b.advance(child);
    b.finish().expect("bplus locate")
}

/// Range scan starting *at a leaf*: emits one record per iteration into
/// sp[8..32], maintaining sp[CURSOR] = in-leaf index, sp[2] = remaining
/// records, sp[3] = emitted count. Returns (yields) when the scratchpad
/// buffer fills or `remaining` hits zero; the CPU node re-issues the
/// continuation (paper §3 bounded execution).
pub fn scan_iter() -> CompiledIter {
    let mut b = IterBuilder::new();
    let i = b.sp_input(SP_CURSOR);
    {
        let mark = b.temp_mark();
        let seven = b.imm(FANOUT as i64);
        // advance to the next leaf when the cursor walks off this one
        b.if_ge(i, seven, |b| {
            let nxt = b.field(NEXT);
            let z = b.imm(0);
            b.if_eq(nxt, z, |b| b.ret());
            b.sp_store(SP_CURSOR, z);
            b.advance(nxt);
        });
        b.temp_release(mark);
    }
    let k = b.field_dyn(i, KEYS, 8);
    {
        let mark = b.temp_mark();
        let maxpad = b.imm(i64::MAX);
        b.if_eq(k, maxpad, |b| {
            // padding: jump to next leaf on the next iteration
            let seven = b.imm(FANOUT as i64);
            b.sp_store(SP_CURSOR, seven);
            let me = b.cur_ptr();
            b.advance(me);
        });
        b.temp_release(mark);
    }
    let v = b.field_dyn(i, VALS, 15);
    let oc = b.sp_input(3);
    b.sp_store_dyn(oc, SP_BUF_BASE, v);
    let oc2 = b.addi(oc, 1);
    b.sp_store(3, oc2);
    {
        let mark = b.temp_mark();
        let i2 = b.addi(i, 1);
        b.sp_store(SP_CURSOR, i2);
        b.temp_release(mark);
    }
    let rem = b.sp_input(2);
    let rem2 = b.addi(rem, -1);
    b.sp_store(2, rem2);
    {
        // publish the continuation point (current leaf) so the CPU node
        // can resume after a yield — sp + cur_ptr are the whole iterator
        // state (paper §5).
        let mark = b.temp_mark();
        let me = b.cur_ptr();
        b.sp_store(SP_RESULT, me);
        b.temp_release(mark);
        let z = b.imm(0);
        b.if_le(rem2, z, |b| b.ret());
        b.temp_release(mark);
        let cap = b.imm(SP_BUF_LEN as i64);
        b.if_ge(oc2, cap, |b| b.ret());
        b.temp_release(mark);
    }
    let me = b.cur_ptr();
    b.advance(me);
    b.finish().expect("bplus scan")
}

/// Leaf-chain sum of values with key <= sp[KEY] (hi bound), starting at
/// a leaf whose keys are all within range (the CPU node handles the
/// partial boundary leaf). Accumulates into sp[ACC_SUM].
pub fn sum_iter() -> CompiledIter {
    let mut b = IterBuilder::new();
    let hi = b.sp_input(SP_KEY);
    let sum = b.sp_input(SP_ACC_SUM);
    let done = b.make_label();
    let mark = b.temp_mark();
    b.for_fixed(FANOUT, |b, j| {
        let k = b.field(KEYS + j as u32);
        // key > hi (incl. MAX padding) => finish via the shared exit
        b.br_gt(k, hi, &done);
        let v = b.field(VALS + j as u32);
        b.add_to(sum, v);
        b.temp_release(mark);
    });
    b.sp_store(SP_ACC_SUM, sum);
    let nxt = b.field(NEXT);
    let z = b.imm(0);
    b.if_eq(nxt, z, |b| b.ret());
    b.advance(nxt);
    b.bind_label(done);
    b.sp_store(SP_ACC_SUM, sum);
    b.ret();
    b.finish().expect("bplus sum")
}

pub struct BPlusTree {
    pub root: GAddr,
    pub first_leaf: GAddr,
    pub len: usize,
    get_p: Arc<CompiledIter>,
    locate_p: Arc<CompiledIter>,
    scan_p: Arc<CompiledIter>,
    sum_p: Arc<CompiledIter>,
    update_p: Arc<CompiledIter>,
}

impl BPlusTree {
    /// Bulk-build from sorted unique (key, value) pairs with the given
    /// leaf fill factor (records per leaf, <= FANOUT).
    pub fn build_sorted(
        rack: &mut Rack,
        pairs: &[(i64, i64)],
        fill: usize,
    ) -> Self {
        assert!(!pairs.is_empty());
        let fill = fill.clamp(1, FANOUT);
        let mut leaves: Vec<(i64, GAddr)> = Vec::new();
        let mut prev: Option<GAddr> = None;
        for chunk in pairs.chunks(fill) {
            let addr = rack.alloc((NODE_WORDS * 8) as u64);
            let mut node = [0i64; NODE_WORDS];
            node[0] = 1;
            node[1] = chunk.len() as i64;
            for j in 0..FANOUT {
                node[KEYS as usize + j] =
                    chunk.get(j).map(|p| p.0).unwrap_or(i64::MAX);
                node[VALS as usize + j] =
                    chunk.get(j).map(|p| p.1).unwrap_or(0);
            }
            rack.write_words(addr, &node);
            if let Some(p) = prev {
                let mut pn = [0i64; NODE_WORDS];
                rack.read_words(p, &mut pn);
                pn[NEXT as usize] = addr as i64;
                rack.write_words(p, &pn);
            }
            prev = Some(addr);
            leaves.push((chunk[0].0, addr));
        }
        let first_leaf = leaves[0].1;
        let mut level = leaves;
        while level.len() > 1 {
            let mut next_level: Vec<(i64, GAddr)> = Vec::new();
            for group in level.chunks(FANOUT + 1) {
                let addr = rack.alloc((NODE_WORDS * 8) as u64);
                let mut node = [0i64; NODE_WORDS];
                node[0] = 0;
                node[1] = (group.len() - 1) as i64;
                for j in 0..FANOUT {
                    node[KEYS as usize + j] = group
                        .get(j + 1)
                        .map(|g| g.0)
                        .unwrap_or(i64::MAX);
                }
                for (j, g) in group.iter().enumerate() {
                    node[VALS as usize + j] = g.1 as i64;
                }
                rack.write_words(addr, &node);
                next_level.push((group[0].0, addr));
            }
            level = next_level;
        }
        Self {
            root: level[0].1,
            first_leaf,
            len: pairs.len(),
            get_p: Arc::new(get_iter()),
            locate_p: Arc::new(locate_iter()),
            scan_p: Arc::new(scan_iter()),
            sum_p: Arc::new(sum_iter()),
            update_p: Arc::new(update_iter()),
        }
    }

    pub fn get_program(&self) -> Arc<CompiledIter> {
        self.get_p.clone()
    }

    pub fn locate_program(&self) -> Arc<CompiledIter> {
        self.locate_p.clone()
    }

    pub fn scan_program(&self) -> Arc<CompiledIter> {
        self.scan_p.clone()
    }

    pub fn sum_program(&self) -> Arc<CompiledIter> {
        self.sum_p.clone()
    }

    pub fn update_program(&self) -> Arc<CompiledIter> {
        self.update_p.clone()
    }

    /// The streamed offloaded in-place value update for one key.
    pub fn update_op(&self, key: i64, value: i64) -> Op {
        let mut sp = [0i64; SP_WORDS];
        sp[SP_KEY as usize] = key;
        sp[SP_RESULT as usize] = value;
        Op::new(self.update_p.clone(), self.root, sp)
    }

    /// Offloaded in-place value update; false if the key is absent.
    pub fn update(&self, rack: &mut Rack, key: i64, value: i64) -> bool {
        let mut sp = [0i64; SP_WORDS];
        sp[SP_KEY as usize] = key;
        sp[SP_RESULT as usize] = value;
        let (_st, sp, _) = rack.traverse(&self.update_p, self.root, sp);
        sp[SP_FLAG as usize] != KEY_NOT_FOUND
    }

    /// Two-stage YCSB-E scan op: locate the covering leaf, then stream
    /// `count` records through the buffered scan with continuation
    /// rounds (`repeat_while`). The scan stage starts at the located
    /// leaf's first slot (leaf-aligned, exactly what the WiredTiger app
    /// serves); callers needing strictly lo-bounded results use
    /// [`BPlusTree::scan`]. Single source of the continuation-protocol
    /// wiring for apps, benches, and the conformance registry.
    pub fn scan_op(&self, lo: i64, count: usize) -> Op {
        let mut sp1 = [0i64; SP_WORDS];
        sp1[SP_KEY as usize] = lo;
        let s1 = Stage::new(self.locate_p.clone(), self.root, sp1);
        let mut s2 = Stage::new(self.scan_p.clone(), 0, [0i64; SP_WORDS]);
        s2.start = StartAddr::FromPrevSp(SP_RESULT);
        s2.sp[2] = count as i64;
        s2.sp_overrides = vec![(3, 0), (SP_CURSOR, 0)];
        s2.repeat_while = Some((SP_RESULT, 2));
        Op { stages: vec![s1, s2], cpu_post_ns: 0 }
    }

    /// Offloaded point lookup (single request).
    pub fn get(&self, rack: &mut Rack, key: i64) -> Option<i64> {
        let mut sp = [0i64; SP_WORDS];
        sp[SP_KEY as usize] = key;
        let (_st, sp, _) = rack.traverse(&self.get_p, self.root, sp);
        (sp[SP_FLAG as usize] != KEY_NOT_FOUND)
            .then_some(sp[SP_RESULT as usize])
    }

    /// Offloaded locate: covering leaf for `key`.
    pub fn locate(&self, rack: &mut Rack, key: i64) -> GAddr {
        let mut sp = [0i64; SP_WORDS];
        sp[SP_KEY as usize] = key;
        let (_st, sp, _) = rack.traverse(&self.locate_p, self.root, sp);
        sp[SP_RESULT as usize] as GAddr
    }

    /// Offloaded range scan: up to `count` values from the first key
    /// >= `start` (YCSB-E). Issues continuations as the scratchpad
    /// buffer fills.
    pub fn scan(&self, rack: &mut Rack, start: i64, count: usize) -> Vec<i64> {
        let leaf = self.locate(rack, start);
        if leaf == 0 {
            return Vec::new();
        }
        // in-leaf cursor: first index with key >= start
        let mut node = [0i64; NODE_WORDS];
        rack.read_words(leaf, &mut node);
        let mut cursor = 0i64;
        while (cursor as usize) < FANOUT
            && node[KEYS as usize + cursor as usize] < start
        {
            cursor += 1;
        }
        let mut out = Vec::with_capacity(count);
        let mut cur_leaf = leaf;
        let mut remaining = count as i64;
        while remaining > 0 && cur_leaf != 0 {
            let mut sp = [0i64; SP_WORDS];
            sp[SP_CURSOR as usize] = cursor;
            sp[2] = remaining;
            sp[3] = 0;
            sp[SP_RESULT as usize] = 0;
            let (st, sp, _) = rack.traverse(&self.scan_p, cur_leaf, sp);
            let emitted = sp[3] as usize;
            out.extend_from_slice(
                &sp[SP_BUF_BASE as usize..SP_BUF_BASE as usize + emitted],
            );
            if st != Status::Return || emitted == 0 {
                break;
            }
            remaining -= emitted as i64;
            // continuation state travels in the scratchpad: the leaf the
            // scan stopped on (SP_RESULT; 0 ⇒ end of chain) + cursor.
            cur_leaf = sp[SP_RESULT as usize] as GAddr;
            cursor = sp[SP_CURSOR as usize];
        }
        out.truncate(count);
        out
    }

    /// Offloaded aggregation: sum of values with lo <= key <= hi.
    /// Boundary leaf handled at the CPU node (partial range), then the
    /// leaf chain aggregates on the accelerators.
    pub fn sum_range(&self, rack: &mut Rack, lo: i64, hi: i64) -> i64 {
        let leaf = self.locate(rack, lo);
        if leaf == 0 {
            return 0;
        }
        let mut node = [0i64; NODE_WORDS];
        rack.read_words(leaf, &mut node);
        let mut sum = 0i64;
        for j in 0..FANOUT {
            let k = node[KEYS as usize + j];
            if k >= lo && k <= hi && k != i64::MAX {
                sum = sum.wrapping_add(node[VALS as usize + j]);
            }
        }
        let next = node[NEXT as usize] as GAddr;
        if next == 0 || node[KEYS as usize + FANOUT - 1] > hi {
            return sum;
        }
        let mut sp = [0i64; SP_WORDS];
        sp[SP_KEY as usize] = hi;
        sp[SP_ACC_SUM as usize] = 0;
        let (_st, sp, _) = rack.traverse(&self.sum_p, next, sp);
        sum.wrapping_add(sp[SP_ACC_SUM as usize])
    }

    /// Host reference lookup.
    pub fn host_get(&self, rack: &mut Rack, key: i64) -> Option<i64> {
        let mut cur = self.root;
        loop {
            let mut node = [0i64; NODE_WORDS];
            rack.read_words(cur, &mut node);
            if node[0] == 1 {
                for j in 0..FANOUT {
                    if node[KEYS as usize + j] == key {
                        return Some(node[VALS as usize + j]);
                    }
                }
                return None;
            }
            let mut idx = 0usize;
            while idx < FANOUT && node[KEYS as usize + idx] <= key {
                idx += 1;
            }
            cur = node[VALS as usize + idx] as GAddr;
        }
    }

    /// Full host read-back of the leaf chain's (key, value) pairs —
    /// the canonical final state for mixed read-write conformance.
    pub fn host_items(&self, rack: &mut Rack) -> Vec<(i64, i64)> {
        let mut out = Vec::with_capacity(self.len);
        let mut cur = self.first_leaf;
        let mut leaves = 0usize;
        while cur != 0 {
            let mut node = [0i64; NODE_WORDS];
            rack.read_words(cur, &mut node);
            for j in 0..FANOUT {
                let k = node[KEYS as usize + j];
                if k != i64::MAX {
                    out.push((k, node[VALS as usize + j]));
                }
            }
            cur = node[NEXT as usize] as GAddr;
            leaves += 1;
            assert!(leaves <= self.len + 1, "leaf chain cycle");
        }
        out
    }

    /// Structural invariants after a (possibly concurrent) mutation
    /// stream: the leaf chain is acyclic, every leaf is tagged as a
    /// leaf with MAX-padding only at its tail, keys are strictly
    /// increasing across the whole chain, and the entry count matches
    /// `len` (in-place value updates never move keys).
    pub fn check_invariants(&self, rack: &mut Rack) {
        let mut cur = self.first_leaf;
        let mut prev_key = i64::MIN;
        let mut total = 0usize;
        let mut leaves = 0usize;
        while cur != 0 {
            let mut node = [0i64; NODE_WORDS];
            rack.read_words(cur, &mut node);
            assert_eq!(node[0], 1, "non-leaf on the leaf chain");
            let mut padded = false;
            for j in 0..FANOUT {
                let k = node[KEYS as usize + j];
                if k == i64::MAX {
                    padded = true;
                    continue;
                }
                assert!(!padded, "key after MAX padding in a leaf");
                assert!(k > prev_key, "leaf keys not increasing at {k}");
                prev_key = k;
                total += 1;
            }
            cur = node[NEXT as usize] as GAddr;
            leaves += 1;
            assert!(leaves <= self.len + 1, "leaf chain cycle");
        }
        assert_eq!(total, self.len, "entry count drifted");
    }

    /// Host reference range sum.
    pub fn host_sum_range(&self, rack: &mut Rack, lo: i64, hi: i64) -> i64 {
        let mut cur = self.first_leaf;
        let mut sum = 0i64;
        while cur != 0 {
            let mut node = [0i64; NODE_WORDS];
            rack.read_words(cur, &mut node);
            for j in 0..FANOUT {
                let k = node[KEYS as usize + j];
                if k != i64::MAX && k >= lo && k <= hi {
                    sum = sum.wrapping_add(node[VALS as usize + j]);
                }
            }
            if node[KEYS as usize] > hi {
                break;
            }
            cur = node[NEXT as usize] as GAddr;
        }
        sum
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rack::RackConfig;

    fn rack() -> Rack {
        Rack::new(RackConfig {
            nodes: 2,
            node_capacity: 64 << 20,
            granularity: 1 << 20,
            ..Default::default()
        })
    }

    fn tree(rack: &mut Rack, n: i64) -> BPlusTree {
        let pairs: Vec<(i64, i64)> =
            (0..n).map(|i| (i * 2, i * 20)).collect();
        BPlusTree::build_sorted(rack, &pairs, FANOUT)
    }

    #[test]
    fn point_lookup_single_request() {
        let mut r = rack();
        let t = tree(&mut r, 2000);
        for i in (0..2000).step_by(37) {
            assert_eq!(t.get(&mut r, i * 2), Some(i * 20), "key {}", i * 2);
            assert_eq!(t.get(&mut r, i * 2 + 1), None);
        }
    }

    #[test]
    fn offloaded_matches_host() {
        let mut r = rack();
        let t = tree(&mut r, 500);
        for k in 0..1100 {
            assert_eq!(t.get(&mut r, k), t.host_get(&mut r, k), "key {k}");
        }
    }

    #[test]
    fn locate_returns_covering_leaf() {
        let mut r = rack();
        let t = tree(&mut r, 100);
        let leaf = t.locate(&mut r, 50);
        assert_ne!(leaf, 0);
        let mut node = [0i64; NODE_WORDS];
        r.read_words(leaf, &mut node);
        assert_eq!(node[0], 1);
        // the covering leaf's key range includes 50
        assert!(node[KEYS as usize] <= 50);
    }

    #[test]
    fn range_scan_returns_expected_values() {
        let mut r = rack();
        let t = tree(&mut r, 300);
        // keys 0,2,..; scan 10 from key 100 => values for keys 100..118
        let got = t.scan(&mut r, 100, 10);
        let want: Vec<i64> = (50..60).map(|i| i * 20).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn range_scan_spans_many_leaves_with_continuations() {
        let mut r = rack();
        let t = tree(&mut r, 500);
        let got = t.scan(&mut r, 0, 100); // > SP_BUF_LEN => continuations
        let want: Vec<i64> = (0..100).map(|i| i * 20).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn scan_clamps_at_end_of_tree() {
        let mut r = rack();
        let t = tree(&mut r, 20);
        let got = t.scan(&mut r, 30, 50);
        let want: Vec<i64> = (15..20).map(|i| i * 20).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn sum_range_matches_host() {
        let mut r = rack();
        let t = tree(&mut r, 400);
        for (lo, hi) in [(0, 798), (100, 500), (301, 303), (700, 9999)] {
            assert_eq!(
                t.sum_range(&mut r, lo, hi),
                t.host_sum_range(&mut r, lo, hi),
                "range {lo}..{hi}"
            );
        }
    }

    #[test]
    fn offloaded_update_rewrites_leaf_value_in_place() {
        let mut r = rack();
        let t = tree(&mut r, 500); // keys 0,2,..,998 -> values i*20
        assert!(t.update(&mut r, 100, -7));
        assert_eq!(t.get(&mut r, 100), Some(-7));
        assert_eq!(t.host_get(&mut r, 100), Some(-7));
        // absent keys: no write, reported not-found
        assert!(!t.update(&mut r, 101, 1));
        assert_eq!(t.get(&mut r, 101), None);
        t.check_invariants(&mut r);
        // streamed form through the functional path
        let op = t.update_op(200, 4242);
        r.run_op_functional(&op);
        assert_eq!(t.host_get(&mut r, 200), Some(4242));
        let items = t.host_items(&mut r);
        assert_eq!(items.len(), 500);
        assert!(items.contains(&(200, 4242)));
        t.check_invariants(&mut r);
    }

    #[test]
    fn programs_offloadable_at_paper_ratios() {
        for (name, it) in [
            ("get", get_iter()),
            ("locate", locate_iter()),
            ("scan", scan_iter()),
            ("sum", sum_iter()),
            ("update", update_iter()),
        ] {
            assert!(
                it.offloadable(0.75),
                "{name} ratio {} too high",
                it.ratio()
            );
        }
        // Table 3: B+Tree point ops ≈ 0.63, BTrDB aggregation ≈ 0.71
        let g = get_iter().ratio();
        assert!(g > 0.4 && g <= 0.75, "get ratio {g}");
    }

    #[test]
    fn partial_fill_leaves() {
        let mut r = rack();
        let pairs: Vec<(i64, i64)> = (0..100).map(|i| (i, i)).collect();
        let t = BPlusTree::build_sorted(&mut r, &pairs, 4); // half-full
        for i in 0..100 {
            assert_eq!(t.get(&mut r, i), Some(i));
        }
        assert_eq!(t.scan(&mut r, 10, 5), vec![10, 11, 12, 13, 14]);
    }
}
