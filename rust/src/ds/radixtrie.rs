//! 256-way radix trie (ART-style byte trie) on disaggregated memory —
//! the stress test for the ≤256 B aggregated-LOAD inference.
//!
//! A 256-pointer child array is 2 KB: it can never fit the 32-word data
//! window, so "read children[byte]" cannot be a `field_dyn` (the
//! dynamic-index load traps outside the window by design). The trie
//! instead does what the paper's pointer-arithmetic traversals do:
//! *compute the slot address* and advance into the middle of the child
//! array, then read the child pointer as `field(0)` of that slot. Each
//! key byte therefore costs two iterations (header visit + slot visit),
//! with a scratchpad phase bit telling the program which half it is in
//! — and the aggregated LOAD stays at 3 words no matter the fan-out.
//!
//! Layouts:
//!   header node (4 words): `[has_value(0), value(1), children(2), pad]`
//!   child array: 256 slots + 2 pad words (the 3-word window read at
//!   slot 255 must stay inside the allocation).
//!
//! Keys are full 64-bit values consumed big-endian, one byte per level,
//! fixed depth 8: values live only in depth-8 headers (which never have
//! a child array), so path == key and no residual compare is needed.
//! The consumed-key cursor travels in sp[7] (shift-left 8 per level —
//! the ISA has no variable-distance shifts); the phase bit in sp[4].

use std::sync::Arc;

use super::{KEY_NOT_FOUND, SP_ACC_CNT, SP_CURSOR, SP_FLAG, SP_KEY, SP_RESULT};
use crate::compiler::{CompiledIter, IterBuilder};
use crate::isa::SP_WORDS;
use crate::mem::GAddr;
use crate::rack::{Op, Rack};

pub const KEY_BYTES: usize = 8;
const HDR_WORDS: usize = 4;
const FANOUT: usize = 256;
/// Lookup window is 3 words; pad the array so slot 255's window read
/// stays inside the allocation.
const ARR_WORDS: usize = FANOUT + 2;

/// Scratchpad word carrying the not-yet-consumed key bytes.
pub const SP_REM: u32 = SP_CURSOR;
/// Phase bit: 0 = at a header node, 1 = at a child-array slot.
pub const SP_PHASE: u32 = SP_ACC_CNT;

/// Point lookup: sp[KEY] = key (informational), sp[REM] = key,
/// sp[PHASE] = 0. Hit: sp[RESULT] = value, sp[FLAG] = 0; miss:
/// sp[FLAG] = KEY_NOT_FOUND.
pub fn lookup_iter() -> CompiledIter {
    let mut b = IterBuilder::new();
    let phase = b.sp_input(SP_PHASE);
    let zero = b.imm(0);
    b.if_eq(phase, zero, |b| {
        // header visit
        let cptr = b.field(2);
        b.if_eq(cptr, zero, |b| {
            // no children: depth-8 leaf (has_value) or empty root
            let hv = b.field(0);
            b.if_eq(hv, zero, |b| {
                let nf = b.imm(KEY_NOT_FOUND);
                b.sp_store(SP_FLAG, nf);
                b.ret();
            });
            let v = b.field(1);
            b.sp_store(SP_RESULT, v);
            b.sp_store(SP_FLAG, zero);
            b.ret();
        });
        // consume the top byte: slot = children + (rem >> 56) * 8
        let rem = b.sp_input(SP_REM);
        let top = b.shr(rem, 56); // logical shift: byte in 0..=255
        let rem2 = b.shl(rem, 8);
        b.sp_store(SP_REM, rem2);
        let off = b.shl(top, 3);
        let slot = b.add(cptr, off);
        let one = b.imm(1);
        b.sp_store(SP_PHASE, one);
        b.advance(slot);
    });
    // slot visit
    let child = b.field(0);
    b.if_eq(child, zero, |b| {
        let nf = b.imm(KEY_NOT_FOUND);
        b.sp_store(SP_FLAG, nf);
        b.ret();
    });
    b.sp_store(SP_PHASE, zero);
    b.advance(child);
    b.finish().expect("radixtrie lookup")
}

pub struct RadixTrie {
    pub root: GAddr,
    pub len: usize,
    lookup_p: Arc<CompiledIter>,
}

impl RadixTrie {
    pub fn new(rack: &mut Rack) -> Self {
        let root = rack.alloc((HDR_WORDS * 8) as u64);
        rack.write_words(root, &[0i64; HDR_WORDS]);
        Self { root, len: 0, lookup_p: Arc::new(lookup_iter()) }
    }

    pub fn lookup_program(&self) -> Arc<CompiledIter> {
        self.lookup_p.clone()
    }

    fn read_hdr(rack: &mut Rack, addr: GAddr) -> [i64; HDR_WORDS] {
        let mut n = [0i64; HDR_WORDS];
        rack.read_words(addr, &mut n);
        n
    }

    /// Insert or overwrite (host path): materializes the byte path,
    /// allocating child arrays and headers lazily.
    pub fn insert(&mut self, rack: &mut Rack, key: i64, value: i64) {
        let mut cur = self.root;
        for d in 0..KEY_BYTES {
            let mut hdr = Self::read_hdr(rack, cur);
            let mut children = hdr[2] as GAddr;
            if children == 0 {
                children = rack.alloc((ARR_WORDS * 8) as u64);
                rack.write_words(children, &[0i64; ARR_WORDS]);
                hdr[2] = children as i64;
                rack.write_words(cur, &hdr);
            }
            let byte = ((key as u64) >> (56 - 8 * d)) & 0xFF;
            let slot = children + byte * 8;
            let mut w = [0i64; 1];
            rack.read_words(slot, &mut w);
            let mut child = w[0] as GAddr;
            if child == 0 {
                child = rack.alloc((HDR_WORDS * 8) as u64);
                rack.write_words(child, &[0i64; HDR_WORDS]);
                rack.write_words(slot, &[child as i64]);
            }
            cur = child;
        }
        let leaf = Self::read_hdr(rack, cur);
        if leaf[0] == 0 {
            self.len += 1;
        }
        rack.write_words(cur, &[1, value, leaf[2], 0]);
    }

    /// Single-stage lookup op (conformance / bench streams).
    pub fn lookup_op(&self, key: i64) -> Op {
        let mut sp = [0i64; SP_WORDS];
        sp[SP_KEY as usize] = key;
        sp[SP_REM as usize] = key;
        Op::new(self.lookup_p.clone(), self.root, sp)
    }

    /// Offloaded lookup (16 iterations for a present key: 8 header +
    /// 8 slot visits).
    pub fn get(&self, rack: &mut Rack, key: i64) -> Option<i64> {
        let mut sp = [0i64; SP_WORDS];
        sp[SP_KEY as usize] = key;
        sp[SP_REM as usize] = key;
        let (_st, sp, _) = rack.traverse(&self.lookup_p, self.root, sp);
        (sp[SP_FLAG as usize] != KEY_NOT_FOUND)
            .then_some(sp[SP_RESULT as usize])
    }

    /// Host reference walk.
    pub fn host_get(&self, rack: &mut Rack, key: i64) -> Option<i64> {
        let mut cur = self.root;
        for d in 0..KEY_BYTES {
            let hdr = Self::read_hdr(rack, cur);
            let children = hdr[2] as GAddr;
            if children == 0 {
                return None;
            }
            let byte = ((key as u64) >> (56 - 8 * d)) & 0xFF;
            let mut w = [0i64; 1];
            rack.read_words(children + byte * 8, &mut w);
            if w[0] == 0 {
                return None;
            }
            cur = w[0] as GAddr;
        }
        let leaf = Self::read_hdr(rack, cur);
        (leaf[0] != 0).then_some(leaf[1])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::DEFAULT_ETA;
    use crate::rack::RackConfig;

    fn rack() -> Rack {
        Rack::new(RackConfig {
            nodes: 2,
            node_capacity: 64 << 20,
            granularity: 1 << 20,
            ..Default::default()
        })
    }

    #[test]
    fn insert_get_round_trip() {
        let mut r = rack();
        let mut t = RadixTrie::new(&mut r);
        for i in 0..400i64 {
            t.insert(&mut r, i * 7, i);
        }
        for i in 0..400i64 {
            assert_eq!(t.get(&mut r, i * 7), Some(i), "key {}", i * 7);
        }
        assert_eq!(t.get(&mut r, 3), None);
        assert_eq!(t.len, 400);
    }

    #[test]
    fn empty_and_missing_paths() {
        let mut r = rack();
        let mut t = RadixTrie::new(&mut r);
        assert_eq!(t.get(&mut r, 0), None); // empty root
        t.insert(&mut r, 0x0102_0304, 9);
        assert_eq!(t.get(&mut r, 0x0102_0304), Some(9));
        assert_eq!(t.get(&mut r, 0x0102_0305), None); // last-byte miss
        assert_eq!(t.get(&mut r, 0x0202_0304), None); // early-byte miss
    }

    #[test]
    fn negative_and_extreme_keys() {
        let mut r = rack();
        let mut t = RadixTrie::new(&mut r);
        for k in [-1i64, i64::MIN, i64::MAX, 0, 255, 256, -256] {
            t.insert(&mut r, k, k ^ 0x5A);
        }
        for k in [-1i64, i64::MIN, i64::MAX, 0, 255, 256, -256] {
            assert_eq!(t.get(&mut r, k), Some(k ^ 0x5A), "key {k}");
            assert_eq!(t.host_get(&mut r, k), Some(k ^ 0x5A), "host {k}");
        }
        assert_eq!(t.get(&mut r, -2), None);
    }

    #[test]
    fn offloaded_matches_host() {
        let mut r = rack();
        let mut t = RadixTrie::new(&mut r);
        for i in 0..200i64 {
            t.insert(&mut r, (i * 2654435761) % 100_000, i);
        }
        for k in 0..300i64 {
            let probe = (k * 2654435761) % 100_000;
            assert_eq!(
                t.get(&mut r, probe),
                t.host_get(&mut r, probe),
                "key {probe}"
            );
        }
    }

    #[test]
    fn overwrite_keeps_len() {
        let mut r = rack();
        let mut t = RadixTrie::new(&mut r);
        t.insert(&mut r, 77, 1);
        t.insert(&mut r, 77, 2);
        assert_eq!(t.len, 1);
        assert_eq!(t.get(&mut r, 77), Some(2));
    }

    #[test]
    fn lookup_costs_two_iters_per_byte() {
        let mut r = rack();
        let mut t = RadixTrie::new(&mut r);
        t.insert(&mut r, 12345, 1);
        let mut sp = [0i64; SP_WORDS];
        sp[SP_KEY as usize] = 12345;
        sp[SP_REM as usize] = 12345;
        let (_st, _sp, iters) = rack_traverse(&mut r, &t, sp);
        assert_eq!(iters, (2 * KEY_BYTES + 1) as u32);
    }

    fn rack_traverse(
        r: &mut Rack,
        t: &RadixTrie,
        sp: [i64; SP_WORDS],
    ) -> (crate::isa::Status, [i64; SP_WORDS], u32) {
        r.traverse(&t.lookup_p, t.root, sp)
    }

    #[test]
    fn window_stays_narrow_despite_256_way_fanout() {
        let it = lookup_iter();
        // the whole point: 256-way dispatch without widening the
        // aggregated LOAD past the header words
        assert_eq!(it.program.load_words, 3);
        assert!(it.offloadable(DEFAULT_ETA), "ratio {}", it.ratio());
    }
}
