//! Google cpp-btree on disaggregated memory (paper Appendix B.3,
//! Listings 8–9: `internal_locate_plain_compare`).
//!
//! Node layout (kNodeValues = 8):
//!   `[is_leaf, num_keys, keys[8] (2..10), child[9] (10..19)]`  — internal
//!   `[is_leaf, num_keys, keys[8] (2..10), values[8] (10..18)]` — leaf
//! Keys are padded with `i64::MAX` past `num_keys` so the unrolled scan
//! needs no bound check (needle ≤ MAX always breaks at the first pad).
//!
//! Exactly like Listing 9, the offloaded iterator *returns the leaf
//! pointer*; the host completes the final in-leaf search with one read.

use std::sync::Arc;

use super::{SP_KEY, SP_RESULT};
use crate::compiler::{CompiledIter, IterBuilder};
use crate::isa::SP_WORDS;
use crate::mem::GAddr;
use crate::rack::Rack;

pub const FANOUT: usize = 8;
const NODE_WORDS: usize = 2 + FANOUT + FANOUT + 1; // 19

/// Listing 9: descend by `first i with needle <= keys[i]`, return
/// cur_ptr when is_leaf.
pub fn locate_iter() -> CompiledIter {
    let mut b = IterBuilder::new();
    let tag = b.field(0);
    let one = b.imm(1);
    b.if_eq(tag, one, |b| {
        let me = b.cur_ptr();
        b.sp_store(SP_RESULT, me);
        b.ret();
    });
    let needle = b.sp_input(SP_KEY);
    let idx = b.var(0);
    let mark = b.temp_mark();
    b.for_fixed(FANOUT, |b, j| {
        let k = b.field(2 + j as u32);
        // child[idx] with idx = |{j : keys[j] <= needle}| — separators
        // are min-of-right-child, so equality descends right. Guarding
        // each increment (instead of breaking) is equivalent because the
        // build keeps keys sorted.
        b.if_le(k, needle, |b| b.add_assign(idx, 1));
        b.temp_release(mark);
    });
    let child = b.field_dyn(idx, 10, (NODE_WORDS - 1) as u32);
    b.advance(child);
    b.finish().expect("btree locate")
}

pub struct GoogleBtree {
    pub root: GAddr,
    pub len: usize,
    height: usize,
    locate: Arc<CompiledIter>,
}

impl GoogleBtree {
    /// Bulk-build from sorted (key, value) pairs.
    pub fn build_sorted(rack: &mut Rack, pairs: &[(i64, i64)]) -> Self {
        assert!(!pairs.is_empty());
        // leaves
        let mut level: Vec<(i64, GAddr)> = Vec::new(); // (min key, addr)
        for chunk in pairs.chunks(FANOUT) {
            let addr = rack.alloc((NODE_WORDS * 8) as u64);
            let mut node = [0i64; NODE_WORDS];
            node[0] = 1;
            node[1] = chunk.len() as i64;
            for j in 0..FANOUT {
                node[2 + j] =
                    chunk.get(j).map(|p| p.0).unwrap_or(i64::MAX);
                node[10 + j] = chunk.get(j).map(|p| p.1).unwrap_or(0);
            }
            rack.write_words(addr, &node);
            level.push((chunk[0].0, addr));
        }
        let mut height = 1;
        while level.len() > 1 {
            let mut next: Vec<(i64, GAddr)> = Vec::new();
            for group in level.chunks(FANOUT + 1) {
                let addr = rack.alloc((NODE_WORDS * 8) as u64);
                let mut node = [0i64; NODE_WORDS];
                node[0] = 0;
                node[1] = (group.len() - 1) as i64;
                for j in 0..FANOUT {
                    // separator j = min key of child j+1
                    node[2 + j] = group
                        .get(j + 1)
                        .map(|g| g.0)
                        .unwrap_or(i64::MAX);
                }
                for (j, g) in group.iter().enumerate() {
                    node[10 + j] = g.1 as i64;
                }
                rack.write_words(addr, &node);
                next.push((group[0].0, addr));
            }
            level = next;
            height += 1;
        }
        Self {
            root: level[0].1,
            len: pairs.len(),
            height,
            locate: Arc::new(locate_iter()),
        }
    }

    pub fn locate_program(&self) -> Arc<CompiledIter> {
        self.locate.clone()
    }

    pub fn height(&self) -> usize {
        self.height
    }

    /// Offloaded locate + host-side in-leaf search (Listing 8/9 split).
    pub fn get(&self, rack: &mut Rack, key: i64) -> Option<i64> {
        let mut sp = [0i64; SP_WORDS];
        sp[SP_KEY as usize] = key;
        let (_st, sp, _) = rack.traverse(&self.locate, self.root, sp);
        let leaf = sp[SP_RESULT as usize] as GAddr;
        if leaf == 0 {
            return None;
        }
        let mut node = [0i64; NODE_WORDS];
        rack.read_words(leaf, &mut node);
        let nk = node[1] as usize;
        for j in 0..nk {
            if node[2 + j] == key {
                return Some(node[10 + j]);
            }
        }
        None
    }

    /// Host full descend (reference).
    pub fn host_get(&self, rack: &mut Rack, key: i64) -> Option<i64> {
        let mut cur = self.root;
        loop {
            let mut node = [0i64; NODE_WORDS];
            rack.read_words(cur, &mut node);
            if node[0] == 1 {
                let nk = node[1] as usize;
                for j in 0..nk {
                    if node[2 + j] == key {
                        return Some(node[10 + j]);
                    }
                }
                return None;
            }
            // same convention as the iterator: count of separators <= key
            let mut i = 0;
            while i < FANOUT && node[2 + i] <= key {
                i += 1;
            }
            cur = node[10 + i] as GAddr;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rack::RackConfig;

    fn rack() -> Rack {
        Rack::new(RackConfig {
            nodes: 2,
            node_capacity: 32 << 20,
            granularity: 1 << 20,
            ..Default::default()
        })
    }

    #[test]
    fn bulk_build_and_get() {
        let mut r = rack();
        let pairs: Vec<(i64, i64)> =
            (0..1000).map(|i| (i * 2, i * 20)).collect();
        let t = GoogleBtree::build_sorted(&mut r, &pairs);
        assert!(t.height() >= 3);
        for i in 0..1000 {
            assert_eq!(t.get(&mut r, i * 2), Some(i * 20), "key {}", i * 2);
            assert_eq!(t.get(&mut r, i * 2 + 1), None);
        }
    }

    #[test]
    fn offloaded_matches_host() {
        let mut r = rack();
        let pairs: Vec<(i64, i64)> =
            (0..500).map(|i| (i * 3 + 7, i)).collect();
        let t = GoogleBtree::build_sorted(&mut r, &pairs);
        for k in 0..1600 {
            assert_eq!(t.get(&mut r, k), t.host_get(&mut r, k), "key {k}");
        }
    }

    #[test]
    fn single_leaf_tree() {
        let mut r = rack();
        let t =
            GoogleBtree::build_sorted(&mut r, &[(5, 50), (7, 70), (9, 90)]);
        assert_eq!(t.height(), 1);
        assert_eq!(t.get(&mut r, 7), Some(70));
        assert_eq!(t.get(&mut r, 8), None);
    }

    #[test]
    fn locate_program_ratio_matches_table3() {
        let it = locate_iter();
        assert!(it.offloadable(0.75), "ratio {}", it.ratio());
        // Table 3: B+Tree family t_c/t_d ≈ 0.6-0.7
        assert!(
            it.ratio() > 0.35 && it.ratio() < 0.75,
            "ratio {}",
            it.ratio()
        );
    }
}
