//! Ordered-tree family on disaggregated memory: STL `map` / `set` /
//! `multimap` / `multiset` plus Boost AVL, splay and scapegoat trees
//! (paper Appendix B.4/B.5, Listings 10–13).
//!
//! The paper's observation (Table 5): all of these share the same
//! offloaded traversal — the `lower_bound` walk — differing only in
//! host-side balancing. We implement exactly that split: one compiled
//! iterator; four insertion disciplines (plain BST for STL's RB-tree
//! stand-in, AVL rotations, splay-to-root, scapegoat rebuild).
//!
//! Node layout: `[key, value, left, right]` (4 words). Balancing
//! metadata (heights, subtree sizes) is kept host-side; on-memory nodes
//! stay 4 words so the aggregated LOAD stays small.

use std::collections::HashMap;
use std::sync::Arc;

use super::{KEY_NOT_FOUND, SP_FLAG, SP_KEY, SP_RESULT};
use crate::compiler::{CompiledIter, IterBuilder};
use crate::isa::SP_WORDS;
use crate::mem::GAddr;
use crate::rack::Rack;

const NODE_WORDS: usize = 4;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BstKind {
    /// STL map/set/multimap/multiset stand-in (unbalanced BST — STL uses
    /// an RB-tree; traversal reads are identical).
    Plain,
    /// Boost intrusive AVL tree.
    Avl,
    /// Boost splay tree (splay on insert; lookups offloaded read-only).
    Splay,
    /// Boost scapegoat tree (α = 0.7 rebuild).
    Scapegoat,
}

/// `lower_bound` walk (Listing 11/13): y = best-so-far; descend left
/// when key <= node.key (recording y), right otherwise; at null, check
/// y's key for equality.
///
/// sp[KEY] = needle; sp[RESULT] = value on hit; sp[FLAG] = NOT_FOUND.
pub fn lower_bound_iter() -> CompiledIter {
    let mut b = IterBuilder::new();
    let needle = b.sp_input(SP_KEY);
    let key = b.field(0);
    // child = (needle <= key) ? (y = cur; left) : right
    let child = b.var(0);
    b.if_else_lt(
        key,
        needle,
        |b| {
            // key < needle: go right
            let r = b.field(3);
            b.assign(child, r);
        },
        |b| {
            // needle <= key: record y (value candidate) and go left
            let v = b.field(1);
            b.sp_store(SP_RESULT, v);
            let k = b.field(0);
            b.sp_store(SP_FLAG, k); // stash candidate key in FLAG
            let l = b.field(2);
            b.assign(child, l);
        },
    );
    let zero = b.imm(0);
    b.if_eq(child, zero, |b| b.ret());
    b.advance(child);
    b.finish().expect("lower_bound iterator")
}

struct HostMeta {
    height: i32, // AVL
    #[allow(dead_code)] // scapegoat rebuilds currently re-measure depth
    size: usize,
}

pub struct BstMap {
    pub kind: BstKind,
    pub root: GAddr,
    pub len: usize,
    /// Host-side balancing metadata (never on the memory nodes).
    meta: HashMap<GAddr, HostMeta>,
    find: Arc<CompiledIter>,
    /// scapegoat parameters
    alpha: f64,
    max_len: usize,
}

impl BstMap {
    pub fn new(kind: BstKind) -> Self {
        Self {
            kind,
            root: 0,
            len: 0,
            meta: HashMap::new(),
            find: Arc::new(lower_bound_iter()),
            alpha: 0.7,
            max_len: 0,
        }
    }

    pub fn find_program(&self) -> Arc<CompiledIter> {
        self.find.clone()
    }

    fn node(rack: &mut Rack, addr: GAddr) -> [i64; NODE_WORDS] {
        let mut n = [0i64; NODE_WORDS];
        rack.read_words(addr, &mut n);
        n
    }

    fn write(rack: &mut Rack, addr: GAddr, n: &[i64; NODE_WORDS]) {
        rack.write_words(addr, n);
    }

    pub fn insert(&mut self, rack: &mut Rack, key: i64, value: i64) {
        let addr = rack.alloc((NODE_WORDS * 8) as u64);
        Self::write(rack, addr, &[key, value, 0, 0]);
        self.meta.insert(addr, HostMeta { height: 1, size: 1 });
        self.root = match self.kind {
            BstKind::Plain => self.insert_plain(rack, self.root, addr),
            BstKind::Avl => self.insert_avl(rack, self.root, addr),
            BstKind::Splay => {
                let r = self.insert_plain(rack, self.root, addr);
                self.splay(rack, r, key)
            }
            BstKind::Scapegoat => {
                let r = self.insert_plain(rack, self.root, addr);
                self.len += 1;
                self.max_len = self.max_len.max(self.len);
                let r = self.maybe_rebuild(rack, r);
                self.len -= 1; // re-added below
                r
            }
        };
        self.len += 1;
    }

    /// Plain BST insert; equal keys descend right (multimap semantics —
    /// the first inserted equal key is what lower_bound finds).
    fn insert_plain(&mut self, rack: &mut Rack, root: GAddr, new: GAddr) -> GAddr {
        if root == 0 {
            return new;
        }
        let nk = Self::node(rack, new)[0];
        let mut cur = root;
        loop {
            let mut n = Self::node(rack, cur);
            if nk < n[0] {
                if n[2] == 0 {
                    n[2] = new as i64;
                    Self::write(rack, cur, &n);
                    break;
                }
                cur = n[2] as GAddr;
            } else {
                if n[3] == 0 {
                    n[3] = new as i64;
                    Self::write(rack, cur, &n);
                    break;
                }
                cur = n[3] as GAddr;
            }
        }
        root
    }

    // ---- AVL ------------------------------------------------------------
    fn height(&self, a: GAddr) -> i32 {
        if a == 0 {
            0
        } else {
            self.meta.get(&a).map(|m| m.height).unwrap_or(1)
        }
    }

    fn fix_height(&mut self, rack: &mut Rack, a: GAddr) {
        let n = Self::node(rack, a);
        let h = 1 + self
            .height(n[2] as GAddr)
            .max(self.height(n[3] as GAddr));
        self.meta.entry(a).or_insert(HostMeta { height: 1, size: 1 }).height =
            h;
    }

    fn rotate_right(&mut self, rack: &mut Rack, y: GAddr) -> GAddr {
        let mut ny = Self::node(rack, y);
        let x = ny[2] as GAddr;
        let mut nx = Self::node(rack, x);
        ny[2] = nx[3];
        nx[3] = y as i64;
        Self::write(rack, y, &ny);
        Self::write(rack, x, &nx);
        self.fix_height(rack, y);
        self.fix_height(rack, x);
        x
    }

    fn rotate_left(&mut self, rack: &mut Rack, x: GAddr) -> GAddr {
        let mut nx = Self::node(rack, x);
        let y = nx[3] as GAddr;
        let mut ny = Self::node(rack, y);
        nx[3] = ny[2];
        ny[2] = x as i64;
        Self::write(rack, x, &nx);
        Self::write(rack, y, &ny);
        self.fix_height(rack, x);
        self.fix_height(rack, y);
        y
    }

    fn insert_avl(&mut self, rack: &mut Rack, root: GAddr, new: GAddr) -> GAddr {
        if root == 0 {
            return new;
        }
        let nk = Self::node(rack, new)[0];
        let mut n = Self::node(rack, root);
        if nk < n[0] {
            let sub = self.insert_avl(rack, n[2] as GAddr, new);
            n[2] = sub as i64;
        } else {
            let sub = self.insert_avl(rack, n[3] as GAddr, new);
            n[3] = sub as i64;
        }
        Self::write(rack, root, &n);
        self.fix_height(rack, root);
        self.rebalance(rack, root)
    }

    fn rebalance(&mut self, rack: &mut Rack, a: GAddr) -> GAddr {
        let n = Self::node(rack, a);
        let bf = self.height(n[2] as GAddr) - self.height(n[3] as GAddr);
        if bf > 1 {
            let l = n[2] as GAddr;
            let nl = Self::node(rack, l);
            if self.height(nl[2] as GAddr) < self.height(nl[3] as GAddr) {
                let newl = self.rotate_left(rack, l);
                let mut n2 = Self::node(rack, a);
                n2[2] = newl as i64;
                Self::write(rack, a, &n2);
            }
            self.rotate_right(rack, a)
        } else if bf < -1 {
            let r = n[3] as GAddr;
            let nr = Self::node(rack, r);
            if self.height(nr[3] as GAddr) < self.height(nr[2] as GAddr) {
                let newr = self.rotate_right(rack, r);
                let mut n2 = Self::node(rack, a);
                n2[3] = newr as i64;
                Self::write(rack, a, &n2);
            }
            self.rotate_left(rack, a)
        } else {
            a
        }
    }

    // ---- splay ------------------------------------------------------------
    /// Bottom-up splay of `key` to the root (host path; simplified
    /// top-down variant via repeated rotations).
    fn splay(&mut self, rack: &mut Rack, root: GAddr, key: i64) -> GAddr {
        if root == 0 {
            return 0;
        }
        let n = Self::node(rack, root);
        if key < n[0] && n[2] != 0 {
            let mut n = n;
            let l = n[2] as GAddr;
            let sub = self.splay(rack, l, key);
            n[2] = sub as i64;
            Self::write(rack, root, &n);
            self.rotate_right(rack, root)
        } else if key > n[0] && n[3] != 0 {
            let mut n = n;
            let r = n[3] as GAddr;
            let sub = self.splay(rack, r, key);
            n[3] = sub as i64;
            Self::write(rack, root, &n);
            self.rotate_left(rack, root)
        } else {
            root
        }
    }

    // ---- scapegoat ----------------------------------------------------------
    fn subtree_nodes(rack: &mut Rack, a: GAddr, out: &mut Vec<(i64, i64, GAddr)>) {
        if a == 0 {
            return;
        }
        let n = Self::node(rack, a);
        Self::subtree_nodes(rack, n[2] as GAddr, out);
        out.push((n[0], n[1], a));
        Self::subtree_nodes(rack, n[3] as GAddr, out);
    }

    fn rebuild(rack: &mut Rack, sorted: &[(i64, i64, GAddr)]) -> GAddr {
        if sorted.is_empty() {
            return 0;
        }
        let mid = sorted.len() / 2;
        let (k, v, a) = sorted[mid];
        let l = Self::rebuild(rack, &sorted[..mid]);
        let r = Self::rebuild(rack, &sorted[mid + 1..]);
        Self::write(rack, a, &[k, v, l as i64, r as i64]);
        a
    }


    fn maybe_rebuild(&mut self, rack: &mut Rack, root: GAddr) -> GAddr {
        // α-height check: rebuild the whole tree when depth exceeds
        // log_{1/α}(n) (coarse but faithful to scapegoat semantics).
        let limit = ((self.len.max(2) as f64).ln()
            / (1.0 / self.alpha).ln())
        .floor() as usize
            + 1;
        // measure depth of the most recent insert — approximated by the
        // max depth of the tree (host metadata-free check).
        let mut stack = vec![(root, 0usize)];
        let mut maxd = 0;
        while let Some((a, d)) = stack.pop() {
            if a == 0 {
                continue;
            }
            maxd = maxd.max(d);
            let n = Self::node(rack, a);
            stack.push((n[2] as GAddr, d + 1));
            stack.push((n[3] as GAddr, d + 1));
        }
        if maxd > limit {
            let mut nodes = Vec::with_capacity(self.len + 1);
            Self::subtree_nodes(rack, root, &mut nodes);
            return Self::rebuild(rack, &nodes);
        }
        root
    }

    // ---- lookups ---------------------------------------------------------
    /// Offloaded find (exact match via lower_bound walk).
    pub fn get(&self, rack: &mut Rack, key: i64) -> Option<i64> {
        if self.root == 0 {
            return None;
        }
        let mut sp = [0i64; SP_WORDS];
        sp[SP_KEY as usize] = key;
        sp[SP_FLAG as usize] = KEY_NOT_FOUND;
        let (_st, sp, _) = rack.traverse(&self.find, self.root, sp);
        (sp[SP_FLAG as usize] == key).then_some(sp[SP_RESULT as usize])
    }

    /// Host reference.
    pub fn host_get(&self, rack: &mut Rack, key: i64) -> Option<i64> {
        let mut cur = self.root;
        let mut best: Option<(i64, i64)> = None;
        while cur != 0 {
            let n = Self::node(rack, cur);
            if key <= n[0] {
                best = Some((n[0], n[1]));
                cur = n[2] as GAddr;
            } else {
                cur = n[3] as GAddr;
            }
        }
        best.and_then(|(k, v)| (k == key).then_some(v))
    }

    /// Max depth (balancing diagnostics for tests).
    pub fn depth(&self, rack: &mut Rack) -> usize {
        let mut stack = vec![(self.root, 0usize)];
        let mut maxd = 0;
        while let Some((a, d)) = stack.pop() {
            if a == 0 {
                continue;
            }
            maxd = maxd.max(d + 1);
            let n = Self::node(rack, a);
            stack.push((n[2] as GAddr, d + 1));
            stack.push((n[3] as GAddr, d + 1));
        }
        maxd
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rack::RackConfig;

    fn rack() -> Rack {
        Rack::new(RackConfig {
            nodes: 2,
            node_capacity: 32 << 20,
            granularity: 1 << 20,
            ..Default::default()
        })
    }

    fn check_kind(kind: BstKind) {
        let mut r = rack();
        let mut t = BstMap::new(kind);
        let keys: Vec<i64> = (0..300).map(|i| (i * 37) % 1000).collect();
        let mut inserted = std::collections::HashSet::new();
        for &k in &keys {
            if inserted.insert(k) {
                t.insert(&mut r, k, k * 10);
            }
        }
        for k in 0..1000 {
            let want = inserted.contains(&k).then_some(k * 10);
            assert_eq!(t.get(&mut r, k), want, "{kind:?} key {k}");
            assert_eq!(t.host_get(&mut r, k), want, "{kind:?} host {k}");
        }
    }

    #[test]
    fn plain_bst_find() {
        check_kind(BstKind::Plain);
    }

    #[test]
    fn avl_find() {
        check_kind(BstKind::Avl);
    }

    #[test]
    fn splay_find() {
        check_kind(BstKind::Splay);
    }

    #[test]
    fn scapegoat_find() {
        check_kind(BstKind::Scapegoat);
    }

    #[test]
    fn avl_stays_balanced_on_sorted_insert() {
        let mut r = rack();
        let mut t = BstMap::new(BstKind::Avl);
        for k in 0..512 {
            t.insert(&mut r, k, k);
        }
        let d = t.depth(&mut r);
        assert!(d <= 11, "AVL depth {d} for 512 sorted inserts");
        assert_eq!(t.get(&mut r, 300), Some(300));
    }

    #[test]
    fn scapegoat_bounds_depth_on_sorted_insert() {
        let mut r = rack();
        let mut t = BstMap::new(BstKind::Scapegoat);
        for k in 0..256 {
            t.insert(&mut r, k, k);
        }
        let d = t.depth(&mut r);
        assert!(d <= 24, "scapegoat depth {d}");
        for k in 0..256 {
            assert_eq!(t.get(&mut r, k), Some(k));
        }
    }

    #[test]
    fn splay_moves_accessed_key_toward_root() {
        let mut r = rack();
        let mut t = BstMap::new(BstKind::Splay);
        for k in 0..64 {
            t.insert(&mut r, k, k);
        }
        // last inserted key is splayed to the root
        let root = BstMap::node(&mut r, t.root);
        assert_eq!(root[0], 63);
    }

    #[test]
    fn multimap_semantics_first_equal_key_wins() {
        let mut r = rack();
        let mut t = BstMap::new(BstKind::Plain);
        t.insert(&mut r, 5, 1);
        t.insert(&mut r, 5, 2); // duplicate key goes right
        assert_eq!(t.get(&mut r, 5), Some(1));
    }

    #[test]
    fn lower_bound_program_is_offloadable() {
        let it = lower_bound_iter();
        assert!(it.offloadable(0.75), "ratio {}", it.ratio());
    }
}
