//! Boost `unordered_map` / `unordered_set` on disaggregated memory
//! (paper §3 Listings 2–3, Appendix B.2).
//!
//! Layout: a bucket array of *sentinel nodes* (`[SENTINEL_KEY, 0, head]`)
//! followed by chain nodes `[key, value, next]`. `init()` runs at the
//! CPU node (paper §3): it hashes the key and computes the bucket
//! sentinel's address; the offloaded program then walks the sentinel +
//! chain uniformly. This mirrors `bucket_ptr(hash(key))` in Listing 3.

use std::collections::BTreeMap;
use std::sync::Arc;

use super::{KEY_NOT_FOUND, SP_FLAG, SP_KEY, SP_RESULT};
use crate::compiler::{CompiledIter, IterBuilder};
use crate::isa::SP_WORDS;
use crate::mem::GAddr;
use crate::rack::{Op, Rack};
use crate::util::zipf::fnv1a_64;

/// Sentinel key no application key may use.
const SENTINEL: i64 = i64::MIN;

const NODE_WORDS: usize = 3;

pub struct HashMapDs {
    pub buckets: usize,
    /// buckets per node shard; bucket b lives on shard b / per_node.
    per_node: usize,
    shard_bases: Vec<GAddr>,
    pub len: usize,
    find: Arc<CompiledIter>,
    update: Arc<CompiledIter>,
}

/// Chain-walk program (shared by map/set/bimap): compare sp[KEY] with
/// node key; on match store value + node addr; else follow next.
/// The bucket sentinel's key never matches, so it walks through.
pub fn chain_find_iter() -> CompiledIter {
    let mut b = IterBuilder::new();
    let needle = b.sp_input(SP_KEY);
    let key = b.field(0);
    b.if_eq(needle, key, |b| {
        let val = b.field(1);
        b.sp_store(SP_RESULT, val);
        let zero = b.imm(0);
        b.sp_store(SP_FLAG, zero);
        b.ret();
    });
    let next = b.field(2);
    let zero = b.imm(0);
    b.if_eq(next, zero, |b| {
        let nf = b.imm(KEY_NOT_FOUND);
        b.sp_store(SP_FLAG, nf);
        b.ret();
    });
    b.advance(next);
    b.finish().expect("chain find")
}

/// Mutating chain walk: overwrite the value in place on match (YCSB
/// update operations; exercises the write-back path, Appendix C.2).
pub fn chain_update_iter() -> CompiledIter {
    let mut b = IterBuilder::new();
    let needle = b.sp_input(SP_KEY);
    let key = b.field(0);
    b.if_eq(needle, key, |b| {
        let newval = b.sp_input(SP_RESULT);
        b.store_field(1, newval);
        let zero = b.imm(0);
        b.sp_store(SP_FLAG, zero);
        b.ret();
    });
    let next = b.field(2);
    let zero = b.imm(0);
    b.if_eq(next, zero, |b| {
        let nf = b.imm(KEY_NOT_FOUND);
        b.sp_store(SP_FLAG, nf);
        b.ret();
    });
    b.advance(next);
    b.finish().expect("chain update")
}

impl HashMapDs {
    /// Allocate the bucket array (sentinel nodes) eagerly. The array is
    /// *partitioned across memory nodes by primary key* (paper §6.1:
    /// "the hash table is partitioned across memory nodes based on
    /// primary keys"), so bucket traffic spreads over all accelerators.
    pub fn build(rack: &mut Rack, buckets: usize) -> Self {
        let nodes = rack.cfg.nodes;
        let stride = (NODE_WORDS * 8) as u64;
        let per_node = buckets.div_ceil(nodes);
        let mut shard_bases = Vec::with_capacity(nodes);
        for n in 0..nodes {
            let base = rack.alloc_on(n as u16, per_node as u64 * stride);
            for i in 0..per_node {
                rack.write_words(
                    base + i as u64 * stride,
                    &[SENTINEL, 0, 0],
                );
            }
            shard_bases.push(base);
        }
        Self {
            buckets,
            per_node,
            shard_bases,
            len: 0,
            find: Arc::new(chain_find_iter()),
            update: Arc::new(chain_update_iter()),
        }
    }

    pub fn find_program(&self) -> Arc<CompiledIter> {
        self.find.clone()
    }

    pub fn update_program(&self) -> Arc<CompiledIter> {
        self.update.clone()
    }

    /// `init()`: CPU-side start-pointer computation (paper §3).
    pub fn bucket_ptr(&self, key: i64) -> GAddr {
        let h = (fnv1a_64(key as u64) % self.buckets as u64) as usize;
        self.bucket_addr(h)
    }

    /// Sentinel address of bucket index `h` (invariant walker).
    pub fn bucket_addr(&self, h: usize) -> GAddr {
        let shard = h / self.per_node;
        let slot = h % self.per_node;
        self.shard_bases[shard] + (slot * NODE_WORDS * 8) as u64
    }

    /// The streamed lookup op for one key.
    pub fn find_op(&self, key: i64) -> Op {
        let mut sp = [0i64; SP_WORDS];
        sp[SP_KEY as usize] = key;
        Op::new(self.find.clone(), self.bucket_ptr(key), sp)
    }

    /// The streamed offloaded put-on-existing-key op (YCSB update):
    /// walks the bucket chain and overwrites the value in place via the
    /// dirty write-back path.
    pub fn update_op(&self, key: i64, value: i64) -> Op {
        let mut sp = [0i64; SP_WORDS];
        sp[SP_KEY as usize] = key;
        sp[SP_RESULT as usize] = value;
        Op::new(self.update.clone(), self.bucket_ptr(key), sp)
    }

    /// Host-path insert (new nodes are pushed at the chain head, after
    /// the sentinel).
    pub fn insert(&mut self, rack: &mut Rack, key: i64, value: i64) {
        assert_ne!(key, SENTINEL);
        let bucket = self.bucket_ptr(key);
        let mut sent = [0i64; NODE_WORDS];
        rack.read_words(bucket, &mut sent);
        // update in place if the key exists
        let mut cur = sent[2] as GAddr;
        while cur != 0 {
            let mut node = [0i64; NODE_WORDS];
            rack.read_words(cur, &mut node);
            if node[0] == key {
                node[1] = value;
                rack.write_words(cur, &node);
                return;
            }
            cur = node[2] as GAddr;
        }
        // chain nodes co-locate with their bucket (paper §6.1: "the
        // linked list for a hash bucket resides in a single memory
        // node"), so hash lookups never cross nodes.
        let node = rack.alloc.owner(bucket).expect("bucket unmapped");
        let addr = rack.alloc_on(node, (NODE_WORDS * 8) as u64);
        rack.write_words(addr, &[key, value, sent[2]]);
        sent[2] = addr as i64;
        rack.write_words(bucket, &sent);
        self.len += 1;
    }

    /// Offloaded find.
    pub fn get(&self, rack: &mut Rack, key: i64) -> Option<i64> {
        let mut sp = [0i64; SP_WORDS];
        sp[SP_KEY as usize] = key;
        let (_st, sp, _) =
            rack.traverse(&self.find, self.bucket_ptr(key), sp);
        (sp[SP_FLAG as usize] != KEY_NOT_FOUND)
            .then_some(sp[SP_RESULT as usize])
    }

    /// Offloaded update-in-place; returns false if the key is absent.
    pub fn update(&self, rack: &mut Rack, key: i64, value: i64) -> bool {
        let mut sp = [0i64; SP_WORDS];
        sp[SP_KEY as usize] = key;
        sp[SP_RESULT as usize] = value;
        let (_st, sp, _) =
            rack.traverse(&self.update, self.bucket_ptr(key), sp);
        sp[SP_FLAG as usize] != KEY_NOT_FOUND
    }

    /// Host reference walk.
    pub fn host_get(&self, rack: &mut Rack, key: i64) -> Option<i64> {
        let mut cur = self.bucket_ptr(key);
        loop {
            let mut node = [0i64; NODE_WORDS];
            rack.read_words(cur, &mut node);
            if node[0] == key {
                return Some(node[1]);
            }
            if node[2] == 0 {
                return None;
            }
            cur = node[2] as GAddr;
        }
    }

    /// Full host read-back of every (key, value) pair — the canonical
    /// final state the mixed read-write conformance suite compares
    /// across backends.
    pub fn host_items(&self, rack: &mut Rack) -> BTreeMap<i64, i64> {
        let mut out = BTreeMap::new();
        for h in 0..self.buckets {
            let mut cur = self.bucket_addr(h);
            let mut hops = 0usize;
            loop {
                let mut node = [0i64; NODE_WORDS];
                rack.read_words(cur, &mut node);
                if node[0] != SENTINEL {
                    out.insert(node[0], node[1]);
                }
                if node[2] == 0 {
                    break;
                }
                cur = node[2] as GAddr;
                hops += 1;
                assert!(hops <= self.len + 1, "bucket {h} chain cycle");
            }
        }
        out
    }

    /// Structural invariants after a (possibly concurrent) mutation
    /// stream: every bucket starts at an intact sentinel, every chain
    /// is acyclic, every chained key hashes to its bucket, and the
    /// total entry count matches `len` (offloaded updates overwrite in
    /// place — they never add or drop nodes).
    pub fn check_invariants(&self, rack: &mut Rack) {
        let mut total = 0usize;
        for h in 0..self.buckets {
            let bucket = self.bucket_addr(h);
            let mut sent = [0i64; NODE_WORDS];
            rack.read_words(bucket, &mut sent);
            assert_eq!(sent[0], SENTINEL, "bucket {h} sentinel clobbered");
            let mut cur = sent[2] as GAddr;
            let mut hops = 0usize;
            while cur != 0 {
                let mut node = [0i64; NODE_WORDS];
                rack.read_words(cur, &mut node);
                assert_ne!(node[0], SENTINEL, "sentinel mid-chain");
                assert_eq!(
                    self.bucket_ptr(node[0]),
                    bucket,
                    "key {} chained into the wrong bucket {h}",
                    node[0]
                );
                total += 1;
                hops += 1;
                assert!(hops <= self.len + 1, "bucket {h} chain cycle");
                cur = node[2] as GAddr;
            }
        }
        assert_eq!(total, self.len, "entry count drifted");
    }
}

/// Boost `unordered_set`: a map with unit values.
pub struct HashSetDs {
    inner: HashMapDs,
}

impl HashSetDs {
    pub fn build(rack: &mut Rack, buckets: usize) -> Self {
        Self { inner: HashMapDs::build(rack, buckets) }
    }

    pub fn insert(&mut self, rack: &mut Rack, key: i64) {
        self.inner.insert(rack, key, 1);
    }

    pub fn contains(&self, rack: &mut Rack, key: i64) -> bool {
        self.inner.get(rack, key).is_some()
    }

    /// The shared chain-walk program (op construction in benches/tests).
    pub fn find_program(&self) -> std::sync::Arc<crate::compiler::CompiledIter> {
        self.inner.find_program()
    }

    /// `init()` for a membership probe: the bucket sentinel address.
    pub fn bucket_ptr(&self, key: i64) -> GAddr {
        self.inner.bucket_ptr(key)
    }

    pub fn len(&self) -> usize {
        self.inner.len
    }

    pub fn is_empty(&self) -> bool {
        self.inner.len == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rack::RackConfig;

    fn rack() -> Rack {
        Rack::new(RackConfig {
            nodes: 2,
            node_capacity: 32 << 20,
            granularity: 1 << 20,
            ..Default::default()
        })
    }

    #[test]
    fn insert_get_round_trip() {
        let mut r = rack();
        let mut m = HashMapDs::build(&mut r, 64);
        for i in 0..500 {
            m.insert(&mut r, i, i * 2);
        }
        for i in 0..500 {
            assert_eq!(m.get(&mut r, i), Some(i * 2), "key {i}");
        }
        assert_eq!(m.get(&mut r, 1000), None);
        assert_eq!(m.len, 500);
    }

    #[test]
    fn collision_chains_work() {
        let mut r = rack();
        // 1 bucket: everything collides into one chain
        let mut m = HashMapDs::build(&mut r, 1);
        for i in 0..50 {
            m.insert(&mut r, i, 100 + i);
        }
        for i in 0..50 {
            assert_eq!(m.get(&mut r, i), Some(100 + i));
        }
        assert_eq!(m.get(&mut r, 50), None);
    }

    #[test]
    fn insert_overwrites() {
        let mut r = rack();
        let mut m = HashMapDs::build(&mut r, 16);
        m.insert(&mut r, 7, 1);
        m.insert(&mut r, 7, 2);
        assert_eq!(m.get(&mut r, 7), Some(2));
        assert_eq!(m.len, 1);
    }

    #[test]
    fn offloaded_update_writes_back() {
        let mut r = rack();
        let mut m = HashMapDs::build(&mut r, 16);
        m.insert(&mut r, 7, 1);
        assert!(m.update(&mut r, 7, 42));
        assert_eq!(m.host_get(&mut r, 7), Some(42));
        assert!(!m.update(&mut r, 8, 9));
    }

    #[test]
    fn host_items_and_invariants_track_updates() {
        let mut r = rack();
        let mut m = HashMapDs::build(&mut r, 8);
        for i in 0..60 {
            m.insert(&mut r, i, i);
        }
        m.check_invariants(&mut r);
        // streamed update ops through the functional path
        for i in (0..60).step_by(3) {
            let op = m.update_op(i, 1000 + i);
            r.run_op_functional(&op);
        }
        m.check_invariants(&mut r);
        let items = m.host_items(&mut r);
        assert_eq!(items.len(), 60);
        for i in 0..60 {
            let want = if i % 3 == 0 { 1000 + i } else { i };
            assert_eq!(items.get(&i), Some(&want), "key {i}");
        }
    }

    #[test]
    fn offloaded_matches_host() {
        let mut r = rack();
        let mut m = HashMapDs::build(&mut r, 32);
        for i in 0..200 {
            m.insert(&mut r, i * 3, i);
        }
        for k in 0..600 {
            assert_eq!(m.get(&mut r, k), m.host_get(&mut r, k), "key {k}");
        }
    }

    #[test]
    fn hashset_contains() {
        let mut r = rack();
        let mut s = HashSetDs::build(&mut r, 32);
        for i in (0..100).step_by(2) {
            s.insert(&mut r, i);
        }
        assert!(s.contains(&mut r, 42));
        assert!(!s.contains(&mut r, 43));
        assert_eq!(s.len(), 50);
    }

    #[test]
    fn programs_offloadable_with_low_ratio() {
        let it = chain_find_iter();
        assert!(it.offloadable(0.75));
        assert!(it.ratio() < 0.5, "hash chain ratio {}", it.ratio());
    }
}
