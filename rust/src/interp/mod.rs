//! Native logic-pipeline interpreter.
//!
//! Executes one iterator *iteration* (paper §4.2: the logic pipeline's
//! pass between two data fetches) over a `Workspace`. Semantics are
//! bit-identical to the Pallas kernel (`python/compile/kernels/
//! logic_step.py`) and the Python oracle; the equivalence is enforced by
//! `rust/tests/integration_runtime.rs` (vs the AOT XLA artifact) and
//! `rust/tests/proptest_isa.rs`.
//!
//! This is also the accelerator's fast-path engine — see
//! `accel::XlaBatchEngine` for the choice between native and XLA.

use crate::isa::{Instr, Op, Program, Status, DATA_WORDS, NREG, SP_WORDS};

/// Per-iterator workspace (paper §4.2): `cur_ptr` (regs[0]),
/// `scratch_pad`, and the `data` window loaded by the memory pipeline.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Workspace {
    pub regs: [i64; NREG],
    pub sp: [i64; SP_WORDS],
    pub data: [i64; DATA_WORDS],
}

impl Default for Workspace {
    fn default() -> Self {
        Self::new()
    }
}

impl Workspace {
    pub fn new() -> Self {
        Self {
            regs: [0; NREG],
            sp: [0; SP_WORDS],
            data: [0; DATA_WORDS],
        }
    }

    pub fn cur_ptr(&self) -> u64 {
        self.regs[0] as u64
    }

    pub fn set_cur_ptr(&mut self, p: u64) {
        self.regs[0] = p as i64;
    }

    /// Scratchpad as raw bytes (wire format of requests/responses).
    pub fn sp_bytes(&self) -> [u8; SP_WORDS * 8] {
        let mut out = [0u8; SP_WORDS * 8];
        for (i, w) in self.sp.iter().enumerate() {
            out[i * 8..i * 8 + 8].copy_from_slice(&w.to_le_bytes());
        }
        out
    }

    pub fn set_sp_bytes(&mut self, bytes: &[u8]) {
        for (i, chunk) in bytes.chunks_exact(8).enumerate().take(SP_WORDS) {
            self.sp[i] = i64::from_le_bytes(chunk.try_into().unwrap());
        }
    }
}

/// Result of one logic pass: terminal status + dynamic instruction count
/// (the DES uses the count for t_c accounting).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PassResult {
    pub status: Status,
    pub steps: u32,
}

/// Execute one iteration of `p` over `ws`. The caller (memory pipeline /
/// test driver) must have filled `ws.data` with the aggregated load for
/// `cur_ptr` beforehand.
pub fn logic_pass(p: &Program, ws: &mut Workspace) -> PassResult {
    let n = p.instrs.len();
    let mut pc = 0usize;
    let mut steps = 0u32;
    loop {
        steps += 1;
        if pc >= n {
            return PassResult { status: Status::Trap, steps };
        }
        let Instr { op, a, b, c, imm } = p.instrs[pc];
        let (a, b, c) = (a as usize, b as usize, c as usize);
        let mut next_pc = pc + 1;
        match op {
            Op::Nop => {}
            Op::Ldd => ws.regs[a] = ws.data[imm as usize],
            Op::Ldx => {
                let idx = ws.regs[b].wrapping_add(imm);
                if !(0..DATA_WORDS as i64).contains(&idx) {
                    return PassResult { status: Status::Trap, steps };
                }
                ws.regs[a] = ws.data[idx as usize];
            }
            Op::Std => ws.data[imm as usize] = ws.regs[a],
            Op::Stx => {
                let idx = ws.regs[b].wrapping_add(imm);
                if !(0..DATA_WORDS as i64).contains(&idx) {
                    return PassResult { status: Status::Trap, steps };
                }
                ws.data[idx as usize] = ws.regs[a];
            }
            Op::Spl => ws.regs[a] = ws.sp[imm as usize],
            Op::Splx => {
                let idx = ws.regs[b].wrapping_add(imm);
                if !(0..SP_WORDS as i64).contains(&idx) {
                    return PassResult { status: Status::Trap, steps };
                }
                ws.regs[a] = ws.sp[idx as usize];
            }
            Op::Sps => ws.sp[imm as usize] = ws.regs[a],
            Op::Spsx => {
                let idx = ws.regs[b].wrapping_add(imm);
                if !(0..SP_WORDS as i64).contains(&idx) {
                    return PassResult { status: Status::Trap, steps };
                }
                ws.sp[idx as usize] = ws.regs[a];
            }
            Op::Mov => ws.regs[a] = ws.regs[b],
            Op::Movi => ws.regs[a] = imm,
            Op::Add => ws.regs[a] = ws.regs[b].wrapping_add(ws.regs[c]),
            Op::Sub => ws.regs[a] = ws.regs[b].wrapping_sub(ws.regs[c]),
            Op::Mul => ws.regs[a] = ws.regs[b].wrapping_mul(ws.regs[c]),
            Op::Div => {
                if ws.regs[c] == 0 {
                    return PassResult { status: Status::Trap, steps };
                }
                ws.regs[a] = ws.regs[b].wrapping_div(ws.regs[c]);
            }
            Op::And => ws.regs[a] = ws.regs[b] & ws.regs[c],
            Op::Or => ws.regs[a] = ws.regs[b] | ws.regs[c],
            Op::Xor => ws.regs[a] = ws.regs[b] ^ ws.regs[c],
            Op::Not => ws.regs[a] = !ws.regs[b],
            Op::Shl => {
                ws.regs[a] = ws.regs[b].wrapping_shl((imm & 63) as u32)
            }
            Op::Shr => {
                ws.regs[a] =
                    ((ws.regs[b] as u64) >> ((imm & 63) as u32)) as i64
            }
            Op::Addi => ws.regs[a] = ws.regs[b].wrapping_add(imm),
            Op::Jeq | Op::Jne | Op::Jlt | Op::Jle | Op::Jgt | Op::Jge => {
                let (x, y) = (ws.regs[a], ws.regs[b]);
                let taken = match op {
                    Op::Jeq => x == y,
                    Op::Jne => x != y,
                    Op::Jlt => x < y,
                    Op::Jle => x <= y,
                    Op::Jgt => x > y,
                    Op::Jge => x >= y,
                    _ => unreachable!(),
                };
                if taken {
                    next_pc = imm as usize;
                }
            }
            Op::Jmp => next_pc = imm as usize,
            Op::Next => {
                return PassResult { status: Status::NextIter, steps }
            }
            Op::Ret => return PassResult { status: Status::Return, steps },
            Op::Trap => return PassResult { status: Status::Trap, steps },
        }
        pc = next_pc;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Asm;

    fn ws() -> Workspace {
        Workspace::new()
    }

    #[test]
    fn alu_semantics() {
        let mut a = Asm::new();
        a.movi(1, 7);
        a.movi(2, -3);
        a.add(3, 1, 2);
        a.sub(4, 1, 2);
        a.mul(5, 1, 2);
        a.div(6, 5, 1);
        a.and(7, 1, 4);
        a.or(8, 1, 4);
        a.xor(9, 1, 4);
        a.not(10, 1);
        a.shl(11, 1, 4);
        a.shr(12, 2, 60);
        a.addi(13, 1, 100);
        a.ret();
        let p = a.finish(1).unwrap();
        let mut w = ws();
        let r = logic_pass(&p, &mut w);
        assert_eq!(r.status, Status::Return);
        assert_eq!(
            &w.regs[1..14],
            &[7, -3, 4, 10, -21, -3, 2, 15, 13, !7, 112, 15, 107]
        );
    }

    #[test]
    fn wrapping_arithmetic() {
        let mut a = Asm::new();
        a.movi(1, i64::MAX);
        a.movi(2, 1);
        a.add(3, 1, 2);
        a.movi(4, i64::MIN);
        a.movi(5, -1);
        a.div(6, 4, 5);
        a.ret();
        let p = a.finish(1).unwrap();
        let mut w = ws();
        assert_eq!(logic_pass(&p, &mut w).status, Status::Return);
        assert_eq!(w.regs[3], i64::MIN);
        assert_eq!(w.regs[6], i64::MIN); // MIN / -1 wraps
    }

    #[test]
    fn div_zero_traps_without_commit() {
        let mut a = Asm::new();
        a.movi(1, 5);
        a.movi(2, 0);
        a.div(3, 1, 2);
        a.sps(1, 0);
        a.ret();
        let p = a.finish(1).unwrap();
        let mut w = ws();
        let r = logic_pass(&p, &mut w);
        assert_eq!(r.status, Status::Trap);
        assert_eq!(w.sp[0], 0); // sps never executed
        assert_eq!(r.steps, 3);
    }

    #[test]
    fn dynamic_oob_traps() {
        for (neg, win) in [(false, DATA_WORDS as i64), (true, -1)] {
            let mut a = Asm::new();
            a.movi(1, if neg { win } else { win });
            a.ldx(2, 1, 0);
            a.ret();
            let p = a.finish(1).unwrap();
            let mut w = ws();
            assert_eq!(logic_pass(&p, &mut w).status, Status::Trap);
        }
    }

    #[test]
    fn next_iter_reports_cur_ptr() {
        let mut a = Asm::new();
        a.ldd(1, 2);
        a.mov(0, 1);
        a.next();
        let p = a.finish(3).unwrap();
        let mut w = ws();
        w.data[2] = 0xABCD;
        let r = logic_pass(&p, &mut w);
        assert_eq!(r.status, Status::NextIter);
        assert_eq!(w.cur_ptr(), 0xABCD);
        assert_eq!(r.steps, 3);
    }

    #[test]
    fn fall_off_end_traps() {
        // jump one past the end
        let mut a = Asm::new();
        let end = a.label();
        a.jmp(end);
        a.ret();
        a.bind(end);
        // label binds after RET — jumping there falls off the program.
        let p = a.finish(1).unwrap();
        let mut w = ws();
        assert_eq!(logic_pass(&p, &mut w).status, Status::Trap);
    }

    #[test]
    fn sp_round_trip_bytes() {
        let mut w = ws();
        w.sp[0] = -1;
        w.sp[31] = 0x0123456789ABCDEF;
        let bytes = w.sp_bytes();
        let mut w2 = ws();
        w2.set_sp_bytes(&bytes);
        assert_eq!(w.sp, w2.sp);
    }

    #[test]
    fn dynamic_indexing_in_window() {
        // B+Tree-style scan: data[4 + i] keys, find first >= needle.
        let mut a = Asm::new();
        let found = a.label();
        let loop_done = a.label();
        a.spl(1, 0); // needle
        a.movi(2, 0); // i = 0
        for _ in 0..4 {
            a.ldx(3, 2, 4); // key_i = data[4 + i]
            a.jge(3, 1, found);
            a.addi(2, 2, 1);
        }
        a.jmp(loop_done);
        a.bind(found);
        a.bind(loop_done);
        a.sps(2, 1);
        a.ret();
        let p = a.finish(8).unwrap();
        let mut w = ws();
        w.sp[0] = 25;
        w.data[4..8].copy_from_slice(&[10, 20, 30, 40]);
        assert_eq!(logic_pass(&p, &mut w).status, Status::Return);
        assert_eq!(w.sp[1], 2); // first key >= 25 is index 2 (30)
    }
}
