//! Compared systems (paper §6): Cache (Fastswap-like swap), RPC (memnode
//! CPU), RPC-ARM (BlueField-2-like wimpy cores), Cache+RPC (AIFM-like),
//! and PULSE-ACC (a `RackConfig` flag, not a module).
//!
//! PULSE itself is measured with the full rack DES; the baselines share
//! the *same functional memory layout and traversals* (traces collected
//! through the rack) but time them with each system's execution model,
//! calibrated from the paper's testbed description (§6) and prior
//! systems' published numbers. See DESIGN.md §2.
//!
//! Every compared system is driven through the unified
//! [`crate::backend::TraversalBackend`] trait: the models here are
//! wrapped by `backend::CacheBackend` / `backend::RpcBackend`, so
//! benches and tests select systems by name instead of bespoke glue.

pub mod cache;
pub mod rpc;

pub use cache::{trace_op, CachedSwapSim, TraceStats};
pub use rpc::{RpcKind, RpcModel, SystemMetrics};

/// Aggregate workload statistics extracted from functional traces —
/// the interface between the apps and the baseline timing models.
#[derive(Debug, Clone, Copy, Default)]
pub struct WorkloadStats {
    /// average traversal iterations per logical op
    pub avg_iters: f64,
    /// 8 B words fetched per iteration
    pub words_per_iter: f64,
    /// request wire bytes (program + scratchpad + headers)
    pub req_bytes: f64,
    /// response payload (scratchpad + bulk object reads)
    pub resp_bytes: f64,
    /// average memory-node crossings per op (distributed traversals)
    pub avg_crossings: f64,
    /// CPU post-processing per op (encrypt/compress etc.)
    pub cpu_post_ns: f64,
    /// number of logical ops measured
    pub ops: u64,
}
