//! Cache-based baseline: swap-backed disaggregated memory (Fastswap
//! [42]-like). The CPU node keeps an LRU page cache over 4 KB pages;
//! every pointer dereference that misses faults a page over the network
//! (kernel fault handling + RTT + 4 KB transfer), and a saturated swap
//! system bounds throughput by its fault pipeline — the reason the paper
//! measures < 1 Gbps network utilization and 28–171× lower throughput
//! than PULSE for traversal workloads.

use std::collections::HashMap;

use crate::compiler::CompiledIter;
use crate::interp::logic_pass;
use crate::isa::{Status, NREG, SP_WORDS};
use crate::mem::GAddr;
use crate::rack::Rack;
use crate::sim::{LatencyModel, Ns};

pub const PAGE: u64 = 4096;

/// Address-level trace of one logical op: the page of every iteration's
/// aggregated load + bulk-read pages, plus the pages dirtied by
/// mutating traversals.
#[derive(Debug, Clone, Default)]
pub struct TraceStats {
    pub pages: Vec<GAddr>,
    /// Pages written by mutating iterations (`writes_data` programs) —
    /// the swap cache pays invalidation + write-back for each.
    pub writes: Vec<GAddr>,
    pub iters: u32,
    pub crossings: u32,
    /// The traversal followed a pointer into unmapped memory (the rack
    /// backend would answer this with a trap response).
    pub trapped: bool,
}

/// Functionally execute a traversal on the host, recording the page of
/// every pointer dereference (shared by the Cache and Cache+RPC
/// baselines).
pub fn trace_op(
    rack: &mut Rack,
    iter: &CompiledIter,
    start: GAddr,
    sp: [i64; SP_WORDS],
    extra_read_bytes: u64,
) -> ([i64; SP_WORDS], TraceStats) {
    let mut ws = crate::interp::Workspace::new();
    ws.sp.copy_from_slice(&sp);
    let words = iter.program.load_words as usize;
    let mut cur = start;
    let mut t = TraceStats::default();
    let mut last_node = rack.alloc.owner(start);
    let mut buf = vec![0i64; words];
    loop {
        let node = rack.alloc.owner(cur);
        if node.is_none() {
            // unmapped pointer: the rack would trap this request
            t.trapped = true;
            break;
        }
        t.pages.push(cur / PAGE);
        if node != last_node {
            t.crossings += 1;
            last_node = node;
        }
        if rack.try_read_words(cur, &mut buf).is_err() {
            t.trapped = true;
            break;
        }
        ws.regs = [0; NREG];
        ws.set_cur_ptr(cur);
        ws.data[..words].copy_from_slice(&buf);
        ws.data[words..].iter_mut().for_each(|w| *w = 0);
        let pass = logic_pass(&iter.program, &mut ws);
        t.iters += 1;
        // mutating traversals really apply their stores: the baselines
        // share the functional heap with every other backend, so a
        // YCSB update must be visible to later reads here too
        if iter.program.writes_data {
            if rack.try_write_words(cur, &ws.data[..words]).is_err() {
                t.trapped = true;
                break;
            }
            t.writes.push(cur / PAGE);
        }
        match pass.status {
            Status::NextIter => cur = ws.cur_ptr(),
            Status::Return => break,
            _ => {
                // ISA trap: mirror the rack backend's trap accounting
                t.trapped = true;
                break;
            }
        }
        if t.iters > 1_000_000 {
            break;
        }
    }
    // bulk read (e.g. the 8 KB object) touches contiguous pages
    for p in 0..extra_read_bytes.div_ceil(PAGE) {
        t.pages.push(cur / PAGE + 1 + p);
    }
    let mut out = [0i64; SP_WORDS];
    out.copy_from_slice(&ws.sp);
    (out, t)
}

/// Trace a full multi-stage [`Op`] (stage chains, scratchpad carry,
/// continuation rounds — the same plumbing as
/// `Rack::run_op_functional`), merging every round's page trace. This
/// is how the baseline execution models replay exactly the memory
/// accesses PULSE offloads (paper §6: same functional layout, different
/// timing model).
pub fn trace_full_op(
    rack: &mut Rack,
    op: &crate::rack::Op,
) -> ([i64; SP_WORDS], TraceStats) {
    let mut prev_sp = [0i64; SP_WORDS];
    let mut total = TraceStats::default();
    for stage in &op.stages {
        let mut repeat_from = None;
        loop {
            let (start, sp) = stage.resolve(&prev_sp, repeat_from);
            if start == 0 {
                // degenerate stage (e.g. empty structure): skip forward
                prev_sp = sp;
                break;
            }
            let (out, t) = trace_op(
                rack,
                &stage.iter,
                start,
                sp,
                stage.object_read_bytes as u64,
            );
            total.pages.extend_from_slice(&t.pages);
            total.writes.extend_from_slice(&t.writes);
            total.iters += t.iters;
            total.crossings += t.crossings;
            if t.trapped {
                total.trapped = true;
                return (out, total);
            }
            if stage.wants_repeat(&out) {
                repeat_from = Some(out);
                continue;
            }
            prev_sp = out;
            break;
        }
    }
    (prev_sp, total)
}

/// LRU page cache + swap timing model.
pub struct CachedSwapSim {
    capacity_pages: usize,
    lru: HashMap<GAddr, u64>,
    tick: u64,
    lat: LatencyModel,
    pub hits: u64,
    pub faults: u64,
    /// Writes that invalidated + flushed a page (write-heavy caching's
    /// dominant cost — see *Memory Disaggregation: Advances and Open
    /// Challenges*: invalidation traffic is what makes caches fare
    /// worst under mutation).
    pub invalidations: u64,
    /// Max outstanding faults the swap path sustains (Fastswap-like
    /// kernel swap has limited async depth; this is what caps
    /// throughput at the "swap system performance" the paper cites).
    pub fault_depth: usize,
}

impl CachedSwapSim {
    pub fn new(cache_bytes: u64) -> Self {
        Self {
            capacity_pages: (cache_bytes / PAGE).max(1) as usize,
            lru: HashMap::new(),
            tick: 0,
            lat: LatencyModel::default(),
            hits: 0,
            faults: 0,
            invalidations: 0,
            fault_depth: 2,
        }
    }

    /// A traversal mutated `page` on the memory side: the swap cache
    /// must write the dirty line through to the memory node and drop
    /// its cached copy (next read refaults). Returns the charged
    /// latency: kernel bookkeeping + one 4 KB flush over the network.
    pub fn invalidate(&mut self, page: GAddr) -> Ns {
        self.invalidations += 1;
        self.lru.remove(&page);
        self.inval_ns()
    }

    /// Cost of one invalidation: kernel path + the dirty-page flush.
    pub fn inval_ns(&self) -> Ns {
        self.lat.pagefault_sw_ns as Ns + self.lat.one_way_ns(PAGE as usize)
    }

    /// Touch a page; returns true on hit.
    pub fn access(&mut self, page: GAddr) -> bool {
        self.tick += 1;
        if let Some(t) = self.lru.get_mut(&page) {
            *t = self.tick;
            self.hits += 1;
            return true;
        }
        self.faults += 1;
        if self.lru.len() >= self.capacity_pages {
            // evict the oldest (O(n) scan amortized by batching evictions)
            let n_evict = (self.capacity_pages / 16).max(1);
            let mut entries: Vec<(GAddr, u64)> =
                self.lru.iter().map(|(&p, &t)| (p, t)).collect();
            entries.sort_by_key(|e| e.1);
            for (p, _) in entries.into_iter().take(n_evict) {
                self.lru.remove(&p);
            }
        }
        self.lru.insert(page, self.tick);
        false
    }

    /// Time to service one page fault: kernel handling + RTT with a
    /// 4 KB payload, plus reclaim/write-back work once the cache runs
    /// at capacity (the "could not evict pages fast enough" behaviour
    /// the paper observes for the swap system).
    pub fn fault_ns(&self) -> Ns {
        let base = self.lat.pagefault_sw_ns as Ns
            + 2 * self.lat.one_way_ns(PAGE as usize);
        if self.lru.len() >= self.capacity_pages {
            base + self.lat.pagefault_sw_ns as Ns
                + self.lat.one_way_ns(PAGE as usize)
        } else {
            base
        }
    }

    /// Per-op latency for a traced op (hit = L3/DRAM-ish, miss = fault;
    /// every dirtied page additionally pays invalidation + flush).
    pub fn op_latency_ns(&mut self, trace: &TraceStats, cpu_post_ns: f64) -> Ns {
        let mut t = 0u64;
        for &p in &trace.pages {
            if self.access(p) {
                t += self.lat.cpu_dram_ns as Ns;
            } else {
                t += self.fault_ns();
            }
        }
        for &p in &trace.writes {
            t += self.invalidate(p);
        }
        t + cpu_post_ns as Ns
    }

    /// Saturation throughput of the swap pipeline, ops/s, for a miss
    /// rate measured over the run. Dirty-page invalidations occupy the
    /// same kernel fault/flush pipeline, so write-heavy mixes bound
    /// lower even at high hit rates.
    pub fn tput_bound_ops_per_s(
        &self,
        pages_per_op: f64,
        writes_per_op: f64,
    ) -> f64 {
        let total = self.hits + self.faults;
        if total == 0 {
            return 0.0;
        }
        let miss = self.faults as f64 / total as f64;
        let faults_per_op = pages_per_op * miss;
        // pipeline time one op consumes: faults + dirty flushes
        let ns_per_op = faults_per_op * self.fault_ns() as f64
            + writes_per_op * self.inval_ns() as f64;
        if ns_per_op < 1e-9 {
            return 1e9; // fully cached, read-only: CPU-bound elsewhere
        }
        self.fault_depth as f64 / (ns_per_op / 1e9)
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.faults;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ds::HashMapDs;
    use crate::rack::RackConfig;

    fn rack() -> Rack {
        Rack::new(RackConfig {
            nodes: 2,
            node_capacity: 64 << 20,
            granularity: 1 << 20,
            ..Default::default()
        })
    }

    #[test]
    fn trace_collects_pages_and_matches_functional_result() {
        let mut r = rack();
        let mut m = HashMapDs::build(&mut r, 8);
        for i in 0..100 {
            m.insert(&mut r, i, i * 5);
        }
        let prog = m.find_program();
        let mut sp = [0i64; SP_WORDS];
        sp[0] = 77;
        let (out, t) =
            trace_op(&mut r, &prog, m.bucket_ptr(77), sp, 0);
        assert_eq!(out[1], 77 * 5);
        assert!(t.iters >= 1);
        assert_eq!(t.pages.len(), t.iters as usize);
    }

    #[test]
    fn small_cache_thrashes_large_cache_hits() {
        let mut r = rack();
        let mut m = HashMapDs::build(&mut r, 8);
        for i in 0..2000 {
            m.insert(&mut r, i, i);
        }
        let prog = m.find_program();
        let run = |cache_bytes: u64, r: &mut Rack| {
            let mut sim = CachedSwapSim::new(cache_bytes);
            for round in 0..3 {
                for k in 0..500 {
                    let mut sp = [0i64; SP_WORDS];
                    sp[0] = k;
                    let (_, t) =
                        trace_op(r, &prog, m.bucket_ptr(k), sp, 0);
                    let _ = sim.op_latency_ns(&t, 0.0);
                    let _ = round;
                }
            }
            sim.hit_rate()
        };
        let big = run(64 << 20, &mut r);
        let small = run(16 << 10, &mut r);
        assert!(big > 0.9, "big cache hit rate {big}");
        assert!(small < big, "small {small} vs big {big}");
    }

    #[test]
    fn fault_latency_is_microseconds() {
        let sim = CachedSwapSim::new(1 << 20);
        let f = sim.fault_ns();
        assert!(f > 5_000 && f < 50_000, "{f}");
    }

    #[test]
    fn throughput_bound_reflects_miss_rate() {
        let mut sim = CachedSwapSim::new(1 << 20);
        // synthetic: all misses over distinct pages
        for p in 0..1000u64 {
            sim.access(p + 1_000_000);
        }
        let t_allmiss = sim.tput_bound_ops_per_s(10.0, 0.0);
        let mut sim2 = CachedSwapSim::new(1 << 30);
        for _ in 0..10 {
            for p in 0..100u64 {
                sim2.access(p);
            }
        }
        let t_mosthit = sim2.tput_bound_ops_per_s(10.0, 0.0);
        assert!(t_mosthit > 5.0 * t_allmiss, "{t_mosthit} vs {t_allmiss}");
    }

    #[test]
    fn invalidation_evicts_and_charges_flush() {
        let mut sim = CachedSwapSim::new(1 << 20);
        assert!(!sim.access(42)); // fault it in
        assert!(sim.access(42)); // now cached
        let t = sim.invalidate(42);
        assert!(t > 5_000, "flush should cost microseconds, got {t}");
        assert_eq!(sim.invalidations, 1);
        assert!(!sim.access(42), "invalidated page must refault");
    }

    #[test]
    fn writes_lower_the_throughput_bound() {
        let mut sim = CachedSwapSim::new(1 << 20);
        for p in 0..1000u64 {
            sim.access(p);
        }
        let read_only = sim.tput_bound_ops_per_s(3.0, 0.0);
        let write_heavy = sim.tput_bound_ops_per_s(3.0, 2.0);
        assert!(
            write_heavy < read_only,
            "{write_heavy} !< {read_only}"
        );
    }

    #[test]
    fn mutating_trace_applies_stores_and_records_dirty_pages() {
        let mut r = rack();
        let mut m = HashMapDs::build(&mut r, 8);
        for i in 0..50 {
            m.insert(&mut r, i, 1);
        }
        let upd = crate::ds::hashmap::chain_update_iter();
        let mut sp = [0i64; SP_WORDS];
        sp[0] = 7; // key
        sp[1] = 999; // new value
        let (out, t) = trace_op(&mut r, &upd, m.bucket_ptr(7), sp, 0);
        assert_ne!(out[2], i64::MAX, "key 7 must be found");
        assert_eq!(t.writes.len(), t.iters as usize);
        assert_eq!(m.host_get(&mut r, 7), Some(999), "store not applied");
    }
}
