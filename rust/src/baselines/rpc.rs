//! RPC-family baselines (paper §6): offload the whole traversal to a
//! processor at the memory node.
//!
//! * `Rpc` — Xeon-class cores + eRPC-like DPDK UDP stack [84]: one round
//!   trip per request; the server walks pointers at DRAM latency.
//! * `RpcArm` — BlueField-2 Cortex-A72s: same structure, `arm_slowdown`×
//!   slower per-iteration processing, fewer cores; can bottleneck below
//!   memory bandwidth (paper §2.2) and burn more energy per op.
//! * `CacheRpc` — AIFM [127]-like: object cache at the CPU node in front
//!   of an RPC backend over a TCP-based stack (higher per-request
//!   overhead — the paper measures it slightly *worse* than plain RPC
//!   when locality is poor).
//!
//! Multi-node: RPC servers cannot continue a traversal on a peer node —
//! a crossing returns to the CPU node, which re-issues to the owner
//! (the PULSE-ACC pattern, but paying the full host stack both ways).

use super::WorkloadStats;
use crate::sim::LatencyModel;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RpcKind {
    Rpc,
    RpcArm,
    CacheRpc,
}

impl RpcKind {
    pub fn name(&self) -> &'static str {
        match self {
            RpcKind::Rpc => "RPC",
            RpcKind::RpcArm => "RPC-ARM",
            RpcKind::CacheRpc => "Cache+RPC",
        }
    }
}

/// Output metrics of a baseline run (one system × app × node count).
#[derive(Debug, Clone, Copy)]
pub struct SystemMetrics {
    pub avg_latency_ns: f64,
    pub tput_ops_per_s: f64,
    /// fraction of latency due to cross-node continuation
    pub cross_frac: f64,
}

#[derive(Debug, Clone)]
pub struct RpcModel {
    pub kind: RpcKind,
    pub lat: LatencyModel,
    /// server cores per memory node available for RPC service
    pub cores: usize,
    /// object-cache hit rate (CacheRpc only; measured by the caller
    /// with `dispatch::ObjectCache` over the workload)
    pub cache_hit_rate: f64,
    /// extra per-request overhead of the TCP-based stack (CacheRpc)
    pub tcp_extra_ns: f64,
}

impl RpcModel {
    pub fn new(kind: RpcKind) -> Self {
        Self {
            kind,
            lat: LatencyModel::default(),
            cores: match kind {
                RpcKind::RpcArm => 8, // BlueField-2: 8×A72
                _ => 18,              // Xeon 6240
            },
            cache_hit_rate: 0.0,
            tcp_extra_ns: 12_000.0,
        }
    }

    fn per_iter_cpu_ns(&self, words_per_iter: f64) -> f64 {
        // pointer chase: one cache-missing DRAM access + touch of the
        // node's words + ~20 instructions of loop logic
        let base = self.lat.cpu_dram_ns
            + words_per_iter / 8.0 * self.lat.cpu_dram_ns * 0.25
            + 20.0 * self.lat.cpu_instr_ns;
        match self.kind {
            RpcKind::RpcArm => base * self.lat.arm_slowdown,
            _ => base,
        }
    }

    /// Closed-loop single-request latency.
    pub fn latency_ns(&self, w: &WorkloadStats) -> f64 {
        let service =
            w.avg_iters * self.per_iter_cpu_ns(w.words_per_iter);
        let rtt = self.lat.one_way_ns(w.req_bytes as usize) as f64
            + self.lat.one_way_ns(w.resp_bytes as usize) as f64;
        // each crossing returns to the CPU node and re-issues
        let crossing_cost = w.avg_crossings
            * (2.0 * self.lat.one_way_ns(w.req_bytes as usize) as f64
                + 2.0 * self.lat.host_net_stack_ns);
        let tcp = if self.kind == RpcKind::CacheRpc {
            self.tcp_extra_ns
        } else {
            0.0
        };
        let miss_part = service + rtt + crossing_cost + tcp;
        let hit_part = w.avg_iters * self.lat.cpu_dram_ns;
        self.cache_hit_rate * hit_part
            + (1.0 - self.cache_hit_rate) * miss_part
            + w.cpu_post_ns
    }

    /// Saturation throughput across `nodes` memory nodes, ops/s.
    pub fn tput_ops_per_s(&self, w: &WorkloadStats, nodes: usize) -> f64 {
        let miss = 1.0 - self.cache_hit_rate;
        if miss < 1e-9 {
            return 1e9;
        }
        // memory-bandwidth bound per node (25 GB/s cap, §6 setup);
        // bulk payloads (e.g. the 8 KB object) also stream from DRAM
        let bytes_per_op =
            w.avg_iters * w.words_per_iter * 8.0 + w.resp_bytes;
        let mem_bound = if bytes_per_op > 0.0 {
            25.0e9 / bytes_per_op
        } else {
            f64::INFINITY
        };
        // CPU bound per node
        let svc = w.avg_iters * self.per_iter_cpu_ns(w.words_per_iter);
        let cpu_bound = if svc > 0.0 {
            self.cores as f64 / (svc / 1e9)
        } else {
            f64::INFINITY
        };
        // network bound (shared 100 Gbps CPU-node link)
        let net_bound = if w.resp_bytes > 0.0 {
            12.5e9 / (w.resp_bytes + w.req_bytes)
        } else {
            f64::INFINITY
        };
        let per_node = mem_bound.min(cpu_bound);
        // Backend sustains `bound` missing ops/s; cached ops ride along
        // without backend work, scaling total op rate by 1/miss.
        (per_node * nodes as f64).min(net_bound) / miss
    }

    pub fn metrics(&self, w: &WorkloadStats, nodes: usize) -> SystemMetrics {
        let lat = self.latency_ns(w);
        let cross = w.avg_crossings
            * (2.0 * self.lat.one_way_ns(w.req_bytes as usize) as f64
                + 2.0 * self.lat.host_net_stack_ns)
            * (1.0 - self.cache_hit_rate);
        SystemMetrics {
            avg_latency_ns: lat,
            tput_ops_per_s: self.tput_ops_per_s(w, nodes),
            cross_frac: (cross / lat).min(1.0),
        }
    }
}

/// Swap-cache baseline metrics (wrapper over `CachedSwapSim` results).
pub fn cache_metrics(
    avg_latency_ns: f64,
    tput_bound: f64,
    w: &WorkloadStats,
) -> SystemMetrics {
    let _ = w;
    SystemMetrics {
        avg_latency_ns,
        tput_ops_per_s: tput_bound,
        cross_frac: 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn webservice_stats() -> WorkloadStats {
        WorkloadStats {
            avg_iters: 3.0,
            words_per_iter: 3.0,
            req_bytes: 350.0,
            resp_bytes: 8192.0 + 300.0,
            avg_crossings: 0.0,
            cpu_post_ns: 40_000.0,
            ops: 1000,
        }
    }

    fn btrdb_stats() -> WorkloadStats {
        WorkloadStats {
            avg_iters: 120.0,
            words_per_iter: 18.0,
            req_bytes: 400.0,
            resp_bytes: 300.0,
            avg_crossings: 0.4,
            cpu_post_ns: 200.0,
            ops: 1000,
        }
    }

    #[test]
    fn rpc_latency_is_one_rtt_plus_service() {
        let m = RpcModel::new(RpcKind::Rpc);
        let w = webservice_stats();
        let lat = m.latency_ns(&w);
        // ~2 one-ways (~5-10 us) + small service + 40 us post
        assert!(lat > 45_000.0 && lat < 80_000.0, "{lat}");
    }

    #[test]
    fn arm_is_slower_than_xeon() {
        let w = btrdb_stats();
        let rpc = RpcModel::new(RpcKind::Rpc).latency_ns(&w);
        let arm = RpcModel::new(RpcKind::RpcArm).latency_ns(&w);
        assert!(arm > rpc * 1.5, "rpc {rpc} arm {arm}");
    }

    #[test]
    fn cache_rpc_pays_tcp_overhead() {
        let w = webservice_stats();
        let rpc = RpcModel::new(RpcKind::Rpc).latency_ns(&w);
        let crpc = RpcModel::new(RpcKind::CacheRpc).latency_ns(&w);
        assert!(crpc > rpc, "cache+rpc {crpc} vs rpc {rpc}");
    }

    #[test]
    fn throughput_scales_with_nodes_until_net_bound() {
        let m = RpcModel::new(RpcKind::Rpc);
        let w = btrdb_stats();
        let t1 = m.tput_ops_per_s(&w, 1);
        let t4 = m.tput_ops_per_s(&w, 4);
        assert!(t4 > 2.0 * t1, "t1 {t1} t4 {t4}");
        // WebService: 8 KB responses net-bind the CPU link
        let ws = webservice_stats();
        let t1 = m.tput_ops_per_s(&ws, 1);
        let t4 = m.tput_ops_per_s(&ws, 4);
        assert!(t4 < 1.6 * t1, "net bound violated: {t1} -> {t4}");
    }

    #[test]
    fn arm_cpu_bound_below_memory_bandwidth() {
        let w = btrdb_stats();
        let xeon = RpcModel::new(RpcKind::Rpc).tput_ops_per_s(&w, 1);
        let arm = RpcModel::new(RpcKind::RpcArm).tput_ops_per_s(&w, 1);
        assert!(arm < xeon, "arm {arm} xeon {xeon}");
    }

    #[test]
    fn crossings_inflate_latency() {
        let m = RpcModel::new(RpcKind::Rpc);
        let mut w = btrdb_stats();
        let l0 = m.latency_ns(&w);
        w.avg_crossings = 3.0;
        let l3 = m.latency_ns(&w);
        assert!(l3 > l0 + 20_000.0, "{l0} -> {l3}");
    }
}
