//! Random-but-verified generators shared by the test tree:
//!
//! * ISA-level: random verified programs + workspaces
//!   (`rust/tests/proptest_isa.rs`, `integration_runtime.rs`), mirroring
//!   the hypothesis strategy in `python/tests/test_hypothesis.py`;
//! * structure-level: the seeded structure-op fuzzer
//!   (`random_structure_ops`) and the [`StructureKind`] scenario
//!   registry covering **all 16 traversal scenarios** — built-host,
//!   queried-offloaded plans shared by the cross-backend differential
//!   conformance suite (`rust/tests/conformance.rs`) and the
//!   data-structure property tests (`rust/tests/proptest_ds.rs`).
//!
//! Anything this module generates passes the verifier, and its trap
//! behaviour (div-zero, dynamic OOB) is defined identically across the
//! native interpreter, the Pallas kernel, and the oracle.

use std::collections::BTreeMap;

use crate::ds::{
    AdjGraph, BPlusTree, Bimap, BstKind, BstMap, ForwardList, GoogleBtree,
    HashMapDs, HashSetDs, LinkedList, RadixTrie, SkipList, SP_KEY,
};
use crate::interp::Workspace;
use crate::isa::{verify, Asm, Instr, Op, Program, DATA_WORDS, NREG, SP_WORDS};
use crate::rack::{Op as AppOp, Rack};
use crate::util::prng::Rng;

/// Generate a random program of at most `max_len` instructions that
/// passes the verifier. May trap at runtime (dynamic OOB / div zero) —
/// deliberately, to exercise trap parity.
pub fn random_verified_program(rng: &mut Rng, max_len: usize) -> Program {
    let n = rng.range_u64(1, max_len as u64 + 1) as usize;
    let mut instrs = Vec::with_capacity(n);
    for pc in 0..n.saturating_sub(1) {
        let reg = |rng: &mut Rng| rng.below(NREG as u64) as u8;
        let instr = match rng.below(6) {
            0 | 1 => {
                // ALU
                let op = *rng.choose(&[
                    Op::Add,
                    Op::Sub,
                    Op::Mul,
                    Op::Div,
                    Op::And,
                    Op::Or,
                    Op::Xor,
                    Op::Mov,
                    Op::Not,
                    Op::Shl,
                    Op::Shr,
                    Op::Addi,
                ]);
                let imm = match op {
                    Op::Shl | Op::Shr => rng.below(64) as i64,
                    _ => rng.range_u64(0, 2001) as i64 - 1000,
                };
                Instr::new(op, reg(rng), reg(rng), reg(rng), imm)
            }
            2 => Instr::new(Op::Movi, reg(rng), 0, 0, rng.next_i64()),
            3 => {
                // memory / scratchpad
                let op = *rng.choose(&[
                    Op::Ldd,
                    Op::Std,
                    Op::Spl,
                    Op::Sps,
                    Op::Ldx,
                    Op::Stx,
                    Op::Splx,
                    Op::Spsx,
                ]);
                let window = if op.touches_data() {
                    DATA_WORDS as i64
                } else {
                    SP_WORDS as i64
                };
                let imm = match op {
                    Op::Ldd | Op::Std | Op::Spl | Op::Sps => {
                        rng.below(window as u64) as i64
                    }
                    // dynamic forms: allow a small OOB margin to exercise
                    // trap parity across engines
                    _ => rng.range_u64(0, window as u64 + 4) as i64 - 2,
                };
                Instr::new(op, reg(rng), reg(rng), 0, imm)
            }
            4 => {
                // forward jump
                let op = *rng.choose(&[
                    Op::Jeq,
                    Op::Jne,
                    Op::Jlt,
                    Op::Jle,
                    Op::Jgt,
                    Op::Jge,
                    Op::Jmp,
                ]);
                let target = rng.range_u64(pc as u64 + 1, n as u64 + 1);
                Instr::new(op, reg(rng), reg(rng), 0, target as i64)
            }
            _ => {
                // occasional early terminal
                if rng.chance(0.3) {
                    Instr::new(
                        *rng.choose(&[Op::Next, Op::Ret]),
                        0,
                        0,
                        0,
                        0,
                    )
                } else {
                    Instr::new(Op::Nop, 0, 0, 0, 0)
                }
            }
        };
        instrs.push(instr);
    }
    instrs.push(Instr::new(
        *rng.choose(&[Op::Next, Op::Ret, Op::Trap]),
        0,
        0,
        0,
        0,
    ));
    let load_words = rng.range_u64(1, DATA_WORDS as u64 + 1) as u8;
    let p = Program::new(instrs, load_words);
    verify(&p).expect("generator produced an unverifiable program");
    p
}

/// Generate a random program the abstract interpreter
/// (`isa::analyze`) can *prove* trap-free: every potentially-trapping
/// construct is emitted as an atomic movi-then-use unit (constant
/// nonzero divisor, constant in-bounds dynamic index) and jumps land
/// only on unit boundaries, so the constant facts are re-established
/// after every control-flow join. The differential-soundness property
/// test (`rust/tests/proptest_ds.rs`) feeds these to the engines:
/// `trap_free` must never be contradicted at runtime.
pub fn random_provable_program(rng: &mut Rng, max_units: usize) -> Program {
    let reg = |rng: &mut Rng| rng.below(NREG as u64) as u8;
    let n_units = rng.range_u64(1, max_units as u64 + 1) as usize;
    // (instructions, forward-jump target as a *unit* index for the
    // unit's last instruction) — flattened and patched below
    let mut units: Vec<(Vec<Instr>, Option<usize>)> = Vec::new();
    for u in 0..n_units {
        let unit = match rng.below(6) {
            0 | 1 => {
                // ALU: wrapping semantics, never traps
                let op = *rng.choose(&[
                    Op::Add,
                    Op::Sub,
                    Op::Mul,
                    Op::And,
                    Op::Or,
                    Op::Xor,
                    Op::Mov,
                    Op::Not,
                    Op::Shl,
                    Op::Shr,
                    Op::Addi,
                ]);
                let imm = match op {
                    Op::Shl | Op::Shr => rng.below(64) as i64,
                    _ => rng.range_u64(0, 2001) as i64 - 1000,
                };
                (
                    vec![Instr::new(op, reg(rng), reg(rng), reg(rng), imm)],
                    None,
                )
            }
            2 => (
                vec![Instr::new(Op::Movi, reg(rng), 0, 0, rng.next_i64())],
                None,
            ),
            3 => {
                // provably safe division: constant nonzero divisor
                let d = reg(rng);
                let mag = rng.range_u64(1, 1000) as i64;
                let k = if rng.chance(0.5) { mag } else { -mag };
                (
                    vec![
                        Instr::new(Op::Movi, d, 0, 0, k),
                        Instr::new(Op::Div, reg(rng), reg(rng), d, 0),
                    ],
                    None,
                )
            }
            4 => {
                // provably in-bounds dynamic access: constant base
                let op =
                    *rng.choose(&[Op::Ldx, Op::Stx, Op::Splx, Op::Spsx]);
                let window = if op.touches_data() {
                    DATA_WORDS as u64
                } else {
                    SP_WORDS as u64
                };
                let b = reg(rng);
                let base = rng.below(window);
                let imm = rng.below(window - base) as i64;
                (
                    vec![
                        Instr::new(Op::Movi, b, 0, 0, base as i64),
                        Instr::new(op, reg(rng), b, 0, imm),
                    ],
                    None,
                )
            }
            _ => {
                // forward jump to a later unit boundary (incl. the
                // terminal unit) — never to pc == n, the trap edge
                let op = *rng.choose(&[
                    Op::Jeq,
                    Op::Jne,
                    Op::Jlt,
                    Op::Jle,
                    Op::Jgt,
                    Op::Jge,
                    Op::Jmp,
                ]);
                let tgt = rng.range_u64(u as u64 + 1, n_units as u64 + 1)
                    as usize;
                (
                    vec![Instr::new(op, reg(rng), reg(rng), 0, 0)],
                    Some(tgt),
                )
            }
        };
        units.push(unit);
    }
    // terminal unit: Ret/Next only — an explicit Trap would (rightly)
    // spoil the trap-free proof
    units.push((
        vec![Instr::new(*rng.choose(&[Op::Next, Op::Ret]), 0, 0, 0, 0)],
        None,
    ));
    let starts: Vec<usize> = units
        .iter()
        .scan(0usize, |acc, (is, _)| {
            let s = *acc;
            *acc += is.len();
            Some(s)
        })
        .collect();
    let mut instrs = Vec::new();
    for (is, tgt) in &units {
        for (j, ins) in is.iter().enumerate() {
            let mut ins = *ins;
            if j == is.len() - 1 {
                if let Some(t) = tgt {
                    ins.imm = starts[*t] as i64;
                }
            }
            instrs.push(ins);
        }
    }
    let p = Program::new(instrs, DATA_WORDS as u8);
    verify(&p).expect("provable generator made an unverifiable program");
    p
}

/// Random workspace with full-range register/window contents.
pub fn random_workspace(rng: &mut Rng) -> Workspace {
    let mut w = Workspace::new();
    for r in w.regs.iter_mut() {
        *r = rng.next_i64() >> rng.below(3); // mix of magnitudes
    }
    for s in w.sp.iter_mut() {
        *s = rng.next_i64();
    }
    for d in w.data.iter_mut() {
        *d = rng.next_i64();
    }
    w
}

/// A small well-formed traversal program (list find) used by many tests.
pub fn list_find_program() -> Program {
    let mut a = Asm::new();
    let miss = a.label();
    let walk = a.label();
    a.spl(1, 0); // key
    a.ldd(2, 0); // node.key
    a.jne(1, 2, miss);
    a.ldd(3, 1); // node.value
    a.sps(3, 1); // sp[1] = value
    a.ret();
    a.bind(miss);
    a.ldd(3, 2); // next
    a.movi(4, 0);
    a.jne(3, 4, walk);
    a.movi(5, i64::MAX);
    a.sps(5, 2); // sp[2] = NOT_FOUND
    a.ret();
    a.bind(walk);
    a.mov(0, 3);
    a.next();
    a.finish(3).unwrap()
}

// ---------------------------------------------------------------------
// Structure-op fuzzer + scenario registry
// ---------------------------------------------------------------------

/// Right-domain offset for bimap pairs (left key k maps to
/// `BIMAP_RIGHT_BASE + k`), so a probe's domain identifies the index.
pub const BIMAP_RIGHT_BASE: i64 = 1 << 40;

/// Every traversal scenario the repo serves — the paper's 13 structures
/// (4 BST balancing disciplines share one traversal; scans count
/// separately because they exercise a different program + continuation
/// protocol), the B+Tree family, and the three expansion scenarios.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StructureKind {
    ForwardList,
    LinkedList,
    HashMap,
    HashSet,
    Bimap,
    BstPlain,
    BstAvl,
    BstSplay,
    BstScapegoat,
    GoogleBtree,
    BPlusTreeGet,
    BPlusTreeScan,
    SkipListFind,
    SkipListScan,
    RadixTrie,
    GraphKhop,
}

impl StructureKind {
    pub const ALL: [StructureKind; 16] = [
        StructureKind::ForwardList,
        StructureKind::LinkedList,
        StructureKind::HashMap,
        StructureKind::HashSet,
        StructureKind::Bimap,
        StructureKind::BstPlain,
        StructureKind::BstAvl,
        StructureKind::BstSplay,
        StructureKind::BstScapegoat,
        StructureKind::GoogleBtree,
        StructureKind::BPlusTreeGet,
        StructureKind::BPlusTreeScan,
        StructureKind::SkipListFind,
        StructureKind::SkipListScan,
        StructureKind::RadixTrie,
        StructureKind::GraphKhop,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            StructureKind::ForwardList => "forward_list",
            StructureKind::LinkedList => "list",
            StructureKind::HashMap => "hashmap",
            StructureKind::HashSet => "hashset",
            StructureKind::Bimap => "bimap",
            StructureKind::BstPlain => "bst-plain",
            StructureKind::BstAvl => "bst-avl",
            StructureKind::BstSplay => "bst-splay",
            StructureKind::BstScapegoat => "bst-scapegoat",
            StructureKind::GoogleBtree => "google-btree",
            StructureKind::BPlusTreeGet => "bplustree-get",
            StructureKind::BPlusTreeScan => "bplustree-scan",
            StructureKind::SkipListFind => "skiplist-find",
            StructureKind::SkipListScan => "skiplist-scan",
            StructureKind::RadixTrie => "radix-trie",
            StructureKind::GraphKhop => "graph-khop",
        }
    }

    fn is_scan(&self) -> bool {
        matches!(
            self,
            StructureKind::BPlusTreeScan | StructureKind::SkipListScan
        )
    }
}

/// One host-side mutation of a build script (applied sequentially to
/// every backend's rack, so all layouts are identical).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BuildStep {
    Insert(i64, i64),
    Remove(i64),
}

/// One streamed query. Queries are read-only by construction: mutations
/// live in the build script, so concurrent backends (the live engine at
/// any shard count) produce scheduling-independent, bit-identical
/// scratchpads.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Query {
    Lookup(i64),
    /// Scan(lo, record_count) — YCSB-E style.
    Scan(i64, usize),
    /// Khop(start_vertex, hops, per-hop draws).
    Khop(u64, u32, Vec<i64>),
}

/// A seeded, rack-independent scenario: build script + query stream.
#[derive(Debug, Clone)]
pub struct ScenarioPlan {
    pub kind: StructureKind,
    pub seed: u64,
    pub build: Vec<BuildStep>,
    pub queries: Vec<Query>,
}

impl ScenarioPlan {
    /// The reference key/value model after applying the build script
    /// (later inserts win, removes delete — matching every structure's
    /// host-path semantics).
    pub fn model(&self) -> BTreeMap<i64, i64> {
        let mut m = BTreeMap::new();
        for step in &self.build {
            match *step {
                BuildStep::Insert(k, v) => {
                    m.insert(k, v);
                }
                BuildStep::Remove(k) => {
                    m.remove(&k);
                }
            }
        }
        m
    }
}

/// Generate a seeded build/insert/delete/lookup/scan (or k-hop) plan
/// for one structure. Same (kind, seed, sizes) => same plan, anywhere.
pub fn random_structure_ops(
    kind: StructureKind,
    seed: u64,
    build_n: usize,
    query_n: usize,
) -> ScenarioPlan {
    let mut rng = Rng::with_stream(seed, 0xD5_0000 + kind as u64);
    let build_n = build_n.max(8);
    // key space sized to get both collisions and misses; the trie uses
    // a dense 16-bit space so byte paths share prefixes
    let space: i64 = match kind {
        StructureKind::RadixTrie => 1 << 16,
        _ => (build_n as i64 * 3).max(64),
    };
    let mut build = Vec::with_capacity(build_n);
    match kind {
        StructureKind::GraphKhop => {
            // one step per vertex: the script carries the graph size,
            // the topology itself is seeded inside `AdjGraph::build`
            for i in 0..build_n {
                build.push(BuildStep::Insert(i as i64, 0));
            }
        }
        StructureKind::SkipListFind | StructureKind::SkipListScan => {
            let mut live = 0usize;
            for _ in 0..build_n {
                if live > 8 && rng.chance(0.2) {
                    build.push(BuildStep::Remove(rng.below(space as u64) as i64));
                    live = live.saturating_sub(1);
                } else {
                    build.push(BuildStep::Insert(
                        rng.below(space as u64) as i64,
                        rng.next_i64() >> 8,
                    ));
                    live += 1;
                }
            }
        }
        StructureKind::Bimap => {
            for _ in 0..build_n {
                let k = rng.below(space as u64) as i64;
                build.push(BuildStep::Insert(k, BIMAP_RIGHT_BASE + k));
            }
        }
        StructureKind::BstPlain
        | StructureKind::BstAvl
        | StructureKind::BstSplay
        | StructureKind::BstScapegoat => {
            // unique keys: the BST insert path has no overwrite, so a
            // duplicate would make tree and model disagree on the value
            let mut used = std::collections::HashSet::new();
            for _ in 0..build_n {
                let k = rng.below(space as u64) as i64;
                if used.insert(k) {
                    build.push(BuildStep::Insert(k, rng.next_i64() >> 8));
                }
            }
        }
        _ => {
            for _ in 0..build_n {
                build.push(BuildStep::Insert(
                    rng.below(space as u64) as i64,
                    rng.next_i64() >> 8,
                ));
            }
        }
    }
    let mut queries = Vec::with_capacity(query_n);
    for _ in 0..query_n {
        let q = match kind {
            StructureKind::GraphKhop => {
                let hops = 1 + rng.below(12) as u32;
                let draws = (0..hops)
                    .map(|_| (rng.next_u64() >> 1) as i64)
                    .collect();
                Query::Khop(rng.below(build_n as u64), hops, draws)
            }
            k if k.is_scan() => Query::Scan(
                rng.below(space as u64 + space as u64 / 4) as i64,
                1 + rng.below(60) as usize,
            ),
            StructureKind::Bimap => {
                // half the probes target the reverse index
                let k = rng.below(space as u64 + 32) as i64;
                if rng.chance(0.5) {
                    Query::Lookup(k)
                } else {
                    Query::Lookup(BIMAP_RIGHT_BASE + k)
                }
            }
            _ => Query::Lookup(
                rng.below(space as u64 + space as u64 / 4) as i64,
            ),
        };
        queries.push(q);
    }
    ScenarioPlan { kind, seed, build, queries }
}

/// A scenario materialized on one rack.
pub enum BuiltScenario {
    FList(ForwardList),
    LList(LinkedList),
    Hash(HashMapDs),
    HSet(HashSetDs),
    Bi(Bimap),
    Bst(BstMap),
    Btree(GoogleBtree),
    Bplus(BPlusTree),
    Skip(SkipList),
    Trie(RadixTrie),
    Graph(AdjGraph),
}

impl BuiltScenario {
    /// Apply the plan's build script to `rack`. Deterministic: the same
    /// plan on two identically configured racks produces identical VA
    /// layouts (the conformance suite's precondition).
    pub fn build(plan: &ScenarioPlan, rack: &mut Rack) -> BuiltScenario {
        let inserts = || {
            plan.build.iter().filter_map(|s| match *s {
                BuildStep::Insert(k, v) => Some((k, v)),
                BuildStep::Remove(_) => None,
            })
        };
        match plan.kind {
            StructureKind::ForwardList => {
                let mut l = ForwardList::new();
                for (k, _v) in inserts() {
                    l.push(rack, k);
                }
                BuiltScenario::FList(l)
            }
            StructureKind::LinkedList => {
                let mut l = LinkedList::new();
                for (k, _v) in inserts() {
                    l.push_back(rack, k);
                }
                BuiltScenario::LList(l)
            }
            StructureKind::HashMap => {
                let mut m = HashMapDs::build(rack, 64);
                for (k, v) in inserts() {
                    m.insert(rack, k, v);
                }
                BuiltScenario::Hash(m)
            }
            StructureKind::HashSet => {
                let mut s = HashSetDs::build(rack, 64);
                for (k, _v) in inserts() {
                    s.insert(rack, k);
                }
                BuiltScenario::HSet(s)
            }
            StructureKind::Bimap => {
                let mut b = Bimap::build(rack, 64);
                // dedup left keys: bimap pairs must be 1:1
                let mut seen = std::collections::HashSet::new();
                for (k, v) in inserts() {
                    if seen.insert(k) {
                        b.insert(rack, k, v);
                    }
                }
                BuiltScenario::Bi(b)
            }
            StructureKind::BstPlain
            | StructureKind::BstAvl
            | StructureKind::BstSplay
            | StructureKind::BstScapegoat => {
                let kind = match plan.kind {
                    StructureKind::BstPlain => BstKind::Plain,
                    StructureKind::BstAvl => BstKind::Avl,
                    StructureKind::BstSplay => BstKind::Splay,
                    _ => BstKind::Scapegoat,
                };
                let mut t = BstMap::new(kind);
                let mut seen = std::collections::HashSet::new();
                for (k, v) in inserts() {
                    if seen.insert(k) {
                        t.insert(rack, k, v);
                    }
                }
                BuiltScenario::Bst(t)
            }
            StructureKind::GoogleBtree => {
                let pairs: Vec<(i64, i64)> =
                    plan.model().into_iter().collect();
                BuiltScenario::Btree(GoogleBtree::build_sorted(rack, &pairs))
            }
            StructureKind::BPlusTreeGet | StructureKind::BPlusTreeScan => {
                let pairs: Vec<(i64, i64)> =
                    plan.model().into_iter().collect();
                BuiltScenario::Bplus(BPlusTree::build_sorted(rack, &pairs, 7))
            }
            StructureKind::SkipListFind | StructureKind::SkipListScan => {
                let mut s = SkipList::new(rack, plan.seed);
                for step in &plan.build {
                    match *step {
                        BuildStep::Insert(k, v) => s.insert(rack, k, v),
                        BuildStep::Remove(k) => {
                            s.remove(rack, k);
                        }
                    }
                }
                BuiltScenario::Skip(s)
            }
            StructureKind::RadixTrie => {
                let mut t = RadixTrie::new(rack);
                for (k, v) in inserts() {
                    t.insert(rack, k, v);
                }
                BuiltScenario::Trie(t)
            }
            StructureKind::GraphKhop => {
                let n = plan.build.len().max(8);
                BuiltScenario::Graph(AdjGraph::build(rack, n, 6, plan.seed))
            }
        }
    }

    /// Build the streamed op for one query.
    pub fn make_op(&self, q: &Query) -> AppOp {
        fn lookup_sp(key: i64) -> [i64; SP_WORDS] {
            let mut sp = [0i64; SP_WORDS];
            sp[SP_KEY as usize] = key;
            sp
        }
        match (self, q) {
            (BuiltScenario::FList(l), Query::Lookup(k)) => {
                AppOp::new(l.find_program(), l.head, lookup_sp(*k))
            }
            (BuiltScenario::LList(l), Query::Lookup(k)) => {
                AppOp::new(l.find_program(), l.head, lookup_sp(*k))
            }
            (BuiltScenario::Hash(m), Query::Lookup(k)) => {
                AppOp::new(m.find_program(), m.bucket_ptr(*k), lookup_sp(*k))
            }
            (BuiltScenario::HSet(s), Query::Lookup(k)) => {
                AppOp::new(s.find_program(), s.bucket_ptr(*k), lookup_sp(*k))
            }
            (BuiltScenario::Bi(b), Query::Lookup(k)) => {
                let idx = if *k >= BIMAP_RIGHT_BASE {
                    b.right_index()
                } else {
                    b.left_index()
                };
                AppOp::new(idx.find_program(), idx.bucket_ptr(*k), lookup_sp(*k))
            }
            (BuiltScenario::Bst(t), Query::Lookup(k)) => {
                AppOp::new(t.find_program(), t.root, lookup_sp(*k))
            }
            (BuiltScenario::Btree(t), Query::Lookup(k)) => {
                AppOp::new(t.locate_program(), t.root, lookup_sp(*k))
            }
            (BuiltScenario::Bplus(t), Query::Lookup(k)) => {
                AppOp::new(t.get_program(), t.root, lookup_sp(*k))
            }
            (BuiltScenario::Bplus(t), Query::Scan(lo, len)) => {
                // WiredTiger's locate + buffered-scan chain, one source
                t.scan_op(*lo, *len)
            }
            (BuiltScenario::Skip(s), Query::Lookup(k)) => s.find_op(*k),
            (BuiltScenario::Skip(s), Query::Scan(lo, len)) => {
                s.scan_op(*lo, *len)
            }
            (BuiltScenario::Trie(t), Query::Lookup(k)) => t.lookup_op(*k),
            (BuiltScenario::Graph(g), Query::Khop(start, hops, draws)) => {
                g.khop_op(*start as usize, *hops, draws)
            }
            _ => panic!("query/structure mismatch"),
        }
    }

    /// The full streamed op sequence of a plan.
    pub fn ops(&self, plan: &ScenarioPlan) -> Vec<AppOp> {
        plan.queries.iter().map(|q| self.make_op(q)).collect()
    }

    /// Property check: every query's offloaded answer (through the
    /// structure API on `rack`) must match the host-side reference —
    /// the plan model for point lookups, host walks for scans and
    /// k-hops. Returns `Err` with context for `run_prop` bodies.
    pub fn check_against_reference(
        &self,
        rack: &mut Rack,
        plan: &ScenarioPlan,
    ) -> Result<(), String> {
        let model = plan.model();
        let scan_model = |lo: i64, len: usize| -> Vec<i64> {
            model.range(lo..).take(len).map(|(_, &v)| v).collect()
        };
        for (i, q) in plan.queries.iter().enumerate() {
            let ctx = |msg: String| {
                Err(format!("{} query {i} ({q:?}): {msg}", plan.kind.name()))
            };
            match (self, q) {
                (BuiltScenario::FList(l), Query::Lookup(k)) => {
                    let got = l.find(rack, *k);
                    let want = l.host_find(rack, *k);
                    if got != want {
                        return ctx(format!("{got:?} != host {want:?}"));
                    }
                }
                (BuiltScenario::LList(l), Query::Lookup(k)) => {
                    let got = l.find(rack, *k).is_some();
                    let want = plan.build.iter().any(|s| {
                        matches!(s, BuildStep::Insert(key, _) if key == k)
                    });
                    if got != want {
                        return ctx(format!("membership {got} != {want}"));
                    }
                }
                (BuiltScenario::Hash(m), Query::Lookup(k)) => {
                    let got = m.get(rack, *k);
                    let want = model.get(k).copied();
                    if got != want {
                        return ctx(format!("{got:?} != {want:?}"));
                    }
                }
                (BuiltScenario::HSet(s), Query::Lookup(k)) => {
                    let got = s.contains(rack, *k);
                    let want = model.contains_key(k);
                    if got != want {
                        return ctx(format!("membership {got} != {want}"));
                    }
                }
                (BuiltScenario::Bi(b), Query::Lookup(k)) => {
                    let (got, want) = if *k >= BIMAP_RIGHT_BASE {
                        (
                            b.get_by_right(rack, *k),
                            model
                                .iter()
                                .find(|&(_, &v)| v == *k)
                                .map(|(&l, _)| l),
                        )
                    } else {
                        (b.get_by_left(rack, *k), model.get(k).copied())
                    };
                    if got != want {
                        return ctx(format!("{got:?} != {want:?}"));
                    }
                }
                (BuiltScenario::Bst(t), Query::Lookup(k)) => {
                    let got = t.get(rack, *k);
                    let want = model.get(k).copied();
                    if got != want {
                        return ctx(format!("{got:?} != {want:?}"));
                    }
                }
                (BuiltScenario::Btree(t), Query::Lookup(k)) => {
                    let got = t.get(rack, *k);
                    let want = model.get(k).copied();
                    if got != want {
                        return ctx(format!("{got:?} != {want:?}"));
                    }
                }
                (BuiltScenario::Bplus(t), Query::Lookup(k)) => {
                    let got = t.get(rack, *k);
                    let want = model.get(k).copied();
                    if got != want {
                        return ctx(format!("{got:?} != {want:?}"));
                    }
                }
                (BuiltScenario::Bplus(t), Query::Scan(lo, len)) => {
                    let got = t.scan(rack, *lo, *len);
                    let want = scan_model(*lo, *len);
                    if got != want {
                        return ctx(format!("{got:?} != {want:?}"));
                    }
                }
                (BuiltScenario::Skip(s), Query::Lookup(k)) => {
                    let got = s.find(rack, *k);
                    let want = model.get(k).copied();
                    if got != want {
                        return ctx(format!("{got:?} != {want:?}"));
                    }
                }
                (BuiltScenario::Skip(s), Query::Scan(lo, len)) => {
                    let got = s.scan(rack, *lo, *len);
                    let want = scan_model(*lo, *len);
                    if got != want {
                        return ctx(format!("{got:?} != {want:?}"));
                    }
                }
                (BuiltScenario::Trie(t), Query::Lookup(k)) => {
                    let got = t.get(rack, *k);
                    let want = model.get(k).copied();
                    if got != want {
                        return ctx(format!("{got:?} != {want:?}"));
                    }
                }
                (BuiltScenario::Graph(g), Query::Khop(start, hops, draws)) => {
                    let got = g.khop(rack, *start as usize, *hops, draws);
                    let want =
                        g.host_khop(rack, *start as usize, *hops, draws);
                    if got != want {
                        return ctx(format!("{got:?} != {want:?}"));
                    }
                }
                _ => return ctx("query/structure mismatch".into()),
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Mutating-stream mode (offloaded write path)
// ---------------------------------------------------------------------

/// One query of a mixed read-write stream. Restricted so that the
/// *final structure state* is schedule-independent under concurrent
/// execution (live shards, DES event order):
///
/// * `Update` targets are **single-writer-per-key** — the generator
///   never emits two updates to the same key, so the last-value race
///   cannot arise and every interleaving converges to the same heap;
/// * `PushFront` pushes commute as a *set* (each push links its own
///   pre-allocated node; the sentinel iteration is the linearization
///   point), so the final chain is order-dependent but
///   content-deterministic — the conformance suite compares exact
///   chains for serialized runs and multisets for concurrent ones.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MutQuery {
    Lookup(i64),
    /// In-place value overwrite of an existing key (hashmap put /
    /// B+Tree leaf update). At most one per key per stream.
    Update(i64, i64),
    /// Offloaded list push of a host-pre-allocated node with this value.
    PushFront(i64),
}

/// A seeded mixed read-write scenario: build script + mutation stream.
#[derive(Debug, Clone)]
pub struct MutPlan {
    pub kind: StructureKind,
    pub seed: u64,
    pub build: Vec<BuildStep>,
    pub queries: Vec<MutQuery>,
}

impl MutPlan {
    /// Reference key/value state after the build script *and* every
    /// update in the stream (updates are single-writer-per-key, so
    /// application order cannot matter).
    pub fn final_model(&self) -> BTreeMap<i64, i64> {
        let mut m = BTreeMap::new();
        for step in &self.build {
            match *step {
                BuildStep::Insert(k, v) => {
                    m.insert(k, v);
                }
                BuildStep::Remove(k) => {
                    m.remove(&k);
                }
            }
        }
        for q in &self.queries {
            if let MutQuery::Update(k, v) = *q {
                m.insert(k, v);
            }
        }
        m
    }

    /// Values pushed by the stream, in issue order.
    pub fn pushed_values(&self) -> Vec<i64> {
        self.queries
            .iter()
            .filter_map(|q| match *q {
                MutQuery::PushFront(v) => Some(v),
                _ => None,
            })
            .collect()
    }

    fn write_count(&self) -> usize {
        self.queries
            .iter()
            .filter(|q| !matches!(q, MutQuery::Lookup(_)))
            .count()
    }
}

/// Structures with an offloaded mutation program. `HashMap` puts on
/// existing keys, `ForwardList` push_front via pre-allocated nodes,
/// `BPlusTreeGet` in-place leaf value updates.
pub const MUTATING_KINDS: [StructureKind; 3] = [
    StructureKind::HashMap,
    StructureKind::ForwardList,
    StructureKind::BPlusTreeGet,
];

/// Generate a seeded mixed read-write stream (~1/3 writes) for one of
/// the [`MUTATING_KINDS`]. Same (kind, seed, sizes) => same plan.
pub fn random_mutating_ops(
    kind: StructureKind,
    seed: u64,
    build_n: usize,
    query_n: usize,
) -> MutPlan {
    assert!(
        MUTATING_KINDS.contains(&kind),
        "{} has no offloaded mutation program",
        kind.name()
    );
    let mut rng = Rng::with_stream(seed, 0xD5_1000 + kind as u64);
    let build_n = build_n.max(8);
    let space: i64 = (build_n as i64 * 3).max(64);
    let mut build = Vec::with_capacity(build_n);
    for _ in 0..build_n {
        build.push(BuildStep::Insert(
            rng.below(space as u64) as i64,
            rng.next_i64() >> 8,
        ));
    }
    // existing keys, shuffled: update targets are drawn without
    // replacement => single writer per key by construction
    let mut keys: Vec<i64> = {
        let mut m = BTreeMap::new();
        for step in &build {
            if let BuildStep::Insert(k, v) = *step {
                m.insert(k, v);
            }
        }
        m.into_keys().collect()
    };
    for i in (1..keys.len()).rev() {
        let j = rng.below(i as u64 + 1) as usize;
        keys.swap(i, j);
    }
    let mut next_key = keys.into_iter();
    let mut queries = Vec::with_capacity(query_n);
    for qi in 0..query_n {
        // query 0 always writes so every stream exercises the path
        let write = qi == 0 || rng.chance(1.0 / 3.0);
        let q = match kind {
            StructureKind::ForwardList if write => {
                // pushed values live outside the build key space so
                // lookups distinguish old from new content
                MutQuery::PushFront(space + qi as i64)
            }
            _ if write => match next_key.next() {
                Some(k) => MutQuery::Update(k, rng.next_i64() >> 8),
                // ran out of distinct keys: degrade to a read
                None => MutQuery::Lookup(rng.below(space as u64) as i64),
            },
            _ => MutQuery::Lookup(
                rng.below(space as u64 + space as u64 / 4) as i64,
            ),
        };
        queries.push(q);
    }
    MutPlan { kind, seed, build, queries }
}

/// A mutating scenario materialized on one rack: the built structure
/// plus the pre-allocated nodes its `PushFront` queries consume (the
/// "node handed in through the scratchpad" of the offloaded list push).
/// Pre-allocation happens at build time, in query order, so every
/// backend sees a bit-identical heap before serving starts.
pub enum MutScenario {
    Hash(HashMapDs),
    List(ForwardList, Vec<crate::mem::GAddr>),
    Bplus(BPlusTree),
}

impl MutScenario {
    pub fn build(plan: &MutPlan, rack: &mut Rack) -> MutScenario {
        let inserts = || {
            plan.build.iter().filter_map(|s| match *s {
                BuildStep::Insert(k, v) => Some((k, v)),
                BuildStep::Remove(_) => None,
            })
        };
        match plan.kind {
            StructureKind::HashMap => {
                let mut m = HashMapDs::build(rack, 64);
                for (k, v) in inserts() {
                    m.insert(rack, k, v);
                }
                MutScenario::Hash(m)
            }
            StructureKind::ForwardList => {
                let mut l = ForwardList::with_sentinel(rack);
                for (k, _v) in inserts() {
                    l.push(rack, k);
                }
                let nodes = plan
                    .pushed_values()
                    .into_iter()
                    .map(|v| l.prealloc_node(rack, v))
                    .collect();
                MutScenario::List(l, nodes)
            }
            StructureKind::BPlusTreeGet => {
                let pairs: Vec<(i64, i64)> = {
                    let mut m = BTreeMap::new();
                    for (k, v) in inserts() {
                        m.insert(k, v);
                    }
                    m.into_iter().collect()
                };
                MutScenario::Bplus(BPlusTree::build_sorted(rack, &pairs, 7))
            }
            other => panic!("{} is not a mutating scenario", other.name()),
        }
    }

    /// The full streamed op sequence (push ops consume the
    /// pre-allocated nodes in query order).
    pub fn ops(&self, plan: &MutPlan) -> Vec<AppOp> {
        let mut push_idx = 0usize;
        plan.queries
            .iter()
            .map(|q| match (self, q) {
                (MutScenario::Hash(m), MutQuery::Lookup(k)) => m.find_op(*k),
                (MutScenario::Hash(m), MutQuery::Update(k, v)) => {
                    m.update_op(*k, *v)
                }
                (MutScenario::List(l, _), MutQuery::Lookup(k)) => {
                    let mut sp = [0i64; SP_WORDS];
                    sp[SP_KEY as usize] = *k;
                    AppOp::new(l.find_program(), l.head, sp)
                }
                (MutScenario::List(l, nodes), MutQuery::PushFront(_)) => {
                    let op = l.push_front_op(nodes[push_idx]);
                    push_idx += 1;
                    op
                }
                (MutScenario::Bplus(t), MutQuery::Lookup(k)) => {
                    let mut sp = [0i64; SP_WORDS];
                    sp[SP_KEY as usize] = *k;
                    AppOp::new(t.get_program(), t.root, sp)
                }
                (MutScenario::Bplus(t), MutQuery::Update(k, v)) => {
                    t.update_op(*k, *v)
                }
                _ => panic!("query/structure mismatch"),
            })
            .collect()
    }

    /// Final-structure-state check after the stream drained. `exact`
    /// demands the bit-exact serial-order outcome (always true for the
    /// single-writer structures; for the list only when serving was
    /// serialized) — otherwise the list chain is compared as a
    /// multiset, which every valid interleaving must produce.
    pub fn check_final_state(
        &self,
        rack: &mut Rack,
        plan: &MutPlan,
        exact: bool,
    ) -> Result<(), String> {
        match self {
            MutScenario::Hash(m) => {
                let got = m.host_items(rack);
                let want = plan.final_model();
                if got != want {
                    return Err(format!(
                        "hashmap final state diverged: {} entries vs {}",
                        got.len(),
                        want.len()
                    ));
                }
            }
            MutScenario::Bplus(t) => {
                let got = t.host_items(rack);
                let want: Vec<(i64, i64)> =
                    plan.final_model().into_iter().collect();
                if got != want {
                    return Err(format!(
                        "bplustree final state diverged: {} entries vs {}",
                        got.len(),
                        want.len()
                    ));
                }
            }
            MutScenario::List(l, _) => {
                let got = l.host_values(rack);
                // serial order: pushes prepend, so the chain is the
                // pushed values reversed, then the built prefix
                let mut want: Vec<i64> =
                    plan.pushed_values().into_iter().rev().collect();
                for step in &plan.build {
                    if let BuildStep::Insert(k, _) = *step {
                        want.push(k);
                    }
                }
                if exact {
                    if got != want {
                        return Err(format!(
                            "list chain diverged from serial order \
                             ({} vs {} nodes)",
                            got.len(),
                            want.len()
                        ));
                    }
                } else {
                    let mut g = got.clone();
                    let mut w = want.clone();
                    g.sort_unstable();
                    w.sort_unstable();
                    if g != w {
                        return Err(format!(
                            "list content diverged as a multiset \
                             ({} vs {} nodes)",
                            g.len(),
                            w.len()
                        ));
                    }
                }
            }
        }
        Ok(())
    }

    /// Structure invariants after the stream (panics on violation).
    pub fn check_invariants(&self, rack: &mut Rack, plan: &MutPlan) {
        match self {
            MutScenario::Hash(m) => m.check_invariants(rack),
            MutScenario::Bplus(t) => t.check_invariants(rack),
            MutScenario::List(l, _) => {
                let built = plan
                    .build
                    .iter()
                    .filter(|s| matches!(s, BuildStep::Insert(..)))
                    .count();
                l.check_invariants(
                    rack,
                    built + plan.pushed_values().len(),
                );
            }
        }
    }

    /// Number of mutating ops in the plan (bench/report accounting).
    pub fn writes(plan: &MutPlan) -> usize {
        plan.write_count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interp::logic_pass;

    #[test]
    fn generated_programs_verify_and_run() {
        let mut rng = Rng::new(1234);
        for _ in 0..200 {
            let p = random_verified_program(&mut rng, 24);
            let mut w = random_workspace(&mut rng);
            let r = logic_pass(&p, &mut w);
            // must terminate with a defined status in bounded steps
            assert!(r.steps as usize <= p.len() + 1);
            assert_ne!(r.status as i32, 0);
        }
    }

    #[test]
    fn list_find_program_verifies() {
        let p = list_find_program();
        assert!(verify(&p).is_ok());
        assert_eq!(p.load_words, 3);
    }

    #[test]
    fn structure_plans_are_deterministic() {
        for kind in StructureKind::ALL {
            let a = random_structure_ops(kind, 99, 50, 20);
            let b = random_structure_ops(kind, 99, 50, 20);
            assert_eq!(a.build, b.build, "{}", kind.name());
            assert_eq!(a.queries, b.queries, "{}", kind.name());
            assert_eq!(b.queries.len(), 20);
        }
    }

    #[test]
    fn every_scenario_builds_and_matches_its_reference() {
        use crate::rack::RackConfig;
        for kind in StructureKind::ALL {
            let plan = random_structure_ops(kind, 7, 60, 15);
            let mut rack = Rack::new(RackConfig::small(2));
            let built = BuiltScenario::build(&plan, &mut rack);
            let ops = built.ops(&plan);
            assert_eq!(ops.len(), 15, "{}", kind.name());
            built
                .check_against_reference(&mut rack, &plan)
                .unwrap_or_else(|e| panic!("{e}"));
        }
    }

    #[test]
    fn mutating_plans_are_deterministic_and_single_writer() {
        for kind in MUTATING_KINDS {
            let a = random_mutating_ops(kind, 11, 60, 40);
            let b = random_mutating_ops(kind, 11, 60, 40);
            assert_eq!(a.build, b.build, "{}", kind.name());
            assert_eq!(a.queries, b.queries, "{}", kind.name());
            assert!(MutScenario::writes(&a) > 0, "{}", kind.name());
            // single writer per key: no update key repeats
            let mut seen = std::collections::HashSet::new();
            for q in &a.queries {
                if let MutQuery::Update(k, _) = q {
                    assert!(seen.insert(*k), "double writer on key {k}");
                }
            }
        }
    }

    #[test]
    fn mutating_streams_apply_functionally_and_hold_invariants() {
        use crate::rack::RackConfig;
        for kind in MUTATING_KINDS {
            let plan = random_mutating_ops(kind, 5, 50, 30);
            let mut rack = Rack::new(RackConfig::small(2));
            let ms = MutScenario::build(&plan, &mut rack);
            for op in ms.ops(&plan) {
                rack.run_op_functional(&op);
            }
            ms.check_final_state(&mut rack, &plan, true)
                .unwrap_or_else(|e| panic!("{}: {e}", kind.name()));
            ms.check_invariants(&mut rack, &plan);
        }
    }

    #[test]
    fn mutating_streams_contain_mutating_stages() {
        use crate::rack::RackConfig;
        for kind in MUTATING_KINDS {
            let plan = random_mutating_ops(kind, 4, 40, 20);
            let mut rack = Rack::new(RackConfig::small(1));
            let ms = MutScenario::build(&plan, &mut rack);
            let dirty = ms
                .ops(&plan)
                .iter()
                .flat_map(|op| op.stages.iter())
                .any(|s| s.iter.program.writes_data);
            assert!(dirty, "{} stream never writes", kind.name());
        }
    }

    #[test]
    fn streamed_ops_are_read_only_or_repeat_bounded() {
        // conformance precondition: streamed query ops never mutate the
        // heap, so concurrent execution orders cannot diverge
        for kind in StructureKind::ALL {
            let plan = random_structure_ops(kind, 3, 40, 10);
            let mut rack =
                Rack::new(crate::rack::RackConfig::small(1));
            let built = BuiltScenario::build(&plan, &mut rack);
            for op in built.ops(&plan) {
                for stage in &op.stages {
                    assert!(
                        !stage.iter.program.writes_data,
                        "{} streams a mutating stage",
                        kind.name()
                    );
                }
            }
        }
    }
}
