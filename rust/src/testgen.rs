//! Random-but-verified program and workspace generators, shared by the
//! property tests (`rust/tests/proptest_isa.rs`) and the cross-layer
//! equivalence tests (`rust/tests/integration_runtime.rs`).
//!
//! Mirrors the hypothesis strategy in `python/tests/test_hypothesis.py`:
//! anything this module generates passes the verifier, and its trap
//! behaviour (div-zero, dynamic OOB) is defined identically across the
//! native interpreter, the Pallas kernel, and the oracle.

use crate::interp::Workspace;
use crate::isa::{verify, Asm, Instr, Op, Program, DATA_WORDS, NREG, SP_WORDS};
use crate::util::prng::Rng;

/// Generate a random program of at most `max_len` instructions that
/// passes the verifier. May trap at runtime (dynamic OOB / div zero) —
/// deliberately, to exercise trap parity.
pub fn random_verified_program(rng: &mut Rng, max_len: usize) -> Program {
    let n = rng.range_u64(1, max_len as u64 + 1) as usize;
    let mut instrs = Vec::with_capacity(n);
    for pc in 0..n.saturating_sub(1) {
        let reg = |rng: &mut Rng| rng.below(NREG as u64) as u8;
        let instr = match rng.below(6) {
            0 | 1 => {
                // ALU
                let op = *rng.choose(&[
                    Op::Add,
                    Op::Sub,
                    Op::Mul,
                    Op::Div,
                    Op::And,
                    Op::Or,
                    Op::Xor,
                    Op::Mov,
                    Op::Not,
                    Op::Shl,
                    Op::Shr,
                    Op::Addi,
                ]);
                let imm = match op {
                    Op::Shl | Op::Shr => rng.below(64) as i64,
                    _ => rng.range_u64(0, 2001) as i64 - 1000,
                };
                Instr::new(op, reg(rng), reg(rng), reg(rng), imm)
            }
            2 => Instr::new(Op::Movi, reg(rng), 0, 0, rng.next_i64()),
            3 => {
                // memory / scratchpad
                let op = *rng.choose(&[
                    Op::Ldd,
                    Op::Std,
                    Op::Spl,
                    Op::Sps,
                    Op::Ldx,
                    Op::Stx,
                    Op::Splx,
                    Op::Spsx,
                ]);
                let window = if op.touches_data() {
                    DATA_WORDS as i64
                } else {
                    SP_WORDS as i64
                };
                let imm = match op {
                    Op::Ldd | Op::Std | Op::Spl | Op::Sps => {
                        rng.below(window as u64) as i64
                    }
                    // dynamic forms: allow a small OOB margin to exercise
                    // trap parity across engines
                    _ => rng.range_u64(0, window as u64 + 4) as i64 - 2,
                };
                Instr::new(op, reg(rng), reg(rng), 0, imm)
            }
            4 => {
                // forward jump
                let op = *rng.choose(&[
                    Op::Jeq,
                    Op::Jne,
                    Op::Jlt,
                    Op::Jle,
                    Op::Jgt,
                    Op::Jge,
                    Op::Jmp,
                ]);
                let target = rng.range_u64(pc as u64 + 1, n as u64 + 1);
                Instr::new(op, reg(rng), reg(rng), 0, target as i64)
            }
            _ => {
                // occasional early terminal
                if rng.chance(0.3) {
                    Instr::new(
                        *rng.choose(&[Op::Next, Op::Ret]),
                        0,
                        0,
                        0,
                        0,
                    )
                } else {
                    Instr::new(Op::Nop, 0, 0, 0, 0)
                }
            }
        };
        instrs.push(instr);
    }
    instrs.push(Instr::new(
        *rng.choose(&[Op::Next, Op::Ret, Op::Trap]),
        0,
        0,
        0,
        0,
    ));
    let load_words = rng.range_u64(1, DATA_WORDS as u64 + 1) as u8;
    let p = Program::new(instrs, load_words);
    verify(&p).expect("generator produced an unverifiable program");
    p
}

/// Random workspace with full-range register/window contents.
pub fn random_workspace(rng: &mut Rng) -> Workspace {
    let mut w = Workspace::new();
    for r in w.regs.iter_mut() {
        *r = rng.next_i64() >> rng.below(3); // mix of magnitudes
    }
    for s in w.sp.iter_mut() {
        *s = rng.next_i64();
    }
    for d in w.data.iter_mut() {
        *d = rng.next_i64();
    }
    w
}

/// A small well-formed traversal program (list find) used by many tests.
pub fn list_find_program() -> Program {
    let mut a = Asm::new();
    let miss = a.label();
    let walk = a.label();
    a.spl(1, 0); // key
    a.ldd(2, 0); // node.key
    a.jne(1, 2, miss);
    a.ldd(3, 1); // node.value
    a.sps(3, 1); // sp[1] = value
    a.ret();
    a.bind(miss);
    a.ldd(3, 2); // next
    a.movi(4, 0);
    a.jne(3, 4, walk);
    a.movi(5, i64::MAX);
    a.sps(5, 2); // sp[2] = NOT_FOUND
    a.ret();
    a.bind(walk);
    a.mov(0, 3);
    a.next();
    a.finish(3).unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interp::logic_pass;

    #[test]
    fn generated_programs_verify_and_run() {
        let mut rng = Rng::new(1234);
        for _ in 0..200 {
            let p = random_verified_program(&mut rng, 24);
            let mut w = random_workspace(&mut rng);
            let r = logic_pass(&p, &mut w);
            // must terminate with a defined status in bounded steps
            assert!(r.steps as usize <= p.len() + 1);
            assert_ne!(r.status as i32, 0);
        }
    }

    #[test]
    fn list_find_program_verifies() {
        let p = list_find_program();
        assert!(verify(&p).is_ok());
        assert_eq!(p.load_words, 3);
    }
}
