//! YCSB core workloads A/B/C/E (Cooper et al., SoCC'10), as used in the
//! paper's WebService (A/B/C) and WiredTiger (E) evaluations.

use crate::util::prng::Rng;
use crate::util::zipf::KeyChooser;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum YcsbOp {
    Read(u64),
    Update(u64),
    /// Scan(start_key, record_count)
    Scan(u64, usize),
    Insert(u64),
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum YcsbSpec {
    /// 50% read / 50% update.
    A,
    /// 95% read / 5% update.
    B,
    /// 100% read.
    C,
    /// 95% scan / 5% insert.
    E,
}

impl YcsbSpec {
    pub fn name(&self) -> &'static str {
        match self {
            YcsbSpec::A => "YCSB-A",
            YcsbSpec::B => "YCSB-B",
            YcsbSpec::C => "YCSB-C",
            YcsbSpec::E => "YCSB-E",
        }
    }
}

pub struct YcsbWorkload {
    spec: YcsbSpec,
    chooser: KeyChooser,
    rng: Rng,
    insert_cursor: u64,
    /// YCSB-E scan length: uniform in [1, max_scan].
    max_scan: usize,
}

impl YcsbWorkload {
    pub fn new(spec: YcsbSpec, keys: u64, zipfian: bool, seed: u64) -> Self {
        let chooser = if zipfian {
            KeyChooser::scrambled_zipfian(keys)
        } else {
            KeyChooser::uniform(keys)
        };
        Self {
            spec,
            chooser,
            rng: Rng::with_stream(seed, 0x4C5B),
            insert_cursor: keys,
            max_scan: 100,
        }
    }

    pub fn with_max_scan(mut self, max_scan: usize) -> Self {
        self.max_scan = max_scan;
        self
    }

    pub fn spec(&self) -> YcsbSpec {
        self.spec
    }

    pub fn next_op(&mut self) -> YcsbOp {
        let p = self.rng.next_f64();
        match self.spec {
            YcsbSpec::A => {
                if p < 0.5 {
                    YcsbOp::Read(self.chooser.next(&mut self.rng))
                } else {
                    YcsbOp::Update(self.chooser.next(&mut self.rng))
                }
            }
            YcsbSpec::B => {
                if p < 0.95 {
                    YcsbOp::Read(self.chooser.next(&mut self.rng))
                } else {
                    YcsbOp::Update(self.chooser.next(&mut self.rng))
                }
            }
            YcsbSpec::C => YcsbOp::Read(self.chooser.next(&mut self.rng)),
            YcsbSpec::E => {
                if p < 0.95 {
                    let len = 1 + self.rng.below(self.max_scan as u64)
                        as usize;
                    YcsbOp::Scan(self.chooser.next(&mut self.rng), len)
                } else {
                    let k = self.insert_cursor;
                    self.insert_cursor += 1;
                    YcsbOp::Insert(k)
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mix(spec: YcsbSpec, n: usize) -> (usize, usize, usize, usize) {
        let mut w = YcsbWorkload::new(spec, 10_000, true, 7);
        let (mut r, mut u, mut s, mut i) = (0, 0, 0, 0);
        for _ in 0..n {
            match w.next_op() {
                YcsbOp::Read(_) => r += 1,
                YcsbOp::Update(_) => u += 1,
                YcsbOp::Scan(..) => s += 1,
                YcsbOp::Insert(_) => i += 1,
            }
        }
        (r, u, s, i)
    }

    #[test]
    fn ycsb_a_is_half_updates() {
        let (r, u, _, _) = mix(YcsbSpec::A, 10_000);
        assert!((r as f64 - 5000.0).abs() < 300.0, "reads {r}");
        assert_eq!(r + u, 10_000);
    }

    #[test]
    fn ycsb_b_is_5pct_updates() {
        let (_, u, _, _) = mix(YcsbSpec::B, 10_000);
        assert!((u as f64 - 500.0).abs() < 150.0, "updates {u}");
    }

    #[test]
    fn ycsb_c_is_read_only() {
        let (r, _, _, _) = mix(YcsbSpec::C, 5_000);
        assert_eq!(r, 5_000);
    }

    #[test]
    fn ycsb_e_is_scans_plus_inserts() {
        let (_, _, s, i) = mix(YcsbSpec::E, 10_000);
        assert!(s > 9_000, "scans {s}");
        assert!(i > 200, "inserts {i}");
    }

    #[test]
    fn scan_lengths_bounded() {
        let mut w = YcsbWorkload::new(YcsbSpec::E, 1000, true, 3)
            .with_max_scan(50);
        for _ in 0..1000 {
            if let YcsbOp::Scan(_, len) = w.next_op() {
                assert!((1..=50).contains(&len));
            }
        }
    }

    #[test]
    fn inserts_use_fresh_keys() {
        let mut w = YcsbWorkload::new(YcsbSpec::E, 100, true, 3);
        let mut inserted = std::collections::HashSet::new();
        for _ in 0..2000 {
            if let YcsbOp::Insert(k) = w.next_op() {
                assert!(k >= 100);
                assert!(inserted.insert(k), "duplicate insert key {k}");
            }
        }
    }

    #[test]
    fn zipf_skews_reads() {
        let mut w = YcsbWorkload::new(YcsbSpec::C, 100_000, true, 9);
        let mut counts = std::collections::HashMap::new();
        for _ in 0..20_000 {
            if let YcsbOp::Read(k) = w.next_op() {
                *counts.entry(k).or_insert(0usize) += 1;
            }
        }
        let max = counts.values().max().copied().unwrap_or(0);
        assert!(max > 100, "hottest key only {max} hits");
    }
}
