//! Synthetic OpenµPMU-style time series (paper §6: BTrDB on the LBNL
//! micro-phasor measurement dataset — voltage, current, phase at
//! 120 Hz). The real dataset is not redistributable here; this source
//! generates the same *structure*: time-ordered keys at a fixed sample
//! rate, a 60 Hz carrier with slow diurnal drift, measurement noise and
//! occasional sag/swell events, so window aggregations and locality
//! behave like the paper's workload.

use crate::util::prng::Rng;

/// Samples are keyed by timestamp (ns); values stored as milli-units
/// (fixed point) so they fit the i64 value slots of the B+Tree.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PmuSample {
    pub t_ns: i64,
    /// voltage, millivolts
    pub voltage_mv: i64,
    /// current, milliamps
    pub current_ma: i64,
    /// phase angle, microdegrees
    pub phase_udeg: i64,
}

pub struct PmuSource {
    rng: Rng,
    /// sample interval (120 Hz => 8_333_333 ns)
    pub dt_ns: i64,
    t: i64,
    /// event state: remaining samples of a voltage sag
    sag: u32,
}

pub const PMU_RATE_HZ: f64 = 120.0;

impl PmuSource {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: Rng::with_stream(seed, 0x9A11),
            dt_ns: (1e9 / PMU_RATE_HZ) as i64,
            t: 0,
            sag: 0,
        }
    }

    /// Next sample in time order.
    pub fn next_sample(&mut self) -> PmuSample {
        let t = self.t;
        self.t += self.dt_ns;
        let secs = t as f64 / 1e9;
        // nominal 120 V RMS with slow diurnal drift (~0.5%)
        let diurnal =
            1.0 + 0.005 * (2.0 * std::f64::consts::PI * secs / 86_400.0).sin();
        let mut v = 120_000.0 * diurnal;
        // rare sag events: 5-30% dip for up to 2 s
        if self.sag > 0 {
            v *= 0.8;
            self.sag -= 1;
        } else if self.rng.chance(1e-4) {
            self.sag = self.rng.range_u64(12, 240) as u32;
        }
        v += self.rng.next_normal() * 150.0; // measurement noise
        let i = 5_000.0 * diurnal + self.rng.next_normal() * 40.0;
        let ph = 120.0 * (secs * 0.01).sin() * 1e6 / 360.0
            + self.rng.next_normal() * 500.0;
        PmuSample {
            t_ns: t,
            voltage_mv: v as i64,
            current_ma: i as i64,
            phase_udeg: ph as i64,
        }
    }

    /// Generate `n` samples (time-ordered).
    pub fn take(&mut self, n: usize) -> Vec<PmuSample> {
        (0..n).map(|_| self.next_sample()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_are_time_ordered_at_120hz() {
        let mut s = PmuSource::new(1);
        let xs = s.take(1000);
        for w in xs.windows(2) {
            assert_eq!(w[1].t_ns - w[0].t_ns, s.dt_ns);
        }
        // 120 samples ≈ 1 second
        assert!((xs[120].t_ns - xs[0].t_ns - 1_000_000_000).abs() < 10_000);
    }

    #[test]
    fn voltage_near_nominal() {
        let mut s = PmuSource::new(2);
        let xs = s.take(5000);
        let mean: f64 = xs.iter().map(|x| x.voltage_mv as f64).sum::<f64>()
            / xs.len() as f64;
        assert!((mean - 120_000.0).abs() < 3_000.0, "mean {mean}");
    }

    #[test]
    fn deterministic_per_seed() {
        let a = PmuSource::new(7).take(100);
        let b = PmuSource::new(7).take(100);
        let c = PmuSource::new(8).take(100);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn noise_present() {
        let mut s = PmuSource::new(3);
        let xs = s.take(1000);
        let uniq: std::collections::HashSet<_> =
            xs.iter().map(|x| x.voltage_mv).collect();
        // ~150 mV Gaussian noise over millivolt quantization: expect a
        // few hundred distinct values out of 1000 samples
        assert!(uniq.len() > 300, "only {} distinct voltages", uniq.len());
    }
}
