//! k-hop neighbor-walk workload over a directed graph: the request
//! generator for the `ds::graph` scenario (bounded random walks with
//! Zipf or uniform start vertices — social-graph style "friends of
//! friends" queries).
//!
//! A query carries its per-hop neighbor draws, pre-sampled here on the
//! host exactly like a real `init()` would: the accelerator program,
//! the host reference walk, and every backend then replay the same
//! neighbor sequence deterministically.

use crate::ds::graph::MAX_HOPS;
use crate::util::prng::Rng;
use crate::util::zipf::KeyChooser;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct KhopQuery {
    /// Start vertex index (caller maps to a vertex address).
    pub start: u64,
    /// Walk length in hops (1..=max_hops).
    pub hops: u32,
    /// Non-negative per-hop draws, `hops` of them, indexed by
    /// remaining-hop counter (draws[hops-1] picks the first edge).
    pub draws: Vec<i64>,
}

pub struct GraphKhopWorkload {
    chooser: KeyChooser,
    rng: Rng,
    max_hops: u32,
}

impl GraphKhopWorkload {
    pub fn new(vertices: u64, max_hops: u32, zipfian: bool, seed: u64) -> Self {
        assert!(max_hops >= 1 && max_hops as usize <= MAX_HOPS);
        let chooser = if zipfian {
            KeyChooser::scrambled_zipfian(vertices)
        } else {
            KeyChooser::uniform(vertices)
        };
        Self { chooser, rng: Rng::with_stream(seed, 0x6B09), max_hops }
    }

    pub fn next_query(&mut self) -> KhopQuery {
        let start = self.chooser.next(&mut self.rng);
        let hops = 1 + self.rng.below(self.max_hops as u64) as u32;
        let draws = (0..hops)
            .map(|_| (self.rng.next_u64() >> 1) as i64)
            .collect();
        KhopQuery { start, hops, draws }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn queries_are_bounded_and_deterministic() {
        let mut a = GraphKhopWorkload::new(10_000, 8, true, 42);
        let mut b = GraphKhopWorkload::new(10_000, 8, true, 42);
        for _ in 0..500 {
            let qa = a.next_query();
            assert_eq!(qa, b.next_query());
            assert!(qa.start < 10_000);
            assert!((1..=8).contains(&qa.hops));
            assert_eq!(qa.draws.len(), qa.hops as usize);
            assert!(qa.draws.iter().all(|&d| d >= 0));
        }
    }

    #[test]
    fn distinct_seeds_diverge() {
        let mut a = GraphKhopWorkload::new(1000, 6, false, 1);
        let mut b = GraphKhopWorkload::new(1000, 6, false, 2);
        let same = (0..100)
            .filter(|_| a.next_query() == b.next_query())
            .count();
        assert!(same < 5, "{same} identical queries");
    }

    #[test]
    fn zipf_skews_start_vertices() {
        let mut w = GraphKhopWorkload::new(100_000, 4, true, 9);
        let mut counts = std::collections::HashMap::new();
        for _ in 0..20_000 {
            *counts.entry(w.next_query().start).or_insert(0usize) += 1;
        }
        let max = counts.values().max().copied().unwrap_or(0);
        assert!(max > 100, "hottest start only {max} hits");
    }
}
