//! Workload generators (paper §6): YCSB A/B/C/E with Zipf or uniform
//! key choosers, a synthetic OpenµPMU-style time-series source for
//! BTrDB (voltage / current / phase at 120 Hz; the real LBNL dataset is
//! unavailable — see DESIGN.md §2 substitution table), and the k-hop
//! graph-walk generator for the `ds::graph` scenario. YCSB-E also
//! drives the skip-list scan scenario (see `benches/scenarios.rs` and
//! `pulse serve --app skiplist`).

pub mod graph_khop;
pub mod timeseries;
pub mod ycsb;

pub use graph_khop::{GraphKhopWorkload, KhopQuery};
pub use timeseries::PmuSource;
pub use ycsb::{YcsbOp, YcsbWorkload, YcsbSpec};
