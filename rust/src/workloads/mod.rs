//! Workload generators (paper §6): YCSB A/B/C/E with Zipf or uniform
//! key choosers, and a synthetic OpenµPMU-style time-series source for
//! BTrDB (voltage / current / phase at 120 Hz; the real LBNL dataset is
//! unavailable — see DESIGN.md §2 substitution table).

pub mod timeseries;
pub mod ycsb;

pub use timeseries::PmuSource;
pub use ycsb::{YcsbOp, YcsbWorkload, YcsbSpec};
