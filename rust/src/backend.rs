//! `TraversalBackend` — the unified execution-model abstraction.
//!
//! PULSE's core claim (paper §1) is that *one* expressive traversal
//! framework serves many linked structures and execution models. This
//! module is that claim's architectural seam: every compared system —
//! PULSE and PULSE-ACC (the rack DES), the swap-cache baseline
//! (Fastswap-like, paper §2/§6), and the RPC family (Xeon, BlueField-2
//! ARM, AIFM-like Cache+RPC) — implements the same trait, so apps,
//! benches, and tests drive any of them through one API:
//!
//! * [`TraversalBackend::submit`] — functional execution of one op;
//! * [`TraversalBackend::serve`] — closed-loop timed serving;
//! * [`TraversalBackend::serve_batch`] — open-loop serving of a
//!   pre-materialized batch (amortizes per-request setup; on the rack it
//!   also reuses the DES scratch structures across calls);
//! * [`TraversalBackend::metrics`] — cumulative metrics.
//!
//! All backends share the *same functional memory layout* — the model
//! backends own a [`Rack`] as their functional substrate and replay the
//! exact page/iteration traces PULSE offloads, timed under their own
//! execution model (DESIGN note: this mirrors how the paper reports
//! baselines on identical datasets).

use crate::baselines::cache::{trace_full_op, CachedSwapSim, TraceStats};
use crate::baselines::{RpcKind, RpcModel, WorkloadStats};
use crate::isa::SP_WORDS;
use crate::rack::{Op, Rack, ServeReport};

/// Clamp a model-produced per-op latency into a sane range. Analytic
/// models can emit NaN (0/0 in a rate formula), negative values
/// (mis-calibrated subtraction), or +inf (division by a zero
/// bandwidth); none of those may poison the summed latency or the
/// histogram. NaN and anything below the 1 ns floor become 1 ns; +inf
/// caps at ~11.6 days, far beyond any legitimate model output.
fn sanitize_latency_ns(lat: f64) -> f64 {
    const MAX_NS: f64 = 1e15;
    if lat.is_nan() {
        1.0
    } else {
        lat.clamp(1.0, MAX_NS)
    }
}

/// Shared serving loop of the model backends: trace each op through
/// the rack's functional substrate, time it with `per_op_latency_ns`
/// (which may accumulate model state), and record the accounting every
/// backend reports identically. Returns the partial report plus summed
/// latency; the caller derives its saturation bound, makespan, wall
/// clock, and cumulative merge.
fn trace_serve(
    rack: &mut Rack,
    ops: &mut dyn FnMut(u64) -> Option<Op>,
    per_op_latency_ns: &mut dyn FnMut(&Op, &TraceStats) -> f64,
) -> (ServeReport, f64) {
    let mut report = ServeReport::default();
    let mut total_ns = 0f64;
    let mut issued = 0u64;
    while let Some(op) = ops(issued) {
        issued += 1;
        // same admission-time shape check as the DES and the live
        // coordinator: malformed ops trap instead of panicking
        if op.validate().is_err() {
            report.record_admission_trap();
            continue;
        }
        let (_sp, trace) = trace_full_op(rack, &op);
        let lat = sanitize_latency_ns(per_op_latency_ns(&op, &trace));
        total_ns += lat;
        if trace.trapped {
            report.trapped += 1;
        }
        report.completed += 1;
        report.latency.record(lat as u64);
        report.crossings.record(trace.crossings as u64);
        if trace.crossings > 0 {
            report.cross_node_requests += 1;
        }
        report.total_iters += trace.iters as u64;
    }
    (report, total_ns)
}

/// Backend-agnostic cumulative metrics, derived from a `ServeReport`.
#[derive(Debug, Clone)]
pub struct BackendMetrics {
    pub name: &'static str,
    pub ops: u64,
    pub trapped: u64,
    pub mean_latency_ns: f64,
    pub p50_latency_ns: u64,
    pub p95_latency_ns: u64,
    pub p99_latency_ns: u64,
    pub tput_ops_per_s: f64,
    pub total_iters: u64,
    pub cross_node_requests: u64,
    /// Messages dropped by the link layer (`LinkStats.dropped` summed
    /// across the rack's links; the DES retransmits these, so a
    /// non-zero count with zero lost ops means loss was *absorbed*,
    /// not absent). 0 on backends without simulated links.
    pub net_dropped: u64,
    /// Serving-tier overload counters (filled by `srv` when the
    /// backend is exposed over sockets; 0 for in-process serving).
    /// Frames rejected by magic/version/CRC/body checks:
    pub wire_decode_errors: u64,
    /// Requests answered BUSY instead of executed:
    pub wire_busy: u64,
    /// Live-engine shard counters (filled by `LiveBackend`; 0 on the
    /// DES and the model backends, whose equivalents live in the
    /// `ServeReport`). Messages forwarded shard→shard in-network:
    pub live_forwards: u64,
    /// Traversals that yielded on budget exhaustion:
    pub live_yields: u64,
    /// Traversals that trapped on a shard:
    pub live_traps: u64,
    /// Messages dropped at a full shard queue:
    pub live_drops: u64,
    /// High-water mark across all shard queues:
    pub live_max_queue_depth: u64,
}

impl BackendMetrics {
    pub fn from_report(name: &'static str, r: &ServeReport) -> Self {
        Self {
            name,
            ops: r.completed,
            trapped: r.trapped,
            mean_latency_ns: r.latency.mean(),
            p50_latency_ns: r.latency.p50(),
            p95_latency_ns: r.latency.p95(),
            p99_latency_ns: r.latency.p99(),
            tput_ops_per_s: r.tput_ops_per_s,
            total_iters: r.total_iters,
            cross_node_requests: r.cross_node_requests,
            net_dropped: 0,
            wire_decode_errors: 0,
            wire_busy: 0,
            live_forwards: 0,
            live_yields: 0,
            live_traps: 0,
            live_drops: 0,
            live_max_queue_depth: 0,
        }
    }
}

/// One execution model for distributed pointer traversals.
///
/// Object safe: benches hold `Box<dyn TraversalBackend>` and iterate
/// the compared systems uniformly.
pub trait TraversalBackend {
    /// Display name ("PULSE", "RPC-ARM", "Cache", ...).
    fn name(&self) -> &'static str;

    /// The functional substrate. Every backend owns a rack: the DES
    /// backends execute on it, the model backends trace through it.
    /// Apps are built against this rack, so all systems share one
    /// memory layout.
    fn rack_mut(&mut self) -> &mut Rack;

    /// Whether this backend's execution model is real parallel shard
    /// threads over the rack's memory nodes. The wire-serving tier
    /// keys its engine mode on this capability (sharded live dataplane
    /// vs inline functional execution) — a capability, not a
    /// display-name comparison, so renames can't silently degrade
    /// serving.
    fn serves_sharded(&self) -> bool {
        false
    }

    /// Execute one op functionally (no timing); returns the final
    /// scratchpad.
    fn submit(&mut self, op: &Op) -> [i64; SP_WORDS];

    /// Closed-loop serving: `concurrency` outstanding ops drawn from
    /// the generator until it returns `None`.
    fn serve(
        &mut self,
        ops: &mut dyn FnMut(u64) -> Option<Op>,
        concurrency: usize,
    ) -> ServeReport;

    /// Open-loop serving of a pre-materialized batch. Default: drain
    /// the slice through `serve`. The rack overrides this with its
    /// scratch-reusing batched DES path.
    fn serve_batch(&mut self, ops: &[Op], concurrency: usize) -> ServeReport {
        self.serve(&mut |i| ops.get(i as usize).cloned(), concurrency)
    }

    /// Cumulative metrics across every serve call on this backend.
    fn metrics(&self) -> BackendMetrics;
}

// ---------------------------------------------------------------------
// PULSE / PULSE-ACC: the rack DES is a backend directly.
// ---------------------------------------------------------------------

impl TraversalBackend for Rack {
    fn name(&self) -> &'static str {
        if self.cfg.in_network_routing {
            "PULSE"
        } else {
            "PULSE-ACC"
        }
    }

    fn rack_mut(&mut self) -> &mut Rack {
        self
    }

    fn submit(&mut self, op: &Op) -> [i64; SP_WORDS] {
        self.run_op_functional(op)
    }

    fn serve(
        &mut self,
        ops: &mut dyn FnMut(u64) -> Option<Op>,
        concurrency: usize,
    ) -> ServeReport {
        Rack::serve(self, ops, concurrency)
    }

    fn serve_batch(&mut self, ops: &[Op], concurrency: usize) -> ServeReport {
        Rack::serve_batch(self, ops, concurrency)
    }

    fn metrics(&self) -> BackendMetrics {
        let mut m = BackendMetrics::from_report(
            TraversalBackend::name(self),
            self.cumulative(),
        );
        // loss lives in the links; surfacing it here is what makes an
        // overloaded/lossy run distinguishable from a clean one
        m.net_dropped = self.link_totals().dropped;
        m
    }
}

// ---------------------------------------------------------------------
// Cache: swap-backed disaggregated memory (Fastswap-like, paper §6).
// ---------------------------------------------------------------------

/// Swap-cache baseline behind the backend trait: traversals execute
/// functionally through the owned rack, and every touched page is timed
/// through the LRU-cache + page-fault model.
pub struct CacheBackend {
    pub rack: Rack,
    pub sim: CachedSwapSim,
    totals: ServeReport,
}

impl CacheBackend {
    pub fn new(rack: Rack, cache_bytes: u64) -> Self {
        Self {
            rack,
            sim: CachedSwapSim::new(cache_bytes),
            totals: ServeReport::default(),
        }
    }
}

impl TraversalBackend for CacheBackend {
    fn name(&self) -> &'static str {
        "Cache"
    }

    fn rack_mut(&mut self) -> &mut Rack {
        &mut self.rack
    }

    fn submit(&mut self, op: &Op) -> [i64; SP_WORDS] {
        trace_full_op(&mut self.rack, op).0
    }

    fn serve(
        &mut self,
        ops: &mut dyn FnMut(u64) -> Option<Op>,
        concurrency: usize,
    ) -> ServeReport {
        let wall_start = std::time::Instant::now();
        let Self { rack, sim, totals } = self;
        let mut total_pages = 0u64;
        let mut total_writes = 0u64;
        let (mut report, total_ns) =
            trace_serve(rack, ops, &mut |op, trace| {
                total_pages += trace.pages.len() as u64;
                total_writes += trace.writes.len() as u64;
                sim.op_latency_ns(trace, op.cpu_post_ns as f64) as f64
            });
        if report.completed > 0 {
            let mean_ns = total_ns / report.completed as f64;
            let pages_per_op =
                total_pages as f64 / report.completed as f64;
            let writes_per_op =
                total_writes as f64 / report.completed as f64;
            // closed-loop concurrency bound vs the swap system's fault
            // pipeline (what the paper's "swap system performance" caps;
            // dirty-page invalidations occupy the same pipeline)
            let conc_bound = concurrency as f64 / (mean_ns / 1e9);
            let fault_bound =
                sim.tput_bound_ops_per_s(pages_per_op, writes_per_op);
            report.tput_ops_per_s = conc_bound.min(fault_bound).max(1e-9);
            report.makespan_ns = (report.completed as f64
                / report.tput_ops_per_s
                * 1e9) as u64;
        }
        report.wall_ms = wall_start.elapsed().as_secs_f64() * 1e3;
        totals.merge(&report);
        report
    }

    fn metrics(&self) -> BackendMetrics {
        BackendMetrics::from_report("Cache", &self.totals)
    }
}

// ---------------------------------------------------------------------
// RPC family: Xeon / BlueField-ARM / AIFM-like Cache+RPC (paper §6).
// ---------------------------------------------------------------------

/// RPC baseline behind the backend trait: per-op iteration/crossing
/// counts come from the real functional trace; latency and saturation
/// throughput come from the calibrated RPC execution model.
pub struct RpcBackend {
    pub rack: Rack,
    pub model: RpcModel,
    totals: ServeReport,
}

impl RpcBackend {
    pub fn new(rack: Rack, kind: RpcKind) -> Self {
        Self { rack, model: RpcModel::new(kind), totals: ServeReport::default() }
    }

    /// Per-op workload stats from a trace of `op` (the model's input).
    fn op_stats(op: &Op, iters: u32, crossings: u32) -> WorkloadStats {
        let stages = op.stages.len().max(1) as f64;
        let words_per_iter = op
            .stages
            .iter()
            .map(|s| s.iter.program.load_words as f64)
            .sum::<f64>()
            / stages;
        let resp_bytes = 300.0
            + op.stages
                .iter()
                .map(|s| s.object_read_bytes as f64)
                .sum::<f64>();
        WorkloadStats {
            avg_iters: iters as f64,
            words_per_iter,
            req_bytes: 420.0,
            resp_bytes,
            avg_crossings: crossings as f64,
            cpu_post_ns: op.cpu_post_ns as f64,
            ops: 1,
        }
    }
}

impl TraversalBackend for RpcBackend {
    fn name(&self) -> &'static str {
        self.model.kind.name()
    }

    fn rack_mut(&mut self) -> &mut Rack {
        &mut self.rack
    }

    fn submit(&mut self, op: &Op) -> [i64; SP_WORDS] {
        trace_full_op(&mut self.rack, op).0
    }

    fn serve(
        &mut self,
        ops: &mut dyn FnMut(u64) -> Option<Op>,
        concurrency: usize,
    ) -> ServeReport {
        let wall_start = std::time::Instant::now();
        let Self { rack, model, totals } = self;
        let nodes = rack.cfg.nodes;
        let mut mean_stats = WorkloadStats::default();
        let (mut report, total_ns) =
            trace_serve(rack, ops, &mut |op, trace| {
                let w = Self::op_stats(op, trace.iters, trace.crossings);
                mean_stats.avg_iters += w.avg_iters;
                mean_stats.words_per_iter += w.words_per_iter;
                mean_stats.req_bytes += w.req_bytes;
                mean_stats.resp_bytes += w.resp_bytes;
                mean_stats.avg_crossings += w.avg_crossings;
                mean_stats.cpu_post_ns += w.cpu_post_ns;
                model.latency_ns(&w)
            });
        if report.completed > 0 {
            let n = report.completed as f64;
            mean_stats.avg_iters /= n;
            mean_stats.words_per_iter /= n;
            mean_stats.req_bytes /= n;
            mean_stats.resp_bytes /= n;
            mean_stats.avg_crossings /= n;
            mean_stats.cpu_post_ns /= n;
            mean_stats.ops = report.completed;
            let mean_ns = total_ns / n;
            let conc_bound = concurrency as f64 / (mean_ns / 1e9);
            let model_bound = model.tput_ops_per_s(&mean_stats, nodes);
            report.tput_ops_per_s = conc_bound.min(model_bound).max(1e-9);
            report.makespan_ns =
                (n / report.tput_ops_per_s * 1e9) as u64;
        }
        report.wall_ms = wall_start.elapsed().as_secs_f64() * 1e3;
        totals.merge(&report);
        report
    }

    fn metrics(&self) -> BackendMetrics {
        BackendMetrics::from_report(self.model.kind.name(), &self.totals)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ds::HashMapDs;
    use crate::rack::RackConfig;

    fn ops_through(backend: &mut dyn TraversalBackend, n: u64) -> ServeReport {
        let mut m = HashMapDs::build(backend.rack_mut(), 64);
        for i in 0..500 {
            m.insert(backend.rack_mut(), i, i * 2);
        }
        let prog = m.find_program();
        let ops: Vec<Op> = (0..n)
            .map(|i| {
                let key = (i % 500) as i64;
                let mut sp = [0i64; SP_WORDS];
                sp[0] = key;
                Op::new(prog.clone(), m.bucket_ptr(key), sp)
            })
            .collect();
        backend.serve_batch(&ops, 8)
    }

    #[test]
    fn sanitize_latency_guards_degenerate_model_outputs() {
        assert_eq!(sanitize_latency_ns(f64::NAN), 1.0);
        assert_eq!(sanitize_latency_ns(-5.0e9), 1.0);
        assert_eq!(sanitize_latency_ns(f64::NEG_INFINITY), 1.0);
        assert_eq!(sanitize_latency_ns(0.0), 1.0);
        assert_eq!(sanitize_latency_ns(0.25), 1.0);
        assert_eq!(sanitize_latency_ns(f64::INFINITY), 1e15);
        assert_eq!(sanitize_latency_ns(42.5), 42.5);
    }

    #[test]
    fn trace_serve_survives_degenerate_per_op_latencies() {
        // a latency model gone wrong (NaN, negative, inf, sub-ns) must
        // still yield a finite, internally consistent report
        let mut rack = Rack::new(RackConfig::small(1));
        let mut m = HashMapDs::build(&mut rack, 16);
        for i in 0..50 {
            m.insert(&mut rack, i, i);
        }
        let prog = m.find_program();
        let ops: Vec<Op> = (0..4)
            .map(|i| {
                let mut sp = [0i64; SP_WORDS];
                sp[0] = i;
                Op::new(prog.clone(), m.bucket_ptr(i), sp)
            })
            .collect();
        let bad = [f64::NAN, -1.0e12, f64::INFINITY, 0.001];
        let mut k = 0usize;
        let (report, total_ns) = trace_serve(
            &mut rack,
            &mut |i| ops.get(i as usize).cloned(),
            &mut |_op, _trace| {
                k += 1;
                bad[k - 1]
            },
        );
        assert_eq!(report.completed, 4);
        assert_eq!(report.latency.count(), 4);
        assert!(total_ns.is_finite(), "summed latency not finite");
        assert!(report.latency.mean().is_finite());
        assert!(report.latency.min() >= 1, "below the 1 ns floor");
        assert!(report.latency.max() <= 1_000_000_000_000_000);
    }

    #[test]
    fn rack_is_a_backend() {
        let mut rack = Rack::new(RackConfig::small(2));
        let rep = ops_through(&mut rack, 100);
        assert_eq!(rep.completed, 100);
        assert!(rep.latency.mean() > 0.0);
        let m = TraversalBackend::metrics(&rack);
        assert_eq!(m.name, "PULSE");
        assert_eq!(m.ops, 100);
    }

    #[test]
    fn cache_backend_times_via_page_faults() {
        let mut b =
            CacheBackend::new(Rack::new(RackConfig::small(2)), 64 << 10);
        let rep = ops_through(&mut b, 100);
        assert_eq!(rep.completed, 100);
        assert!(rep.latency.mean() > 0.0);
        assert!(b.sim.faults > 0, "tiny cache never faulted");
        assert_eq!(b.metrics().name, "Cache");
    }

    #[test]
    fn rpc_backend_reports_model_latency() {
        let mut b =
            RpcBackend::new(Rack::new(RackConfig::small(2)), RpcKind::Rpc);
        let rep = ops_through(&mut b, 100);
        assert_eq!(rep.completed, 100);
        // at least one network round trip per op
        assert!(rep.latency.mean() > 1_000.0, "{}", rep.latency.mean());
        assert!(rep.tput_ops_per_s > 0.0);
        assert_eq!(b.metrics().name, "RPC");
    }

    #[test]
    fn functional_submit_agrees_across_backends() {
        let mut rack = Rack::new(RackConfig::small(1));
        let mut m = HashMapDs::build(&mut rack, 64);
        for i in 0..200 {
            m.insert(&mut rack, i, i * 7);
        }
        let prog = m.find_program();
        let mut sp = [0i64; SP_WORDS];
        sp[0] = 123;
        let op = Op::new(prog.clone(), m.bucket_ptr(123), sp);
        let want = rack.run_op_functional(&op);
        assert_eq!(want[1], 123 * 7);

        let mut cache = CacheBackend::new(rack, 1 << 20);
        assert_eq!(cache.submit(&op), want);
        let mut rpc = RpcBackend::new(
            {
                // fresh rack with the same deterministic layout
                let mut r = Rack::new(RackConfig::small(1));
                let mut m2 = HashMapDs::build(&mut r, 64);
                for i in 0..200 {
                    m2.insert(&mut r, i, i * 7);
                }
                r
            },
            RpcKind::RpcArm,
        );
        assert_eq!(rpc.submit(&op)[1], 123 * 7);
    }
}
