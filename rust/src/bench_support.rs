//! Shared harness for the paper-figure benches (`rust/benches/*.rs`,
//! built with `harness = false`): table printing + CSV emission under
//! `bench_out/`, and the workload-stats extraction shared by the
//! baseline models.

use std::fmt::Write as _;
use std::path::Path;

use crate::apps::{BtrDbApp, WebServiceApp, WiredTigerApp};
use crate::baselines::WorkloadStats;
use crate::rack::{Rack, RackConfig, ServeReport};
use crate::workloads::{YcsbSpec, YcsbWorkload};

/// Simple fixed-width table printer.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: title.to_string(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n== {} ==", self.title);
        let mut line = String::new();
        for (h, w) in self.header.iter().zip(&widths) {
            let _ = write!(line, "{h:>w$}  ");
        }
        println!("{line}");
        for r in &self.rows {
            let mut line = String::new();
            for (c, w) in r.iter().zip(&widths) {
                let _ = write!(line, "{c:>w$}  ");
            }
            println!("{line}");
        }
    }

    /// Write the table as CSV under `bench_out/<name>.csv`.
    pub fn save_csv(&self, name: &str) {
        let dir = Path::new("bench_out");
        let _ = std::fs::create_dir_all(dir);
        let mut out = String::new();
        out.push_str(&self.header.join(","));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.join(","));
            out.push('\n');
        }
        let path = dir.join(format!("{name}.csv"));
        if std::fs::write(&path, out).is_ok() {
            println!("[saved {}]", path.display());
        }
    }
}

pub fn fmt_us(ns: f64) -> String {
    format!("{:.1}", ns / 1e3)
}

pub fn fmt_kops(ops: f64) -> String {
    format!("{:.1}", ops / 1e3)
}

/// Standard rack config used across benches.
pub fn bench_rack(nodes: usize, granularity: u64) -> Rack {
    Rack::new(RackConfig {
        nodes,
        node_capacity: 1 << 30,
        granularity,
        ..Default::default()
    })
}

/// Extract baseline-model workload stats from a PULSE serve report.
pub fn stats_from_report(
    rep: &ServeReport,
    words_per_iter: f64,
    resp_bytes: f64,
    cpu_post_ns: f64,
) -> WorkloadStats {
    let ops = rep.completed.max(1);
    WorkloadStats {
        avg_iters: rep.total_iters as f64 / ops as f64,
        words_per_iter,
        req_bytes: 420.0,
        resp_bytes,
        avg_crossings: rep.crossings.mean(),
        cpu_post_ns,
        ops,
    }
}

/// App handle bundling the built application with its op stream maker.
pub enum BenchApp {
    Web(WebServiceApp),
    Wt(WiredTigerApp),
    Bt(BtrDbApp),
}

pub const SEC: i64 = 1_000_000_000;

/// Build one of the three paper apps at bench scale.
pub fn build_app(rack: &mut Rack, which: &str, seed: u64) -> BenchApp {
    match which {
        "webservice" => {
            BenchApp::Web(WebServiceApp::build(rack, 2_000, seed))
        }
        "wiredtiger" => {
            BenchApp::Wt(WiredTigerApp::build(rack, 60_000, seed))
        }
        "btrdb" => BenchApp::Bt(BtrDbApp::build(rack, 40_000, seed)),
        _ => panic!("unknown app {which}"),
    }
}

impl BenchApp {
    /// Serve `n` ops with the given concurrency; zipf toggles the key
    /// chooser; `window_s` applies to BTrDB.
    pub fn serve(
        &self,
        rack: &mut Rack,
        n: u64,
        conc: usize,
        zipf: bool,
        window_s: i64,
        seed: u64,
    ) -> ServeReport {
        match self {
            BenchApp::Web(app) => {
                let w =
                    YcsbWorkload::new(YcsbSpec::B, app.users, zipf, seed);
                let mut ops = app.op_stream(w, n);
                rack.serve(move |i| ops(i), conc)
            }
            BenchApp::Wt(app) => {
                let w = YcsbWorkload::new(YcsbSpec::E, app.keys, zipf, seed)
                    .with_max_scan(100);
                let mut ops = app.op_stream(w, n);
                rack.serve(move |i| ops(i), conc)
            }
            BenchApp::Bt(app) => {
                let mut ops = app.op_stream(window_s * SEC, n, seed);
                rack.serve(move |i| ops(i), conc)
            }
        }
    }

    pub fn words_per_iter(&self) -> f64 {
        match self {
            BenchApp::Web(_) => 3.0,
            _ => 18.0,
        }
    }

    pub fn resp_bytes(&self) -> f64 {
        match self {
            BenchApp::Web(_) => 8192.0 + 300.0,
            BenchApp::Wt(_) => 50.0 * 240.0 + 300.0,
            BenchApp::Bt(_) => 300.0,
        }
    }

    pub fn cpu_post_ns(&self) -> f64 {
        match self {
            BenchApp::Web(app) => app.post_ns as f64,
            _ => 200.0,
        }
    }
}
