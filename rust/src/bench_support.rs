//! Shared harness for the paper-figure benches (`rust/benches/*.rs`,
//! built with `harness = false`): table printing + CSV/JSON emission
//! under `bench_out/`, backend construction for the compared systems,
//! and the workload-stats extraction shared by the baseline models.
//!
//! Every figure bench drives its systems through the
//! [`TraversalBackend`] trait: pick a backend with [`make_backend`],
//! build the app against `backend.rack_mut()`, then serve with
//! [`BenchApp::serve_on`] (closed loop) or [`BenchApp::materialize_ops`]
//! + `serve_batch` (open loop).

use std::fmt::Write as _;
use std::io;
use std::path::{Path, PathBuf};

use crate::apps::{BtrDbApp, WebServiceApp, WiredTigerApp};
use crate::backend::{CacheBackend, RpcBackend, TraversalBackend};
use crate::baselines::{RpcKind, WorkloadStats};
use crate::ds::{AdjGraph, HashMapDs, RadixTrie, SkipList};
use crate::live::LiveBackend;
use crate::rack::{Op, Rack, RackConfig, ServeReport};
use crate::util::json::Json;
use crate::util::prng::Rng;
use crate::workloads::{GraphKhopWorkload, YcsbOp, YcsbSpec, YcsbWorkload};

/// Simple fixed-width table printer.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: title.to_string(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n== {} ==", self.title);
        let mut line = String::new();
        for (h, w) in self.header.iter().zip(&widths) {
            let _ = write!(line, "{h:>w$}  ");
        }
        println!("{line}");
        for r in &self.rows {
            let mut line = String::new();
            for (c, w) in r.iter().zip(&widths) {
                let _ = write!(line, "{c:>w$}  ");
            }
            println!("{line}");
        }
    }

    /// Write the table as CSV under `bench_out/<name>.csv`, creating
    /// the directory if needed. Returns the written path.
    pub fn save_csv(&self, name: &str) -> io::Result<PathBuf> {
        let dir = Path::new("bench_out");
        std::fs::create_dir_all(dir)?;
        let mut out = String::new();
        out.push_str(&self.header.join(","));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.join(","));
            out.push('\n');
        }
        let path = dir.join(format!("{name}.csv"));
        std::fs::write(&path, out)?;
        println!("[saved {}]", path.display());
        Ok(path)
    }
}

/// Write a JSON document under `bench_out/<name>.json`, creating the
/// directory if needed. Returns the written path.
pub fn save_json(name: &str, j: &Json) -> io::Result<PathBuf> {
    let dir = Path::new("bench_out");
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("{name}.json"));
    std::fs::write(&path, j.render())?;
    println!("[saved {}]", path.display());
    Ok(path)
}

/// Check that a serving-tier registry snapshot (the object a STATS
/// frame returns, see `obs/`) accounts for every request exactly once:
/// `srv.requests == srv.responses + srv.busy + srv.errors_sent`. On a
/// clean run the error term is zero; either way a request that was
/// neither answered nor rejected — or answered twice — breaks the
/// partition. Shared by `tests/integration_srv.rs` and the CI serving
/// smoke so both pin the same invariant.
pub fn check_stats_partition(snap: &Json) -> Result<(), String> {
    let get = |k: &str| -> Result<f64, String> {
        snap.get(k)
            .and_then(|v| v.as_f64())
            .ok_or_else(|| format!("snapshot is missing {k:?}"))
    };
    let requests = get("srv.requests")?;
    let answered = get("srv.responses")?
        + get("srv.busy")?
        + get("srv.errors_sent")?;
    if requests == answered {
        Ok(())
    } else {
        Err(format!(
            "request accounting does not partition: \
             srv.requests={requests} but \
             responses+busy+errors={answered}"
        ))
    }
}

pub fn fmt_us(ns: f64) -> String {
    format!("{:.1}", ns / 1e3)
}

pub fn fmt_kops(ops: f64) -> String {
    format!("{:.1}", ops / 1e3)
}

/// Standard rack config used across benches.
pub fn bench_rack(nodes: usize, granularity: u64) -> Rack {
    Rack::new(RackConfig::bench(nodes, granularity))
}

/// Build one of the compared systems behind the unified trait.
/// Kinds: `pulse`, `pulse-acc`, `cache`, `rpc`, `rpc-arm`, `cache-rpc`,
/// `live` (real-core sharded execution; wall-clock metrics).
/// `+ Send` so a backend can be handed to a serving thread (the wire
/// tier runs `Server::run` off the main thread in benches and tests).
pub fn make_backend(
    kind: &str,
    cfg: RackConfig,
) -> Box<dyn TraversalBackend + Send> {
    match kind {
        "pulse" => Box::new(Rack::new(cfg)),
        "pulse-acc" => Box::new(Rack::new(cfg.acc())),
        // one real worker thread per memory node, same functional heap
        "live" => Box::new(LiveBackend::new(Rack::new(cfg))),
        // cache sized at ~25% of the bench-scale working set (the paper
        // runs 2 GB caches against much larger datasets; the cache:WSS
        // ratio is what shapes the result)
        "cache" => Box::new(CacheBackend::new(Rack::new(cfg), 4 << 20)),
        "rpc" => Box::new(RpcBackend::new(Rack::new(cfg), RpcKind::Rpc)),
        "rpc-arm" => {
            Box::new(RpcBackend::new(Rack::new(cfg), RpcKind::RpcArm))
        }
        "cache-rpc" => {
            let mut b =
                RpcBackend::new(Rack::new(cfg), RpcKind::CacheRpc);
            b.model.cache_hit_rate = 0.05; // poor locality (paper)
            Box::new(b)
        }
        other => panic!("unknown backend kind {other:?}"),
    }
}

/// Extract baseline-model workload stats from a PULSE serve report.
pub fn stats_from_report(
    rep: &ServeReport,
    words_per_iter: f64,
    resp_bytes: f64,
    cpu_post_ns: f64,
) -> WorkloadStats {
    let ops = rep.completed.max(1);
    WorkloadStats {
        avg_iters: rep.total_iters as f64 / ops as f64,
        words_per_iter,
        req_bytes: 420.0,
        resp_bytes,
        avg_crossings: rep.crossings.mean(),
        cpu_post_ns,
        ops,
    }
}

/// Parameters of one scenario-expansion workload (`build_scenario_ops`).
#[derive(Debug, Clone, Copy)]
pub struct ScenarioSpec {
    /// Keys (skiplist/trie) or vertices (graph).
    pub keys: u64,
    /// Ops to materialize.
    pub ops: u64,
    pub zipf: bool,
    /// YCSB-E max scan length (skiplist).
    pub max_scan: usize,
    /// Walk-length cap (graph).
    pub max_hops: u32,
    /// Out-degree cap (graph).
    pub max_degree: usize,
    pub seed: u64,
}

impl Default for ScenarioSpec {
    fn default() -> Self {
        Self {
            keys: 20_000,
            ops: 4_000,
            zipf: true,
            max_scan: 60,
            max_hops: 8,
            max_degree: 8,
            seed: 42,
        }
    }
}

/// Build one scenario-expansion workload on `rack` and materialize its
/// deterministic op stream. One definition shared by
/// `benches/scenarios.rs` and `pulse serve --app skiplist|radixtrie|
/// graph`, so the CLI serves exactly the stream the bench reports.
///
/// * `skiplist-e`  — YCSB-E over the skip list: 95% two-stage scans,
///   inserts modeled as point lookups of the insertion position (as
///   the WiredTiger app does);
/// * `trie-lookup` — YCSB-C point lookups over the 256-way radix trie
///   (dense 20-bit key space: realistic shared byte prefixes);
/// * `graph-khop`  — bounded k-hop walks over the adjacency-list graph
///   (the data-dependent fan-out scenario).
pub fn build_scenario_ops(
    rack: &mut Rack,
    which: &str,
    spec: &ScenarioSpec,
) -> Vec<Op> {
    let keys = spec.keys.max(1);
    match which {
        "skiplist-e" => {
            let mut s = SkipList::new(rack, spec.seed);
            let mut rng = Rng::with_stream(spec.seed, 0x5CA);
            for k in 0..keys as i64 {
                s.insert(rack, k * 2, rng.next_i64() >> 8);
            }
            let mut w =
                YcsbWorkload::new(YcsbSpec::E, keys, spec.zipf, spec.seed ^ 1)
                    .with_max_scan(spec.max_scan);
            (0..spec.ops)
                .map(|_| match w.next_op() {
                    YcsbOp::Scan(start, len) => {
                        s.scan_op((start % keys) as i64 * 2, len)
                    }
                    YcsbOp::Insert(k) | YcsbOp::Read(k) | YcsbOp::Update(k) => {
                        s.find_op((k % keys) as i64 * 2)
                    }
                })
                .collect()
        }
        "trie-lookup" => {
            let mut t = RadixTrie::new(rack);
            let mut rng = Rng::with_stream(spec.seed, 0x791);
            for k in 0..keys as i64 {
                t.insert(rack, (k * 53) % (1 << 20), rng.next_i64() >> 8);
            }
            let mut w =
                YcsbWorkload::new(YcsbSpec::C, keys, spec.zipf, spec.seed ^ 2);
            (0..spec.ops)
                .map(|_| match w.next_op() {
                    YcsbOp::Read(k) => {
                        t.lookup_op(((k % keys) as i64 * 53) % (1 << 20))
                    }
                    other => unreachable!("YCSB-C produced {other:?}"),
                })
                .collect()
        }
        "graph-khop" => {
            let g = AdjGraph::build(
                rack,
                keys as usize,
                spec.max_degree,
                spec.seed,
            );
            let mut w = GraphKhopWorkload::new(
                keys,
                spec.max_hops,
                spec.zipf,
                spec.seed ^ 3,
            );
            (0..spec.ops)
                .map(|_| {
                    let q = w.next_query();
                    g.khop_op(q.start as usize, q.hops, &q.draws)
                })
                .collect()
        }
        other => panic!("unknown scenario workload {other:?}"),
    }
}

/// Parameters of the YCSB-A/B mixed read-write workload over the hash
/// index (the offloaded write path's bench workload).
#[derive(Debug, Clone, Copy)]
pub struct WriteMixSpec {
    pub keys: u64,
    pub ops: u64,
    pub zipf: bool,
    pub seed: u64,
}

impl Default for WriteMixSpec {
    fn default() -> Self {
        Self { keys: 20_000, ops: 4_000, zipf: true, seed: 42 }
    }
}

/// Build the hash index on `rack` and materialize one deterministic
/// YCSB-A (50% update) or YCSB-B (5% update) op stream over it. Reads
/// are offloaded chain finds; updates are offloaded put-on-existing-key
/// programs that overwrite the value through the dirty write-back path.
/// One definition shared by `benches/write_path.rs` and
/// `pulse serve --mix a|b`, so the CLI serves exactly the stream
/// `BENCH_write_path.json` reports.
pub fn build_write_mix_ops(
    rack: &mut Rack,
    mix: YcsbSpec,
    spec: &WriteMixSpec,
) -> Vec<Op> {
    let keys = spec.keys.max(1);
    let mut m = HashMapDs::build(rack, (keys as usize / 8).max(64));
    for k in 0..keys as i64 {
        m.insert(rack, k, k * 3);
    }
    let mut w = YcsbWorkload::new(mix, keys, spec.zipf, spec.seed ^ 5);
    let mut vals = Rng::with_stream(spec.seed, 0x3217E);
    (0..spec.ops)
        .map(|_| match w.next_op() {
            YcsbOp::Update(k) => {
                m.update_op((k % keys) as i64, vals.next_i64() >> 8)
            }
            YcsbOp::Read(k)
            | YcsbOp::Insert(k)
            | YcsbOp::Scan(k, _) => m.find_op((k % keys) as i64),
        })
        .collect()
}

/// Parameters of one wire-servable workload (`build_serving_ops`).
/// The serving tier's determinism contract hangs off this struct: a
/// server and a load generator that build from the same `RackConfig`
/// and the same `ServingSpec` get identical rack layouts, so the
/// client's materialized start pointers are valid on the server.
#[derive(Debug, Clone)]
pub struct ServingSpec {
    /// Workload name: `mix-a` / `mix-b` / `mix-c` (YCSB over the hash
    /// index; c = read-only), or a scenario app — `skiplist`
    /// (YCSB-E scans), `radixtrie` (YCSB-C lookups), `graph`
    /// (bounded k-hop walks).
    pub workload: String,
    pub keys: u64,
    pub ops: u64,
    pub zipf: bool,
    pub max_scan: usize,
    pub max_hops: u32,
    pub seed: u64,
}

impl Default for ServingSpec {
    fn default() -> Self {
        Self {
            workload: "mix-c".into(),
            keys: 20_000,
            ops: 4_000,
            zipf: true,
            max_scan: 60,
            max_hops: 8,
            seed: 42,
        }
    }
}

/// Build the named workload's data structure on `rack` and materialize
/// its deterministic op stream. One definition shared by `pulse serve
/// --listen` (which keeps the structure and discards the ops), `pulse
/// loadgen` (which materializes the ops against a shadow rack), the
/// `net_serving` bench, and `tests/integration_srv.rs` — so all four
/// agree byte-for-byte on what "the same op stream" means.
pub fn build_serving_ops(
    rack: &mut Rack,
    spec: &ServingSpec,
) -> Vec<Op> {
    let wspec = WriteMixSpec {
        keys: spec.keys,
        ops: spec.ops,
        zipf: spec.zipf,
        seed: spec.seed,
    };
    let sspec = ScenarioSpec {
        keys: spec.keys,
        ops: spec.ops,
        zipf: spec.zipf,
        max_scan: spec.max_scan,
        max_hops: spec.max_hops,
        seed: spec.seed,
        ..Default::default()
    };
    match spec.workload.as_str() {
        "mix-a" => build_write_mix_ops(rack, YcsbSpec::A, &wspec),
        "mix-b" => build_write_mix_ops(rack, YcsbSpec::B, &wspec),
        // YCSB-C emits only reads, so the write-mix builder serves it
        // as the pure-lookup stream
        "mix-c" => build_write_mix_ops(rack, YcsbSpec::C, &wspec),
        "skiplist" | "skiplist-e" => {
            build_scenario_ops(rack, "skiplist-e", &sspec)
        }
        "radixtrie" | "trie-lookup" => {
            build_scenario_ops(rack, "trie-lookup", &sspec)
        }
        "graph" | "graph-khop" => {
            build_scenario_ops(rack, "graph-khop", &sspec)
        }
        other => panic!("unknown serving workload {other:?}"),
    }
}

/// App handle bundling the built application with its op stream maker.
pub enum BenchApp {
    Web(WebServiceApp),
    Wt(WiredTigerApp),
    Bt(BtrDbApp),
}

pub const SEC: i64 = 1_000_000_000;

/// Build one of the three paper apps at bench scale against a rack
/// (use `backend.rack_mut()` so every system shares the layout).
pub fn build_app(rack: &mut Rack, which: &str, seed: u64) -> BenchApp {
    match which {
        "webservice" => {
            BenchApp::Web(WebServiceApp::build(rack, 2_000, seed))
        }
        "wiredtiger" => {
            BenchApp::Wt(WiredTigerApp::build(rack, 60_000, seed))
        }
        "btrdb" => BenchApp::Bt(BtrDbApp::build(rack, 40_000, seed)),
        _ => panic!("unknown app {which}"),
    }
}

impl BenchApp {
    /// Serve `n` ops on any backend with the given concurrency; zipf
    /// toggles the key chooser; `window_s` applies to BTrDB.
    pub fn serve_on<B: TraversalBackend + ?Sized>(
        &self,
        backend: &mut B,
        n: u64,
        conc: usize,
        zipf: bool,
        window_s: i64,
        seed: u64,
    ) -> ServeReport {
        match self {
            BenchApp::Web(app) => {
                let w =
                    YcsbWorkload::new(YcsbSpec::B, app.users, zipf, seed);
                let mut ops = app.op_stream(w, n);
                backend.serve(&mut ops, conc)
            }
            BenchApp::Wt(app) => {
                let w = YcsbWorkload::new(YcsbSpec::E, app.keys, zipf, seed)
                    .with_max_scan(100);
                let mut ops = app.op_stream(w, n);
                backend.serve(&mut ops, conc)
            }
            BenchApp::Bt(app) => {
                let mut ops = app.op_stream(window_s * SEC, n, seed);
                backend.serve(&mut ops, conc)
            }
        }
    }

    /// Back-compat wrapper: serve directly on a rack.
    pub fn serve(
        &self,
        rack: &mut Rack,
        n: u64,
        conc: usize,
        zipf: bool,
        window_s: i64,
        seed: u64,
    ) -> ServeReport {
        self.serve_on(rack, n, conc, zipf, window_s, seed)
    }

    /// Pre-materialize `n` ops (the open-loop `serve_batch` input);
    /// same deterministic stream as `serve_on` with the same seed.
    pub fn materialize_ops(
        &self,
        n: u64,
        zipf: bool,
        window_s: i64,
        seed: u64,
    ) -> Vec<Op> {
        match self {
            BenchApp::Web(app) => {
                let mut w =
                    YcsbWorkload::new(YcsbSpec::B, app.users, zipf, seed);
                (0..n).map(|_| app.make_op(&w.next_op())).collect()
            }
            BenchApp::Wt(app) => {
                let mut w =
                    YcsbWorkload::new(YcsbSpec::E, app.keys, zipf, seed)
                        .with_max_scan(100);
                (0..n).map(|_| app.make_op(&w.next_op())).collect()
            }
            BenchApp::Bt(app) => {
                let mut ops = app.op_stream(window_s * SEC, n, seed);
                (0..n).map_while(|i| ops(i)).collect()
            }
        }
    }

    pub fn words_per_iter(&self) -> f64 {
        match self {
            BenchApp::Web(_) => 3.0,
            _ => 18.0,
        }
    }

    pub fn resp_bytes(&self) -> f64 {
        match self {
            BenchApp::Web(_) => 8192.0 + 300.0,
            BenchApp::Wt(_) => 50.0 * 240.0 + 300.0,
            BenchApp::Bt(_) => 300.0,
        }
    }

    pub fn cpu_post_ns(&self) -> f64 {
        match self {
            BenchApp::Web(app) => app.post_ns as f64,
            _ => 200.0,
        }
    }
}
