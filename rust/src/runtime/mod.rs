//! PJRT runtime: load the AOT HLO artifacts and execute them from Rust.
//!
//! This is the three-layer glue (DESIGN.md §3): `make artifacts` lowers
//! the L2 JAX graphs (which call the L1 Pallas kernels) to HLO *text*;
//! this module parses and compiles each artifact once with the PJRT CPU
//! client and exposes typed entry points. Python never runs on the
//! request path — the compiled executables are invoked directly from the
//! accelerator's XLA engine and the BTrDB app.

use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

use crate::interp::Workspace;
use crate::isa::{Program, Status, DATA_WORDS, MAX_INSTRS, NREG, SP_WORDS};

pub struct PjrtRuntime {
    client: xla::PjRtClient,
    dir: PathBuf,
}

/// Compiled `logic_batch_step` artifact for a fixed batch size.
pub struct LogicStepExe {
    exe: xla::PjRtLoadedExecutable,
    pub batch: usize,
}

/// Compiled `window_aggregate` artifact for a fixed (n, window).
pub struct WindowAggExe {
    exe: xla::PjRtLoadedExecutable,
    pub n: usize,
    pub window: usize,
}

impl PjrtRuntime {
    /// Create a CPU PJRT client rooted at an artifacts directory.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let client =
            xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client, dir: artifacts_dir.as_ref().to_path_buf() })
    }

    /// Locate the artifacts directory: `$PULSE_ARTIFACTS`, then
    /// `./artifacts`, then `CARGO_MANIFEST_DIR/artifacts`.
    pub fn default_dir() -> PathBuf {
        if let Ok(p) = std::env::var("PULSE_ARTIFACTS") {
            return PathBuf::from(p);
        }
        let local = PathBuf::from("artifacts");
        if local.exists() {
            return local;
        }
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn compile(&self, name: &str) -> Result<xla::PjRtLoadedExecutable> {
        let path = self.dir.join(name);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| {
            format!(
                "parsing {} — run `make artifacts` first",
                path.display()
            )
        })?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))
    }

    /// Load a logic-step artifact (`logic_step.hlo.txt` is batch 32,
    /// `logic_step_b256.hlo.txt` batch 256 — see `aot.py`).
    pub fn load_logic_step(&self, batch: usize) -> Result<LogicStepExe> {
        let name = if batch == 32 {
            "logic_step.hlo.txt".to_string()
        } else {
            format!("logic_step_b{batch}.hlo.txt")
        };
        Ok(LogicStepExe { exe: self.compile(&name)?, batch })
    }

    pub fn load_window_agg(
        &self,
        n: usize,
        window: usize,
    ) -> Result<WindowAggExe> {
        let name = if (n, window) == (4096, 64) {
            "window_agg.hlo.txt".to_string()
        } else {
            format!("window_agg_n{n}_w{window}.hlo.txt")
        };
        Ok(WindowAggExe { exe: self.compile(&name)?, n, window })
    }
}

impl LogicStepExe {
    /// Execute one logic-pipeline pass over up to `batch` workspaces
    /// running the same program (lanes past `ws.len()` are padding).
    ///
    /// Returns per-lane status; workspaces are updated in place —
    /// bit-identical to `interp::logic_pass` (enforced by
    /// `integration_runtime.rs`).
    pub fn run(
        &self,
        program: &Program,
        ws: &mut [Workspace],
    ) -> Result<Vec<Status>> {
        assert!(
            ws.len() <= self.batch,
            "{} workspaces > batch {}",
            ws.len(),
            self.batch
        );
        let (ops, imm) = program.pack();

        let mut regs = vec![0i64; self.batch * NREG];
        let mut sp = vec![0i64; self.batch * SP_WORDS];
        let mut data = vec![0i64; self.batch * DATA_WORDS];
        for (i, w) in ws.iter().enumerate() {
            regs[i * NREG..(i + 1) * NREG].copy_from_slice(&w.regs);
            sp[i * SP_WORDS..(i + 1) * SP_WORDS].copy_from_slice(&w.sp);
            data[i * DATA_WORDS..(i + 1) * DATA_WORDS]
                .copy_from_slice(&w.data);
        }

        let ops_l =
            xla::Literal::vec1(&ops).reshape(&[MAX_INSTRS as i64, 4])?;
        let imm_l = xla::Literal::vec1(&imm);
        let regs_l = xla::Literal::vec1(&regs)
            .reshape(&[self.batch as i64, NREG as i64])?;
        let sp_l = xla::Literal::vec1(&sp)
            .reshape(&[self.batch as i64, SP_WORDS as i64])?;
        let data_l = xla::Literal::vec1(&data)
            .reshape(&[self.batch as i64, DATA_WORDS as i64])?;

        let result = self
            .exe
            .execute::<xla::Literal>(&[ops_l, imm_l, regs_l, sp_l, data_l])?
            [0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True: (regs, sp, data, status,
        // next_ptr).
        let parts = result.to_tuple()?;
        anyhow::ensure!(parts.len() == 5, "expected 5 outputs");
        let regs_out: Vec<i64> = parts[0].to_vec()?;
        let sp_out: Vec<i64> = parts[1].to_vec()?;
        let data_out: Vec<i64> = parts[2].to_vec()?;
        let status_out: Vec<i32> = parts[3].to_vec()?;

        let mut statuses = Vec::with_capacity(ws.len());
        for (i, w) in ws.iter_mut().enumerate() {
            w.regs.copy_from_slice(&regs_out[i * NREG..(i + 1) * NREG]);
            w.sp.copy_from_slice(&sp_out[i * SP_WORDS..(i + 1) * SP_WORDS]);
            w.data.copy_from_slice(
                &data_out[i * DATA_WORDS..(i + 1) * DATA_WORDS],
            );
            statuses.push(Status::from_i32(status_out[i]));
        }
        Ok(statuses)
    }
}

impl WindowAggExe {
    /// Aggregate `values` (len == n) into per-window
    /// (sum, mean, min, max), each of length n/window.
    pub fn run(&self, values: &[f32]) -> Result<WindowAggOut> {
        anyhow::ensure!(
            values.len() == self.n,
            "expected {} values, got {}",
            self.n,
            values.len()
        );
        let v = xla::Literal::vec1(values);
        let result =
            self.exe.execute::<xla::Literal>(&[v])?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        anyhow::ensure!(parts.len() == 4, "expected 4 outputs");
        Ok(WindowAggOut {
            sum: parts[0].to_vec()?,
            mean: parts[1].to_vec()?,
            min: parts[2].to_vec()?,
            max: parts[3].to_vec()?,
        })
    }
}

#[derive(Debug, Clone)]
pub struct WindowAggOut {
    pub sum: Vec<f32>,
    pub mean: Vec<f32>,
    pub min: Vec<f32>,
    pub max: Vec<f32>,
}
