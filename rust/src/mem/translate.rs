//! Hierarchical address translation (paper §4.2 + §5, Fig. 6).
//!
//! * `RangeTable` — the *fine* per-node table realized in TCAM on the
//!   FPGA prototype: (base, len) → local DRAM offset + permissions. The
//!   memory pipeline consults it on every aggregated LOAD; a miss means
//!   "this pointer is not local" and bounces the request to the switch.
//!   Capacity-bounded like real TCAM (prototype uses the Xilinx CAM IP).
//! * `RangeMap` — the *coarse* switch map: range-partitioned VA space →
//!   owning memory node. Only base addresses are kept at the switch to
//!   minimize switch state (paper §5).
//!
//! Both use sorted ranges + binary search (the software analogue of
//! parallel TCAM match).

use super::{GAddr, NodeId};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Perms {
    pub read: bool,
    pub write: bool,
}

impl Perms {
    pub const RW: Perms = Perms { read: true, write: true };
    pub const RO: Perms = Perms { read: true, write: false };
}

#[derive(Debug, Clone)]
struct RangeEntry {
    base: GAddr,
    len: u64,
    local_off: u64,
    perms: Perms,
}

/// Per-node translation + protection table (TCAM model).
#[derive(Debug)]
pub struct RangeTable {
    entries: Vec<RangeEntry>,
    capacity: usize,
    /// Diagnostic counters (Fig. 10 latency path hits).
    pub lookups: u64,
    pub misses: u64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TranslateError {
    /// No covering range: pointer is not on this node (switch bounce).
    NotLocal,
    /// Covering range exists but denies the access (protection fault).
    Protection,
}

impl RangeTable {
    pub fn new(capacity: usize) -> Self {
        Self { entries: Vec::new(), capacity, lookups: 0, misses: 0 }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Install a mapping. Ranges must not overlap (allocator invariant).
    pub fn insert(
        &mut self,
        base: GAddr,
        len: u64,
        local_off: u64,
        perms: Perms,
    ) -> Result<(), &'static str> {
        if self.entries.len() >= self.capacity {
            return Err("TCAM capacity exceeded");
        }
        let idx = self.entries.partition_point(|e| e.base < base);
        if let Some(prev) = idx.checked_sub(1).and_then(|i| self.entries.get(i)) {
            if prev.base + prev.len > base {
                return Err("overlapping range");
            }
        }
        if let Some(next) = self.entries.get(idx) {
            if base + len > next.base {
                return Err("overlapping range");
            }
        }
        self.entries.insert(
            idx,
            RangeEntry { base, len, local_off, perms },
        );
        Ok(())
    }

    /// Translate a global address for an access of `bytes` bytes.
    pub fn translate(
        &mut self,
        addr: GAddr,
        bytes: u64,
        write: bool,
    ) -> Result<u64, TranslateError> {
        self.lookups += 1;
        let idx = self.entries.partition_point(|e| e.base <= addr);
        let Some(e) = idx.checked_sub(1).and_then(|i| self.entries.get(i))
        else {
            self.misses += 1;
            return Err(TranslateError::NotLocal);
        };
        if addr + bytes > e.base + e.len {
            self.misses += 1;
            return Err(TranslateError::NotLocal);
        }
        if (write && !e.perms.write) || (!write && !e.perms.read) {
            return Err(TranslateError::Protection);
        }
        Ok(e.local_off + (addr - e.base))
    }

    pub fn remove(&mut self, base: GAddr) -> bool {
        if let Some(i) = self.entries.iter().position(|e| e.base == base) {
            self.entries.remove(i);
            true
        } else {
            false
        }
    }
}

/// Coarse switch-level map: VA range → owning node.
#[derive(Debug, Default, Clone)]
pub struct RangeMap {
    entries: Vec<(GAddr, u64, NodeId)>,
}

impl RangeMap {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn insert(&mut self, base: GAddr, len: u64, node: NodeId) {
        let idx = self.entries.partition_point(|e| e.0 < base);
        // Coalesce with the previous entry when contiguous + same node —
        // keeps switch state minimal (paper §5: "only the base address to
        // memory node mapping").
        if idx > 0 {
            let (pbase, plen, pnode) = self.entries[idx - 1];
            if pnode == node && pbase + plen == base {
                self.entries[idx - 1].1 += len;
                return;
            }
        }
        self.entries.insert(idx, (base, len, node));
    }

    pub fn lookup(&self, addr: GAddr) -> Option<NodeId> {
        let idx = self.entries.partition_point(|e| e.0 <= addr);
        let (base, len, node) = *idx.checked_sub(1).and_then(|i| self.entries.get(i))?;
        (addr < base + len).then_some(node)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn translate_hit_and_offset() {
        let mut t = RangeTable::new(16);
        t.insert(0x1000, 0x100, 0x8000, Perms::RW).unwrap();
        assert_eq!(t.translate(0x1000, 8, false), Ok(0x8000));
        assert_eq!(t.translate(0x10F8, 8, true), Ok(0x80F8));
    }

    #[test]
    fn translate_miss_is_not_local() {
        let mut t = RangeTable::new(16);
        t.insert(0x1000, 0x100, 0, Perms::RW).unwrap();
        assert_eq!(
            t.translate(0x2000, 8, false),
            Err(TranslateError::NotLocal)
        );
        assert_eq!(
            t.translate(0x10FF, 8, false), // straddles the end
            Err(TranslateError::NotLocal)
        );
        assert_eq!(t.misses, 2);
    }

    #[test]
    fn protection_fault() {
        let mut t = RangeTable::new(16);
        t.insert(0x1000, 0x100, 0, Perms::RO).unwrap();
        assert_eq!(t.translate(0x1000, 8, false), Ok(0));
        assert_eq!(
            t.translate(0x1000, 8, true),
            Err(TranslateError::Protection)
        );
    }

    #[test]
    fn overlap_rejected() {
        let mut t = RangeTable::new(16);
        t.insert(0x1000, 0x100, 0, Perms::RW).unwrap();
        assert!(t.insert(0x1080, 0x100, 0, Perms::RW).is_err());
        assert!(t.insert(0x0F80, 0x100, 0, Perms::RW).is_err());
        // adjacent is fine
        assert!(t.insert(0x1100, 0x100, 0, Perms::RW).is_ok());
    }

    #[test]
    fn capacity_bounded_like_tcam() {
        let mut t = RangeTable::new(2);
        t.insert(0x1000, 8, 0, Perms::RW).unwrap();
        t.insert(0x2000, 8, 8, Perms::RW).unwrap();
        assert!(t.insert(0x3000, 8, 16, Perms::RW).is_err());
        assert!(t.remove(0x1000));
        assert!(t.insert(0x3000, 8, 16, Perms::RW).is_ok());
    }

    #[test]
    fn range_map_routes_and_coalesces() {
        let mut m = RangeMap::new();
        m.insert(0x0000, 0x1000, 0);
        m.insert(0x1000, 0x1000, 0); // coalesces
        m.insert(0x2000, 0x1000, 1);
        assert_eq!(m.len(), 2);
        assert_eq!(m.lookup(0x0500), Some(0));
        assert_eq!(m.lookup(0x1FFF), Some(0));
        assert_eq!(m.lookup(0x2000), Some(1));
        assert_eq!(m.lookup(0x3000), None);
    }
}
