//! Disaggregated memory substrate: per-node DRAM regions, range-based
//! address translation (the accelerator's TCAM, paper §4.2), the coarse
//! switch-level range map (paper §5 hierarchical translation), and the
//! rack allocator with the paper's allocation policies/granularities
//! (§2.1 Fig. 2b, Appendix C.2 "allocation policy").

pub mod alloc;
pub mod region;
pub mod translate;

pub use alloc::{AllocPolicy, RackAllocator};
pub use region::Region;
pub use translate::{Perms, RangeMap, RangeTable};

/// Global virtual address in the rack-wide disaggregated address space.
/// Address 0 is NULL by convention (list terminators etc.).
pub type GAddr = u64;

/// Memory node identifier.
pub type NodeId = u16;

/// First valid virtual address (keeps NULL and low sentinels distinct).
pub const VA_BASE: GAddr = 0x1000_0000;

/// All data-structure nodes are 8 B aligned; the ISA addresses the data
/// window in 8 B words.
pub const WORD: u64 = 8;
