//! Rack-wide allocator over the disaggregated address space.
//!
//! Carves the global VA space into *slabs* of a configurable granularity
//! (the paper studies 2 MB .. 1 GB, §2.1 Fig. 2b) and places each slab on
//! a memory node per policy:
//!
//! * `Contiguous` — range-partition: fill node 0's share, then node 1 …
//!   (the switch map stays tiny; matches the paper's default §5 layout).
//! * `RoundRobin` — uniform interleaving (glibc-like "uniform" policy in
//!   Appendix C.2).
//! * `Random` — random node per slab (the appendix's "random allocation"
//!   that is 3.7–10.8× worse for distributed traversals).
//!
//! Objects are bump-allocated inside the current slab; an allocation
//! never straddles a slab boundary (so a single object is always on one
//! node — pointer *chains*, not objects, cross nodes).

use std::sync::Arc;

use super::translate::{Perms, RangeMap, RangeTable};
use super::{GAddr, NodeId, VA_BASE};
use crate::util::prng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllocPolicy {
    Contiguous,
    RoundRobin,
    Random,
}

#[derive(Debug)]
struct Slab {
    base: GAddr,
    #[allow(dead_code)] // kept for debugging/placement introspection
    node: NodeId,
    used: u64,
}

#[derive(Debug)]
pub struct RackAllocator {
    granularity: u64,
    policy: AllocPolicy,
    nodes: usize,
    node_capacity: u64,
    /// Bytes of slab space handed to each node.
    node_used: Vec<u64>,
    /// Next local DRAM offset per node.
    node_local_off: Vec<u64>,
    current: Option<Slab>,
    /// per-node open slab for app-directed placement (`alloc_on`).
    current_on: Vec<Option<Slab>>,
    next_va: GAddr,
    next_node_rr: usize,
    rng: Rng,
    /// Switch-level coarse map built as slabs are placed.
    pub switch_map: RangeMap,
    /// Cached immutable snapshot of `switch_map` ([`Self::publish_map`]).
    /// Invalidated on slab placement; rebuilt (one clone) per mutation
    /// epoch, then shared by Arc bump with every consumer.
    published_map: Option<Arc<RangeMap>>,
    /// Per-node slab records for installing accelerator TCAM entries.
    pub node_ranges: Vec<Vec<(GAddr, u64, u64)>>,
    pub slabs_allocated: u64,
}

impl RackAllocator {
    pub fn new(
        nodes: usize,
        node_capacity: u64,
        granularity: u64,
        policy: AllocPolicy,
        seed: u64,
    ) -> Self {
        assert!(nodes > 0 && granularity > 0);
        Self {
            granularity,
            policy,
            nodes,
            node_capacity,
            node_used: vec![0; nodes],
            node_local_off: vec![0; nodes],
            current: None,
            current_on: (0..nodes).map(|_| None).collect(),
            next_va: VA_BASE,
            next_node_rr: 0,
            rng: Rng::with_stream(seed, 0x5EED_A110C),
            switch_map: RangeMap::new(),
            published_map: None,
            node_ranges: vec![Vec::new(); nodes],
            slabs_allocated: 0,
        }
    }

    pub fn granularity(&self) -> u64 {
        self.granularity
    }

    pub fn nodes(&self) -> usize {
        self.nodes
    }

    pub fn node_used(&self, node: NodeId) -> u64 {
        self.node_used[node as usize]
    }

    fn pick_node(&mut self) -> NodeId {
        match self.policy {
            AllocPolicy::Contiguous => {
                // first node with spare capacity
                for n in 0..self.nodes {
                    if self.node_used[n] + self.granularity
                        <= self.node_capacity
                    {
                        return n as NodeId;
                    }
                }
                panic!("rack out of memory");
            }
            AllocPolicy::RoundRobin => {
                for _ in 0..self.nodes {
                    let n = self.next_node_rr % self.nodes;
                    self.next_node_rr += 1;
                    if self.node_used[n] + self.granularity
                        <= self.node_capacity
                    {
                        return n as NodeId;
                    }
                }
                panic!("rack out of memory");
            }
            AllocPolicy::Random => {
                for _ in 0..64 {
                    let n = self.rng.below(self.nodes as u64) as usize;
                    if self.node_used[n] + self.granularity
                        <= self.node_capacity
                    {
                        return n as NodeId;
                    }
                }
                // fall back to first-fit
                for n in 0..self.nodes {
                    if self.node_used[n] + self.granularity
                        <= self.node_capacity
                    {
                        return n as NodeId;
                    }
                }
                panic!("rack out of memory");
            }
        }
    }

    fn new_slab(&mut self) -> Slab {
        let node = self.pick_node();
        let base = self.next_va;
        self.next_va += self.granularity;
        let local = self.node_local_off[node as usize];
        self.node_local_off[node as usize] += self.granularity;
        self.node_used[node as usize] += self.granularity;
        self.switch_map.insert(base, self.granularity, node);
        self.published_map = None;
        self.node_ranges[node as usize].push((
            base,
            self.granularity,
            local,
        ));
        self.slabs_allocated += 1;
        Slab { base, node, used: 0 }
    }

    /// Immutable shared snapshot of the coarse switch map. Costs one
    /// `RangeMap` clone per mutation epoch; every further call (switch
    /// republish, live-router construction) is an Arc refcount bump —
    /// snapshot/republish is pointer-swap cheap.
    pub fn publish_map(&mut self) -> Arc<RangeMap> {
        if self.published_map.is_none() {
            self.published_map =
                Some(Arc::new(self.switch_map.clone()));
        }
        Arc::clone(self.published_map.as_ref().unwrap())
    }

    /// Allocate `size` bytes (8 B aligned). Never straddles a slab.
    pub fn alloc(&mut self, size: u64) -> GAddr {
        let size = size.div_ceil(8) * 8;
        assert!(
            size <= self.granularity,
            "object {size} larger than slab {}",
            self.granularity
        );
        let need_new = match &self.current {
            None => true,
            Some(s) => s.used + size > self.granularity,
        };
        if need_new {
            self.current = Some(self.new_slab());
        }
        let s = self.current.as_mut().unwrap();
        let addr = s.base + s.used;
        s.used += size;
        addr
    }

    /// Allocate on a caller-chosen node (app-directed partitioned
    /// allocation, Appendix C.2). Each node keeps its own open slab so
    /// interleaved placements don't leak slab space.
    pub fn alloc_on(&mut self, node: NodeId, size: u64) -> GAddr {
        let size = size.div_ceil(8) * 8;
        assert!(
            size <= self.granularity,
            "object {size} larger than slab {}",
            self.granularity
        );
        let need_new = match &self.current_on[node as usize] {
            Some(s) => s.used + size > self.granularity,
            None => true,
        };
        if need_new {
            assert!(
                self.node_used[node as usize] + self.granularity
                    <= self.node_capacity,
                "node {node} out of memory"
            );
            let base = self.next_va;
            self.next_va += self.granularity;
            let local = self.node_local_off[node as usize];
            self.node_local_off[node as usize] += self.granularity;
            self.node_used[node as usize] += self.granularity;
            self.switch_map.insert(base, self.granularity, node);
            self.published_map = None;
            self.node_ranges[node as usize].push((
                base,
                self.granularity,
                local,
            ));
            self.slabs_allocated += 1;
            self.current_on[node as usize] =
                Some(Slab { base, node, used: 0 });
        }
        let s = self.current_on[node as usize].as_mut().unwrap();
        let addr = s.base + s.used;
        s.used += size;
        addr
    }

    /// Which node owns an address (via the coarse map).
    pub fn owner(&self, addr: GAddr) -> Option<NodeId> {
        self.switch_map.lookup(addr)
    }

    /// Install all placed ranges into per-node TCAM tables.
    pub fn build_node_tables(&self, capacity: usize) -> Vec<RangeTable> {
        (0..self.nodes)
            .map(|n| {
                let mut t = RangeTable::new(capacity);
                for &(base, len, local) in &self.node_ranges[n] {
                    t.insert(base, len, local, Perms::RW)
                        .expect("TCAM capacity too small for workload");
                }
                t
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MB: u64 = 1 << 20;

    #[test]
    fn contiguous_fills_nodes_in_order() {
        let mut a =
            RackAllocator::new(4, 4 * MB, MB, AllocPolicy::Contiguous, 1);
        let mut owners = Vec::new();
        for _ in 0..16 {
            let addr = a.alloc(MB); // one slab per alloc
            owners.push(a.owner(addr).unwrap());
        }
        assert_eq!(owners[..4], [0, 0, 0, 0]);
        assert_eq!(owners[4..8], [1, 1, 1, 1]);
        assert_eq!(owners[12..16], [3, 3, 3, 3]);
    }

    #[test]
    fn round_robin_interleaves() {
        let mut a =
            RackAllocator::new(4, 64 * MB, MB, AllocPolicy::RoundRobin, 1);
        let owners: Vec<_> = (0..8)
            .map(|_| {
                let addr = a.alloc(MB);
                a.owner(addr).unwrap()
            })
            .collect();
        assert_eq!(owners, [0, 1, 2, 3, 0, 1, 2, 3]);
    }

    #[test]
    fn random_spreads() {
        let mut a =
            RackAllocator::new(4, 1024 * MB, MB, AllocPolicy::Random, 42);
        let mut counts = [0usize; 4];
        for _ in 0..200 {
            let addr = a.alloc(MB);
            counts[a.owner(addr).unwrap() as usize] += 1;
        }
        for &c in &counts {
            assert!(c > 20, "skewed random placement {counts:?}");
        }
    }

    #[test]
    fn objects_do_not_straddle_slabs() {
        let mut a =
            RackAllocator::new(2, 16 * MB, MB, AllocPolicy::RoundRobin, 1);
        let mut last_slab = u64::MAX;
        for _ in 0..5000 {
            let addr = a.alloc(612); // odd size, 8B-rounded
            let slab = (addr - VA_BASE) / MB;
            let end_slab = (addr - VA_BASE + 616 - 1) / MB;
            assert_eq!(slab, end_slab, "object straddles slab");
            last_slab = last_slab.min(slab);
        }
    }

    #[test]
    fn alignment_is_8b() {
        let mut a =
            RackAllocator::new(1, 16 * MB, MB, AllocPolicy::Contiguous, 1);
        for sz in [1u64, 7, 8, 9, 24, 100] {
            let addr = a.alloc(sz);
            assert_eq!(addr % 8, 0, "size {sz} gave unaligned {addr:#x}");
        }
    }

    #[test]
    fn alloc_on_places_on_requested_node() {
        let mut a =
            RackAllocator::new(4, 64 * MB, MB, AllocPolicy::Contiguous, 1);
        for node in [2u16, 0, 3, 1] {
            let addr = a.alloc_on(node, 128);
            assert_eq!(a.owner(addr), Some(node));
        }
    }

    #[test]
    fn node_tables_translate_allocated_addrs() {
        let mut a =
            RackAllocator::new(2, 16 * MB, MB, AllocPolicy::RoundRobin, 1);
        let addrs: Vec<_> = (0..100).map(|_| a.alloc(4096)).collect();
        let mut tables = a.build_node_tables(1024);
        for addr in addrs {
            let node = a.owner(addr).unwrap() as usize;
            assert!(tables[node].translate(addr, 8, true).is_ok());
            let other = 1 - node;
            assert!(tables[other].translate(addr, 8, false).is_err());
        }
    }

    /// The published snapshot is shared, not recloned: stable across
    /// calls within one mutation epoch, replaced after a new slab.
    #[test]
    fn publish_map_shares_one_snapshot_per_epoch() {
        let mut a =
            RackAllocator::new(2, 16 * MB, MB, AllocPolicy::RoundRobin, 1);
        let addr = a.alloc(64);
        let m1 = a.publish_map();
        let m2 = a.publish_map();
        assert!(Arc::ptr_eq(&m1, &m2));
        assert_eq!(m1.lookup(addr), a.owner(addr));
        // same slab: no new placement, snapshot stays valid
        let _ = a.alloc(64);
        assert!(Arc::ptr_eq(&m1, &a.publish_map()));
        // force a fresh slab: snapshot must be rebuilt and see it
        let grown = a.alloc(MB);
        let m3 = a.publish_map();
        assert!(!Arc::ptr_eq(&m1, &m3));
        assert_eq!(m3.lookup(grown), a.owner(grown));
        assert_eq!(m1.lookup(grown), None, "old snapshot stays stale");
    }

    #[test]
    #[should_panic(expected = "out of memory")]
    fn capacity_exhaustion_panics() {
        let mut a =
            RackAllocator::new(1, 2 * MB, MB, AllocPolicy::Contiguous, 1);
        for _ in 0..3 {
            a.alloc(MB);
        }
    }
}
