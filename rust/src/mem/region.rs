//! A memory node's DRAM: flat byte region with word accessors and
//! bandwidth counters.
//!
//! The region is allocated lazily (grows in 2 MB steps up to capacity) so
//! tests can declare large node capacities without committing RSS.

use super::WORD;

#[derive(Debug)]
pub struct Region {
    bytes: Vec<u8>,
    capacity: usize,
    /// Bandwidth accounting (Appendix C.1 utilization figures).
    pub bytes_read: u64,
    pub bytes_written: u64,
}

const GROW_STEP: usize = 2 << 20;

impl Region {
    pub fn new(capacity: usize) -> Self {
        Self { bytes: Vec::new(), capacity, bytes_read: 0, bytes_written: 0 }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn committed(&self) -> usize {
        self.bytes.len()
    }

    fn ensure(&mut self, end: usize) {
        assert!(
            end <= self.capacity,
            "region access at {end} beyond capacity {}",
            self.capacity
        );
        if self.bytes.len() < end {
            let new_len = end.div_ceil(GROW_STEP) * GROW_STEP;
            self.bytes.resize(new_len.min(self.capacity), 0);
        }
    }

    /// Read `n_words` 8 B words at byte offset `off` into `out`.
    pub fn read_words(&mut self, off: u64, out: &mut [i64]) {
        let start = off as usize;
        let end = start + out.len() * WORD as usize;
        self.ensure(end);
        for (i, w) in out.iter_mut().enumerate() {
            let p = start + i * WORD as usize;
            *w = i64::from_le_bytes(
                self.bytes[p..p + 8].try_into().unwrap(),
            );
        }
        self.bytes_read += (end - start) as u64;
    }

    pub fn write_words(&mut self, off: u64, words: &[i64]) {
        let start = off as usize;
        let end = start + words.len() * WORD as usize;
        self.ensure(end);
        for (i, w) in words.iter().enumerate() {
            let p = start + i * WORD as usize;
            self.bytes[p..p + 8].copy_from_slice(&w.to_le_bytes());
        }
        self.bytes_written += (end - start) as u64;
    }

    pub fn read_bytes(&mut self, off: u64, out: &mut [u8]) {
        let start = off as usize;
        let end = start + out.len();
        self.ensure(end);
        out.copy_from_slice(&self.bytes[start..end]);
        self.bytes_read += out.len() as u64;
    }

    pub fn write_bytes(&mut self, off: u64, data: &[u8]) {
        let start = off as usize;
        let end = start + data.len();
        self.ensure(end);
        self.bytes[start..end].copy_from_slice(data);
        self.bytes_written += data.len() as u64;
    }

    pub fn read_u64(&mut self, off: u64) -> u64 {
        let mut w = [0i64; 1];
        self.read_words(off, &mut w);
        w[0] as u64
    }

    pub fn write_u64(&mut self, off: u64, v: u64) {
        self.write_words(off, &[v as i64]);
    }

    pub fn reset_counters(&mut self) {
        self.bytes_read = 0;
        self.bytes_written = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn word_round_trip() {
        let mut r = Region::new(1 << 20);
        r.write_words(64, &[1, -2, i64::MAX]);
        let mut out = [0i64; 3];
        r.read_words(64, &mut out);
        assert_eq!(out, [1, -2, i64::MAX]);
    }

    #[test]
    fn lazy_growth() {
        let mut r = Region::new(64 << 20);
        assert_eq!(r.committed(), 0);
        r.write_u64(0, 42);
        assert!(r.committed() >= 8);
        assert!(r.committed() < 64 << 20);
    }

    #[test]
    #[should_panic(expected = "beyond capacity")]
    fn capacity_enforced() {
        let mut r = Region::new(1024);
        r.write_u64(1024, 1);
    }

    #[test]
    fn counters_track_traffic() {
        let mut r = Region::new(1 << 20);
        r.write_words(0, &[0; 32]);
        let mut buf = [0i64; 32];
        r.read_words(0, &mut buf);
        assert_eq!(r.bytes_written, 256);
        assert_eq!(r.bytes_read, 256);
        r.reset_counters();
        assert_eq!(r.bytes_read, 0);
    }

    #[test]
    fn bytes_and_words_interoperate() {
        let mut r = Region::new(4096);
        r.write_bytes(8, &0x1122334455667788u64.to_le_bytes());
        assert_eq!(r.read_u64(8), 0x1122334455667788);
    }
}
