//! Network load generator + client library for the wire protocol.
//!
//! Three layers, each reusable on its own:
//!
//! * [`WireClient`] — one blocking connection: frame out, frame in,
//!   program registration. The byte-level hardening tests drive it
//!   raw ([`WireClient::send_raw`]).
//! * [`OpDriver`] — the CPU-node library role from the paper: an
//!   application [`Op`] is a *stage chain*, and chaining is client
//!   work — resolve a stage against the previous scratchpad, ship one
//!   traversal, decide repeat/next-stage/finish from the response.
//!   It calls the very same [`Stage::resolve`] / [`Stage::wants_repeat`]
//!   the in-process executors use, so a wire-served op stream produces
//!   bit-identical scratchpads to `LiveBackend::serve` (the
//!   `integration_srv` conformance tests pin this).
//! * [`run_loadgen`] — N connections × pipeline depth over a
//!   materialized op stream, closed-loop (a completion funds the next
//!   launch) or open-loop (launches paced at a target rate regardless
//!   of completions), reporting wall ops/s, client-observed latency
//!   percentiles, and BUSY/error counts.
//!
//! The generator never builds data structures itself: the caller
//! materializes ops against a *shadow rack* constructed with the same
//! `RackConfig` + seed + workload spec as the server's, which yields
//! the same deterministic layout and therefore valid start pointers —
//! the same build-once/agree-on-seed contract every conformance suite
//! in this repo relies on.

use std::collections::HashMap;
use std::io::{self, BufReader, Write};
use std::net::TcpStream;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::compiler::CompiledIter;
use crate::isa::{Program, ProgramId, Status, SP_WORDS};
use crate::mem::GAddr;
use crate::rack::Op;
use crate::util::hist::Histogram;
use crate::util::json::Json;

use super::wire::{
    decode_payload, encode_frame_into, read_frame_into, Envelope, Frame,
    FrameEvent, RespTiming, DEFAULT_MAX_FRAME, REGISTER_FLAG_TIMING,
};

// ---------------------------------------------------------------------
// WireClient: one blocking connection.
// ---------------------------------------------------------------------

/// Sending half of a connection (cloneable via `try_clone` on the
/// underlying socket; a whole frame is written with one `write_all`,
/// so two senders behind a mutex never interleave bytes). Each sender
/// owns a reusable encode buffer (clear-don't-free), so the
/// steady-state request path allocates nothing.
pub struct WireSender {
    w: TcpStream,
    buf: Vec<u8>,
}

impl WireSender {
    pub fn send(&mut self, seq: u64, frame: &Frame) -> io::Result<()> {
        self.buf.clear();
        encode_frame_into(seq, frame, &mut self.buf);
        self.w.write_all(&self.buf)
    }

    pub fn send_raw(&mut self, bytes: &[u8]) -> io::Result<()> {
        self.w.write_all(bytes)
    }
}

/// One blocking client connection.
pub struct WireClient {
    r: BufReader<TcpStream>,
    /// Reused receive-payload scratch (capacity settles at the largest
    /// frame the server sends and stays there).
    rbuf: Vec<u8>,
    w: WireSender,
    max_frame: u32,
    next_seq: u64,
}

impl WireClient {
    pub fn connect<A: std::net::ToSocketAddrs>(
        addr: A,
    ) -> io::Result<Self> {
        let s = TcpStream::connect(addr)?;
        let _ = s.set_nodelay(true);
        Ok(Self {
            r: BufReader::new(s.try_clone()?),
            rbuf: Vec::new(),
            w: WireSender { w: s, buf: Vec::new() },
            max_frame: DEFAULT_MAX_FRAME,
            next_seq: 1,
        })
    }

    /// [`WireClient::connect`] with bounded retry on the transient
    /// refusals a connection storm produces: hundreds of simultaneous
    /// SYNs against a freshly bound listener overflow its accept
    /// backlog, and the kernel answers RST/refused for connections the
    /// server would happily serve a few milliseconds later. Retries
    /// only the storm-shaped errors (refused / reset / timed out /
    /// ephemeral-port exhaustion) with linear backoff; anything else —
    /// unroutable address, permission — fails immediately.
    pub fn connect_retry<A: std::net::ToSocketAddrs>(
        addr: A,
        attempts: u32,
    ) -> io::Result<Self> {
        let mut tries = 0;
        loop {
            match Self::connect(&addr) {
                Ok(c) => return Ok(c),
                Err(e) => {
                    tries += 1;
                    let transient = matches!(
                        e.kind(),
                        io::ErrorKind::ConnectionRefused
                            | io::ErrorKind::ConnectionReset
                            | io::ErrorKind::TimedOut
                            | io::ErrorKind::AddrNotAvailable
                    );
                    if !transient || tries >= attempts.max(1) {
                        return Err(e);
                    }
                    std::thread::sleep(Duration::from_millis(
                        (5 * tries as u64).min(100),
                    ));
                }
            }
        }
    }

    /// Fresh per-connection sequence number.
    pub fn next_seq(&mut self) -> u64 {
        let s = self.next_seq;
        self.next_seq += 1;
        s
    }

    pub fn send(&mut self, seq: u64, frame: &Frame) -> io::Result<()> {
        self.w.send(seq, frame)
    }

    /// Raw bytes straight onto the stream (corruption tests).
    pub fn send_raw(&mut self, bytes: &[u8]) -> io::Result<()> {
        self.w.send_raw(bytes)
    }

    /// A second sending half for the open-loop split (receiver thread
    /// keeps `self`, pacer thread sends through the clone).
    pub fn sender(&self) -> io::Result<WireSender> {
        Ok(WireSender { w: self.w.w.try_clone()?, buf: Vec::new() })
    }

    /// Receive one frame. `Ok(None)` is a clean EOF at a frame
    /// boundary; an undecodable or unframeable payload maps to
    /// `InvalidData` (clients talk to one trusted server — there is
    /// nothing useful to salvage from a corrupt downstream frame).
    pub fn recv(&mut self) -> io::Result<Option<Envelope>> {
        loop {
            return match read_frame_into(
                &mut self.r,
                self.max_frame,
                &mut self.rbuf,
            ) {
                FrameEvent::Frame => decode_payload(&self.rbuf)
                    .map(Some)
                    .map_err(|e| {
                        io::Error::new(
                            io::ErrorKind::InvalidData,
                            format!(
                                "bad frame from server: {:?}",
                                e.kind
                            ),
                        )
                    }),
                FrameEvent::Eof => Ok(None),
                // only reachable with a read timeout configured on
                // the socket: idle at a frame boundary, keep waiting
                FrameEvent::Idle => continue,
                FrameEvent::Oversize(n) => Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("unframeable length {n} from server"),
                )),
                FrameEvent::Io(e) => Err(e),
            };
        }
    }

    /// Install a program under `id` and wait for the acknowledgement.
    pub fn register(
        &mut self,
        id: u32,
        program: &Program,
    ) -> io::Result<()> {
        self.register_opts(id, program, false)
    }

    /// [`WireClient::register`] with the latency-attribution opt-in.
    /// The timing flag rides the high bit of the REGISTER id; a server
    /// that understands it masks the bit and echoes the bare id back,
    /// while a server that predates it echoes the flagged value
    /// verbatim — so a flagged echo means "unsupported" and the
    /// negotiation fails loudly instead of silently measuring nothing.
    pub fn register_opts(
        &mut self,
        id: u32,
        program: &Program,
        timing: bool,
    ) -> io::Result<()> {
        let wire_id =
            if timing { id | REGISTER_FLAG_TIMING } else { id };
        let seq = self.next_seq();
        self.send(
            seq,
            &Frame::Register { id: wire_id, program: program.clone() },
        )?;
        match self.recv()? {
            Some(Envelope {
                frame: Frame::RegisterOk { id: got }, ..
            }) if got == id => Ok(()),
            Some(Envelope {
                frame: Frame::RegisterOk { id: got }, ..
            }) if timing && got == wire_id => Err(io::Error::new(
                io::ErrorKind::Unsupported,
                "server echoed the timing flag: \
                 latency attribution not supported",
            )),
            Some(Envelope { frame: Frame::Error { code, msg }, .. }) => {
                Err(io::Error::new(
                    io::ErrorKind::InvalidInput,
                    format!("register rejected ({code:?}): {msg}"),
                ))
            }
            other => Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("unexpected register reply: {other:?}"),
            )),
        }
    }
}

/// Poll a live server's metrics registry: one connection, one STATS
/// frame, one STATS_OK back. Returns the parsed snapshot object (flat
/// map of metric name → number). Frames that are not the answer to our
/// sequence number (there should be none on a dedicated connection,
/// but the protocol does not forbid them) are skipped.
pub fn fetch_stats<A: std::net::ToSocketAddrs>(
    addr: A,
) -> io::Result<Json> {
    let mut client = WireClient::connect(addr)?;
    let seq = client.next_seq();
    client.send(seq, &Frame::Stats)?;
    loop {
        match client.recv()? {
            Some(Envelope {
                seq: got,
                frame: Frame::StatsOk { body },
            }) if got == seq => {
                return Json::parse(&body).map_err(|e| {
                    io::Error::new(
                        io::ErrorKind::InvalidData,
                        format!("stats snapshot is not valid json: {e}"),
                    )
                });
            }
            Some(Envelope {
                frame: Frame::Error { code, msg }, ..
            }) => {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("stats rejected ({code:?}): {msg}"),
                ));
            }
            Some(_) => continue,
            None => {
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "server closed before answering STATS",
                ));
            }
        }
    }
}

// ---------------------------------------------------------------------
// OpDriver: client-side stage chaining.
// ---------------------------------------------------------------------

/// Client-side execution state of one application op. Mirrors
/// `Rack::run_op_functional` / the live coordinator stage machine:
/// degenerate stages (resolved start 0) are skipped without a network
/// round trip, `repeat_while` re-issues a stage from its continuation
/// scratchpad, and a trap is terminal for the whole op.
pub struct OpDriver {
    op: Op,
    stage_idx: usize,
    prev_sp: [i64; SP_WORDS],
    repeat_from: Option<[i64; SP_WORDS]>,
    done: bool,
    trapped: bool,
    final_sp: [i64; SP_WORDS],
}

impl OpDriver {
    pub fn new(op: Op) -> Self {
        // mirror admission-time validation: a malformed op traps
        // client-side with a zero scratchpad, exactly as
        // `ServeReport::record_admission_trap` accounts it in-process
        let malformed = op.validate().is_err();
        Self {
            op,
            stage_idx: 0,
            prev_sp: [0i64; SP_WORDS],
            repeat_from: None,
            done: malformed,
            trapped: malformed,
            final_sp: [0i64; SP_WORDS],
        }
    }

    /// The next traversal to put on the wire, or `None` once the op is
    /// complete (check [`OpDriver::final_sp`]). Degenerate stages are
    /// consumed here without producing a request.
    pub fn next_request(
        &mut self,
    ) -> Option<(Arc<CompiledIter>, GAddr, [i64; SP_WORDS])> {
        if self.done {
            return None;
        }
        loop {
            if self.stage_idx >= self.op.stages.len() {
                self.final_sp = self.prev_sp;
                self.done = true;
                return None;
            }
            let stage = &self.op.stages[self.stage_idx];
            let repeat = self.repeat_from.take();
            let (start, sp) = stage.resolve(&self.prev_sp, repeat);
            if start == 0 {
                // degenerate: skip forward, exactly like the executors
                self.prev_sp = sp;
                self.stage_idx += 1;
                continue;
            }
            return Some((stage.iter.clone(), start, sp));
        }
    }

    /// Feed the response of the traversal the last
    /// [`OpDriver::next_request`] produced.
    pub fn on_response(&mut self, status: Status, sp: [i64; SP_WORDS]) {
        if self.done {
            return;
        }
        if status == Status::Trap {
            self.final_sp = sp;
            self.trapped = true;
            self.done = true;
            return;
        }
        let stage = &self.op.stages[self.stage_idx];
        if stage.wants_repeat(&sp) {
            self.repeat_from = Some(sp);
        } else {
            self.prev_sp = sp;
            self.stage_idx += 1;
        }
    }

    pub fn is_done(&self) -> bool {
        self.done
    }

    pub fn trapped(&self) -> bool {
        self.trapped
    }

    pub fn final_sp(&self) -> [i64; SP_WORDS] {
        self.final_sp
    }
}

// ---------------------------------------------------------------------
// Load generator.
// ---------------------------------------------------------------------

/// Load-generator parameters.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    pub addr: String,
    pub conns: usize,
    /// Closed-loop pipeline depth per connection (in-flight ops).
    pub depth: usize,
    /// Open-loop total launch rate, ops/s across all connections;
    /// 0 = closed loop.
    pub open_rate: f64,
    /// Per-request iteration budget; 0 = server default.
    pub budget: u32,
    /// Capture every op's final scratchpad (conformance tests).
    pub record_results: bool,
    /// Negotiate per-request latency attribution: RESPONSE frames grow
    /// the fixed-width timing block and the report gains the
    /// network+queueing residue (client RTT − server time).
    pub attribution: bool,
    /// JSONL sink for per-request slow-op records (implies
    /// `attribution`): each row joins the client seq + RTT with the
    /// server's phase breakdown and the PR 7 trace op id.
    pub slow_op_log: Option<String>,
    /// Threshold (µs of client RTT) above which a request is logged
    /// to `slow_op_log`; 0 logs every request.
    pub slow_op_us: u64,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7311".into(),
            conns: 4,
            depth: 16,
            open_rate: 0.0,
            budget: 0,
            record_results: false,
            attribution: false,
            slow_op_log: None,
            slow_op_us: 1000,
        }
    }
}

/// Aggregated client-side view of one load-generation run.
#[derive(Debug, Default)]
pub struct LoadReport {
    /// Ops handed to the generator.
    pub ops: u64,
    /// Ops that were actually launched (== `ops` on a clean run).
    pub launched: u64,
    pub completed: u64,
    /// Completed ops whose traversal trapped.
    pub trapped: u64,
    /// Ops aborted by a BUSY answer.
    pub busy: u64,
    /// Ops lost to ERROR frames / protocol violations / dead conns.
    pub errors: u64,
    pub wall_s: f64,
    pub ops_per_s: f64,
    /// Client-observed per-op latency (first request → op complete).
    pub latency: Histogram,
    /// Requests that came back with a server timing block.
    pub timed: u64,
    /// Per-request network+queueing residue: client RTT minus the
    /// server's own decode→encode time (attribution runs only).
    pub residue: Histogram,
    /// Final scratchpads by original op index (only with
    /// `record_results`; `None` for ops that did not complete).
    pub results: Vec<Option<[i64; SP_WORDS]>>,
}

impl LoadReport {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("ops", self.ops)
            .set("launched", self.launched)
            .set("completed", self.completed)
            .set("trapped", self.trapped)
            .set("busy", self.busy)
            .set("errors", self.errors)
            .set("wall_s", self.wall_s)
            .set("ops_per_s", self.ops_per_s)
            .set("p50_ns", self.latency.p50())
            .set("p95_ns", self.latency.p95())
            .set("p99_ns", self.latency.p99())
            .set("mean_ns", self.latency.mean());
        if self.timed > 0 {
            j.set("timed_ops", self.timed)
                .set("residue_p50_ns", self.residue.p50())
                .set("residue_p99_ns", self.residue.p99())
                .set("residue_mean_ns", self.residue.mean());
        }
        j
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "ops={} completed={} trapped={} busy={} errors={}\n\
             wall={:.3}s throughput={:.0} ops/s\n\
             client latency: p50={:.1}us p95={:.1}us p99={:.1}us \
             mean={:.1}us",
            self.ops,
            self.completed,
            self.trapped,
            self.busy,
            self.errors,
            self.wall_s,
            self.ops_per_s,
            self.latency.p50() as f64 / 1e3,
            self.latency.p95() as f64 / 1e3,
            self.latency.p99() as f64 / 1e3,
            self.latency.mean() / 1e3,
        );
        if self.timed > 0 {
            s.push_str(&format!(
                "\nattributed requests={} network+queueing residue: \
                 p50={:.1}us p99={:.1}us mean={:.1}us",
                self.timed,
                self.residue.p50() as f64 / 1e3,
                self.residue.p99() as f64 / 1e3,
                self.residue.mean() / 1e3,
            ));
        }
        s
    }
}

/// Anything that can put a frame on the wire (direct sender, or a
/// mutex-shared one in open-loop mode).
trait FrameSink {
    fn put(&mut self, seq: u64, frame: &Frame) -> io::Result<()>;
}

impl FrameSink for WireSender {
    fn put(&mut self, seq: u64, frame: &Frame) -> io::Result<()> {
        self.send(seq, frame)
    }
}

impl FrameSink for &Mutex<WireSender> {
    fn put(&mut self, seq: u64, frame: &Frame) -> io::Result<()> {
        self.lock().unwrap().send(seq, frame)
    }
}

/// Shared slow-request JSONL sink: one file, one mutex, rows from
/// every connection. Each row is one request that crossed the RTT
/// threshold, joining the client-side view (seq, op index, RTT,
/// residue) with the server's wire-propagated phase breakdown and the
/// trace op id (joinable against the PR 7 trace JSONL).
struct SlowLog {
    threshold_ns: u64,
    sink: Mutex<std::io::BufWriter<std::fs::File>>,
}

impl SlowLog {
    fn create(path: &str, threshold_us: u64) -> io::Result<SlowLog> {
        Ok(SlowLog {
            threshold_ns: threshold_us.saturating_mul(1000),
            sink: Mutex::new(std::io::BufWriter::new(
                std::fs::File::create(path)?,
            )),
        })
    }

    fn record(
        &self,
        seq: u64,
        op: usize,
        rtt_ns: u64,
        crossings: u32,
        t: &RespTiming,
    ) {
        if rtt_ns < self.threshold_ns {
            return;
        }
        let mut j = Json::obj();
        j.set("seq", seq)
            .set("op", op as u64)
            .set("rtt_ns", rtt_ns)
            .set("server_ns", t.server_ns)
            .set("queue_ns", t.queue_ns)
            .set("exec_ns", t.exec_ns)
            .set("transit_ns", t.transit_ns)
            .set("completion_ns", t.completion_ns)
            .set("visits", t.visits as u64)
            .set("crossings", crossings as u64)
            .set("residue_ns", rtt_ns.saturating_sub(t.server_ns))
            .set("traced", t.traced)
            .set("trace_op", t.op);
        let mut w = self.sink.lock().unwrap();
        let _ = writeln!(w, "{}", j.render());
    }
}

/// Per-connection stats folded into the final report.
#[derive(Debug, Default)]
struct ConnStats {
    launched: u64,
    completed: u64,
    trapped: u64,
    busy: u64,
    errors: u64,
    hist: Histogram,
    timed: u64,
    residue: Histogram,
}

/// One connection's serving state: its slice of the op stream, the
/// in-flight seq → op map, and the per-op drivers.
struct ConnRun {
    work: Vec<(usize, OpDriver)>,
    t0: Vec<Option<Instant>>,
    results: Vec<Option<[i64; SP_WORDS]>>,
    /// seq → (local op index, request send instant): the send stamp
    /// closes the per-request RTT when the response correlates back.
    inflight: HashMap<u64, (usize, Instant)>,
    next: usize,
    seq: u64,
    budget: u32,
    ids: Arc<HashMap<ProgramId, u32>>,
    slow: Option<Arc<SlowLog>>,
    stats: ConnStats,
}

impl ConnRun {
    fn new(
        work: Vec<(usize, OpDriver)>,
        budget: u32,
        ids: Arc<HashMap<ProgramId, u32>>,
        slow: Option<Arc<SlowLog>>,
    ) -> Self {
        let n = work.len();
        Self {
            work,
            t0: vec![None; n],
            results: vec![None; n],
            inflight: HashMap::new(),
            next: 0,
            seq: 1,
            budget,
            ids,
            slow,
            stats: ConnStats::default(),
        }
    }

    fn all_launched(&self) -> bool {
        self.next >= self.work.len()
    }

    fn finished(&self) -> bool {
        self.all_launched() && self.inflight.is_empty()
    }

    /// Launch the next op (first request, or immediate completion for
    /// an op that resolves degenerately). Returns false when the
    /// stream is exhausted.
    fn launch_next(&mut self, w: &mut impl FrameSink) -> io::Result<bool> {
        if self.all_launched() {
            return Ok(false);
        }
        let li = self.next;
        self.next += 1;
        self.stats.launched += 1;
        self.t0[li] = Some(Instant::now());
        self.pump_op(li, w)?;
        Ok(true)
    }

    /// Send the op's next traversal, or record its completion.
    fn pump_op(
        &mut self,
        li: usize,
        w: &mut impl FrameSink,
    ) -> io::Result<()> {
        let step = self.work[li].1.next_request();
        match step {
            Some((iter, start, sp)) => {
                let seq = self.seq;
                self.seq += 1;
                let prog = *self
                    .ids
                    .get(&iter.program.id())
                    .expect("op stream program was not registered");
                // register BEFORE the write: if the put fails the op
                // is still in `inflight`, so the unconditional
                // abort_inflight sweep folds it into the error count
                // instead of dropping it from every counter
                self.inflight.insert(seq, (li, Instant::now()));
                w.put(
                    seq,
                    &Frame::Request {
                        prog,
                        budget: self.budget,
                        start,
                        sp,
                    },
                )?;
            }
            None => self.complete(li),
        }
        Ok(())
    }

    fn complete(&mut self, li: usize) {
        let d = &self.work[li].1;
        self.stats.completed += 1;
        if d.trapped() {
            self.stats.trapped += 1;
        }
        self.results[li] = Some(d.final_sp());
        if let Some(t0) = self.t0[li] {
            self.stats
                .hist
                .record((t0.elapsed().as_nanos() as u64).max(1));
        }
    }

    /// Feed one server frame; may send a continuation request.
    fn on_envelope(
        &mut self,
        env: Envelope,
        w: &mut impl FrameSink,
    ) -> io::Result<()> {
        match env.frame {
            Frame::Response { status, sp, crossings, timing, .. } => {
                // uncorrelated (duplicate/late) responses are ignored
                // like uncorrelated BUSY/ERROR frames: the error count
                // stays a partition of ops, never of stray frames
                let Some((li, sent_at)) =
                    self.inflight.remove(&env.seq)
                else {
                    return Ok(());
                };
                if let Some(t) = &timing {
                    let rtt = (sent_at.elapsed().as_nanos() as u64)
                        .max(1);
                    self.stats.timed += 1;
                    self.stats.residue.record(
                        rtt.saturating_sub(t.server_ns).max(1),
                    );
                    if let Some(slow) = &self.slow {
                        slow.record(
                            env.seq,
                            self.work[li].0,
                            rtt,
                            crossings,
                            t,
                        );
                    }
                }
                self.work[li].1.on_response(status, sp);
                self.pump_op(li, w)?;
            }
            Frame::Busy => {
                if self.inflight.remove(&env.seq).is_some() {
                    self.stats.busy += 1;
                }
            }
            Frame::Error { .. } => {
                // count as an op error only when it correlates to an
                // in-flight request; connection-level errors (seq 0,
                // pre-disconnect notices) are accounted by the abort
                // sweeps when the connection dies — never both, so
                // completed+busy+errors stays a partition of ops
                if self.inflight.remove(&env.seq).is_some() {
                    self.stats.errors += 1;
                }
            }
            // unexpected server-to-client kinds: not op-correlated;
            // ignore rather than distort the op accounting
            _ => {}
        }
        Ok(())
    }

    /// The connection died with ops still outstanding.
    fn abort_inflight(&mut self) {
        self.stats.errors += self.inflight.len() as u64;
        self.inflight.clear();
    }
}

/// Closed loop: keep `depth` ops in flight; every completion funds the
/// next launch.
fn closed_loop(
    client: &mut WireClient,
    run: &mut ConnRun,
    depth: usize,
) -> io::Result<()> {
    let mut w = client.sender()?;
    loop {
        while run.inflight.len() < depth.max(1)
            && !run.all_launched()
        {
            run.launch_next(&mut w)?;
        }
        if run.finished() {
            return Ok(());
        }
        match client.recv() {
            Ok(Some(env)) => run.on_envelope(env, &mut w)?,
            Ok(None) => {
                run.abort_inflight();
                return Ok(());
            }
            Err(e) => {
                run.abort_inflight();
                return Err(e);
            }
        }
    }
}

/// Open loop: a pacer thread launches ops on a fixed schedule while
/// the receiver processes responses (and sends continuation stages);
/// both write through one mutexed sender, so frames never interleave.
/// Borrows the run so a connection error leaves its partial stats
/// intact for aggregation.
fn open_loop(
    client: &mut WireClient,
    run: &mut ConnRun,
    rate_per_conn: f64,
) -> io::Result<()> {
    let sender = Mutex::new(client.sender()?);
    let state = Mutex::new(run);
    // receiver -> pacer abort: once the connection is dead there is
    // no point pacing the rest of the stream into it
    let stop = std::sync::atomic::AtomicBool::new(false);
    let period = Duration::from_secs_f64(1.0 / rate_per_conn.max(1e-6));
    std::thread::scope(|s| {
        let pacer = s.spawn(|| -> io::Result<()> {
            let start = Instant::now();
            let mut k = 0u32;
            loop {
                let next_at = start + period * k;
                let now = Instant::now();
                if next_at > now {
                    std::thread::sleep(next_at - now);
                }
                if stop.load(std::sync::atomic::Ordering::Relaxed) {
                    return Ok(());
                }
                let (more, fin) = {
                    let mut st = state.lock().unwrap();
                    let more = st.launch_next(&mut &sender)?;
                    (more, st.finished())
                };
                if fin {
                    // the last op resolved without a wire round trip
                    // (degenerate stages): the receiver may be parked
                    // in recv with nothing left to arrive — wake it
                    let _ = sender
                        .lock()
                        .unwrap()
                        .w
                        .shutdown(std::net::Shutdown::Read);
                }
                if !more {
                    return Ok(());
                }
                k += 1;
            }
        });
        // receiver: drain until every launched op resolves
        loop {
            {
                let st = state.lock().unwrap();
                if st.finished() {
                    break;
                }
            }
            match client.recv() {
                Ok(Some(env)) => {
                    let mut st = state.lock().unwrap();
                    st.on_envelope(env, &mut &sender)?;
                }
                Ok(None) => {
                    stop.store(
                        true,
                        std::sync::atomic::Ordering::Relaxed,
                    );
                    state.lock().unwrap().abort_inflight();
                    break;
                }
                Err(e) => {
                    stop.store(
                        true,
                        std::sync::atomic::Ordering::Relaxed,
                    );
                    state.lock().unwrap().abort_inflight();
                    let _ = pacer.join();
                    return Err(e);
                }
            }
        }
        pacer.join().expect("pacer panicked")?;
        Ok(())
    })
}

/// Drive `ops` against a listening server. See the module docs for the
/// shadow-rack contract that makes the op stream's pointers valid.
pub fn run_loadgen(
    cfg: &LoadgenConfig,
    ops: Vec<Op>,
) -> io::Result<LoadReport> {
    let total = ops.len();
    // one registration plan shared by every connection: wire ids in
    // first-appearance order, deterministic across runs
    let mut ids: HashMap<ProgramId, u32> = HashMap::new();
    let mut plan: Vec<(u32, Arc<Program>)> = Vec::new();
    for op in &ops {
        for stage in &op.stages {
            let p = &stage.iter.program;
            if !ids.contains_key(&p.id()) {
                let wire_id = plan.len() as u32;
                ids.insert(p.id(), wire_id);
                plan.push((wire_id, Arc::clone(p)));
            }
        }
    }
    let ids = Arc::new(ids);
    let plan = Arc::new(plan);

    // a slow-op log is meaningless without the wire breakdown, so it
    // implies the negotiation
    let attribution = cfg.attribution || cfg.slow_op_log.is_some();
    let slow: Option<Arc<SlowLog>> = match &cfg.slow_op_log {
        Some(path) => {
            Some(Arc::new(SlowLog::create(path, cfg.slow_op_us)?))
        }
        None => None,
    };

    let conns = cfg.conns.max(1);
    // round-robin split preserves per-connection issue order
    let mut slices: Vec<Vec<(usize, OpDriver)>> =
        (0..conns).map(|_| Vec::new()).collect();
    for (i, op) in ops.into_iter().enumerate() {
        slices[i % conns].push((i, OpDriver::new(op)));
    }

    let wall_start = Instant::now();
    let runs: Vec<ConnRun> = std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(conns);
        for work in slices {
            let ids = Arc::clone(&ids);
            let plan = Arc::clone(&plan);
            let slow = slow.clone();
            let cfg = cfg.clone();
            handles.push(s.spawn(move || -> ConnRun {
                let mut run =
                    ConnRun::new(work, cfg.budget, ids, slow);
                // one dead connection must not discard every other
                // connection's stats: fold its loss into this run's
                // error count and keep aggregating
                let res: io::Result<()> = (|| {
                    // storms of simultaneous connects (the ≥1k-conn
                    // sweeps) overflow the accept backlog; retry the
                    // transient refusals instead of reporting a whole
                    // connection's ops as errors
                    let mut client =
                        WireClient::connect_retry(&cfg.addr, 40)?;
                    for (wire_id, program) in plan.iter() {
                        client.register_opts(
                            *wire_id,
                            program,
                            attribution,
                        )?;
                    }
                    // continue the connection's seq space past the
                    // registration handshakes so request ids can
                    // never overlap them
                    run.seq = client.next_seq();
                    if cfg.open_rate > 0.0 {
                        open_loop(
                            &mut client,
                            &mut run,
                            cfg.open_rate / conns as f64,
                        )
                    } else {
                        closed_loop(&mut client, &mut run, cfg.depth)
                    }
                })();
                if let Err(e) = res {
                    eprintln!(
                        "loadgen: connection died: {e} \
                         (continuing with remaining connections)"
                    );
                }
                // unconditional: anything still in flight once the
                // serving loop is over is lost — including ops the
                // open-loop pacer managed to launch *after* the
                // receiver hit EOF (writes into a dying socket's
                // buffer can still succeed)
                run.abort_inflight();
                // ops this connection never got to launch are lost
                // whether it died with an io error or the server
                // closed the stream cleanly mid-run (EOF) — either
                // way they must show up in the error count, not
                // silently narrow the report
                let unlaunched =
                    (run.work.len() - run.next) as u64;
                if unlaunched > 0 {
                    run.stats.errors += unlaunched;
                    run.next = run.work.len();
                }
                run
            }));
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("loadgen connection panicked"))
            .collect()
    });
    let wall_s = wall_start.elapsed().as_secs_f64();

    let mut report = LoadReport {
        ops: total as u64,
        wall_s,
        results: if cfg.record_results {
            vec![None; total]
        } else {
            Vec::new()
        },
        ..LoadReport::default()
    };
    for run in runs {
        report.launched += run.stats.launched;
        report.completed += run.stats.completed;
        report.trapped += run.stats.trapped;
        report.busy += run.stats.busy;
        report.errors += run.stats.errors;
        report.latency.merge(&run.stats.hist);
        report.timed += run.stats.timed;
        report.residue.merge(&run.stats.residue);
        if cfg.record_results {
            for (li, (gi, _)) in run.work.iter().enumerate() {
                report.results[*gi] = run.results[li];
            }
        }
    }
    if wall_s > 0.0 {
        report.ops_per_s = report.completed as f64 / wall_s;
    }
    if let Some(slow) = &slow {
        slow.sink.lock().unwrap().flush()?;
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ds::SkipList;
    use crate::rack::{Rack, RackConfig};

    /// OpDriver must replay exactly what `run_op_functional` computes,
    /// including multi-stage scans with continuation rounds.
    #[test]
    fn op_driver_matches_functional_execution() {
        let mut rack = Rack::new(RackConfig::small(2));
        let mut sl = SkipList::new(&mut rack, 7);
        for k in 0..400i64 {
            sl.insert(&mut rack, k * 2, k * 11);
        }
        let ops = vec![
            sl.find_op(120),
            sl.find_op(121), // miss
            sl.scan_op(50, 40),
            sl.scan_op(790, 30), // runs off the tail
        ];
        for (i, op) in ops.into_iter().enumerate() {
            let want = rack.run_op_functional(&op);
            let mut d = OpDriver::new(op);
            let mut hops = 0;
            while let Some((iter, start, sp)) = d.next_request() {
                // the "server": one traversal, same substrate
                let (st, out, _) = rack.traverse(&iter, start, sp);
                d.on_response(st, out);
                hops += 1;
                assert!(hops < 1000, "driver failed to converge");
            }
            assert!(d.is_done());
            assert!(!d.trapped(), "op {i} trapped");
            assert_eq!(d.final_sp(), want, "op {i} diverged");
        }
    }

    #[test]
    fn op_driver_trap_is_terminal_and_malformed_ops_trap_locally() {
        let mut rack = Rack::new(RackConfig::small(1));
        let mut sl = SkipList::new(&mut rack, 3);
        for k in 0..50i64 {
            sl.insert(&mut rack, k, k);
        }
        // malformed shape: traps at "admission" without any request
        let mut bad = sl.find_op(1);
        bad.stages[0].repeat_while = Some((99, 2));
        let mut d = OpDriver::new(bad);
        assert!(d.next_request().is_none());
        assert!(d.trapped());
        assert_eq!(d.final_sp(), [0i64; SP_WORDS]);

        // a trapped response ends the op even mid-chain
        let op = sl.scan_op(0, 20);
        let mut d = OpDriver::new(op);
        let (_, _, _) = d.next_request().unwrap();
        let mut sp = [7i64; SP_WORDS];
        sp[0] = 1;
        d.on_response(Status::Trap, sp);
        assert!(d.is_done());
        assert!(d.trapped());
        assert_eq!(d.final_sp(), sp);
        assert!(d.next_request().is_none());
    }
}
