//! Serving-tier metrics: connection, frame, and error counters shared
//! across the accept loop, every reader/writer thread, and the CLI.
//!
//! All counters are relaxed atomics (they are metrics, not
//! synchronization — same discipline as `live::queue`), including the
//! end-to-end latency histogram (frame decoded → response written, the
//! server-side slice of what the client observes): it used to sit
//! behind a global `Mutex` taken once per response, which serialized
//! every writer thread through one lock on the hot path — it is now an
//! [`obs::AtomicHist`](crate::obs::AtomicHist) (same bucket layout,
//! relaxed per-slot atomics).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::obs::{AtomicHist, MetricsRegistry};
use crate::util::json::Json;

#[derive(Debug, Default)]
pub struct SrvMetrics {
    conns_accepted: AtomicU64,
    conns_active: AtomicU64,
    /// Monotonic open/close counters; with `conns_failed` they make
    /// the connection ledger reconcile exactly:
    /// `accepted == opened + failed` and `opened == closed + active`.
    /// (`conns_active` alone cannot distinguish "accepted but never
    /// set up" from "opened and already closed".)
    conns_opened: AtomicU64,
    conns_closed: AtomicU64,
    /// Accepted connections whose per-connection setup failed (fd
    /// clone / registration error) before they were ever opened.
    /// Without this the accept-time bump of `conns_accepted` leaks:
    /// `conn_opened`/`conn_closed` never fire for the failed socket
    /// and the ledger silently drifts.
    conns_failed: AtomicU64,
    frames_in: AtomicU64,
    frames_out: AtomicU64,
    requests: AtomicU64,
    responses: AtomicU64,
    /// BUSY frames sent (inbox-full rejects + dispatcher sheds).
    busy: AtomicU64,
    /// ERROR frames sent.
    errors_sent: AtomicU64,
    /// Frames that failed magic/version/CRC/body checks.
    decode_errors: AtomicU64,
    programs_registered: AtomicU64,
    /// Connections dropped because the client stopped draining its
    /// responses (writer backlog cap exceeded).
    backlog_drops: AtomicU64,
    e2e: AtomicHist,
}

macro_rules! bump {
    ($($fn_name:ident => $field:ident),* $(,)?) => {
        $(pub fn $fn_name(&self) {
            self.$field.fetch_add(1, Ordering::Relaxed);
        })*
    };
}

impl SrvMetrics {
    bump!(
        conn_accepted => conns_accepted,
        frame_in => frames_in,
        frame_out => frames_out,
        request => requests,
        busy => busy,
        error_sent => errors_sent,
        decode_error => decode_errors,
        program_registered => programs_registered,
        backlog_drop => backlog_drops,
    );

    /// Batched sent-side counters: one RMW per writer flush instead
    /// of one per frame.
    pub fn sent_batch(&self, frames: u64, busy: u64, errors: u64) {
        self.frames_out.fetch_add(frames, Ordering::Relaxed);
        self.busy.fetch_add(busy, Ordering::Relaxed);
        self.errors_sent.fetch_add(errors, Ordering::Relaxed);
    }

    pub fn conn_opened(&self) {
        self.conns_opened.fetch_add(1, Ordering::Relaxed);
        self.conns_active.fetch_add(1, Ordering::Relaxed);
    }

    pub fn conn_closed(&self) {
        self.conns_closed.fetch_add(1, Ordering::Relaxed);
        self.conns_active.fetch_sub(1, Ordering::Relaxed);
    }

    /// An accepted connection whose setup failed before it was opened
    /// (e.g. `try_clone` on the fd). Keeps the ledger balanced:
    /// `accepted == opened + failed`.
    pub fn conn_spawn_failed(&self) {
        self.conns_failed.fetch_add(1, Ordering::Relaxed);
    }

    /// One RESPONSE written, with its decode→write latency. Lock-free:
    /// a handful of relaxed RMWs, no cross-thread serialization.
    pub fn response(&self, e2e_ns: u64) {
        self.responses.fetch_add(1, Ordering::Relaxed);
        self.e2e.record(e2e_ns.max(1));
    }

    /// Register every counter as a named gauge (plus the e2e p99) in
    /// `reg`, so the serving tier shows up in registry snapshots —
    /// STATS frames, the periodic sampler — without changing any hot
    /// path: the gauges read the same relaxed atomics on demand.
    pub fn register_into(self: &Arc<Self>, reg: &MetricsRegistry) {
        macro_rules! gauge {
            ($($name:literal => $field:ident),* $(,)?) => {
                $(
                    let m = Arc::clone(self);
                    reg.gauge_fn(concat!("srv.", $name), move || {
                        m.$field.load(Ordering::Relaxed) as f64
                    });
                )*
            };
        }
        gauge!(
            "conns_accepted" => conns_accepted,
            "conns_active" => conns_active,
            "conns_opened" => conns_opened,
            "conns_closed" => conns_closed,
            "conns_failed" => conns_failed,
            "frames_in" => frames_in,
            "frames_out" => frames_out,
            "requests" => requests,
            "responses" => responses,
            "busy" => busy,
            "errors_sent" => errors_sent,
            "decode_errors" => decode_errors,
            "programs_registered" => programs_registered,
            "backlog_drops" => backlog_drops,
        );
        let m = Arc::clone(self);
        reg.gauge_fn("srv.e2e_p99_ns", move || {
            m.e2e.snapshot().p99() as f64
        });
    }

    pub fn snapshot(&self) -> SrvSnapshot {
        let h = self.e2e.snapshot();
        SrvSnapshot {
            conns_accepted: self.conns_accepted.load(Ordering::Relaxed),
            conns_active: self.conns_active.load(Ordering::Relaxed),
            conns_opened: self.conns_opened.load(Ordering::Relaxed),
            conns_closed: self.conns_closed.load(Ordering::Relaxed),
            conns_failed: self.conns_failed.load(Ordering::Relaxed),
            frames_in: self.frames_in.load(Ordering::Relaxed),
            frames_out: self.frames_out.load(Ordering::Relaxed),
            requests: self.requests.load(Ordering::Relaxed),
            responses: self.responses.load(Ordering::Relaxed),
            busy: self.busy.load(Ordering::Relaxed),
            errors_sent: self.errors_sent.load(Ordering::Relaxed),
            decode_errors: self.decode_errors.load(Ordering::Relaxed),
            programs_registered: self
                .programs_registered
                .load(Ordering::Relaxed),
            backlog_drops: self.backlog_drops.load(Ordering::Relaxed),
            e2e_p50_ns: h.p50(),
            e2e_p95_ns: h.p95(),
            e2e_p99_ns: h.p99(),
            e2e_mean_ns: h.mean(),
        }
    }
}

/// Point-in-time view of the serving tier.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SrvSnapshot {
    pub conns_accepted: u64,
    pub conns_active: u64,
    pub conns_opened: u64,
    pub conns_closed: u64,
    pub conns_failed: u64,
    pub frames_in: u64,
    pub frames_out: u64,
    pub requests: u64,
    pub responses: u64,
    pub busy: u64,
    pub errors_sent: u64,
    pub decode_errors: u64,
    pub programs_registered: u64,
    pub backlog_drops: u64,
    pub e2e_p50_ns: u64,
    pub e2e_p95_ns: u64,
    pub e2e_p99_ns: u64,
    pub e2e_mean_ns: f64,
}

impl SrvSnapshot {
    /// Human-readable summary for the CLI metrics table.
    pub fn summary(&self) -> String {
        format!(
            "conns: accepted={} active={} opened={} closed={} \
             failed={}\n\
             frames: in={} out={} decode-errors={}\n\
             requests={} responses={} busy={} errors={} \
             backlog-drops={}\n\
             server e2e: p50={:.1}us p95={:.1}us p99={:.1}us \
             mean={:.1}us",
            self.conns_accepted,
            self.conns_active,
            self.conns_opened,
            self.conns_closed,
            self.conns_failed,
            self.frames_in,
            self.frames_out,
            self.decode_errors,
            self.requests,
            self.responses,
            self.busy,
            self.errors_sent,
            self.backlog_drops,
            self.e2e_p50_ns as f64 / 1e3,
            self.e2e_p95_ns as f64 / 1e3,
            self.e2e_p99_ns as f64 / 1e3,
            self.e2e_mean_ns / 1e3,
        )
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("conns_accepted", self.conns_accepted)
            .set("conns_active", self.conns_active)
            .set("conns_opened", self.conns_opened)
            .set("conns_closed", self.conns_closed)
            .set("conns_failed", self.conns_failed)
            .set("frames_in", self.frames_in)
            .set("frames_out", self.frames_out)
            .set("requests", self.requests)
            .set("responses", self.responses)
            .set("busy", self.busy)
            .set("errors_sent", self.errors_sent)
            .set("decode_errors", self.decode_errors)
            .set("programs_registered", self.programs_registered)
            .set("backlog_drops", self.backlog_drops)
            .set("e2e_p50_ns", self.e2e_p50_ns)
            .set("e2e_p95_ns", self.e2e_p95_ns)
            .set("e2e_p99_ns", self.e2e_p99_ns)
            .set("e2e_mean_ns", self.e2e_mean_ns);
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_latency_aggregate() {
        let m = SrvMetrics::default();
        m.conn_accepted();
        m.conn_opened();
        m.frame_in();
        m.request();
        m.response(2_000);
        m.response(4_000);
        m.frame_out();
        m.busy();
        m.decode_error();
        m.conn_closed();
        let s = m.snapshot();
        assert_eq!(s.conns_accepted, 1);
        assert_eq!(s.conns_active, 0);
        assert_eq!(s.responses, 2);
        assert_eq!(s.busy, 1);
        assert_eq!(s.decode_errors, 1);
        assert!(s.e2e_mean_ns > 0.0);
        // renders without panicking
        let _ = s.summary();
        let _ = s.to_json().render();
    }

    #[test]
    fn connection_ledger_reconciles_with_spawn_failures() {
        // the accept loop bumps conns_accepted before per-connection
        // setup can still fail; only an explicit failure counter keeps
        // accepted == opened + failed (and opened == closed + active)
        // true — the invariant the serving tier's teardown asserts
        let m = Arc::new(SrvMetrics::default());
        for _ in 0..5 {
            m.conn_accepted();
        }
        m.conn_opened(); // conn 1: opened, still active
        m.conn_opened(); // conn 2: opened then closed
        m.conn_closed();
        m.conn_spawn_failed(); // conn 3: setup failed post-accept
        m.conn_opened(); // conn 4: opened then closed
        m.conn_closed();
        m.conn_spawn_failed(); // conn 5: setup failed post-accept
        let s = m.snapshot();
        assert_eq!(s.conns_accepted, 5);
        assert_eq!(s.conns_opened, 3);
        assert_eq!(s.conns_closed, 2);
        assert_eq!(s.conns_failed, 2);
        assert_eq!(s.conns_active, 1);
        assert_eq!(
            s.conns_accepted,
            s.conns_opened + s.conns_failed,
            "accept-side ledger drifted"
        );
        assert_eq!(
            s.conns_opened,
            s.conns_closed + s.conns_active,
            "open-side ledger drifted"
        );
        // the registry view carries the same ledger
        let reg = MetricsRegistry::new();
        m.register_into(&reg);
        let snap = reg.snapshot();
        let get = |k: &str| {
            snap.get(k).and_then(|v| v.as_f64()).unwrap_or(-1.0)
        };
        assert_eq!(
            get("srv.conns_accepted"),
            get("srv.conns_opened") + get("srv.conns_failed"),
        );
        assert_eq!(
            get("srv.conns_opened"),
            get("srv.conns_closed") + get("srv.conns_active"),
        );
    }

    #[test]
    fn responses_record_concurrently_without_a_lock() {
        let m = Arc::new(SrvMetrics::default());
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let m = m.clone();
                s.spawn(move || {
                    for i in 0..2_000u64 {
                        m.response(t * 10_000 + i + 1);
                    }
                });
            }
        });
        let s = m.snapshot();
        assert_eq!(s.responses, 8_000);
        assert!(s.e2e_p99_ns > 0);
    }

    #[test]
    fn registers_gauges_into_a_registry() {
        let m = Arc::new(SrvMetrics::default());
        let reg = MetricsRegistry::new();
        m.register_into(&reg);
        m.request();
        m.request();
        m.response(1_000);
        m.busy();
        let snap = reg.snapshot();
        let get = |k: &str| {
            snap.get(k).and_then(|v| v.as_f64()).unwrap_or(-1.0)
        };
        assert_eq!(get("srv.requests"), 2.0);
        assert_eq!(get("srv.responses"), 1.0);
        assert_eq!(get("srv.busy"), 1.0);
        assert!(get("srv.e2e_p99_ns") >= 1.0);
    }
}
