//! The PULSE wire protocol: versioned, length-prefixed, CRC-protected
//! binary frames over a byte stream.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! frame    := len:u32 payload[len]          len excludes itself
//! payload  := header body crc:u32           crc over header+body
//! header   := magic:u32 version:u8 kind:u8 pad:u16 seq:u64   (16 B)
//! ```
//!
//! `seq` is the per-connection request id; responses echo it, which is
//! what makes pipelining work (many in-flight ids per connection,
//! completions in any order). Bodies by kind:
//!
//! ```text
//! REGISTER     prog_id:u32 program            Program::encode bytes
//! REGISTER_OK  prog_id:u32
//! REQUEST      prog_id:u32 budget:u32 cur_ptr:u64 sp[32]:i64
//! RESPONSE     status:u8 pad:u8x3 crossings:u32 iters:u64 sp[32]:i64
//!              [timing]                       only when negotiated
//! BUSY         (empty)
//! ERROR        code:u8 pad:u8 msg_len:u16 msg[msg_len]      utf-8
//! STATS        (empty)
//! STATS_OK     body_len:u32 body[body_len]                  utf-8 JSON
//!
//! timing      := queue_ns:u64 exec_ns:u64 transit_ns:u64
//!                completion_ns:u64 server_ns:u64 op:u64
//!                visits:u32 traced:u32                      (56 B)
//! ```
//!
//! Latency attribution is **negotiated, default off**: a client sets
//! the [`REGISTER_FLAG_TIMING`] bit (bit 31) of the REGISTER prog_id;
//! a timing-aware server masks the flag off, registers the program
//! under the low 31 bits, arms per-request attribution for that
//! connection, and echoes the *masked* id in REGISTER_OK. An old
//! server treats the flagged value as an opaque id and echoes it back
//! verbatim — the client detects the un-masked echo and knows timing
//! is unsupported. Once negotiated, every RESPONSE body carries the
//! fixed 56-byte timing block after the scratchpad; un-negotiated
//! connections produce byte-identical frames to the pre-timing
//! protocol. `traced` is 0 or 1 (canonical form: other values are
//! rejected); when 1, `op` joins the sampled-trace span stream
//! (`obs::Span::op`) emitted by `--trace-out`.
//!
//! STATS polls the server's metrics registry: the reply body is one
//! JSON object (`obs::MetricsRegistry::snapshot`), so `pulse stats
//! --addr` and the load generator can watch a live server without a
//! side channel. The body is opaque at the wire layer — adding a
//! metric is not a protocol change.
//!
//! This is `net::TraversalMsg`'s request format (paper §5: `{request
//! id, program, cur_ptr, scratch_pad, budget}`) with one deliberate
//! difference: programs are installed once via REGISTER and referenced
//! by a connection-local `prog_id` afterwards, so the per-request
//! frame stays ~330 B instead of re-shipping the program bytes —
//! exactly the "install the traversal code on the accelerator, then
//! stream requests" split the paper's dispatch engine makes.
//!
//! Server and load generator both encode and decode through this
//! module — there is no second implementation to skew against.

use std::io::Read;

use crate::isa::{Program, Status, SP_WORDS};

/// `b"PLSE"` read as a little-endian u32.
pub const MAGIC: u32 = u32::from_le_bytes(*b"PLSE");
pub const VERSION: u8 = 1;
/// Header bytes before the body (magic, version, kind, pad, seq).
pub const HEADER_BYTES: usize = 16;
/// CRC trailer bytes.
pub const CRC_BYTES: usize = 4;
/// Smallest valid payload: header + empty body + crc.
pub const MIN_PAYLOAD: usize = HEADER_BYTES + CRC_BYTES;
/// Default cap on a payload; anything larger is unframeable garbage
/// (a max-size program + scratchpad request is ~1.4 KB).
pub const DEFAULT_MAX_FRAME: u32 = 256 * 1024;

/// REGISTER prog_id flag bit: the client requests per-request latency
/// attribution for this connection (see module docs). Program ids are
/// confined to the low 31 bits.
pub const REGISTER_FLAG_TIMING: u32 = 1 << 31;

/// RESPONSE body length without the timing block.
pub const RESPONSE_BASE_BYTES: usize = 16 + SP_WORDS * 8;
/// Fixed width of the negotiated timing block.
pub const TIMING_BLOCK_BYTES: usize = 56;

const KIND_REGISTER: u8 = 1;
const KIND_REGISTER_OK: u8 = 2;
const KIND_REQUEST: u8 = 3;
const KIND_RESPONSE: u8 = 4;
const KIND_BUSY: u8 = 5;
const KIND_ERROR: u8 = 6;
const KIND_STATS: u8 = 7;
const KIND_STATS_OK: u8 = 8;

/// Machine-readable cause carried by an ERROR frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum ErrCode {
    BadCrc = 1,
    BadMagic = 2,
    BadVersion = 3,
    UnknownKind = 4,
    BadBody = 5,
    UnknownProgram = 6,
    BadProgram = 7,
    Oversize = 8,
    ShuttingDown = 9,
    UnexpectedKind = 10,
    Backlog = 11,
    Other = 12,
}

impl ErrCode {
    pub fn from_u8(v: u8) -> ErrCode {
        match v {
            1 => ErrCode::BadCrc,
            2 => ErrCode::BadMagic,
            3 => ErrCode::BadVersion,
            4 => ErrCode::UnknownKind,
            5 => ErrCode::BadBody,
            6 => ErrCode::UnknownProgram,
            7 => ErrCode::BadProgram,
            8 => ErrCode::Oversize,
            9 => ErrCode::ShuttingDown,
            10 => ErrCode::UnexpectedKind,
            11 => ErrCode::Backlog,
            _ => ErrCode::Other,
        }
    }
}

/// Per-request server-side latency breakdown, appended to RESPONSE
/// bodies on connections that negotiated [`REGISTER_FLAG_TIMING`].
/// All slices are nanoseconds measured on the server; they satisfy
/// `queue + exec + transit + completion <= server_ns` (write-backlog
/// time after encode is server-side-only and not in the block).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct RespTiming {
    /// Admission (wire decode) → first shard pop (includes the engine
    /// inbox wait).
    pub queue_ns: u64,
    /// Sum of measured accelerator visit durations across all shards.
    pub exec_ns: u64,
    /// Inter-shard forward/bounce transit plus the final reply leg
    /// back to the dispatcher.
    pub transit_ns: u64,
    /// Completion-mailbox delivery: done-callback → writer pickup.
    pub completion_ns: u64,
    /// Total server residence: admission → response encode.
    pub server_ns: u64,
    /// Engine admission index (joins `--trace-out` spans when
    /// `traced`).
    pub op: u64,
    /// Shard visits (pops) this traversal made.
    pub visits: u32,
    /// Whether the PR-7 sampler traced this op.
    pub traced: bool,
}

/// One decoded frame body.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    Register { id: u32, program: Program },
    RegisterOk { id: u32 },
    Request {
        prog: u32,
        budget: u32,
        start: u64,
        sp: [i64; SP_WORDS],
    },
    Response {
        status: Status,
        crossings: u32,
        iters: u64,
        sp: [i64; SP_WORDS],
        /// `Some` only on connections that negotiated timing.
        timing: Option<RespTiming>,
    },
    Busy,
    Error { code: ErrCode, msg: String },
    /// Poll the server's metrics registry.
    Stats,
    /// Registry snapshot: one JSON object, rendered by `util::json`.
    StatsOk { body: String },
}

/// A frame plus its connection-local sequence number.
#[derive(Debug, Clone, PartialEq)]
pub struct Envelope {
    pub seq: u64,
    pub frame: Frame,
}

/// Why a payload failed to decode. `seq` is best-effort (0 when the
/// header itself was unreadable) so an ERROR response can still be
/// correlated when possible.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireError {
    pub seq: u64,
    pub kind: WireErrorKind,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireErrorKind {
    TooShort,
    /// Framing can no longer be trusted; close the connection.
    BadMagic,
    BadVersion(u8),
    BadCrc,
    UnknownKind(u8),
    BadBody(&'static str),
}

impl WireErrorKind {
    /// True when the stream itself is untrustworthy (close it);
    /// false when the frame boundary held and the connection can
    /// continue after an ERROR response.
    pub fn is_fatal(&self) -> bool {
        matches!(
            self,
            WireErrorKind::BadMagic | WireErrorKind::BadVersion(_)
        )
    }

    pub fn err_code(&self) -> ErrCode {
        match self {
            WireErrorKind::TooShort => ErrCode::BadBody,
            WireErrorKind::BadMagic => ErrCode::BadMagic,
            WireErrorKind::BadVersion(_) => ErrCode::BadVersion,
            WireErrorKind::BadCrc => ErrCode::BadCrc,
            WireErrorKind::UnknownKind(_) => ErrCode::UnknownKind,
            WireErrorKind::BadBody(_) => ErrCode::BadBody,
        }
    }
}

// IEEE CRC-32 (reflected, poly 0xEDB88320), table built at compile
// time — the std-only build has no crc crate.
const CRC_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

pub fn crc32(data: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

fn kind_byte(f: &Frame) -> u8 {
    match f {
        Frame::Register { .. } => KIND_REGISTER,
        Frame::RegisterOk { .. } => KIND_REGISTER_OK,
        Frame::Request { .. } => KIND_REQUEST,
        Frame::Response { .. } => KIND_RESPONSE,
        Frame::Busy => KIND_BUSY,
        Frame::Error { .. } => KIND_ERROR,
        Frame::Stats => KIND_STATS,
        Frame::StatsOk { .. } => KIND_STATS_OK,
    }
}

/// Encode a frame into its full wire form (length prefix included).
///
/// Convenience wrapper over [`encode_frame_into`] that allocates a
/// fresh buffer; hot paths (the server's writer loop, the load
/// generator's sender) append into a reused buffer instead.
pub fn encode_frame(seq: u64, frame: &Frame) -> Vec<u8> {
    let mut p = Vec::with_capacity(64 + SP_WORDS * 8);
    encode_frame_into(seq, frame, &mut p);
    p
}

/// Encode a frame into its full wire form (length prefix included),
/// **appending** to `out`. `out` is not cleared — callers batch many
/// frames into one buffer and flush with a single write. Reusing the
/// buffer across frames (clear, don't free) keeps the steady-state
/// encode path allocation-free.
pub fn encode_frame_into(seq: u64, frame: &Frame, out: &mut Vec<u8>) {
    let base = out.len();
    let p = out;
    p.extend_from_slice(&[0u8; 4]); // length placeholder
    p.extend_from_slice(&MAGIC.to_le_bytes());
    p.push(VERSION);
    p.push(kind_byte(frame));
    p.extend_from_slice(&0u16.to_le_bytes());
    p.extend_from_slice(&seq.to_le_bytes());
    match frame {
        Frame::Register { id, program } => {
            p.extend_from_slice(&id.to_le_bytes());
            p.extend_from_slice(&program.encode());
        }
        Frame::RegisterOk { id } => {
            p.extend_from_slice(&id.to_le_bytes());
        }
        Frame::Request { prog, budget, start, sp } => {
            p.extend_from_slice(&prog.to_le_bytes());
            p.extend_from_slice(&budget.to_le_bytes());
            p.extend_from_slice(&start.to_le_bytes());
            for w in sp {
                p.extend_from_slice(&w.to_le_bytes());
            }
        }
        Frame::Response { status, crossings, iters, sp, timing } => {
            p.push(*status as i32 as u8);
            p.extend_from_slice(&[0u8; 3]);
            p.extend_from_slice(&crossings.to_le_bytes());
            p.extend_from_slice(&iters.to_le_bytes());
            for w in sp {
                p.extend_from_slice(&w.to_le_bytes());
            }
            if let Some(t) = timing {
                p.extend_from_slice(&t.queue_ns.to_le_bytes());
                p.extend_from_slice(&t.exec_ns.to_le_bytes());
                p.extend_from_slice(&t.transit_ns.to_le_bytes());
                p.extend_from_slice(&t.completion_ns.to_le_bytes());
                p.extend_from_slice(&t.server_ns.to_le_bytes());
                p.extend_from_slice(&t.op.to_le_bytes());
                p.extend_from_slice(&t.visits.to_le_bytes());
                p.extend_from_slice(&(t.traced as u32).to_le_bytes());
            }
        }
        Frame::Busy => {}
        Frame::Error { code, msg } => {
            let bytes = msg.as_bytes();
            let n = bytes.len().min(u16::MAX as usize);
            p.push(*code as u8);
            p.push(0);
            p.extend_from_slice(&(n as u16).to_le_bytes());
            p.extend_from_slice(&bytes[..n]);
        }
        Frame::Stats => {}
        Frame::StatsOk { body } => {
            let bytes = body.as_bytes();
            p.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
            p.extend_from_slice(bytes);
        }
    }
    let crc = crc32(&p[base + 4..]);
    p.extend_from_slice(&crc.to_le_bytes());
    let len = (p.len() - base - 4) as u32;
    p[base..base + 4].copy_from_slice(&len.to_le_bytes());
}

fn le_u32(b: &[u8]) -> u32 {
    u32::from_le_bytes(b[..4].try_into().unwrap())
}

fn le_u64(b: &[u8]) -> u64 {
    u64::from_le_bytes(b[..8].try_into().unwrap())
}

fn read_sp(b: &[u8]) -> Option<[i64; SP_WORDS]> {
    if b.len() < SP_WORDS * 8 {
        return None;
    }
    let mut sp = [0i64; SP_WORDS];
    for (i, w) in sp.iter_mut().enumerate() {
        *w = i64::from_le_bytes(b[i * 8..i * 8 + 8].try_into().unwrap());
    }
    Some(sp)
}

/// Decode one payload (the bytes after the length prefix). Every body
/// is checked for exact length — trailing garbage is a `BadBody`, so
/// an encoder bug can never ship silently truncated state.
pub fn decode_payload(p: &[u8]) -> Result<Envelope, WireError> {
    let fail = |seq, kind| Err(WireError { seq, kind });
    if p.len() < MIN_PAYLOAD {
        return fail(0, WireErrorKind::TooShort);
    }
    if le_u32(p) != MAGIC {
        return fail(0, WireErrorKind::BadMagic);
    }
    let seq = le_u64(&p[8..16]);
    if p[4] != VERSION {
        return fail(seq, WireErrorKind::BadVersion(p[4]));
    }
    let body_end = p.len() - CRC_BYTES;
    let want = le_u32(&p[body_end..]);
    if crc32(&p[..body_end]) != want {
        return fail(seq, WireErrorKind::BadCrc);
    }
    if p[6] != 0 || p[7] != 0 {
        // pad bytes are part of the canonical form, same discipline
        // as net::TraversalMsg / Instr: every byte of a valid frame
        // is load-bearing, so nothing can hide in ignored padding
        return fail(seq, WireErrorKind::BadBody("nonzero header pad"));
    }
    let kind = p[5];
    let body = &p[HEADER_BYTES..body_end];
    let bad = |m| fail(seq, WireErrorKind::BadBody(m));
    let frame = match kind {
        KIND_REGISTER => {
            if body.len() < 4 {
                return bad("register body too short");
            }
            let id = le_u32(body);
            let Some(program) = Program::decode(&body[4..]) else {
                return bad("undecodable program");
            };
            if 4 + program.wire_size() != body.len() {
                return bad("trailing bytes after program");
            }
            Frame::Register { id, program }
        }
        KIND_REGISTER_OK => {
            if body.len() != 4 {
                return bad("register-ok body must be 4 bytes");
            }
            Frame::RegisterOk { id: le_u32(body) }
        }
        KIND_REQUEST => {
            if body.len() != 16 + SP_WORDS * 8 {
                return bad("request body length");
            }
            Frame::Request {
                prog: le_u32(body),
                budget: le_u32(&body[4..]),
                start: le_u64(&body[8..]),
                sp: read_sp(&body[16..]).unwrap(),
            }
        }
        KIND_RESPONSE => {
            let timing = match body.len() {
                RESPONSE_BASE_BYTES => None,
                n if n == RESPONSE_BASE_BYTES + TIMING_BLOCK_BYTES => {
                    let t = &body[RESPONSE_BASE_BYTES..];
                    let traced = le_u32(&t[52..]);
                    if traced > 1 {
                        return bad("timing traced flag out of range");
                    }
                    Some(RespTiming {
                        queue_ns: le_u64(t),
                        exec_ns: le_u64(&t[8..]),
                        transit_ns: le_u64(&t[16..]),
                        completion_ns: le_u64(&t[24..]),
                        server_ns: le_u64(&t[32..]),
                        op: le_u64(&t[40..]),
                        visits: le_u32(&t[48..]),
                        traced: traced == 1,
                    })
                }
                _ => return bad("response body length"),
            };
            if body[0] > 3 {
                return bad("status out of range");
            }
            if body[1..4] != [0u8; 3] {
                return bad("nonzero response pad");
            }
            Frame::Response {
                status: Status::from_i32(body[0] as i32),
                crossings: le_u32(&body[4..]),
                iters: le_u64(&body[8..]),
                sp: read_sp(&body[16..]).unwrap(),
                timing,
            }
        }
        KIND_BUSY => {
            if !body.is_empty() {
                return bad("busy carries no body");
            }
            Frame::Busy
        }
        KIND_ERROR => {
            if body.len() < 4 {
                return bad("error body too short");
            }
            if body[1] != 0 {
                return bad("nonzero error pad");
            }
            let n = u16::from_le_bytes([body[2], body[3]]) as usize;
            if body.len() != 4 + n {
                return bad("error message length");
            }
            let msg = String::from_utf8_lossy(&body[4..]).into_owned();
            Frame::Error { code: ErrCode::from_u8(body[0]), msg }
        }
        KIND_STATS => {
            if !body.is_empty() {
                return bad("stats carries no body");
            }
            Frame::Stats
        }
        KIND_STATS_OK => {
            if body.len() < 4 {
                return bad("stats-ok body too short");
            }
            let n = le_u32(body) as usize;
            if body.len() != 4 + n {
                return bad("stats-ok body length");
            }
            // the snapshot is machine-parsed JSON: invalid UTF-8 is a
            // hard reject, not a lossy substitution
            let Ok(s) = std::str::from_utf8(&body[4..]) else {
                return bad("stats-ok body not utf-8");
            };
            Frame::StatsOk { body: s.to_owned() }
        }
        other => return fail(seq, WireErrorKind::UnknownKind(other)),
    };
    Ok(Envelope { seq, frame })
}

/// Outcome of pulling one frame off a byte stream.
#[derive(Debug)]
pub enum FrameRead {
    /// A complete payload (decode it with [`decode_payload`]).
    Frame(Vec<u8>),
    /// Clean end of stream at a frame boundary.
    Eof,
    /// A read timeout fired *at a frame boundary* (no bytes consumed):
    /// the connection is idle, not broken — call again. A timeout
    /// mid-frame surfaces as `Io` instead: the peer stalled inside a
    /// frame (or a corrupted length prefix promised bytes that never
    /// come), and the stream must be closed. This is what bounds the
    /// worst case of a flipped length prefix — the CRC cannot cover
    /// the prefix that frames it, so the timeout is the backstop that
    /// keeps "never a wedged connection" true.
    Idle,
    /// Length prefix outside `[MIN_PAYLOAD, max_frame]` — the stream
    /// cannot be resynchronized; close it.
    Oversize(u32),
    /// Transport error (including EOF mid-frame).
    Io(std::io::Error),
}

/// [`read_frame_into`]'s outcome: identical to [`FrameRead`] except
/// the payload lives in the caller's reused buffer instead of a fresh
/// allocation.
#[derive(Debug)]
pub enum FrameEvent {
    /// A complete payload now fills the caller's buffer.
    Frame,
    /// Clean end of stream at a frame boundary.
    Eof,
    /// Read timeout at a frame boundary (see [`FrameRead::Idle`]).
    Idle,
    /// Length prefix outside `[MIN_PAYLOAD, max_frame]`; close.
    Oversize(u32),
    /// Transport error (including EOF mid-frame).
    Io(std::io::Error),
}

/// Is a length prefix inside the codec's `[MIN_PAYLOAD, max_frame]`
/// window? This is exactly the check [`read_frame_into`] applies; the
/// nonblocking runtime's incremental framer shares it so the blocking
/// and event-loop paths can never disagree on which prefixes are
/// unframeable garbage.
#[inline]
pub fn prefix_len_ok(len: u32, max_frame: u32) -> bool {
    (len as usize) >= MIN_PAYLOAD && len <= max_frame
}

/// Read one length-prefixed frame. Blocking; safe to call repeatedly
/// on a `BufReader`-wrapped socket (with or without a read timeout —
/// see [`FrameRead::Idle`]). Allocates the payload; hot loops use
/// [`read_frame_into`] with a per-connection scratch buffer instead.
pub fn read_frame(r: &mut impl Read, max_frame: u32) -> FrameRead {
    let mut payload = Vec::new();
    match read_frame_into(r, max_frame, &mut payload) {
        FrameEvent::Frame => FrameRead::Frame(payload),
        FrameEvent::Eof => FrameRead::Eof,
        FrameEvent::Idle => FrameRead::Idle,
        FrameEvent::Oversize(n) => FrameRead::Oversize(n),
        FrameEvent::Io(e) => FrameRead::Io(e),
    }
}

/// Read one length-prefixed frame into `payload` (cleared and resized
/// to the frame length; capacity is kept across calls, so a
/// connection's reader settles at its largest frame size and stops
/// allocating). Semantics otherwise identical to [`read_frame`].
pub fn read_frame_into(
    r: &mut impl Read,
    max_frame: u32,
    payload: &mut Vec<u8>,
) -> FrameEvent {
    let mut len4 = [0u8; 4];
    // distinguish clean EOF (no bytes at all) from a torn prefix
    match r.read(&mut len4) {
        Ok(0) => return FrameEvent::Eof,
        Ok(n) => {
            if n < 4 {
                if let Err(e) = r.read_exact(&mut len4[n..]) {
                    return FrameEvent::Io(e);
                }
            }
        }
        Err(e)
            if e.kind() == std::io::ErrorKind::WouldBlock
                || e.kind() == std::io::ErrorKind::TimedOut =>
        {
            return FrameEvent::Idle
        }
        Err(e) => return FrameEvent::Io(e),
    }
    let len = u32::from_le_bytes(len4);
    if (len as usize) < MIN_PAYLOAD || len > max_frame {
        return FrameEvent::Oversize(len);
    }
    payload.clear();
    payload.resize(len as usize, 0);
    match r.read_exact(payload) {
        Ok(()) => FrameEvent::Frame,
        Err(e) => FrameEvent::Io(e),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Asm;

    fn sample_program() -> Program {
        let mut a = Asm::new();
        a.ldd(1, 2);
        a.mov(0, 1);
        a.next();
        a.finish(3).unwrap()
    }

    fn sample_frames() -> Vec<(u64, Frame)> {
        let mut sp = [0i64; SP_WORDS];
        sp[0] = -9;
        sp[SP_WORDS - 1] = i64::MAX;
        vec![
            (1, Frame::Register { id: 7, program: sample_program() }),
            (1, Frame::RegisterOk { id: 7 }),
            (
                2,
                Frame::Request {
                    prog: 7,
                    budget: 4096,
                    start: 0xDEAD_BEE0,
                    sp,
                },
            ),
            (
                2,
                Frame::Response {
                    status: Status::Return,
                    crossings: 3,
                    iters: 41,
                    sp,
                    timing: None,
                },
            ),
            (3, Frame::Busy),
            (
                0,
                Frame::Error {
                    code: ErrCode::UnknownProgram,
                    msg: "no such program".into(),
                },
            ),
            (4, Frame::Stats),
            (
                4,
                Frame::StatsOk {
                    body: "{\"counters\":{\"srv.requests\":12}}".into(),
                },
            ),
        ]
    }

    #[test]
    fn every_kind_round_trips() {
        for (seq, frame) in sample_frames() {
            let wire = encode_frame(seq, &frame);
            let len = u32::from_le_bytes(wire[..4].try_into().unwrap());
            assert_eq!(len as usize, wire.len() - 4);
            let env = decode_payload(&wire[4..]).unwrap();
            assert_eq!(env.seq, seq);
            assert_eq!(env.frame, frame, "{frame:?}");
        }
    }

    #[test]
    fn crc_catches_any_single_byte_corruption() {
        let (seq, frame) = &sample_frames()[2];
        let wire = encode_frame(*seq, frame);
        let payload = &wire[4..];
        for pos in 0..payload.len() {
            let mut bad = payload.to_vec();
            bad[pos] ^= 0x41;
            assert!(
                decode_payload(&bad).is_err(),
                "flip at byte {pos} went undetected"
            );
        }
    }

    #[test]
    fn bad_magic_and_version_are_fatal_bad_crc_is_not() {
        let wire = encode_frame(5, &Frame::Busy);
        let mut p = wire[4..].to_vec();
        p[0] ^= 0xFF;
        let e = decode_payload(&p).unwrap_err();
        assert_eq!(e.kind, WireErrorKind::BadMagic);
        assert!(e.kind.is_fatal());

        let mut p = wire[4..].to_vec();
        p[4] = 99; // version; crc now stale but version checked first
        let e = decode_payload(&p).unwrap_err();
        assert_eq!(e.kind, WireErrorKind::BadVersion(99));
        assert!(e.kind.is_fatal());
        assert_eq!(e.seq, 5, "seq still recoverable");

        let mut p = wire[4..].to_vec();
        let last = p.len() - 1;
        p[last] ^= 1; // corrupt the crc itself
        let e = decode_payload(&p).unwrap_err();
        assert_eq!(e.kind, WireErrorKind::BadCrc);
        assert!(!e.kind.is_fatal());
        assert_eq!(e.seq, 5);
    }

    #[test]
    fn trailing_garbage_and_wrong_lengths_are_rejected() {
        // valid register + one stray byte before the crc
        let wire = encode_frame(1, &sample_frames()[0].1);
        let mut p = wire[4..].to_vec();
        let crc_at = p.len() - CRC_BYTES;
        p.insert(crc_at, 0xCC);
        let body_end = p.len() - CRC_BYTES;
        let crc = crc32(&p[..body_end]).to_le_bytes();
        p[body_end..].copy_from_slice(&crc);
        let e = decode_payload(&p).unwrap_err();
        assert!(matches!(e.kind, WireErrorKind::BadBody(_)));

        // truncated below the minimum payload
        assert_eq!(
            decode_payload(&p[..8]).unwrap_err().kind,
            WireErrorKind::TooShort
        );
    }

    /// Canonical-form discipline: a nonzero pad byte with a correctly
    /// recomputed CRC must still be rejected — nothing hides in
    /// padding, even against a non-accidental peer.
    #[test]
    fn nonzero_pads_are_rejected_even_with_valid_crc() {
        let restamp = |p: &mut [u8]| {
            let body_end = p.len() - CRC_BYTES;
            let crc = crc32(&p[..body_end]).to_le_bytes();
            p[body_end..].copy_from_slice(&crc);
        };
        // header pad (payload bytes 6..8)
        let wire = encode_frame(3, &Frame::Busy);
        let mut p = wire[4..].to_vec();
        p[6] = 1;
        restamp(&mut p);
        assert!(matches!(
            decode_payload(&p).unwrap_err().kind,
            WireErrorKind::BadBody(_)
        ));
        // response body pad (body bytes 1..4)
        let wire = encode_frame(3, &sample_frames()[3].1);
        let mut p = wire[4..].to_vec();
        p[HEADER_BYTES + 2] = 7;
        restamp(&mut p);
        assert!(matches!(
            decode_payload(&p).unwrap_err().kind,
            WireErrorKind::BadBody(_)
        ));
        // error body pad (body byte 1)
        let wire = encode_frame(3, &sample_frames()[5].1);
        let mut p = wire[4..].to_vec();
        p[HEADER_BYTES + 1] = 9;
        restamp(&mut p);
        assert!(matches!(
            decode_payload(&p).unwrap_err().kind,
            WireErrorKind::BadBody(_)
        ));
    }

    /// STATS codec edges: the empty-body and length-prefix invariants,
    /// and the hard UTF-8 rejection (the snapshot body is parsed as
    /// JSON downstream — a lossy substitution would corrupt it
    /// silently). Round-trip + the flip-a-byte sweep already cover the
    /// happy path via `sample_frames`.
    #[test]
    fn stats_frames_reject_malformed_bodies() {
        let restamp = |p: &mut [u8]| {
            let body_end = p.len() - CRC_BYTES;
            let crc = crc32(&p[..body_end]).to_le_bytes();
            p[body_end..].copy_from_slice(&crc);
        };
        // STATS with a stray body byte
        let wire = encode_frame(9, &Frame::Stats);
        let mut p = wire[4..].to_vec();
        let crc_at = p.len() - CRC_BYTES;
        p.insert(crc_at, 0x01);
        restamp(&mut p);
        assert!(matches!(
            decode_payload(&p).unwrap_err().kind,
            WireErrorKind::BadBody(_)
        ));
        // STATS_OK whose length prefix disagrees with the body
        let wire =
            encode_frame(9, &Frame::StatsOk { body: "{}".into() });
        let mut p = wire[4..].to_vec();
        p[HEADER_BYTES] = 1; // claims 1 byte, carries 2
        restamp(&mut p);
        assert!(matches!(
            decode_payload(&p).unwrap_err().kind,
            WireErrorKind::BadBody(_)
        ));
        // STATS_OK carrying invalid UTF-8 (0xFF) with a valid CRC
        let wire =
            encode_frame(9, &Frame::StatsOk { body: "ab".into() });
        let mut p = wire[4..].to_vec();
        p[HEADER_BYTES + 4] = 0xFF;
        restamp(&mut p);
        assert!(matches!(
            decode_payload(&p).unwrap_err().kind,
            WireErrorKind::BadBody("stats-ok body not utf-8")
        ));
    }

    fn sample_timing() -> RespTiming {
        RespTiming {
            queue_ns: 1_200,
            exec_ns: 48_000,
            transit_ns: 9_999,
            completion_ns: 310,
            server_ns: 61_000,
            op: 0xFEED_F00D,
            visits: 5,
            traced: true,
        }
    }

    fn timed_response() -> Frame {
        let mut sp = [0i64; SP_WORDS];
        sp[1] = -77;
        Frame::Response {
            status: Status::Return,
            crossings: 2,
            iters: 17,
            sp,
            timing: Some(sample_timing()),
        }
    }

    /// The negotiated timing block round-trips and is exactly 56
    /// bytes on the wire (the body grows by TIMING_BLOCK_BYTES, no
    /// more, no less).
    #[test]
    fn timing_block_round_trips_at_fixed_width() {
        let frame = timed_response();
        let wire = encode_frame(6, &frame);
        let bare = {
            let Frame::Response { status, crossings, iters, sp, .. } =
                frame.clone()
            else {
                unreachable!()
            };
            encode_frame(
                6,
                &Frame::Response {
                    status,
                    crossings,
                    iters,
                    sp,
                    timing: None,
                },
            )
        };
        assert_eq!(wire.len(), bare.len() + TIMING_BLOCK_BYTES);
        let env = decode_payload(&wire[4..]).unwrap();
        assert_eq!(env.frame, frame);
        // untraced variant round-trips too (traced encodes as 0)
        let mut t = sample_timing();
        t.traced = false;
        let f2 = Frame::Response {
            status: Status::Trap,
            crossings: 0,
            iters: 1,
            sp: [0; SP_WORDS],
            timing: Some(t),
        };
        let wire = encode_frame(7, &f2);
        assert_eq!(decode_payload(&wire[4..]).unwrap().frame, f2);
    }

    /// Wire-compat pin: the untimed RESPONSE body is byte-for-byte
    /// the pre-attribution layout (272 B: status, 3 pad, crossings,
    /// iters, 32 sp words) — a client that never sets the REGISTER
    /// flag can never observe a changed frame.
    #[test]
    fn untimed_response_bytes_pin_the_legacy_layout() {
        let mut sp = [0i64; SP_WORDS];
        sp[0] = 0x0102_0304_0506_0708;
        let wire = encode_frame(
            0x1122_3344_5566_7788,
            &Frame::Response {
                status: Status::Return,
                crossings: 0xA1B2_C3D4,
                iters: 0x0908_0706_0504_0302,
                sp,
                timing: None,
            },
        );
        let body =
            &wire[4 + HEADER_BYTES..wire.len() - CRC_BYTES];
        assert_eq!(body.len(), RESPONSE_BASE_BYTES);
        // hand-assembled golden bytes for the fixed-width prefix
        let mut golden = vec![Status::Return as i32 as u8, 0, 0, 0];
        golden.extend_from_slice(&0xA1B2_C3D4u32.to_le_bytes());
        golden
            .extend_from_slice(&0x0908_0706_0504_0302u64.to_le_bytes());
        assert_eq!(&body[..16], &golden[..]);
        assert_eq!(
            &body[16..24],
            &0x0102_0304_0506_0708i64.to_le_bytes()
        );
        assert!(body[24..].iter().all(|&b| b == 0));
        // header: magic, version, kind, zero pad, seq
        let payload = &wire[4..];
        assert_eq!(le_u32(payload), MAGIC);
        assert_eq!(payload[4], VERSION);
        assert_eq!(payload[5], KIND_RESPONSE);
        assert_eq!(&payload[6..8], &[0, 0]);
        assert_eq!(le_u64(&payload[8..]), 0x1122_3344_5566_7788);
    }

    /// The corruption sweep extended to the timing block: any single
    /// flipped byte in a timed RESPONSE is caught (CRC covers the
    /// block too).
    #[test]
    fn crc_catches_corruption_in_the_timing_block() {
        let wire = encode_frame(8, &timed_response());
        let payload = &wire[4..];
        for pos in 0..payload.len() {
            let mut bad = payload.to_vec();
            bad[pos] ^= 0x41;
            assert!(
                decode_payload(&bad).is_err(),
                "flip at byte {pos} went undetected"
            );
        }
    }

    /// Canonical-form discipline for the block: traced must be 0|1
    /// even under a valid CRC, and a body that is neither the bare
    /// nor the timed length is rejected.
    #[test]
    fn timing_block_rejects_noncanonical_forms() {
        let restamp = |p: &mut [u8]| {
            let body_end = p.len() - CRC_BYTES;
            let crc = crc32(&p[..body_end]).to_le_bytes();
            p[body_end..].copy_from_slice(&crc);
        };
        // traced = 2 with a recomputed CRC
        let wire = encode_frame(3, &timed_response());
        let mut p = wire[4..].to_vec();
        let traced_at = HEADER_BYTES + RESPONSE_BASE_BYTES + 52;
        p[traced_at] = 2;
        restamp(&mut p);
        assert!(matches!(
            decode_payload(&p).unwrap_err().kind,
            WireErrorKind::BadBody("timing traced flag out of range")
        ));
        // a truncated block (one byte short) is not a valid body
        let wire = encode_frame(3, &timed_response());
        let mut p = wire[4..].to_vec();
        let crc_at = p.len() - CRC_BYTES;
        p.remove(crc_at - 1);
        restamp(&mut p);
        assert!(matches!(
            decode_payload(&p).unwrap_err().kind,
            WireErrorKind::BadBody("response body length")
        ));
        // one stray byte after the block is trailing garbage
        let wire = encode_frame(3, &timed_response());
        let mut p = wire[4..].to_vec();
        let crc_at = p.len() - CRC_BYTES;
        p.insert(crc_at, 0xEE);
        restamp(&mut p);
        assert!(matches!(
            decode_payload(&p).unwrap_err().kind,
            WireErrorKind::BadBody("response body length")
        ));
    }

    #[test]
    fn unknown_kind_reports_seq_for_correlation() {
        let wire = encode_frame(77, &Frame::Busy);
        let mut p = wire[4..].to_vec();
        p[5] = 200;
        let body_end = p.len() - CRC_BYTES;
        let crc = crc32(&p[..body_end]).to_le_bytes();
        p[body_end..].copy_from_slice(&crc);
        let e = decode_payload(&p).unwrap_err();
        assert_eq!(e.kind, WireErrorKind::UnknownKind(200));
        assert_eq!(e.seq, 77);
        assert!(!e.kind.is_fatal());
    }

    /// The zero-copy pair must be byte-identical to the allocating
    /// wrappers: frames appended into one shared buffer are the exact
    /// concatenation of per-frame `encode_frame` outputs, and
    /// `read_frame_into` walks them back out reusing one payload
    /// buffer (capacity only ever grows — clear-don't-free).
    #[test]
    fn into_variants_match_allocating_wrappers_and_reuse_buffers() {
        let mut batch = Vec::new();
        let mut reference = Vec::new();
        for (seq, frame) in sample_frames() {
            encode_frame_into(seq, &frame, &mut batch);
            reference.extend_from_slice(&encode_frame(seq, &frame));
        }
        assert_eq!(batch, reference);

        let mut cur = &batch[..];
        let mut payload = Vec::new();
        let mut prev_cap = 0usize;
        for (seq, frame) in sample_frames() {
            match read_frame_into(&mut cur, DEFAULT_MAX_FRAME, &mut payload)
            {
                FrameEvent::Frame => {}
                other => panic!("unexpected {other:?}"),
            }
            // clear-don't-free: capacity is monotone across frames
            assert!(payload.capacity() >= prev_cap);
            prev_cap = payload.capacity();
            let env = decode_payload(&payload).unwrap();
            assert_eq!(env.seq, seq);
            assert_eq!(env.frame, frame);
        }
        assert!(matches!(
            read_frame_into(&mut cur, DEFAULT_MAX_FRAME, &mut payload),
            FrameEvent::Eof
        ));
    }

    #[test]
    fn read_timeout_at_frame_boundary_is_idle_not_an_error() {
        struct TimesOut;
        impl Read for TimesOut {
            fn read(&mut self, _: &mut [u8]) -> std::io::Result<usize> {
                Err(std::io::ErrorKind::WouldBlock.into())
            }
        }
        assert!(matches!(
            read_frame(&mut TimesOut, DEFAULT_MAX_FRAME),
            FrameRead::Idle
        ));

        // but a timeout mid-frame (prefix read, bytes promised) is Io
        struct PrefixThenTimeout(usize);
        impl Read for PrefixThenTimeout {
            fn read(
                &mut self,
                buf: &mut [u8],
            ) -> std::io::Result<usize> {
                if self.0 > 0 {
                    let n = self.0.min(buf.len());
                    buf[..n].fill(0x40); // plausible length prefix
                    self.0 -= n;
                    Ok(n)
                } else {
                    Err(std::io::ErrorKind::WouldBlock.into())
                }
            }
        }
        assert!(matches!(
            read_frame(&mut PrefixThenTimeout(2), DEFAULT_MAX_FRAME),
            FrameRead::Io(_)
        ));
    }

    #[test]
    fn read_frame_streams_and_detects_oversize() {
        let mut bytes = Vec::new();
        for (seq, frame) in sample_frames() {
            bytes.extend_from_slice(&encode_frame(seq, &frame));
        }
        let mut cur = &bytes[..];
        let mut n = 0;
        loop {
            match read_frame(&mut cur, DEFAULT_MAX_FRAME) {
                FrameRead::Frame(p) => {
                    decode_payload(&p).unwrap();
                    n += 1;
                }
                FrameRead::Eof => break,
                other => panic!("unexpected {other:?}"),
            }
        }
        assert_eq!(n, sample_frames().len());

        // huge length prefix
        let huge = (DEFAULT_MAX_FRAME + 1).to_le_bytes();
        let mut cur = &huge[..];
        assert!(matches!(
            read_frame(&mut cur, DEFAULT_MAX_FRAME),
            FrameRead::Oversize(_)
        ));
        // absurdly small prefix is equally unframeable
        let tiny = 3u32.to_le_bytes();
        let mut cur = &tiny[..];
        assert!(matches!(
            read_frame(&mut cur, DEFAULT_MAX_FRAME),
            FrameRead::Oversize(3)
        ));

        // torn mid-frame: EOF inside the payload is an Io error
        let wire = encode_frame(1, &Frame::Busy);
        let mut cur = &wire[..wire.len() - 2];
        assert!(matches!(
            read_frame(&mut cur, DEFAULT_MAX_FRAME),
            FrameRead::Io(_)
        ));
    }
}
