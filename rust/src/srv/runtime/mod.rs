//! `srv::runtime` — the thread-per-core event-loop serving tier.
//!
//! The legacy model spends two OS threads per connection (a blocking
//! reader and a blocking writer); at a thousand connections that is two
//! thousand stacks and a scheduler full of parked threads. This module
//! replaces it with a small **worker pool**: each worker owns a slice
//! of the connections outright and multiplexes them over one readiness
//! wait ([`Readiness`], `poll(2)` by default). Nothing about the wire
//! protocol, the counter discipline, or the backpressure edges changes
//! — `tests/integration_srv.rs` runs against this runtime unmodified.
//!
//! Topology per worker:
//!
//! ```text
//!             accept loop                engine dispatcher
//!                  │ adopt(stream)            │ done(completion)
//!                  ▼                          ▼
//!            ┌──────────── WorkerShared ────────────┐
//!            │  mailbox (mutex): newconns, comps    │
//!            │  signaled flag + wake socketpair ────┼──┐ one byte,
//!            └──────────────────────────────────────┘  │ only when
//!                  ▲                                   │ not already
//!                  │ drain mailbox                     │ signaled
//!            ┌─────┴─────── worker thread ◄────────────┘
//!            │ poll(wake, conn fds) → read/decode/submit, flush
//!            │ sessions: slab of per-connection state machines
//!            └───────────────────────────────────────
//! ```
//!
//! **Wakeup protocol.** Producers (the accept loop handing over a
//! connection, the engine dispatcher delivering a completion) push into
//! the mailbox, then write one byte to the wake pipe — but only if a
//! `signaled` flag was clear, so a burst of completions costs one
//! syscall, not one per completion. The worker drains the pipe, clears
//! `signaled`, *then* takes the mailbox: anything pushed after the take
//! finds the flag clear and writes a fresh byte, so no wakeup is ever
//! lost.
//!
//! **Identity.** Sessions are addressed by a `(generation << 32) |
//! slot` token baked into each submission's completion callback. A
//! completion for a connection that died while its traversal was in
//! flight carries a stale token and is dropped — the slot may already
//! host a new connection, which must never receive a dead client's
//! response.
//!
//! **Drain.** `Server::run` stops accepting, shuts the engine down and
//! joins it (every completion is delivered into worker mailboxes
//! first), then calls [`Runtime::finish`]: workers drain their final
//! mailbox, half-close every session's read side, flush the remaining
//! write backlogs (the per-session 5 s stall guard bounds a client
//! that stopped reading), close, and exit. A client that keeps reading
//! therefore sees every response for every admitted op before EOF —
//! the same clean-EOF invariant the threaded tier guaranteed.

mod poll;
pub(crate) mod session;

pub use self::poll::{Interest, PollBackend, Readied, Readiness};

use std::io::{Read, Write};
use std::net::TcpStream;
use std::os::unix::io::AsRawFd;
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::live::engine::{Completion, EngineHandle};
use crate::obs::{AtomicHist, MetricsRegistry};
use crate::srv::metrics::SrvMetrics;
use crate::srv::{SrvConfig, SrvPhaseHists};

pub(crate) use super::{completion_frame, resp_timing};

use self::session::Session;

/// One engine completion routed back to the worker that owns the
/// originating session.
pub(crate) struct CompletionMsg {
    /// Session identity at submit time; stale tokens are dropped.
    pub(crate) token: u64,
    /// Request sequence number (echoed in the response frame).
    pub(crate) seq: u64,
    /// Decode instant — the e2e latency measurement origin.
    pub(crate) t0: Instant,
    /// Done-callback stamp (attributed ops only): the completion-slice
    /// origin, closed when the response frame is built.
    pub(crate) t_done: Option<Instant>,
    /// Per-program e2e histogram, recorded when the bytes flush.
    pub(crate) prog_e2e: Option<Arc<AtomicHist>>,
    pub(crate) c: Completion,
}

#[derive(Default)]
struct Mailbox {
    completions: Vec<CompletionMsg>,
    newconns: Vec<TcpStream>,
}

/// The producer-facing half of one worker: mailbox + wakeup.
pub(crate) struct WorkerShared {
    inbox: Mutex<Mailbox>,
    /// True once a wake byte is pending; collapses a burst of pushes
    /// into a single pipe write.
    signaled: AtomicBool,
    finish: AtomicBool,
    wake_w: UnixStream,
}

impl WorkerShared {
    fn wake(&self) {
        if !self.signaled.swap(true, Ordering::SeqCst) {
            // nonblocking; a full pipe already guarantees a wakeup
            let _ = (&self.wake_w).write(&[1u8]);
        }
    }

    /// Engine-dispatcher side: deliver a completion. Must stay cheap —
    /// it runs on the dispatcher's critical path (one mailbox push
    /// plus, at most, one one-byte write per burst).
    pub(crate) fn complete(&self, msg: CompletionMsg) {
        self.inbox.lock().unwrap().completions.push(msg);
        self.wake();
    }

    /// Accept-loop side: hand a fresh connection to this worker.
    fn adopt(&self, stream: TcpStream) {
        self.inbox.lock().unwrap().newconns.push(stream);
        self.wake();
    }
}

/// Hard ceiling on the finishing flush: even if every remaining client
/// wedges in a way the per-session stall guard somehow misses, the
/// worker still exits.
const FINISH_DEADLINE: Duration = Duration::from_secs(10);

/// Everything a session needs from its surroundings, owned once per
/// worker (config copy, counter handles, engine endpoint, and the
/// worker's own mailbox for completion callbacks).
pub(crate) struct Ctx {
    pub(crate) cfg: SrvConfig,
    pub(crate) metrics: Arc<SrvMetrics>,
    pub(crate) registry: Arc<MetricsRegistry>,
    pub(crate) engine: EngineHandle,
    pub(crate) phase: Arc<SrvPhaseHists>,
    pub(crate) shared: Arc<WorkerShared>,
}

struct Worker {
    wake_r: UnixStream,
    ctx: Ctx,
    /// Session slab; `gens[slot]` bumps on reuse so stale completion
    /// tokens miss.
    sessions: Vec<Option<Session>>,
    gens: Vec<u32>,
    free: Vec<usize>,
    backend: PollBackend,
    // reused poll-round scratch (clear-don't-free)
    interests: Vec<Interest>,
    idx_slots: Vec<usize>,
    events: Vec<Readied>,
    comp_scratch: Vec<CompletionMsg>,
    conn_scratch: Vec<TcpStream>,
    finishing: bool,
    finish_deadline: Option<Instant>,
}

impl Worker {
    fn new(wake_r: UnixStream, ctx: Ctx) -> Worker {
        Worker {
            wake_r,
            ctx,
            sessions: Vec::new(),
            gens: Vec::new(),
            free: Vec::new(),
            backend: PollBackend::default(),
            interests: Vec::new(),
            idx_slots: Vec::new(),
            events: Vec::new(),
            comp_scratch: Vec::new(),
            conn_scratch: Vec::new(),
            finishing: false,
            finish_deadline: None,
        }
    }

    fn live_sessions(&self) -> usize {
        self.sessions.iter().filter(|s| s.is_some()).count()
    }

    /// Pull every pending wake byte off the pipe.
    fn drain_wake_pipe(&mut self) {
        let mut buf = [0u8; 256];
        loop {
            match (&self.wake_r).read(&mut buf) {
                Ok(0) => break, // producer side gone: nothing to drain
                Ok(_) => continue,
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock =>
                {
                    break;
                }
                Err(e)
                    if e.kind()
                        == std::io::ErrorKind::Interrupted =>
                {
                    continue;
                }
                Err(_) => break,
            }
        }
    }

    /// Take the mailbox. Pipe-drain and `signaled` clear happen
    /// *before* the take: a producer pushing after the take sees the
    /// flag clear and writes a fresh wake byte, so the missed-wakeup
    /// window is provably empty.
    fn take_mailbox(&mut self) {
        self.drain_wake_pipe();
        self.ctx.shared.signaled.store(false, Ordering::SeqCst);
        self.comp_scratch.clear();
        self.conn_scratch.clear();
        let mut mb = self.ctx.shared.inbox.lock().unwrap();
        std::mem::swap(&mut mb.completions, &mut self.comp_scratch);
        std::mem::swap(&mut mb.newconns, &mut self.conn_scratch);
    }

    fn adopt_new(&mut self) {
        while let Some(stream) = self.conn_scratch.pop() {
            let slot = self.free.pop().unwrap_or_else(|| {
                self.sessions.push(None);
                self.gens.push(0);
                self.sessions.len() - 1
            });
            let token =
                ((self.gens[slot] as u64) << 32) | slot as u64;
            match Session::new(stream, token) {
                Ok(sess) => {
                    // ledger: accepted == opened + failed (the accept
                    // loop counted conn_accepted before handing over)
                    self.ctx.metrics.conn_opened();
                    self.sessions[slot] = Some(sess);
                }
                Err(_) => {
                    self.ctx.metrics.conn_spawn_failed();
                    self.free.push(slot);
                }
            }
        }
    }

    fn route_completions(&mut self) {
        let ctx = &self.ctx;
        let sessions = &mut self.sessions;
        for msg in self.comp_scratch.drain(..) {
            let slot = (msg.token & 0xffff_ffff) as usize;
            let live = sessions
                .get(slot)
                .and_then(|s| s.as_ref())
                .is_some_and(|s| s.token == msg.token);
            if live {
                // stale tokens (connection died mid-traversal, slot
                // possibly reused) fall through silently — exactly the
                // legacy writer's behavior when its channel was gone
                sessions[slot]
                    .as_mut()
                    .unwrap()
                    .apply_completion(msg, ctx);
            }
        }
    }

    fn flush_pending(&mut self) {
        let ctx = &self.ctx;
        for sess in self.sessions.iter_mut().flatten() {
            if sess.wants_write() {
                sess.try_flush(ctx);
            }
        }
    }

    fn check_timeouts(&mut self) {
        let read_timeout =
            Duration::from_secs(self.ctx.cfg.read_timeout_secs);
        for sess in self.sessions.iter_mut().flatten() {
            sess.check_timeouts(read_timeout);
        }
    }

    fn reap_closable(&mut self) {
        for (slot, entry) in self.sessions.iter_mut().enumerate() {
            if entry.as_ref().is_some_and(|s| s.closable()) {
                // dropping the session closes the stream; count the
                // close on the same side that counted the open
                *entry = None;
                self.ctx.metrics.conn_closed();
                self.gens[slot] = self.gens[slot].wrapping_add(1);
                self.free.push(slot);
            }
        }
    }

    fn build_interests(&mut self) {
        self.interests.clear();
        self.idx_slots.clear();
        self.interests.push(Interest {
            fd: self.wake_r.as_raw_fd(),
            readable: true,
            writable: false,
        });
        self.idx_slots.push(usize::MAX);
        for (slot, sess) in self.sessions.iter().enumerate() {
            let Some(sess) = sess else { continue };
            let r = sess.wants_read();
            let w = sess.wants_write();
            if r || w {
                self.interests.push(Interest {
                    fd: sess.fd,
                    readable: r,
                    writable: w,
                });
                self.idx_slots.push(slot);
            }
            // neither: parked awaiting engine completions only — the
            // mailbox wakeup covers it, no fd interest needed
        }
    }

    fn dispatch_events(&mut self) {
        let events = std::mem::take(&mut self.events);
        for ev in &events {
            if ev.idx == 0 {
                continue; // wake pipe: drained at the loop top
            }
            let slot = self.idx_slots[ev.idx];
            let Some(sess) = self.sessions[slot].as_mut() else {
                continue;
            };
            if ev.readable || ev.closed {
                sess.on_readable(&self.ctx);
            }
            if ev.writable || ev.closed {
                // a closed event on the write side surfaces through
                // the failing flush and marks the session Dead
                sess.try_flush(&self.ctx);
            }
        }
        self.events = events; // hand the scratch buffer back
    }

    fn run(mut self) {
        loop {
            self.take_mailbox();
            self.adopt_new();
            self.route_completions();
            if self.ctx.shared.finish.load(Ordering::SeqCst)
                && !self.finishing
            {
                self.finishing = true;
                self.finish_deadline =
                    Some(Instant::now() + FINISH_DEADLINE);
            }
            if self.finishing {
                // idempotent: only Open sessions transition; anything
                // adopted in the final mailbox drains and closes too
                for sess in self.sessions.iter_mut().flatten() {
                    sess.input_close();
                }
                if self
                    .finish_deadline
                    .is_some_and(|d| Instant::now() >= d)
                {
                    break; // hard stop: drop whatever remains
                }
            }
            self.flush_pending();
            self.check_timeouts();
            self.reap_closable();
            if self.finishing && self.live_sessions() == 0 {
                break;
            }
            self.build_interests();
            let wait = self
                .backend
                .wait(
                    &self.interests,
                    Duration::from_millis(100),
                    &mut self.events,
                )
                .is_ok();
            if !wait {
                // a failing readiness syscall would otherwise spin;
                // degrade to a coarse tick and keep serving
                std::thread::sleep(Duration::from_millis(10));
                continue;
            }
            self.dispatch_events();
        }
        // hard-stop stragglers still count in the connection ledger
        for entry in self.sessions.iter_mut() {
            if entry.take().is_some() {
                self.ctx.metrics.conn_closed();
            }
        }
    }
}

/// The worker pool: started once per [`super::Server::run`], fed by
/// the accept loop, torn down after the engine drains.
pub(crate) struct Runtime {
    workers: Vec<(Arc<WorkerShared>, JoinHandle<()>)>,
    next: usize,
}

impl Runtime {
    /// Spawn `threads` workers (each with its own wake socketpair).
    pub(crate) fn start(
        threads: usize,
        engine: EngineHandle,
        metrics: Arc<SrvMetrics>,
        registry: Arc<MetricsRegistry>,
        phase: Arc<SrvPhaseHists>,
        cfg: SrvConfig,
    ) -> std::io::Result<Runtime> {
        let threads = threads.max(1);
        let mut workers = Vec::with_capacity(threads);
        for i in 0..threads {
            let (wake_r, wake_w) = UnixStream::pair()?;
            wake_r.set_nonblocking(true)?;
            wake_w.set_nonblocking(true)?;
            let shared = Arc::new(WorkerShared {
                inbox: Mutex::new(Mailbox::default()),
                signaled: AtomicBool::new(false),
                finish: AtomicBool::new(false),
                wake_w,
            });
            let ctx = Ctx {
                cfg,
                metrics: Arc::clone(&metrics),
                registry: Arc::clone(&registry),
                engine: engine.clone(),
                phase: Arc::clone(&phase),
                shared: Arc::clone(&shared),
            };
            let h = std::thread::Builder::new()
                .name(format!("srv-io-{i}"))
                .spawn(move || Worker::new(wake_r, ctx).run())?;
            workers.push((shared, h));
        }
        Ok(Runtime { workers, next: 0 })
    }

    /// Hand an accepted connection to a worker (round-robin: every
    /// worker's poll set stays the same size, so tail latency does
    /// not depend on which connection a client happened to get).
    pub(crate) fn adopt(&mut self, stream: TcpStream) {
        let idx = self.next % self.workers.len();
        self.next = self.next.wrapping_add(1);
        self.workers[idx].0.adopt(stream);
    }

    /// Graceful teardown. Call only after the engine has been joined:
    /// every completion is then already in a worker mailbox, so the
    /// final flush writes every admitted op's response before EOF.
    pub(crate) fn finish(self) {
        for (shared, _) in &self.workers {
            shared.finish.store(true, Ordering::SeqCst);
            shared.wake();
        }
        for (_, h) in self.workers {
            let _ = h.join();
        }
    }
}

/// Resolve the configured worker count: explicit wins; `0` means
/// auto — `min(4, available_parallelism)`, enough to saturate the
/// wire tier without stealing cores from the engine's shard workers.
pub(crate) fn resolve_io_threads(configured: usize) -> usize {
    if configured > 0 {
        return configured;
    }
    std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(4)
        .clamp(1, 4)
}
