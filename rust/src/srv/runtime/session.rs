//! Per-connection session state machine for the event-loop runtime.
//!
//! One [`Session`] replaces the legacy reader+writer thread pair. Its
//! life is a small state machine driven by readiness events:
//!
//! * **reading-prefix / reading-body** — bytes accumulate in `rbuf`;
//!   the incremental framer pulls out complete `len`-prefixed payloads
//!   (the nonblocking analogue of `wire::read_frame_into`, sharing
//!   `wire::prefix_len_ok` so both paths reject identical prefixes)
//!   and hands them to `wire::decode_payload` unchanged;
//! * **executing** — decoded REQUESTs are `try_submit`ted; the engine
//!   completion comes back through the worker's completion mailbox
//!   (`inflight` counts submissions whose completion is still out);
//! * **writing-backlog** — every outbound frame is encoded straight
//!   into the reused `wbuf` (`wire::encode_frame_into`, PR 6
//!   discipline: clear-don't-free, no per-frame allocation) and
//!   flushed opportunistically; unflushed bytes register POLLOUT
//!   interest.
//!
//! Sent-side counters keep the legacy writer's honesty rule: frames
//! (and their BUSY/ERROR/response splits, and e2e latencies) are
//! counted only once their bytes are fully on the wire — a torn
//! connection never reports unsent frames as sent.

use std::collections::{HashMap, VecDeque};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::os::unix::io::{AsRawFd, RawFd};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::compiler::CompiledIter;
use crate::live::engine::{Submission, SubmitError};
use crate::obs::AtomicHist;
use crate::srv::wire::{
    decode_payload, encode_frame_into, prefix_len_ok, ErrCode, Frame,
    REGISTER_FLAG_TIMING,
};
use crate::srv::ProgEntry;

use super::{completion_frame, resp_timing, CompletionMsg, Ctx};

/// How much of the connection is still live.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Gate {
    /// Reading and writing.
    Open,
    /// No more input (EOF, fatal frame, backlog cut, drain): finish
    /// in-flight ops, flush the write backlog, then close.
    InputClosed,
    /// Transport is gone (write failure / reset): drop immediately.
    Dead,
}

/// Sent-side accounting for one queued frame: `end` is the absolute
/// outbound byte offset at which the frame is fully on the wire.
struct SentRec {
    end: u64,
    busy: bool,
    error: bool,
    /// RESPONSE frames carry latency accounting, reported once the
    /// bytes flush.
    resp: Option<RespMeta>,
}

/// Response-frame accounting queued alongside the bytes; recorded only
/// when the frame fully flushes (the honesty rule covers histograms
/// the same way it covers counters).
struct RespMeta {
    /// decode → encode e2e latency (the legacy writer's measurement).
    e2e_ns: u64,
    /// Per-program e2e histogram (attributed connections only).
    prog_e2e: Option<Arc<AtomicHist>>,
    /// Encode stamp for attributed ops: closes the write-backlog
    /// slice (`srv.phase.write`) when the bytes hit the wire.
    queued_at: Option<Instant>,
}

/// How many bytes one readiness event may pull off a socket before
/// yielding to the other sessions (fairness under pipelined bursts).
const READ_CHUNK: usize = 16 * 1024;
const MAX_READ_PER_EVENT: usize = 256 * 1024;

/// A stalled write (no forward progress while bytes are pending) cuts
/// the connection after this long — the event-loop analogue of the
/// legacy writer's 5 s socket write timeout.
pub(crate) const WRITE_STALL: Duration = Duration::from_secs(5);

pub(crate) struct Session {
    stream: TcpStream,
    pub(crate) fd: RawFd,
    /// Worker-local identity (slot | generation) echoed by engine
    /// completions; a stale token from a closed session misses.
    pub(crate) token: u64,
    pub(crate) gate: Gate,
    // ---- reading-prefix / reading-body ----
    rbuf: Vec<u8>,
    rpos: usize,
    /// Partial frame buffered (prefix or body): a read timeout now is
    /// a torn/corrupted stream, not idleness.
    mid_frame: bool,
    last_read: Instant,
    // ---- writing-backlog ----
    wbuf: Vec<u8>,
    wpos: usize,
    last_write_progress: Instant,
    /// Absolute outbound byte counters (queued vs flushed); survive
    /// `wbuf` compaction, so `SentRec::end` never needs rebasing.
    queued_total: u64,
    written_total: u64,
    sent: VecDeque<SentRec>,
    // ---- executing ----
    programs: HashMap<u32, ProgEntry>,
    /// Latency attribution armed (REGISTER carried the timing flag
    /// bit): submissions are stamped and responses grow the fixed-
    /// width timing block.
    timing: bool,
    /// Submissions whose completion has not yet come back.
    pub(crate) inflight: u64,
}

impl Session {
    pub(crate) fn new(
        stream: TcpStream,
        token: u64,
    ) -> std::io::Result<Session> {
        let _ = stream.set_nodelay(true);
        stream.set_nonblocking(true)?;
        let fd = stream.as_raw_fd();
        let now = Instant::now();
        Ok(Session {
            stream,
            fd,
            token,
            gate: Gate::Open,
            rbuf: Vec::new(),
            rpos: 0,
            mid_frame: false,
            last_read: now,
            wbuf: Vec::new(),
            wpos: 0,
            last_write_progress: now,
            queued_total: 0,
            written_total: 0,
            sent: VecDeque::new(),
            programs: HashMap::new(),
            timing: false,
            inflight: 0,
        })
    }

    pub(crate) fn wants_read(&self) -> bool {
        self.gate == Gate::Open
    }

    pub(crate) fn wants_write(&self) -> bool {
        self.gate != Gate::Dead && self.wpos < self.wbuf.len()
    }

    /// Finished: nothing left to read, execute, or flush.
    pub(crate) fn closable(&self) -> bool {
        match self.gate {
            Gate::Dead => true,
            Gate::InputClosed => {
                self.inflight == 0 && !self.wants_write()
            }
            Gate::Open => false,
        }
    }

    /// Stop consuming input; the session lingers until in-flight ops
    /// complete and the write backlog flushes.
    pub(crate) fn input_close(&mut self) {
        if self.gate == Gate::Open {
            self.gate = Gate::InputClosed;
            // half-close the read side so the peer's sends stop
            // accumulating in kernel buffers we will never drain
            let _ = self.stream.shutdown(std::net::Shutdown::Read);
        }
    }

    /// Mid-frame read timeout / write stall bookkeeping, run on every
    /// worker tick. Mirrors the legacy semantics exactly: a timeout at
    /// a frame *boundary* is idleness (connection stays open); a
    /// timeout mid-frame means a torn stream or a corrupted length
    /// prefix promising bytes that never come — close it. A write
    /// with pending bytes and no progress for [`WRITE_STALL`] is a
    /// non-reading client: cut it.
    pub(crate) fn check_timeouts(&mut self, read_timeout: Duration) {
        if self.gate == Gate::Open
            && self.mid_frame
            && !read_timeout.is_zero()
            && self.last_read.elapsed() >= read_timeout
        {
            self.input_close();
        }
        if self.wants_write()
            && self.last_write_progress.elapsed() >= WRITE_STALL
        {
            self.gate = Gate::Dead;
        }
    }

    /// Drain whatever the socket has, then pump the framer. Returns
    /// after `MAX_READ_PER_EVENT` bytes to keep one chatty connection
    /// from starving the rest of the worker's poll set.
    pub(crate) fn on_readable(&mut self, ctx: &Ctx) {
        if self.gate != Gate::Open {
            return;
        }
        let mut eof = false;
        let mut total = 0usize;
        loop {
            let start = self.rbuf.len();
            self.rbuf.resize(start + READ_CHUNK, 0);
            match self.stream.read(&mut self.rbuf[start..]) {
                Ok(0) => {
                    self.rbuf.truncate(start);
                    eof = true;
                    break;
                }
                Ok(n) => {
                    self.rbuf.truncate(start + n);
                    self.last_read = Instant::now();
                    total += n;
                    if total >= MAX_READ_PER_EVENT {
                        break;
                    }
                }
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock =>
                {
                    self.rbuf.truncate(start);
                    break;
                }
                Err(e)
                    if e.kind()
                        == std::io::ErrorKind::Interrupted =>
                {
                    self.rbuf.truncate(start);
                }
                Err(_) => {
                    // reset/torn transport: same as the legacy
                    // reader's Io exit — stop reading; in-flight
                    // completions still get a best-effort flush
                    self.rbuf.truncate(start);
                    eof = true;
                    break;
                }
            }
        }
        self.pump(ctx);
        if eof && self.gate == Gate::Open {
            self.gate = Gate::InputClosed;
        }
    }

    /// The incremental framer: reading-prefix → reading-body →
    /// dispatch, repeated while complete frames are buffered.
    fn pump(&mut self, ctx: &Ctx) {
        while self.gate == Gate::Open {
            let avail = self.rbuf.len() - self.rpos;
            if avail < 4 {
                self.mid_frame = avail > 0;
                break;
            }
            let len = u32::from_le_bytes(
                self.rbuf[self.rpos..self.rpos + 4].try_into().unwrap(),
            );
            if !prefix_len_ok(len, ctx.cfg.max_frame) {
                // unframeable: best-effort ERROR, then the stream is
                // done (the prefix cannot be resynchronized)
                ctx.metrics.decode_error();
                self.queue_frame(
                    0,
                    &Frame::Error {
                        code: ErrCode::Oversize,
                        msg: format!("unframeable length {len}"),
                    },
                    None,
                );
                self.input_close();
                break;
            }
            let len = len as usize;
            if avail < 4 + len {
                self.mid_frame = true;
                break;
            }
            self.mid_frame = false;
            let body = self.rpos + 4;
            self.rpos = body + len;
            self.handle_payload(body..body + len, ctx);
        }
        // compact: consumed bytes leave; a partial frame's prefix/body
        // slides to the front (small — at most one frame)
        if self.rpos > 0 {
            if self.rpos == self.rbuf.len() {
                self.rbuf.clear();
            } else {
                self.rbuf.copy_within(self.rpos.., 0);
                let keep = self.rbuf.len() - self.rpos;
                self.rbuf.truncate(keep);
            }
            self.rpos = 0;
        }
    }

    /// One complete payload: decode and dispatch. Mirrors the legacy
    /// `reader_loop` frame-for-frame so every counter and every answer
    /// byte stays identical.
    fn handle_payload(
        &mut self,
        range: std::ops::Range<usize>,
        ctx: &Ctx,
    ) {
        ctx.metrics.frame_in();
        // non-draining-client guard, on EVERY frame kind: once the
        // unflushed response backlog passes the cap the client is cut
        // loose instead of growing the write buffer without bound
        if self.sent.len() as u64 >= ctx.cfg.max_conn_backlog {
            ctx.metrics.backlog_drop();
            self.queue_frame(
                0,
                &Frame::Error {
                    code: ErrCode::Backlog,
                    msg: "response backlog exceeded; closing".into(),
                },
                None,
            );
            self.input_close();
            return;
        }
        let env = match decode_payload(&self.rbuf[range]) {
            Ok(env) => env,
            Err(e) => {
                ctx.metrics.decode_error();
                self.queue_frame(
                    e.seq,
                    &Frame::Error {
                        code: e.kind.err_code(),
                        msg: format!("{:?}", e.kind),
                    },
                    None,
                );
                if e.kind.is_fatal() {
                    self.input_close();
                }
                return;
            }
        };
        match env.frame {
            Frame::Register { id: raw_id, program } => {
                // the high id bit is the attribution opt-in, not part
                // of the program id; echoing the masked id back tells
                // the client the flag was understood
                let id = raw_id & !REGISTER_FLAG_TIMING;
                if raw_id & REGISTER_FLAG_TIMING != 0 {
                    self.timing = true;
                }
                // semantic rejection (verifier or analyzer deny, or
                // a write under read-only serving), not wire
                // corruption: answers ERROR without touching
                // decode_errors
                if let Err(msg) = crate::srv::vet_program(
                    &program,
                    ctx.cfg.allow_writes,
                ) {
                    self.queue_frame(
                        env.seq,
                        &Frame::Error {
                            code: ErrCode::BadProgram,
                            msg,
                        },
                        None,
                    );
                    return;
                }
                // bounded like every other client-controlled edge
                if !self.programs.contains_key(&id)
                    && self.programs.len() >= ctx.cfg.max_programs
                {
                    self.queue_frame(
                        env.seq,
                        &Frame::Error {
                            code: ErrCode::Backlog,
                            msg: "program table full".into(),
                        },
                        None,
                    );
                    return;
                }
                let (e2e, exec) = if self.timing {
                    (
                        ctx.registry.labeled_hist(
                            "srv.e2e",
                            id,
                            ctx.cfg.max_programs,
                        ),
                        ctx.registry.labeled_hist(
                            "engine.execute",
                            id,
                            ctx.cfg.max_programs,
                        ),
                    )
                } else {
                    (None, None)
                };
                self.programs.insert(
                    id,
                    ProgEntry {
                        iter: Arc::new(CompiledIter::new(program)),
                        e2e,
                        exec,
                    },
                );
                ctx.metrics.program_registered();
                self.queue_frame(
                    env.seq,
                    &Frame::RegisterOk { id },
                    None,
                );
            }
            Frame::Request { prog, budget, start, sp } => {
                ctx.metrics.request();
                // clone the entry out first so the program-table
                // borrow ends before the error path needs `&mut self`
                let entry = self.programs.get(&prog).cloned();
                let Some(entry) = entry else {
                    self.queue_frame(
                        env.seq,
                        &Frame::Error {
                            code: ErrCode::UnknownProgram,
                            msg: format!(
                                "program id {prog} not registered"
                            ),
                        },
                        None,
                    );
                    return;
                };
                let seq = env.seq;
                let t0 = Instant::now();
                let shared = Arc::clone(&ctx.shared);
                let token = self.token;
                let prog_e2e = if self.timing {
                    entry.e2e.clone()
                } else {
                    None
                };
                let sub = Submission {
                    iter: entry.iter,
                    start,
                    sp,
                    budget,
                    tag: seq,
                    t0: self.timing.then_some(t0),
                    exec_hist: if self.timing {
                        entry.exec
                    } else {
                        None
                    },
                    // the engine invokes this on its dispatcher
                    // thread: one mailbox push + one conditional
                    // one-byte wakeup write — as cheap as the legacy
                    // channel send, and batched across a burst of
                    // completions by the dirty flag
                    done: Box::new(move |c| {
                        let t_done =
                            c.phases.is_some().then(Instant::now);
                        shared.complete(CompletionMsg {
                            token,
                            seq,
                            t0,
                            t_done,
                            prog_e2e,
                            c,
                        });
                    }),
                };
                match ctx.engine.try_submit(sub) {
                    Ok(()) => self.inflight += 1,
                    Err(SubmitError::Busy(_)) => {
                        self.queue_frame(seq, &Frame::Busy, None)
                    }
                    Err(SubmitError::Down(_)) => {
                        self.queue_frame(
                            seq,
                            &Frame::Error {
                                code: ErrCode::ShuttingDown,
                                msg: "server draining".into(),
                            },
                            None,
                        );
                        self.input_close();
                    }
                }
            }
            Frame::Stats => {
                self.queue_frame(
                    env.seq,
                    &Frame::StatsOk {
                        body: ctx.registry.snapshot().render(),
                    },
                    None,
                );
            }
            // a server never expects client-bound kinds
            Frame::RegisterOk { .. }
            | Frame::Response { .. }
            | Frame::Busy
            | Frame::Error { .. }
            | Frame::StatsOk { .. } => {
                self.queue_frame(
                    env.seq,
                    &Frame::Error {
                        code: ErrCode::UnexpectedKind,
                        msg: "client sent a server-to-client frame"
                            .into(),
                    },
                    None,
                );
            }
        }
    }

    /// An engine completion for this session: encode its frame into
    /// the write backlog. e2e latency (decode → encode, the legacy
    /// writer's measurement point) rides on the sent record and hits
    /// the histogram when the bytes flush. Attributed completions
    /// additionally close their completion slice here (`resp_timing`,
    /// shared with the legacy writer) and carry the timing block out
    /// on the RESPONSE frame.
    pub(crate) fn apply_completion(
        &mut self,
        msg: CompletionMsg,
        ctx: &Ctx,
    ) {
        self.inflight = self.inflight.saturating_sub(1);
        let timing =
            resp_timing(&msg.c, msg.t0, msg.t_done, &ctx.phase);
        let frame = completion_frame(&msg.c, timing);
        let resp = matches!(frame, Frame::Response { .. }).then(|| {
            RespMeta {
                e2e_ns: msg.t0.elapsed().as_nanos() as u64,
                prog_e2e: msg.prog_e2e,
                queued_at: timing.map(|_| Instant::now()),
            }
        });
        self.queue_frame(msg.seq, &frame, resp);
    }

    /// Append one frame to the write backlog (no allocation in steady
    /// state: `wbuf` is compacted, never freed).
    fn queue_frame(
        &mut self,
        seq: u64,
        frame: &Frame,
        resp: Option<RespMeta>,
    ) {
        if self.gate == Gate::Dead {
            return;
        }
        if !self.wants_write() {
            // backlog was empty: restart the stall clock
            self.last_write_progress = Instant::now();
        }
        let before = self.wbuf.len();
        encode_frame_into(seq, frame, &mut self.wbuf);
        self.queued_total += (self.wbuf.len() - before) as u64;
        self.sent.push_back(SentRec {
            end: self.queued_total,
            busy: matches!(frame, Frame::Busy),
            error: matches!(frame, Frame::Error { .. }),
            resp,
        });
    }

    /// Opportunistic nonblocking flush; counts frames as sent only
    /// once their last byte is on the wire.
    pub(crate) fn try_flush(&mut self, ctx: &Ctx) {
        if self.gate == Gate::Dead {
            return;
        }
        while self.wpos < self.wbuf.len() {
            match self.stream.write(&self.wbuf[self.wpos..]) {
                Ok(0) => {
                    self.gate = Gate::Dead;
                    break;
                }
                Ok(n) => {
                    self.wpos += n;
                    self.written_total += n as u64;
                    self.last_write_progress = Instant::now();
                }
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock =>
                {
                    break;
                }
                Err(e)
                    if e.kind()
                        == std::io::ErrorKind::Interrupted => {}
                Err(_) => {
                    // dead or stalled-past-timeout client: the whole
                    // connection goes (legacy writer shut both halves
                    // down) — unflushed frames are never counted
                    let _ =
                        self.stream.shutdown(std::net::Shutdown::Both);
                    self.gate = Gate::Dead;
                    break;
                }
            }
        }
        // compact once fully flushed; otherwise only when the flushed
        // prefix has grown large (a slow client must not pin memory)
        if self.wpos == self.wbuf.len() {
            self.wbuf.clear();
            self.wpos = 0;
        } else if self.wpos >= 64 * 1024 {
            self.wbuf.copy_within(self.wpos.., 0);
            let keep = self.wbuf.len() - self.wpos;
            self.wbuf.truncate(keep);
            self.wpos = 0;
        }
        // honesty rule: counters fire only for fully-written frames
        let mut frames = 0u64;
        let mut busy = 0u64;
        let mut errors = 0u64;
        while let Some(rec) = self.sent.front() {
            if rec.end > self.written_total {
                break;
            }
            let rec = self.sent.pop_front().unwrap();
            frames += 1;
            if rec.busy {
                busy += 1;
            }
            if rec.error {
                errors += 1;
            }
            if let Some(m) = rec.resp {
                ctx.metrics.response(m.e2e_ns);
                if let Some(h) = m.prog_e2e {
                    h.record(m.e2e_ns.max(1));
                }
                if let Some(t) = m.queued_at {
                    ctx.phase.write.record(
                        (t.elapsed().as_nanos() as u64).max(1),
                    );
                }
            }
        }
        if frames > 0 {
            ctx.metrics.sent_batch(frames, busy, errors);
        }
    }
}
