//! Readiness backend: which fds can make progress right now?
//!
//! The event loop is written against the [`Readiness`] trait so the
//! multiplexing syscall is a pluggable detail — `poll(2)` today,
//! epoll/kqueue/io_uring backends can slot in later without touching
//! the session state machines or the worker loop. The default
//! [`PollBackend`] declares `poll(2)` directly (std exposes no
//! readiness API and this build links no libc crate; libc itself is
//! always linked, so a one-line `extern "C"` declaration is all the
//! FFI there is). `poll` is in POSIX and behaves identically across
//! Linux and the BSDs; O(n) per wait is irrelevant at the few hundred
//! fds each worker owns (connections are spread across the pool).

use std::io;
use std::os::raw::{c_int, c_ulong};
use std::os::unix::io::RawFd;
use std::time::Duration;

// poll(2) event bits (POSIX values, identical on Linux and the BSDs).
const POLLIN: i16 = 0x001;
const POLLOUT: i16 = 0x004;
const POLLERR: i16 = 0x008;
const POLLHUP: i16 = 0x010;
const POLLNVAL: i16 = 0x020;

/// `struct pollfd` — layout fixed by POSIX.
#[repr(C)]
#[derive(Clone, Copy)]
struct PollFd {
    fd: c_int,
    events: i16,
    revents: i16,
}

extern "C" {
    /// `int poll(struct pollfd *fds, nfds_t nfds, int timeout);`
    /// (`nfds_t` is `unsigned long` on every platform this builds on.)
    fn poll(fds: *mut PollFd, nfds: c_ulong, timeout: c_int) -> c_int;
}

/// One fd the caller wants readiness for, with its interest set.
#[derive(Debug, Clone, Copy)]
pub struct Interest {
    pub fd: RawFd,
    pub readable: bool,
    pub writable: bool,
}

/// One readiness event. `idx` indexes the caller's interest slice —
/// the backend never needs an fd→session map of its own.
#[derive(Debug, Clone, Copy)]
pub struct Readied {
    pub idx: usize,
    pub readable: bool,
    pub writable: bool,
    /// Error/hangup on the fd (POLLERR/POLLHUP/POLLNVAL). The caller
    /// should attempt a read — it will surface the error or EOF — and
    /// tear the session down through the normal path.
    pub closed: bool,
}

/// A blocking "wait until some fd is ready" primitive.
pub trait Readiness: Send {
    /// Wait up to `timeout` for readiness on `interests`, appending
    /// events to `out` (cleared first). Returning with `out` empty
    /// means the timeout elapsed (or a signal interrupted the wait) —
    /// both are normal; the caller runs its tick work and re-polls.
    fn wait(
        &mut self,
        interests: &[Interest],
        timeout: Duration,
        out: &mut Vec<Readied>,
    ) -> io::Result<()>;
}

/// The `poll(2)` readiness backend. Owns a reused `pollfd` scratch
/// array (clear-don't-free, PR 6 discipline), so steady-state waits
/// allocate nothing.
#[derive(Default)]
pub struct PollBackend {
    scratch: Vec<PollFd>,
}

impl Readiness for PollBackend {
    fn wait(
        &mut self,
        interests: &[Interest],
        timeout: Duration,
        out: &mut Vec<Readied>,
    ) -> io::Result<()> {
        out.clear();
        self.scratch.clear();
        for it in interests {
            let mut events = 0i16;
            if it.readable {
                events |= POLLIN;
            }
            if it.writable {
                events |= POLLOUT;
            }
            self.scratch.push(PollFd { fd: it.fd, events, revents: 0 });
        }
        let ms: c_int = timeout
            .as_millis()
            .min(c_int::MAX as u128)
            .try_into()
            .unwrap_or(c_int::MAX);
        let n = unsafe {
            poll(
                self.scratch.as_mut_ptr(),
                self.scratch.len() as c_ulong,
                ms,
            )
        };
        if n < 0 {
            let e = io::Error::last_os_error();
            // a signal mid-wait is a spurious wakeup, not a failure
            if e.kind() == io::ErrorKind::Interrupted {
                return Ok(());
            }
            return Err(e);
        }
        if n == 0 {
            return Ok(()); // timeout tick
        }
        for (idx, pfd) in self.scratch.iter().enumerate() {
            if pfd.revents == 0 {
                continue;
            }
            out.push(Readied {
                idx,
                readable: pfd.revents & POLLIN != 0,
                writable: pfd.revents & POLLOUT != 0,
                closed: pfd.revents & (POLLERR | POLLHUP | POLLNVAL)
                    != 0,
            });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::os::unix::io::AsRawFd;
    use std::os::unix::net::UnixStream;

    #[test]
    fn readable_after_write_and_timeout_when_idle() {
        let (a, mut b) = UnixStream::pair().unwrap();
        a.set_nonblocking(true).unwrap();
        let mut be = PollBackend::default();
        let interests = [Interest {
            fd: a.as_raw_fd(),
            readable: true,
            writable: false,
        }];
        let mut out = Vec::new();
        // idle: the wait times out with no events
        be.wait(&interests, Duration::from_millis(10), &mut out)
            .unwrap();
        assert!(out.is_empty(), "idle socket reported ready: {out:?}");
        // one byte lands -> readable fires with the right index
        b.write_all(&[7u8]).unwrap();
        be.wait(&interests, Duration::from_millis(1000), &mut out)
            .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].idx, 0);
        assert!(out[0].readable);
        let mut buf = [0u8; 8];
        let mut ar = &a;
        assert_eq!(ar.read(&mut buf).unwrap(), 1);
    }

    #[test]
    fn hangup_reports_closed_or_readable() {
        let (a, b) = UnixStream::pair().unwrap();
        drop(b);
        let mut be = PollBackend::default();
        let interests = [Interest {
            fd: a.as_raw_fd(),
            readable: true,
            writable: false,
        }];
        let mut out = Vec::new();
        be.wait(&interests, Duration::from_millis(1000), &mut out)
            .unwrap();
        assert_eq!(out.len(), 1);
        // a peer hangup must wake the waiter (as EOF-readable and/or
        // POLLHUP); either way the read path observes the close
        assert!(out[0].readable || out[0].closed);
    }

    #[test]
    fn writable_fires_on_an_unfilled_socket() {
        let (a, _b) = UnixStream::pair().unwrap();
        let mut be = PollBackend::default();
        let interests = [Interest {
            fd: a.as_raw_fd(),
            readable: false,
            writable: true,
        }];
        let mut out = Vec::new();
        be.wait(&interests, Duration::from_millis(1000), &mut out)
            .unwrap();
        assert_eq!(out.len(), 1);
        assert!(out[0].writable);
    }
}
