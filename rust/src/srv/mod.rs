//! `srv` — the TCP wire-serving tier: any [`TraversalBackend`] exposed
//! over real sockets.
//!
//! This is the layer the paper's §4.2 network stack occupies between
//! CPU-node libraries and the rack: clients install traversal programs
//! once (REGISTER), then stream `{program_id, cur_ptr, scratch_pad,
//! budget}` requests and collect responses by request id — the single
//! shared request/response format, now as length-prefixed CRC-checked
//! frames on a byte stream (`srv::wire`) instead of structs on a
//! simulated link (`net::transport`).
//!
//! Threading model (see `srv/README.md` for the full diagram):
//!
//! * the **accept loop** (the thread that called [`Server::run`])
//!   polls the listener and hands each accepted connection to the
//!   **event-loop runtime** ([`runtime`]): a small worker pool where
//!   each worker multiplexes its share of the connections over one
//!   readiness wait, running a per-connection session state machine
//!   (reading-prefix / reading-body / executing / writing-backlog);
//! * sessions decode frames, resolve program ids against their
//!   connection-local registry, and submit traversals to the engine
//!   with a non-blocking `try_submit`;
//! * the **engine** ([`crate::live::engine`]) executes them — sharded
//!   (one worker per memory node, the live dataplane) when the backend
//!   is the live engine, inline on a single dispatcher thread for the
//!   model backends (which all share the same functional substrate) —
//!   and delivers each completion as a mailbox push plus a coalesced
//!   one-byte wakeup into the owning worker's event loop;
//! * the legacy **two-threads-per-connection** path (blocking reader +
//!   writer) survives behind [`SrvConfig::legacy_threads`] — it is the
//!   comparison baseline for the `net_serving` bench and the fallback
//!   on platforms without the unix readiness runtime.
//!
//! Backpressure never hangs a connection: a full engine inbox or a
//! full admission window answers an explicit BUSY frame; a client that
//! stops draining responses is disconnected once its writer backlog
//! passes `max_conn_backlog`. Malformed frames answer ERROR (or a
//! clean disconnect when the stream itself can no longer be framed) —
//! never a panic, matching the trap discipline of the execution tiers.

// Hot-path modules keep clones honest: a clone the borrow checker
// would let us drop is a bug here, not a style nit.
#![deny(clippy::redundant_clone)]

pub mod loadgen;
pub mod metrics;
#[cfg(unix)]
pub mod runtime;
pub mod wire;

pub use self::loadgen::{
    fetch_stats, run_loadgen, LoadReport, LoadgenConfig,
};
pub use self::metrics::{SrvMetrics, SrvSnapshot};

use std::collections::HashMap;
use std::io::{BufReader, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::backend::{BackendMetrics, TraversalBackend};
use crate::compiler::CompiledIter;
use crate::live::engine::{
    Completion, CompletionCode, Engine, EngineConfig, EngineHandle,
    EngineReport, Submission, SubmitError,
};
use crate::obs::{
    AtomicHist, MetricsRegistry, SnapshotSampler, TraceConfig,
};
use crate::util::json::Json;

use self::wire::{
    decode_payload, encode_frame_into, read_frame_into, ErrCode, Frame,
    FrameEvent, RespTiming, REGISTER_FLAG_TIMING,
};

/// Tunables of the serving tier.
#[derive(Debug, Clone, Copy)]
pub struct SrvConfig {
    /// Engine admission window (traversals in flight across every
    /// connection).
    pub window: usize,
    /// Engine inbox capacity; 0 = auto (see [`EngineConfig`]).
    pub inbox_capacity: usize,
    /// Submissions parked past the window before BUSY; 0 = auto.
    pub pending_cap: usize,
    /// Yield-continuation cap per traversal.
    pub max_boosts: u32,
    /// Largest acceptable frame payload.
    pub max_frame: u32,
    /// Responses queued on one connection before it is declared
    /// non-draining and dropped.
    pub max_conn_backlog: u64,
    /// Distinct program ids one connection may register (bounds the
    /// only other per-connection allocation a client controls).
    pub max_programs: usize,
    /// Reader-side timeout per socket read. A timeout at a frame
    /// boundary is idle (keep waiting); a timeout *mid-frame* closes
    /// the connection — the backstop that bounds a corrupted length
    /// prefix (which the CRC cannot cover) to seconds instead of a
    /// permanently wedged reader thread. 0 = no timeout.
    pub read_timeout_secs: u64,
    /// Exit (drain + return) after this many seconds; 0 = run until
    /// [`ServerHandle::shutdown`].
    pub run_secs: f64,
    /// Periodic registry-snapshot interval for the JSONL sampler
    /// (needs [`Server::set_stats_out`]); 0 = sampler off.
    pub stats_interval_s: f64,
    /// Sampled traversal tracing for the engine (`None` = off; the
    /// drained trace rides back on [`EngineReport::trace`]).
    pub trace: Option<TraceConfig>,
    /// Event-loop worker threads serving connections; 0 = auto
    /// (`min(4, available_parallelism)`). Ignored on the legacy path.
    pub io_threads: usize,
    /// Serve with the legacy two-threads-per-connection model instead
    /// of the event-loop runtime (the `net_serving` old-vs-new
    /// baseline; also the forced fallback on non-unix targets).
    pub legacy_threads: bool,
    /// Admit programs whose analysis proves they may store into node
    /// DRAM (`Analysis::writes_dram`). `false` = read-only serving:
    /// mutating REGISTERs are rejected with a structured ERROR
    /// (`pulse serve --read-only`).
    pub allow_writes: bool,
}

impl Default for SrvConfig {
    fn default() -> Self {
        Self {
            window: 64,
            inbox_capacity: 0,
            pending_cap: 0,
            max_boosts: 4096,
            max_frame: wire::DEFAULT_MAX_FRAME,
            max_conn_backlog: 1024,
            max_programs: 256,
            read_timeout_secs: 30,
            run_secs: 0.0,
            stats_interval_s: 0.0,
            trace: None,
            io_threads: 0,
            legacy_threads: false,
            allow_writes: true,
        }
    }
}

/// Wire-admission vetting shared by both serving tiers (the second of
/// the three enforcement layers: compile → **wire admission** → `pulse
/// lint`). Runs the structural verifier *and* the abstract
/// interpreter; any deny-severity diagnostic — certain trap, provably
/// out-of-bounds computed offset — rejects the REGISTER, as does a
/// proven DRAM write under read-only serving. The returned string
/// carries the rendered diagnostic (pc + disassembled instruction)
/// back to the client in the ERROR frame.
pub(crate) fn vet_program(
    program: &crate::isa::Program,
    allow_writes: bool,
) -> Result<(), String> {
    let analysis = crate::isa::analyze(program, crate::isa::SP_INPUTS_ALL);
    if let Some(d) = analysis
        .diags
        .iter()
        .find(|d| d.severity == crate::isa::Severity::Deny)
    {
        return Err(format!("program rejected: {d}"));
    }
    if !allow_writes && analysis.writes_dram {
        return Err(
            "program rejected: writes to node DRAM, but this server \
             is read-only (--read-only)"
                .to_string(),
        );
    }
    Ok(())
}

/// Serving-tier per-phase histograms (`srv.phase.*`), created eagerly
/// in [`Server::run`] so the names always appear in STATS snapshots;
/// both serving tiers record into them only for requests on
/// connections that negotiated timing — an unattributed workload
/// leaves every count at zero.
#[derive(Debug)]
pub(crate) struct SrvPhaseHists {
    /// Completion-mailbox delivery: engine done-callback → writer /
    /// session pickup.
    pub(crate) completion: Arc<AtomicHist>,
    /// Write backlog: response encode → flushed to the socket.
    pub(crate) write: Arc<AtomicHist>,
}

impl SrvPhaseHists {
    pub(crate) fn new(reg: &MetricsRegistry) -> Self {
        Self {
            completion: reg.hist("srv.phase.completion"),
            write: reg.hist("srv.phase.write"),
        }
    }
}

/// One registered program on a connection: the compiled iterator plus
/// its per-program latency series (`srv.e2e.prog{id}`,
/// `engine.execute.prog{id}`), resolved at REGISTER time only when
/// the connection negotiated timing and the label-cardinality cap
/// (`max_programs`) has room. `None` hists mean "aggregate only".
#[derive(Clone)]
pub(crate) struct ProgEntry {
    pub(crate) iter: Arc<CompiledIter>,
    pub(crate) e2e: Option<Arc<AtomicHist>>,
    pub(crate) exec: Option<Arc<AtomicHist>>,
}

/// Everything one server run observed, returned by [`Server::run`].
#[derive(Debug)]
pub struct SrvSummary {
    /// Execution-tier accounting (completions, latency, shard/router
    /// counters).
    pub engine: EngineReport,
    /// Serving-tier counters (conns, frames, decode errors, busy).
    pub srv: SrvSnapshot,
    /// The unified metrics row every backend reports, fed from the
    /// engine's serve report with the wire-tier overload counters
    /// filled in — overload is observable, not silent.
    pub backend: BackendMetrics,
    /// The serving window: bind-to-last-accept-poll wall time. This —
    /// not the drain — is what `engine.report.wall_ms` and
    /// `tput_ops_per_s` are computed over, so throughput is not
    /// diluted by however long shutdown took.
    pub serving_ms: f64,
    /// Teardown tail: engine drain + final response flush + close.
    pub drain_ms: f64,
    /// Final metrics-registry snapshot (phase histograms, per-program
    /// series, queue gauges), taken after the drain — the same JSON a
    /// STATS poll would have returned, preserved so bench artifacts
    /// carry attribution.
    pub registry: Json,
}

/// Control half handed back by [`Server::bind`]: lives on any thread,
/// addresses the server while [`Server::run`] blocks elsewhere.
#[derive(Clone)]
pub struct ServerHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    metrics: Arc<SrvMetrics>,
}

impl ServerHandle {
    /// Actual bound address (resolves `:0` ephemeral binds).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Begin graceful shutdown: stop accepting, drain in-flight ops,
    /// flush responses, close connections, return from `run`.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Relaxed);
    }

    /// Live serving-tier counters.
    pub fn metrics(&self) -> SrvSnapshot {
        self.metrics.snapshot()
    }
}

/// The serving tier: own a backend, listen, serve until shutdown.
pub struct Server {
    backend: Box<dyn TraversalBackend + Send>,
    listener: TcpListener,
    cfg: SrvConfig,
    stop: Arc<AtomicBool>,
    metrics: Arc<SrvMetrics>,
    /// JSONL file the periodic snapshot sampler appends to (needs
    /// `cfg.stats_interval_s > 0`).
    stats_out: Option<std::path::PathBuf>,
}

impl Server {
    /// Bind the listener now (so port-in-use fails loudly here, not
    /// mid-serve) and return the server plus its control handle.
    pub fn bind(
        backend: Box<dyn TraversalBackend + Send>,
        addr: &str,
        cfg: SrvConfig,
    ) -> std::io::Result<(Server, ServerHandle)> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let metrics = Arc::new(SrvMetrics::default());
        let handle = ServerHandle {
            addr,
            stop: Arc::clone(&stop),
            metrics: Arc::clone(&metrics),
        };
        Ok((
            Server { backend, listener, cfg, stop, metrics, stats_out: None },
            handle,
        ))
    }

    /// Enable the periodic time-series sampler: one JSONL row of the
    /// metrics registry every `cfg.stats_interval_s` seconds.
    pub fn set_stats_out(&mut self, path: std::path::PathBuf) {
        self.stats_out = Some(path);
    }

    /// Serve until shutdown (handle, `run_secs`, or listener failure),
    /// then drain and report. Blocks the calling thread; everything —
    /// engine, shards, connections — is torn down before returning.
    pub fn run(mut self) -> SrvSummary {
        let cfg = self.cfg;
        // the live engine gets real shards; every model backend shares
        // the functional substrate and serves inline (their *modeled*
        // time is meaningless over a real socket — wall clock rules)
        let sharded = self.backend.serves_sharded();
        let (mut engine, ehandle) = Engine::new(EngineConfig {
            window: cfg.window,
            inbox_capacity: cfg.inbox_capacity,
            pending_cap: cfg.pending_cap,
            max_boosts: cfg.max_boosts,
            sharded,
            trace: cfg.trace,
        });
        // one registry for the whole run: serving-tier counters and
        // engine queue gauges snapshot together (STATS frames, the
        // periodic sampler, ServerHandle observers)
        let registry = Arc::new(MetricsRegistry::new());
        self.metrics.register_into(&registry);
        engine.set_registry(Arc::clone(&registry));
        let phase = Arc::new(SrvPhaseHists::new(&registry));
        let sampler = match (&self.stats_out, cfg.stats_interval_s > 0.0)
        {
            (Some(path), true) => SnapshotSampler::start(
                Arc::clone(&registry),
                path.clone(),
                Duration::from_secs_f64(cfg.stats_interval_s),
            )
            .ok(),
            _ => None,
        };
        let name = self.backend.name();
        let rack = self.backend.rack_mut();
        let metrics = Arc::clone(&self.metrics);
        let stop = Arc::clone(&self.stop);
        let listener = self.listener;
        let _ = listener.set_nonblocking(true);
        let wall_start = Instant::now();

        // the event-loop runtime serves by default; the legacy
        // two-threads-per-connection path remains selectable (bench
        // baseline) and is the forced fallback off-unix or if the
        // runtime cannot start (socketpair/thread exhaustion)
        #[cfg(unix)]
        let mut runtime: Option<runtime::Runtime> =
            if cfg.legacy_threads {
                None
            } else {
                runtime::Runtime::start(
                    runtime::resolve_io_threads(cfg.io_threads),
                    ehandle.clone(),
                    Arc::clone(&metrics),
                    Arc::clone(&registry),
                    Arc::clone(&phase),
                    cfg,
                )
                .ok()
            };

        let (mut engine_report, serving) = std::thread::scope(|s| {
            let eng = s.spawn(move || engine.run(rack));
            let deadline = (cfg.run_secs > 0.0).then(|| {
                Instant::now() + Duration::from_secs_f64(cfg.run_secs)
            });
            let mut conns: Vec<(JoinHandle<()>, TcpStream)> = Vec::new();
            // transient accept errors (ECONNABORTED from a client
            // resetting mid-handshake, EMFILE under fd pressure) must
            // not take the whole server down; only a persistently
            // failing listener does
            let mut accept_failures = 0u32;
            loop {
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                if deadline.is_some_and(|d| Instant::now() >= d) {
                    break;
                }
                // reap finished connections: dropping the pair frees
                // the control-stream fd and detaches the (already
                // exited) threads, so a reconnect-heavy client cannot
                // exhaust fds over a long-running serve
                conns.retain(|(h, _)| !h.is_finished());
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        accept_failures = 0;
                        metrics.conn_accepted();
                        #[cfg(unix)]
                        let stream = match runtime.as_mut() {
                            Some(rt) => {
                                rt.adopt(stream);
                                continue;
                            }
                            None => stream,
                        };
                        match spawn_connection(
                            stream,
                            ehandle.clone(),
                            Arc::clone(&metrics),
                            Arc::clone(&registry),
                            Arc::clone(&phase),
                            cfg,
                        ) {
                            Ok(pair) => conns.push(pair),
                            // an accepted-then-unservable socket
                            // (try_clone/fd exhaustion) must still
                            // land in the ledger, or conns_accepted
                            // silently drifts from opened+failed
                            Err(_) => metrics.conn_spawn_failed(),
                        }
                    }
                    Err(e)
                        if e.kind()
                            == std::io::ErrorKind::WouldBlock =>
                    {
                        // idle poll at 100 Hz: cheap enough to leave
                        // running for days, fine-grained enough that
                        // shutdown/deadline latency stays ~10 ms
                        std::thread::sleep(Duration::from_millis(10));
                    }
                    Err(_) => {
                        accept_failures += 1;
                        if accept_failures >= 100 {
                            break; // listener is genuinely broken
                        }
                        std::thread::sleep(Duration::from_millis(10));
                    }
                }
            }
            drop(listener);
            // the serving window closes here: everything after is
            // drain, and must not dilute throughput numbers
            let serving = wall_start.elapsed();
            // drain: admitted ops complete, late submissions answer
            // shutting-down, then the engine (and its shards) exits
            ehandle.shutdown();
            let report = eng.join().expect("engine thread panicked");
            // every completion is now in a worker mailbox (event
            // loop) or writer channel (legacy): flush them all, then
            // close — a client that keeps reading sees every
            // admitted op's response before EOF
            #[cfg(unix)]
            if let Some(rt) = runtime.take() {
                rt.finish();
            }
            // legacy teardown: unblock readers parked in recv — read
            // half only, so writers can still flush completions
            // queued during the drain; each writer exits once its
            // reader drops the channel and the remaining frames are
            // on the wire
            for (_, stream) in &conns {
                let _ = stream.shutdown(Shutdown::Read);
            }
            for (h, _) in conns {
                let _ = h.join();
            }
            (report, serving)
        });

        if let Some(s) = sampler {
            s.stop(); // writes its final row before we report
        }
        let total = wall_start.elapsed();
        let drain = total.saturating_sub(serving);
        // rate accounting over the serving window only (satellite of
        // the runtime change: the old code divided by serve+drain,
        // understating throughput by however long teardown took)
        engine_report.report.wall_ms = serving.as_secs_f64() * 1e3;
        engine_report.report.makespan_ns = serving.as_nanos() as u64;
        if engine_report.report.completed > 0
            && serving.as_secs_f64() > 0.0
        {
            engine_report.report.tput_ops_per_s =
                engine_report.report.completed as f64
                    / serving.as_secs_f64();
        }
        let srv = self.metrics.snapshot();
        let mut backend =
            BackendMetrics::from_report(name, &engine_report.report);
        backend.net_dropped =
            self.backend.rack_mut().link_totals().dropped;
        backend.wire_decode_errors = srv.decode_errors;
        backend.wire_busy = srv.busy;
        SrvSummary {
            engine: engine_report,
            srv,
            backend,
            serving_ms: serving.as_secs_f64() * 1e3,
            drain_ms: drain.as_secs_f64() * 1e3,
            registry: registry.snapshot(),
        }
    }
}

/// What the writer thread emits on one connection.
enum WriterMsg {
    /// Engine completion for request `seq` (decoded at `t0`).
    /// `t_done` is the done-callback stamp and `prog_e2e` the
    /// per-program latency series — both `Some` only on attributed
    /// requests (negotiated timing).
    Done {
        seq: u64,
        t0: Instant,
        t_done: Option<Instant>,
        prog_e2e: Option<Arc<AtomicHist>>,
        c: Completion,
    },
    /// Reader-originated control frame (RegisterOk / Busy / Error).
    Ctrl { seq: u64, frame: Frame },
}

/// Spawn the reader/writer pair for one accepted connection. Returns
/// the reader's join handle plus a stream clone the accept loop uses
/// to unblock the reader at shutdown.
fn spawn_connection(
    stream: TcpStream,
    engine: EngineHandle,
    metrics: Arc<SrvMetrics>,
    registry: Arc<MetricsRegistry>,
    phase: Arc<SrvPhaseHists>,
    cfg: SrvConfig,
) -> std::io::Result<(JoinHandle<()>, TcpStream)> {
    let _ = stream.set_nodelay(true);
    // BSD-derived platforms (macOS) make accepted sockets inherit the
    // listener's O_NONBLOCK; the reader/writer loops are blocking by
    // design, so reset it explicitly (no-op on Linux)
    let _ = stream.set_nonblocking(false);
    if cfg.read_timeout_secs > 0 {
        let _ = stream.set_read_timeout(Some(Duration::from_secs(
            cfg.read_timeout_secs,
        )));
    }
    let control = stream.try_clone()?;
    let wstream = stream.try_clone()?;
    // a client that stops reading cannot wedge teardown: a stalled
    // response write fails after the timeout and the writer exits
    let _ = wstream
        .set_write_timeout(Some(Duration::from_secs(5)));
    let (wtx, wrx) = mpsc::channel::<WriterMsg>();
    let backlog = Arc::new(AtomicU64::new(0));
    metrics.conn_opened();
    let wmetrics = Arc::clone(&metrics);
    let wbacklog = Arc::clone(&backlog);
    let wphase = Arc::clone(&phase);
    let writer = std::thread::spawn(move || {
        writer_loop(wstream, wrx, wmetrics, wbacklog, wphase)
    });
    let h = std::thread::spawn(move || {
        reader_loop(stream, engine, wtx, &metrics, &registry, backlog, cfg);
        // reader done: drop our sender; writer exits once in-flight
        // completions (whose closures hold the other clones) land
        let _ = writer.join();
        metrics.conn_closed();
    });
    Ok((h, control))
}

/// Writer thread: serialize completions + control frames. Bursts are
/// drained greedily and flushed once, so pipelined responses share
/// syscalls without adding latency to a lone response. Frames are
/// encoded straight into the reused batch buffer
/// ([`encode_frame_into`]) — the steady-state send path performs no
/// per-frame allocation and no intermediate copy.
fn writer_loop(
    mut stream: TcpStream,
    rx: mpsc::Receiver<WriterMsg>,
    metrics: Arc<SrvMetrics>,
    backlog: Arc<AtomicU64>,
    phase: Arc<SrvPhaseHists>,
) {
    let mut buf: Vec<u8> = Vec::with_capacity(4096);
    loop {
        let first = match rx.recv() {
            Ok(m) => m,
            Err(_) => return, // all senders gone: connection finished
        };
        buf.clear();
        let mut batch = Some(first);
        // all sent-side counters (frames out, busy, errors, response
        // latencies) are applied only after write_all succeeds — a
        // torn connection must not report unsent frames as sent.
        // Per entry: e2e ns, the per-program series, the encode stamp
        // (attributed responses only — the write-backlog slice).
        let mut pending_e2e: Vec<(
            u64,
            Option<Arc<AtomicHist>>,
            Option<Instant>,
        )> = Vec::new();
        let mut frames = 0u64;
        let mut busy = 0u64;
        let mut errors = 0u64;
        while let Some(m) = batch.take() {
            backlog.fetch_sub(1, Ordering::Relaxed);
            match m {
                WriterMsg::Done { seq, t0, t_done, prog_e2e, c } => {
                    let timing =
                        resp_timing(&c, t0, t_done, &phase);
                    let frame = completion_frame(&c, timing);
                    match &frame {
                        Frame::Busy => busy += 1,
                        Frame::Error { .. } => errors += 1,
                        _ => pending_e2e.push((
                            t0.elapsed().as_nanos() as u64,
                            prog_e2e,
                            timing.map(|_| Instant::now()),
                        )),
                    }
                    encode_frame_into(seq, &frame, &mut buf);
                }
                WriterMsg::Ctrl { seq, frame } => {
                    match &frame {
                        Frame::Busy => busy += 1,
                        Frame::Error { .. } => errors += 1,
                        _ => {}
                    }
                    encode_frame_into(seq, &frame, &mut buf);
                }
            }
            frames += 1;
            if buf.len() < 64 * 1024 {
                batch = rx.try_recv().ok();
            }
        }
        if stream.write_all(&buf).is_err() {
            // a dead or stalled-past-timeout client: shut the whole
            // socket down so the reader sees EOF and tears the
            // connection down too — otherwise the conn sits half-open
            // with the reader executing requests whose responses go
            // nowhere while a pipelined client waits forever
            let _ = stream.shutdown(Shutdown::Both);
            return;
        }
        metrics.sent_batch(frames, busy, errors);
        for (ns, prog_e2e, encoded_at) in pending_e2e {
            metrics.response(ns);
            if let Some(h) = prog_e2e {
                h.record(ns.max(1));
            }
            if let Some(t) = encoded_at {
                phase
                    .write
                    .record((t.elapsed().as_nanos() as u64).max(1));
            }
        }
    }
}

/// Build the wire timing block for an attributed completion: the
/// engine's phase slices plus the serving-tier completion slice
/// (done-callback → pickup, recorded into `srv.phase.completion`
/// here) and the total server residence at encode time. `None` for
/// unattributed completions — the caller emits the legacy frame.
pub(crate) fn resp_timing(
    c: &Completion,
    t0: Instant,
    t_done: Option<Instant>,
    phase: &SrvPhaseHists,
) -> Option<RespTiming> {
    let ph = c.phases.as_ref()?;
    let completion_ns = t_done
        .map(|t| t.elapsed().as_nanos() as u64)
        .unwrap_or(0);
    phase.completion.record(completion_ns.max(1));
    Some(RespTiming {
        queue_ns: ph.queue_ns,
        exec_ns: ph.exec_ns,
        transit_ns: ph.transit_ns,
        completion_ns,
        server_ns: (t0.elapsed().as_nanos() as u64).max(1),
        op: ph.op,
        visits: ph.visits,
        traced: ph.traced,
    })
}

/// Engine completion → wire frame, shared verbatim by the event-loop
/// sessions and the legacy writer so both paths answer identical
/// bytes for identical completions. `timing` is `Some` only for
/// attributed responses (BUSY / shutting-down frames never carry a
/// block — those ops never executed).
pub(crate) fn completion_frame(
    c: &Completion,
    timing: Option<RespTiming>,
) -> Frame {
    match c.code {
        CompletionCode::Done(status) => Frame::Response {
            status,
            crossings: c.crossings,
            iters: c.iters,
            sp: c.sp,
            timing,
        },
        CompletionCode::Busy => Frame::Busy,
        CompletionCode::ShuttingDown => Frame::Error {
            code: ErrCode::ShuttingDown,
            msg: "server draining".into(),
        },
    }
}

/// Reader thread: frame in, decode, dispatch. Decode failures answer
/// ERROR and continue while the frame boundary holds; unframeable
/// garbage (bad magic/version, oversize, torn stream) closes the
/// connection after a best-effort ERROR.
fn reader_loop(
    stream: TcpStream,
    engine: EngineHandle,
    wtx: mpsc::Sender<WriterMsg>,
    metrics: &SrvMetrics,
    registry: &MetricsRegistry,
    backlog: Arc<AtomicU64>,
    cfg: SrvConfig,
) {
    let mut programs: HashMap<u32, ProgEntry> = HashMap::new();
    // per-connection attribution mode, armed by the REGISTER flag bit
    // (negotiated once; stays on for the connection's lifetime)
    let mut timing = false;
    let mut r = BufReader::new(stream);
    // per-connection decode scratch, reused across frames (capacity
    // settles at the connection's largest frame and stays there)
    let mut payload: Vec<u8> = Vec::new();
    let ctrl = |seq: u64, frame: Frame| {
        backlog.fetch_add(1, Ordering::Relaxed);
        let _ = wtx.send(WriterMsg::Ctrl { seq, frame });
    };
    let err =
        |seq: u64, code: ErrCode, msg: &str| {
            ctrl(seq, Frame::Error { code, msg: msg.into() })
        };
    loop {
        match read_frame_into(&mut r, cfg.max_frame, &mut payload) {
            FrameEvent::Frame => {}
            FrameEvent::Eof => return,
            // idle at a frame boundary: nothing consumed, keep waiting
            FrameEvent::Idle => continue,
            FrameEvent::Oversize(n) => {
                metrics.decode_error();
                err(
                    0,
                    ErrCode::Oversize,
                    &format!("unframeable length {n}"),
                );
                return;
            }
            FrameEvent::Io(_) => return,
        }
        metrics.frame_in();
        // non-draining-client guard, on EVERY frame kind: whatever the
        // client streams (requests, re-registrations, garbage), once
        // its unread responses pass the cap it gets cut loose instead
        // of growing the writer queue without bound
        if backlog.load(Ordering::Relaxed) >= cfg.max_conn_backlog {
            metrics.backlog_drop();
            err(0, ErrCode::Backlog, "response backlog exceeded; closing");
            return;
        }
        let env = match decode_payload(&payload) {
            Ok(env) => env,
            Err(e) => {
                metrics.decode_error();
                err(e.seq, e.kind.err_code(), &format!("{:?}", e.kind));
                if e.kind.is_fatal() {
                    return;
                }
                continue;
            }
        };
        match env.frame {
            Frame::Register { id: raw_id, program } => {
                // bit 31 of the id is the timing-attribution flag: it
                // arms per-request breakdowns for this connection and
                // is masked off before the id is used — the masked id
                // is echoed in REGISTER_OK, which is how the client
                // learns the server understood the negotiation (an
                // old server would echo the flagged value verbatim)
                let id = raw_id & !REGISTER_FLAG_TIMING;
                if raw_id & REGISTER_FLAG_TIMING != 0 {
                    timing = true;
                }
                // a frame that decoded but carries an unverifiable or
                // analyzer-denied program is a semantic rejection, not
                // wire corruption: it answers ERROR (counted by the
                // writer as errors_sent) without touching the
                // decode_errors counter
                if let Err(e) = vet_program(&program, cfg.allow_writes)
                {
                    err(env.seq, ErrCode::BadProgram, &e);
                    continue;
                }
                // bounded like every other client-controlled edge:
                // past the cap, new ids shed explicitly (existing ids
                // may still be re-registered)
                if !programs.contains_key(&id)
                    && programs.len() >= cfg.max_programs
                {
                    err(
                        env.seq,
                        ErrCode::Backlog,
                        "program table full",
                    );
                    continue;
                }
                // per-program latency series exist only for timed
                // connections, bounded by the same max_programs cap
                // (labeled_hist returns None past it — aggregate only)
                let (e2e, exec) = if timing {
                    (
                        registry.labeled_hist(
                            "srv.e2e",
                            id,
                            cfg.max_programs,
                        ),
                        registry.labeled_hist(
                            "engine.execute",
                            id,
                            cfg.max_programs,
                        ),
                    )
                } else {
                    (None, None)
                };
                programs.insert(
                    id,
                    ProgEntry {
                        iter: Arc::new(CompiledIter::new(program)),
                        e2e,
                        exec,
                    },
                );
                metrics.program_registered();
                ctrl(env.seq, Frame::RegisterOk { id });
            }
            Frame::Request { prog, budget, start, sp } => {
                metrics.request();
                let Some(entry) = programs.get(&prog) else {
                    err(
                        env.seq,
                        ErrCode::UnknownProgram,
                        &format!("program id {prog} not registered"),
                    );
                    continue;
                };
                let seq = env.seq;
                let t0 = Instant::now();
                let done_tx = wtx.clone();
                let done_backlog = Arc::clone(&backlog);
                let prog_e2e =
                    if timing { entry.e2e.clone() } else { None };
                let sub = Submission {
                    iter: Arc::clone(&entry.iter),
                    start,
                    sp,
                    budget,
                    tag: seq,
                    t0: timing.then_some(t0),
                    exec_hist: if timing {
                        entry.exec.clone()
                    } else {
                        None
                    },
                    done: Box::new(move |c| {
                        // the extra clock read exists only on
                        // attributed completions
                        let t_done =
                            c.phases.is_some().then(Instant::now);
                        done_backlog.fetch_add(1, Ordering::Relaxed);
                        let _ = done_tx.send(WriterMsg::Done {
                            seq,
                            t0,
                            t_done,
                            prog_e2e,
                            c,
                        });
                    }),
                };
                match engine.try_submit(sub) {
                    Ok(()) => {}
                    Err(SubmitError::Busy(_)) => {
                        ctrl(seq, Frame::Busy)
                    }
                    Err(SubmitError::Down(_)) => {
                        err(
                            seq,
                            ErrCode::ShuttingDown,
                            "server draining",
                        );
                        return;
                    }
                }
            }
            Frame::Stats => {
                // registry snapshot as one JSON object; the body is
                // opaque to the wire layer, so new metrics are not a
                // protocol change
                ctrl(
                    env.seq,
                    Frame::StatsOk {
                        body: registry.snapshot().render(),
                    },
                );
            }
            // a server never expects client-bound kinds
            Frame::RegisterOk { .. }
            | Frame::Response { .. }
            | Frame::Busy
            | Frame::Error { .. }
            | Frame::StatsOk { .. } => {
                err(
                    env.seq,
                    ErrCode::UnexpectedKind,
                    "client sent a server-to-client frame",
                );
            }
        }
    }
}
