//! Traversal request/response message.

use crate::isa::{Program, Status, SP_WORDS};

/// Request identity: CPU node id + per-node sequence number (paper §4.1:
//  "embeds a request ID with the CPU node ID and a local request
//  counter" for retransmission).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RequestId {
    pub cpu_node: u16,
    pub seq: u64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum MsgKind {
    /// CPU node -> switch -> memory node (or memnode -> switch -> memnode
    /// for distributed continuation).
    Request = 0,
    /// Memory node -> switch -> CPU node, carrying the final scratchpad.
    Response = 1,
}

/// The single message format used on every hop.
#[derive(Debug, Clone, PartialEq)]
pub struct TraversalMsg {
    pub kind: MsgKind,
    pub id: RequestId,
    pub program: Program,
    pub cur_ptr: u64,
    pub sp: [i64; SP_WORDS],
    /// Iterations already executed (for the max-iteration bound, §3).
    pub iters_done: u32,
    /// Budget; exceeding it yields back to the CPU node.
    pub max_iters: u32,
    /// Terminal status (responses only; `Status::Running` while in
    /// flight, which doubles as "continue on another node" when a
    /// request bounces).
    pub status: Status,
    /// Memory-node hops this traversal has made (metrics: Fig. 2c CDF).
    pub node_crossings: u32,
}

impl TraversalMsg {
    pub fn request(
        id: RequestId,
        program: Program,
        cur_ptr: u64,
        sp: [i64; SP_WORDS],
        max_iters: u32,
    ) -> Self {
        Self {
            kind: MsgKind::Request,
            id,
            program,
            cur_ptr,
            sp,
            iters_done: 0,
            max_iters,
            status: Status::Running,
            node_crossings: 0,
        }
    }

    /// Wire size in bytes (for link serialization accounting):
    /// eth+ip+udp headers (42) + pulse header (32) + program + sp.
    pub fn wire_size(&self) -> usize {
        42 + 32 + self.program.wire_size() + SP_WORDS * 8
    }

    /// Serialize (used by the byte-level transport tests; the in-process
    /// rack passes the struct directly but sizes/loss come from this).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.wire_size());
        out.push(self.kind as u8);
        out.push(0); // pad
        out.extend_from_slice(&self.id.cpu_node.to_le_bytes());
        out.extend_from_slice(&self.id.seq.to_le_bytes());
        out.extend_from_slice(&self.cur_ptr.to_le_bytes());
        out.extend_from_slice(&self.iters_done.to_le_bytes());
        out.extend_from_slice(&self.max_iters.to_le_bytes());
        out.push(self.status as i32 as u8);
        out.extend_from_slice(&self.node_crossings.to_le_bytes());
        for w in &self.sp {
            out.extend_from_slice(&w.to_le_bytes());
        }
        out.extend_from_slice(&self.program.encode());
        out
    }

    pub fn decode(buf: &[u8]) -> Option<Self> {
        if buf.len() < 39 + SP_WORDS * 8 {
            return None;
        }
        let kind = match buf[0] {
            0 => MsgKind::Request,
            1 => MsgKind::Response,
            _ => return None,
        };
        let cpu_node = u16::from_le_bytes([buf[2], buf[3]]);
        let seq = u64::from_le_bytes(buf[4..12].try_into().ok()?);
        let cur_ptr = u64::from_le_bytes(buf[12..20].try_into().ok()?);
        let iters_done = u32::from_le_bytes(buf[20..24].try_into().ok()?);
        let max_iters = u32::from_le_bytes(buf[24..28].try_into().ok()?);
        let status = Status::from_i32(buf[28] as i32);
        let node_crossings =
            u32::from_le_bytes(buf[29..33].try_into().ok()?);
        let mut sp = [0i64; SP_WORDS];
        let sp_off = 33;
        for (i, w) in sp.iter_mut().enumerate() {
            let p = sp_off + i * 8;
            *w = i64::from_le_bytes(buf[p..p + 8].try_into().ok()?);
        }
        let program = Program::decode(&buf[sp_off + SP_WORDS * 8..])?;
        Some(Self {
            kind,
            id: RequestId { cpu_node, seq },
            program,
            cur_ptr,
            sp,
            iters_done,
            max_iters,
            status,
            node_crossings,
        })
    }

    /// Turn an in-flight request into the response form, preserving all
    /// traversal state (the formats are identical by design, §5).
    pub fn into_response(mut self, status: Status) -> Self {
        self.kind = MsgKind::Response;
        self.status = status;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Asm;

    fn sample_program() -> Program {
        let mut a = Asm::new();
        a.ldd(1, 2);
        a.mov(0, 1);
        a.next();
        a.finish(3).unwrap()
    }

    fn sample_msg() -> TraversalMsg {
        let mut sp = [0i64; SP_WORDS];
        sp[0] = -7;
        sp[31] = i64::MAX;
        TraversalMsg::request(
            RequestId { cpu_node: 3, seq: 12345 },
            sample_program(),
            0xDEAD_BEE0,
            sp,
            64,
        )
    }

    #[test]
    fn encode_decode_round_trip() {
        let m = sample_msg();
        let buf = m.encode();
        let back = TraversalMsg::decode(&buf).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn response_preserves_state() {
        let m = sample_msg();
        let cur = m.cur_ptr;
        let r = m.clone().into_response(Status::Return);
        assert_eq!(r.kind, MsgKind::Response);
        assert_eq!(r.status, Status::Return);
        assert_eq!(r.cur_ptr, cur);
        assert_eq!(r.sp, m.sp);
        // round-trips too
        let back = TraversalMsg::decode(&r.encode()).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn wire_size_matches_encoding_plus_headers() {
        let m = sample_msg();
        // encode() omits the 42B ethernet/ip/udp headers and the 32-byte
        // header is compressed; wire_size is the on-link estimate.
        assert!(m.wire_size() >= m.encode().len());
        assert!(m.wire_size() < m.encode().len() + 64);
    }

    #[test]
    fn decode_rejects_truncated() {
        let m = sample_msg();
        let buf = m.encode();
        assert!(TraversalMsg::decode(&buf[..20]).is_none());
        let mut bad = buf.clone();
        bad[0] = 9;
        assert!(TraversalMsg::decode(&bad).is_none());
    }
}
