//! Traversal request/response message.

use std::sync::Arc;

use crate::isa::{Program, Status, SP_WORDS};

/// Request identity: CPU node id + per-node sequence number (paper §4.1:
//  "embeds a request ID with the CPU node ID and a local request
//  counter" for retransmission).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RequestId {
    pub cpu_node: u16,
    pub seq: u64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum MsgKind {
    /// CPU node -> switch -> memory node (or memnode -> switch -> memnode
    /// for distributed continuation).
    Request = 0,
    /// Memory node -> switch -> CPU node, carrying the final scratchpad.
    Response = 1,
}

/// The single message format used on every hop.
///
/// The program rides as `Arc<Program>`: dispatching, forwarding, and
/// cloning a message (retransmit buffers) bump a refcount rather than
/// deep-copying the instruction stream. `PartialEq` still compares
/// program *contents* (`Arc<T>: PartialEq` delegates to `T`), and the
/// wire codec is unchanged — encode writes the program body, decode
/// materializes a fresh Arc.
#[derive(Debug, Clone, PartialEq)]
pub struct TraversalMsg {
    pub kind: MsgKind,
    pub id: RequestId,
    pub program: Arc<Program>,
    pub cur_ptr: u64,
    pub sp: [i64; SP_WORDS],
    /// Iterations already executed (for the max-iteration bound, §3).
    pub iters_done: u32,
    /// Budget; exceeding it yields back to the CPU node.
    pub max_iters: u32,
    /// Terminal status (responses only; `Status::Running` while in
    /// flight, which doubles as "continue on another node" when a
    /// request bounces).
    pub status: Status,
    /// Memory-node hops this traversal has made (metrics: Fig. 2c CDF).
    pub node_crossings: u32,
}

impl TraversalMsg {
    /// `program` accepts either a bare `Program` (wrapped into a fresh
    /// Arc — convenient in tests) or an `Arc<Program>` clone from a
    /// `CompiledIter` (the zero-copy dispatch path).
    pub fn request(
        id: RequestId,
        program: impl Into<Arc<Program>>,
        cur_ptr: u64,
        sp: [i64; SP_WORDS],
        max_iters: u32,
    ) -> Self {
        Self {
            kind: MsgKind::Request,
            id,
            program: program.into(),
            cur_ptr,
            sp,
            iters_done: 0,
            max_iters,
            status: Status::Running,
            node_crossings: 0,
        }
    }

    /// Wire size in bytes (for link serialization accounting):
    /// eth+ip+udp headers (42) + pulse header (32) + program + sp.
    pub fn wire_size(&self) -> usize {
        Self::wire_size_for(&self.program)
    }

    /// [`TraversalMsg::wire_size`] from the program alone — single
    /// definition of the on-link size formula, so byte accounting that
    /// never materializes a message (the serving tier's inline
    /// executor) cannot drift from the link layer's.
    pub fn wire_size_for(program: &Program) -> usize {
        42 + 32 + program.wire_size() + SP_WORDS * 8
    }

    /// Serialize (used by the byte-level transport tests; the in-process
    /// rack passes the struct directly but sizes/loss come from this).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.wire_size());
        out.push(self.kind as u8);
        out.push(0); // pad
        out.extend_from_slice(&self.id.cpu_node.to_le_bytes());
        out.extend_from_slice(&self.id.seq.to_le_bytes());
        out.extend_from_slice(&self.cur_ptr.to_le_bytes());
        out.extend_from_slice(&self.iters_done.to_le_bytes());
        out.extend_from_slice(&self.max_iters.to_le_bytes());
        out.push(self.status as i32 as u8);
        out.extend_from_slice(&self.node_crossings.to_le_bytes());
        for w in &self.sp {
            out.extend_from_slice(&w.to_le_bytes());
        }
        out.extend_from_slice(&self.program.encode());
        out
    }

    /// Canonical decode: strict inverse of [`TraversalMsg::encode`].
    /// Shared by the byte-level transport tests and (via `srv::wire`'s
    /// frame bodies) the socket tier, so rejection is total: unknown
    /// kind or status bytes, a nonzero pad, an undecodable program, or
    /// any length mismatch — including trailing garbage after the
    /// program — all return `None` rather than decoding to a message
    /// that would re-encode differently.
    pub fn decode(buf: &[u8]) -> Option<Self> {
        if buf.len() < 39 + SP_WORDS * 8 {
            return None;
        }
        let kind = match buf[0] {
            0 => MsgKind::Request,
            1 => MsgKind::Response,
            _ => return None,
        };
        if buf[1] != 0 {
            return None; // pad byte is part of the canonical form
        }
        let cpu_node = u16::from_le_bytes([buf[2], buf[3]]);
        let seq = u64::from_le_bytes(buf[4..12].try_into().ok()?);
        let cur_ptr = u64::from_le_bytes(buf[12..20].try_into().ok()?);
        let iters_done = u32::from_le_bytes(buf[20..24].try_into().ok()?);
        let max_iters = u32::from_le_bytes(buf[24..28].try_into().ok()?);
        if buf[28] > 3 {
            return None; // Status is 0..=3; nothing else round-trips
        }
        let status = Status::from_i32(buf[28] as i32);
        let node_crossings =
            u32::from_le_bytes(buf[29..33].try_into().ok()?);
        let mut sp = [0i64; SP_WORDS];
        let sp_off = 33;
        for (i, w) in sp.iter_mut().enumerate() {
            let p = sp_off + i * 8;
            *w = i64::from_le_bytes(buf[p..p + 8].try_into().ok()?);
        }
        let prog_off = sp_off + SP_WORDS * 8;
        let program = Program::decode(&buf[prog_off..])?;
        if prog_off + program.wire_size() != buf.len() {
            return None; // trailing bytes: not a canonical encoding
        }
        Some(Self {
            kind,
            id: RequestId { cpu_node, seq },
            program: Arc::new(program),
            cur_ptr,
            sp,
            iters_done,
            max_iters,
            status,
            node_crossings,
        })
    }

    /// Turn an in-flight request into the response form, preserving all
    /// traversal state (the formats are identical by design, §5).
    pub fn into_response(mut self, status: Status) -> Self {
        self.kind = MsgKind::Response;
        self.status = status;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Asm;

    fn sample_program() -> Program {
        let mut a = Asm::new();
        a.ldd(1, 2);
        a.mov(0, 1);
        a.next();
        a.finish(3).unwrap()
    }

    fn sample_msg() -> TraversalMsg {
        let mut sp = [0i64; SP_WORDS];
        sp[0] = -7;
        sp[31] = i64::MAX;
        TraversalMsg::request(
            RequestId { cpu_node: 3, seq: 12345 },
            sample_program(),
            0xDEAD_BEE0,
            sp,
            64,
        )
    }

    #[test]
    fn encode_decode_round_trip() {
        let m = sample_msg();
        let buf = m.encode();
        let back = TraversalMsg::decode(&buf).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn response_preserves_state() {
        let m = sample_msg();
        let cur = m.cur_ptr;
        let r = m.clone().into_response(Status::Return);
        assert_eq!(r.kind, MsgKind::Response);
        assert_eq!(r.status, Status::Return);
        assert_eq!(r.cur_ptr, cur);
        assert_eq!(r.sp, m.sp);
        // round-trips too
        let back = TraversalMsg::decode(&r.encode()).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn wire_size_matches_encoding_plus_headers() {
        let m = sample_msg();
        // encode() omits the 42B ethernet/ip/udp headers and the 32-byte
        // header is compressed; wire_size is the on-link estimate.
        assert!(m.wire_size() >= m.encode().len());
        assert!(m.wire_size() < m.encode().len() + 64);
    }

    /// Zero-copy invariant: a request built from an `Arc<Program>`
    /// *shares* it — no hidden deep clone on construction, on message
    /// clone (retransmit buffers), or on the request→response flip
    /// (the forward/finish path reuses the same struct).
    #[test]
    fn request_shares_the_program_arc() {
        let p = Arc::new(sample_program());
        let m = TraversalMsg::request(
            RequestId { cpu_node: 1, seq: 1 },
            Arc::clone(&p),
            0x1000,
            [0i64; SP_WORDS],
            64,
        );
        assert!(Arc::ptr_eq(&m.program, &p));
        let copy = m.clone();
        assert!(Arc::ptr_eq(&copy.program, &p));
        let resp = copy.into_response(Status::Return);
        assert!(Arc::ptr_eq(&resp.program, &p));
    }

    #[test]
    fn decode_rejects_truncated() {
        let m = sample_msg();
        let buf = m.encode();
        assert!(TraversalMsg::decode(&buf[..20]).is_none());
        let mut bad = buf.clone();
        bad[0] = 9;
        assert!(TraversalMsg::decode(&bad).is_none());
    }

    #[test]
    fn decode_rejects_non_canonical_forms() {
        let buf = sample_msg().encode();
        // trailing garbage after the program
        let mut padded = buf.clone();
        padded.push(0xAB);
        assert!(TraversalMsg::decode(&padded).is_none());
        // nonzero pad byte
        let mut bad = buf.clone();
        bad[1] = 1;
        assert!(TraversalMsg::decode(&bad).is_none());
        // status byte outside 0..=3 (used to alias to Trap)
        let mut bad = buf.clone();
        bad[28] = 200;
        assert!(TraversalMsg::decode(&bad).is_none());
    }

    /// Randomized canonical round trip at pinned seeds: arbitrary
    /// verified programs + arbitrary traversal state encode/decode to
    /// the identical message, and re-encoding is byte-identical
    /// (`decode ∘ encode = id` and `encode ∘ decode ∘ encode =
    /// encode`). Server and load generator share this codec, so this
    /// property is what keeps the two from skewing.
    #[test]
    fn randomized_round_trip_at_pinned_seeds() {
        crate::util::ptest::run_prop(
            "traversal_msg_round_trip",
            0x7EA_15E5,
            200,
            |rng| {
                let program =
                    crate::testgen::random_verified_program(rng, 24);
                let mut sp = [0i64; SP_WORDS];
                for w in sp.iter_mut() {
                    *w = rng.next_i64();
                }
                let mut m = TraversalMsg::request(
                    RequestId {
                        cpu_node: (rng.below(1 << 16)) as u16,
                        seq: rng.next_i64() as u64,
                    },
                    program,
                    rng.next_i64() as u64,
                    sp,
                    1 + rng.below(1 << 20) as u32,
                );
                m.iters_done = rng.below(1 << 20) as u32;
                m.node_crossings = rng.below(64) as u32;
                if rng.chance(0.5) {
                    m = m.into_response(if rng.chance(0.2) {
                        Status::Trap
                    } else {
                        Status::Return
                    });
                }
                let bytes = m.encode();
                let back = match TraversalMsg::decode(&bytes) {
                    Some(b) => b,
                    None => {
                        return Err("canonical encoding rejected".into())
                    }
                };
                crate::prop_assert_eq!(back, m);
                crate::prop_assert_eq!(back.encode(), bytes);
                Ok(())
            },
        );
    }

    /// Any single-byte corruption either fails to decode or decodes
    /// to a visibly different message — there is no byte the codec
    /// silently ignores.
    #[test]
    fn corruption_never_decodes_to_the_same_message() {
        crate::util::ptest::run_prop(
            "traversal_msg_corruption",
            0xC0_44E7,
            50,
            |rng| {
                let program =
                    crate::testgen::random_verified_program(rng, 16);
                let mut sp = [0i64; SP_WORDS];
                sp[0] = rng.next_i64();
                let m = TraversalMsg::request(
                    RequestId { cpu_node: 1, seq: 7 },
                    program,
                    0x4000,
                    sp,
                    64,
                );
                let bytes = m.encode();
                let pos = rng.below(bytes.len() as u64) as usize;
                let mut bad = bytes.clone();
                bad[pos] ^= 1 + rng.below(255) as u8;
                if let Some(back) = TraversalMsg::decode(&bad) {
                    crate::prop_assert!(
                        back != m,
                        "flip at byte {pos} was invisible"
                    );
                }
                Ok(())
            },
        );
    }
}
