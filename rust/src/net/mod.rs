//! Wire formats + simulated transport.
//!
//! PULSE requests and responses share one format (paper §4.2 network
//! stack / §5): `{request id, program code, cur_ptr, scratch_pad,
//! iteration budget}` — identical layouts are what let a memory node
//! bounce an in-flight traversal to the switch for re-routing without
//! CPU-node involvement.

// Hot-path modules keep clones honest: a clone the borrow checker
// would let us drop is a bug here, not a style nit.
#![deny(clippy::redundant_clone)]

pub mod message;
pub mod transport;

pub use message::{MsgKind, RequestId, TraversalMsg};
pub use transport::{Link, LinkStats};
