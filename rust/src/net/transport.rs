//! Simulated link: latency + serialization + loss + byte accounting.
//!
//! The rack is a single-process discrete-event simulation, so a link
//! does not move bytes — it computes *when* a message arrives (or that
//! it was dropped) and meters bandwidth for the utilization figures
//! (Appendix C.1). Retransmission on loss is the dispatch engine's job
//! (paper §4.1), exercised by `integration_distributed.rs`.

use crate::sim::{LatencyModel, Ns};
use crate::util::prng::Rng;

#[derive(Debug, Default, Clone, Copy)]
pub struct LinkStats {
    pub messages: u64,
    pub bytes: u64,
    pub dropped: u64,
}

/// A unidirectional link segment (host->switch, switch->node, ...).
#[derive(Debug)]
pub struct Link {
    /// Fixed one-way latency for this segment (propagation + stacks).
    pub latency_ns: Ns,
    /// Serialization bandwidth, bytes per ns.
    pub bytes_per_ns: f64,
    /// Packet loss probability.
    pub loss: f64,
    rng: Rng,
    /// Time the head of the link is next free (serialization is the
    /// contended resource — models NIC egress queueing).
    next_free: Ns,
    pub stats: LinkStats,
}

impl Link {
    pub fn new(latency_ns: Ns, bytes_per_ns: f64, loss: f64, seed: u64) -> Self {
        Self {
            latency_ns,
            bytes_per_ns,
            loss,
            rng: Rng::with_stream(seed, 0x11AE),
            next_free: 0,
            stats: LinkStats::default(),
        }
    }

    pub fn from_model(m: &LatencyModel, loss: f64, seed: u64) -> Self {
        Self::new(
            (m.host_net_stack_ns + m.net_hop_ns) as Ns,
            m.link_bytes_per_ns,
            loss,
            seed,
        )
    }

    /// Send `bytes` at time `now`; returns arrival time or None if the
    /// packet was dropped. Updates egress-queue occupancy and counters.
    pub fn send(&mut self, now: Ns, bytes: usize) -> Option<Ns> {
        self.stats.messages += 1;
        self.stats.bytes += bytes as u64;
        let ser = (bytes as f64 / self.bytes_per_ns).ceil() as Ns;
        let start = now.max(self.next_free);
        self.next_free = start + ser;
        if self.loss > 0.0 && self.rng.chance(self.loss) {
            self.stats.dropped += 1;
            return None;
        }
        Some(start + ser + self.latency_ns)
    }

    /// Achieved goodput over an interval, bytes/ns.
    pub fn goodput(&self, elapsed: Ns) -> f64 {
        if elapsed == 0 {
            0.0
        } else {
            self.stats.bytes as f64 / elapsed as f64
        }
    }

    pub fn reset(&mut self) {
        self.stats = LinkStats::default();
        self.next_free = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_plus_serialization() {
        let mut l = Link::new(1000, 12.5, 0.0, 1);
        // 125 bytes at 12.5 B/ns = 10 ns serialization
        assert_eq!(l.send(0, 125), Some(1010));
    }

    #[test]
    fn egress_queueing_backs_up() {
        let mut l = Link::new(1000, 12.5, 0.0, 1);
        let a = l.send(0, 12_500).unwrap(); // 1000 ns ser
        let b = l.send(0, 12_500).unwrap(); // queued behind the first
        assert_eq!(a, 2000);
        assert_eq!(b, 3000);
        // after the queue drains, latency resets
        let c = l.send(10_000, 125).unwrap();
        assert_eq!(c, 11_010);
    }

    #[test]
    fn loss_drops_expected_fraction() {
        let mut l = Link::new(0, 1e9, 0.3, 7);
        let mut dropped = 0;
        for _ in 0..10_000 {
            if l.send(0, 1).is_none() {
                dropped += 1;
            }
        }
        let frac = dropped as f64 / 10_000.0;
        assert!((frac - 0.3).abs() < 0.03, "{frac}");
        assert_eq!(l.stats.dropped, dropped);
    }

    #[test]
    fn stats_meter_bytes() {
        let mut l = Link::new(0, 12.5, 0.0, 1);
        l.send(0, 100);
        l.send(0, 200);
        assert_eq!(l.stats.messages, 2);
        assert_eq!(l.stats.bytes, 300);
        assert!(l.goodput(100) > 0.0);
        l.reset();
        assert_eq!(l.stats.bytes, 0);
    }
}
